//! Offline API stub of the `xla` crate's PJRT surface.
//!
//! The real `xla` crate links `xla_extension` (PJRT CPU client) and is
//! not available in the hermetic build environment. This stub exposes
//! the exact API subset `da4ml::runtime::pjrt` compiles against so the
//! `pjrt` feature can be *built* anywhere; every runtime entry point
//! returns an explanatory error. To execute real HLO artifacts, replace
//! this path dependency with the actual `xla` crate (same API) and
//! rebuild with `--features pjrt`.

use std::fmt;
use std::path::Path;

/// Error type mirroring the real crate's opaque error.
#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

/// Stub-local result type.
pub type Result<T> = std::result::Result<T, XlaError>;

fn stub_err() -> XlaError {
    XlaError(
        "xla stub: the PJRT runtime is not linked in this offline build; \
         swap vendor/xla for the real xla crate to execute HLO artifacts"
            .to_string(),
    )
}

/// Marker trait for element types a [`Literal`] can carry.
pub trait NativeType: Copy {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for f32 {}
impl NativeType for f64 {}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create a CPU client — always errors in the stub.
    pub fn cpu() -> Result<Self> {
        Err(stub_err())
    }

    /// Platform name reported by PJRT.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation — always errors in the stub.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_err())
    }
}

/// Parsed HLO module (stub: parsing always fails).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO **text** file — always errors in the stub.
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self> {
        Err(stub_err())
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

/// A compiled executable (stub: unreachable, the client never compiles).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute on device — always errors in the stub.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err())
    }
}

/// A device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy back to a host literal — always errors in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_err())
    }
}

/// Array shape: element dims (the real crate also carries a dtype).
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    /// Dimension sizes.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// An XLA shape.
pub enum Shape {
    /// A dense array shape.
    Array(ArrayShape),
    /// A tuple of shapes.
    Tuple(Vec<Shape>),
}

/// A host-side literal value.
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal { _private: () }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _private: () })
    }

    /// Split a tuple literal into its elements — stub: always errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(stub_err())
    }

    /// The literal's shape — stub: always errors.
    pub fn shape(&self) -> Result<Shape> {
        Err(stub_err())
    }

    /// Copy the elements out — stub: always errors.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(stub_err())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_surfaces_errors_not_panics() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo.txt").is_err());
        let lit = Literal::vec1(&[1i32, 2, 3]).reshape(&[3]).unwrap();
        assert!(lit.to_vec::<i32>().is_err());
        assert!(lit.shape().is_err());
        assert!(lit.to_tuple().is_err());
    }
}
