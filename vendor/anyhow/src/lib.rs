//! A minimal, offline-compatible subset of the `anyhow` error-handling
//! crate, vendored so the workspace builds hermetically (no network, no
//! registry). Only the surface the da4ml crate actually uses is
//! implemented:
//!
//! * [`Error`] — an opaque, context-carrying error value;
//! * [`Result<T>`] — `Result<T, Error>` with a defaulted error type;
//! * [`anyhow!`], [`bail!`], [`ensure!`] — message/guard macros;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on results.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`; that keeps the blanket `From<E: std::error::Error>`
//! conversion coherent with the reflexive `From<Error> for Error`, so `?`
//! works both on foreign errors and on already-`anyhow` results.

use std::fmt;

/// An opaque error: a chain of human-readable context frames, outermost
/// first, ending in the root-cause message.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context frame.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// Iterate over the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Flatten the source chain into the message so nothing is lost.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(cause) = src {
            msg.push_str(": ");
            msg.push_str(&cause.to_string());
            src = cause.source();
        }
        Self { chain: vec![msg] }
    }
}

/// `Result` with a defaulted [`Error`] type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error arm of a result.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn macros_and_display() {
        assert_eq!(fails(true).unwrap(), 7);
        let e = fails(false).unwrap_err();
        assert_eq!(format!("{e}"), "flag was false");
        let e = anyhow!("x = {}", 3);
        assert_eq!(format!("{e:?}"), "x = 3");
    }

    #[test]
    fn context_chains() {
        let r: Result<()> = Err(anyhow!("root"));
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer: root");
        assert_eq!(e.root_cause(), "root");
    }

    #[test]
    fn question_mark_on_foreign_errors() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
    }
}
