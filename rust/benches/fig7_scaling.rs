//! Paper Fig. 7: da4ml optimizer runtime scaling on random m×m 8-bit
//! matrices up to 128×128, against the O(N² · log²N) asymptote
//! (N = m² · bw), normalized at m = 16.

use da4ml::cmvm::{compile, CmvmProblem, OptimizeOptions, Strategy};
use da4ml::report::{sci, Table};

fn main() {
    let sizes: &[usize] = &[4, 8, 16, 24, 32, 48, 64, 96, 128];
    let mut table = Table::new(
        "Fig. 7 — optimizer runtime scaling (dc = -1, 8-bit)",
        &["m", "N=m^2*bw", "cpu[ms]", "O(N^2 log^2 N) fit[ms]", "ratio"],
    );
    let mut norm: Option<f64> = None;
    let asym = |m: usize| -> f64 {
        let n = (m * m * 8) as f64;
        n * n * n.ln() * n.ln()
    };
    for &m in sizes {
        let trials = if m <= 32 { 3 } else { 1 };
        let mut ms = 0f64;
        for t in 0..trials {
            let p = CmvmProblem::random(77 * m as u64 + t as u64, m, m, 8);
            let sol = compile(&p, &OptimizeOptions::new(Strategy::Da { dc: -1 })).expect("compile");
            ms += sol.opt_time.as_secs_f64() * 1e3;
        }
        ms /= trials as f64;
        if m == 16 {
            norm = Some(ms / asym(16));
        }
        let fit = norm.map(|k| k * asym(m));
        table.push(vec![
            m.to_string(),
            (m * m * 8).to_string(),
            sci(ms),
            fit.map(|f| sci(f)).unwrap_or_else(|| "-".into()),
            fit.map(|f| format!("{:.2}", ms / f)).unwrap_or_else(|| "-".into()),
        ]);
    }
    println!("{}", table.render());
    println!("ratio ~= 1 across sizes confirms the O(N^2 log^2 N) empirical complexity (fit pinned at m=16).");
}
