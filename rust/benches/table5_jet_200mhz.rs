//! Paper Table 5: the jet-tagging MLP at a 200 MHz target (pipeline
//! every 5 adders), latency strategy vs da4ml, six quantization levels.

use da4ml::bench_tables::network_table;
use da4ml::pipeline::PipelineConfig;

fn main() {
    network_table(
        "Table 5 — jet-tagging MLP @ 200 MHz (register every 5 adders, dc = 2)",
        "jet_mlp",
        "accuracy",
        "acc",
        &PipelineConfig::every_n_adders(5),
    )
    .expect("run `make artifacts` first");
}
