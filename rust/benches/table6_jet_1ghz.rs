//! Paper Table 6: the jet-tagging MLP at a 1 GHz target (register every
//! adder: deeper pipeline, more FFs, higher Fmax).

use da4ml::bench_tables::network_table;
use da4ml::pipeline::PipelineConfig;

fn main() {
    network_table(
        "Table 6 — jet-tagging MLP @ 1 GHz (register every adder, dc = 2)",
        "jet_mlp",
        "accuracy",
        "acc",
        &PipelineConfig::every_n_adders(1),
    )
    .expect("run `make artifacts` first");
}
