//! Paper Table 7: the SVHN-like conv net (HLS-flow path: conv CMVM
//! kernels are optimized once and time-multiplexed across positions, so
//! II equals the position count of the widest layer).

use da4ml::bench_tables::{metric, load_level, LEVELS};
use da4ml::cmvm::Strategy;
use da4ml::estimate::FpgaModel;
use da4ml::nn::{self, LayerSpec};
use da4ml::pipeline::PipelineConfig;
use da4ml::report::Table;

fn main() {
    let model = FpgaModel::default();
    let pipe = PipelineConfig::every_n_adders(5);
    let mut table = Table::new(
        "Table 7 — SVHN-like conv net @ 200 MHz (dc = 2)",
        &["strategy", "acc", "II[cycles]", "latency[cycles]", "LUT", "DSP", "FF", "adders"],
    );
    for &(w, a) in LEVELS {
        let spec = load_level("svhn", w, a).expect("run `make artifacts` first");
        let acc = metric("svhn", w, a, "accuracy").unwrap();
        // II = positions of the widest conv (time-multiplexed kernel).
        let mut hw = (spec.input_shape[0], spec.input_shape[1]);
        let mut ii = 1usize;
        for l in &spec.layers {
            match l {
                LayerSpec::Conv2D { kh, kw, .. } => {
                    hw = (hw.0 - kh + 1, hw.1 - kw + 1);
                    ii = ii.max(hw.0 * hw.1);
                }
                LayerSpec::MaxPool2D | LayerSpec::AvgPool2D => {
                    hw = (hw.0 / 2, hw.1 / 2);
                }
                _ => {}
            }
        }
        for s in [Strategy::Latency, Strategy::Da { dc: 2 }] {
            let reports = nn::compile::layer_reports(&spec, s, &model, &pipe).unwrap();
            let agg = nn::compile::aggregate(&reports);
            let latency = ii as u32 + agg.latency_cycles;
            let adders = if matches!(s, Strategy::Latency) {
                format!("({})", agg.adders)
            } else {
                agg.adders.to_string()
            };
            table.push(vec![
                format!("{} w{w}a{a}", s.name()),
                format!("{:.3}", acc),
                ii.to_string(),
                latency.to_string(),
                agg.lut.to_string(),
                agg.dsp.to_string(),
                agg.ff.to_string(),
                adders,
            ]);
        }
    }
    println!("{}", table.render());
}
