//! Paper Table 11: jet-tagging MLP, hls4ml+DA vs standalone da4ml RTL,
//! 1 GHz target (pipeline every adder).

fn main() {
    da4ml::bench_tables_rtl::rtl_table(
        "Table 11 — jet tagging, HLS flow vs RTL flow @ 1 GHz",
        "jet_mlp",
        1,
    )
    .expect("run `make artifacts` first");
}
