//! Paper Table 12: MLP-Mixer, hls4ml+DA vs standalone da4ml RTL,
//! 200 MHz target.

fn main() {
    da4ml::bench_tables_rtl::rtl_table(
        "Table 12 — MLP-Mixer, HLS flow vs RTL flow @ 200 MHz",
        "mixer",
        5,
    )
    .expect("run `make artifacts` first");
}
