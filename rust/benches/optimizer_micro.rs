//! Micro-benchmarks of the optimizer hot paths. Plain timing harness:
//! median of N runs (see also `ingestion_micro` for the artifact-load path).

use da4ml::cmvm::{optimize, CmvmProblem, Strategy};
use da4ml::dais::interp;
use da4ml::report::{sci, Table};
use da4ml::util::time_median;

fn main() {
    let mut table = Table::new(
        "Optimizer micro-benchmarks",
        &["case", "median[ms]", "adders"],
    );
    for &(m, bw, dc) in &[(16usize, 8u32, -1i32), (16, 8, 0), (32, 8, -1), (64, 8, 2), (64, 4, 2)] {
        let p = CmvmProblem::random(5 + m as u64, m, m, bw);
        let runs = if m <= 16 { 9 } else { 3 };
        let (d, sol) = time_median(runs, || optimize(&p, Strategy::Da { dc }).expect("optimize"));
        table.push(vec![
            format!("da {m}x{m} {bw}b dc={dc}"),
            sci(d.as_secs_f64() * 1e3),
            sol.adders.to_string(),
        ]);
    }
    // Interpreter throughput (e2e accuracy sweeps depend on it).
    let p = CmvmProblem::random(99, 32, 32, 8);
    let sol = optimize(&p, Strategy::Da { dc: 2 }).expect("optimize");
    let xs: Vec<Vec<i64>> = (0..256)
        .map(|i| (0..32).map(|j| ((i * 31 + j * 17) % 255 - 128) as i64).collect())
        .collect();
    let (d, _) = time_median(5, || interp::evaluate_batch(&sol.program, &xs));
    let evals = 256.0 * sol.program.nodes.len() as f64;
    table.push(vec![
        "interp 32x32 x256 vec".into(),
        sci(d.as_secs_f64() * 1e3),
        format!("{:.1} Mop/s", evals / d.as_secs_f64() / 1e6),
    ]);
    println!("{}", table.render());
}
