//! Optimizer micro-benchmark — a thin front-end over the shared perf
//! suite (`da4ml::perf`), so `cargo bench optimizer_micro` and
//! `da4ml perf --smoke` measure the same cases through the same
//! plumbing and report identical numbers (the CLI additionally writes
//! the machine-readable `BENCH_cmvm.json`; see docs/perf.md).
//!
//! The ad-hoc table that used to live here is gone: phase timings,
//! adder counts and the engine work counters all come from
//! [`da4ml::perf::run_suite`]. Interpreter throughput moved to
//! `netlist_micro`, which times the cycle-accurate simulator on the
//! same workload.

use da4ml::perf::{self, PerfConfig};

fn main() {
    let cfg = PerfConfig::smoke();
    let report = perf::run_suite(&cfg).expect("perf suite");
    println!("{}", perf::render_table(&report));
    println!(
        "(shared plumbing with `da4ml perf --smoke`; add --out/--baseline there for \
         the machine-readable report and the regression gate)"
    );
}
