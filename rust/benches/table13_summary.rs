//! Paper Table 13: cross-method summary. Our rows are measured from the
//! artifacts (HLS flow and RTL flow, finest quantization level); the
//! literature rows are the paper's published numbers, reproduced as
//! constants for context (those systems are not reproducible here).

use da4ml::bench_tables::{load_level, metric};
use da4ml::cmvm::Strategy;
use da4ml::estimate::FpgaModel;
use da4ml::nn;
use da4ml::pipeline::PipelineConfig;
use da4ml::report::Table;

const LITERATURE: &[(&str, &str, &str, &str, &str, &str)] = &[
    // (task, implementation, metric, LUT, DSP, FF) — paper Table 13.
    ("jet (paper)", "HGQ+da4ml (RTL)", "76.5%", "6165", "0", "7207"),
    ("jet (paper)", "HGQ+hls4ml", "76.9%", "16081", "57", "26484"),
    ("jet (paper)", "DWN [ICLR'24]", "76.3%", "6302", "0", "4128"),
    ("jet (paper)", "NeuraLUT-Assemble", "76.0%", "1780", "0", "540"),
    ("jet (paper)", "TreeLUT [FPGA'25]", "75.6%", "2234", "0", "347"),
    ("muon (paper)", "HGQ+da4ml (HLS)", "1.95mrad", "37125", "0", "5547"),
    ("muon (paper)", "QKeras+hls4ml", "1.95mrad", "37867", "1762", "8443"),
    ("svhn (paper)", "HGQ+da4ml (HLS)", "93.9%", "53425", "0", "20048"),
    ("svhn (paper)", "QKeras+hls4ml", "94.0%", "111152", "174", "32554"),
    ("mixer (paper)", "HGQ+da4ml (RTL)", "81.4%", "120512", "0", "28284"),
    ("mixer (paper)", "LL-GNN [TEC'23]", "81.2%", "815k", "8986", "189k"),
];

fn main() {
    let model = FpgaModel::default();
    let pipe = PipelineConfig::every_n_adders(5);
    let mut table = Table::new(
        "Table 13 — cross-method summary (ours measured; literature rows from the paper)",
        &["task", "implementation", "metric", "LUT", "DSP", "FF"],
    );
    for (name, key, label) in [
        ("jet_mlp", "accuracy", "acc"),
        ("muon", "resolution_mrad", "res"),
        ("mixer", "accuracy", "acc"),
        ("svhn", "accuracy", "acc"),
    ] {
        let spec = load_level(name, 8, 8).expect("run `make artifacts` first");
        let mv = metric(name, 8, 8, key).unwrap();
        for s in [Strategy::Da { dc: 2 }, Strategy::Latency] {
            let rep = nn::compile::network_report(&spec, s, &model, &pipe).unwrap();
            let tag = match s {
                Strategy::Latency => "synthetic+hls4ml (latency)",
                _ => "synthetic+da4ml",
            };
            table.push(vec![
                format!("{name} (ours)"),
                tag.into(),
                format!("{mv:.3} {label}"),
                rep.lut.to_string(),
                rep.dsp.to_string(),
                rep.ff.to_string(),
            ]);
        }
    }
    for &(task, imp, m, lut, dsp, ff) in LITERATURE {
        table.push(vec![
            task.into(),
            imp.into(),
            m.into(),
            lut.into(),
            dsp.into(),
            ff.into(),
        ]);
    }
    println!("{}", table.render());
    println!(
        "Shape to verify: da4ml rows eliminate DSPs and cut LUTs vs the latency rows, \
         mirroring the paper's HGQ+da4ml vs hls4ml relation across all four tasks."
    );
}
