//! Paper Table 8: the muon-tracking network @ 160 MHz; the metric is
//! the truncated-MSE angular resolution (lower is better).

use da4ml::bench_tables::network_table;
use da4ml::pipeline::PipelineConfig;

fn main() {
    network_table(
        "Table 8 — muon tracking @ 160 MHz (register every 5 adders, dc = 2)",
        "muon",
        "resolution_mrad",
        "res[mrad]",
        &PipelineConfig::every_n_adders(5),
    )
    .expect("run `make artifacts` first");
}
