//! Paper Table 10: jet-tagging MLP, hls4ml+DA vs standalone da4ml RTL,
//! 200 MHz target (pipeline every 5 adders).

fn main() {
    da4ml::bench_tables_rtl::rtl_table(
        "Table 10 — jet tagging, HLS flow vs RTL flow @ 200 MHz",
        "jet_mlp",
        5,
    )
    .expect("run `make artifacts` first");
}
