//! Paper Table 2: da4ml vs the H_cmvm-like look-ahead comparator on
//! random m×m 8-bit matrices under dc ∈ {-1, 0, 2}.
//!
//! Reports adder depth, adder count and single-thread CPU time, averaged
//! over several random matrices per size (the paper's fractional values
//! come from the same averaging). The paper's published H_cmvm numbers
//! are printed alongside as reference constants — the *shape* to check:
//! da4ml within a few % of the comparator's adders, with orders of
//! magnitude less CPU time; the in-tree O(N³) comparator reproduces the
//! runtime blow-up on the sizes where it is feasible to run.

use da4ml::cmvm::{compile, CmvmProblem, OptimizeOptions, Strategy};
use da4ml::report::{sci, Table};

/// Paper Table 2 H_cmvm reference rows: (m, dc, depth, adders, cpu_ms).
const HCMVM_PAPER: &[(usize, i32, f64, f64, f64)] = &[
    (2, -1, 4.4, 8.2, 1.0e1),
    (4, -1, 7.8, 27.6, 4.8e2),
    (8, -1, 11.9, 96.3, 1.5e4),
    (16, -1, 16.3, 338.3, 1.2e6),
    (2, 0, 3.1, 8.8, 1.0e1),
    (4, 0, 4.1, 32.1, 4.7e2),
    (8, 0, 5.1, 117.2, 1.7e4),
    (16, 0, 6.0, 423.2, 9.9e5),
    (2, 2, 3.7, 8.2, -1.0),
    (4, 2, 5.7, 28.1, -1.0),
    (8, 2, 7.1, 99.5, -1.0),
    (16, 2, 8.0, 353.3, -1.0),
];

fn paper_ref(m: usize, dc: i32) -> Option<&'static (usize, i32, f64, f64, f64)> {
    HCMVM_PAPER.iter().find(|r| r.0 == m && r.1 == dc)
}

fn main() {
    let sizes = [2usize, 4, 6, 8, 10, 12, 14, 16];
    let trials = 5;
    // The honest O(N^3) comparator becomes minutes-scale beyond this.
    let lookahead_max_m = 10;

    for dc in [-1i32, 0, 2] {
        let mut table = Table::new(
            &format!("Table 2 (dc = {dc}) — random m×m 8-bit matrices, {trials} trials"),
            &[
                "m",
                "da depth",
                "da adders",
                "da cpu[ms]",
                "la depth",
                "la adders",
                "la cpu[ms]",
                "Hcmvm depth*",
                "Hcmvm adders*",
                "Hcmvm cpu[ms]*",
            ],
        );
        for &m in &sizes {
            let mut da = (0f64, 0f64, 0f64);
            let mut la = (0f64, 0f64, 0f64);
            let mut la_runs = 0usize;
            for t in 0..trials {
                let p = CmvmProblem::random(1000 * m as u64 + t as u64, m, m, 8);
                let sol = compile(&p, &OptimizeOptions::new(Strategy::Da { dc })).expect("compile");
                da.0 += sol.depth as f64;
                da.1 += sol.adders as f64;
                da.2 += sol.opt_time.as_secs_f64() * 1e3;
                if m <= lookahead_max_m {
                    let sol = compile(&p, &OptimizeOptions::new(Strategy::Lookahead { dc }))
                        .expect("compile");
                    la.0 += sol.depth as f64;
                    la.1 += sol.adders as f64;
                    la.2 += sol.opt_time.as_secs_f64() * 1e3;
                    la_runs += 1;
                }
            }
            let n = trials as f64;
            let fmt_la = |v: f64| {
                if la_runs > 0 {
                    sci(v / la_runs as f64)
                } else {
                    "-".into()
                }
            };
            let (pd, pa, pc) = match paper_ref(m, dc) {
                Some(&(_, _, d, a, c)) => (
                    format!("{d}"),
                    format!("{a}"),
                    if c > 0.0 { sci(c) } else { "-".into() },
                ),
                None => ("-".into(), "-".into(), "-".into()),
            };
            table.push(vec![
                m.to_string(),
                format!("{:.1}", da.0 / n),
                format!("{:.1}", da.1 / n),
                sci(da.2 / n),
                fmt_la(la.0),
                fmt_la(la.1),
                fmt_la(la.2),
                pd,
                pa,
                pc,
            ]);
        }
        println!("{}", table.render());
    }
    println!("* Hcmvm columns are the paper's published values (Xeon 2.33 GHz), shown for shape comparison.");
    println!("  'la' is the in-tree O(N^3) conflict-aware look-ahead comparator (our H_cmvm stand-in).");
}
