//! Ablation bench (DESIGN.md §5): what each design choice of the da4ml
//! algorithm contributes, on random matrices —
//!
//! * naive DA (no CSE, no decomposition) — the floor;
//! * CSE only, unweighted frequency (SCMVM-like selection);
//! * CSE only, bit-overlap-weighted frequency (paper §4.4);
//! * full two-stage (decomposition + weighted CSE);
//! * and the correlated-columns case where stage 1 shines (the paper:
//!   "useful for matrices with correlated columns").

use da4ml::cmvm::{compile, compile_terms, CmvmProblem, OptimizeOptions, Strategy};
use da4ml::cse::{self, CseConfig, InputTerm};
use da4ml::dais::DaisBuilder;
use da4ml::report::Table;
use da4ml::util::Rng;

fn cse_only(p: &CmvmProblem, weighted: bool) -> usize {
    let mut b = DaisBuilder::new();
    let inputs: Vec<InputTerm> = (0..p.d_in)
        .map(|j| InputTerm { node: b.input(j, p.input_qint[j], 0) })
        .collect();
    let cfg = CseConfig { dc: -1, weighted };
    let (outs, _) = cse::compile(&mut b, &inputs, &p.matrix, p.d_in, p.d_out, &cfg, None);
    for o in &outs {
        if let Some(n) = o.node {
            let n = if o.neg { b.neg(n) } else { n };
            b.output(n, o.shift);
        }
    }
    b.finish().adder_count()
}

/// A matrix whose columns are ±shifted copies + noise — the correlated
/// regime stage 1 exists for.
fn correlated(seed: u64, m: usize) -> CmvmProblem {
    let mut rng = Rng::seed_from(seed);
    let base: Vec<i64> = (0..m).map(|_| rng.range_i64(-127, 127)).collect();
    let mut mat = vec![0i64; m * m];
    for i in 0..m {
        let sign = if rng.chance(0.5) { -1 } else { 1 };
        for j in 0..m {
            let noise = if rng.chance(0.2) { rng.range_i64(-8, 8) } else { 0 };
            mat[j * m + i] = sign * base[j] + noise;
        }
    }
    CmvmProblem::new(m, m, mat, 8).expect("valid bits")
}

fn main() {
    let trials = 5;
    for (regime, gen) in [
        ("uniform random", false),
        ("correlated columns", true),
    ] {
        let mut table = Table::new(
            &format!("Ablation — adders on {regime} 16x16 8-bit ({trials} trials)"),
            &["variant", "adders (avg)", "vs naive"],
        );
        let mut sums = [0f64; 4];
        for t in 0..trials {
            let p = if gen { correlated(50 + t, 16) } else { CmvmProblem::random(50 + t, 16, 16, 8) };
            sums[0] += compile(&p, &OptimizeOptions::new(Strategy::NaiveDa))
                .expect("compile")
                .adders as f64;
            sums[1] += cse_only(&p, false) as f64;
            sums[2] += cse_only(&p, true) as f64;
            sums[3] += compile(&p, &OptimizeOptions::new(Strategy::Da { dc: -1 }))
                .expect("compile")
                .adders as f64;
        }
        let naive = sums[0] / trials as f64;
        for (name, s) in [
            ("naive DA", sums[0]),
            ("CSE, unweighted", sums[1]),
            ("CSE, overlap-weighted", sums[2]),
            ("two-stage (full da4ml)", sums[3]),
        ] {
            let avg = s / trials as f64;
            table.push(vec![
                name.into(),
                format!("{avg:.1}"),
                format!("{:+.1}%", (avg / naive - 1.0) * 100.0),
            ]);
        }
        println!("{}", table.render());
    }

    // Ensure compile_terms is exercised for the ablation doc example.
    let p = CmvmProblem::random(1, 4, 4, 4);
    let mut b = DaisBuilder::new();
    let inputs: Vec<InputTerm> =
        (0..4).map(|j| InputTerm { node: b.input(j, p.input_qint[j], 0) }).collect();
    let _ = compile_terms(&mut b, &inputs, &p, &OptimizeOptions::new(Strategy::Da { dc: 2 }))
        .expect("compile");
}
