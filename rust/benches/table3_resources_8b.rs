//! Paper Table 3: post-synthesis resources of random m×m **8-bit**
//! matrices under the latency baseline and DA at dc ∈ {0, 2, -1}
//! (Vivado is substituted by the calibrated analytic model,
//! DESIGN.md §3).

use da4ml::bench_tables::resource_table;

fn main() {
    resource_table("Table 3 — random matrices, 8-bit weights, 8-bit inputs", 8);
}
