//! Netlist micro-benchmark: lowering + cycle-accurate simulation of the
//! jet-tagging network on the new netlist subsystem.
//!
//! Loads `artifacts/jet_mlp.weights.json` when the exported artifacts
//! exist, otherwise synthesizes the jet-MLP-shaped spec
//! (`bench_tables::synthetic_jet_spec`). Reports, per pipelining
//! configuration: netlist size, materialized register bits, lowering
//! time and the cycle-accurate simulation throughput over a 256-vector
//! II = 1 stream — every run is also differential-checked against the
//! DAIS interpreter, so the numbers are from verified simulations.

use da4ml::bench_tables::synthetic_jet_spec;
use da4ml::cmvm::Strategy;
use da4ml::dais::interp;
use da4ml::netlist::{sim, Netlist};
use da4ml::nn::{self, NetworkSpec};
use da4ml::pipeline::{assign_stages, PipelineConfig};
use da4ml::report::{sci, Table};
use da4ml::runtime;
use da4ml::util::{time_median, Rng};

fn main() {
    let artifact = runtime::artifacts_dir().join("jet_mlp.weights.json");
    let (source, spec) = match runtime::load_text(&artifact) {
        Ok(t) => (
            artifact.display().to_string(),
            NetworkSpec::from_json(&t).expect("artifact spec decodes"),
        ),
        Err(_) => ("synthetic jet_mlp (16-64-32-32-5)".into(), synthetic_jet_spec()),
    };
    let opts = nn::compile::CompileOptions::new(Strategy::Da { dc: 2 });
    let prog = nn::compile::compile(&spec, &opts).expect("compile").program;
    println!(
        "source: {source} — {} DAIS nodes, {} adders, depth {}\n",
        prog.nodes.len(),
        prog.adder_count(),
        prog.adder_depth()
    );

    let mut rng = Rng::seed_from(1);
    let q = spec.input_qint();
    let stream: Vec<Vec<i64>> = (0..256)
        .map(|_| (0..spec.input_len()).map(|_| rng.range_i64(q.min, q.max)).collect())
        .collect();
    let want = interp::evaluate_batch(&prog, &stream);

    let mut table = Table::new(
        "netlist_micro — lower + cycle-accurate simulate (jet tagging)",
        &["configuration", "cells", "regs", "reg bits", "lower[ms]", "sim[ms]", "vec/s"],
    );
    let configs: [(&str, u32); 3] =
        [("combinational", 0), ("200 MHz (every 5)", 5), ("1 GHz (every 1)", 1)];
    for (name, every) in configs {
        let stages = (every > 0)
            .then(|| assign_stages(&prog, &PipelineConfig::every_n_adders(every)));
        let (t_lower, nl) = time_median(9, || {
            Netlist::lower(&prog, stages.as_deref()).expect("lower")
        });
        let (t_sim, got) = time_median(5, || sim::simulate(&nl, &stream));
        assert_eq!(got, want, "{name}: netlist simulation must match the interpreter");
        table.push(vec![
            name.to_string(),
            nl.cells.len().to_string(),
            nl.regs.len().to_string(),
            nl.reg_bits().to_string(),
            sci(t_lower.as_secs_f64() * 1e3),
            sci(t_sim.as_secs_f64() * 1e3),
            sci(stream.len() as f64 / t_sim.as_secs_f64().max(1e-9)),
        ]);
    }
    println!("{}", table.render());
    println!(
        "sim is the full II=1 stream ({} vectors) incl. pipeline flush; every row is \
         differential-verified against dais::interp before timing is reported.",
        stream.len()
    );
}
