//! Paper Table 4: same as Table 3 with **4-bit** weights (the all-LUT
//! regime: no DSP inference, DA LUTs ≈ half of the baseline's).

use da4ml::bench_tables::resource_table;

fn main() {
    resource_table("Table 4 — random matrices, 4-bit weights, 8-bit inputs", 4);
}
