//! Ingestion micro-benchmark: the zero-copy streaming artifact loader
//! vs the legacy DOM path on the jet-tagging weight artifact.
//!
//! Loads `artifacts/jet_mlp.weights.json` when the exported artifacts
//! exist, otherwise synthesizes a jet-MLP-shaped spec (16-64-32-32-5,
//! 8-bit weights) of the same JSON form. A counting global allocator
//! makes the headline claim measurable: the pull-parser path
//! (`NetworkSpec::from_json`) allocates **no `Value` tree** — only the
//! final spec storage — while the DOM path pays for every matrix
//! element boxed as a `Value`.

use da4ml::bench_tables::synthetic_jet_spec;
use da4ml::json;
use da4ml::nn::{NetworkSpec, TestVectors};
use da4ml::report::{sci, Table};
use da4ml::runtime;
use da4ml::util::alloc_count::{self, CountingAlloc};
use da4ml::util::time_median;

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Run `f`, returning its result plus (allocations, bytes) it made.
fn count_allocs<T>(f: impl FnOnce() -> T) -> (T, u64, u64) {
    let (a0, b0) = (alloc_count::allocations(), alloc_count::bytes_requested());
    let out = f();
    let (a1, b1) = (alloc_count::allocations(), alloc_count::bytes_requested());
    (out, a1 - a0, b1 - b0)
}

// The synthetic jet-MLP fallback spec is shared with `netlist_micro`
// (see `bench_tables::synthetic_jet_spec`).

fn main() {
    let artifact = runtime::artifacts_dir().join("jet_mlp.weights.json");
    let (source, text) = match runtime::load_text(&artifact) {
        Ok(t) => (artifact.display().to_string(), t),
        Err(_) => ("synthetic jet_mlp (16-64-32-32-5)".into(), synthetic_jet_spec().to_json()),
    };
    println!("artifact: {source} ({} KiB)\n", text.len() / 1024);

    let mut table = Table::new(
        "Artifact ingestion: DOM vs streaming pull parser",
        &["path", "median[ms]", "allocs", "alloc KiB"],
    );

    // DOM path: parse to a Value tree, then decode the tree.
    let (dur_dom, _) = time_median(15, || {
        let v = json::parse(&text).expect("parse");
        NetworkSpec::from_value(&v).expect("decode")
    });
    let (_, allocs_tree, bytes_tree) = count_allocs(|| json::parse(&text).expect("parse"));
    let (_, allocs_dom, bytes_dom) = count_allocs(|| {
        let v = json::parse(&text).expect("parse");
        NetworkSpec::from_value(&v).expect("decode")
    });
    table.push(vec![
        "DOM (parse + from_value)".into(),
        sci(dur_dom.as_secs_f64() * 1e3),
        allocs_dom.to_string(),
        (bytes_dom / 1024).to_string(),
    ]);
    table.push(vec![
        "  of which Value tree".into(),
        "-".into(),
        allocs_tree.to_string(),
        (bytes_tree / 1024).to_string(),
    ]);

    // Streaming path: events straight into the spec, no tree.
    let (dur_stream, _) = time_median(15, || NetworkSpec::from_json(&text).expect("decode"));
    let (_, allocs_stream, bytes_stream) =
        count_allocs(|| NetworkSpec::from_json(&text).expect("decode"));
    table.push(vec![
        "streaming (from_json)".into(),
        sci(dur_stream.as_secs_f64() * 1e3),
        allocs_stream.to_string(),
        (bytes_stream / 1024).to_string(),
    ]);
    println!("{}", table.render());

    // Test vectors ride the same fast path.
    let vec_artifact = runtime::artifacts_dir().join("jet_mlp.testvec.json");
    if let Ok(vtext) = runtime::load_text(&vec_artifact) {
        let (dur_v, _) = time_median(9, || TestVectors::from_json(&vtext).expect("decode"));
        println!(
            "testvec streaming decode: {} ms ({} KiB)",
            sci(dur_v.as_secs_f64() * 1e3),
            vtext.len() / 1024
        );
    }

    // The decoded specs agree, and the headline claims hold.
    let dom_spec = NetworkSpec::from_value(&json::parse(&text).expect("parse")).expect("decode");
    let stream_spec = NetworkSpec::from_json(&text).expect("decode");
    assert_eq!(dom_spec.to_json(), stream_spec.to_json(), "paths decode identically");
    assert!(
        allocs_stream < allocs_tree,
        "streaming ({allocs_stream} allocs) must allocate less than the \
         Value tree alone ({allocs_tree} allocs)"
    );
    assert!(
        bytes_stream < bytes_dom,
        "streaming ({bytes_stream} B) must allocate fewer bytes than the DOM \
         path ({bytes_dom} B)"
    );
    println!(
        "\nstreaming path: {:.1}x fewer allocations, {:.1}x less allocated memory, \
         {:.2}x speedup vs DOM",
        allocs_dom as f64 / allocs_stream.max(1) as f64,
        bytes_dom as f64 / bytes_stream.max(1) as f64,
        dur_dom.as_secs_f64() / dur_stream.as_secs_f64().max(1e-9)
    );
}
