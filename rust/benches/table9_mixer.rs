//! Paper Table 9: the MLP-Mixer particle jet tagger @ 200 MHz. The
//! paper's headline here: the baseline fails to reach II=1 while the DA
//! designs hold II=1 — our latency rows correspondingly show the deeper
//! naive-unrolled pipeline.

use da4ml::bench_tables::network_table;
use da4ml::pipeline::PipelineConfig;

fn main() {
    network_table(
        "Table 9 — MLP-Mixer jet tagger @ 200 MHz (register every 5 adders, dc = 2)",
        "mixer",
        "accuracy",
        "acc",
        &PipelineConfig::every_n_adders(5),
    )
    .expect("run `make artifacts` first");
}
