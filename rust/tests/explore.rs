//! Explorer acceptance tests: `--jobs N` determinism (byte-identical
//! serialized reports), Pareto-dominance invariants, the jet-tagging
//! front, and coordinator cache reuse across explorations.

use da4ml::bench_tables::synthetic_jet_spec_scaled;
use da4ml::cmvm::CmvmProblem;
use da4ml::coordinator::Coordinator;
use da4ml::explore::{self, dominates, ExploreConfig, ExploreTarget, Objective};
use da4ml::util::property;

fn smoke(jobs: usize) -> ExploreConfig {
    ExploreConfig { jobs, ..ExploreConfig::smoke() }
}

/// The acceptance pin: exploring with 4 worker threads produces a
/// serialized JSON report byte-identical to the single-threaded run,
/// across a seeded suite of CMVM shapes and a scaled jet network.
#[test]
fn jobs4_report_byte_identical_to_jobs1_on_seeded_suite() {
    let targets: Vec<ExploreTarget> = vec![
        ExploreTarget::Cmvm(CmvmProblem::random(700, 4, 6, 8)),
        ExploreTarget::Cmvm(CmvmProblem::random(701, 6, 4, 8)),
        ExploreTarget::Cmvm(CmvmProblem::random(702, 5, 5, 4)),
        ExploreTarget::Network(synthetic_jet_spec_scaled(1, 8)),
    ];
    for target in &targets {
        let r1 = explore::explore(target, &Coordinator::new(), &smoke(1)).unwrap();
        let r4 = explore::explore(target, &Coordinator::new(), &smoke(4)).unwrap();
        let (t1, t4) = (explore::schema::render(&r1), explore::schema::render(&r4));
        assert_eq!(t1, t4, "jobs=4 diverged from jobs=1 on {}", r1.target);
        assert!(!r1.front.is_empty());
    }
}

/// Seeded property: `--jobs 1` and `--jobs 4` agree on random problems
/// too, not just the fixed suite.
#[test]
fn prop_report_bytes_independent_of_jobs() {
    property("explore_jobs_independent", 4, |rng| {
        let d_in = rng.below(4) + 2;
        let d_out = rng.below(4) + 2;
        let m: Vec<i64> = (0..d_in * d_out).map(|_| rng.range_i64(-127, 127)).collect();
        let target = ExploreTarget::Cmvm(CmvmProblem::new(d_in, d_out, m, 8).unwrap());
        let r1 = explore::explore(&target, &Coordinator::new(), &smoke(1)).unwrap();
        let r4 = explore::explore(&target, &Coordinator::new(), &smoke(4)).unwrap();
        assert_eq!(explore::schema::render(&r1), explore::schema::render(&r4));
    });
}

/// The jet-tagging network's front is a genuine trade-off curve: at
/// least two non-dominated points, no front point dominating another,
/// and every dominated point dominated by some front point.
#[test]
fn jet_front_tradeoff_and_dominance_invariants() {
    let spec = synthetic_jet_spec_scaled(1, 4);
    let report = explore::explore_network(&spec, &smoke(0)).unwrap();
    assert!(
        report.front.len() >= 2,
        "expected >= 2 non-dominated points, got {:?}",
        report.front.iter().map(|p| &p.id).collect::<Vec<_>>()
    );
    for (i, a) in report.front.iter().enumerate() {
        for (j, b) in report.front.iter().enumerate() {
            if i != j {
                assert!(!dominates(a, b), "front point {} dominates {}", a.id, b.id);
            }
        }
    }
    for d in &report.dominated {
        assert!(
            report.front.iter().any(|f| dominates(f, d)),
            "dominated point {} is not dominated by any front point",
            d.id
        );
    }
    // Every objective picks a member of the front.
    for obj in [Objective::MinLut, Objective::MinLatency, Objective::Knee] {
        let p = explore::pick(&report.front, obj).expect("non-empty front");
        assert!(report.front.iter().any(|f| f.id == p.id));
    }
    // The report serializes and parses back as valid JSON with the
    // documented top-level fields.
    let text = explore::schema::render(&report);
    let v = da4ml::json::parse(&text).expect("valid JSON");
    assert_eq!(v.get("schema_version").unwrap().as_i64().unwrap(), 1);
    assert_eq!(
        v.get("front").unwrap().as_array().unwrap().len(),
        report.front.len()
    );
    assert_eq!(
        v.get("dominated").unwrap().as_array().unwrap().len(),
        report.dominated.len()
    );
}

/// Explorations share the coordinator's solution cache: re-exploring
/// the same CMVM compiles nothing and reproduces the same report.
#[test]
fn re_exploration_hits_the_shared_cache() {
    let target = ExploreTarget::Cmvm(CmvmProblem::random(703, 5, 5, 8));
    let coord = Coordinator::new();
    let first = explore::explore(&target, &coord, &smoke(2)).unwrap();
    let s1 = coord.stats();
    assert!(s1.submitted > 0);
    assert_eq!(s1.cache_hits, 0);
    let second = explore::explore(&target, &coord, &smoke(2)).unwrap();
    let s2 = coord.stats();
    assert_eq!(s2.submitted, 2 * s1.submitted);
    assert_eq!(s2.cache_hits, s1.submitted, "every re-compile must be a cache hit");
    assert_eq!(
        explore::schema::render(&first),
        explore::schema::render(&second),
        "cached exploration must reproduce the identical report"
    );
}
