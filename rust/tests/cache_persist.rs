//! Integration test for cache persistence: a serve run baked into a
//! schema-v1 cache file must warm-start a fresh coordinator so that the
//! same workload is answered entirely from the loaded cache with
//! byte-identical result lines (only the `cached` flag flips). Also
//! exercises the `da4ml cache bake|info|merge` CLI round trip end to
//! end through the real binary.

use da4ml::coordinator::Coordinator;
use da4ml::json::{self, Value};
use da4ml::serve::{serve_with, ServeConfig};
use da4ml::util::Rng;
use std::io::Cursor;

fn matrix_json(seed: u64, d_in: usize, d_out: usize) -> String {
    let mut rng = Rng::seed_from(seed);
    let rows: Vec<String> = (0..d_in)
        .map(|_| {
            let row: Vec<String> =
                (0..d_out).map(|_| rng.range_i64(-127, 127).to_string()).collect();
            format!("[{}]", row.join(","))
        })
        .collect();
    format!("[{}]", rows.join(","))
}

/// The determinism contract of `docs/cache.md`: replies computed live,
/// replies served from the in-memory cache, and replies served from a
/// cache reloaded off disk are byte-identical (the `cached` flag is
/// the only field allowed to differ, and `opt_ms` survives the disk
/// round trip exactly because the file stores integer nanoseconds).
#[test]
fn warm_start_serves_byte_identical_replies() {
    let mut input = String::new();
    for round in 0..2 {
        for (i, seed) in [41u64, 42, 43].iter().enumerate() {
            input.push_str(&format!(
                "{{\"id\": \"r{round}-m{i}\", \"matrix\": {}, \"dc\": -1}}\n",
                matrix_json(*seed, 6, 6)
            ));
        }
    }
    let cfg = ServeConfig { batch_size: 1, ..ServeConfig::default() };

    // Cold run: 3 compiles + 3 in-memory hits.
    let cold = Coordinator::new();
    let mut cold_out = Vec::new();
    let cold_summary =
        serve_with(&cold, Cursor::new(input.clone()), &mut cold_out, &cfg).unwrap();
    assert_eq!(cold_summary.jobs, 6);
    assert_eq!(cold_summary.stats.cache_hits, 3);
    assert_eq!(cold_summary.stats.loaded, 0);

    // Persist, then warm-start a fresh coordinator from the file text.
    let saved = cold.save_cache();
    let warm = Coordinator::new();
    assert_eq!(warm.load_cache(&saved).unwrap(), 3);
    // The file format is canonical: saving the loaded cache reproduces
    // the original bytes.
    assert_eq!(warm.save_cache(), saved, "save -> load -> save must be stable");

    let mut warm_out = Vec::new();
    let warm_summary = serve_with(&warm, Cursor::new(input), &mut warm_out, &cfg).unwrap();
    assert_eq!(warm_summary.jobs, 6);
    assert_eq!(warm_summary.stats.submitted, 6);
    assert_eq!(warm_summary.stats.cache_hits, 6, "every warm job must hit");
    assert_eq!(warm_summary.stats.loaded, 3);

    let cold_text = String::from_utf8(cold_out).unwrap();
    let warm_text = String::from_utf8(warm_out).unwrap();
    let mask = |text: &str| -> Vec<String> {
        text.lines()
            .filter(|l| {
                json::parse(l).unwrap().get("type").unwrap().as_str().unwrap() == "result"
            })
            .map(|l| {
                l.replace("\"cached\":false", "\"cached\":#")
                    .replace("\"cached\":true", "\"cached\":#")
            })
            .collect()
    };
    let cold_results = mask(&cold_text);
    let warm_results = mask(&warm_text);
    assert_eq!(cold_results.len(), 6);
    assert_eq!(
        cold_results, warm_results,
        "loaded-from-disk replies must be byte-identical to computed ones"
    );
    for line in warm_text.lines() {
        let v = json::parse(line).unwrap();
        match v.get("type").unwrap().as_str().unwrap() {
            "result" => {
                assert!(v.get("cached").unwrap().as_bool().unwrap(), "warm reply not cached")
            }
            "stats" => {
                assert_eq!(v.get("cache_loaded").unwrap().as_i64().unwrap(), 3);
                assert_eq!(v.get("cache_shards").unwrap().as_i64().unwrap(), 1);
            }
            other => panic!("unexpected reply type {other}"),
        }
    }
}

/// Sharding is a cache-internal detail: a cache baked by a sharded
/// coordinator warm-starts a single-shard one (and vice versa), since
/// the file orders entries by key, not by shard.
#[test]
fn cache_files_are_shard_layout_independent() {
    let mut input = String::new();
    for (i, seed) in [61u64, 62, 63, 64, 65].iter().enumerate() {
        input.push_str(&format!(
            "{{\"id\": \"m{i}\", \"matrix\": {}, \"dc\": -1}}\n",
            matrix_json(*seed, 4, 4)
        ));
    }
    let cfg = ServeConfig { batch_size: 1, ..ServeConfig::default() };
    let sharded = Coordinator::with_shards(4);
    let mut out = Vec::new();
    serve_with(&sharded, Cursor::new(input.clone()), &mut out, &cfg).unwrap();
    let saved = sharded.save_cache();

    for shards in [1usize, 3] {
        let coord = Coordinator::with_shards(shards);
        assert_eq!(coord.load_cache(&saved).unwrap(), 5, "{shards}-shard load");
        let mut warm_out = Vec::new();
        let summary =
            serve_with(&coord, Cursor::new(input.clone()), &mut warm_out, &cfg).unwrap();
        assert_eq!(summary.stats.cache_hits, 5, "{shards}-shard warm run must all hit");
    }
}

/// End-to-end CLI round trip through the real binary:
/// `cache bake --corpus` -> `cache info` -> `serve --cache-load`
/// (all hits) -> `cache merge`. Mirrors the CI perf-smoke recipe.
#[test]
fn cli_bake_info_warm_serve_round_trip() {
    use std::process::Command;

    let dir = std::env::temp_dir().join(format!("da4ml-cache-persist-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let jobs = dir.join("jobs.jsonl");
    let cache = dir.join("cache.json");
    let merged = dir.join("merged.json");
    std::fs::write(
        &jobs,
        "{\"id\": \"a\", \"matrix\": [[3, 5], [-7, 9]], \"dc\": -1}\n\
         {\"id\": \"b\", \"matrix\": [[2, 4, 6], [1, -8, 11]], \"dc\": -1}\n",
    )
    .unwrap();
    let bin = env!("CARGO_BIN_EXE_da4ml");

    let bake = Command::new(bin)
        .args(["cache", "bake", "--corpus"])
        .arg(&jobs)
        .arg("--out")
        .arg(&cache)
        .output()
        .unwrap();
    let bake_out = String::from_utf8_lossy(&bake.stdout).to_string();
    assert!(bake.status.success(), "bake failed: {}", String::from_utf8_lossy(&bake.stderr));
    assert!(bake_out.contains("2 solutions from 2 jobs"), "bake stdout: {bake_out}");

    let info = Command::new(bin).args(["cache", "info"]).arg(&cache).output().unwrap();
    let info_out = String::from_utf8_lossy(&info.stdout).to_string();
    assert!(info.status.success(), "info failed: {}", String::from_utf8_lossy(&info.stderr));
    assert!(info_out.contains("schema v1"), "info stdout: {info_out}");
    assert!(info_out.contains("2 entries"), "info stdout: {info_out}");

    let serve = Command::new(bin)
        .args(["serve", "--batch", "1", "--input"])
        .arg(&jobs)
        .arg("--cache-load")
        .arg(&cache)
        .output()
        .unwrap();
    assert!(serve.status.success(), "serve failed: {}", String::from_utf8_lossy(&serve.stderr));
    let serve_err = String::from_utf8_lossy(&serve.stderr).to_string();
    assert!(
        serve_err.contains("warm start: loaded 2 solutions"),
        "serve stderr: {serve_err}"
    );
    let serve_out = String::from_utf8_lossy(&serve.stdout).to_string();
    let results: Vec<Value> = serve_out
        .lines()
        .map(|l| json::parse(l).unwrap())
        .filter(|v| v.get("type").unwrap().as_str().unwrap() == "result")
        .collect();
    assert_eq!(results.len(), 2, "serve stdout: {serve_out}");
    for r in &results {
        assert!(
            r.get("cached").unwrap().as_bool().unwrap(),
            "warm serve must answer from the baked cache: {serve_out}"
        );
    }

    let merge = Command::new(bin)
        .args(["cache", "merge"])
        .arg(&merged)
        .arg(&cache)
        .arg(&cache)
        .output()
        .unwrap();
    let merge_out = String::from_utf8_lossy(&merge.stdout).to_string();
    assert!(merge.status.success(), "merge failed: {}", String::from_utf8_lossy(&merge.stderr));
    assert!(merge_out.contains("merged 2 entries"), "merge stdout: {merge_out}");

    std::fs::remove_dir_all(&dir).ok();
}
