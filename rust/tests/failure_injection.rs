//! Failure-injection tests: the static verifier must reject every class
//! of corrupted DAIS program, and the JSON/spec decoders must reject
//! malformed artifacts with useful errors (never panic).

use da4ml::dais::{verify, DaisBuilder, DaisNode, DaisOp, DaisProgram, OutputSpec};
use da4ml::fixed::QInterval;
use da4ml::json;
use da4ml::nn::{NetworkSpec, TestVectors};

fn valid_program() -> DaisProgram {
    let mut b = DaisBuilder::new();
    let q = QInterval::new(-128, 127, 0);
    let x = b.input(0, q, 0);
    let y = b.input(1, q, 0);
    let t = b.add_shift(x, y, 1, false);
    b.output(t, 0);
    b.finish()
}

#[test]
fn verifier_accepts_valid() {
    verify::check_well_formed(&valid_program()).unwrap();
}

#[test]
fn verifier_rejects_ssa_violation() {
    let mut p = valid_program();
    // Make the adder reference a later node.
    p.nodes[2].op = DaisOp::AddShift { a: 2, b: 1, shift_a: 0, shift_b: 0, sub: false };
    assert!(verify::check_well_formed(&p).is_err());
}

#[test]
fn verifier_rejects_corrupted_interval() {
    let mut p = valid_program();
    p.nodes[2].qint = QInterval::new(0, 1, 0); // too narrow for the sum
    let err = verify::check_well_formed(&p).unwrap_err();
    assert!(format!("{err}").contains("interval"));
}

#[test]
fn verifier_rejects_corrupted_depth() {
    let mut p = valid_program();
    p.nodes[2].depth = 7;
    assert!(verify::check_well_formed(&p).is_err());
}

#[test]
fn verifier_rejects_dangling_output() {
    let mut p = valid_program();
    p.outputs.push(OutputSpec { node: 99, shift: 0 });
    assert!(verify::check_well_formed(&p).is_err());
}

#[test]
fn verifier_rejects_oversized_shift() {
    let mut p = valid_program();
    p.nodes.push(DaisNode {
        op: DaisOp::AddShift { a: 0, b: 1, shift_a: 0, shift_b: 63, sub: false },
        qint: QInterval::new(-1, 1, 0),
        depth: 1,
    });
    assert!(verify::check_well_formed(&p).is_err());
}

#[test]
fn equivalence_rejects_wrong_matrix() {
    let p = valid_program();
    // Program computes [x + 2y]; claim it computes [x + 3y].
    assert!(verify::check_cmvm_equivalence(&p, &[1, 3], 2, 1).is_err());
    verify::check_cmvm_equivalence(&p, &[1, 2], 2, 1).unwrap();
}

#[test]
fn spec_decoder_rejects_malformed() {
    for bad in [
        "{}",
        r#"{"name":"x"}"#,
        r#"{"name":"x","input_bits":8,"input_signed":true,"input_shape":[2],"layers":[{"type":"nope"}]}"#,
        r#"{"name":"x","input_bits":8,"input_signed":true,"input_shape":[2],"layers":[{"type":"dense","w":[[1,"a"]],"b":[0],"relu":false,"shift":0,"clip_min":0,"clip_max":1}]}"#,
    ] {
        assert!(NetworkSpec::from_json(bad).is_err(), "accepted: {bad}");
    }
}

#[test]
fn testvec_decoder_rejects_malformed() {
    assert!(TestVectors::from_json("{}").is_err());
    assert!(TestVectors::from_json(r#"{"inputs":[[1]],"outputs":"x"}"#).is_err());
    let ok = TestVectors::from_json(r#"{"inputs":[[1,2]],"outputs":[[3]]}"#).unwrap();
    assert!(ok.labels.is_empty());
}

/// Regression for unbounded recursion: a deeply nested artifact used to
/// blow the stack inside `json::parse` (decoders must return errors,
/// never panic or crash). The depth limit converts it into a clean error
/// long before stack exhaustion, and is configurable per call.
#[test]
fn json_depth_bomb_returns_error_not_stack_overflow() {
    // 200k unclosed arrays: without a depth limit this recursion level
    // overflows an 8 MiB stack; with the limit it must error cleanly.
    let bomb = "[".repeat(200_000);
    assert!(json::parse(&bomb).is_err());
    // Alternating array/object nesting hits both recursion sites.
    let mixed = "[{\"k\":".repeat(50_000);
    assert!(json::parse(&mixed).is_err());
    // A closed-but-too-deep document is also rejected, with a
    // depth-specific message.
    let deep = format!("{}1{}", "[".repeat(300), "]".repeat(300));
    let err = json::parse(&deep).unwrap_err();
    assert!(format!("{err}").contains("nesting depth"), "got: {err}");
    // The limit is configurable (picojson-rs convention).
    assert!(json::parse_with_depth(&deep, 512).is_ok());
    assert!(json::parse_with_depth("[[1]]", 1).is_err());
}

#[test]
fn json_parser_never_panics_on_garbage() {
    let cases = [
        "", "{", "}", "[[[", "\"", "\u{0}", "nul", "-", "1e", "{\"a\":}", "[1 2]",
        "\"\\u12\"", "\"\\q\"", "123abc", "{\"k\": \"v\",}",
    ];
    for c in cases {
        let _ = json::parse(c); // must return Err, not panic
    }
}

#[test]
fn interp_checked_catches_spec_input_violation() {
    // Feeding an out-of-range input into a checked evaluation panics
    // with the interval diagnostic (wrap-impossible guarantee).
    let p = valid_program();
    let result = std::panic::catch_unwind(|| {
        da4ml::dais::interp::evaluate_checked(&p, &[4096, 0])
    });
    assert!(result.is_err());
}

// ---------------------------------------------------------------------------
// Socket-path failure injection: hostile or broken clients must each
// produce a clean per-connection teardown — the shared queue, worker
// pool, and coordinator stats keep serving everyone else.
// ---------------------------------------------------------------------------

mod socket {
    use da4ml::coordinator::Coordinator;
    use da4ml::json;
    use da4ml::serve::server::{Server, ServerConfig, ServerHandle, ServerSummary};
    use std::io::{BufRead, BufReader, Read, Write};
    use std::os::unix::net::UnixStream;
    use std::path::{Path, PathBuf};
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::thread;
    use std::time::Duration;

    fn socket_path(tag: &str) -> PathBuf {
        static N: AtomicU64 = AtomicU64::new(0);
        let n = N.fetch_add(1, Ordering::SeqCst);
        std::env::temp_dir().join(format!("da4ml-fi-{tag}-{}-{n}.sock", std::process::id()))
    }

    fn start(
        cfg: ServerConfig,
        tag: &str,
    ) -> (PathBuf, ServerHandle, thread::JoinHandle<ServerSummary>) {
        let path = socket_path(tag);
        let server = Server::bind(Coordinator::new(), cfg, &path, None).expect("bind");
        let handle = server.handle();
        let join = thread::spawn(move || server.run().expect("server run"));
        (path, handle, join)
    }

    /// A well-formed 2x2 job round trip: the liveness probe run after
    /// every injected failure.
    fn assert_still_serving(path: &Path, id: &str) {
        let mut tx = UnixStream::connect(path).expect("connect");
        let rx = tx.try_clone().expect("clone");
        tx.write_all(
            format!("{{\"id\": \"{id}\", \"matrix\": [[2, 3], [5, 7]], \"dc\": -1}}\n")
                .as_bytes(),
        )
        .expect("send");
        tx.shutdown(std::net::Shutdown::Write).expect("half-close");
        let lines: Vec<String> =
            BufReader::new(rx).lines().map(|l| l.expect("reply")).collect();
        assert_eq!(lines.len(), 2, "result + final stats: {lines:?}");
        let v = json::parse(&lines[0]).unwrap();
        assert_eq!(v.get("type").unwrap().as_str().unwrap(), "result");
        assert_eq!(v.get("id").unwrap().as_str().unwrap(), id);
        assert!(v.get("adders").unwrap().as_i64().unwrap() > 0);
    }

    /// A client that dies mid-line (connection drop with a half-written
    /// frame on the wire) is answered as far as correlatable and torn
    /// down cleanly; a client that half-closes after a partial frame
    /// gets the decode error spelled out.
    #[test]
    fn mid_line_disconnect_and_half_frames_tear_down_cleanly() {
        let (path, handle, join) = start(ServerConfig::default(), "midline");

        // Drop mid-line: no newline ever arrives, then the socket dies.
        let mut dropper = UnixStream::connect(&path).expect("connect");
        dropper.write_all(b"{\"id\": \"x\", \"matr").expect("send partial");
        drop(dropper);

        // Half-written frame, but the client keeps reading: the final
        // unterminated line is decoded and rejected with a real error.
        let mut tx = UnixStream::connect(&path).expect("connect");
        let rx = tx.try_clone().expect("clone");
        tx.write_all(b"{\"id\": \"y\", \"matrix\": [[1").expect("send partial");
        tx.shutdown(std::net::Shutdown::Write).expect("half-close");
        let lines: Vec<String> =
            BufReader::new(rx).lines().map(|l| l.expect("reply")).collect();
        assert!(lines.len() >= 2, "error + final stats: {lines:?}");
        let err = json::parse(&lines[0]).unwrap();
        assert_eq!(err.get("type").unwrap().as_str().unwrap(), "error");

        assert_still_serving(&path, "after-midline");
        handle.shutdown();
        let summary = join.join().expect("server thread");
        assert_eq!(summary.dropped_jobs, 0);
        assert_eq!(summary.jobs, 1, "only the probe executed");
        assert!(summary.errors >= 1, "the half frame was rejected");
        assert_eq!(summary.stats.submitted, 1, "coordinator stats unpoisoned");
    }

    /// An unframed line past the byte bound gets exactly one error
    /// reply, then the connection is torn down — without the server
    /// ever buffering the oversized payload.
    #[test]
    fn oversized_line_is_rejected_then_torn_down() {
        let cfg = ServerConfig { max_line_bytes: 256, ..ServerConfig::default() };
        let (path, handle, join) = start(cfg, "oversized");

        let mut tx = UnixStream::connect(&path).expect("connect");
        let rx = tx.try_clone().expect("clone");
        let mut big = vec![b'z'; 4096];
        big.push(b'\n');
        tx.write_all(&big).expect("send oversized");
        // A valid job after the oversized line: the teardown means it
        // must NOT be answered (the connection is gone, not limping).
        let _ = tx.write_all(b"{\"id\": \"late\", \"matrix\": [[1]]}\n");
        let lines: Vec<String> =
            BufReader::new(rx).lines().map(|l| l.expect("reply")).collect();
        assert_eq!(lines.len(), 2, "one error + final stats: {lines:?}");
        let err = json::parse(&lines[0]).unwrap();
        assert_eq!(err.get("type").unwrap().as_str().unwrap(), "error");
        assert!(
            err.get("error").unwrap().as_str().unwrap().contains("exceeds"),
            "got: {}",
            lines[0]
        );
        let stats = json::parse(&lines[1]).unwrap();
        assert_eq!(stats.get("type").unwrap().as_str().unwrap(), "stats");
        assert!(stats.get("final").unwrap().as_bool().unwrap());

        assert_still_serving(&path, "after-oversized");
        handle.shutdown();
        let summary = join.join().expect("server thread");
        assert_eq!(summary.dropped_jobs, 0);
        assert_eq!(summary.jobs, 1, "the late job must not execute");
    }

    /// A client that stops reading while big replies pile up trips the
    /// write timeout: that connection alone is declared dead; its
    /// accepted jobs still execute and are accounted (never wedging a
    /// worker or the queue), and other clients keep being served.
    #[test]
    fn slow_reader_write_timeout_is_a_clean_death() {
        // One worker: strictly sequential execution, so exactly one
        // compile of the recurring matrix reaches the optimizer and the
        // cache accounting below is deterministic.
        let cfg =
            ServerConfig { write_timeout_ms: 100, workers: 1, ..ServerConfig::default() };
        let (path, handle, join) = start(cfg, "slowreader");

        let mut tx = UnixStream::connect(&path).expect("connect");
        let rx = tx.try_clone().expect("clone");
        // One 12x12 compile, then cached re-emissions: every reply
        // carries the full Verilog text, overflowing the socket buffer
        // of a reader that never reads.
        let row: Vec<String> = (0..12).map(|i| (17 * i % 201 - 100).to_string()).collect();
        let mat = format!(
            "[{}]",
            (0..12).map(|_| format!("[{}]", row.join(","))).collect::<Vec<_>>().join(",")
        );
        const JOBS: usize = 64;
        for j in 0..JOBS {
            let line =
                format!("{{\"id\": \"big-{j}\", \"matrix\": {mat}, \"dc\": 2, \"emit\": \"verilog\"}}\n");
            if tx.write_all(line.as_bytes()).is_err() {
                break; // reader side already torn down: also a clean death
            }
        }
        // Never read. Give the server time to fill the buffer and trip
        // the timeout, then vanish.
        thread::sleep(Duration::from_millis(600));
        drop(tx);
        drop(rx);

        assert_still_serving(&path, "after-slow-reader");
        handle.shutdown();
        let summary = join.join().expect("server thread");
        assert_eq!(summary.dropped_jobs, 0, "discarded replies are still accounted");
        assert!(summary.jobs >= 1, "the probe executed");
        // The shared cache is intact: at most one compile of the big
        // matrix plus the probe actually ran the optimizer.
        assert!(summary.stats.cache_hits + 2 >= summary.jobs, "cache poisoned: {summary:?}");
    }

    /// Serializes the tests that flip the process-global tracing flag
    /// (and drain the shared event buffers) against each other.
    fn obs_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Hostile teardowns (mid-line disconnect, a client that vanishes
    /// without reading its replies) must still close every accepted
    /// job's execute span exactly once — no orphaned spans, no double
    /// closes — and drop no events.
    #[test]
    fn disconnects_and_dead_readers_close_job_spans_exactly_once() {
        let _obs = obs_lock();
        let _ = da4ml::obs::take_dropped_events();
        da4ml::obs::enable();
        let cfg =
            ServerConfig { write_timeout_ms: 100, workers: 1, ..ServerConfig::default() };
        let (path, handle, join) = start(cfg, "spans");

        // Mid-line disconnect: one accepted job, then a half-written
        // frame and a dead socket. The accepted job still executes and
        // its span closes on the worker.
        let mut dropper = UnixStream::connect(&path).expect("connect");
        dropper
            .write_all(
                b"{\"id\": \"span-mid\", \"matrix\": [[2, 3], [5, 7]]}\n{\"id\": \"x\", \"matr",
            )
            .expect("send");
        drop(dropper);

        // Dead reader: several accepted jobs, then the client vanishes
        // without ever reading. Replies are discarded, spans still
        // close exactly once each.
        let tx = UnixStream::connect(&path).expect("connect");
        let rx = tx.try_clone().expect("clone");
        let mut tx = tx;
        for j in 0..4 {
            let line = format!("{{\"id\": \"span-slow-{j}\", \"matrix\": [[2, 3], [5, 7]]}}\n");
            if tx.write_all(line.as_bytes()).is_err() {
                break;
            }
        }
        thread::sleep(Duration::from_millis(300));
        drop(tx);
        drop(rx);

        assert_still_serving(&path, "span-probe");
        handle.shutdown();
        let summary = join.join().expect("server thread");
        da4ml::obs::disable();
        assert_eq!(summary.dropped_jobs, 0);

        let events = da4ml::obs::drain_events();
        let execute_count = |id: &str| {
            events
                .iter()
                .filter(|e| e.name == "serve.execute")
                .filter(|e| {
                    e.args.iter().any(|(k, v)| {
                        *k == "id"
                            && matches!(v, da4ml::obs::ArgValue::Str(s) if s == id)
                    })
                })
                .count()
        };
        assert_eq!(execute_count("span-mid"), 1, "mid-line disconnect span");
        for j in 0..4 {
            let id = format!("span-slow-{j}");
            assert_eq!(execute_count(&id), 1, "dead-reader span {id}");
        }
        assert_eq!(execute_count("span-probe"), 1, "probe span");
        assert_eq!(da4ml::obs::take_dropped_events(), 0, "events dropped");
    }

    /// The determinism contract of `docs/observability.md`: enabling
    /// tracing must not change a single `result`/`error` reply byte.
    /// Both runs serve from the same baked cache so `opt_ms` is the
    /// persisted value, making the full reply lines comparable.
    #[test]
    fn traced_replies_are_byte_identical_to_untraced() {
        let _obs = obs_lock();
        let req = da4ml::serve::JobRequest::from_json(
            r#"{"id": "a", "matrix": [[3, 5], [-7, 9]]}"#,
        )
        .expect("request");
        let job = req.to_compile_job("a".into(), -1).expect("job");
        let bake = Coordinator::new();
        bake.compile_cached(&job).expect("bake");
        let cache = bake.save_cache();

        let jobs = "{\"id\": \"a\", \"matrix\": [[3, 5], [-7, 9]]}\n\
                    {\"id\": \"b\", \"matrix\": [[3, 5], [-7, 9]]}\n\
                    {\"id\": \"bad\", \"matrix\": \"nope\"}\n";
        let run = |tag: &str| -> Vec<String> {
            let coord = Coordinator::new();
            coord.load_cache(&cache).expect("load cache");
            let path = socket_path(tag);
            let server =
                Server::bind(coord, ServerConfig::default(), &path, None).expect("bind");
            let handle = server.handle();
            let join = thread::spawn(move || server.run().expect("server run"));
            let mut tx = UnixStream::connect(&path).expect("connect");
            let rx = tx.try_clone().expect("clone");
            tx.write_all(jobs.as_bytes()).expect("send");
            tx.shutdown(std::net::Shutdown::Write).expect("half-close");
            let lines: Vec<String> =
                BufReader::new(rx).lines().map(|l| l.expect("reply")).collect();
            handle.shutdown();
            join.join().expect("server thread");
            // Stats lines carry live timing digests by design; the
            // contract pins the job replies.
            lines
                .into_iter()
                .filter(|l| {
                    let v = json::parse(l).unwrap();
                    let ty = v.get("type").unwrap().as_str().unwrap().to_string();
                    ty == "result" || ty == "error"
                })
                .collect()
        };

        let untraced = run("untraced");
        da4ml::obs::enable();
        let traced = run("traced");
        da4ml::obs::disable();
        let _ = da4ml::obs::drain_events();
        assert_eq!(untraced.len(), 3, "two results + one error: {untraced:?}");
        assert_eq!(untraced, traced, "tracing changed reply bytes");

        // The streaming exporter (the long-lived-server trace mode,
        // rotation enabled) is held to the same contract: a live
        // .jsonl flusher must not perturb a single reply byte.
        let trace_path = std::env::temp_dir()
            .join(format!("da4ml-fi-stream-{}.jsonl", std::process::id()));
        let session = da4ml::obs::StreamingTraceSession::begin(da4ml::obs::StreamConfig {
            path: trace_path.to_string_lossy().into_owned(),
            rotate_bytes: Some(64 * 1024),
        })
        .expect("begin streaming trace");
        let streamed = run("streamed");
        let (trace_file, metrics_file) = session.finish().expect("finish streaming trace");
        let _ = std::fs::remove_file(&trace_file);
        let _ = std::fs::remove_file(format!("{trace_file}.1"));
        let _ = std::fs::remove_file(&metrics_file);
        assert_eq!(untraced, streamed, "streaming trace export changed reply bytes");
    }

    /// A connection that never sends anything must not block the
    /// drain: it is released with a final stats line and EOF.
    #[test]
    fn idle_connection_does_not_block_drain() {
        let (path, handle, join) = start(ServerConfig::default(), "idle");
        let mut idle = UnixStream::connect(&path).expect("connect");
        assert_still_serving(&path, "with-idler");
        handle.shutdown();
        let summary = join.join().expect("server thread");
        assert_eq!(summary.clients, 2);
        assert_eq!(summary.dropped_jobs, 0);
        // The idler was released with a final stats line and EOF.
        let mut text = String::new();
        idle.read_to_string(&mut text).expect("drain released the idler");
        let last = text.lines().last().expect("final stats line");
        let v = json::parse(last).unwrap();
        assert_eq!(v.get("type").unwrap().as_str().unwrap(), "stats");
        assert!(v.get("final").unwrap().as_bool().unwrap());
    }
}

#[test]
fn conv1d_alias_decodes_and_runs() {
    // Paper §5.1 lists Conv1D among the supported layers; the frontend
    // decodes it as a unit-height Conv2D on a [1, w, c] state.
    let spec = NetworkSpec::from_json(
        r#"{"name":"c1","input_bits":4,"input_signed":false,
            "input_shape":[1,5,1],
            "layers":[{"type":"conv1d","w":[[1],[2],[3]],"b":[0],"k":3,
                       "relu":false,"shift":0,"clip_min":-512,"clip_max":511},
                      {"type":"flatten"}]}"#,
    )
    .unwrap();
    let x: Vec<i64> = vec![1, 2, 3, 4, 5];
    let y = da4ml::nn::sim::forward(&spec, &x);
    // Valid conv positions: [1+4+9, 2+6+12, 3+8+15] = [14, 20, 26].
    assert_eq!(y, vec![14, 20, 26]);
}
