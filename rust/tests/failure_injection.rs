//! Failure-injection tests: the static verifier must reject every class
//! of corrupted DAIS program, and the JSON/spec decoders must reject
//! malformed artifacts with useful errors (never panic).

use da4ml::dais::{verify, DaisBuilder, DaisNode, DaisOp, DaisProgram, OutputSpec};
use da4ml::fixed::QInterval;
use da4ml::json;
use da4ml::nn::{NetworkSpec, TestVectors};

fn valid_program() -> DaisProgram {
    let mut b = DaisBuilder::new();
    let q = QInterval::new(-128, 127, 0);
    let x = b.input(0, q, 0);
    let y = b.input(1, q, 0);
    let t = b.add_shift(x, y, 1, false);
    b.output(t, 0);
    b.finish()
}

#[test]
fn verifier_accepts_valid() {
    verify::check_well_formed(&valid_program()).unwrap();
}

#[test]
fn verifier_rejects_ssa_violation() {
    let mut p = valid_program();
    // Make the adder reference a later node.
    p.nodes[2].op = DaisOp::AddShift { a: 2, b: 1, shift_a: 0, shift_b: 0, sub: false };
    assert!(verify::check_well_formed(&p).is_err());
}

#[test]
fn verifier_rejects_corrupted_interval() {
    let mut p = valid_program();
    p.nodes[2].qint = QInterval::new(0, 1, 0); // too narrow for the sum
    let err = verify::check_well_formed(&p).unwrap_err();
    assert!(format!("{err}").contains("interval"));
}

#[test]
fn verifier_rejects_corrupted_depth() {
    let mut p = valid_program();
    p.nodes[2].depth = 7;
    assert!(verify::check_well_formed(&p).is_err());
}

#[test]
fn verifier_rejects_dangling_output() {
    let mut p = valid_program();
    p.outputs.push(OutputSpec { node: 99, shift: 0 });
    assert!(verify::check_well_formed(&p).is_err());
}

#[test]
fn verifier_rejects_oversized_shift() {
    let mut p = valid_program();
    p.nodes.push(DaisNode {
        op: DaisOp::AddShift { a: 0, b: 1, shift_a: 0, shift_b: 63, sub: false },
        qint: QInterval::new(-1, 1, 0),
        depth: 1,
    });
    assert!(verify::check_well_formed(&p).is_err());
}

#[test]
fn equivalence_rejects_wrong_matrix() {
    let p = valid_program();
    // Program computes [x + 2y]; claim it computes [x + 3y].
    assert!(verify::check_cmvm_equivalence(&p, &[1, 3], 2, 1).is_err());
    verify::check_cmvm_equivalence(&p, &[1, 2], 2, 1).unwrap();
}

#[test]
fn spec_decoder_rejects_malformed() {
    for bad in [
        "{}",
        r#"{"name":"x"}"#,
        r#"{"name":"x","input_bits":8,"input_signed":true,"input_shape":[2],"layers":[{"type":"nope"}]}"#,
        r#"{"name":"x","input_bits":8,"input_signed":true,"input_shape":[2],"layers":[{"type":"dense","w":[[1,"a"]],"b":[0],"relu":false,"shift":0,"clip_min":0,"clip_max":1}]}"#,
    ] {
        assert!(NetworkSpec::from_json(bad).is_err(), "accepted: {bad}");
    }
}

#[test]
fn testvec_decoder_rejects_malformed() {
    assert!(TestVectors::from_json("{}").is_err());
    assert!(TestVectors::from_json(r#"{"inputs":[[1]],"outputs":"x"}"#).is_err());
    let ok = TestVectors::from_json(r#"{"inputs":[[1,2]],"outputs":[[3]]}"#).unwrap();
    assert!(ok.labels.is_empty());
}

/// Regression for unbounded recursion: a deeply nested artifact used to
/// blow the stack inside `json::parse` (decoders must return errors,
/// never panic or crash). The depth limit converts it into a clean error
/// long before stack exhaustion, and is configurable per call.
#[test]
fn json_depth_bomb_returns_error_not_stack_overflow() {
    // 200k unclosed arrays: without a depth limit this recursion level
    // overflows an 8 MiB stack; with the limit it must error cleanly.
    let bomb = "[".repeat(200_000);
    assert!(json::parse(&bomb).is_err());
    // Alternating array/object nesting hits both recursion sites.
    let mixed = "[{\"k\":".repeat(50_000);
    assert!(json::parse(&mixed).is_err());
    // A closed-but-too-deep document is also rejected, with a
    // depth-specific message.
    let deep = format!("{}1{}", "[".repeat(300), "]".repeat(300));
    let err = json::parse(&deep).unwrap_err();
    assert!(format!("{err}").contains("nesting depth"), "got: {err}");
    // The limit is configurable (picojson-rs convention).
    assert!(json::parse_with_depth(&deep, 512).is_ok());
    assert!(json::parse_with_depth("[[1]]", 1).is_err());
}

#[test]
fn json_parser_never_panics_on_garbage() {
    let cases = [
        "", "{", "}", "[[[", "\"", "\u{0}", "nul", "-", "1e", "{\"a\":}", "[1 2]",
        "\"\\u12\"", "\"\\q\"", "123abc", "{\"k\": \"v\",}",
    ];
    for c in cases {
        let _ = json::parse(c); // must return Err, not panic
    }
}

#[test]
fn interp_checked_catches_spec_input_violation() {
    // Feeding an out-of-range input into a checked evaluation panics
    // with the interval diagnostic (wrap-impossible guarantee).
    let p = valid_program();
    let result = std::panic::catch_unwind(|| {
        da4ml::dais::interp::evaluate_checked(&p, &[4096, 0])
    });
    assert!(result.is_err());
}

#[test]
fn conv1d_alias_decodes_and_runs() {
    // Paper §5.1 lists Conv1D among the supported layers; the frontend
    // decodes it as a unit-height Conv2D on a [1, w, c] state.
    let spec = NetworkSpec::from_json(
        r#"{"name":"c1","input_bits":4,"input_signed":false,
            "input_shape":[1,5,1],
            "layers":[{"type":"conv1d","w":[[1],[2],[3]],"b":[0],"k":3,
                       "relu":false,"shift":0,"clip_min":-512,"clip_max":511},
                      {"type":"flatten"}]}"#,
    )
    .unwrap();
    let x: Vec<i64> = vec![1, 2, 3, 4, 5];
    let y = da4ml::nn::sim::forward(&spec, &x);
    // Valid conv positions: [1+4+9, 2+6+12, 3+8+15] = [14, 20, 26].
    assert_eq!(y, vec![14, 20, 26]);
}
