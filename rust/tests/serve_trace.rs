//! End-to-end trace correlation over the socket server: run a traced
//! server, export the event log through the streaming session, and
//! reconstruct every job's decode → queue_wait → execute → write story
//! from the JSONL file with the `obs` analysis layer.
//!
//! Tracing is process-global, so this lives in its own test binary:
//! any untraced test running in the same process while the session is
//! live would leak its server's events into the captured log (and
//! colliding `client-0#0` trace ids would trip the checker's
//! at-most-once rule). Tests added here must not run concurrently with
//! an active trace session — keep this binary to traced tests only.

use da4ml::coordinator::Coordinator;
use da4ml::json;
use da4ml::obs::analyze;
use da4ml::obs::{StreamConfig, StreamingTraceSession};
use da4ml::serve::server::{Server, ServerConfig};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::UnixStream;
use std::thread;

const JOBS: usize = 3;

/// Write every line, half-close, read every reply line until EOF.
fn round_trip(path: &std::path::Path, input: &str) -> Vec<String> {
    let mut tx = UnixStream::connect(path).expect("connect");
    let rx = tx.try_clone().expect("clone");
    tx.write_all(input.as_bytes()).expect("send");
    tx.shutdown(std::net::Shutdown::Write).expect("half-close");
    BufReader::new(rx).lines().map(|l| l.expect("reply line")).collect()
}

#[test]
fn streaming_trace_reconstructs_every_job_stage() {
    let pid = std::process::id();
    let trace_path = std::env::temp_dir().join(format!("da4ml-trace-e2e-{pid}.jsonl"));
    let trace_path = trace_path.to_str().unwrap().to_string();
    let session = StreamingTraceSession::begin(StreamConfig {
        path: trace_path.clone(),
        rotate_bytes: None,
    })
    .expect("begin streaming session");

    let sock = std::env::temp_dir().join(format!("da4ml-trace-e2e-{pid}.sock"));
    let server =
        Server::bind(Coordinator::new(), ServerConfig::default(), &sock, None).expect("bind");
    let handle = server.handle();
    let join = thread::spawn(move || server.run().expect("server run"));

    let input: String = (0..JOBS)
        .map(|j| format!("{{\"id\": \"tr-{j}\", \"matrix\": [[2, 3], [5, 7]], \"timing\": true}}\n"))
        .collect();
    let lines = round_trip(&sock, &input);
    handle.shutdown();
    let summary = join.join().expect("server thread");
    let (trace_file, metrics_file) = session.finish().expect("finish session");

    // Wire-side: each opted-in reply names its own trace id, and the
    // final stats line reports the connection's full id range.
    assert_eq!(lines.len(), JOBS + 1, "one reply per job plus final stats: {lines:?}");
    assert_eq!(summary.jobs, JOBS as u64);
    for (j, line) in lines[..JOBS].iter().enumerate() {
        let v = json::parse(line).expect("reply is JSON");
        let timing = v.get("timing").expect("opted-in reply carries timing");
        let got = timing.get("trace_id").unwrap().as_str().unwrap().to_string();
        assert_eq!(got, format!("client-0#{j}"));
    }
    let last = json::parse(&lines[JOBS]).expect("final stats line is JSON");
    assert!(last.get("final").unwrap().as_bool().unwrap());
    assert_eq!(last.get("trace_ids").unwrap().as_str().unwrap(), "client-0#0..client-0#2");

    // Log-side: the exported JSONL passes the structural checker and
    // yields a clean critical path for every job's trace id.
    let text = std::fs::read_to_string(&trace_file).expect("read trace log");
    let log = analyze::parse_log(&text).expect("parse trace log");
    let report = analyze::check(&log.events, log.dropped_events);
    assert!(report.passed(), "trace log fails structural check: {:?}", report.errors);

    let paths = analyze::critical_path(&log.events);
    assert!(paths.problems.is_empty(), "broken phase stories: {:?}", paths.problems);
    assert_eq!(paths.traces, JOBS, "one reconstructed path per job");

    let mut by_trace: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for e in &log.events {
        if let Some(t) = e.arg_str("trace_id") {
            by_trace.entry(t).or_default().push(e.name.as_str());
        }
    }
    for j in 0..JOBS {
        let id = format!("client-0#{j}");
        let names = by_trace.get(id.as_str()).unwrap_or_else(|| panic!("no events for {id}"));
        for want in ["serve.decode", "serve.queue_wait", "serve.execute", "serve.write"] {
            assert!(names.contains(&want), "{id} missing {want}: {names:?}");
        }
    }

    let _ = std::fs::remove_file(&trace_file);
    let _ = std::fs::remove_file(&metrics_file);
}
