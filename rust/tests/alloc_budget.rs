//! Allocation-budget regression test for the arena-allocated optimizer
//! core: an arena-warm compile must allocate strictly less than a cold
//! one, stay under an absolute budget, and emit a bit-identical
//! program either way.
//!
//! This binary installs the counting global allocator itself (the
//! library never forces it on its consumers), so the counters here
//! observe every heap allocation the compile makes.

use da4ml::cmvm::{compile, ArenaMode, CmvmProblem, CompileArena, OptimizeOptions, Strategy};
use da4ml::util::alloc_count::{count, CountingAlloc};

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Jet-MLP-shaped layer problems (16-64-32-32-5, 8-bit weights) — the
/// same shape class the perf suite's `net/jet/*` cases compile.
fn jet_layer_problems() -> Vec<CmvmProblem> {
    [(16usize, 64usize), (64, 32), (32, 32), (32, 5)]
        .iter()
        .enumerate()
        .map(|(i, &(d_in, d_out))| CmvmProblem::random(4200 + i as u64, d_in, d_out, 8))
        .collect()
}

#[test]
fn arena_warm_compile_allocates_less_and_stays_bit_identical() {
    let problems = jet_layer_problems();
    let strategy = Strategy::Da { dc: 2 };

    // Cold: fresh allocations for every layer, no reuse anywhere.
    let (cold_sols, cold_allocs, _) = count(|| {
        problems
            .iter()
            .map(|p| {
                let opts = OptimizeOptions::new(strategy).with_arena(ArenaMode::Fresh);
                compile(p, &opts).expect("compile")
            })
            .collect::<Vec<_>>()
    });

    // Warm the arena on a full pass, then measure a second pass that
    // reuses the slabs the first one grew.
    let arena = CompileArena::new();
    let run = |arena: &CompileArena| {
        problems
            .iter()
            .map(|p| {
                let opts = OptimizeOptions::new(strategy).with_arena(ArenaMode::Local(arena));
                compile(p, &opts).expect("compile")
            })
            .collect::<Vec<_>>()
    };
    let warmup_sols = run(&arena);
    let (warm_sols, warm_allocs, _) = count(|| run(&arena));

    // Bit-identity: the arena is an allocation policy, not a behavior
    // knob — all three passes must emit byte-identical programs.
    for ((c, w0), w1) in cold_sols.iter().zip(&warmup_sols).zip(&warm_sols) {
        assert_eq!(c.program, w0.program, "cold vs warmup program diverged");
        assert_eq!(c.program, w1.program, "cold vs warm program diverged");
        assert_eq!(c.cse, w1.cse, "engine counters diverged");
    }

    // The budget: warm passes recycle the engine containers, the
    // pattern bitset words, and the builder's consing map, so they must
    // allocate strictly less than cold ones — and fit an absolute
    // ceiling generous enough to survive libstd/HashMap implementation
    // drift while still catching a lost arena (which costs many
    // thousands of allocations per layer on these sizes).
    assert!(cold_allocs > 0, "counting allocator must be live in this binary");
    assert!(
        warm_allocs < cold_allocs,
        "arena-warm pass must allocate less than cold: warm {warm_allocs} vs cold {cold_allocs}"
    );
    assert!(
        warm_allocs < 1_000_000,
        "warm allocs per 4-layer jet compile blew the absolute budget: {warm_allocs}"
    );
}
