//! Integration tests over the built artifacts: the full L3 stack
//! (frontend -> optimizer -> DAIS -> estimate/RTL -> runtime) against
//! the Python-exported networks. Requires `make artifacts`; every test
//! skips cleanly when the artifacts are absent (e.g. bare `cargo test`
//! before the first build).

use da4ml::cmvm::Strategy;
use da4ml::coordinator::{CompileJob, Coordinator};
use da4ml::dais::{interp, verify};
use da4ml::estimate::FpgaModel;
use da4ml::nn::{self, LayerSpec, NetworkSpec, TestVectors};
use da4ml::pipeline::{assign_stages, PipelineConfig};
use da4ml::runtime;

fn load(name: &str) -> Option<(NetworkSpec, TestVectors)> {
    let dir = runtime::artifacts_dir();
    let spec = runtime::load_text(dir.join(format!("{name}.weights.json"))).ok()?;
    let vecs = runtime::load_text(dir.join(format!("{name}.testvec.json"))).ok()?;
    Some((
        NetworkSpec::from_json(&spec).expect("spec decodes"),
        TestVectors::from_json(&vecs).expect("vectors decode"),
    ))
}

macro_rules! needs_artifacts {
    ($name:expr) => {
        match load($name) {
            Some(x) => x,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

/// Host integer simulation must reproduce the JAX/Pallas-exported golden
/// outputs bit-exactly for every network and every test vector.
#[test]
fn host_sim_matches_python_export_all_networks() {
    for name in ["jet_mlp", "muon", "mixer", "svhn"] {
        let (spec, vecs) = needs_artifacts!(name);
        let outs = nn::sim::forward_batch(&spec, &vecs.inputs);
        for (i, (got, want)) in outs.iter().zip(&vecs.outputs).enumerate() {
            assert_eq!(got, want, "{name}: vector {i} diverges");
        }
    }
}

/// The fused DAIS adder graph (both strategies) is bit-exact to the
/// host simulation on the fusible networks.
#[test]
fn fused_dais_matches_export() {
    for name in ["jet_mlp", "muon", "mixer"] {
        let (spec, vecs) = needs_artifacts!(name);
        for s in [Strategy::NaiveDa, Strategy::Da { dc: 2 }] {
            let prog = nn::compile::compile(&spec, &nn::compile::CompileOptions::new(s))
                .expect("compile")
                .program;
            verify::check_well_formed(&prog).expect("well-formed");
            for (x, want) in vecs.inputs.iter().zip(&vecs.outputs).take(64) {
                let got = interp::evaluate_checked(&prog, x);
                assert_eq!(&got, want, "{name} {s:?}");
            }
        }
    }
}

/// Pipelined streaming at II=1 equals combinational on real networks.
#[test]
fn pipelined_network_streams_at_ii1() {
    let (spec, vecs) = needs_artifacts!("jet_mlp");
    let opts = nn::compile::CompileOptions::new(Strategy::Da { dc: 2 });
    let prog = nn::compile::compile(&spec, &opts).unwrap().program;
    for every in [1, 5] {
        let stages = assign_stages(&prog, &PipelineConfig::every_n_adders(every));
        let stream: Vec<Vec<i64>> = vecs.inputs.iter().take(48).cloned().collect();
        assert_eq!(
            interp::simulate_pipelined(&prog, &stages, &stream),
            interp::evaluate_batch(&prog, &stream)
        );
    }
}

/// The coordinator compiles every layer of every artifact network; DA
/// never uses more adders than naive DA on any layer.
#[test]
fn coordinator_compiles_all_artifact_layers() {
    let coord = Coordinator::new();
    let mut jobs = Vec::new();
    for name in ["jet_mlp", "muon", "mixer", "svhn"] {
        let (spec, _) = needs_artifacts!(name);
        let mut qint = spec.input_qint();
        for (li, layer) in spec.layers.iter().enumerate() {
            if let LayerSpec::Dense { w, b, clip_min, clip_max, .. }
            | LayerSpec::EinsumDense { w, b, clip_min, clip_max, .. }
            | LayerSpec::Conv2D { w, b, clip_min, clip_max, .. } = layer
            {
                let matrix: Vec<i64> = w.iter().flatten().copied().collect();
                let mut problem =
                    da4ml::cmvm::CmvmProblem::new(w.len(), b.len(), matrix, 8).unwrap();
                problem.input_qint = vec![qint; w.len()];
                for strategy in [Strategy::NaiveDa, Strategy::Da { dc: 2 }] {
                    jobs.push(CompileJob {
                        name: format!("{name}/l{li}/{}", strategy.name()),
                        problem: problem.clone(),
                        strategy,
                    });
                }
                qint = da4ml::fixed::QInterval::new(*clip_min, *clip_max, 0);
            }
        }
    }
    if jobs.is_empty() {
        return;
    }
    let n = jobs.len();
    let sols = coord.compile_many(jobs).unwrap();
    assert_eq!(sols.len(), n);
    for pair in sols.chunks(2) {
        let (naive, da) = (&pair[0], &pair[1]);
        assert!(da.adders <= naive.adders, "DA must not exceed naive adders");
    }
    assert!(coord.stats().submitted as usize >= n);
}

/// RTL emission of a real network parses structurally: module/endmodule
/// balance, one assignment per node, registers only when pipelined.
#[test]
fn rtl_emission_structural_checks() {
    let (spec, _) = needs_artifacts!("jet_mlp");
    let opts = nn::compile::CompileOptions::new(Strategy::Da { dc: 2 });
    let prog = nn::compile::compile(&spec, &opts).unwrap().program;
    let comb = da4ml::rtl::emit_verilog(&prog, "jet", None).unwrap();
    assert_eq!(comb.matches("module ").count(), 1);
    assert!(comb.contains("endmodule"));
    assert!(!comb.contains("posedge"));
    assert_eq!(comb.matches("assign n").count(), prog.nodes.len());

    let stages = assign_stages(&prog, &PipelineConfig::every_n_adders(5));
    let piped = da4ml::rtl::emit_verilog(&prog, "jet_p", Some(&stages)).unwrap();
    assert!(piped.contains("posedge clk"));
    // VHDL pipelines too now (same netlist walk as Verilog).
    let vhdl = da4ml::rtl::emit_vhdl(&prog, "jet_v", Some(&stages)).unwrap();
    assert!(vhdl.contains("end architecture;"));
    assert!(vhdl.contains("rising_edge(clk)"));
    let nl = da4ml::netlist::Netlist::lower(&prog, Some(&stages)).unwrap();
    assert_eq!(
        piped.lines().filter(|l| l.trim_start().starts_with("reg ")).count(),
        nl.regs.len(),
        "Verilog register declarations must match the netlist delay lines"
    );
}

/// The lowered netlist of a real network, cycle-accurately simulated,
/// reproduces the exported golden outputs through the full pipeline —
/// the closest software stand-in for running the emitted RTL under
/// Verilator.
#[test]
fn netlist_simulation_matches_export_jet() {
    let (spec, vecs) = needs_artifacts!("jet_mlp");
    let opts = nn::compile::CompileOptions::new(Strategy::Da { dc: 2 });
    let prog = nn::compile::compile(&spec, &opts).unwrap().program;
    let stream: Vec<Vec<i64>> = vecs.inputs.iter().take(24).cloned().collect();
    let want: Vec<Vec<i64>> = vecs.outputs.iter().take(24).cloned().collect();
    for every in [1, 5] {
        let stages = assign_stages(&prog, &PipelineConfig::every_n_adders(every));
        let nl = da4ml::netlist::Netlist::lower(&prog, Some(&stages)).unwrap();
        assert_eq!(
            da4ml::netlist::sim::simulate(&nl, &stream),
            want,
            "pipelined netlist (every {every}) diverges from the export"
        );
    }
    let nl = da4ml::netlist::Netlist::lower(&prog, None).unwrap();
    assert_eq!(da4ml::netlist::sim::simulate(&nl, &stream), want);
    // And the self-checking testbench generator accepts the real
    // artifact vectors for this netlist.
    let tb = da4ml::netlist::testbench::emit_testbench(&nl, "jet_mlp", &vecs, 8).unwrap();
    assert!(tb.contains("module jet_mlp_tb;"));
    assert!(tb.contains("$finish"));
}

/// The default (pure-Rust) golden backend serves the exported artifacts
/// through the PJRT-shaped `run_i32` entry point and reproduces the
/// JAX-exported outputs bit-exactly. Skips cleanly without artifacts.
#[test]
fn golden_fallback_cross_check_jet() {
    let (spec, vecs) = needs_artifacts!("jet_mlp");
    let golden = runtime::golden::GoldenModel::from_spec(spec.clone());
    let weights = nn::weight_tensors(&spec);
    for (x, want) in vecs.inputs.iter().zip(&vecs.outputs).take(16) {
        let mut args = vec![runtime::TensorI32::new(
            x.iter().map(|&v| v as i32).collect(),
            vec![x.len() as i64],
        )];
        args.extend(weights.iter().cloned());
        let out = golden.run_i32(&args).expect("golden run");
        let got: Vec<i64> = out[0].data.iter().map(|&v| v as i64).collect();
        assert_eq!(&got, want, "golden backend diverges from exported vectors");
    }
}

/// The PJRT golden model agrees with the DAIS graph end-to-end (the
/// three-layer composition proof, also exercised by the jet example).
/// Requires the real `xla` crate; with the vendored stub the client
/// constructor fails, so the test skips rather than asserts.
#[cfg(feature = "pjrt")]
#[test]
fn pjrt_golden_cross_check_jet() {
    let (spec, vecs) = needs_artifacts!("jet_mlp");
    let dir = runtime::artifacts_dir();
    let hlo = dir.join("jet_mlp.hlo.txt");
    if !hlo.exists() {
        eprintln!("skipping: no HLO artifact");
        return;
    }
    let Ok(rt) = runtime::Runtime::cpu() else {
        eprintln!("skipping: PJRT unavailable (xla stub build)");
        return;
    };
    let golden = rt.load_hlo_text(&hlo).expect("compile HLO");
    let weights = nn::weight_tensors(&spec);
    for x in vecs.inputs.iter().take(16) {
        let mut args = vec![runtime::TensorI32::new(
            x.iter().map(|&v| v as i32).collect(),
            vec![x.len() as i64],
        )];
        args.extend(weights.iter().cloned());
        let out = golden.run_i32(&args).expect("execute");
        let got: Vec<i64> = out[0].data.iter().map(|&v| v as i64).collect();
        assert_eq!(got, nn::sim::forward(&spec, x));
    }
}

/// Resource reports behave sanely across quantization levels: LUTs and
/// adders shrink as bits shrink; DA always beats latency on LUTs for
/// the 4-bit level (the all-LUT regime).
#[test]
fn resource_trends_across_levels() {
    let dir = runtime::artifacts_dir();
    let model = FpgaModel::default();
    let cfg = PipelineConfig::every_n_adders(5);
    let mut luts = Vec::new();
    for (w, a) in [(8, 8), (6, 6), (4, 5)] {
        let path = dir.join(format!("jet_mlp_w{w}a{a}.weights.json"));
        let Ok(text) = runtime::load_text(path) else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let spec = NetworkSpec::from_json(&text).unwrap();
        let da = nn::compile::network_report(&spec, Strategy::Da { dc: 2 }, &model, &cfg)
            .unwrap();
        let lat =
            nn::compile::network_report(&spec, Strategy::Latency, &model, &cfg).unwrap();
        assert_eq!(da.dsp, 0);
        assert!(da.lut < lat.lut, "w{w}a{a}: DA {} !< latency {}", da.lut, lat.lut);
        luts.push(da.lut);
    }
    assert!(luts[0] > luts[1] && luts[1] > luts[2], "LUTs shrink with bits: {luts:?}");
}
