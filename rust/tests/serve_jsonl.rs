//! Integration test for the `serve` compile service: a multi-job JSONL
//! batch — realistic layer-matrix jobs with recurring weights — round-
//! trips through the [`da4ml::coordinator::Coordinator`] and streams
//! back per-job reports plus batch stats with the cache hits visible.

use da4ml::coordinator::Coordinator;
use da4ml::json::{self, Value};
use da4ml::serve::server::{run_client, Server, ServerConfig};
use da4ml::serve::{serve, serve_with, ServeConfig};
use da4ml::util::Rng;
use std::io::Cursor;

fn matrix_json(seed: u64, d_in: usize, d_out: usize) -> String {
    let mut rng = Rng::seed_from(seed);
    let rows: Vec<String> = (0..d_in)
        .map(|_| {
            let row: Vec<String> =
                (0..d_out).map(|_| rng.range_i64(-127, 127).to_string()).collect();
            format!("[{}]", row.join(","))
        })
        .collect();
    format!("[{}]", rows.join(","))
}

#[test]
fn serve_round_trips_multi_job_batch_with_cache_hits() {
    // A quantization-sweep-like workload: 3 distinct layer matrices,
    // each compiled twice (the recurring-matrix scenario the
    // coordinator cache exists for), one job per batch so every
    // duplicate is a deterministic cache hit.
    let mut input = String::new();
    for round in 0..2 {
        for (i, seed) in [11u64, 22, 33].iter().enumerate() {
            input.push_str(&format!(
                "{{\"id\": \"r{round}-m{i}\", \"matrix\": {}, \"bits\": 8, \
                 \"strategy\": \"da\", \"dc\": 2}}\n",
                matrix_json(*seed, 8, 8)
            ));
        }
    }
    let cfg = ServeConfig { batch_size: 1, ..ServeConfig::default() };
    let mut out = Vec::new();
    let summary = serve(Cursor::new(input), &mut out, &cfg).unwrap();

    assert_eq!(summary.jobs, 6);
    assert_eq!(summary.errors, 0);
    assert_eq!(summary.batches, 6);
    assert_eq!(summary.stats.submitted, 6);
    assert_eq!(summary.stats.cache_hits, 3);

    let text = String::from_utf8(out).unwrap();
    let lines: Vec<Value> =
        text.lines().map(|l| json::parse(l).expect("reply line is JSON")).collect();
    // One result + one stats line per batch.
    assert_eq!(lines.len(), 12);

    let results: Vec<&Value> = lines
        .iter()
        .filter(|l| l.get("type").unwrap().as_str().unwrap() == "result")
        .collect();
    assert_eq!(results.len(), 6);
    for (i, r) in results.iter().enumerate() {
        // Replies arrive in job order with the caller's correlation ids.
        let (round, m) = (i / 3, i % 3);
        assert_eq!(r.get("id").unwrap().as_str().unwrap(), format!("r{round}-m{m}"));
        // Round 1 is compiled, round 2 is served from cache.
        assert_eq!(r.get("cached").unwrap().as_bool().unwrap(), round == 1);
        assert!(r.get("adders").unwrap().as_i64().unwrap() > 0);
        assert!(r.get("lut").unwrap().as_i64().unwrap() > 0);
        assert!(r.get("latency_ns").unwrap().as_f64().unwrap() > 0.0);
    }
    // Cached replies report the same solution as the original compile.
    for m in 0..3 {
        assert_eq!(
            results[m].get("adders").unwrap().as_i64().unwrap(),
            results[m + 3].get("adders").unwrap().as_i64().unwrap(),
            "cache returned a different solution for matrix {m}"
        );
    }

    // The final stats line shows the whole cache story.
    let stats = lines.last().unwrap();
    assert_eq!(stats.get("type").unwrap().as_str().unwrap(), "stats");
    assert_eq!(stats.get("submitted").unwrap().as_i64().unwrap(), 6);
    assert_eq!(stats.get("cache_hits").unwrap().as_i64().unwrap(), 3);
    assert_eq!(stats.get("cache_size").unwrap().as_i64().unwrap(), 3);
}

/// Compile jobs can request RTL emission on the wire: the reply
/// carries the Verilog/VHDL text of the optimized solution, cached
/// replies re-emit identically, and the emitted Verilog simulates
/// (via the netlist layer) to exactly `x^T M` for the job matrix.
#[test]
fn serve_emits_rtl_on_request() {
    let input = "{\"id\": \"fc1\", \"matrix\": [[2, 3], [5, 7]], \"dc\": -1, \
                 \"emit\": \"verilog\"}\n\
                 {\"id\": \"fc1b\", \"matrix\": [[2, 3], [5, 7]], \"dc\": -1, \
                 \"emit\": \"verilog\"}\n\
                 {\"id\": \"fc1v\", \"matrix\": [[2, 3], [5, 7]], \"dc\": -1, \
                 \"emit\": \"vhdl\"}\n";
    let cfg = ServeConfig { batch_size: 1, ..ServeConfig::default() };
    let mut out = Vec::new();
    let summary = serve(Cursor::new(input.to_string()), &mut out, &cfg).unwrap();
    assert_eq!(summary.jobs, 3);
    assert_eq!(summary.errors, 0);
    assert_eq!(summary.stats.cache_hits, 2);

    let text = String::from_utf8(out).unwrap();
    let results: Vec<Value> = text
        .lines()
        .map(|l| json::parse(l).unwrap())
        .filter(|l| l.get("type").unwrap().as_str().unwrap() == "result")
        .collect();
    assert_eq!(results.len(), 3);
    let v1 = results[0].get("rtl").unwrap().as_str().unwrap().to_string();
    assert!(v1.contains("module fc1 ("));
    assert!(v1.contains("endmodule"));
    // The cached duplicate re-emits the same module body (only the
    // name differs).
    let v2 = results[1].get("rtl").unwrap().as_str().unwrap().to_string();
    assert!(results[1].get("cached").unwrap().as_bool().unwrap());
    assert_eq!(
        v1.replace("fc1", "x"),
        v2.replace("fc1b", "x"),
        "cached reply must emit the identical design"
    );
    let vhdl = results[2].get("rtl").unwrap().as_str().unwrap();
    assert!(vhdl.contains("entity fc1v is"));

    // Close the loop: the served Verilog is the lowering of the same
    // program the netlist simulator executes, so re-deriving the
    // solution locally and simulating must realize y = x^T M.
    let prob = da4ml::cmvm::CmvmProblem::new(2, 2, vec![2, 3, 5, 7], 8).unwrap();
    let opts = da4ml::cmvm::OptimizeOptions::new(da4ml::cmvm::Strategy::Da { dc: -1 });
    let sol = da4ml::cmvm::compile(&prob, &opts).unwrap();
    let local = da4ml::rtl::emit_verilog(&sol.program, "fc1", None).unwrap();
    assert_eq!(local, v1, "served RTL matches a local emission of the same job");
    let nl = da4ml::netlist::Netlist::lower(&sol.program, None).unwrap();
    for x in [[1i64, 0], [0, 1], [3, -4], [-128, 127]] {
        let y = da4ml::netlist::sim::evaluate(&nl, &x);
        assert_eq!(y, vec![2 * x[0] + 5 * x[1], 3 * x[0] + 7 * x[1]]);
    }
}

/// A deterministic mixed job stream: compile jobs (one recurring
/// matrix for a cache hit, one RTL emission, one default id), a blank
/// line, a malformed line, and an invalid job — every reply class both
/// transports must render identically.
fn transport_fixture() -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{{\"id\": \"a\", \"matrix\": {}, \"bits\": 8, \"dc\": 2}}\n",
        matrix_json(41, 4, 4)
    ));
    s.push('\n'); // blank: skipped, but still counted for line numbers
    s.push_str(&format!(
        "{{\"id\": \"b\", \"matrix\": {}, \"dc\": -1, \"emit\": \"verilog\"}}\n",
        matrix_json(42, 3, 3)
    ));
    s.push_str("this is not json\n");
    s.push_str(&format!(
        "{{\"matrix\": {}, \"dc\": 2}}\n", // no id: defaults to job-5
        matrix_json(43, 4, 4)
    ));
    s.push_str("{\"id\": \"bad\", \"matrix\": [[1]], \"strategy\": \"hls\"}\n");
    s.push_str(&format!(
        "{{\"id\": \"a2\", \"matrix\": {}, \"bits\": 8, \"dc\": 2}}\n", // repeat of "a"
        matrix_json(41, 4, 4)
    ));
    s
}

/// The reply lines both transports must agree on: everything except
/// the stats lines (their extra fields are transport bookkeeping —
/// batches on stdin, clients on the socket).
fn non_stats_lines(out: &[u8]) -> Vec<String> {
    String::from_utf8(out.to_vec())
        .unwrap()
        .lines()
        .filter(|l| {
            json::parse(l).expect("reply line is JSON").get("type").unwrap().as_str().unwrap()
                != "stats"
        })
        .map(|l| l.to_string())
        .collect()
}

/// Run the fixture through the socket transport: a real server on a
/// Unix socket, driven by the same thin client the CLI uses.
fn socket_transport_run(coord: Coordinator, cfg: &ServeConfig, input: &str) -> Vec<u8> {
    let sock = std::env::temp_dir().join(format!(
        "da4ml-xport-{}-{}.sock",
        std::process::id(),
        coord.shard_count()
    ));
    let _ = std::fs::remove_file(&sock);
    // One worker: jobs execute strictly in submission order, so the
    // recurring matrix is a deterministic cache hit on both transports.
    let scfg = ServerConfig { serve: cfg.clone(), workers: 1, ..ServerConfig::default() };
    let server = Server::bind(coord, scfg, &sock, None).expect("bind");
    let handle = server.handle();
    let join = std::thread::spawn(move || server.run().expect("server run"));
    let mut out = Vec::new();
    run_client(&sock.to_string_lossy(), Cursor::new(input.to_string()), &mut out)
        .expect("client run");
    handle.shutdown();
    join.join().expect("server thread");
    out
}

/// The tentpole contract: stdin mode and socket mode are thin clients
/// of one core, so the same job file yields byte-identical reply lines
/// on both transports. Cold runs agree after masking the one
/// wall-clock field (`opt_ms`); warm runs from the same baked cache
/// agree byte-for-byte with no masking at all.
#[test]
fn stdin_and_socket_transports_are_byte_identical() {
    let input = transport_fixture();
    let cfg = ServeConfig { batch_size: 1, ..ServeConfig::default() };

    // Cold: fresh coordinator per transport, wall-clock masked.
    let mut stdin_cold = Vec::new();
    serve_with(&Coordinator::new(), Cursor::new(input.clone()), &mut stdin_cold, &cfg).unwrap();
    let socket_cold = socket_transport_run(Coordinator::new(), &cfg, &input);
    let mask = |lines: Vec<String>| -> Vec<Value> {
        lines
            .iter()
            .map(|l| match json::parse(l).unwrap() {
                Value::Object(mut o) => {
                    if o.contains_key("opt_ms") {
                        o.insert("opt_ms".into(), Value::Int(0));
                    }
                    Value::Object(o)
                }
                v => v,
            })
            .collect()
    };
    assert_eq!(
        mask(non_stats_lines(&stdin_cold)),
        mask(non_stats_lines(&socket_cold)),
        "cold replies must agree up to wall-clock timing"
    );

    // Warm: bake a cache once, load the identical cache into both
    // transports — every reply byte (timing included) round-trips.
    let baker = Coordinator::new();
    let mut sink = Vec::new();
    serve_with(&baker, Cursor::new(input.clone()), &mut sink, &cfg).unwrap();
    let cache = baker.save_cache();

    let warm_stdin_coord = Coordinator::new();
    warm_stdin_coord.load_cache(&cache).unwrap();
    let mut stdin_warm = Vec::new();
    serve_with(&warm_stdin_coord, Cursor::new(input.clone()), &mut stdin_warm, &cfg).unwrap();

    let warm_socket_coord = Coordinator::new();
    warm_socket_coord.load_cache(&cache).unwrap();
    let socket_warm = socket_transport_run(warm_socket_coord, &cfg, &input);

    let stdin_lines = non_stats_lines(&stdin_warm);
    let socket_lines = non_stats_lines(&socket_warm);
    assert_eq!(stdin_lines, socket_lines, "warm replies must be byte-identical");
    assert_eq!(stdin_lines.len(), 6, "4 results + 2 error replies");
    // Sanity on the classes covered: cache hits, RTL, errors, default id.
    let vals: Vec<Value> = stdin_lines.iter().map(|l| json::parse(l).unwrap()).collect();
    assert!(vals.iter().all(|v| {
        v.get("type").unwrap().as_str().unwrap() != "result"
            || v.get("cached").unwrap().as_bool().unwrap()
    }));
    assert!(vals[1].get("rtl").unwrap().as_str().unwrap().contains("module b ("));
    assert!(matches!(vals[2].get("id").unwrap(), Value::Null));
    assert_eq!(vals[3].get("id").unwrap().as_str().unwrap(), "job-5");
    assert_eq!(vals[4].get("type").unwrap().as_str().unwrap(), "error");
}

/// Larger batches still answer every job and keep reply order. Every
/// repeat here is cross-batch (batches flush synchronously), so the
/// hit totals are deterministic even with a racing worker pool.
#[test]
fn serve_batched_workload_accounts_every_job() {
    let mut input = String::new();
    for i in 0..10 {
        // 5 distinct matrices, each appearing twice.
        input.push_str(&format!(
            "{{\"id\": \"j{i}\", \"matrix\": {}, \"dc\": -1}}\n",
            matrix_json(100 + (i % 5) as u64, 4, 4)
        ));
    }
    let cfg = ServeConfig { batch_size: 4, ..ServeConfig::default() };
    let mut out = Vec::new();
    let summary = serve(Cursor::new(input), &mut out, &cfg).unwrap();
    assert_eq!(summary.jobs, 10);
    assert_eq!(summary.errors, 0);
    assert_eq!(summary.batches, 3);

    let text = String::from_utf8(out).unwrap();
    let lines: Vec<Value> = text.lines().map(|l| json::parse(l).unwrap()).collect();
    let ids: Vec<String> = lines
        .iter()
        .filter(|l| l.get("type").unwrap().as_str().unwrap() == "result")
        .map(|l| l.get("id").unwrap().as_str().unwrap().to_string())
        .collect();
    let want: Vec<String> = (0..10).map(|i| format!("j{i}")).collect();
    assert_eq!(ids, want, "replies must preserve job order across batches");
    // 5 distinct matrices; every repeat lands in a later batch, so the
    // cache absorbs exactly the 5 repeats.
    assert_eq!(summary.stats.submitted, 10);
    assert_eq!(summary.stats.cache_hits, 5);
    let stats = lines.last().unwrap();
    assert_eq!(stats.get("cache_size").unwrap().as_i64().unwrap(), 5);
}
