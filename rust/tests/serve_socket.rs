//! Integration hammer for the socket compile server: many concurrent
//! clients over one Unix socket must each get their own replies, in
//! their own submission order, with cross-client cache hits visible in
//! the final stats — and a drain under load must answer every accepted
//! job exactly once (a result, a `busy` rejection, or a
//! `shutting_down` rejection; never silence, never a duplicate).

use da4ml::coordinator::Coordinator;
use da4ml::json::{self, Value};
use da4ml::serve::server::{Server, ServerConfig, ServerHandle, ServerSummary};
use da4ml::util::Rng;
use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::UnixStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::thread;
use std::time::Duration;

/// A collision-free socket path in the test temp dir.
fn socket_path(tag: &str) -> PathBuf {
    static N: AtomicU64 = AtomicU64::new(0);
    let n = N.fetch_add(1, Ordering::SeqCst);
    std::env::temp_dir().join(format!("da4ml-{tag}-{}-{n}.sock", std::process::id()))
}

/// Bind + run a server on a background thread.
fn start(
    cfg: ServerConfig,
    tag: &str,
) -> (PathBuf, ServerHandle, thread::JoinHandle<ServerSummary>) {
    let path = socket_path(tag);
    let coord = Coordinator::with_shards(cfg.serve.cache_shards);
    let server = Server::bind(coord, cfg, &path, None).expect("bind");
    let handle = server.handle();
    let join = thread::spawn(move || server.run().expect("server run"));
    (path, handle, join)
}

fn matrix_json(seed: u64, d_in: usize, d_out: usize) -> String {
    let mut rng = Rng::seed_from(seed);
    let rows: Vec<String> = (0..d_in)
        .map(|_| {
            let row: Vec<String> =
                (0..d_out).map(|_| rng.range_i64(-127, 127).to_string()).collect();
            format!("[{}]", row.join(","))
        })
        .collect();
    format!("[{}]", rows.join(","))
}

fn job_line(id: &str, seed: u64, dim: usize) -> String {
    format!(
        "{{\"id\": \"{id}\", \"matrix\": {}, \"bits\": 8, \"dc\": 2}}\n",
        matrix_json(seed, dim, dim)
    )
}

/// Write every line, half-close, read every reply line until EOF.
fn round_trip(path: &std::path::Path, input: &str) -> Vec<String> {
    let mut tx = UnixStream::connect(path).expect("connect");
    let rx = tx.try_clone().expect("clone");
    tx.write_all(input.as_bytes()).expect("send");
    tx.shutdown(std::net::Shutdown::Write).expect("half-close");
    BufReader::new(rx).lines().map(|l| l.expect("reply line")).collect()
}

fn parsed(lines: &[String]) -> Vec<Value> {
    lines.iter().map(|l| json::parse(l).expect("reply is JSON")).collect()
}

fn type_of(v: &Value) -> &str {
    v.get("type").unwrap().as_str().unwrap()
}

/// N clients × M jobs drawn from a small shared matrix pool: every
/// reply reaches the client that asked, in that client's submission
/// order, and (after a pre-warm pass) every hammer job is a cache hit
/// visible both per client and in the final server summary.
#[test]
fn multi_client_hammer_routes_and_orders_replies() {
    const CLIENTS: usize = 4;
    const JOBS: usize = 12;
    const POOL: usize = 6;
    let (path, handle, join) = start(ServerConfig::default(), "hammer");

    // Pre-warm: compile the whole matrix pool once, sequentially, so
    // the hammer phase is deterministic (every job a cache hit — no
    // same-matrix compile races to account for).
    let warm: String = (0..POOL).map(|m| job_line(&format!("warm-{m}"), 7 + m as u64, 4)).collect();
    let warm_replies = round_trip(&path, &warm);
    let warm_vals = parsed(&warm_replies);
    assert_eq!(warm_vals.len(), POOL + 1, "pool results + final stats");
    for v in &warm_vals[..POOL] {
        assert_eq!(type_of(v), "result");
        assert!(!v.get("cached").unwrap().as_bool().unwrap());
    }

    let workers: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let path = path.clone();
            thread::spawn(move || {
                let input: String = (0..JOBS)
                    .map(|j| job_line(&format!("c{c}-j{j}"), 7 + ((c + j) % POOL) as u64, 4))
                    .collect();
                (c, round_trip(&path, &input))
            })
        })
        .collect();
    for w in workers {
        let (c, lines) = w.join().expect("client thread");
        let vals = parsed(&lines);
        assert_eq!(vals.len(), JOBS + 1, "client {c}: {lines:?}");
        for (j, v) in vals[..JOBS].iter().enumerate() {
            assert_eq!(type_of(v), "result");
            // Routing + ordering: my id, my order.
            assert_eq!(v.get("id").unwrap().as_str().unwrap(), format!("c{c}-j{j}"));
            assert!(v.get("cached").unwrap().as_bool().unwrap(), "c{c}-j{j} not cached");
        }
        let stats = &vals[JOBS];
        assert_eq!(type_of(stats), "stats");
        assert!(stats.get("final").unwrap().as_bool().unwrap());
        assert_eq!(stats.get("client_jobs").unwrap().as_i64().unwrap(), JOBS as i64);
        assert_eq!(stats.get("client_replies").unwrap().as_i64().unwrap(), JOBS as i64);
        assert_eq!(stats.get("client_errors").unwrap().as_i64().unwrap(), 0);
        assert_eq!(
            stats.get("client_cache_hits").unwrap().as_i64().unwrap(),
            JOBS as i64,
            "cross-client hits: client {c} compiled nothing itself"
        );
    }

    handle.shutdown();
    let summary = join.join().expect("server thread");
    assert_eq!(summary.clients, 1 + CLIENTS as u64);
    assert_eq!(summary.jobs, (POOL + CLIENTS * JOBS) as u64);
    assert_eq!(summary.errors, 0);
    assert_eq!(summary.rejected_busy, 0);
    assert_eq!(summary.dropped_jobs, 0, "every accepted job answered");
    assert_eq!(summary.stats.submitted, (POOL + CLIENTS * JOBS) as u64);
    assert_eq!(summary.stats.cache_hits, (CLIENTS * JOBS) as u64);
}

/// Global admission control: with the cap at 2 and a deliberately
/// heavy job holding a worker, excess jobs are rejected immediately
/// with a `busy` error reply — in order, never silently dropped.
#[test]
fn admission_control_rejects_past_the_global_cap() {
    let cfg = ServerConfig {
        workers: 1,
        max_inflight: 2,
        conn_inflight: 16,
        ..ServerConfig::default()
    };
    let (path, handle, join) = start(cfg, "busy");
    // One heavy job (lookahead on a 12x12) to pin the single worker,
    // then a burst of trivial jobs faster than it can possibly finish.
    let mut input = format!(
        "{{\"id\": \"heavy\", \"matrix\": {}, \"bits\": 8, \"strategy\": \"lookahead\", \
         \"dc\": 3}}\n",
        matrix_json(99, 12, 12)
    );
    for j in 0..6 {
        input.push_str(&job_line(&format!("t{j}"), 1, 2));
    }
    let lines = round_trip(&path, &input);
    let vals = parsed(&lines);
    assert_eq!(vals.len(), 8, "7 replies + final stats: {lines:?}");
    assert_eq!(type_of(&vals[0]), "result");
    assert_eq!(vals[0].get("id").unwrap().as_str().unwrap(), "heavy");
    let mut results = 0u64;
    let mut busy = 0u64;
    for (j, v) in vals[1..7].iter().enumerate() {
        assert_eq!(v.get("id").unwrap().as_str().unwrap(), format!("t{j}"));
        match type_of(v) {
            "result" => results += 1,
            "error" => {
                busy += 1;
                assert!(
                    v.get("error").unwrap().as_str().unwrap().contains("busy"),
                    "unexpected error: {v:?}"
                );
            }
            other => panic!("unexpected reply type {other}"),
        }
    }
    assert_eq!(results + busy, 6);
    assert!(busy >= 1, "the burst must overrun a cap of 2 behind a pinned worker");
    let stats = &vals[7];
    assert!(stats.get("final").unwrap().as_bool().unwrap());
    assert_eq!(stats.get("client_rejected_busy").unwrap().as_i64().unwrap(), busy as i64);
    handle.shutdown();
    let summary = join.join().expect("server thread");
    assert_eq!(summary.rejected_busy, busy);
    assert_eq!(summary.dropped_jobs, 0);
}

/// A `shutdown` control line from one client drains the whole server:
/// the sender gets a draining-stats acknowledgement, every connection
/// gets its final stats line, and the server run returns.
#[test]
fn shutdown_control_line_drains_all_connections() {
    let (path, _handle, join) = start(ServerConfig::default(), "ctl");
    // An idle second client: it must be released by the drain too.
    let idle = UnixStream::connect(&path).expect("idle connect");

    let mut tx = UnixStream::connect(&path).expect("connect");
    let rx = tx.try_clone().expect("clone");
    let mut replies = BufReader::new(rx);
    tx.write_all(job_line("one", 5, 4).as_bytes()).expect("send job");
    let mut line = String::new();
    replies.read_line(&mut line).expect("result line");
    let v = json::parse(&line).unwrap();
    assert_eq!(type_of(&v), "result");
    assert_eq!(v.get("id").unwrap().as_str().unwrap(), "one");

    tx.write_all(b"{\"type\": \"shutdown\"}\n").expect("send shutdown");
    line.clear();
    replies.read_line(&mut line).expect("drain ack");
    let ack = json::parse(&line).unwrap();
    assert_eq!(type_of(&ack), "stats");
    assert!(ack.get("draining").unwrap().as_bool().unwrap());

    // Everything after the ack until EOF is stats-typed (the final
    // stats line; the exact count is transport bookkeeping).
    let rest: Vec<String> = replies.lines().map(|l| l.unwrap()).collect();
    assert!(!rest.is_empty(), "final stats line expected");
    for l in &rest {
        let v = json::parse(l).unwrap();
        assert_eq!(type_of(&v), "stats");
    }

    // The idle client is released with its own final stats line.
    let idle_lines: Vec<String> =
        BufReader::new(idle).lines().map(|l| l.unwrap()).collect();
    assert_eq!(idle_lines.len(), 1, "idle client: final stats then EOF");
    let v = json::parse(&idle_lines[0]).unwrap();
    assert_eq!(type_of(&v), "stats");
    assert!(v.get("final").unwrap().as_bool().unwrap());
    assert_eq!(v.get("client_jobs").unwrap().as_i64().unwrap(), 0);

    let summary = join.join().expect("server thread");
    assert_eq!(summary.clients, 2);
    assert_eq!(summary.jobs, 1);
    assert_eq!(summary.dropped_jobs, 0);
}

/// Drain under load: clients are mid-stream when the drain hits. Every
/// client's replies must be a duplicate-free prefix of its submission
/// order, each either a result or an explicit rejection — and the
/// server's own accounting must show zero dropped jobs.
#[test]
fn drain_under_load_answers_every_accepted_job_exactly_once() {
    let cfg = ServerConfig {
        workers: 2,
        max_inflight: 8,
        conn_inflight: 4,
        ..ServerConfig::default()
    };
    let (path, handle, join) = start(cfg, "drain");
    const CLIENTS: usize = 3;
    const JOBS: usize = 40;
    let clients: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let path = path.clone();
            thread::spawn(move || {
                let mut tx = UnixStream::connect(&path).expect("connect");
                let rx = tx.try_clone().expect("clone");
                let reader = thread::spawn(move || {
                    BufReader::new(rx)
                        .lines()
                        .map(|l| l.expect("reply line"))
                        .collect::<Vec<_>>()
                });
                let mut sent = Vec::new();
                for j in 0..JOBS {
                    let id = format!("c{c}-j{j}");
                    // Distinct 8x8 matrices: real work, so the queue
                    // and both backpressure bounds are actually live
                    // when the drain lands.
                    let line = job_line(&id, (1000 + c * JOBS + j) as u64, 8);
                    if tx.write_all(line.as_bytes()).is_err() {
                        break; // server shut our read half mid-drain
                    }
                    sent.push(id);
                }
                let _ = tx.shutdown(std::net::Shutdown::Write);
                (sent, reader.join().expect("reader thread"))
            })
        })
        .collect();
    thread::sleep(Duration::from_millis(60));
    handle.shutdown();

    let mut answered_total = 0u64;
    for client in clients {
        let (sent, lines) = client.join().expect("client thread");
        let vals = parsed(&lines);
        let (replies, trailers): (Vec<_>, Vec<_>) =
            vals.iter().partition(|v| type_of(v) != "stats");
        for t in &trailers {
            assert!(t.get("final").is_ok() || t.get("draining").is_ok());
        }
        // Exactly-once, in order: the replied ids are a prefix of the
        // submission order — no gap, no duplicate, no reordering.
        let ids: Vec<String> = replies
            .iter()
            .map(|v| v.get("id").unwrap().as_str().unwrap().to_string())
            .collect();
        assert!(ids.len() <= sent.len());
        assert_eq!(ids[..], sent[..ids.len()], "replies must prefix submission order");
        for v in &replies {
            match type_of(v) {
                "result" => {}
                "error" => {
                    let msg = v.get("error").unwrap().as_str().unwrap();
                    assert!(
                        msg.contains("shutting_down") || msg.contains("busy"),
                        "drain-phase errors must be explicit rejections: {msg}"
                    );
                }
                other => panic!("unexpected reply type {other}"),
            }
        }
        answered_total += ids.len() as u64;
    }

    let summary = join.join().expect("server thread");
    assert_eq!(summary.clients, CLIENTS as u64);
    assert_eq!(summary.dropped_jobs, 0, "drain must answer every accepted job");
    assert_eq!(summary.replies, answered_total, "wire replies match server accounting");
    assert_eq!(summary.jobs + summary.rejected_busy + drain_rejections(&summary), answered_total);
}

/// Errors that are not busy rejections and not job failures are the
/// drain rejections (this workload has no malformed lines and no
/// failing jobs).
fn drain_rejections(summary: &ServerSummary) -> u64 {
    summary.errors - summary.rejected_busy
}

/// The per-job `"timing": true` opt-in: the reply gains exactly one
/// `"timing"` object (trace id + per-stage microseconds) and nothing
/// else — removing it recovers the untimed reply byte for byte, and an
/// explicit `"timing": false` is indistinguishable from absence.
#[test]
fn timing_opt_in_adds_only_the_timing_object() {
    // Bake the job once so every run replies from the same cached
    // solution, pinning `opt_ms` and with it the full reply bytes.
    let req = da4ml::serve::JobRequest::from_json(r#"{"id": "t", "matrix": [[3, 5], [-7, 9]]}"#)
        .expect("request");
    let job = req.to_compile_job("t".into(), -1).expect("job");
    let bake = Coordinator::new();
    bake.compile_cached(&job).expect("bake");
    let cache = bake.save_cache();
    let run = |tag: &str, line: &str| -> String {
        let coord = Coordinator::new();
        coord.load_cache(&cache).expect("load cache");
        let path = socket_path(tag);
        let server = Server::bind(coord, ServerConfig::default(), &path, None).expect("bind");
        let handle = server.handle();
        let join = thread::spawn(move || server.run().expect("server run"));
        let lines = round_trip(&path, line);
        handle.shutdown();
        join.join().expect("server thread");
        lines.into_iter().next().expect("job reply")
    };

    let req_on = "{\"id\": \"t\", \"matrix\": [[3, 5], [-7, 9]], \"timing\": true}\n";
    let plain = run("timing-off", "{\"id\": \"t\", \"matrix\": [[3, 5], [-7, 9]]}\n");
    let timed = run("timing-on", req_on);

    let v = json::parse(&timed).expect("timed reply is JSON");
    let t = v.get("timing").expect("opted-in reply carries a timing object");
    assert_eq!(t.get("trace_id").unwrap().as_str().unwrap(), "client-0#0");
    for key in ["decode_us", "queue_wait_us", "exec_us", "write_wait_us"] {
        assert!(t.get(key).unwrap().as_i64().is_ok(), "missing stage time {key}: {timed}");
    }

    // Strictly additive: dropping the timing object recovers the
    // untimed reply bytes (both renderings sort keys).
    let mut stripped = json::parse(&timed).unwrap();
    if let Value::Object(o) = &mut stripped {
        o.remove("timing");
    }
    assert_eq!(json::to_string(&stripped), plain, "timing must be strictly additive");

    // `"timing": false` must decode — and reply — like an absent field.
    let req_off = "{\"id\": \"t\", \"matrix\": [[3, 5], [-7, 9]], \"timing\": false}\n";
    assert_eq!(run("timing-false", req_off), plain);
}

/// The observability control lines on the socket wire: `metrics`
/// answers with the schema-versioned snapshot, `stats` with
/// `"scope": "connection"` answers with the posting connection's own
/// counters — both sequenced into the reply stream like any other line.
#[test]
fn metrics_and_connection_scope_stats_control_lines() {
    // One worker: the identical second job is deterministically a
    // cache hit (no same-matrix compile race).
    let cfg = ServerConfig { workers: 1, ..ServerConfig::default() };
    let (path, handle, join) = start(cfg, "obsctl");

    // Interactive exchange: read both job replies before posting the
    // control lines — stats-line contents are rendered when the line
    // is *read*, so the counters are only deterministic once the job
    // replies have reached the client.
    let mut tx = UnixStream::connect(&path).expect("connect");
    let rx = tx.try_clone().expect("clone");
    let mut rx = BufReader::new(rx);
    let read_line = |rx: &mut BufReader<UnixStream>| -> String {
        let mut line = String::new();
        rx.read_line(&mut line).expect("reply line");
        line.trim_end().to_string()
    };
    tx.write_all(job_line("m-a", 3, 4).as_bytes()).expect("send");
    // Same matrix: a deterministic cache hit behind the single worker.
    tx.write_all(job_line("m-b", 3, 4).as_bytes()).expect("send");
    let mut lines = vec![read_line(&mut rx), read_line(&mut rx)];
    tx.write_all(b"{\"type\": \"stats\", \"scope\": \"connection\"}\n").expect("send");
    tx.write_all(b"{\"type\": \"metrics\", \"id\": \"snap\"}\n").expect("send");
    tx.shutdown(std::net::Shutdown::Write).expect("half-close");
    for l in rx.lines() {
        lines.push(l.expect("reply line"));
    }
    let vals = parsed(&lines);
    assert_eq!(vals.len(), 5, "2 results + conn stats + metrics + final stats: {lines:?}");
    assert_eq!(type_of(&vals[0]), "result");
    assert_eq!(type_of(&vals[1]), "result");
    assert!(vals[1].get("cached").unwrap().as_bool().unwrap());

    // Per-connection stats: this connection's counters only, no
    // server-wide fields.
    let conn = &vals[2];
    assert_eq!(type_of(conn), "stats");
    assert_eq!(conn.get("scope").unwrap().as_str().unwrap(), "connection");
    assert_eq!(conn.get("jobs").unwrap().as_i64().unwrap(), 2);
    assert_eq!(conn.get("cache_hits").unwrap().as_i64().unwrap(), 1);
    assert_eq!(conn.get("errors").unwrap().as_i64().unwrap(), 0);
    assert!(conn.get("submitted").is_err(), "server-wide field on a connection line");

    // Metrics snapshot: schema-versioned, correlated by id, carrying
    // the registry maps.
    let metrics = &vals[3];
    assert_eq!(type_of(metrics), "metrics");
    assert_eq!(metrics.get("id").unwrap().as_str().unwrap(), "snap");
    assert_eq!(metrics.get("kind").unwrap().as_str().unwrap(), "obs_metrics");
    assert!(metrics.get("schema_version").unwrap().as_i64().unwrap() >= 1);
    assert!(metrics.get("counters").unwrap().as_object().is_ok());
    assert!(metrics.get("gauges").unwrap().as_object().is_ok());
    assert!(metrics.get("histograms").unwrap().as_object().is_ok());

    // The final stats line carries the latency digest fields (zeros
    // while tracing is off — the shape is the contract).
    let fin = &vals[4];
    assert_eq!(type_of(fin), "stats");
    assert!(fin.get("final").unwrap().as_bool().unwrap());
    assert!(fin.get("queue_wait_us_p50").unwrap().as_i64().is_ok());
    assert!(fin.get("queue_wait_us_p99").unwrap().as_i64().is_ok());
    assert!(fin.get("exec_us_p50").unwrap().as_i64().is_ok());
    assert!(fin.get("exec_us_p99").unwrap().as_i64().is_ok());

    handle.shutdown();
    let summary = join.join().expect("server thread");
    assert_eq!(summary.jobs, 2, "control lines are not jobs");
    assert_eq!(summary.errors, 0);
}
