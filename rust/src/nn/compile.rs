//! Lowering a [`NetworkSpec`] to DAIS.
//!
//! The fully-unrolled path ([`compile`]) builds one DAIS program for
//! the whole network: every CMVM is optimized once as a *template* (by
//! the selected strategy, with the per-layer delay constraint) and then
//! inlined per spatial instance — exactly the replication a fully
//! unrolled II=1 design performs. With an objective in
//! [`CompileOptions`], the strategy × dc × pipeline space is explored
//! first and the objective's Pareto pick is compiled. The HLS-flow path
//! ([`layer_reports`]) keeps convolutional layers time-multiplexed
//! (one CMVM instance, as the paper's SVHN network) and reports
//! per-layer resources for both the DA and the latency strategies.

use super::spec::{LayerSpec, NetworkSpec};
use crate::baseline::mac::{mac_report, DspPolicy};
use crate::cmvm::{self, CmvmProblem, OptimizeOptions, Strategy};
use crate::coordinator::CompileJob;
use crate::cse::{CseStats, InputTerm};
use crate::dais::{DaisBuilder, DaisOp, DaisProgram, NodeId, RoundMode};
use crate::estimate::{self, FpgaModel, ResourceReport};
use crate::explore::{DesignPoint, ExploreConfig, Objective};
use crate::fixed::QInterval;
use crate::pipeline::{self, PipelineConfig};
use crate::Result;
use anyhow::{anyhow, bail};
use crate::util::fxhash::FxHashMap;

/// Node-level network state (mirrors [`super::sim::State`]).
#[derive(Debug, Clone)]
enum NodeState {
    Flat(Vec<NodeId>),
    Grid { nodes: Vec<NodeId>, p: usize, f: usize },
}

impl NodeState {
    fn flatten(self) -> Vec<NodeId> {
        match self {
            NodeState::Flat(v) => v,
            NodeState::Grid { nodes, .. } => nodes,
        }
    }
}

/// Inline a template program into `builder`, substituting its inputs
/// with `input_nodes`. Returns (node, shift) per template output.
pub fn inline(
    builder: &mut DaisBuilder,
    template: &DaisProgram,
    input_nodes: &[NodeId],
) -> Vec<(NodeId, i32)> {
    let mut map: Vec<NodeId> = Vec::with_capacity(template.nodes.len());
    for node in &template.nodes {
        let id = match node.op {
            DaisOp::Input { index } => input_nodes[index as usize],
            DaisOp::Const { value } => builder.constant(value),
            DaisOp::AddShift { a, b, shift_a, shift_b, sub } => builder.add_shift2(
                map[a as usize],
                shift_a,
                map[b as usize],
                shift_b,
                sub,
            ),
            DaisOp::Neg { a } => builder.neg(map[a as usize]),
            DaisOp::Relu { a } => builder.relu(map[a as usize]),
            DaisOp::Quant { a, shift, round, clip_min, clip_max } => {
                builder.quant(map[a as usize], shift, round, clip_min, clip_max)
            }
        };
        map.push(id);
    }
    template
        .outputs
        .iter()
        .map(|o| (map[o.node as usize], o.shift))
        .collect()
}

/// Emit bias-add + ReLU + requantization for one CMVM output term.
#[allow(clippy::too_many_arguments)]
fn epilogue(
    builder: &mut DaisBuilder,
    node: Option<NodeId>,
    out_shift: i32,
    neg: bool,
    bias: i64,
    relu: bool,
    shift: i32,
    clip_min: i64,
    clip_max: i64,
) -> NodeId {
    let mut n = match node {
        Some(n) => n,
        None => builder.constant(0),
    };
    if neg {
        n = builder.neg(n);
    }
    let eff_shift = if bias != 0 {
        let b = builder.constant(bias);
        n = builder.add_shift2(n, out_shift.max(0) as u32, b, 0, false);
        shift
    } else {
        shift - out_shift
    };
    if relu {
        n = builder.relu(n);
    }
    builder.quant(n, eff_shift, RoundMode::Floor, clip_min, clip_max)
}

/// Solve a layer's CMVM template with the given strategy.
fn template_for(
    w: &[Vec<i64>],
    in_qint: QInterval,
    strategy: Strategy,
) -> Result<(CmvmProblem, DaisProgram, CseStats)> {
    let d_in = w.len();
    let d_out = w.first().map(|r| r.len()).unwrap_or(0);
    let matrix: Vec<i64> = w.iter().flat_map(|r| r.iter().copied()).collect();
    let mut problem = CmvmProblem::new(d_in, d_out, matrix, 8)?;
    problem.input_qint = vec![in_qint; d_in];
    let sol = cmvm::compile(&problem, &OptimizeOptions::new(strategy))?;
    Ok((problem, sol.program, sol.cse))
}

/// Options for [`compile`] (this module's single entry point).
#[derive(Debug, Clone, Copy)]
pub struct CompileOptions<'a> {
    /// CMVM strategy for every layer template. Ignored when
    /// `objective` is set — exploration picks the strategy then.
    pub strategy: Strategy,
    /// When set, explore the strategy × dc × pipeline space first and
    /// compile the configuration this objective picks from the Pareto
    /// front (the old `fuse_auto` behavior).
    pub objective: Option<(Objective, &'a ExploreConfig)>,
}

impl CompileOptions<'_> {
    /// Compile with a fixed strategy, no design-space exploration.
    pub fn new(strategy: Strategy) -> Self {
        Self { strategy, objective: None }
    }
}

impl<'a> CompileOptions<'a> {
    /// Explore first and compile the objective's Pareto pick.
    pub fn with_objective(self, objective: Objective, cfg: &'a ExploreConfig) -> Self {
        Self { objective: Some((objective, cfg)), ..self }
    }
}

/// A fused network program plus everything the compile learned.
#[derive(Debug, Clone)]
pub struct CompiledNetwork {
    /// The fully-unrolled DAIS program (II = 1).
    pub program: DaisProgram,
    /// CSE engine work counters accumulated over every layer template
    /// the strategy optimized (one engine run per dense layer, one per
    /// einsum template — not per spatial instance).
    pub cse: CseStats,
    /// The design point exploration picked (objective compiles only).
    pub point: Option<DesignPoint>,
    /// Pipeline stage assignment for the picked point (`None` =
    /// combinational, or a fixed-strategy compile).
    pub stages: Option<Vec<u32>>,
}

/// Fuse a dense / einsum / residual network into one DAIS program
/// (fails on conv/pool layers — those use the HLS-flow path
/// [`layer_reports`]).
///
/// With [`CompileOptions::with_objective`], the strategy × dc ×
/// pipeline space is explored first ([`crate::explore`]) and the
/// objective's Pareto pick is compiled; the chosen point and its stage
/// assignment come back on [`CompiledNetwork`]. The MAC-modeled latency
/// baseline can win an objective; its *functional* program is the
/// naive-DA fuse (the resource numbers on the returned point still
/// come from [`crate::baseline::mac`]).
pub fn compile(spec: &NetworkSpec, opts: &CompileOptions) -> Result<CompiledNetwork> {
    match opts.objective {
        None => {
            let (program, cse) = fuse_inner(spec, opts.strategy)?;
            Ok(CompiledNetwork { program, cse, point: None, stages: None })
        }
        Some((objective, cfg)) => {
            let report = crate::explore::explore_network(spec, cfg)?;
            let point = crate::explore::pick(&report.front, objective)
                .ok_or_else(|| anyhow!("explore: empty Pareto front for '{}'", spec.name))?
                .clone();
            let strategy = match point.strategy {
                Strategy::Latency => Strategy::NaiveDa,
                s => s,
            };
            let (program, cse) = fuse_inner(spec, strategy)?;
            let stages = point.pipe.map(|n| {
                pipeline::assign_stages(&program, &PipelineConfig::every_n_adders(n))
            });
            Ok(CompiledNetwork { program, cse, point: Some(point), stages })
        }
    }
}

/// Old fixed-strategy entry point.
#[deprecated(note = "use nn::compile::compile with CompileOptions")]
pub fn fuse(spec: &NetworkSpec, strategy: Strategy) -> Result<DaisProgram> {
    fuse_inner(spec, strategy).map(|(prog, _)| prog)
}

/// Old fixed-strategy entry point with engine counters.
#[deprecated(note = "use nn::compile::compile with CompileOptions")]
pub fn fuse_with_stats(spec: &NetworkSpec, strategy: Strategy) -> Result<(DaisProgram, CseStats)> {
    fuse_inner(spec, strategy)
}

fn fuse_inner(spec: &NetworkSpec, strategy: Strategy) -> Result<(DaisProgram, CseStats)> {
    let mut cse_stats = CseStats::default();
    let mut b = DaisBuilder::new();
    let in_q = spec.input_qint();
    let n_in = spec.input_len();
    let nodes: Vec<NodeId> = (0..n_in).map(|j| b.input(j, in_q, 0)).collect();
    let mut state = match spec.input_shape.len() {
        1 => NodeState::Flat(nodes),
        2 => NodeState::Grid { nodes, p: spec.input_shape[0], f: spec.input_shape[1] },
        r => bail!("fuse: unsupported input rank {r}"),
    };
    let mut qint = in_q;
    let mut saved: FxHashMap<String, NodeState> = FxHashMap::default();

    for (li, layer) in spec.layers.iter().enumerate() {
        let mut layer_span = crate::obs::span("nn", "nn.layer");
        layer_span.arg("index", li as i64);
        layer_span.arg_str("kind", || layer_kind(layer).to_string());
        state = match layer {
            LayerSpec::Dense { w, b: bias, relu, shift, clip_min, clip_max } => {
                let x = state.flatten();
                let d_in = w.len();
                anyhow::ensure!(x.len() == d_in, "layer {li}: dense arity");
                let matrix: Vec<i64> = w.iter().flat_map(|r| r.iter().copied()).collect();
                let d_out = bias.len();
                let mut problem = CmvmProblem::new(d_in, d_out, matrix, 8)?;
                problem.input_qint = vec![qint; d_in];
                let inputs: Vec<InputTerm> =
                    x.iter().map(|&node| InputTerm { node }).collect();
                let opts = OptimizeOptions::new(strategy);
                let (outs, st) = cmvm::compile_terms(&mut b, &inputs, &problem, &opts)?;
                cse_stats.absorb(&st);
                let ys: Vec<NodeId> = outs
                    .iter()
                    .enumerate()
                    .map(|(i, o)| {
                        epilogue(
                            &mut b, o.node, o.shift, o.neg, bias[i], *relu, *shift,
                            *clip_min, *clip_max,
                        )
                    })
                    .collect();
                qint = QInterval::new(*clip_min, *clip_max, 0);
                NodeState::Flat(ys)
            }
            LayerSpec::EinsumDense { w, b: bias, axis, relu, shift, clip_min, clip_max } => {
                let NodeState::Grid { nodes, p, f } = state else {
                    bail!("layer {li}: einsum_dense needs grid state")
                };
                let (_, template, st) = template_for(w, qint, strategy)?;
                cse_stats.absorb(&st);
                let d_out = bias.len();
                let apply = |b: &mut DaisBuilder, xs: &[NodeId]| -> Vec<NodeId> {
                    inline(b, &template, xs)
                        .into_iter()
                        .enumerate()
                        .map(|(i, (node, os))| {
                            epilogue(
                                b, Some(node), os, false, bias[i], *relu, *shift,
                                *clip_min, *clip_max,
                            )
                        })
                        .collect()
                };
                let out = match axis.as_str() {
                    "feature" => {
                        let mut out = Vec::with_capacity(p * d_out);
                        for row in 0..p {
                            let xs = &nodes[row * f..(row + 1) * f];
                            out.extend(apply(&mut b, xs));
                        }
                        NodeState::Grid { nodes: out, p, f: d_out }
                    }
                    "particle" => {
                        let mut out = vec![0 as NodeId; d_out * f];
                        for col in 0..f {
                            let xs: Vec<NodeId> =
                                (0..p).map(|r| nodes[r * f + col]).collect();
                            for (r, n) in apply(&mut b, &xs).into_iter().enumerate() {
                                out[r * f + col] = n;
                            }
                        }
                        NodeState::Grid { nodes: out, p: d_out, f }
                    }
                    other => bail!("layer {li}: unknown einsum axis {other}"),
                };
                qint = QInterval::new(*clip_min, *clip_max, 0);
                out
            }
            LayerSpec::Flatten => NodeState::Flat(state.flatten()),
            LayerSpec::Save { tag } => {
                saved.insert(tag.clone(), state.clone());
                state
            }
            LayerSpec::AddSaved { tag } => {
                let other = saved
                    .get(tag)
                    .ok_or_else(|| anyhow!("layer {li}: no saved state '{tag}'"))?
                    .clone();
                let shape = match &other {
                    NodeState::Grid { p, f, .. } => Some((*p, *f)),
                    NodeState::Flat(_) => None,
                };
                let a = state.flatten();
                let o = other.flatten();
                anyhow::ensure!(a.len() == o.len(), "layer {li}: residual shape mismatch");
                let sum: Vec<NodeId> = a
                    .iter()
                    .zip(&o)
                    .map(|(&x, &y)| b.add_shift(x, y, 0, false))
                    .collect();
                // Residual sum widens the range by one bit.
                qint = qint.add(&qint);
                match shape {
                    Some((p, f)) => NodeState::Grid { nodes: sum, p, f },
                    None => NodeState::Flat(sum),
                }
            }
            LayerSpec::Conv2D { .. } | LayerSpec::MaxPool2D | LayerSpec::AvgPool2D => {
                bail!("layer {li}: conv/pool layers use the HLS-flow path (layer_reports)")
            }
        };
    }

    for n in state.flatten() {
        b.output(n, 0);
    }
    Ok((b.finish(), cse_stats))
}

/// Short layer-kind label attached to the per-layer trace span.
fn layer_kind(layer: &LayerSpec) -> &'static str {
    match layer {
        LayerSpec::Dense { .. } => "dense",
        LayerSpec::EinsumDense { .. } => "einsum_dense",
        LayerSpec::Flatten => "flatten",
        LayerSpec::Save { .. } => "save",
        LayerSpec::AddSaved { .. } => "add_saved",
        LayerSpec::Conv2D { .. } => "conv2d",
        LayerSpec::MaxPool2D => "max_pool2d",
        LayerSpec::AvgPool2D => "avg_pool2d",
    }
}

/// Per-layer resource accounting for one strategy.
#[derive(Debug, Clone)]
pub struct LayerReport {
    /// Layer label.
    pub name: String,
    /// Number of hardware instances of the CMVM (1 for time-multiplexed
    /// convolutions, the spatial count for unrolled einsum layers).
    pub instances: u64,
    /// Resources of one instance.
    pub per_instance: ResourceReport,
    /// Resources times instances.
    pub total: ResourceReport,
    /// Adders of one instance (DA metric) for the table's adder column.
    pub adders: u64,
}

/// Strategy-aware per-layer reports for any network (the HLS-flow path).
/// Convolutions count one instance (temporal reuse, as the paper's SVHN
/// design); einsum layers count their spatial replication.
pub fn layer_reports(
    spec: &NetworkSpec,
    strategy: Strategy,
    model: &FpgaModel,
    pipe: &PipelineConfig,
) -> Result<Vec<LayerReport>> {
    let mut qint = spec.input_qint();
    let mut reports = Vec::new();
    for (li, layer) in spec.layers.iter().enumerate() {
        match layer {
            LayerSpec::Dense { w, b, relu, shift, clip_min, clip_max }
            | LayerSpec::Conv2D { w, b, relu, shift, clip_min, clip_max, .. }
            | LayerSpec::EinsumDense { w, b, relu, shift, clip_min, clip_max, .. } => {
                let d_in = w.len();
                let d_out = b.len();
                let matrix: Vec<i64> = w.iter().flat_map(|r| r.iter().copied()).collect();
                let mut problem = CmvmProblem::new(d_in, d_out, matrix, 8)?;
                problem.input_qint = vec![qint; d_in];

                let per_instance = match strategy {
                    Strategy::Latency => {
                        mac_report(&problem, model, &DspPolicy::default())
                    }
                    s => {
                        // Full per-layer program incl. epilogue.
                        let mut bb = DaisBuilder::new();
                        let inputs: Vec<InputTerm> = (0..d_in)
                            .map(|j| InputTerm { node: bb.input(j, qint, 0) })
                            .collect();
                        let opts = OptimizeOptions::new(s);
                        let (outs, _) = cmvm::compile_terms(&mut bb, &inputs, &problem, &opts)?;
                        for (i, o) in outs.iter().enumerate() {
                            let n = epilogue(
                                &mut bb, o.node, o.shift, o.neg, b[i], *relu, *shift,
                                *clip_min, *clip_max,
                            );
                            bb.output(n, 0);
                        }
                        let prog = bb.finish();
                        let stages = pipeline::assign_stages(&prog, pipe);
                        estimate::pipelined(&prog, &stages, model)
                    }
                };
                let instances: u64 = match layer {
                    LayerSpec::EinsumDense { axis, .. } => {
                        // Spatial replication count is resolved by the
                        // caller's input shape bookkeeping below.
                        let (p, f) = grid_shape(spec, li)?;
                        if axis == "feature" {
                            p as u64
                        } else {
                            f as u64
                        }
                    }
                    _ => 1,
                };
                let mut total = per_instance;
                total.lut *= instances;
                total.dsp *= instances;
                total.ff *= instances;
                total.adders *= instances;
                reports.push(LayerReport {
                    name: format!("layer{li}"),
                    instances,
                    per_instance,
                    total,
                    adders: per_instance.adders,
                });
                qint = QInterval::new(*clip_min, *clip_max, 0);
            }
            LayerSpec::MaxPool2D | LayerSpec::AvgPool2D | LayerSpec::Flatten
            | LayerSpec::Save { .. } => {}
            LayerSpec::AddSaved { .. } => {
                qint = qint.add(&qint);
            }
        }
    }
    Ok(reports)
}

/// Extract each weight matrix of a network as a standalone CMVM
/// problem, threading the running activation interval exactly like
/// [`layer_reports`] does. Shared by the perf lab's engine A/B case and
/// the solution-cache bake flow ([`layer_jobs`]).
pub fn layer_problems(spec: &NetworkSpec) -> Result<Vec<CmvmProblem>> {
    let mut qint = spec.input_qint();
    let mut out = Vec::new();
    for (li, layer) in spec.layers.iter().enumerate() {
        match layer {
            LayerSpec::Dense { w, b, clip_min, clip_max, .. }
            | LayerSpec::Conv2D { w, b, clip_min, clip_max, .. }
            | LayerSpec::EinsumDense { w, b, clip_min, clip_max, .. } => {
                let d_in = w.len();
                let d_out = b.len();
                let matrix: Vec<i64> = w.iter().flat_map(|r| r.iter().copied()).collect();
                let mut p = CmvmProblem::new(d_in, d_out, matrix, 8)?;
                p.input_qint = vec![qint; d_in];
                out.push(p);
                anyhow::ensure!(
                    clip_min <= clip_max,
                    "layer {li}: clip range [{clip_min}, {clip_max}] is empty"
                );
                qint = QInterval::new(*clip_min, *clip_max, 0);
            }
            LayerSpec::AddSaved { .. } => qint = qint.add(&qint),
            _ => {}
        }
    }
    Ok(out)
}

/// Every weight layer of a network as a coordinator [`CompileJob`]
/// (named `"{spec.name}/L{i}"`), all under one strategy — the `da4ml
/// cache bake` surface: compile these through a [`Coordinator`]
/// (`crate::coordinator::Coordinator`) and persist its solution cache.
pub fn layer_jobs(spec: &NetworkSpec, strategy: Strategy) -> Result<Vec<CompileJob>> {
    Ok(layer_problems(spec)?
        .into_iter()
        .enumerate()
        .map(|(i, problem)| CompileJob {
            name: format!("{}/L{i}", spec.name),
            problem,
            strategy,
        })
        .collect())
}

/// Grid shape seen by layer `li` (replaying shape transforms).
fn grid_shape(spec: &NetworkSpec, li: usize) -> Result<(usize, usize)> {
    anyhow::ensure!(spec.input_shape.len() == 2, "grid_shape on non-grid network");
    let (mut p, mut f) = (spec.input_shape[0], spec.input_shape[1]);
    for layer in &spec.layers[..li] {
        if let LayerSpec::EinsumDense { b, axis, .. } = layer {
            if axis == "feature" {
                f = b.len();
            } else {
                p = b.len();
            }
        }
    }
    Ok((p, f))
}

/// One-call network-level report for the benches: resources + timing of
/// a whole network under a strategy and pipelining config.
///
/// * DA-family strategies on fusible networks (dense/einsum/residual)
///   use the fully-unrolled fused program (II = 1);
/// * the latency strategy takes LUT/DSP from the analytic MAC model and
///   pipeline stats from the naive-DA fused program (its functional
///   twin), matching how the paper's tables pair the two columns;
/// * conv networks always use the per-layer (HLS-flow) path.
pub fn network_report(
    spec: &NetworkSpec,
    strategy: Strategy,
    model: &FpgaModel,
    pipe: &PipelineConfig,
) -> Result<ResourceReport> {
    let fusible = !spec.layers.iter().any(|l| {
        matches!(
            l,
            LayerSpec::Conv2D { .. } | LayerSpec::MaxPool2D | LayerSpec::AvgPool2D
        )
    });
    if !fusible {
        let reports = layer_reports(spec, strategy, model, pipe)?;
        return Ok(aggregate(&reports));
    }
    match strategy {
        Strategy::Latency => {
            let reports = layer_reports(spec, Strategy::Latency, model, pipe)?;
            let mut agg = aggregate(&reports);
            // Timing/FF structure from the functionally identical
            // naive-DA unrolled graph (deeper than the DA graph, hence
            // the extra pipeline stages the paper's latency rows show).
            let (prog, _) = fuse_inner(spec, Strategy::NaiveDa)?;
            let stages = pipeline::assign_stages(&prog, pipe);
            let rep = estimate::pipelined(&prog, &stages, model);
            // The HLS schedule pipelines the (DSP/LUT) multiplier stage
            // ahead of the accumulation tree — the extra stages the
            // paper's latency rows consistently show over the DA rows.
            let mult_stages = 2;
            agg.latency_cycles = rep.latency_cycles + mult_stages;
            agg.latency_ns = rep.latency_ns * (1.0 + mult_stages as f64
                / rep.latency_cycles.max(1) as f64);
            agg.fmax_mhz = rep.fmax_mhz * 0.95;
            agg.ff = rep.ff;
            agg.depth = rep.depth;
            Ok(agg)
        }
        s => {
            let (prog, _) = fuse_inner(spec, s)?;
            let stages = pipeline::assign_stages(&prog, pipe);
            Ok(estimate::pipelined(&prog, &stages, model))
        }
    }
}

/// Old explore-then-compile entry point.
#[deprecated(note = "use nn::compile::compile with CompileOptions::with_objective")]
pub fn fuse_auto(
    spec: &NetworkSpec,
    objective: Objective,
    cfg: &ExploreConfig,
) -> Result<(DesignPoint, DaisProgram, Option<Vec<u32>>)> {
    let opts = CompileOptions::new(Strategy::NaiveDa).with_objective(objective, cfg);
    let c = compile(spec, &opts)?;
    let point = c.point.expect("objective compiles always carry a point");
    Ok((point, c.program, c.stages))
}

/// Aggregate layer reports into one network-level report.
pub fn aggregate(reports: &[LayerReport]) -> ResourceReport {
    let mut total = ResourceReport::default();
    for r in reports {
        total.lut += r.total.lut;
        total.dsp += r.total.dsp;
        total.ff += r.total.ff;
        total.adders += r.total.adders;
        total.depth += r.per_instance.depth;
        total.latency_cycles += r.per_instance.latency_cycles;
        total.latency_ns += r.per_instance.latency_ns;
        total.fmax_mhz = if total.fmax_mhz == 0.0 {
            r.per_instance.fmax_mhz
        } else {
            total.fmax_mhz.min(r.per_instance.fmax_mhz)
        };
    }
    total
}
