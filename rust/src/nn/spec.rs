//! JSON interchange types shared with the Python build layer.
//!
//! Conventions (identical on both sides — this is what makes the DAIS
//! simulation bit-exact to the PJRT golden model):
//!
//! * all tensors are integers (weights, biases, activations);
//! * dense: `z[i] = Σ_j x[j] * w[j][i] + b[i]` (w is `d_in × d_out`);
//! * requantization: `y = clip(z >> shift, clip_min, clip_max)` with
//!   **floor** rounding (arithmetic shift), applied after the optional
//!   ReLU;
//! * conv2d is `valid`-padded NHWC with kernel `kh·kw·cin × cout`
//!   (im2col patch order: (dy, dx, cin) row-major);
//! * pooling is 2×2 stride-2; `avg` divides by 4 with floor shift.

use crate::fixed::QInterval;
use crate::json::decode::Decoder;
use crate::json::{self, Value};
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Unwrap a streamed field slot with the classic missing-field error.
fn req<T>(v: Option<T>, field: &str) -> Result<T> {
    v.ok_or_else(|| anyhow!("missing field '{field}'"))
}

/// One layer of a quantized network.
#[derive(Debug, Clone)]
pub enum LayerSpec {
    /// Fully connected layer on the flattened state.
    Dense {
        /// Weights, `d_in` rows × `d_out` cols.
        w: Vec<Vec<i64>>,
        /// Bias per output (post-matmul, pre-shift).
        b: Vec<i64>,
        /// Apply ReLU before requantization.
        relu: bool,
        /// Right-shift of the requantizer.
        shift: i32,
        /// Clip bounds of the requantizer.
        clip_min: i64,
        /// Upper clip bound.
        clip_max: i64,
    },
    /// Dense applied along one axis of a 2D state `[particles][features]`
    /// (the paper's EinsumDense in the MLP-Mixer).
    EinsumDense {
        /// Weights (`d_in × d_out` along the chosen axis).
        w: Vec<Vec<i64>>,
        /// Bias per output element of the transformed axis.
        b: Vec<i64>,
        /// `"feature"` (axis 1) or `"particle"` (axis 0).
        axis: String,
        /// Apply ReLU before requantization.
        relu: bool,
        /// Right-shift of the requantizer.
        shift: i32,
        /// Clip bounds.
        clip_min: i64,
        /// Upper clip bound.
        clip_max: i64,
    },
    /// 2D convolution (NHWC, valid padding, stride 1).
    Conv2D {
        /// Kernel as im2col matrix: `(kh*kw*cin) × cout`.
        w: Vec<Vec<i64>>,
        /// Bias per output channel.
        b: Vec<i64>,
        /// Kernel height.
        kh: usize,
        /// Kernel width.
        kw: usize,
        /// Apply ReLU before requantization.
        relu: bool,
        /// Right-shift of the requantizer.
        shift: i32,
        /// Clip bounds.
        clip_min: i64,
        /// Upper clip bound.
        clip_max: i64,
    },
    /// 2×2 stride-2 max pooling.
    MaxPool2D,
    /// 2×2 stride-2 average pooling (floor >> 2).
    AvgPool2D,
    /// Flatten the spatial state into a vector (row-major HWC).
    Flatten,
    /// Save the current state under a tag (residual source).
    Save {
        /// Tag name.
        tag: String,
    },
    /// Element-wise add the saved state (residual connection; scales
    /// must already match — the exporter guarantees it).
    AddSaved {
        /// Tag to add.
        tag: String,
    },
}

/// A whole network.
#[derive(Debug, Clone)]
pub struct NetworkSpec {
    /// Model name (e.g. "jet_mlp").
    pub name: String,
    /// Input element bitwidth.
    pub input_bits: u32,
    /// Whether inputs are signed.
    pub input_signed: bool,
    /// Input shape: `[n]` for flat, `[h, w, c]` for images,
    /// `[particles, features]` for sets.
    pub input_shape: Vec<usize>,
    /// The layers, in order.
    pub layers: Vec<LayerSpec>,
}

impl NetworkSpec {
    /// Quantized interval of one input element.
    pub fn input_qint(&self) -> QInterval {
        if self.input_signed {
            QInterval::new(
                -(1i64 << (self.input_bits - 1)),
                (1i64 << (self.input_bits - 1)) - 1,
                0,
            )
        } else {
            QInterval::new(0, (1i64 << self.input_bits) - 1, 0)
        }
    }

    /// Total flat input size.
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Load from JSON text (tagged layer objects, see the Python
    /// exporter `python/compile/aot.py`).
    ///
    /// Streams the document through the pull parser
    /// ([`crate::json::decode::Decoder`]): weight matrices land
    /// directly in their `Vec<Vec<i64>>` storage without an
    /// intermediate [`Value`] tree (see the `ingestion_micro` bench for
    /// the allocation/time delta on the jet-tagging artifact).
    pub fn from_json(text: &str) -> Result<Self> {
        let mut d = Decoder::new(text);
        let spec = Self::decode(&mut d)?;
        d.end()?;
        Ok(spec)
    }

    /// Streaming decode of one network-spec object (field order
    /// independent; unknown fields are skipped). Consumes the object's
    /// `{` itself, so it composes at any value position — the serve
    /// wire uses this to decode inline `"spec"` objects on explore
    /// jobs.
    pub(crate) fn decode(d: &mut Decoder<'_>) -> Result<Self> {
        let mut name = None;
        let mut input_bits = None;
        let mut input_signed = None;
        let mut input_shape: Option<Vec<usize>> = None;
        let mut layers = None;
        d.object_start()?;
        while let Some(key) = d.next_key()? {
            match key.as_ref() {
                "name" => name = Some(d.string()?),
                "input_bits" => input_bits = Some(d.i64()? as u32),
                "input_signed" => input_signed = Some(d.bool()?),
                "input_shape" => {
                    input_shape = Some(d.i64_vec()?.into_iter().map(|x| x as usize).collect())
                }
                "layers" => layers = Some(Self::decode_layers(d)?),
                _ => d.skip_value()?,
            }
        }
        Ok(Self {
            name: req(name, "name")?,
            input_bits: req(input_bits, "input_bits")?,
            input_signed: req(input_signed, "input_signed")?,
            input_shape: req(input_shape, "input_shape")?,
            layers: req(layers, "layers")?,
        })
    }

    fn decode_layers(d: &mut Decoder<'_>) -> Result<Vec<LayerSpec>> {
        d.array_start()?;
        let mut out = Vec::new();
        while d.next_object_in_array()? {
            out.push(LayerSpec::decode_object(d)?);
        }
        Ok(out)
    }

    /// Decode from a parsed JSON value.
    pub fn from_value(v: &Value) -> Result<Self> {
        Ok(Self {
            name: v.get("name")?.as_str()?.to_string(),
            input_bits: v.get("input_bits")?.as_i64()? as u32,
            input_signed: v.get("input_signed")?.as_bool()?,
            input_shape: v
                .get("input_shape")?
                .to_i64_vec()?
                .into_iter()
                .map(|x| x as usize)
                .collect(),
            layers: v
                .get("layers")?
                .as_array()?
                .iter()
                .map(LayerSpec::from_value)
                .collect::<Result<_>>()?,
        })
    }

    /// Encode to JSON (for tests and spec fixtures).
    pub fn to_json(&self) -> String {
        let mut o = BTreeMap::new();
        o.insert("name".into(), Value::Str(self.name.clone()));
        o.insert("input_bits".into(), Value::Int(self.input_bits as i64));
        o.insert("input_signed".into(), Value::Bool(self.input_signed));
        o.insert(
            "input_shape".into(),
            Value::Array(self.input_shape.iter().map(|&x| Value::Int(x as i64)).collect()),
        );
        o.insert(
            "layers".into(),
            Value::Array(self.layers.iter().map(LayerSpec::to_value).collect()),
        );
        json::to_string(&Value::Object(o))
    }
}

fn mat_value(w: &[Vec<i64>]) -> Value {
    Value::Array(
        w.iter()
            .map(|r| Value::Array(r.iter().map(|&x| Value::Int(x)).collect()))
            .collect(),
    )
}

fn vec_value(b: &[i64]) -> Value {
    Value::Array(b.iter().map(|&x| Value::Int(x)).collect())
}

impl LayerSpec {
    /// Streaming decode of one tagged layer object whose `{` has
    /// already been consumed. Fields arrive in any order (the exporter
    /// sorts keys, so `"type"` is typically *last*): every known field
    /// is parked in a slot, then the tag dispatches at the closing `}`.
    ///
    /// Intentionally stricter than the DOM path ([`LayerSpec::from_value`]):
    /// a known field with the wrong JSON type is rejected even when the
    /// final tag would not read it — single-pass decoding cannot defer
    /// the type check, and exporter artifacts never carry such fields.
    fn decode_object(d: &mut Decoder<'_>) -> Result<Self> {
        let mut ty: Option<String> = None;
        let mut w: Option<Vec<Vec<i64>>> = None;
        let mut b: Option<Vec<i64>> = None;
        let mut relu: Option<bool> = None;
        let mut shift: Option<i32> = None;
        let mut clip_min: Option<i64> = None;
        let mut clip_max: Option<i64> = None;
        let mut axis: Option<String> = None;
        let mut kh: Option<usize> = None;
        let mut kw: Option<usize> = None;
        let mut k: Option<usize> = None;
        let mut tag: Option<String> = None;
        while let Some(key) = d.next_key()? {
            match key.as_ref() {
                "type" => ty = Some(d.string()?),
                "w" => w = Some(d.i64_mat()?),
                "b" => b = Some(d.i64_vec()?),
                "relu" => relu = Some(d.bool()?),
                "shift" => shift = Some(d.i64()? as i32),
                "clip_min" => clip_min = Some(d.i64()?),
                "clip_max" => clip_max = Some(d.i64()?),
                "axis" => axis = Some(d.string()?),
                "kh" => kh = Some(d.i64()? as usize),
                "kw" => kw = Some(d.i64()? as usize),
                "k" => k = Some(d.i64()? as usize),
                "tag" => tag = Some(d.string()?),
                _ => d.skip_value()?,
            }
        }
        let ty = req(ty, "type")?;
        Ok(match ty.as_str() {
            "dense" => LayerSpec::Dense {
                w: req(w, "w")?,
                b: req(b, "b")?,
                relu: req(relu, "relu")?,
                shift: req(shift, "shift")?,
                clip_min: req(clip_min, "clip_min")?,
                clip_max: req(clip_max, "clip_max")?,
            },
            "einsum_dense" => LayerSpec::EinsumDense {
                w: req(w, "w")?,
                b: req(b, "b")?,
                axis: req(axis, "axis")?,
                relu: req(relu, "relu")?,
                shift: req(shift, "shift")?,
                clip_min: req(clip_min, "clip_min")?,
                clip_max: req(clip_max, "clip_max")?,
            },
            "conv2d" => LayerSpec::Conv2D {
                w: req(w, "w")?,
                b: req(b, "b")?,
                kh: req(kh, "kh")?,
                kw: req(kw, "kw")?,
                relu: req(relu, "relu")?,
                shift: req(shift, "shift")?,
                clip_min: req(clip_min, "clip_min")?,
                clip_max: req(clip_max, "clip_max")?,
            },
            // Conv1D is Conv2D with a unit-height kernel on a [1, w, c]
            // image (the hls4ml Conv1D support of paper §5.1).
            "conv1d" => LayerSpec::Conv2D {
                w: req(w, "w")?,
                b: req(b, "b")?,
                kh: 1,
                kw: req(k, "k")?,
                relu: req(relu, "relu")?,
                shift: req(shift, "shift")?,
                clip_min: req(clip_min, "clip_min")?,
                clip_max: req(clip_max, "clip_max")?,
            },
            "max_pool2d" => LayerSpec::MaxPool2D,
            "avg_pool2d" => LayerSpec::AvgPool2D,
            "flatten" => LayerSpec::Flatten,
            "save" => LayerSpec::Save { tag: req(tag, "tag")? },
            "add_saved" => LayerSpec::AddSaved { tag: req(tag, "tag")? },
            other => bail!("unknown layer type '{other}'"),
        })
    }

    /// Decode one tagged layer object.
    pub fn from_value(v: &Value) -> Result<Self> {
        let ty = v.get("type")?.as_str()?;
        let quant = |v: &Value| -> Result<(bool, i32, i64, i64)> {
            Ok((
                v.get("relu")?.as_bool()?,
                v.get("shift")?.as_i64()? as i32,
                v.get("clip_min")?.as_i64()?,
                v.get("clip_max")?.as_i64()?,
            ))
        };
        Ok(match ty {
            "dense" => {
                let (relu, shift, clip_min, clip_max) = quant(v)?;
                LayerSpec::Dense {
                    w: v.get("w")?.to_i64_mat()?,
                    b: v.get("b")?.to_i64_vec()?,
                    relu,
                    shift,
                    clip_min,
                    clip_max,
                }
            }
            "einsum_dense" => {
                let (relu, shift, clip_min, clip_max) = quant(v)?;
                LayerSpec::EinsumDense {
                    w: v.get("w")?.to_i64_mat()?,
                    b: v.get("b")?.to_i64_vec()?,
                    axis: v.get("axis")?.as_str()?.to_string(),
                    relu,
                    shift,
                    clip_min,
                    clip_max,
                }
            }
            "conv2d" => {
                let (relu, shift, clip_min, clip_max) = quant(v)?;
                LayerSpec::Conv2D {
                    w: v.get("w")?.to_i64_mat()?,
                    b: v.get("b")?.to_i64_vec()?,
                    kh: v.get("kh")?.as_i64()? as usize,
                    kw: v.get("kw")?.as_i64()? as usize,
                    relu,
                    shift,
                    clip_min,
                    clip_max,
                }
            }
            // Conv1D is Conv2D with a unit-height kernel on a [1, w, c]
            // image (the hls4ml Conv1D support of paper §5.1).
            "conv1d" => {
                let (relu, shift, clip_min, clip_max) = quant(v)?;
                LayerSpec::Conv2D {
                    w: v.get("w")?.to_i64_mat()?,
                    b: v.get("b")?.to_i64_vec()?,
                    kh: 1,
                    kw: v.get("k")?.as_i64()? as usize,
                    relu,
                    shift,
                    clip_min,
                    clip_max,
                }
            }
            "max_pool2d" => LayerSpec::MaxPool2D,
            "avg_pool2d" => LayerSpec::AvgPool2D,
            "flatten" => LayerSpec::Flatten,
            "save" => LayerSpec::Save { tag: v.get("tag")?.as_str()?.to_string() },
            "add_saved" => LayerSpec::AddSaved { tag: v.get("tag")?.as_str()?.to_string() },
            other => bail!("unknown layer type '{other}'"),
        })
    }

    /// Encode to a tagged JSON object.
    pub fn to_value(&self) -> Value {
        let mut o = BTreeMap::new();
        let put_quant =
            |o: &mut BTreeMap<String, Value>, relu: bool, shift: i32, lo: i64, hi: i64| {
                o.insert("relu".into(), Value::Bool(relu));
                o.insert("shift".into(), Value::Int(shift as i64));
                o.insert("clip_min".into(), Value::Int(lo));
                o.insert("clip_max".into(), Value::Int(hi));
            };
        match self {
            LayerSpec::Dense { w, b, relu, shift, clip_min, clip_max } => {
                o.insert("type".into(), Value::Str("dense".into()));
                o.insert("w".into(), mat_value(w));
                o.insert("b".into(), vec_value(b));
                put_quant(&mut o, *relu, *shift, *clip_min, *clip_max);
            }
            LayerSpec::EinsumDense { w, b, axis, relu, shift, clip_min, clip_max } => {
                o.insert("type".into(), Value::Str("einsum_dense".into()));
                o.insert("w".into(), mat_value(w));
                o.insert("b".into(), vec_value(b));
                o.insert("axis".into(), Value::Str(axis.clone()));
                put_quant(&mut o, *relu, *shift, *clip_min, *clip_max);
            }
            LayerSpec::Conv2D { w, b, kh, kw, relu, shift, clip_min, clip_max } => {
                o.insert("type".into(), Value::Str("conv2d".into()));
                o.insert("w".into(), mat_value(w));
                o.insert("b".into(), vec_value(b));
                o.insert("kh".into(), Value::Int(*kh as i64));
                o.insert("kw".into(), Value::Int(*kw as i64));
                put_quant(&mut o, *relu, *shift, *clip_min, *clip_max);
            }
            LayerSpec::MaxPool2D => {
                o.insert("type".into(), Value::Str("max_pool2d".into()));
            }
            LayerSpec::AvgPool2D => {
                o.insert("type".into(), Value::Str("avg_pool2d".into()));
            }
            LayerSpec::Flatten => {
                o.insert("type".into(), Value::Str("flatten".into()));
            }
            LayerSpec::Save { tag } => {
                o.insert("type".into(), Value::Str("save".into()));
                o.insert("tag".into(), Value::Str(tag.clone()));
            }
            LayerSpec::AddSaved { tag } => {
                o.insert("type".into(), Value::Str("add_saved".into()));
                o.insert("tag".into(), Value::Str(tag.clone()));
            }
        }
        Value::Object(o)
    }
}

/// The (w, b) tensors of every compute layer in layer order — the
/// runtime-parameter convention of the HLO golden model (weights are
/// PJRT execute-time arguments, see python `compile.model.weight_args`).
pub fn weight_tensors(spec: &NetworkSpec) -> Vec<crate::runtime::TensorI32> {
    let mut out = Vec::new();
    for layer in &spec.layers {
        let (w, b) = match layer {
            LayerSpec::Dense { w, b, .. }
            | LayerSpec::EinsumDense { w, b, .. }
            | LayerSpec::Conv2D { w, b, .. } => (w, b),
            _ => continue,
        };
        let d_in = w.len() as i64;
        let d_out = b.len() as i64;
        let wdata: Vec<i32> = w.iter().flatten().map(|&v| v as i32).collect();
        out.push(crate::runtime::TensorI32::new(wdata, vec![d_in, d_out]));
        out.push(crate::runtime::TensorI32::new(
            b.iter().map(|&v| v as i32).collect(),
            vec![d_out],
        ));
    }
    out
}

/// Exported test vectors for golden cross-checking.
#[derive(Debug, Clone)]
pub struct TestVectors {
    /// Input vectors (flat, row-major).
    pub inputs: Vec<Vec<i64>>,
    /// Expected outputs from the JAX model (flat).
    pub outputs: Vec<Vec<i64>>,
    /// Class labels (for accuracy), if applicable.
    pub labels: Vec<u32>,
}

impl TestVectors {
    /// Load from JSON text (streamed — the input/output matrices decode
    /// straight into their `Vec` storage, no [`Value`] tree).
    pub fn from_json(text: &str) -> Result<Self> {
        let mut d = Decoder::new(text);
        let mut inputs = None;
        let mut outputs = None;
        let mut labels = Vec::new();
        d.object_start()?;
        while let Some(key) = d.next_key()? {
            match key.as_ref() {
                "inputs" => inputs = Some(d.i64_mat()?),
                "outputs" => outputs = Some(d.i64_mat()?),
                "labels" => labels = d.i64_vec()?.into_iter().map(|x| x as u32).collect(),
                _ => d.skip_value()?,
            }
        }
        d.end()?;
        Ok(Self {
            inputs: req(inputs, "inputs")?,
            outputs: req(outputs, "outputs")?,
            labels,
        })
    }
}
