//! Bit-exact host simulation of a quantized network spec.
//!
//! This is the integer reference semantics shared with the JAX golden
//! model; the DAIS-compiled programs are verified against it (and it
//! against PJRT) in tests and the end-to-end examples. i64 arithmetic
//! everywhere — overflow-free for the bitwidths in play.

use super::spec::{LayerSpec, NetworkSpec};
use crate::dais::interp::quant_scalar;
use crate::dais::RoundMode;
use crate::util::fxhash::FxHashMap;

/// The flowing activation state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum State {
    /// Flat vector.
    Flat(Vec<i64>),
    /// Image `[h][w][c]`, row-major.
    Image { data: Vec<i64>, h: usize, w: usize, c: usize },
    /// Set `[particles][features]`, row-major.
    Grid { data: Vec<i64>, p: usize, f: usize },
}

impl State {
    /// Flatten (row-major) — the terminal representation.
    pub fn flatten(self) -> Vec<i64> {
        match self {
            State::Flat(v) => v,
            State::Image { data, .. } => data,
            State::Grid { data, .. } => data,
        }
    }

    fn from_shape(data: Vec<i64>, shape: &[usize]) -> Self {
        match shape.len() {
            1 => State::Flat(data),
            2 => State::Grid { data, p: shape[0], f: shape[1] },
            3 => State::Image { data, h: shape[0], w: shape[1], c: shape[2] },
            _ => panic!("unsupported input rank {}", shape.len()),
        }
    }
}

fn requant(z: i64, relu: bool, shift: i32, lo: i64, hi: i64) -> i64 {
    let z = if relu { z.max(0) } else { z };
    quant_scalar(z, shift, RoundMode::Floor, lo, hi)
}

fn dense(x: &[i64], w: &[Vec<i64>], b: &[i64]) -> Vec<i64> {
    let d_out = b.len();
    let mut z = b.to_vec();
    for (j, xj) in x.iter().enumerate() {
        let row = &w[j];
        for i in 0..d_out {
            z[i] += xj * row[i];
        }
    }
    z
}

/// Run one input vector through the network; returns the flat output.
pub fn forward(spec: &NetworkSpec, input: &[i64]) -> Vec<i64> {
    assert_eq!(input.len(), spec.input_len(), "input length mismatch");
    let mut state = State::from_shape(input.to_vec(), &spec.input_shape);
    let mut saved: FxHashMap<&str, State> = FxHashMap::default();

    for layer in &spec.layers {
        state = match layer {
            LayerSpec::Dense { w, b, relu, shift, clip_min, clip_max } => {
                let x = state.flatten();
                let z = dense(&x, w, b);
                State::Flat(
                    z.into_iter().map(|v| requant(v, *relu, *shift, *clip_min, *clip_max)).collect(),
                )
            }
            LayerSpec::EinsumDense { w, b, axis, relu, shift, clip_min, clip_max } => {
                let State::Grid { data, p, f } = state else {
                    panic!("einsum_dense needs a grid state")
                };
                match axis.as_str() {
                    "feature" => {
                        // Each particle row is a CMVM instance.
                        let d_out = b.len();
                        let mut out = Vec::with_capacity(p * d_out);
                        for row in 0..p {
                            let x = &data[row * f..(row + 1) * f];
                            let z = dense(x, w, b);
                            out.extend(
                                z.into_iter()
                                    .map(|v| requant(v, *relu, *shift, *clip_min, *clip_max)),
                            );
                        }
                        State::Grid { data: out, p, f: d_out }
                    }
                    "particle" => {
                        // Each feature column is a CMVM instance.
                        let d_out = b.len();
                        let mut out = vec![0i64; d_out * f];
                        for col in 0..f {
                            let x: Vec<i64> = (0..p).map(|r| data[r * f + col]).collect();
                            let z = dense(&x, w, b);
                            for (r, v) in z.into_iter().enumerate() {
                                out[r * f + col] =
                                    requant(v, *relu, *shift, *clip_min, *clip_max);
                            }
                        }
                        State::Grid { data: out, p: d_out, f }
                    }
                    other => panic!("unknown einsum axis {other}"),
                }
            }
            LayerSpec::Conv2D { w, b, kh, kw, relu, shift, clip_min, clip_max } => {
                let State::Image { data, h, w: iw, c } = state else {
                    panic!("conv2d needs an image state")
                };
                let (oh, ow) = (h - kh + 1, iw - kw + 1);
                let cout = b.len();
                let mut out = Vec::with_capacity(oh * ow * cout);
                for oy in 0..oh {
                    for ox in 0..ow {
                        // im2col patch in (dy, dx, cin) order.
                        let mut patch = Vec::with_capacity(kh * kw * c);
                        for dy in 0..*kh {
                            for dx in 0..*kw {
                                let base = ((oy + dy) * iw + (ox + dx)) * c;
                                patch.extend_from_slice(&data[base..base + c]);
                            }
                        }
                        let z = dense(&patch, w, b);
                        out.extend(
                            z.into_iter()
                                .map(|v| requant(v, *relu, *shift, *clip_min, *clip_max)),
                        );
                    }
                }
                State::Image { data: out, h: oh, w: ow, c: cout }
            }
            LayerSpec::MaxPool2D | LayerSpec::AvgPool2D => {
                let State::Image { data, h, w, c } = state else {
                    panic!("pooling needs an image state")
                };
                let (oh, ow) = (h / 2, w / 2);
                let mut out = Vec::with_capacity(oh * ow * c);
                for oy in 0..oh {
                    for ox in 0..ow {
                        for ch in 0..c {
                            let at = |dy: usize, dx: usize| {
                                data[((2 * oy + dy) * w + (2 * ox + dx)) * c + ch]
                            };
                            let v = match layer {
                                LayerSpec::MaxPool2D => {
                                    at(0, 0).max(at(0, 1)).max(at(1, 0)).max(at(1, 1))
                                }
                                _ => (at(0, 0) + at(0, 1) + at(1, 0) + at(1, 1)) >> 2,
                            };
                            out.push(v);
                        }
                    }
                }
                State::Image { data: out, h: oh, w: ow, c }
            }
            LayerSpec::Flatten => State::Flat(state.flatten()),
            LayerSpec::Save { tag } => {
                saved.insert(tag.as_str(), state.clone());
                state
            }
            LayerSpec::AddSaved { tag } => {
                let other = saved
                    .get(tag.as_str())
                    .unwrap_or_else(|| panic!("no saved state '{tag}'"))
                    .clone();
                let a = state.flatten();
                let b = other.clone().flatten();
                assert_eq!(a.len(), b.len(), "residual shape mismatch");
                let sum: Vec<i64> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
                match other {
                    State::Grid { p, f, .. } => State::Grid { data: sum, p, f },
                    State::Image { h, w, c, .. } => State::Image { data: sum, h, w, c },
                    State::Flat(_) => State::Flat(sum),
                }
            }
        };
    }
    state.flatten()
}

/// Run a batch; returns flat outputs per input.
pub fn forward_batch(spec: &NetworkSpec, inputs: &[Vec<i64>]) -> Vec<Vec<i64>> {
    inputs.iter().map(|x| forward(spec, x)).collect()
}

/// Top-1 accuracy of argmax(outputs) against labels.
pub fn accuracy(outputs: &[Vec<i64>], labels: &[u32]) -> f64 {
    assert_eq!(outputs.len(), labels.len());
    let correct = outputs
        .iter()
        .zip(labels)
        .filter(|(o, &l)| {
            let arg = o
                .iter()
                .enumerate()
                .max_by_key(|(_, &v)| v)
                .map(|(i, _)| i as u32)
                .unwrap_or(0);
            arg == l
        })
        .count();
    correct as f64 / outputs.len().max(1) as f64
}
