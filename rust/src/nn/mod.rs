//! The hls4ml-substitute neural-network frontend.
//!
//! Networks arrive as JSON specs exported by the build-time Python layer
//! (`python/compile/train.py` → `artifacts/<name>.weights.json`): a
//! sequence of integer-quantized layers with per-layer requantization
//! (shift + clip), mirroring the HGQ → hls4ml flow of the paper. The
//! integer semantics here are **bit-exact** to the JAX golden model
//! (same floor-shift / clip convention), which the end-to-end examples
//! verify through PJRT.
//!
//! Two consumption paths, as in the paper:
//!
//! * [`compile::compile`] — the fully-unrolled II=1 path (dense / einsum /
//!   residual networks): one DAIS program for the whole network, usable
//!   for RTL emission, pipelining and streaming simulation (paper §5.2).
//! * [`sim`] + per-layer [`compile::layer_reports`] — the HLS-flow path
//!   for networks with temporal reuse (convolutions, paper §6.2.2):
//!   layer-by-layer bit-exact host simulation plus resource accounting
//!   with per-layer CMVM optimization and instance counting.

pub mod compile;
pub mod sim;
mod spec;

pub use spec::{weight_tensors, LayerSpec, NetworkSpec, TestVectors};

#[cfg(test)]
mod tests;
