//! NN frontend tests: host simulation vs fused DAIS programs, layer
//! shapes, accuracy metric.

use super::compile::{aggregate, compile, layer_reports, CompileOptions};
use super::sim;
use super::spec::{LayerSpec, NetworkSpec};
use crate::cmvm::Strategy;
use crate::dais::interp;
use crate::estimate::FpgaModel;
use crate::pipeline::PipelineConfig;
use crate::util::Rng;

fn dense_layer(rng: &mut Rng, d_in: usize, d_out: usize, relu: bool) -> LayerSpec {
    LayerSpec::Dense {
        w: (0..d_in)
            .map(|_| (0..d_out).map(|_| rng.range_i64(-31, 31)).collect())
            .collect(),
        b: (0..d_out).map(|_| rng.range_i64(-64, 64)).collect(),
        relu,
        shift: 5,
        clip_min: -128,
        clip_max: 127,
    }
}

fn mlp(seed: u64) -> NetworkSpec {
    let mut rng = Rng::seed_from(seed);
    NetworkSpec {
        name: "test_mlp".into(),
        input_bits: 8,
        input_signed: true,
        input_shape: vec![6],
        layers: vec![
            dense_layer(&mut rng, 6, 10, true),
            dense_layer(&mut rng, 10, 8, true),
            dense_layer(&mut rng, 8, 3, false),
        ],
    }
}

#[test]
fn fused_dais_matches_host_sim_all_strategies() {
    let spec = mlp(3);
    let mut rng = Rng::seed_from(99);
    let inputs: Vec<Vec<i64>> = (0..16)
        .map(|_| (0..6).map(|_| rng.range_i64(-128, 127)).collect())
        .collect();
    let want = sim::forward_batch(&spec, &inputs);
    for s in [Strategy::NaiveDa, Strategy::Da { dc: 2 }, Strategy::Da { dc: -1 }] {
        let prog = compile(&spec, &CompileOptions::new(s)).unwrap().program;
        for (x, w) in inputs.iter().zip(&want) {
            let got = interp::evaluate_checked(&prog, x);
            assert_eq!(&got, w, "strategy {s:?}");
        }
    }
}

/// An objective compile explores the space and compiles the
/// objective's pick: the program is functionally identical to the host
/// simulation, and the stage assignment matches the picked pipeline
/// rung.
#[test]
fn objective_compile_compiles_the_picked_configuration() {
    use crate::explore::{ExploreConfig, Objective};
    let spec = mlp(5);
    let cfg = ExploreConfig { jobs: 1, ..ExploreConfig::smoke() };
    let opts = CompileOptions::new(Strategy::NaiveDa).with_objective(Objective::Knee, &cfg);
    let c = compile(&spec, &opts).unwrap();
    let point = c.point.expect("objective compile carries its pick");
    assert_eq!(c.stages.is_some(), point.pipe.is_some());
    if let Some(st) = &c.stages {
        assert_eq!(st.len(), c.program.nodes.len());
    }
    // Whatever configuration won, the compiled program is bit-exact.
    let mut rng = Rng::seed_from(17);
    for _ in 0..8 {
        let x: Vec<i64> = (0..6).map(|_| rng.range_i64(-128, 127)).collect();
        assert_eq!(interp::evaluate_checked(&c.program, &x), sim::forward(&spec, &x));
    }
}

/// The deprecated free functions are exact shims over [`compile`].
#[test]
#[allow(deprecated)]
fn deprecated_fuse_shims_match_compile() {
    use super::compile::{fuse, fuse_with_stats};
    let spec = mlp(13);
    let s = Strategy::Da { dc: 1 };
    let c = compile(&spec, &CompileOptions::new(s)).unwrap();
    assert_eq!(fuse(&spec, s).unwrap(), c.program);
    let (prog, stats) = fuse_with_stats(&spec, s).unwrap();
    assert_eq!(prog, c.program);
    assert_eq!(stats, c.cse);
}

#[test]
fn fused_da_uses_fewer_adders_than_naive() {
    let spec = mlp(7);
    let naive = compile(&spec, &CompileOptions::new(Strategy::NaiveDa)).unwrap().program;
    let da = compile(&spec, &CompileOptions::new(Strategy::Da { dc: 2 })).unwrap().program;
    assert!(
        da.adder_count() < naive.adder_count(),
        "da {} >= naive {}",
        da.adder_count(),
        naive.adder_count()
    );
}

#[test]
fn mixer_grid_fuse_matches_sim() {
    // Tiny MLP-Mixer-like: feature mix, particle mix, residual.
    let mut rng = Rng::seed_from(11);
    let mk_w = |i: usize, o: usize, rng: &mut Rng| -> Vec<Vec<i64>> {
        (0..i).map(|_| (0..o).map(|_| rng.range_i64(-15, 15)).collect()).collect()
    };
    let spec = NetworkSpec {
        name: "test_mixer".into(),
        input_bits: 6,
        input_signed: true,
        input_shape: vec![4, 3], // 4 particles, 3 features
        layers: vec![
            LayerSpec::Save { tag: "skip".into() },
            LayerSpec::EinsumDense {
                w: mk_w(3, 3, &mut rng),
                b: vec![1, -2, 3],
                axis: "feature".into(),
                relu: true,
                shift: 4,
                clip_min: -32,
                clip_max: 31,
            },
            LayerSpec::EinsumDense {
                w: mk_w(4, 4, &mut rng),
                b: vec![0, 0, 1, -1],
                axis: "particle".into(),
                relu: false,
                shift: 4,
                clip_min: -32,
                clip_max: 31,
            },
            LayerSpec::AddSaved { tag: "skip".into() },
            LayerSpec::Flatten,
            dense_layer(&mut rng, 12, 2, false),
        ],
    };
    let inputs: Vec<Vec<i64>> = (0..8)
        .map(|_| (0..12).map(|_| rng.range_i64(-32, 31)).collect())
        .collect();
    let want = sim::forward_batch(&spec, &inputs);
    let prog = compile(&spec, &CompileOptions::new(Strategy::Da { dc: 2 })).unwrap().program;
    for (x, w) in inputs.iter().zip(&want) {
        assert_eq!(&interp::evaluate_checked(&prog, x), w);
    }
}

#[test]
fn conv_sim_hand_checked() {
    // 3x3x1 input, 2x2 kernel, one channel: valid conv positions 2x2.
    let spec = NetworkSpec {
        name: "conv".into(),
        input_bits: 4,
        input_signed: false,
        input_shape: vec![3, 3, 1],
        layers: vec![
            LayerSpec::Conv2D {
                w: vec![vec![1], vec![2], vec![3], vec![4]], // (dy,dx,cin) order
                b: vec![0],
                kh: 2,
                kw: 2,
                relu: false,
                shift: 0,
                clip_min: -512,
                clip_max: 511,
            },
            LayerSpec::Flatten,
        ],
    };
    // Input image 1..9 row-major.
    let x: Vec<i64> = (1..=9).collect();
    let y = sim::forward(&spec, &x);
    // Position (0,0): 1*1+2*2+3*4+4*5 = 37; (0,1): 2+6+15+24=47... check:
    // patch(0,1) = [2,3,5,6] -> 2+6+15+24 = 47.
    assert_eq!(y, vec![37, 47, 67, 77]);
}

#[test]
fn pool_and_conv_reports() {
    let spec = NetworkSpec {
        name: "convnet".into(),
        input_bits: 8,
        input_signed: false,
        input_shape: vec![6, 6, 1],
        layers: vec![
            LayerSpec::Conv2D {
                w: (0..9).map(|k| vec![k as i64 - 4, 2 * k as i64 - 7]).collect(),
                b: vec![3, -3],
                kh: 3,
                kw: 3,
                relu: true,
                shift: 4,
                clip_min: 0,
                clip_max: 255,
            },
            LayerSpec::MaxPool2D,
            LayerSpec::Flatten,
            LayerSpec::Dense {
                w: (0..8).map(|_| vec![5, -9]).collect(),
                b: vec![0, 0],
                relu: false,
                shift: 2,
                clip_min: -128,
                clip_max: 127,
            },
        ],
    };
    // Host sim runs.
    let x: Vec<i64> = (0..36).map(|i| i % 13).collect();
    let y = sim::forward(&spec, &x);
    assert_eq!(y.len(), 2);
    // Reports exist for both compute layers under both strategies.
    for s in [Strategy::Latency, Strategy::Da { dc: 2 }] {
        let r = layer_reports(&spec, s, &FpgaModel::default(), &PipelineConfig::default())
            .unwrap();
        assert_eq!(r.len(), 2);
        let agg = aggregate(&r);
        assert!(agg.lut > 0);
        if matches!(s, Strategy::Da { .. }) {
            assert_eq!(agg.dsp, 0);
        }
    }
}

#[test]
fn einsum_instance_counting() {
    let spec = NetworkSpec {
        name: "grid".into(),
        input_bits: 6,
        input_signed: true,
        input_shape: vec![5, 3],
        layers: vec![LayerSpec::EinsumDense {
            w: vec![vec![1, 2], vec![3, 4], vec![5, 6]],
            b: vec![0, 0],
            axis: "feature".into(),
            relu: false,
            shift: 0,
            clip_min: -1024,
            clip_max: 1023,
        }],
    };
    let r = layer_reports(
        &spec,
        Strategy::Da { dc: -1 },
        &FpgaModel::default(),
        &PipelineConfig::default(),
    )
    .unwrap();
    assert_eq!(r[0].instances, 5); // one CMVM per particle
    assert_eq!(r[0].total.lut, 5 * r[0].per_instance.lut);
}

#[test]
fn accuracy_metric() {
    let outputs = vec![vec![1, 5, 2], vec![9, 0, 0], vec![0, 0, 7]];
    let labels = vec![1, 0, 1];
    let acc = sim::accuracy(&outputs, &labels);
    assert!((acc - 2.0 / 3.0).abs() < 1e-9);
}

#[test]
fn spec_json_roundtrip() {
    let spec = mlp(1);
    let text = spec.to_json();
    let back = NetworkSpec::from_json(&text).unwrap();
    let x: Vec<i64> = (0..6).collect();
    assert_eq!(sim::forward(&spec, &x), sim::forward(&back, &x));
}

/// The streaming decoder must agree exactly with the DOM-based
/// [`NetworkSpec::from_value`] path on the same document.
#[test]
fn streaming_decode_matches_dom_decode() {
    let spec = mlp(7);
    let text = spec.to_json();
    let streamed = NetworkSpec::from_json(&text).unwrap();
    let dom = NetworkSpec::from_value(&crate::json::parse(&text).unwrap()).unwrap();
    // NetworkSpec has no PartialEq; compare via re-serialization and
    // bit-exact behavior.
    assert_eq!(streamed.to_json(), dom.to_json());
    assert_eq!(streamed.to_json(), text);
}

/// Field order must not matter to the streaming decoder — in
/// particular the layer `"type"` tag, which the sorted exporter places
/// near the *end* of each layer object.
#[test]
fn streaming_decode_is_field_order_independent() {
    let reordered = r#"{
        "layers": [
            {"w": [[1, 2], [3, 4]], "shift": 0, "relu": false,
             "clip_min": -512, "clip_max": 511, "b": [0, -1],
             "future_field": {"ignored": [1, 2]}, "type": "dense"}
        ],
        "input_shape": [2], "input_signed": true, "input_bits": 4,
        "name": "reordered"
    }"#;
    let spec = NetworkSpec::from_json(reordered).unwrap();
    assert_eq!(spec.name, "reordered");
    assert_eq!(sim::forward(&spec, &[1, 2]), vec![7, 9]);
}

/// The streaming decoder is intentionally stricter than the DOM path:
/// a known field of the wrong type is rejected even when the layer tag
/// would not read it (single-pass decoding cannot defer the check).
#[test]
fn streaming_decode_rejects_mistyped_known_fields() {
    let text = r#"{"name":"x","input_bits":4,"input_signed":true,"input_shape":[1],
        "layers":[{"type":"flatten","shift":"none"}]}"#;
    assert!(NetworkSpec::from_json(text).is_err());
    // The DOM path ignores fields the tag does not use.
    assert!(NetworkSpec::from_value(&crate::json::parse(text).unwrap()).is_ok());
}

#[test]
fn streaming_decode_conv1d_and_tags() {
    let text = r#"{
        "name": "c1", "input_bits": 4, "input_signed": false, "input_shape": [1, 4, 1],
        "layers": [
            {"type": "conv1d", "k": 2, "w": [[1], [1]], "b": [0],
             "relu": false, "shift": 0, "clip_min": -512, "clip_max": 511},
            {"type": "flatten"},
            {"type": "save", "tag": "skip"},
            {"type": "add_saved", "tag": "skip"}
        ]
    }"#;
    let spec = NetworkSpec::from_json(text).unwrap();
    assert_eq!(spec.layers.len(), 4);
    match &spec.layers[0] {
        LayerSpec::Conv2D { kh, kw, .. } => {
            assert_eq!((*kh, *kw), (1, 2));
        }
        other => panic!("expected Conv2D from conv1d, got {other:?}"),
    }
    // y[i] = x[i] + x[i+1], then the residual add doubles it.
    assert_eq!(sim::forward(&spec, &[1, 2, 3, 4]), vec![6, 10, 14]);
}
