//! Canonical Signed Digit (CSD) representation (Avizienis 1961).
//!
//! CSD writes an integer as a sum of signed powers of two with no two
//! adjacent non-zero digits. The non-zero digit count is guaranteed
//! minimal among signed-digit representations — on average ~1/3 of the
//! bit positions — which is the discrete substrate both stages of the
//! da4ml algorithm operate on (paper §4.2).

/// One signed digit: `sign * 2^power`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Digit {
    /// Power of two of this digit.
    pub power: i32,
    /// `+1` or `-1`.
    pub sign: i8,
}

impl Digit {
    /// Signed value of this digit as i128 (powers can reach 63+).
    pub fn value(&self) -> i128 {
        (self.sign as i128) << self.power
    }
}

/// The CSD expansion of an integer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Csd {
    digits: Vec<Digit>,
}

impl Csd {
    /// Encode `x` into CSD. The result has no two adjacent non-zero
    /// digits and minimal non-zero digit count.
    pub fn encode(x: i64) -> Self {
        let mut digits = Vec::new();
        let mut v = x as i128;
        let mut power = 0;
        while v != 0 {
            if v & 1 != 0 {
                // d = 2 - (v mod 4) maps v≡1 (mod 4) -> +1, v≡3 -> -1.
                let rem = (v & 3) as i8;
                let d: i8 = if rem == 1 { 1 } else { -1 };
                digits.push(Digit { power, sign: d });
                v -= d as i128;
            }
            v >>= 1;
            power += 1;
        }
        Self { digits }
    }

    /// Decode back to the integer value.
    pub fn decode(&self) -> i64 {
        let v: i128 = self.digits.iter().map(|d| d.value()).sum();
        v as i64
    }

    /// The non-zero digits, in increasing power order.
    pub fn digits(&self) -> &[Digit] {
        &self.digits
    }

    /// Number of non-zero digits (the `N` of the paper's complexity
    /// analysis is the sum of this over all matrix entries).
    pub fn nnz(&self) -> usize {
        self.digits.len()
    }

    /// Whether the expansion is empty (value == 0).
    pub fn is_zero(&self) -> bool {
        self.digits.is_empty()
    }

    /// Lowest non-zero power, if any.
    pub fn min_power(&self) -> Option<i32> {
        self.digits.first().map(|d| d.power)
    }

    /// Highest non-zero power, if any.
    pub fn max_power(&self) -> Option<i32> {
        self.digits.last().map(|d| d.power)
    }
}

/// Number of non-zero CSD digits of `x` without materializing the digits.
pub fn nnz(x: i64) -> u32 {
    let mut v = x as i128;
    let mut n = 0;
    while v != 0 {
        if v & 1 != 0 {
            let d: i128 = if v & 3 == 1 { 1 } else { -1 };
            v -= d;
            n += 1;
        }
        v >>= 1;
    }
    n
}

/// Sum of non-zero CSD digit counts over a slice (vector distance helper
/// for the stage-1 graph construction).
pub fn nnz_vec(xs: &[i64]) -> u32 {
    xs.iter().map(|&x| nnz(x)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_known_values() {
        // 7 = 8 - 1 -> two digits, not three.
        let c = Csd::encode(7);
        assert_eq!(c.nnz(), 2);
        assert_eq!(c.decode(), 7);
        // 15 = 16 - 1.
        assert_eq!(Csd::encode(15).nnz(), 2);
        // 5 = 4 + 1.
        assert_eq!(Csd::encode(5).nnz(), 2);
        // 0 has no digits.
        assert!(Csd::encode(0).is_zero());
    }

    #[test]
    fn encode_negative() {
        let c = Csd::encode(-7);
        assert_eq!(c.decode(), -7);
        assert_eq!(c.nnz(), 2); // -8 + 1
    }

    #[test]
    fn no_adjacent_nonzeros() {
        for x in -4096i64..=4096 {
            let c = Csd::encode(x);
            for w in c.digits().windows(2) {
                assert!(
                    w[1].power - w[0].power >= 2,
                    "adjacent digits in CSD of {x}: {:?}",
                    c.digits()
                );
            }
        }
    }

    #[test]
    fn roundtrip_exhaustive_small() {
        for x in -100_000i64..=100_000 {
            assert_eq!(Csd::encode(x).decode(), x);
        }
    }

    #[test]
    fn roundtrip_extremes() {
        for &x in &[i64::MAX, i64::MIN + 1, i64::MIN, 1 << 62, -(1 << 62)] {
            assert_eq!(Csd::encode(x).decode(), x);
        }
    }

    #[test]
    fn nnz_matches_encode() {
        for x in -5000i64..=5000 {
            assert_eq!(nnz(x), Csd::encode(x).nnz() as u32);
        }
    }

    #[test]
    fn nnz_minimal_vs_binary() {
        // CSD digit count never exceeds the binary popcount.
        for x in 0i64..=10_000 {
            assert!(nnz(x) <= (x as u64).count_ones());
        }
    }

    #[test]
    fn nnz_bound_floor_half_plus_one() {
        // For an x-digit number, CSD has at most floor(x/2 + 1) non-zeros.
        for x in 1i64..=65535 {
            let bits = 64 - (x as u64).leading_zeros();
            assert!(nnz(x) <= bits / 2 + 1);
        }
    }
}
