//! Quantized interval `[l, h, δ]` arithmetic (paper §4.1, Table 1).

/// A quantized interval: the set `{ m * 2^exp : m ∈ [min, max] }`.
///
/// All adder-graph values are tracked with this type; it determines the
/// exact bitwidths fed to the cost model (Eq. 1) and the wrap-free
/// semantics the DAIS interpreter enforces.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct QInterval {
    /// Smallest integer mantissa.
    pub min: i64,
    /// Largest integer mantissa.
    pub max: i64,
    /// Binary exponent of the step size: `δ = 2^exp`.
    pub exp: i32,
}

impl QInterval {
    /// Create a new interval; panics if `min > max`.
    pub fn new(min: i64, max: i64, exp: i32) -> Self {
        assert!(min <= max, "QInterval min {min} > max {max}");
        Self { min, max, exp }
    }

    /// The degenerate interval containing only zero.
    pub fn zero() -> Self {
        Self { min: 0, max: 0, exp: 0 }
    }

    /// Interval of a single constant mantissa value at `exp`.
    pub fn constant(value: i64, exp: i32) -> Self {
        Self { min: value, max: value, exp }
    }

    /// Whether this interval only contains zero.
    pub fn is_zero(&self) -> bool {
        self.min == 0 && self.max == 0
    }

    /// Whether negative values are representable (a sign bit is needed).
    pub fn signed(&self) -> bool {
        self.min < 0
    }

    /// Step size `δ` as a float (may underflow for very negative `exp`).
    pub fn step(&self) -> f64 {
        (self.exp as f64).exp2()
    }

    /// Lowest representable value as a float.
    pub fn min_value(&self) -> f64 {
        self.min as f64 * self.step()
    }

    /// Highest representable value as a float.
    pub fn max_value(&self) -> f64 {
        self.max as f64 * self.step()
    }

    /// Total bitwidth `W` required: mantissa magnitude bits plus a sign
    /// bit when the interval extends below zero.
    pub fn width(&self) -> u32 {
        if self.is_zero() {
            return 0;
        }
        let mag_bits = |v: i64| -> u32 {
            if v >= 0 {
                64 - (v as u64).leading_zeros()
            } else {
                // Two's complement: -2^k needs k+1 bits total (handled via
                // sign below); magnitude bits for value v<0 is bits of
                // (-v - 1) i.e. ceil(log2(-v)) for non-power-of-two.
                64 - ((-v - 1) as u64).leading_zeros()
            }
        };
        let body = mag_bits(self.min).max(mag_bits(self.max));
        body + self.signed() as u32
    }

    /// Position of the most significant bit relative to `exp == 0`
    /// (i.e. `exp + width`). Used for operand-overlap computation.
    pub fn msb(&self) -> i32 {
        self.exp + self.width() as i32
    }

    /// Position of the least significant bit (== `exp`).
    pub fn lsb(&self) -> i32 {
        self.exp
    }

    /// Shift the interval left by `s` bits (`s` may be negative; a right
    /// shift only re-scales `exp`, it never discards mantissa bits).
    pub fn shl(&self, s: i32) -> Self {
        Self { min: self.min, max: self.max, exp: self.exp + s }
    }

    /// Negated interval.
    pub fn neg(&self) -> Self {
        Self { min: -self.max, max: -self.min, exp: self.exp }
    }

    /// Exact interval of `self + other`.
    pub fn add(&self, other: &Self) -> Self {
        let (a, b, exp) = Self::align(self, other);
        Self { min: a.0 + b.0, max: a.1 + b.1, exp }
    }

    /// Exact interval of `self - other`.
    pub fn sub(&self, other: &Self) -> Self {
        let (a, b, exp) = Self::align(self, other);
        Self { min: a.0 - b.1, max: a.1 - b.0, exp }
    }

    /// Exact interval of multiplication by a constant mantissa `c * 2^cexp`.
    pub fn mul_const(&self, c: i64, cexp: i32) -> Self {
        let (a, b) = (self.min * c, self.max * c);
        Self { min: a.min(b), max: a.max(b), exp: self.exp + cexp }
    }

    /// Union (convex hull) of two intervals.
    pub fn union(&self, other: &Self) -> Self {
        if self.is_zero() {
            return *other;
        }
        if other.is_zero() {
            return *self;
        }
        let (a, b, exp) = Self::align(self, other);
        Self { min: a.0.min(b.0), max: a.1.max(b.1), exp }
    }

    /// Whether the scalar mantissa-aligned value `v * 2^vexp` lies inside.
    pub fn contains(&self, v: i64, vexp: i32) -> bool {
        if vexp >= self.exp {
            let shifted = v.checked_shl((vexp - self.exp) as u32);
            match shifted {
                Some(m) => m >= self.min && m <= self.max,
                None => false,
            }
        } else {
            // Finer step than representable -> must be a multiple.
            let d = (self.exp - vexp) as u32;
            if d >= 64 || v & ((1i64 << d) - 1) != 0 {
                return false;
            }
            let m = v >> d;
            m >= self.min && m <= self.max
        }
    }

    /// Align mantissas of two intervals to a common exponent.
    fn align(a: &Self, b: &Self) -> ((i64, i64), (i64, i64), i32) {
        let exp = a.exp.min(b.exp);
        let sa = (a.exp - exp) as u32;
        let sb = (b.exp - exp) as u32;
        ((a.min << sa, a.max << sa), (b.min << sb, b.max << sb), exp)
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widths() {
        assert_eq!(QInterval::new(0, 255, 0).width(), 8);
        assert_eq!(QInterval::new(-128, 127, 0).width(), 8);
        assert_eq!(QInterval::new(-1, 0, 0).width(), 1);
        assert_eq!(QInterval::new(0, 1, 0).width(), 1);
        assert_eq!(QInterval::new(0, 0, 0).width(), 0);
        assert_eq!(QInterval::new(-129, 127, 0).width(), 9);
        assert_eq!(QInterval::new(-128, 128, 0).width(), 9);
    }

    #[test]
    fn add_tracks_exact_range() {
        // Accumulating 4 values in [0, 255] needs exactly 10 bits, not 12.
        let q = QInterval::new(0, 255, 0);
        let sum = q.add(&q).add(&q).add(&q);
        assert_eq!(sum.max, 1020);
        assert_eq!(sum.width(), 10);
    }

    #[test]
    fn sub_and_neg() {
        let a = QInterval::new(0, 10, 0);
        let b = QInterval::new(-3, 5, 0);
        let d = a.sub(&b);
        assert_eq!((d.min, d.max), (-5, 13));
        let n = b.neg();
        assert_eq!((n.min, n.max), (-5, 3));
    }

    #[test]
    fn align_mixed_exponents() {
        let a = QInterval::new(0, 3, 2); // {0,4,8,12}
        let b = QInterval::new(0, 1, 0); // {0,1}
        let s = a.add(&b);
        assert_eq!(s.exp, 0);
        assert_eq!(s.max, 13);
    }

    #[test]
    fn mul_const_negative() {
        let a = QInterval::new(-2, 5, 1);
        let m = a.mul_const(-3, 2);
        assert_eq!((m.min, m.max, m.exp), (-15, 6, 3));
    }

    #[test]
    fn contains_respects_step() {
        let a = QInterval::new(0, 4, 2); // multiples of 4 up to 16
        assert!(a.contains(8, 0));
        assert!(!a.contains(6, 0));
        assert!(a.contains(2, 2)); // 2*4 = 8
        assert!(!a.contains(5, 2)); // 20 > 16
    }

    #[test]
    fn union_hull() {
        let a = QInterval::new(0, 3, 0);
        let b = QInterval::new(-2, 1, 1);
        let u = a.union(&b);
        assert!(u.contains(3, 0) && u.contains(-4, 0) && u.contains(2, 0));
    }
}
