//! Fixed-point number representation and quantized-interval arithmetic.
//!
//! The da4ml algorithm tracks every intermediate value of the adder graph
//! as a *quantized interval* `[l, h, δ]` (paper §4.1): the value is an
//! integer multiple of the step `δ = 2^exp` lying in `[l, h]`. Tracking
//! intervals (instead of plain bitwidths) avoids the pessimistic
//! carry-bit-per-addition growth when accumulating many terms and gives
//! exact cost-model inputs for Eq. (1).
//!
//! Internally we keep the integer mantissa range `[min, max]` and the
//! binary exponent `exp`, i.e. the represented values are
//! `{ m * 2^exp : m ∈ [min, max] }`.

mod qinterval;

pub use qinterval::QInterval;

/// A fixed-point format `fixed<S, W, I>` (paper §4.1): `S` sign bit,
/// `W` total bits, `I` integer bits (including the sign bit when present).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FixedFormat {
    /// Whether the format has a sign bit.
    pub signed: bool,
    /// Total bitwidth `W` (must be ≥ 1).
    pub width: u32,
    /// Integer bits `I`, including the sign bit if present. May be
    /// negative (purely fractional formats) or exceed `W` (trailing
    /// implied zeros).
    pub integer: i32,
}

impl FixedFormat {
    /// Create a new fixed-point format.
    pub fn new(signed: bool, width: u32, integer: i32) -> Self {
        assert!(width >= 1, "fixed-point width must be >= 1");
        Self { signed, width, integer }
    }

    /// Number of fractional bits `F = W - I`.
    pub fn frac(&self) -> i32 {
        self.width as i32 - self.integer
    }

    /// The quantized interval covered by this format:
    /// `l = -S * 2^(I-S)`, `h = 2^(I-S) - 2^(I-W)`, `δ = 2^(I-W)`.
    pub fn qinterval(&self) -> QInterval {
        let exp = -self.frac();
        let s = self.signed as u32;
        // Mantissa range: signed -> [-2^(W-1), 2^(W-1)-1]; unsigned -> [0, 2^W - 1].
        let (min, max) = if self.signed {
            (-(1i64 << (self.width - s)), (1i64 << (self.width - s)) - 1)
        } else {
            (0, (1i64 << self.width) - 1)
        };
        QInterval::new(min, max, exp)
    }

    /// Number of distinct representable values.
    pub fn cardinality(&self) -> i64 {
        1i64 << self.width
    }
}

impl std::fmt::Display for FixedFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "fixed<{}, {}, {}>",
            if self.signed { 1 } else { 0 },
            self.width,
            self.integer
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_qinterval_int8() {
        // fixed<1, 8, 8>: classic signed 8-bit integer.
        let f = FixedFormat::new(true, 8, 8);
        let q = f.qinterval();
        assert_eq!(q.min_value(), -128.0);
        assert_eq!(q.max_value(), 127.0);
        assert_eq!(q.step(), 1.0);
        assert_eq!(q.width(), 8);
        assert!(q.signed());
    }

    #[test]
    fn format_qinterval_unsigned() {
        let f = FixedFormat::new(false, 4, 4);
        let q = f.qinterval();
        assert_eq!(q.min_value(), 0.0);
        assert_eq!(q.max_value(), 15.0);
        assert_eq!(q.width(), 4);
        assert!(!q.signed());
    }

    #[test]
    fn format_qinterval_fractional() {
        // fixed<1, 8, 2>: 6 fractional bits, range [-2, 2).
        let f = FixedFormat::new(true, 8, 2);
        let q = f.qinterval();
        assert_eq!(q.min_value(), -2.0);
        assert_eq!(q.step(), 1.0 / 64.0);
        assert_eq!(q.max_value(), 2.0 - 1.0 / 64.0);
    }

    #[test]
    fn format_display() {
        assert_eq!(FixedFormat::new(true, 8, 3).to_string(), "fixed<1, 8, 3>");
    }
}
