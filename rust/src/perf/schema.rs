//! The `BENCH_cmvm.json` schema (version [`super::SCHEMA_VERSION`]) and
//! the baseline document the regression gate consumes.
//!
//! Both documents are plain JSON through the in-tree [`crate::json`]
//! layer; the full field reference lives in `docs/perf.md`. A **report**
//! is what `da4ml perf` writes; a **baseline** is the subset a repo
//! commits for CI to gate on (`ci/bench_baseline.json`):
//!
//! * deterministic counters (`adders`, `lut`, `heap_pops`, …) are pinned
//!   exactly when present in a baseline case;
//! * phase timings (`optimize_ms`, …) are machine-dependent, so a
//!   baseline only carries them when blessed with `--with-times`, and
//!   the diff applies the relative `time_tolerance`;
//! * `min_speedup` gates the engine A/B ratio, which is same-machine
//!   relative and therefore portable across CI hosts.

use super::{CaseReport, CoordinatorShardBench, EngineAb, SuiteReport};
use crate::cse::CseStats;
use crate::json::{self, Value};
use crate::Result;
use std::collections::BTreeMap;

/// Deterministic per-case counters a baseline may pin (exact match).
pub const COUNTER_KEYS: &[&str] = &[
    "adders",
    "depth",
    "lut",
    "ff",
    "stages",
    "cse_steps",
    "depth_rejections",
    "heap_pops",
    "stale_pops",
    "occ_cols_scanned",
    "occ_digits_scanned",
];

/// Machine-dependent per-case timings a baseline may bound (relative
/// tolerance).
pub const TIME_KEYS: &[&str] = &["optimize_ms", "lower_ms", "emit_ms"];

/// Default engine A/B speedup floor written into blessed baselines —
/// deliberately below the measured headline so CI jitter cannot flake
/// the gate, while still catching a real regression of the overhaul.
/// Raised from 1.25 when the bitset-occupancy engine landed: the
/// reference engine must now be strictly >1.4x slower.
pub const DEFAULT_MIN_SPEEDUP: f64 = 1.4;

/// Default ceiling on [`CaseReport::allocs_per_compile`] written into
/// blessed baselines: 2x the worst measured case, so allocation-churn
/// regressions (losing the arena, reintroducing per-node boxing) trip
/// the gate while honest growth has headroom.
pub const DEFAULT_ALLOC_HEADROOM: f64 = 2.0;

/// Default relative tolerance for time metrics (+50 %).
pub const DEFAULT_TIME_TOLERANCE: f64 = 0.5;

/// Default coordinator-shard speedup floor written into blessed
/// baselines. Deliberately modest: the warm hammer is lock-bound, so
/// the win over a single mutex varies with core count far more than
/// the engine A/B does — 1.1x still catches a refactor that reverts to
/// one global lock.
pub const DEFAULT_MIN_SHARD_SPEEDUP: f64 = 1.1;

fn obj(entries: Vec<(&str, Value)>) -> Value {
    Value::Object(
        entries
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

fn int(v: u64) -> Value {
    Value::Int(v as i64)
}

fn stats_entries(s: &CseStats) -> Vec<(&'static str, Value)> {
    vec![
        ("cse_steps", int(s.steps as u64)),
        ("depth_rejections", int(s.depth_rejections as u64)),
        ("heap_pops", int(s.heap_pops as u64)),
        ("stale_pops", int(s.stale_pops as u64)),
        ("occ_cols_scanned", int(s.occ_cols_scanned as u64)),
        ("occ_digits_scanned", int(s.occ_digits_scanned as u64)),
    ]
}

fn case_value(c: &CaseReport) -> Value {
    let mut entries = vec![
        ("id", Value::Str(c.id.clone())),
        ("kind", Value::Str(c.kind.to_string())),
        ("strategy", Value::Str(c.strategy.to_string())),
        ("optimize_ms", Value::Float(c.phases.optimize)),
        ("lower_ms", Value::Float(c.phases.lower)),
        ("emit_ms", Value::Float(c.phases.emit)),
        ("adders", int(c.adders)),
        ("depth", int(c.depth as u64)),
        ("lut", int(c.lut)),
        ("ff", int(c.ff)),
        ("stages", int(c.stages as u64)),
        ("worst_stage_ns", Value::Float(c.worst_stage_ns)),
        ("allocs_per_compile", int(c.allocs_per_compile)),
    ];
    entries.extend(stats_entries(&c.cse));
    obj(entries)
}

fn engine_ab_value(ab: &EngineAb) -> Value {
    obj(vec![
        ("case", Value::Str(ab.case_id.clone())),
        ("indexed_ms", Value::Float(ab.indexed_ms)),
        ("reference_ms", Value::Float(ab.reference_ms)),
        ("speedup", Value::Float(ab.speedup)),
        ("programs_match", Value::Bool(ab.programs_match)),
        ("indexed", obj(stats_entries(&ab.indexed))),
        ("reference", obj(stats_entries(&ab.reference))),
    ])
}

fn coordinator_value(cs: &CoordinatorShardBench) -> Value {
    obj(vec![
        ("case", Value::Str(cs.case_id.clone())),
        ("threads", int(cs.threads as u64)),
        ("shards", int(cs.shards as u64)),
        ("jobs", int(cs.jobs as u64)),
        ("lookups", int(cs.lookups)),
        ("cold_ms", Value::Float(cs.cold_ms)),
        ("single_warm_ms", Value::Float(cs.single_warm_ms)),
        ("sharded_warm_ms", Value::Float(cs.sharded_warm_ms)),
        ("speedup", Value::Float(cs.speedup)),
    ])
}

/// The full report as a JSON value (the `BENCH_cmvm.json` document).
pub fn to_value(r: &SuiteReport) -> Value {
    obj(vec![
        ("schema_version", int(r.schema_version as u64)),
        ("suite", Value::Str(r.suite.to_string())),
        ("jet_source", Value::Str(r.jet_source.clone())),
        ("runs", int(r.runs as u64)),
        (
            "cases",
            Value::Array(r.cases.iter().map(case_value).collect()),
        ),
        ("engine_ab", engine_ab_value(&r.engine_ab)),
        ("coordinator", coordinator_value(&r.coordinator)),
        (
            "skipped",
            Value::Array(
                r.skipped
                    .iter()
                    .map(|s| {
                        obj(vec![
                            ("id", Value::Str(s.id.clone())),
                            ("reason", Value::Str(s.reason.clone())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Serialize the report to the `BENCH_cmvm.json` text (compact JSON,
/// one document).
pub fn render(r: &SuiteReport) -> String {
    json::to_string(&to_value(r))
}

/// A blessed baseline document derived from a run: every deterministic
/// counter of every case, the engine A/B floor, the allocation ceiling
/// (when the blessing run measured allocations at all), and — only
/// with `with_times` — the phase timings of the blessing machine.
pub fn baseline_value(r: &SuiteReport, with_times: bool) -> Value {
    // Suite-level ceiling, not a per-case pin: allocation counts are
    // deterministic for a given allocator/libstd but shift across
    // toolchains, so the gate bounds the worst case with headroom
    // instead of pinning each case exactly.
    let max_allocs = r.cases.iter().map(|c| c.allocs_per_compile).max().unwrap_or(0);
    let cases: Vec<Value> = r
        .cases
        .iter()
        .map(|c| {
            let mut entries = vec![
                ("id", Value::Str(c.id.clone())),
                ("adders", int(c.adders)),
                ("depth", int(c.depth as u64)),
                ("lut", int(c.lut)),
                ("ff", int(c.ff)),
                ("stages", int(c.stages as u64)),
            ];
            entries.extend(stats_entries(&c.cse));
            if with_times {
                entries.push(("optimize_ms", Value::Float(c.phases.optimize)));
                entries.push(("lower_ms", Value::Float(c.phases.lower)));
                entries.push(("emit_ms", Value::Float(c.phases.emit)));
            }
            obj(entries)
        })
        .collect();
    let out = obj(vec![
        ("schema_version", int(r.schema_version as u64)),
        ("suite", Value::Str(r.suite.to_string())),
        // net/jet/* counters depend on which jet network the blessing
        // run saw (exported artifact vs synthetic stand-in); recording
        // it lets the gate diagnose artifact-presence mismatches
        // instead of reporting misleading counter drift.
        ("jet_source", Value::Str(r.jet_source.clone())),
        ("min_speedup", Value::Float(DEFAULT_MIN_SPEEDUP)),
        ("min_shard_speedup", Value::Float(DEFAULT_MIN_SHARD_SPEEDUP)),
        ("time_tolerance", Value::Float(DEFAULT_TIME_TOLERANCE)),
        ("cases", Value::Array(cases)),
    ]);
    let Value::Object(mut m) = out else { unreachable!("obj returns an object") };
    if max_allocs > 0 {
        m.insert(
            "max_allocs_per_compile".into(),
            int((max_allocs as f64 * DEFAULT_ALLOC_HEADROOM).ceil() as u64),
        );
    }
    Value::Object(m)
}

/// Serialize a blessed baseline (see [`baseline_value`]).
pub fn render_baseline(r: &SuiteReport, with_times: bool) -> String {
    json::to_string(&baseline_value(r, with_times))
}

/// One baseline case: the id plus whichever metrics the document pins.
#[derive(Debug, Clone, Default)]
pub struct BaselineCase {
    /// Join key against [`CaseReport::id`].
    pub id: String,
    /// Exact-match counter pins present in the document.
    pub counters: Vec<(String, i64)>,
    /// Tolerance-bounded time pins present in the document (ms).
    pub times_ms: Vec<(String, f64)>,
}

/// A parsed baseline document.
#[derive(Debug, Clone)]
pub struct Baseline {
    /// Schema version the baseline was written against.
    pub schema_version: i64,
    /// True for the committed bootstrap stub (no pinned cases yet).
    pub bootstrap: bool,
    /// Which jet network the blessing run measured (`"artifact"` /
    /// `"synthetic"`); absent in hand-written stubs.
    pub jet_source: Option<String>,
    /// Engine A/B speedup floor (absent = not gated).
    pub min_speedup: Option<f64>,
    /// Coordinator shard-hammer speedup floor (absent = not gated; a
    /// single-core host cannot meaningfully exceed 1.0, so only
    /// multi-core CI baselines should pin this).
    pub min_shard_speedup: Option<f64>,
    /// Ceiling on any case's `allocs_per_compile` (absent = not gated;
    /// also skipped when the run measured all-zero, i.e. the counting
    /// allocator was not installed).
    pub max_allocs_per_compile: Option<i64>,
    /// Relative tolerance for time metrics.
    pub time_tolerance: f64,
    /// Pinned cases.
    pub cases: Vec<BaselineCase>,
}

/// Parse a baseline document (either a blessed baseline or the
/// committed bootstrap stub).
pub fn parse_baseline(text: &str) -> Result<Baseline> {
    let v = json::parse(text)?;
    let schema_version = v.get("schema_version")?.as_i64()?;
    let bootstrap = match v.get_opt("bootstrap") {
        Some(b) => b.as_bool()?,
        None => false,
    };
    let jet_source = match v.get_opt("jet_source") {
        Some(x) => Some(x.as_str()?.to_string()),
        None => None,
    };
    let min_speedup = match v.get_opt("min_speedup") {
        Some(x) => Some(x.as_f64()?),
        None => None,
    };
    let min_shard_speedup = match v.get_opt("min_shard_speedup") {
        Some(x) => Some(x.as_f64()?),
        None => None,
    };
    let max_allocs_per_compile = match v.get_opt("max_allocs_per_compile") {
        Some(x) => Some(x.as_i64()?),
        None => None,
    };
    let time_tolerance = match v.get_opt("time_tolerance") {
        Some(x) => x.as_f64()?,
        None => DEFAULT_TIME_TOLERANCE,
    };
    let mut cases = Vec::new();
    if let Some(arr) = v.get_opt("cases") {
        for cv in arr.as_array()? {
            let mut case = BaselineCase {
                id: cv.get("id")?.as_str()?.to_string(),
                ..BaselineCase::default()
            };
            for &k in COUNTER_KEYS {
                if let Some(x) = cv.get_opt(k) {
                    case.counters.push((k.to_string(), x.as_i64()?));
                }
            }
            for &k in TIME_KEYS {
                if let Some(x) = cv.get_opt(k) {
                    case.times_ms.push((k.to_string(), x.as_f64()?));
                }
            }
            cases.push(case);
        }
    }
    Ok(Baseline {
        schema_version,
        bootstrap,
        jet_source,
        min_speedup,
        min_shard_speedup,
        max_allocs_per_compile,
        time_tolerance,
        cases,
    })
}

#[cfg(test)]
mod tests {
    use super::super::{PhaseMs, SkippedCase};
    use super::*;

    fn tiny_report() -> SuiteReport {
        SuiteReport {
            schema_version: super::super::SCHEMA_VERSION,
            suite: "smoke",
            jet_source: "synthetic".into(),
            runs: 3,
            cases: vec![CaseReport {
                id: "cmvm/2x2/da".into(),
                kind: "cmvm",
                strategy: "da",
                phases: PhaseMs { optimize: 1.5, lower: 0.25, emit: 0.125 },
                adders: 4,
                depth: 2,
                lut: 40,
                ff: 32,
                stages: 0,
                worst_stage_ns: 2.5,
                cse: CseStats {
                    steps: 3,
                    depth_rejections: 0,
                    heap_pops: 11,
                    stale_pops: 5,
                    occ_cols_scanned: 7,
                    occ_digits_scanned: 21,
                },
                allocs_per_compile: 1200,
            }],
            engine_ab: EngineAb {
                case_id: "jet/cse-stage".into(),
                indexed_ms: 2.0,
                reference_ms: 5.0,
                speedup: 2.5,
                programs_match: true,
                indexed: CseStats::default(),
                reference: CseStats::default(),
            },
            coordinator: CoordinatorShardBench {
                case_id: "coordinator/shard-hammer".into(),
                threads: 4,
                shards: 8,
                jobs: 24,
                lookups: 6144,
                cold_ms: 12.0,
                single_warm_ms: 4.0,
                sharded_warm_ms: 2.0,
                speedup: 2.0,
            },
            skipped: vec![SkippedCase { id: "cmvm/64x64/lookahead".into(), reason: "O(N^3)".into() }],
        }
    }

    #[test]
    fn report_roundtrips_through_json() {
        let r = tiny_report();
        let text = render(&r);
        let v = json::parse(&text).expect("report is valid JSON");
        assert_eq!(v.get("schema_version").unwrap().as_i64().unwrap(), 1);
        assert_eq!(v.get("suite").unwrap().as_str().unwrap(), "smoke");
        let cases = v.get("cases").unwrap().as_array().unwrap();
        assert_eq!(cases.len(), 1);
        assert_eq!(cases[0].get("id").unwrap().as_str().unwrap(), "cmvm/2x2/da");
        assert_eq!(cases[0].get("heap_pops").unwrap().as_i64().unwrap(), 11);
        assert_eq!(
            cases[0].get("allocs_per_compile").unwrap().as_i64().unwrap(),
            1200
        );
        assert!(
            (cases[0].get("optimize_ms").unwrap().as_f64().unwrap() - 1.5).abs() < 1e-12
        );
        let ab = v.get("engine_ab").unwrap();
        assert!((ab.get("speedup").unwrap().as_f64().unwrap() - 2.5).abs() < 1e-12);
        assert!(ab.get("programs_match").unwrap().as_bool().unwrap());
        let cs = v.get("coordinator").unwrap();
        assert_eq!(cs.get("threads").unwrap().as_i64().unwrap(), 4);
        assert_eq!(cs.get("shards").unwrap().as_i64().unwrap(), 8);
        assert!((cs.get("speedup").unwrap().as_f64().unwrap() - 2.0).abs() < 1e-12);
        assert_eq!(v.get("skipped").unwrap().as_array().unwrap().len(), 1);
    }

    #[test]
    fn blessed_baseline_parses_back() {
        let r = tiny_report();
        let text = render_baseline(&r, false);
        let b = parse_baseline(&text).expect("baseline parses");
        assert_eq!(b.schema_version, 1);
        assert!(!b.bootstrap);
        assert_eq!(b.jet_source.as_deref(), Some("synthetic"));
        assert_eq!(b.min_speedup, Some(DEFAULT_MIN_SPEEDUP));
        assert_eq!(b.min_shard_speedup, Some(DEFAULT_MIN_SHARD_SPEEDUP));
        assert_eq!(
            b.max_allocs_per_compile,
            Some(2400),
            "ceiling = 2x the worst measured case"
        );
        assert_eq!(b.cases.len(), 1);
        let case = &b.cases[0];
        assert_eq!(case.id, "cmvm/2x2/da");
        assert!(case.counters.iter().any(|(k, v)| k == "adders" && *v == 4));
        assert!(case.counters.iter().any(|(k, v)| k == "heap_pops" && *v == 11));
        assert!(case.times_ms.is_empty(), "times only with --with-times");

        let with_times = parse_baseline(&render_baseline(&r, true)).unwrap();
        assert!(with_times.cases[0]
            .times_ms
            .iter()
            .any(|(k, v)| k == "optimize_ms" && (*v - 1.5).abs() < 1e-12));
    }

    #[test]
    fn bootstrap_stub_parses() {
        let stub = r#"{"schema_version": 1, "suite": "smoke", "bootstrap": true,
                       "min_speedup": 1.25, "time_tolerance": 0.5, "cases": []}"#;
        let b = parse_baseline(stub).unwrap();
        assert!(b.bootstrap);
        assert_eq!(b.cases.len(), 0);
        assert_eq!(b.min_speedup, Some(1.25));
        assert_eq!(b.min_shard_speedup, None, "stub without the key does not gate it");
        assert_eq!(b.max_allocs_per_compile, None);
    }
}
