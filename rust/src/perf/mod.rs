//! The perf lab: a fixed, machine-readable benchmark suite over the
//! whole compile pipeline.
//!
//! The paper's headline claim is that the DA/CSE optimizer matches
//! state-of-the-art resource reduction *while being significantly
//! faster to compute*; this module is the measurement subsystem that
//! keeps the claim honest over time. It runs a deterministic case list
//! — seeded random CMVMs across sizes × all five
//! [`crate::cmvm::Strategy`] variants, plus the jet-tagging network
//! (exported artifact when present, synthetic stand-in otherwise) and
//! scaled variants of it — and times the three pipeline phases
//! (**optimize** → **lower** → **emit**) on the monotonic clock,
//! alongside the deterministic engine work counters
//! ([`crate::cse::CseStats`]) and the analytic resource estimates
//! ([`crate::estimate`]), including the per-stage breakdown for
//! pipelined network cases.
//!
//! Results serialize to the schema-versioned `BENCH_cmvm.json`
//! ([`schema`], documented in `docs/perf.md`) and diff against a
//! committed baseline with per-metric tolerances ([`diff`]) — the CI
//! `perf-smoke` job gates on it via `da4ml perf --smoke --baseline
//! ci/bench_baseline.json`.
//!
//! The suite also carries an **engine A/B** case: the indexed CSE
//! engine vs the retained pre-index [`crate::cse::reference`] engine on
//! the jet network's layer matrices, reporting the measured speedup and
//! asserting the two emit bit-identical programs. A second same-machine
//! A/B measures the **coordinator cache under contention**: a
//! multi-threaded warm hammer over one job set, on the single-lock
//! cache vs the sharded one ([`coordinator_shard`]) — with exact
//! hit/miss accounting asserted, so a lost update fails the suite, not
//! just the gate.
//!
//! Every case the suite intentionally drops (the O(N³) lookahead
//! comparator above its size cap, the latency strategy's functionally
//! identical network twin) is listed in the report's `skipped` array —
//! no silent coverage holes.

pub mod diff;
pub mod schema;

use crate::bench_tables::{synthetic_jet_spec, synthetic_jet_spec_scaled};
use crate::cmvm::{self, CmvmProblem, OptimizeOptions, Strategy};
use crate::coordinator::{CompileJob, Coordinator};
use crate::cse::{self, CseConfig, CseStats, InputTerm};
use crate::dais::{DaisBuilder, DaisProgram};
use crate::estimate::{self, FpgaModel};
use crate::netlist::Netlist;
use crate::nn::{self, NetworkSpec};
use crate::pipeline::{assign_stages, PipelineConfig};
use crate::report::{sci, Table};
use crate::rtl;
use crate::runtime;
use crate::util::{alloc_count, median_duration, time_once};
use crate::Result;
use anyhow::ensure;
use std::time::Duration;

/// Version of the `BENCH_cmvm.json` schema this build writes; bumped on
/// any incompatible change, and checked against the baseline by the
/// regression gate.
pub const SCHEMA_VERSION: u32 = 1;

/// Delay constraint used by the engine-driven suite strategies.
pub const SUITE_DC: i32 = 2;

/// Pipeline config of the network cases (matches the `rtl` CLI default:
/// a register every 5 adders).
pub const PIPE_EVERY: u32 = 5;

/// Suite selection: `Smoke` is CI-sized, `Full` is the weekly run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Suite {
    /// CI-sized subset (small CMVMs, down-scaled networks, 3 repeats).
    Smoke,
    /// The whole case list (up to 64×64 CMVMs and a 2× jet network).
    Full,
}

impl Suite {
    /// Name used in reports and baselines.
    pub fn name(&self) -> &'static str {
        match self {
            Suite::Smoke => "smoke",
            Suite::Full => "full",
        }
    }
}

/// Perf-run configuration.
#[derive(Debug, Clone, Copy)]
pub struct PerfConfig {
    /// Which case list to run.
    pub suite: Suite,
    /// Timing repeats per case; the **median** per phase is reported.
    /// The deterministic counters are asserted identical across
    /// repeats — a mismatch fails the run (it would mean the optimizer
    /// is not deterministic, which the differential tests forbid).
    pub runs: usize,
}

impl PerfConfig {
    /// The CI-sized configuration (`da4ml perf --smoke`).
    pub fn smoke() -> Self {
        Self { suite: Suite::Smoke, runs: 3 }
    }

    /// The full configuration (`da4ml perf`).
    pub fn full() -> Self {
        Self { suite: Suite::Full, runs: 5 }
    }
}

/// Median per-phase wall-clock times, milliseconds.
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseMs {
    /// CMVM optimization (strategy run / network fuse).
    pub optimize: f64,
    /// Pipeline stage assignment + netlist lowering.
    pub lower: f64,
    /// Verilog emission from the netlist.
    pub emit: f64,
}

/// One measured suite case.
#[derive(Debug, Clone)]
pub struct CaseReport {
    /// Stable case id (`cmvm/16x16/da`, `net/jet/da`, …) — the baseline
    /// join key.
    pub id: String,
    /// Case family: `"cmvm"` or `"network"`.
    pub kind: &'static str,
    /// Strategy short name.
    pub strategy: &'static str,
    /// Median phase timings.
    pub phases: PhaseMs,
    /// Adder count of the optimized program.
    pub adders: u64,
    /// Adder depth.
    pub depth: u32,
    /// LUT estimate (Eq. 1 model).
    pub lut: u64,
    /// Flip-flop estimate.
    pub ff: u64,
    /// Pipeline stage count (0 for combinational CMVM cases).
    pub stages: u32,
    /// Worst per-stage critical path in ns (combinational latency for
    /// CMVM cases).
    pub worst_stage_ns: f64,
    /// Engine work counters (zeros for engine-bypassing strategies).
    pub cse: CseStats,
    /// Heap allocations performed by the optimize phase of the *final*
    /// timing repeat (arena-warm for arena-reusing entry points). The
    /// process-wide counter only ticks when the binary installs
    /// [`crate::util::alloc_count::CountingAlloc`] as its global
    /// allocator (the `da4ml` CLI does); 0 means "not measured" and the
    /// baseline gate skips its ceiling.
    pub allocs_per_compile: u64,
}

/// A case the suite intentionally did not run.
#[derive(Debug, Clone)]
pub struct SkippedCase {
    /// The case id that would have been measured.
    pub id: String,
    /// Why it was dropped.
    pub reason: String,
}

/// The engine A/B measurement: indexed vs reference CSE engine on the
/// jet network's layer matrices.
#[derive(Debug, Clone)]
pub struct EngineAb {
    /// Stable id of the A/B case.
    pub case_id: String,
    /// Median wall-clock of the indexed engine over all layers, ms.
    pub indexed_ms: f64,
    /// Median wall-clock of the reference engine over all layers, ms.
    pub reference_ms: f64,
    /// `reference_ms / indexed_ms` — >1 means the indexed engine is
    /// faster. Machine-relative, so it is gate-able across CI hosts.
    pub speedup: f64,
    /// Both engines emitted bit-identical programs on every run.
    pub programs_match: bool,
    /// Work counters of the indexed engine.
    pub indexed: CseStats,
    /// Work counters of the reference engine (full-rescan semantics).
    pub reference: CseStats,
}

/// The coordinator sharding measurement: a cold bake (all misses)
/// followed by a multi-threaded warm hammer (all hits) over the same
/// job set, timed on a single-lock coordinator vs a sharded one. The
/// speedup is same-machine relative (like [`EngineAb::speedup`]), so
/// the CI gate can floor it across hosts.
#[derive(Debug, Clone)]
pub struct CoordinatorShardBench {
    /// Stable id of the contention case.
    pub case_id: String,
    /// Hammer threads (the contention level).
    pub threads: usize,
    /// Shard count of the sharded coordinator under test.
    pub shards: usize,
    /// Distinct jobs in the working set.
    pub jobs: usize,
    /// Total warm cache-hit lookups performed per coordinator.
    pub lookups: u64,
    /// Cold bake wall-clock (all misses, sharded coordinator), ms.
    pub cold_ms: f64,
    /// Median warm-hammer wall-clock on the single-lock cache, ms.
    pub single_warm_ms: f64,
    /// Median warm-hammer wall-clock on the sharded cache, ms.
    pub sharded_warm_ms: f64,
    /// `single_warm_ms / sharded_warm_ms` — >1 means sharding wins
    /// under contention.
    pub speedup: f64,
}

/// The whole suite result — serialized to `BENCH_cmvm.json`.
#[derive(Debug, Clone)]
pub struct SuiteReport {
    /// Schema version ([`SCHEMA_VERSION`]).
    pub schema_version: u32,
    /// Suite name (`smoke` / `full`).
    pub suite: &'static str,
    /// Where the jet network came from: `"artifact"` or `"synthetic"`.
    pub jet_source: String,
    /// Timing repeats per case.
    pub runs: usize,
    /// Measured cases.
    pub cases: Vec<CaseReport>,
    /// The engine A/B measurement.
    pub engine_ab: EngineAb,
    /// The coordinator-cache contention measurement.
    pub coordinator: CoordinatorShardBench,
    /// Cases intentionally not run, with reasons.
    pub skipped: Vec<SkippedCase>,
}

fn ms(d: Duration) -> f64 {
    // Microsecond precision keeps the JSON readable; the tolerances are
    // far coarser than this rounding.
    (d.as_secs_f64() * 1e3 * 1e3).round() / 1e3
}

/// The jet network: the exported artifact when present, otherwise the
/// synthetic stand-in (the choice is recorded in the report).
pub fn jet_spec() -> (String, NetworkSpec) {
    let artifact = runtime::artifacts_dir().join("jet_mlp.weights.json");
    if let Ok(text) = runtime::load_text(&artifact) {
        if let Ok(spec) = NetworkSpec::from_json(&text) {
            return ("artifact".into(), spec);
        }
    }
    ("synthetic".into(), synthetic_jet_spec())
}

fn cmvm_sizes(suite: Suite) -> &'static [usize] {
    match suite {
        Suite::Smoke => &[8, 16],
        Suite::Full => &[8, 16, 32, 64],
    }
}

/// The O(N³) lookahead comparator is only run on CMVMs up to this edge
/// length; larger cases are recorded as skipped.
fn lookahead_cap(suite: Suite) -> usize {
    match suite {
        Suite::Smoke => 8,
        Suite::Full => 16,
    }
}

fn net_scales(suite: Suite) -> &'static [(usize, usize)] {
    match suite {
        Suite::Smoke => &[(1, 4), (1, 2)],
        Suite::Full => &[(1, 4), (1, 2), (1, 1), (2, 1)],
    }
}

/// All five strategy variants, with the suite delay constraint where
/// one applies.
fn strategies() -> [(&'static str, Strategy); 5] {
    [
        ("latency", Strategy::Latency),
        ("naive-da", Strategy::NaiveDa),
        ("cse-only", Strategy::CseOnly { dc: SUITE_DC }),
        ("da", Strategy::Da { dc: SUITE_DC }),
        ("lookahead", Strategy::Lookahead { dc: SUITE_DC }),
    ]
}

/// The deterministic facts of one case run — asserted identical across
/// timing repeats.
#[derive(Debug, Clone, Copy, PartialEq)]
struct CaseFacts {
    adders: u64,
    depth: u32,
    lut: u64,
    ff: u64,
    stages: u32,
    worst_stage_ns: f64,
    cse: CseStats,
}

/// Measure one case: run `optimize_fn` (then lower + emit) `runs`
/// times, median the phase timings, and pin the deterministic facts.
fn measure_case<F>(
    runs: usize,
    id: String,
    kind: &'static str,
    strategy: &'static str,
    pipe: Option<u32>,
    optimize_fn: F,
) -> Result<CaseReport>
where
    F: Fn() -> Result<(DaisProgram, CseStats)>,
{
    let model = FpgaModel::default();
    let runs = runs.max(1);
    let mut t_opt = Vec::with_capacity(runs);
    let mut t_low = Vec::with_capacity(runs);
    let mut t_emit = Vec::with_capacity(runs);
    let mut pinned: Option<CaseFacts> = None;
    // Cheap determinism pin, checked on *every* repeat; the full
    // resource estimate (a whole-program walk) runs once, on the first.
    let mut quick_pin: Option<(usize, usize, CseStats)> = None;
    // Allocation count of the *final* repeat: by then any arena-reusing
    // entry point runs warm, so this is the steady-state figure the
    // baseline ceiling gates. Deliberately outside `CaseFacts` — it is
    // legitimately different on the cold first repeat.
    let mut allocs_per_compile = 0u64;
    for run in 0..runs {
        let allocs_before = alloc_count::allocations();
        let (d_opt, optimized) = time_once(&optimize_fn);
        if run == runs - 1 {
            allocs_per_compile = alloc_count::allocations().saturating_sub(allocs_before);
        }
        let (program, cse_stats) = optimized?;
        // Stage assignment is part of the lowering phase (it is the
        // schedule the netlist materializes), so it is timed with it.
        let (d_low, lowered) = time_once(|| {
            let stages =
                pipe.map(|n| assign_stages(&program, &PipelineConfig::every_n_adders(n.max(1))));
            Netlist::lower(&program, stages.as_deref()).map(|nl| (nl, stages))
        });
        let (nl, stages) = lowered?;
        let (d_emit, text) = time_once(|| rtl::verilog_from_netlist(&nl, "perf_case"));
        ensure!(!text.is_empty(), "perf: empty RTL emission for case {id}");
        t_opt.push(d_opt);
        t_low.push(d_low);
        t_emit.push(d_emit);

        let quick = (program.nodes.len(), program.outputs.len(), cse_stats);
        match quick_pin {
            None => {
                quick_pin = Some(quick);
                let rep = match &stages {
                    Some(st) => estimate::pipelined(&program, st, &model),
                    None => estimate::combinational(&program, &model),
                };
                let (n_stages, worst_ns) = match &stages {
                    Some(st) => {
                        let per = estimate::per_stage(&program, st, &model);
                        (
                            per.len() as u32,
                            per.iter().map(|s| s.crit_ns).fold(0.0, f64::max),
                        )
                    }
                    None => (0, rep.latency_ns),
                };
                pinned = Some(CaseFacts {
                    adders: rep.adders,
                    depth: rep.depth,
                    lut: rep.lut,
                    ff: rep.ff,
                    stages: n_stages,
                    worst_stage_ns: worst_ns,
                    cse: cse_stats,
                });
            }
            Some(prev) => ensure!(
                prev == quick,
                "perf: non-deterministic optimizer output for case {id} on repeat \
                 {run}: {prev:?} vs {quick:?}"
            ),
        }
    }
    let facts = pinned.expect("at least one run");
    Ok(CaseReport {
        id,
        kind,
        strategy,
        phases: PhaseMs {
            optimize: ms(median_duration(&mut t_opt)),
            lower: ms(median_duration(&mut t_low)),
            emit: ms(median_duration(&mut t_emit)),
        },
        adders: facts.adders,
        depth: facts.depth,
        lut: facts.lut,
        ff: facts.ff,
        stages: facts.stages,
        worst_stage_ns: facts.worst_stage_ns,
        cse: facts.cse,
        allocs_per_compile,
    })
}

/// Run the CSE stage (only) on each layer problem with one engine;
/// returns the accumulated counters and the finished per-layer
/// programs for the bit-identity check.
fn run_cse_engine(problems: &[CmvmProblem], reference: bool) -> (CseStats, Vec<DaisProgram>) {
    let cfg = CseConfig::default();
    let mut stats = CseStats::default();
    let mut programs = Vec::with_capacity(problems.len());
    for p in problems {
        let mut b = DaisBuilder::new();
        let inputs: Vec<InputTerm> = (0..p.d_in)
            .map(|j| InputTerm { node: b.input(j, p.input_qint[j], p.input_depth[j]) })
            .collect();
        // Fresh storage (`None` arena) on the indexed side: the A/B
        // measures the bitset engine layout itself, not arena warmth.
        let (outs, st) = if reference {
            cse::reference::optimize_into_stats(&mut b, &inputs, &p.matrix, p.d_in, p.d_out, &cfg)
        } else {
            cse::compile(&mut b, &inputs, &p.matrix, p.d_in, p.d_out, &cfg, None)
        };
        stats.absorb(&st);
        for o in &outs {
            match o.node {
                Some(n) => {
                    let n = if o.neg { b.neg(n) } else { n };
                    b.output(n, o.shift);
                }
                None => {
                    let z = b.constant(0);
                    b.output(z, 0);
                }
            }
        }
        programs.push(b.finish());
    }
    (stats, programs)
}

/// The engine A/B case: indexed vs reference CSE engine on the given
/// network's layer matrices (CSE stage only, so the measurement
/// isolates exactly the overhauled hot path).
pub fn engine_ab(runs: usize, case_id: &str, spec: &NetworkSpec) -> Result<EngineAb> {
    let problems = nn::compile::layer_problems(spec)?;
    ensure!(!problems.is_empty(), "engine A/B: network has no weight layers");
    let runs = runs.max(1);
    let mut t_idx = Vec::with_capacity(runs);
    let mut t_ref = Vec::with_capacity(runs);
    let mut programs_match = true;
    let mut pin: Option<(CseStats, CseStats)> = None;
    for run in 0..runs {
        let (d_i, (si, progs_i)) = time_once(|| run_cse_engine(&problems, false));
        let (d_r, (sr, progs_r)) = time_once(|| run_cse_engine(&problems, true));
        programs_match &= progs_i == progs_r;
        match pin {
            None => pin = Some((si, sr)),
            Some(prev) => ensure!(
                prev == (si, sr),
                "engine A/B ({case_id}): non-deterministic counters on repeat {run}"
            ),
        }
        t_idx.push(d_i);
        t_ref.push(d_r);
    }
    // The bit-identity is an engine invariant, not a tunable metric:
    // fail every consumer loudly (CLI without --baseline, the
    // optimizer_micro bench), not just the CI diff — which also gates
    // on the field for defense in depth.
    ensure!(
        programs_match,
        "engine A/B ({case_id}): indexed and reference engines emitted different \
         programs — the overhaul broke bit-identity (see cse::tests differential \
         sweep to localize)"
    );
    let (stats_idx, stats_ref) = pin.expect("at least one run");
    let indexed_ms = ms(median_duration(&mut t_idx));
    let reference_ms = ms(median_duration(&mut t_ref));
    Ok(EngineAb {
        case_id: case_id.to_string(),
        indexed_ms,
        reference_ms,
        speedup: reference_ms / indexed_ms.max(1e-6),
        programs_match,
        indexed: stats_idx,
        reference: stats_ref,
    })
}

/// The coordinator sharding A/B: bake one tiny job set cold into a
/// single-lock and an 8-shard coordinator (asserting bit-identical
/// programs), then hammer both warm from 4 threads and compare the
/// median wall-clock. Accounting is asserted exact on both
/// coordinators — every lookup a hit, nothing lost, nothing evicted —
/// so the timing can never paper over a correctness bug. Timings are
/// meaningless on a single-core host; the gate floors the speedup only
/// when the baseline pins `min_shard_speedup` (CI runs multi-core).
pub fn coordinator_shard(runs: usize, case_id: &str) -> Result<CoordinatorShardBench> {
    const THREADS: usize = 4;
    const SHARDS: usize = 8;
    const JOBS: usize = 24;
    const ROUNDS: usize = 64;
    let jobs: Vec<CompileJob> = (0..JOBS)
        .map(|i| CompileJob {
            name: format!("shard-bench/{i}"),
            problem: CmvmProblem::random(7100 + i as u64, 3, 3, 8),
            strategy: Strategy::Da { dc: SUITE_DC },
        })
        .collect();
    let runs = runs.max(1);

    let single = Coordinator::new();
    let sharded = Coordinator::with_shards(SHARDS);
    // Cold bake. Only the sharded pass is timed — cold compile time is
    // optimizer-dominated either way; the warm A/B below is the
    // contention measurement.
    let (d_cold, baked) = time_once(|| {
        jobs.iter()
            .map(|j| sharded.compile_cached(j))
            .collect::<Result<Vec<_>>>()
    });
    let baked = baked?;
    for (j, (sol, hit)) in jobs.iter().zip(&baked) {
        ensure!(!hit, "coordinator shard bench: cold pass must miss ({})", j.name);
        let (single_sol, single_hit) = single.compile_cached(j)?;
        ensure!(!single_hit, "coordinator shard bench: cold pass must miss ({})", j.name);
        ensure!(
            single_sol.program == sol.program,
            "coordinator shard bench: single-lock and sharded coordinators \
             produced different programs for {}",
            j.name
        );
    }

    let hammer = |coord: &Coordinator| {
        std::thread::scope(|scope| {
            for t in 0..THREADS {
                let jobs = &jobs;
                scope.spawn(move || {
                    for round in 0..ROUNDS {
                        for k in 0..jobs.len() {
                            // Offset the walk per thread and per round so
                            // threads collide on different keys (and thus
                            // different shards) at any instant.
                            let j = &jobs[(k + t * 7 + round) % jobs.len()];
                            coord.compile_cached(j).expect("warm lookup cannot fail");
                        }
                    }
                });
            }
        });
    };
    let mut t_single = Vec::with_capacity(runs);
    let mut t_sharded = Vec::with_capacity(runs);
    for _ in 0..runs {
        t_single.push(time_once(|| hammer(&single)).0);
        t_sharded.push(time_once(|| hammer(&sharded)).0);
    }

    // Exact accounting on both coordinators: JOBS misses, every warm
    // lookup a hit, zero evictions (uncapped) — no lost updates under
    // contention.
    let lookups = (runs * THREADS * ROUNDS * JOBS) as u64;
    for (name, coord) in [("single", &single), ("sharded", &sharded)] {
        let st = coord.stats();
        ensure!(
            st.submitted == lookups + JOBS as u64,
            "coordinator shard bench ({name}): submitted {} != {}",
            st.submitted,
            lookups + JOBS as u64
        );
        ensure!(
            st.cache_hits == lookups,
            "coordinator shard bench ({name}): lost updates — {} hits, want {lookups}",
            st.cache_hits
        );
        ensure!(
            st.evictions == 0 && coord.cache_len() == JOBS,
            "coordinator shard bench ({name}): cache corrupted — {} evictions, \
             {} entries (want 0 / {JOBS})",
            st.evictions,
            coord.cache_len()
        );
    }

    let single_warm_ms = ms(median_duration(&mut t_single));
    let sharded_warm_ms = ms(median_duration(&mut t_sharded));
    Ok(CoordinatorShardBench {
        case_id: case_id.to_string(),
        threads: THREADS,
        shards: SHARDS,
        jobs: JOBS,
        lookups,
        cold_ms: ms(d_cold),
        single_warm_ms,
        sharded_warm_ms,
        speedup: single_warm_ms / sharded_warm_ms.max(1e-6),
    })
}

/// Run the whole suite for a configuration.
pub fn run_suite(cfg: &PerfConfig) -> Result<SuiteReport> {
    let (jet_source, jet) = jet_spec();
    let mut cases = Vec::new();
    let mut skipped = Vec::new();

    // CMVM group: seeded random square matrices × all five strategies.
    for &m in cmvm_sizes(cfg.suite) {
        let problem = CmvmProblem::random(9000 + m as u64, m, m, 8);
        for (name, strategy) in strategies() {
            let id = format!("cmvm/{m}x{m}/{name}");
            if matches!(strategy, Strategy::Lookahead { .. }) && m > lookahead_cap(cfg.suite) {
                skipped.push(SkippedCase {
                    id,
                    reason: format!(
                        "lookahead is O(N^3) in the digit count; capped at \
                         {0}x{0} for the {1} suite",
                        lookahead_cap(cfg.suite),
                        cfg.suite.name()
                    ),
                });
                continue;
            }
            let p = &problem;
            cases.push(measure_case(cfg.runs, id, "cmvm", name, None, || {
                cmvm::compile(p, &OptimizeOptions::new(strategy)).map(|s| (s.program, s.cse))
            })?);
        }
    }

    // Network group: the jet network + scaled synthetic stand-ins,
    // fused end to end and pipelined like the `rtl` CLI flow.
    let mut nets: Vec<(String, NetworkSpec)> = vec![("jet".into(), jet.clone())];
    for &(num, den) in net_scales(cfg.suite) {
        let net_id = format!("jet-x{num}of{den}");
        if (num, den) == (1, 1) && jet_source == "synthetic" {
            // Without the exported artifact the jet case *is* the
            // seed-42 synthetic network, so the 1:1 scale would measure
            // byte-identical programs twice under a second id.
            skipped.push(SkippedCase {
                id: format!("net/{net_id}/*"),
                reason: "identical to net/jet/* when the jet artifact is absent \
                         (jet_source=synthetic)"
                    .into(),
            });
            continue;
        }
        nets.push((net_id, synthetic_jet_spec_scaled(num, den)));
    }
    for (net_id, spec) in &nets {
        for (name, strategy) in strategies() {
            let id = format!("net/{net_id}/{name}");
            match strategy {
                Strategy::Lookahead { .. } => {
                    skipped.push(SkippedCase {
                        id,
                        reason: "lookahead is O(N^3) in the digit count; never run on \
                                 full networks"
                            .into(),
                    });
                    continue;
                }
                Strategy::Latency => {
                    skipped.push(SkippedCase {
                        id,
                        reason: "the latency strategy fuses to the same graph as \
                                 naive-da (functional twin); timed once under naive-da"
                            .into(),
                    });
                    continue;
                }
                _ => {}
            }
            cases.push(measure_case(
                cfg.runs,
                id,
                "network",
                name,
                Some(PIPE_EVERY),
                || {
                    let opts = nn::compile::CompileOptions::new(strategy);
                    nn::compile::compile(spec, &opts).map(|c| (c.program, c.cse))
                },
            )?);
        }
    }

    let ab = engine_ab(cfg.runs, "jet/cse-stage", &jet)?;
    let coordinator = coordinator_shard(cfg.runs, "coordinator/shard-hammer")?;

    Ok(SuiteReport {
        schema_version: SCHEMA_VERSION,
        suite: cfg.suite.name(),
        jet_source,
        runs: cfg.runs,
        cases,
        engine_ab: ab,
        coordinator,
        skipped,
    })
}

/// Human-readable rendering of a suite report (the CLI and the
/// `optimizer_micro` bench print exactly this, so bench and CLI always
/// report the same numbers).
pub fn render_table(r: &SuiteReport) -> String {
    let mut table = Table::new(
        &format!(
            "perf suite '{}' (runs={}, jet={}, schema v{})",
            r.suite, r.runs, r.jet_source, r.schema_version
        ),
        &[
            "case",
            "opt[ms]",
            "lower[ms]",
            "emit[ms]",
            "adders",
            "depth",
            "LUT",
            "stages",
            "heap pops",
            "digit scans",
            "allocs",
        ],
    );
    for c in &r.cases {
        table.push(vec![
            c.id.clone(),
            sci(c.phases.optimize),
            sci(c.phases.lower),
            sci(c.phases.emit),
            c.adders.to_string(),
            c.depth.to_string(),
            c.lut.to_string(),
            c.stages.to_string(),
            c.cse.heap_pops.to_string(),
            c.cse.occ_digits_scanned.to_string(),
            c.allocs_per_compile.to_string(),
        ]);
    }
    let mut out = table.render();
    let ab = &r.engine_ab;
    out.push_str(&format!(
        "\nengine A/B ({}): indexed {} ms vs reference {} ms -> {:.2}x speedup; \
         programs bit-identical: {}; digit scans {} vs {}\n",
        ab.case_id,
        sci(ab.indexed_ms),
        sci(ab.reference_ms),
        ab.speedup,
        ab.programs_match,
        ab.indexed.occ_digits_scanned,
        ab.reference.occ_digits_scanned,
    ));
    let cs = &r.coordinator;
    out.push_str(&format!(
        "coordinator shard hammer ({}): {} threads x {} jobs warm, single-lock \
         {} ms vs {}-shard {} ms -> {:.2}x speedup (cold bake {} ms)\n",
        cs.case_id,
        cs.threads,
        cs.jobs,
        sci(cs.single_warm_ms),
        cs.shards,
        sci(cs.sharded_warm_ms),
        cs.speedup,
        sci(cs.cold_ms),
    ));
    for sk in &r.skipped {
        out.push_str(&format!("skipped: {} — {}\n", sk.id, sk.reason));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A tiny case through the full measure path (optimize + lower +
    /// emit, no pipelining): phases time, counters pin, ids stick.
    #[test]
    fn measure_case_cmvm_smoke() {
        let p = CmvmProblem::new(2, 2, vec![3, 5, -7, 9], 8).unwrap();
        let c = measure_case(2, "cmvm/2x2/da".into(), "cmvm", "da", None, || {
            cmvm::compile(&p, &OptimizeOptions::new(Strategy::Da { dc: -1 }))
                .map(|s| (s.program, s.cse))
        })
        .unwrap();
        assert_eq!(c.id, "cmvm/2x2/da");
        assert!(c.adders > 0);
        assert!(c.lut > 0);
        assert_eq!(c.stages, 0);
        assert!(c.phases.optimize >= 0.0);
    }

    /// A pipelined network case reports stage structure.
    #[test]
    fn measure_case_network_smoke() {
        let spec = synthetic_jet_spec_scaled(1, 8);
        let c = measure_case(1, "net/tiny/da".into(), "network", "da", Some(PIPE_EVERY), || {
            let opts = nn::compile::CompileOptions::new(Strategy::Da { dc: SUITE_DC });
            nn::compile::compile(&spec, &opts).map(|c| (c.program, c.cse))
        })
        .unwrap();
        assert!(c.stages > 0, "pipelined case must report stages");
        assert!(c.worst_stage_ns > 0.0);
        assert!(c.adders > 0);
    }

    /// The A/B harness on a down-scaled jet: programs must match
    /// bit-identically and the indexed engine must not scan more digits
    /// than the reference.
    #[test]
    fn engine_ab_tiny_jet() {
        let spec = synthetic_jet_spec_scaled(1, 8);
        let ab = engine_ab(1, "tiny/cse-stage", &spec).unwrap();
        assert!(ab.programs_match, "engines diverged");
        assert!(ab.indexed_ms > 0.0 && ab.reference_ms > 0.0);
        assert!(
            ab.indexed.occ_digits_scanned <= ab.reference.occ_digits_scanned,
            "index must bound the scan work: {} > {}",
            ab.indexed.occ_digits_scanned,
            ab.reference.occ_digits_scanned
        );
        assert_eq!(ab.indexed.steps, ab.reference.steps);
        assert_eq!(ab.indexed.heap_pops, ab.reference.heap_pops);
    }

    /// The contention A/B completes with exact accounting (the
    /// accounting ensures inside `coordinator_shard` are the real
    /// assertions; timings are not compared — this host may be
    /// single-core, the CI gate floors the speedup instead).
    #[test]
    fn coordinator_shard_bench_accounts_exactly() {
        let b = coordinator_shard(1, "tiny/coordinator-shard").unwrap();
        assert_eq!(b.case_id, "tiny/coordinator-shard");
        assert_eq!(b.threads, 4);
        assert_eq!(b.shards, 8);
        assert!(b.jobs > 0 && b.lookups > 0);
        assert!(b.single_warm_ms >= 0.0 && b.sharded_warm_ms >= 0.0);
        assert!(b.speedup > 0.0);
    }

    #[test]
    fn layer_problems_track_shapes() {
        let spec = synthetic_jet_spec_scaled(1, 4);
        let ps = nn::compile::layer_problems(&spec).unwrap();
        assert_eq!(ps.len(), 4);
        assert_eq!(ps[0].d_in, 4);
        assert_eq!(ps[0].d_out, 16);
        assert_eq!(ps[3].d_out, 5);
    }
}
