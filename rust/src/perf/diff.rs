//! Baseline comparison for the perf regression gate.
//!
//! Semantics (documented in `docs/perf.md`):
//!
//! * the baseline's `schema_version` must equal the binary's
//!   [`super::SCHEMA_VERSION`] — a mismatch is a regression (the gate
//!   cannot interpret the pins);
//! * the engine A/B check always gates `programs_match`, and gates the
//!   measured speedup when the baseline carries `min_speedup` (the
//!   ratio is same-machine relative, so it ports across CI hosts);
//! * a counter pinned by a baseline case must match **exactly** — the
//!   optimizer is deterministic, so any drift is a behavior change;
//! * a time pinned by a baseline case may grow by at most
//!   `time_tolerance` (relative), with a 1 ms absolute jitter floor;
//! * `max_allocs_per_compile` (when the baseline carries it) is a
//!   ceiling on every case's measured `allocs_per_compile` — it only
//!   gates when the run actually measured allocations (the counting
//!   allocator is installed and some case reported > 0);
//! * a pinned case missing from the run is a regression (coverage
//!   loss); a run case absent from the baseline is only a note.

use super::schema::Baseline;
use super::{CaseReport, SuiteReport, SCHEMA_VERSION};

/// The gate's verdict: regressions fail CI, notes are informational.
#[derive(Debug, Clone, Default)]
pub struct DiffOutcome {
    /// Human-readable regression descriptions; empty = gate passes.
    pub regressions: Vec<String>,
    /// Informational findings (unpinned cases, unknown keys, …).
    pub notes: Vec<String>,
    /// Number of metrics actually compared.
    pub checked: usize,
}

impl DiffOutcome {
    /// True when no regression was found.
    pub fn passed(&self) -> bool {
        self.regressions.is_empty()
    }
}

fn counter_metric(c: &CaseReport, key: &str) -> Option<i64> {
    Some(match key {
        "adders" => c.adders as i64,
        "depth" => c.depth as i64,
        "lut" => c.lut as i64,
        "ff" => c.ff as i64,
        "stages" => c.stages as i64,
        "cse_steps" => c.cse.steps as i64,
        "depth_rejections" => c.cse.depth_rejections as i64,
        "heap_pops" => c.cse.heap_pops as i64,
        "stale_pops" => c.cse.stale_pops as i64,
        "occ_cols_scanned" => c.cse.occ_cols_scanned as i64,
        "occ_digits_scanned" => c.cse.occ_digits_scanned as i64,
        _ => return None,
    })
}

fn time_metric(c: &CaseReport, key: &str) -> Option<f64> {
    Some(match key {
        "optimize_ms" => c.phases.optimize,
        "lower_ms" => c.phases.lower,
        "emit_ms" => c.phases.emit,
        _ => return None,
    })
}

/// Compare a fresh run against a parsed baseline.
pub fn against_baseline(report: &SuiteReport, baseline: &Baseline) -> DiffOutcome {
    let mut out = DiffOutcome::default();

    if baseline.schema_version != SCHEMA_VERSION as i64 {
        out.regressions.push(format!(
            "baseline schema_version {} does not match this binary's {} — \
             re-bless the baseline",
            baseline.schema_version, SCHEMA_VERSION
        ));
        return out;
    }
    if baseline.bootstrap {
        out.notes.push(
            "baseline is a bootstrap stub (no pinned cases yet); gate covers the \
             engine A/B only — bless a full baseline with \
             `da4ml perf --smoke --bless ci/bench_baseline.json`"
                .into(),
        );
    }
    // The net/jet/* counters depend on which jet network was measured;
    // gate the provenance so an artifact-presence mismatch is reported
    // as such instead of as inexplicable counter drift.
    if let Some(src) = &baseline.jet_source {
        out.checked += 1;
        if *src != report.jet_source {
            out.regressions.push(format!(
                "jet_source mismatch: baseline was blessed against '{src}' but this \
                 run measured '{}' (net/jet/* pins are not comparable; re-bless on a \
                 machine with the same artifact availability)",
                report.jet_source
            ));
            return out;
        }
    }

    // Engine A/B: correctness always, speedup when the baseline pins it.
    out.checked += 1;
    if !report.engine_ab.programs_match {
        out.regressions.push(
            "engine A/B: indexed and reference engines emitted different programs"
                .into(),
        );
    }
    if let Some(min) = baseline.min_speedup {
        out.checked += 1;
        if report.engine_ab.speedup < min {
            out.regressions.push(format!(
                "engine A/B speedup {:.2}x (indexed {:.3} ms vs reference {:.3} ms) \
                 is below the required {:.2}x",
                report.engine_ab.speedup,
                report.engine_ab.indexed_ms,
                report.engine_ab.reference_ms,
                min
            ));
        }
    }
    // Allocation ceiling: the arena overhaul's headline number. Gated
    // only when this run measured allocations at all — a binary without
    // the counting global allocator reports 0 everywhere, which must
    // read as "not measured", never as "zero-allocation compile".
    if let Some(cap) = baseline.max_allocs_per_compile {
        let measured = report.cases.iter().any(|c| c.allocs_per_compile > 0);
        if !measured {
            out.notes.push(
                "baseline pins max_allocs_per_compile but this run measured no \
                 allocations (counting allocator not installed); ceiling skipped"
                    .into(),
            );
        } else {
            for c in &report.cases {
                out.checked += 1;
                if c.allocs_per_compile as i64 > cap {
                    out.regressions.push(format!(
                        "{}: allocs_per_compile {} exceeds the baseline ceiling {cap} — \
                         allocation churn regressed (arena reuse lost?)",
                        c.id, c.allocs_per_compile
                    ));
                }
            }
        }
    }
    // Coordinator shard hammer: gated only when the baseline pins the
    // floor (single-core hosts cannot beat a single lock, so the stub
    // and locally blessed baselines may omit it).
    if let Some(min) = baseline.min_shard_speedup {
        out.checked += 1;
        if report.coordinator.speedup < min {
            out.regressions.push(format!(
                "coordinator shard speedup {:.2}x (single-lock {:.3} ms vs \
                 {}-shard {:.3} ms under {} threads) is below the required {:.2}x",
                report.coordinator.speedup,
                report.coordinator.single_warm_ms,
                report.coordinator.shards,
                report.coordinator.sharded_warm_ms,
                report.coordinator.threads,
                min
            ));
        }
    }

    for bc in &baseline.cases {
        let Some(rc) = report.cases.iter().find(|c| c.id == bc.id) else {
            out.regressions.push(format!(
                "case '{}' is pinned by the baseline but missing from the run",
                bc.id
            ));
            continue;
        };
        for (key, want) in &bc.counters {
            out.checked += 1;
            match counter_metric(rc, key) {
                Some(got) if got == *want => {}
                Some(got) => out.regressions.push(format!(
                    "{}: {key} = {got} but baseline pins {want} — deterministic \
                     counter drifted (behavior change; re-bless if intended)",
                    bc.id
                )),
                None => out
                    .notes
                    .push(format!("{}: unknown counter '{key}' in baseline", bc.id)),
            }
        }
        for (key, want) in &bc.times_ms {
            out.checked += 1;
            let Some(got) = time_metric(rc, key) else {
                out.notes
                    .push(format!("{}: unknown time metric '{key}' in baseline", bc.id));
                continue;
            };
            let limit = want * (1.0 + baseline.time_tolerance);
            // 1 ms absolute floor: sub-millisecond phases jitter more
            // than any tolerance can meaningfully bound.
            if got > limit && got - want > 1.0 {
                out.regressions.push(format!(
                    "{}: {key} {got:.3} ms exceeds baseline {want:.3} ms \
                     (+{:.0}% tolerance)",
                    bc.id,
                    baseline.time_tolerance * 100.0
                ));
            }
        }
    }

    if !baseline.cases.is_empty() {
        for rc in &report.cases {
            if baseline.cases.iter().all(|b| b.id != rc.id) {
                out.notes
                    .push(format!("case '{}' is not pinned by the baseline", rc.id));
            }
        }
    }

    out
}

#[cfg(test)]
mod tests {
    use super::super::schema::{parse_baseline, render_baseline};
    use super::super::{CoordinatorShardBench, EngineAb, PhaseMs, SuiteReport};
    use super::*;
    use crate::cse::CseStats;

    fn report() -> SuiteReport {
        SuiteReport {
            schema_version: SCHEMA_VERSION,
            suite: "smoke",
            jet_source: "synthetic".into(),
            runs: 3,
            cases: vec![CaseReport {
                id: "cmvm/8x8/da".into(),
                kind: "cmvm",
                strategy: "da",
                phases: PhaseMs { optimize: 10.0, lower: 1.0, emit: 0.5 },
                adders: 50,
                depth: 6,
                lut: 500,
                ff: 128,
                stages: 0,
                worst_stage_ns: 3.0,
                cse: CseStats {
                    steps: 12,
                    depth_rejections: 1,
                    heap_pops: 90,
                    stale_pops: 40,
                    occ_cols_scanned: 70,
                    occ_digits_scanned: 300,
                },
                allocs_per_compile: 900,
            }],
            engine_ab: EngineAb {
                case_id: "jet/cse-stage".into(),
                indexed_ms: 10.0,
                reference_ms: 20.0,
                speedup: 2.0,
                programs_match: true,
                indexed: CseStats::default(),
                reference: CseStats::default(),
            },
            coordinator: CoordinatorShardBench {
                case_id: "coordinator/shard-hammer".into(),
                threads: 4,
                shards: 8,
                jobs: 24,
                lookups: 6144,
                cold_ms: 12.0,
                single_warm_ms: 4.0,
                sharded_warm_ms: 2.0,
                speedup: 2.0,
            },
            skipped: vec![],
        }
    }

    /// Self-consistency: a report always passes against the baseline
    /// blessed from itself (with and without times).
    #[test]
    fn self_blessed_baseline_passes() {
        let r = report();
        for with_times in [false, true] {
            let b = parse_baseline(&render_baseline(&r, with_times)).unwrap();
            let d = against_baseline(&r, &b);
            assert!(d.passed(), "regressions: {:?}", d.regressions);
            assert!(d.checked > 2);
        }
    }

    #[test]
    fn counter_drift_is_a_regression() {
        let r = report();
        let b = parse_baseline(&render_baseline(&r, false)).unwrap();
        let mut drifted = r.clone();
        drifted.cases[0].adders = 51;
        let d = against_baseline(&drifted, &b);
        assert!(!d.passed());
        assert!(d.regressions[0].contains("adders"), "{:?}", d.regressions);
    }

    #[test]
    fn time_regression_respects_tolerance_and_floor() {
        let r = report();
        let b = parse_baseline(&render_baseline(&r, true)).unwrap();
        // +40% on a 10ms phase: within the +50% tolerance.
        let mut ok = r.clone();
        ok.cases[0].phases.optimize = 14.0;
        assert!(against_baseline(&ok, &b).passed());
        // +100%: over tolerance and over the 1ms floor.
        let mut slow = r.clone();
        slow.cases[0].phases.optimize = 20.0;
        let d = against_baseline(&slow, &b);
        assert!(!d.passed());
        assert!(d.regressions[0].contains("optimize_ms"));
        // A sub-millisecond phase can double without tripping the floor.
        let mut jitter = r.clone();
        jitter.cases[0].phases.emit = 1.2;
        assert!(against_baseline(&jitter, &b).passed());
    }

    #[test]
    fn speedup_floor_and_program_mismatch_gate() {
        let r = report();
        let b = parse_baseline(&render_baseline(&r, false)).unwrap();
        let mut slow = r.clone();
        slow.engine_ab.speedup = 1.1;
        let d = against_baseline(&slow, &b);
        assert!(!d.passed());
        assert!(d.regressions[0].contains("speedup"));

        let mut diverged = r.clone();
        diverged.engine_ab.programs_match = false;
        assert!(!against_baseline(&diverged, &b).passed());
    }

    /// The shard-speedup floor gates only when the baseline pins it —
    /// and a blessed baseline does pin it.
    #[test]
    fn shard_speedup_floor_gates_when_pinned() {
        let r = report();
        let b = parse_baseline(&render_baseline(&r, false)).unwrap();
        assert!(b.min_shard_speedup.is_some());
        let mut slow = r.clone();
        slow.coordinator.speedup = 0.9;
        let d = against_baseline(&slow, &b);
        assert!(!d.passed());
        assert!(
            d.regressions[0].contains("coordinator shard speedup"),
            "{:?}",
            d.regressions
        );

        // Without the key the case is informational only.
        let stub = r#"{"schema_version": 1, "bootstrap": true, "cases": []}"#;
        let unpinned = parse_baseline(stub).unwrap();
        assert!(against_baseline(&slow, &unpinned).passed());
    }

    /// The allocation ceiling gates measured runs, skips unmeasured
    /// ones (all-zero counts), and trips on churn above the cap.
    #[test]
    fn alloc_ceiling_gates_only_measured_runs() {
        let r = report();
        let b = parse_baseline(&render_baseline(&r, false)).unwrap();
        assert_eq!(b.max_allocs_per_compile, Some(1800), "2x the measured 900");

        // Within the ceiling: passes.
        assert!(against_baseline(&r, &b).passed());

        // Churn above the ceiling: regression.
        let mut churny = r.clone();
        churny.cases[0].allocs_per_compile = 5000;
        let d = against_baseline(&churny, &b);
        assert!(!d.passed());
        assert!(
            d.regressions[0].contains("allocs_per_compile"),
            "{:?}",
            d.regressions
        );

        // All-zero run (allocator not installed): skipped with a note,
        // even though 0 < cap would trivially pass.
        let mut unmeasured = r.clone();
        unmeasured.cases[0].allocs_per_compile = 0;
        let d = against_baseline(&unmeasured, &b);
        assert!(d.passed());
        assert!(
            d.notes.iter().any(|n| n.contains("counting allocator")),
            "{:?}",
            d.notes
        );
    }

    #[test]
    fn jet_source_mismatch_is_a_regression() {
        let r = report();
        let b = parse_baseline(&render_baseline(&r, false)).unwrap();
        assert_eq!(b.jet_source.as_deref(), Some("synthetic"));
        let mut artifact_run = r.clone();
        artifact_run.jet_source = "artifact".into();
        let d = against_baseline(&artifact_run, &b);
        assert!(!d.passed());
        assert!(d.regressions[0].contains("jet_source"), "{:?}", d.regressions);
    }

    #[test]
    fn missing_pinned_case_is_a_regression() {
        let r = report();
        let b = parse_baseline(&render_baseline(&r, false)).unwrap();
        let mut empty = r.clone();
        empty.cases.clear();
        let d = against_baseline(&empty, &b);
        assert!(!d.passed());
        assert!(d.regressions[0].contains("missing from the run"));
    }

    #[test]
    fn schema_mismatch_is_a_regression() {
        let r = report();
        let mut b = parse_baseline(&render_baseline(&r, false)).unwrap();
        b.schema_version = 999;
        let d = against_baseline(&r, &b);
        assert!(!d.passed());
        assert!(d.regressions[0].contains("schema_version"));
    }

    #[test]
    fn bootstrap_baseline_gates_ab_only() {
        let r = report();
        let stub = r#"{"schema_version": 1, "bootstrap": true, "min_speedup": 1.25, "cases": []}"#;
        let b = parse_baseline(stub).unwrap();
        let d = against_baseline(&r, &b);
        assert!(d.passed());
        assert!(d.notes.iter().any(|n| n.contains("bootstrap")));

        let mut slow = r;
        slow.engine_ab.speedup = 1.0;
        assert!(!against_baseline(&slow, &b).passed());
    }
}
