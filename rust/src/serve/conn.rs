//! Per-connection plumbing for the socket server: a line reader over a
//! reused byte buffer, and the ordered reply pipeline.
//!
//! [`LineReader`] follows the bytes-backed-value idiom the streaming
//! JSON layer is built on: one rolling `Vec<u8>` per connection,
//! newline scanning in place, and `&[u8]` line slices handed straight
//! to [`crate::serve::Request::from_json_bytes`] — a hot connection
//! never allocates a line `String`. Oversized lines (no newline within
//! the configured bound) are detected without buffering them.
//!
//! [`Conn`] is the reply side: jobs from one connection may complete
//! out of order on the shared worker pool, so the reader stamps every
//! accepted line with a monotonically increasing sequence number and
//! [`Conn::complete`] buffers out-of-order replies until their turn,
//! writing each client's replies in its own submission order. The same
//! structure carries the per-connection backpressure bound (the reader
//! blocks in [`Conn::wait_capacity`] once too many of its jobs are in
//! flight, which the kernel socket buffer turns into sender-side
//! backpressure) and the per-client counters behind the `client_*`
//! stats fields.

use super::core::{self, JobTiming};
use crate::json::{self, Value};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::ops::Range;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex};

/// What [`LineReader::next_line`] yielded.
pub(crate) enum NextLine {
    /// One complete line: index range into [`LineReader::slice`]
    /// (trailing `\n`/`\r\n` stripped). Valid until the next call.
    Line(Range<usize>),
    /// A line exceeded the size bound. The offending bytes were
    /// discarded (the reader keeps consuming until the newline); the
    /// caller decides whether to keep reading or tear down.
    Oversized,
    /// End of stream (a final unterminated line, if any, was yielded
    /// as a `Line` first).
    Eof,
}

/// A newline-delimited reader over one reused, rolling byte buffer.
pub(crate) struct LineReader<R> {
    inner: R,
    buf: Vec<u8>,
    /// Start of the unconsumed region in `buf`.
    start: usize,
    /// End of the valid region in `buf`.
    end: usize,
    max_line: usize,
    /// Mid-discard of an oversized line: drop bytes until its newline.
    discarding: bool,
}

const READ_CHUNK: usize = 8 * 1024;

impl<R: Read> LineReader<R> {
    pub(crate) fn new(inner: R, max_line: usize) -> Self {
        Self {
            inner,
            buf: vec![0u8; READ_CHUNK],
            start: 0,
            end: 0,
            max_line: max_line.max(1),
            discarding: false,
        }
    }

    /// The bytes of a [`NextLine::Line`] range.
    pub(crate) fn slice(&self, range: Range<usize>) -> &[u8] {
        &self.buf[range]
    }

    /// Pull the next complete line (or EOF / oversized marker). Blocks
    /// on the underlying read.
    pub(crate) fn next_line(&mut self) -> std::io::Result<NextLine> {
        loop {
            // Scan the unconsumed region for a newline.
            if let Some(pos) = self.buf[self.start..self.end].iter().position(|&b| b == b'\n') {
                let line_start = self.start;
                let mut line_end = line_start + pos;
                self.start = line_end + 1;
                if self.discarding {
                    // Tail end of an already-reported oversized line.
                    self.discarding = false;
                    continue;
                }
                if line_end - line_start > self.max_line {
                    // The whole line arrived in one read but is still
                    // over the bound (already consumed, so no discard
                    // protocol needed).
                    return Ok(NextLine::Oversized);
                }
                if line_end > line_start && self.buf[line_end - 1] == b'\r' {
                    line_end -= 1;
                }
                return Ok(NextLine::Line(line_start..line_end));
            }
            let pending = self.end - self.start;
            if pending > self.max_line {
                // No newline within the bound: discard what is
                // buffered and keep discarding until the newline.
                self.start = self.end;
                if self.discarding {
                    continue;
                }
                self.discarding = true;
                return Ok(NextLine::Oversized);
            }
            // Compact the partial line to the front, then refill.
            if self.start > 0 {
                self.buf.copy_within(self.start..self.end, 0);
                self.end -= self.start;
                self.start = 0;
            }
            if self.end == self.buf.len() {
                // Linear growth is enough: the oversized check above
                // fires before the buffer can exceed
                // `max_line + READ_CHUNK` bytes of pending data.
                let grown = self.buf.len() + READ_CHUNK;
                self.buf.resize(grown, 0);
            }
            let n = match self.inner.read(&mut self.buf[self.end..]) {
                Ok(n) => n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            };
            if n == 0 {
                if self.discarding {
                    self.discarding = false;
                    self.start = self.end;
                    return Ok(NextLine::Eof);
                }
                if pending == 0 {
                    return Ok(NextLine::Eof);
                }
                // Final unterminated line: yield it, EOF on next call.
                let range = self.start..self.end;
                self.start = self.end;
                return Ok(NextLine::Line(range));
            }
            self.end += n;
        }
    }
}

/// One reply waiting in (or passing through) the resequencing buffer.
pub(crate) enum Reply {
    /// A rendered line, written verbatim at its turn. Every untimed
    /// reply takes this path, so its bytes are fixed the moment the
    /// job finishes — resequencing cannot perturb them.
    Ready(String),
    /// A `"timing": true` job's reply: kept as a [`Value`] and
    /// rendered at drain time, when the write-wait (time spent parked
    /// behind earlier replies) is known and can be injected into the
    /// `"timing"` object.
    Timed {
        /// The built reply object, without its `"timing"` key yet.
        reply: Value,
        /// Stage timings measured so far (`write_wait_us` still 0).
        timing: JobTiming,
        /// Clock at job completion — write wait is measured from here.
        completed_us: u64,
    },
}

/// How a reply line should be counted — the one place the per-client
/// and global accounting can't drift apart.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum ReplyKind {
    /// A `result`/`explore` reply; `cache_hit` feeds the per-client
    /// cache-hit counter.
    Result { cache_hit: bool },
    /// An error reply for a job that executed and failed.
    JobError,
    /// An error reply for a line that never became a job (malformed,
    /// non-UTF-8, oversized).
    WireError,
    /// A `busy` rejection from global admission control.
    Busy,
    /// A `shutting_down` rejection while draining.
    ShuttingDown,
    /// A control acknowledgement (stats line): not counted as a reply.
    Control,
}

/// Per-client reply counters (snapshot for the stats line).
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct ConnCounters {
    pub jobs: u64,
    pub replies: u64,
    pub errors: u64,
    pub rejected_busy: u64,
    pub cache_hits: u64,
    /// Lowest and highest sequence number of any *executed* job on
    /// this connection — the `trace_ids` range on the final stats
    /// line (`<client>#<lo>..<client>#<hi>`). `None` until a job ran.
    pub job_seq_range: Option<(u64, u64)>,
}

struct ConnInner {
    /// Write half of the socket. `None` once the connection is dead.
    writer: Option<Box<dyn Write + Send>>,
    /// Next sequence number whose reply goes on the wire.
    next_write: u64,
    /// Replies that completed ahead of their turn.
    pending: BTreeMap<u64, Reply>,
    /// This connection's accepted-but-unanswered jobs.
    inflight: usize,
    counters: ConnCounters,
}

/// The shared reply side of one connection (reader thread + workers).
pub(crate) struct Conn {
    /// Client label on stats lines: `client-<n>` in accept order.
    pub(crate) name: String,
    inner: Mutex<ConnInner>,
    cv: Condvar,
    dead: AtomicBool,
}

impl Conn {
    pub(crate) fn new(name: String, writer: Box<dyn Write + Send>) -> Self {
        Self {
            name,
            inner: Mutex::new(ConnInner {
                writer: Some(writer),
                next_write: 0,
                pending: BTreeMap::new(),
                inflight: 0,
                counters: ConnCounters::default(),
            }),
            cv: Condvar::new(),
            dead: AtomicBool::new(false),
        }
    }

    /// A dead connection stops reading and writing; its remaining
    /// replies are discarded (but still accounted, so the shared queue
    /// and global inflight never wedge).
    pub(crate) fn is_dead(&self) -> bool {
        self.dead.load(Ordering::SeqCst)
    }

    /// Mark dead and wake every waiter. Idempotent.
    pub(crate) fn mark_dead(&self) {
        self.dead.store(true, Ordering::SeqCst);
        let mut g = self.inner.lock().unwrap();
        g.writer = None;
        g.pending.clear();
        drop(g);
        self.cv.notify_all();
    }

    /// Reader side: block until this connection has capacity for one
    /// more in-flight job, the server starts draining, or the
    /// connection dies. Returns `true` when the job may be enqueued.
    pub(crate) fn wait_capacity(&self, cap: usize, draining: &AtomicBool) -> bool {
        let mut g = self.inner.lock().unwrap();
        while g.inflight >= cap.max(1)
            && !self.is_dead()
            && !draining.load(Ordering::SeqCst)
        {
            g = self.cv.wait(g).unwrap();
        }
        !self.is_dead() && !draining.load(Ordering::SeqCst)
    }

    /// Reader side: account one accepted job before enqueueing it.
    pub(crate) fn begin_job(&self) {
        self.inner.lock().unwrap().inflight += 1;
    }

    /// Worker side: account one finished job (its reply already went
    /// through [`Conn::complete`]).
    pub(crate) fn job_done(&self) {
        let mut g = self.inner.lock().unwrap();
        g.inflight = g.inflight.saturating_sub(1);
        drop(g);
        self.cv.notify_all();
    }

    /// Deliver the reply for sequence number `seq`. Out-of-order
    /// completions are buffered; everything consecutive from the next
    /// expected sequence number is written in one pass, so each
    /// client's replies leave in its own submission order. Returns the
    /// number of sequenced lines drained to the wire in this pass
    /// (the `--stats-every` cadence counter).
    pub(crate) fn complete(&self, seq: u64, reply: Reply, kind: ReplyKind) -> u64 {
        let mut g = self.inner.lock().unwrap();
        match kind {
            ReplyKind::Result { cache_hit } => {
                g.counters.jobs += 1;
                if cache_hit {
                    g.counters.cache_hits += 1;
                }
            }
            ReplyKind::JobError => {
                g.counters.jobs += 1;
                g.counters.errors += 1;
            }
            ReplyKind::WireError => g.counters.errors += 1,
            ReplyKind::Busy => {
                g.counters.errors += 1;
                g.counters.rejected_busy += 1;
            }
            ReplyKind::ShuttingDown => g.counters.errors += 1,
            ReplyKind::Control => {}
        }
        if matches!(kind, ReplyKind::Result { .. } | ReplyKind::JobError) {
            g.counters.job_seq_range = Some(match g.counters.job_seq_range {
                None => (seq, seq),
                Some((lo, hi)) => (lo.min(seq), hi.max(seq)),
            });
        }
        g.pending.insert(seq, reply);
        let mut wrote = 0u64;
        while let Some(reply) = g.pending.remove(&g.next_write) {
            g.next_write += 1;
            let line = match reply {
                Reply::Ready(line) => line,
                Reply::Timed { reply, mut timing, completed_us } => {
                    let mut reply = reply;
                    timing.write_wait_us = crate::obs::now_us().saturating_sub(completed_us);
                    core::inject_timing(&mut reply, &timing);
                    json::to_string(&reply)
                }
            };
            // The reply is drained whether or not the socket is still
            // writable: the job was accepted and answered, and the
            // accounting must not depend on the client sticking around.
            wrote += 1;
            let mut failed = false;
            if let Some(w) = g.writer.as_mut() {
                if writeln!(w, "{line}").and_then(|()| w.flush()).is_err() {
                    failed = true;
                }
            }
            if failed {
                g.writer = None;
                g.pending.clear();
                self.dead.store(true, Ordering::SeqCst);
            }
        }
        // `replies` counts countable lines only; `wrote` above may
        // include buffered control acks drained in the same pass, so
        // recount from the kind of *this* completion plus what drained.
        if kind != ReplyKind::Control {
            g.counters.replies += 1;
        }
        drop(g);
        if wrote > 0 {
            self.cv.notify_all();
        }
        wrote
    }

    /// Direct, unsequenced write (periodic and final stats lines).
    /// Returns `false` if the connection is no longer writable.
    pub(crate) fn write_line(&self, line: &str) -> bool {
        let mut g = self.inner.lock().unwrap();
        let Some(w) = g.writer.as_mut() else { return false };
        if writeln!(w, "{line}").and_then(|()| w.flush()).is_err() {
            g.writer = None;
            g.pending.clear();
            self.dead.store(true, Ordering::SeqCst);
            drop(g);
            self.cv.notify_all();
            return false;
        }
        true
    }

    /// Reader side at teardown: block until every accepted job has
    /// been answered (or the connection died).
    pub(crate) fn wait_idle(&self) {
        let mut g = self.inner.lock().unwrap();
        while (g.inflight > 0 || !g.pending.is_empty()) && !self.is_dead() {
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Wake any thread blocked in [`Conn::wait_capacity`] /
    /// [`Conn::wait_idle`] so it re-checks external state (the server
    /// calls this on every live connection when a drain starts).
    pub(crate) fn notify(&self) {
        self.cv.notify_all();
    }

    /// Snapshot the per-client counters for a stats line.
    pub(crate) fn counters(&self) -> ConnCounters {
        self.inner.lock().unwrap().counters
    }

    /// Close the write half (the final stats line has been written).
    pub(crate) fn close_writer(&self) {
        let mut g = self.inner.lock().unwrap();
        g.writer = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;
    use std::sync::Arc;

    fn lines_of(reader: &mut LineReader<Cursor<Vec<u8>>>) -> Vec<String> {
        let mut out = Vec::new();
        loop {
            match reader.next_line().unwrap() {
                NextLine::Line(r) => {
                    out.push(String::from_utf8_lossy(reader.slice(r)).into_owned())
                }
                NextLine::Oversized => out.push("<oversized>".into()),
                NextLine::Eof => return out,
            }
        }
    }

    #[test]
    fn line_reader_splits_reuses_and_handles_partials() {
        let data = b"alpha\nbeta\r\n\ngamma".to_vec();
        let mut r = LineReader::new(Cursor::new(data), 1 << 20);
        assert_eq!(lines_of(&mut r), vec!["alpha", "beta", "", "gamma"]);
    }

    #[test]
    fn line_reader_detects_oversized_lines_without_buffering_them() {
        let mut data = vec![b'x'; 4096];
        data.push(b'\n');
        data.extend_from_slice(b"ok\n");
        let mut r = LineReader::new(Cursor::new(data), 64);
        assert_eq!(lines_of(&mut r), vec!["<oversized>", "ok"]);
    }

    #[test]
    fn line_reader_oversized_at_eof_without_newline() {
        let data = vec![b'y'; 4096];
        let mut r = LineReader::new(Cursor::new(data), 64);
        assert_eq!(lines_of(&mut r), vec!["<oversized>"]);
    }

    /// Out-of-order completions leave in submission order, with the
    /// counters attributing each kind correctly.
    #[test]
    fn conn_orders_replies_and_counts_kinds() {
        let sink = Arc::new(Mutex::new(Vec::<u8>::new()));
        struct SharedSink(Arc<Mutex<Vec<u8>>>);
        impl Write for SharedSink {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(buf);
                Ok(buf.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let conn = Conn::new("client-0".into(), Box::new(SharedSink(sink.clone())));
        for _ in 0..3 {
            conn.begin_job();
        }
        conn.complete(2, Reply::Ready("r2".into()), ReplyKind::Result { cache_hit: true });
        conn.job_done();
        assert_eq!(sink.lock().unwrap().len(), 0, "seq 2 must wait for 0 and 1");
        conn.complete(0, Reply::Ready("r0".into()), ReplyKind::Result { cache_hit: false });
        conn.job_done();
        conn.complete(1, Reply::Ready("e1".into()), ReplyKind::JobError);
        conn.job_done();
        conn.wait_idle();
        let text = String::from_utf8(sink.lock().unwrap().clone()).unwrap();
        assert_eq!(text, "r0\ne1\nr2\n");
        let c = conn.counters();
        assert_eq!((c.jobs, c.replies, c.errors, c.cache_hits), (3, 3, 1, 1));
        assert_eq!(c.job_seq_range, Some((0, 2)), "trace-id range spans executed jobs");
    }

    /// A timed reply is rendered at drain time with its `"timing"`
    /// object injected, so the write wait covers the whole park behind
    /// earlier replies.
    #[test]
    fn timed_replies_render_with_timing_at_drain() {
        let sink = Arc::new(Mutex::new(Vec::<u8>::new()));
        struct SharedSink(Arc<Mutex<Vec<u8>>>);
        impl Write for SharedSink {
            fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(buf);
                Ok(buf.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let conn = Conn::new("client-0".into(), Box::new(SharedSink(sink.clone())));
        conn.begin_job();
        conn.begin_job();
        let mut o = BTreeMap::new();
        o.insert("id".to_string(), Value::Str("t".into()));
        o.insert("type".to_string(), Value::Str("result".into()));
        let timed = Reply::Timed {
            reply: Value::Object(o),
            timing: JobTiming {
                trace_id: "client-0#1".into(),
                decode_us: 3,
                queue_wait_us: 5,
                exec_us: 7,
                write_wait_us: 0,
            },
            completed_us: 0,
        };
        // Seq 1 completes first: it parks behind seq 0 and renders
        // only when seq 0 unblocks the drain.
        conn.complete(1, timed, ReplyKind::Result { cache_hit: false });
        conn.job_done();
        assert_eq!(sink.lock().unwrap().len(), 0, "seq 1 must wait for 0");
        conn.complete(0, Reply::Ready("r0".into()), ReplyKind::Result { cache_hit: false });
        conn.job_done();
        conn.wait_idle();
        let text = String::from_utf8(sink.lock().unwrap().clone()).unwrap();
        let timed_line = text.lines().nth(1).unwrap();
        let v = json::parse(timed_line).unwrap();
        let t = v.get("timing").unwrap();
        assert_eq!(t.get("decode_us").unwrap().as_i64().unwrap(), 3);
        assert_eq!(t.get("queue_wait_us").unwrap().as_i64().unwrap(), 5);
        assert_eq!(t.get("exec_us").unwrap().as_i64().unwrap(), 7);
        assert_eq!(t.get("trace_id").unwrap().as_str().unwrap(), "client-0#1");
        assert!(t.get("write_wait_us").unwrap().as_i64().unwrap() >= 0);
    }

    /// A failing writer marks the connection dead; later completions
    /// still drain (keeping global accounting honest) but write nothing.
    #[test]
    fn conn_write_failure_is_clean_death_not_a_wedge() {
        struct FailingSink;
        impl Write for FailingSink {
            fn write(&mut self, _buf: &[u8]) -> std::io::Result<usize> {
                Err(std::io::Error::new(std::io::ErrorKind::BrokenPipe, "gone"))
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let conn = Conn::new("client-0".into(), Box::new(FailingSink));
        conn.begin_job();
        conn.begin_job();
        conn.complete(0, Reply::Ready("r0".into()), ReplyKind::Result { cache_hit: false });
        conn.job_done();
        assert!(conn.is_dead());
        // The second completion must not block or panic.
        conn.complete(1, Reply::Ready("r1".into()), ReplyKind::Result { cache_hit: false });
        conn.job_done();
        conn.wait_idle();
        assert!(!conn.write_line("stats"));
    }
}
