//! The long-lived socket compile server (`da4ml serve --socket`).
//!
//! A [`Server`] listens on a Unix domain socket (always) and optionally
//! a TCP address (`--listen host:port`), serving many concurrent JSONL
//! connections over one shared [`Coordinator`]:
//!
//! * **One reader thread per connection** pulls newline-delimited
//!   requests out of a reused byte buffer (the private `conn`
//!   submodule's line reader), lowers them through the shared serve
//!   core, and enqueues executable jobs on the shared queue.
//! * **A fixed worker pool** ([`ServerConfig::workers`]) pops jobs and
//!   runs them against the coordinator — the sharded solution cache
//!   makes concurrent clients each other's cache warmers.
//! * **Backpressure** is two-level: each connection may only have
//!   [`ServerConfig::conn_inflight`] jobs in flight (its reader blocks,
//!   which the kernel socket buffer turns into sender-side
//!   backpressure), and past the global [`ServerConfig::max_inflight`]
//!   cap new jobs are rejected immediately with a `busy` error reply
//!   (admission control — the client is told, never silently stalled).
//! * **Graceful drain**: a `{"type": "shutdown"}` control line from any
//!   client, [`ServerHandle::shutdown`], or a poll-positive
//!   [`ServerConfig::drain_when`] (the CLI wires SIGTERM/SIGINT to it)
//!   stops accepting, closes the read half of every connection, answers
//!   everything already accepted, writes each client a final stats
//!   line, and returns. Every accepted job is answered exactly once;
//!   job lines read after the drain started get a `shutting_down`
//!   error reply.
//!
//! Replies per connection leave in that connection's submission order
//! (out-of-order completions are resequenced per connection),
//! and the reply lines themselves are byte-identical to the stdin
//! transport's — both are rendered by the same core. Wire format and
//! stats fields: `docs/serve.md`.

use super::conn::{Conn, LineReader, NextLine, Reply, ReplyKind};
use super::core::{self, Lowered, WorkPayload};
use super::{ControlOp, ServeConfig, StatsScope};
use crate::coordinator::{Coordinator, CoordinatorStats};
use crate::json::{self, Value};
use crate::obs::WindowedHistogram;
use crate::Result;
use anyhow::{bail, Context};
use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, BufWriter, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Socket-server knobs on top of the shared [`ServeConfig`].
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// The shared serving knobs (model, default dc, cache shape). The
    /// socket transport ignores `batch_size` — jobs stream through the
    /// worker pool one at a time.
    pub serve: ServeConfig,
    /// Worker threads executing jobs (`0` = hardware parallelism).
    pub workers: usize,
    /// Global admission cap: with this many jobs accepted and
    /// unanswered, further job lines get an immediate `busy` error
    /// reply instead of queueing.
    pub max_inflight: usize,
    /// Per-connection in-flight bound: a connection's reader stops
    /// pulling lines once this many of its jobs are unanswered
    /// (sender-side backpressure through the socket buffer).
    pub conn_inflight: usize,
    /// Emit a cumulative stats line to the active client every N
    /// replies (`0` = only the per-connection final stats line).
    pub stats_every: u64,
    /// Reject request lines longer than this many bytes (the offending
    /// connection gets one error reply and a clean teardown).
    pub max_line_bytes: usize,
    /// Socket write timeout in milliseconds (`0` = none): a client
    /// that stops reading past the kernel buffer is declared dead
    /// instead of wedging a worker forever.
    pub write_timeout_ms: u64,
    /// External drain poll (the CLI passes a SIGTERM/SIGINT flag
    /// check); polled by the accept loop a few times per second.
    pub drain_when: Option<fn() -> bool>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            serve: ServeConfig::default(),
            workers: 0,
            max_inflight: 256,
            conn_inflight: 32,
            stats_every: 0,
            max_line_bytes: 8 * 1024 * 1024,
            write_timeout_ms: 30_000,
            drain_when: None,
        }
    }
}

/// End-of-run accounting returned by [`Server::run`] (the CLI prints
/// it to stderr; sockets carry pure JSONL).
#[derive(Debug, Clone, Copy, Default)]
pub struct ServerSummary {
    /// Connections accepted over the server's lifetime.
    pub clients: u64,
    /// Jobs executed (successfully or not) across all clients.
    pub jobs: u64,
    /// Reply lines answered (results + errors; stats lines excluded).
    pub replies: u64,
    /// Error replies (malformed lines, failed jobs, `busy`,
    /// `shutting_down`).
    pub errors: u64,
    /// Jobs rejected by global admission control.
    pub rejected_busy: u64,
    /// Accepted jobs left unanswered at exit. The drain protocol
    /// guarantees this is zero; it is measured, not assumed.
    pub dropped_jobs: u64,
    /// Final coordinator statistics (shared across all clients).
    pub stats: CoordinatorStats,
}

/// One accepted byte stream, Unix or TCP.
pub(crate) enum Stream {
    /// A Unix-domain connection.
    Unix(UnixStream),
    /// A TCP connection.
    Tcp(TcpStream),
}

impl Stream {
    fn try_clone(&self) -> std::io::Result<Stream> {
        Ok(match self {
            Stream::Unix(s) => Stream::Unix(s.try_clone()?),
            Stream::Tcp(s) => Stream::Tcp(s.try_clone()?),
        })
    }

    fn shutdown(&self, how: Shutdown) -> std::io::Result<()> {
        match self {
            Stream::Unix(s) => s.shutdown(how),
            Stream::Tcp(s) => s.shutdown(how),
        }
    }

    fn set_write_timeout(&self, dur: Option<Duration>) -> std::io::Result<()> {
        match self {
            Stream::Unix(s) => s.set_write_timeout(dur),
            Stream::Tcp(s) => s.set_write_timeout(dur),
        }
    }

    fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Stream::Unix(s) => s.set_nonblocking(nb),
            Stream::Tcp(s) => s.set_nonblocking(nb),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Unix(s) => s.read(buf),
            Stream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Unix(s) => s.write(buf),
            Stream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Unix(s) => s.flush(),
            Stream::Tcp(s) => s.flush(),
        }
    }
}

/// One bound listener, Unix or TCP.
enum Listener {
    Unix(UnixListener),
    Tcp(TcpListener),
}

impl Listener {
    fn set_nonblocking(&self, nb: bool) -> std::io::Result<()> {
        match self {
            Listener::Unix(l) => l.set_nonblocking(nb),
            Listener::Tcp(l) => l.set_nonblocking(nb),
        }
    }

    /// Non-blocking accept: `Ok(None)` when no connection is pending.
    fn accept_stream(&self) -> std::io::Result<Option<Stream>> {
        let res = match self {
            Listener::Unix(l) => l.accept().map(|(s, _)| Stream::Unix(s)),
            Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
        };
        match res {
            Ok(s) => Ok(Some(s)),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
            Err(e) => Err(e),
        }
    }
}

/// Global reply counters (mirrors of the per-connection counters, kept
/// with atomics so the stats path never takes the queue lock).
#[derive(Default)]
struct Totals {
    clients: AtomicU64,
    jobs: AtomicU64,
    replies: AtomicU64,
    errors: AtomicU64,
    rejected_busy: AtomicU64,
}

/// Rolling window behind the stats line's latency percentiles: the
/// socket `queue_wait_us_*` / `exec_us_*` fields digest the last
/// minute, not the process lifetime, so a long-gone spike ages out of
/// a long-lived server's stats.
const STATS_WINDOW_US: u64 = 60_000_000;

/// Metrics-registry handles for the socket transport's hot path. The
/// counters and gauges are always-on relaxed atomics; the clock reads
/// feeding the latency histograms run when tracing is enabled or the
/// job opted into `"timing"`, so the cold path costs one relaxed load
/// per job and allocates nothing.
struct ServerObs {
    /// Microseconds a job sat on the shared queue (reader → worker).
    queue_wait_us: crate::obs::Histogram,
    /// Microseconds a worker spent executing a job.
    exec_us: crate::obs::Histogram,
    /// Rolling-window twin of `queue_wait_us` (stats-line digest).
    queue_wait_win: WindowedHistogram,
    /// Rolling-window twin of `exec_us` (stats-line digest).
    exec_win: WindowedHistogram,
    /// Jobs sitting on the shared queue right now.
    queue_depth: crate::obs::Gauge,
    /// Workers currently executing a job (utilization gauge).
    workers_busy: crate::obs::Gauge,
}

impl ServerObs {
    fn new() -> Self {
        let m = crate::obs::metrics();
        Self {
            queue_wait_us: m.histogram("serve.queue_wait_us"),
            exec_us: m.histogram("serve.exec_us"),
            queue_wait_win: WindowedHistogram::new(STATS_WINDOW_US),
            exec_win: WindowedHistogram::new(STATS_WINDOW_US),
            queue_depth: m.gauge("serve.queue_depth"),
            workers_busy: m.gauge("serve.workers_busy"),
        }
    }
}

/// One accepted job on the shared queue.
struct Work {
    conn: Arc<Conn>,
    seq: u64,
    id: String,
    payload: WorkPayload,
    /// Enqueue timestamp ([`crate::obs::now_us`]); `None` when neither
    /// tracing nor per-job timing wants it — the queue-wait histogram
    /// needs a clock read, which is exactly the cost the cold path
    /// avoids.
    enqueued_us: Option<u64>,
    /// Wire-decode time; `Some` iff the job posted `"timing": true`,
    /// in which case the reply carries a `"timing"` object.
    decode_us: Option<u64>,
}

/// State shared by the accept loop, reader threads, and worker pool.
struct Shared {
    cfg: ServerConfig,
    coord: Coordinator,
    queue: Mutex<VecDeque<Work>>,
    qcv: Condvar,
    /// Set after all readers exited: workers drain the queue and stop.
    pool_closed: AtomicBool,
    /// Set when the drain starts: no new jobs are accepted anywhere.
    draining: AtomicBool,
    /// Globally accepted-but-unanswered jobs (admission control).
    inflight: AtomicUsize,
    /// Live connections (+ a stream handle so the drain can close
    /// read halves and teardown can close sockets).
    conns: Mutex<Vec<(Arc<Conn>, Stream)>>,
    totals: Totals,
    obs: ServerObs,
}

impl Shared {
    fn draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Idempotent drain trigger: stop admissions, then close the read
    /// half of every live connection so blocked readers see EOF and
    /// enter their teardown path.
    fn start_drain(&self) {
        if self.draining.swap(true, Ordering::SeqCst) {
            return;
        }
        let conns = self.conns.lock().unwrap();
        for (conn, stream) in conns.iter() {
            let _ = stream.shutdown(Shutdown::Read);
            conn.notify();
        }
    }

    fn register(&self, conn: Arc<Conn>, stream: Stream) {
        let mut conns = self.conns.lock().unwrap();
        // A connection accepted in the same instant the drain started:
        // close its read half here, under the same lock the drain
        // iterates under, so no connection can slip past the drain.
        if self.draining() {
            let _ = stream.shutdown(Shutdown::Read);
        }
        conns.push((conn, stream));
        self.totals.clients.fetch_add(1, Ordering::SeqCst);
    }

    fn unregister(&self, conn: &Conn) {
        let mut conns = self.conns.lock().unwrap();
        if let Some(i) = conns.iter().position(|(c, _)| std::ptr::eq(c.as_ref(), conn)) {
            let (_, stream) = conns.swap_remove(i);
            let _ = stream.shutdown(Shutdown::Both);
        }
    }

    fn live_clients(&self) -> usize {
        self.conns.lock().unwrap().len()
    }

    /// Claim one global in-flight slot, or fail if the cap is reached.
    fn try_admit(&self) -> bool {
        let cap = self.cfg.max_inflight.max(1);
        let mut cur = self.inflight.load(Ordering::SeqCst);
        loop {
            if cur >= cap {
                return false;
            }
            match self.inflight.compare_exchange(
                cur,
                cur + 1,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => return true,
                Err(now) => cur = now,
            }
        }
    }
}

/// Which occasion a stats line marks (they differ only in one flag).
enum StatsFlavor {
    /// `--stats-every` cadence or an on-demand `{"type": "stats"}`.
    Cumulative,
    /// Acknowledging a `{"type": "shutdown"}`: carries `"draining"`.
    DrainAck,
    /// The last line of a connection: carries `"final"`.
    Final,
}

/// Render one socket-transport stats line: the shared coordinator base
/// fields plus the global and per-client breakdown.
fn stats_line(shared: &Shared, conn: &Conn, flavor: StatsFlavor) -> String {
    let c = conn.counters();
    let t = &shared.totals;
    // Latency digests from the rolling-window histograms: the
    // percentiles cover the last STATS_WINDOW_US, not the process
    // lifetime. They fill only for traced or `"timing": true` jobs
    // (the clock reads are gated); otherwise the digests report zeros
    // — the fields stay so clients parse one shape.
    let qw = shared.obs.queue_wait_win.snapshot();
    let ex = shared.obs.exec_win.snapshot();
    let mut extra = vec![
        ("clients", Value::Int(shared.live_clients() as i64)),
        ("clients_total", Value::Int(t.clients.load(Ordering::SeqCst) as i64)),
        ("replies", Value::Int(t.replies.load(Ordering::SeqCst) as i64)),
        ("rejected_busy", Value::Int(t.rejected_busy.load(Ordering::SeqCst) as i64)),
        ("queue_wait_us_p50", Value::Int(qw.p50 as i64)),
        ("queue_wait_us_p99", Value::Int(qw.p99 as i64)),
        ("exec_us_p50", Value::Int(ex.p50 as i64)),
        ("exec_us_p99", Value::Int(ex.p99 as i64)),
        // Trace-pipeline pressure: events dropped at full per-thread
        // buffers (process-global, survives rotation) and events
        // currently buffered awaiting a drain.
        ("dropped_events", Value::Int(crate::obs::dropped_events() as i64)),
        ("trace_buffered", Value::Int(crate::obs::buffered_events() as i64)),
        ("client", Value::Str(conn.name.clone())),
        ("client_jobs", Value::Int(c.jobs as i64)),
        ("client_replies", Value::Int(c.replies as i64)),
        ("client_errors", Value::Int(c.errors as i64)),
        ("client_rejected_busy", Value::Int(c.rejected_busy as i64)),
        ("client_cache_hits", Value::Int(c.cache_hits as i64)),
    ];
    match flavor {
        StatsFlavor::Cumulative => {}
        StatsFlavor::DrainAck => extra.push(("draining", Value::Bool(true))),
        StatsFlavor::Final => extra.push(("final", Value::Bool(true))),
    }
    // The final line also reports the connection's trace-id range, so
    // a client can find its own jobs in an exported trace without
    // parsing span args.
    let trace_ids = c.job_seq_range.map(|(lo, hi)| {
        let name = &conn.name;
        format!("{name}#{lo}..{name}#{hi}")
    });
    if let (StatsFlavor::Final, Some(range)) = (&flavor, trace_ids) {
        extra.push(("trace_ids", Value::Str(range)));
    }
    json::to_string(&core::stats_value(&shared.coord, &extra))
}

/// Render the per-connection stats reply (`{"type": "stats", "scope":
/// "connection"}`): this connection's own counters only — no
/// coordinator scan, no server-wide fields — so one client can poll
/// its own numbers cheaply without draining server state.
fn conn_stats_line(conn: &Conn) -> String {
    let c = conn.counters();
    let mut o = BTreeMap::new();
    o.insert("type".to_string(), Value::Str("stats".into()));
    o.insert("scope".to_string(), Value::Str("connection".into()));
    o.insert("client".to_string(), Value::Str(conn.name.clone()));
    o.insert("jobs".to_string(), Value::Int(c.jobs as i64));
    o.insert("replies".to_string(), Value::Int(c.replies as i64));
    o.insert("errors".to_string(), Value::Int(c.errors as i64));
    o.insert("rejected_busy".to_string(), Value::Int(c.rejected_busy as i64));
    o.insert("cache_hits".to_string(), Value::Int(c.cache_hits as i64));
    json::to_string(&Value::Object(o))
}

/// Sequence a reply onto its connection and mirror its accounting into
/// the global totals; emits the periodic stats line on cadence.
fn deliver(shared: &Shared, conn: &Conn, seq: u64, reply: Reply, kind: ReplyKind) {
    {
        // Resequence + write: `complete` buffers out-of-order replies
        // and drains everything consecutive to the socket.
        let mut span = crate::obs::span("serve", "serve.write");
        span.arg("seq", seq as i64);
        span.arg_str("trace_id", || format!("{}#{seq}", conn.name));
        conn.complete(seq, reply, kind);
    }
    let t = &shared.totals;
    match kind {
        ReplyKind::Result { .. } => {
            t.jobs.fetch_add(1, Ordering::SeqCst);
        }
        ReplyKind::JobError => {
            t.jobs.fetch_add(1, Ordering::SeqCst);
            t.errors.fetch_add(1, Ordering::SeqCst);
        }
        ReplyKind::WireError | ReplyKind::ShuttingDown => {
            t.errors.fetch_add(1, Ordering::SeqCst);
        }
        ReplyKind::Busy => {
            t.errors.fetch_add(1, Ordering::SeqCst);
            t.rejected_busy.fetch_add(1, Ordering::SeqCst);
        }
        ReplyKind::Control => {}
    }
    if !matches!(kind, ReplyKind::Control) {
        let n = t.replies.fetch_add(1, Ordering::SeqCst) + 1;
        if shared.cfg.stats_every > 0 && n % shared.cfg.stats_every == 0 {
            conn.write_line(&stats_line(shared, conn, StatsFlavor::Cumulative));
        }
    }
}

/// The worker pool body: pop, execute, sequence the reply, release the
/// in-flight slot. Exits when the pool is closed and the queue empty.
fn worker_loop(shared: &Arc<Shared>) {
    loop {
        let work = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(w) = q.pop_front() {
                    shared.obs.queue_depth.set(q.len() as i64);
                    break Some(w);
                }
                if shared.pool_closed.load(Ordering::SeqCst) {
                    break None;
                }
                q = shared.qcv.wait(q).unwrap();
            }
        };
        let Some(w) = work else { return };
        let trace_id = || format!("{}#{}", w.conn.name, w.seq);
        // The queue-wait interval starts on the reader thread and ends
        // here, so it is a complete event, not an RAII span.
        let mut queue_wait_us = 0u64;
        if let Some(t0) = w.enqueued_us {
            let now = crate::obs::now_us();
            queue_wait_us = now.saturating_sub(t0);
            shared.obs.queue_wait_us.record(queue_wait_us);
            shared.obs.queue_wait_win.record_at(now, queue_wait_us);
            crate::obs::complete_event(
                "serve",
                "serve.queue_wait",
                t0,
                now,
                vec![
                    ("id", crate::obs::ArgValue::Str(w.id.clone())),
                    ("trace_id", crate::obs::ArgValue::Str(trace_id())),
                ],
            );
        }
        shared.obs.workers_busy.add(1);
        let timed = w.decode_us.is_some();
        let exec_t0 = (crate::obs::enabled() || timed).then(crate::obs::now_us);
        let outcome = {
            let mut span = crate::obs::span("serve", "serve.execute");
            span.arg_str("id", || w.id.clone());
            span.arg_str("trace_id", trace_id);
            core::run_payload(&shared.coord, &w.id, w.payload, &shared.cfg.serve)
        };
        let mut exec_us = 0u64;
        if let Some(t0) = exec_t0 {
            let now = crate::obs::now_us();
            exec_us = now.saturating_sub(t0);
            shared.obs.exec_us.record(exec_us);
            shared.obs.exec_win.record_at(now, exec_us);
        }
        shared.obs.workers_busy.add(-1);
        let kind = if outcome.is_err {
            ReplyKind::JobError
        } else {
            ReplyKind::Result { cache_hit: outcome.cache_hit }
        };
        let reply = match w.decode_us {
            // Timed replies render at drain time so the timing object
            // can carry the measured write wait.
            Some(decode_us) => Reply::Timed {
                reply: outcome.reply,
                timing: core::JobTiming {
                    trace_id: trace_id(),
                    decode_us,
                    queue_wait_us,
                    exec_us,
                    write_wait_us: 0,
                },
                completed_us: crate::obs::now_us(),
            },
            None => Reply::Ready(json::to_string(&outcome.reply)),
        };
        deliver(shared, &w.conn, w.seq, reply, kind);
        w.conn.job_done();
        shared.inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The per-connection reader body: pull lines, lower them, enqueue or
/// answer immediately; on EOF/teardown answer everything in flight,
/// write the final stats line, and close.
fn reader_loop(shared: &Arc<Shared>, conn: &Arc<Conn>, stream: Stream) {
    let mut reader = LineReader::new(stream, shared.cfg.max_line_bytes);
    let mut next_seq = 0u64;
    let mut line_no = 0u64;
    loop {
        if conn.is_dead() {
            break;
        }
        let item = match reader.next_line() {
            Ok(item) => item,
            Err(_) => break,
        };
        let range = match item {
            NextLine::Eof => break,
            NextLine::Oversized => {
                line_no += 1;
                let seq = next_seq;
                next_seq += 1;
                let reply = core::error_reply(
                    None,
                    &format!(
                        "input line {line_no} exceeds the {} byte limit",
                        shared.cfg.max_line_bytes
                    ),
                );
                let reply = Reply::Ready(json::to_string(&reply));
                deliver(shared, conn, seq, reply, ReplyKind::WireError);
                // An unframed client is not a client we can keep
                // decoding for: answer, then tear the connection down.
                break;
            }
            NextLine::Line(range) => range,
        };
        line_no += 1;
        let bytes = reader.slice(range);
        if bytes.iter().all(|b| b.is_ascii_whitespace()) {
            continue;
        }
        // The next accepted line gets sequence number `next_seq`, so
        // the decode span can carry the job's trace id before the
        // line's type is even known.
        let decode_start_us = crate::obs::now_us();
        let lowered = {
            let mut span = crate::obs::span("serve", "serve.decode");
            span.arg_str("trace_id", || format!("{}#{next_seq}", conn.name));
            core::lower_line_bytes(bytes, line_no, shared.cfg.serve.default_dc)
        };
        match lowered {
            Lowered::Bad { id, error } => {
                let seq = next_seq;
                next_seq += 1;
                let reply = core::error_reply(id.as_deref(), &error);
                let reply = Reply::Ready(json::to_string(&reply));
                deliver(shared, conn, seq, reply, ReplyKind::WireError);
            }
            Lowered::Control { op: ControlOp::Stats { scope: StatsScope::Server }, .. } => {
                let seq = next_seq;
                next_seq += 1;
                let line = stats_line(shared, conn, StatsFlavor::Cumulative);
                deliver(shared, conn, seq, Reply::Ready(line), ReplyKind::Control);
            }
            Lowered::Control { op: ControlOp::Stats { scope: StatsScope::Connection }, .. } => {
                let seq = next_seq;
                next_seq += 1;
                let line = conn_stats_line(conn);
                deliver(shared, conn, seq, Reply::Ready(line), ReplyKind::Control);
            }
            Lowered::Control { id, op: ControlOp::Metrics } => {
                let seq = next_seq;
                next_seq += 1;
                let line = json::to_string(&core::metrics_value(id.as_deref()));
                deliver(shared, conn, seq, Reply::Ready(line), ReplyKind::Control);
            }
            Lowered::Control { op: ControlOp::Shutdown, .. } => {
                shared.start_drain();
                let seq = next_seq;
                next_seq += 1;
                let line = stats_line(shared, conn, StatsFlavor::DrainAck);
                deliver(shared, conn, seq, Reply::Ready(line), ReplyKind::Control);
            }
            Lowered::Work { id, timing, payload } => {
                let seq = next_seq;
                next_seq += 1;
                if shared.draining()
                    || !conn.wait_capacity(shared.cfg.conn_inflight, &shared.draining)
                {
                    if conn.is_dead() {
                        break;
                    }
                    let reply = core::error_reply(
                        Some(&id),
                        "shutting_down: server is draining, job not accepted",
                    );
                    let reply = Reply::Ready(json::to_string(&reply));
                    deliver(shared, conn, seq, reply, ReplyKind::ShuttingDown);
                } else if !shared.try_admit() {
                    let reply = core::error_reply(
                        Some(&id),
                        &format!(
                            "busy: server at its global in-flight cap ({}), retry later",
                            shared.cfg.max_inflight.max(1)
                        ),
                    );
                    let reply = Reply::Ready(json::to_string(&reply));
                    deliver(shared, conn, seq, reply, ReplyKind::Busy);
                } else {
                    conn.begin_job();
                    // Timed jobs bill decode from the clock read taken
                    // before lowering; the enqueue stamp feeds the
                    // queue-wait measurement whenever anyone (trace or
                    // this job's `"timing"` opt-in) will consume it.
                    let decode_us =
                        timing.then(|| crate::obs::now_us().saturating_sub(decode_start_us));
                    let enqueued_us = (crate::obs::enabled() || timing).then(crate::obs::now_us);
                    let mut q = shared.queue.lock().unwrap();
                    q.push_back(Work {
                        conn: Arc::clone(conn),
                        seq,
                        id,
                        payload,
                        enqueued_us,
                        decode_us,
                    });
                    shared.obs.queue_depth.set(q.len() as i64);
                    drop(q);
                    shared.qcv.notify_one();
                }
            }
        }
    }
    // Teardown: every accepted job is answered before the connection
    // closes (dead connections skip straight through — their replies
    // are discarded but still accounted by the workers).
    conn.wait_idle();
    conn.write_line(&stats_line(shared, conn, StatsFlavor::Final));
    conn.close_writer();
    conn.mark_dead();
    shared.unregister(conn);
}

/// A drain trigger usable from another thread (tests, embedders).
#[derive(Clone)]
pub struct ServerHandle {
    shared: Arc<Shared>,
}

impl ServerHandle {
    /// Start the graceful drain (idempotent): equivalent to a
    /// `{"type": "shutdown"}` control line.
    pub fn shutdown(&self) {
        self.shared.start_drain();
    }

    /// Whether the drain has started.
    pub fn is_draining(&self) -> bool {
        self.shared.draining()
    }
}

/// A bound (but not yet running) socket server.
pub struct Server {
    shared: Arc<Shared>,
    listeners: Vec<Listener>,
    uds_path: PathBuf,
}

impl Server {
    /// Bind the Unix socket at `socket` (replacing a stale socket file
    /// left by a dead server; refusing one owned by a live server) and
    /// optionally a TCP listener at `listen` (`host:port`). The
    /// coordinator is caller-owned — load a persisted cache first for
    /// a warm start, save it after [`Server::run`] returns.
    pub fn bind(
        coord: Coordinator,
        cfg: ServerConfig,
        socket: &Path,
        listen: Option<&str>,
    ) -> Result<Server> {
        let unix = match UnixListener::bind(socket) {
            Ok(l) => l,
            Err(e) if e.kind() == std::io::ErrorKind::AddrInUse => {
                if UnixStream::connect(socket).is_ok() {
                    bail!("socket {} is in use by a live server", socket.display());
                }
                std::fs::remove_file(socket)
                    .with_context(|| format!("replacing stale socket {}", socket.display()))?;
                UnixListener::bind(socket)
                    .with_context(|| format!("binding socket {}", socket.display()))?
            }
            Err(e) => {
                return Err(anyhow::Error::new(e)
                    .context(format!("binding socket {}", socket.display())))
            }
        };
        let mut listeners = vec![Listener::Unix(unix)];
        if let Some(addr) = listen {
            let tcp = TcpListener::bind(addr)
                .with_context(|| format!("binding TCP listener on {addr}"))?;
            listeners.push(Listener::Tcp(tcp));
        }
        let shared = Arc::new(Shared {
            cfg,
            coord,
            queue: Mutex::new(VecDeque::new()),
            qcv: Condvar::new(),
            pool_closed: AtomicBool::new(false),
            draining: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            conns: Mutex::new(Vec::new()),
            totals: Totals::default(),
            obs: ServerObs::new(),
        });
        Ok(Server { shared, listeners, uds_path: socket.to_path_buf() })
    }

    /// A drain handle usable while [`Server::run`] owns the server.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle { shared: Arc::clone(&self.shared) }
    }

    /// Accept and serve until a drain is triggered (control line,
    /// [`ServerHandle::shutdown`], or [`ServerConfig::drain_when`]),
    /// then drain gracefully and return the accounting.
    pub fn run(self) -> Result<ServerSummary> {
        let shared = self.shared;
        let workers = match shared.cfg.workers {
            0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            n => n,
        };
        let worker_handles: Vec<_> = (0..workers)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        for listener in &self.listeners {
            listener.set_nonblocking(true)?;
        }
        let mut reader_handles = Vec::new();
        let mut client_no = 0u64;
        while !shared.draining() {
            if let Some(drain_when) = shared.cfg.drain_when {
                if drain_when() {
                    break;
                }
            }
            let mut accepted_any = false;
            for listener in &self.listeners {
                // Drain the whole backlog before sleeping again.
                while let Ok(Some(stream)) = listener.accept_stream() {
                    let _span = crate::obs::span("serve", "serve.accept");
                    accepted_any = true;
                    if stream.set_nonblocking(false).is_err() {
                        continue;
                    }
                    if shared.cfg.write_timeout_ms > 0 {
                        let dur = Duration::from_millis(shared.cfg.write_timeout_ms);
                        let _ = stream.set_write_timeout(Some(dur));
                    }
                    let (registry, writer) = match (stream.try_clone(), stream.try_clone()) {
                        (Ok(r), Ok(w)) => (r, w),
                        _ => continue,
                    };
                    let conn = Arc::new(Conn::new(
                        format!("client-{client_no}"),
                        Box::new(BufWriter::new(writer)),
                    ));
                    client_no += 1;
                    shared.register(Arc::clone(&conn), registry);
                    let shared = Arc::clone(&shared);
                    reader_handles.push(std::thread::spawn(move || {
                        reader_loop(&shared, &conn, stream)
                    }));
                }
            }
            if !accepted_any {
                std::thread::sleep(Duration::from_millis(25));
            }
        }
        // Drain: stop listening, close read halves, answer everything
        // accepted, then let the workers run the queue dry.
        shared.start_drain();
        drop(self.listeners);
        let _ = std::fs::remove_file(&self.uds_path);
        for h in reader_handles {
            let _ = h.join();
        }
        shared.pool_closed.store(true, Ordering::SeqCst);
        shared.qcv.notify_all();
        for h in worker_handles {
            let _ = h.join();
        }
        let leftover = shared.queue.lock().unwrap().len() as u64;
        let t = &shared.totals;
        Ok(ServerSummary {
            clients: t.clients.load(Ordering::SeqCst),
            jobs: t.jobs.load(Ordering::SeqCst),
            replies: t.replies.load(Ordering::SeqCst),
            errors: t.errors.load(Ordering::SeqCst),
            rejected_busy: t.rejected_busy.load(Ordering::SeqCst),
            dropped_jobs: leftover + shared.inflight.load(Ordering::SeqCst) as u64,
            stats: shared.coord.stats(),
        })
    }
}

/// Connect to a serve socket: a Unix socket path, or `host:port` when
/// the target parses as one and no such path exists.
fn connect(target: &str) -> Result<Stream> {
    let path = Path::new(target);
    if target.contains('/') || path.exists() {
        return Ok(Stream::Unix(
            UnixStream::connect(path)
                .with_context(|| format!("connecting to socket {target}"))?,
        ));
    }
    if target.contains(':') {
        return Ok(Stream::Tcp(
            TcpStream::connect(target).with_context(|| format!("connecting to {target}"))?,
        ));
    }
    Ok(Stream::Unix(
        UnixStream::connect(path).with_context(|| format!("connecting to socket {target}"))?,
    ))
}

/// The thin socket client behind `da4ml serve --connect`: stream
/// `input` lines to the server, stream reply lines to `output` until
/// the server closes the connection (which it does after its final
/// per-connection stats line — so this returns when the server is done
/// with us, not merely when input runs out).
pub fn run_client<R, W>(target: &str, input: R, output: &mut W) -> Result<()>
where
    R: BufRead + Send,
    W: Write,
{
    let mut rx = connect(target)?;
    let tx = rx.try_clone()?;
    std::thread::scope(|scope| -> Result<()> {
        let sender = scope.spawn(move || {
            let mut input = input;
            let mut tx = BufWriter::new(tx);
            let mut line = String::new();
            loop {
                line.clear();
                match input.read_line(&mut line) {
                    Ok(0) | Err(_) => break,
                    Ok(_) => {
                        if !line.ends_with('\n') {
                            line.push('\n');
                        }
                        // A send failure means the server tore us down
                        // (e.g. drain); keep reading its replies.
                        if tx.write_all(line.as_bytes()).and_then(|()| tx.flush()).is_err() {
                            break;
                        }
                    }
                }
            }
            let _ = tx.flush();
            // Half-close: the server sees EOF, answers everything,
            // sends its final stats line, then closes the other half.
            let _ = tx.get_ref().shutdown(Shutdown::Write);
        });
        let copy = std::io::copy(&mut rx, output);
        let _ = sender.join();
        copy?;
        output.flush()?;
        Ok(())
    })
}
