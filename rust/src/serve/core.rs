//! The transport-independent serve engine.
//!
//! Everything both transports share lives here: lowering one wire line
//! into work ([`lower_line`] / [`lower_line_bytes`]), executing a
//! lowered payload against the shared [`Coordinator`]
//! ([`run_payload`]), building the reply objects (`result` / `explore`
//! / `error`), and rendering the cumulative `stats` line. The stdin
//! JSONL loop ([`serve`] / [`serve_with`]) is a thin batched client of
//! this core; the socket server ([`crate::serve::server`]) is a
//! concurrent one. Because both funnel through the same lowering and
//! reply builders, the two transports produce byte-identical
//! `result`/`error` reply lines for the same job stream — pinned by
//! `rust/tests/serve_jsonl.rs`.

use super::{ControlOp, EmitLang, Request, ServeConfig, ServeSummary, StatsScope};
use crate::cmvm::CmvmSolution;
use crate::coordinator::{CompileJob, Coordinator};
use crate::estimate;
use crate::explore::{self, ExploreConfig, ExploreTarget, Objective, SpaceConfig};
use crate::json::{self, Value};
use crate::Result;
use std::collections::BTreeMap;
use std::io::{BufRead, Write};
use std::sync::Arc;

/// One unit of executable work lowered from a wire line: a compile job
/// or a validated design-space exploration.
pub(crate) enum WorkPayload {
    /// A CMVM compile (plus optional RTL emission).
    Job {
        job: CompileJob,
        emit: Option<EmitLang>,
    },
    /// A validated explore job, executed against the shared coordinator.
    Explore {
        target: ExploreTarget,
        space: SpaceConfig,
        objective: Option<Objective>,
    },
}

/// One lowered wire line: executable work, a control request, or an
/// immediate error reply.
pub(crate) enum Lowered {
    /// A job to execute (reply built by [`run_payload`]). `timing` is
    /// the job's `"timing": true` opt-in: the transport then measures
    /// the job across its stages and attaches a [`JobTiming`] object
    /// to the reply.
    Work { id: String, timing: bool, payload: WorkPayload },
    /// A control line (`shutdown` / `stats` / `metrics`):
    /// transport-level, answered by the transport itself.
    Control { id: Option<String>, op: ControlOp },
    /// A malformed line or invalid job: an immediate error reply.
    Bad { id: Option<String>, error: String },
}

/// Lower one wire line. Validation happens here — not at execution
/// time — so a malformed job becomes an immediate error reply with
/// uniform accounting on every transport.
pub(crate) fn lower_line(line: &str, line_no: u64, default_dc: i32) -> Lowered {
    match Request::from_json(line) {
        Ok(Request::Compile(req)) => {
            let id = req.id.clone().unwrap_or_else(|| format!("job-{line_no}"));
            let lowered = req
                .to_compile_job(id.clone(), default_dc)
                .and_then(|job| Ok((job, req.emit_lang()?)));
            match lowered {
                Ok((job, emit)) => Lowered::Work {
                    id,
                    timing: req.timing,
                    payload: WorkPayload::Job { job, emit },
                },
                Err(e) => Lowered::Bad { id: Some(id), error: format!("{e:#}") },
            }
        }
        Ok(Request::Explore(req)) => {
            let id = req.id.clone().unwrap_or_else(|| format!("job-{line_no}"));
            match req.validate() {
                Ok((target, space, objective)) => Lowered::Work {
                    id,
                    timing: req.timing,
                    payload: WorkPayload::Explore { target, space, objective },
                },
                Err(e) => Lowered::Bad { id: Some(id), error: format!("{e:#}") },
            }
        }
        Ok(Request::Control(ctl)) => Lowered::Control { id: ctl.id, op: ctl.op },
        Err(e) => Lowered::Bad { id: None, error: format!("{e:#}") },
    }
}

/// [`lower_line`] over raw bytes (the socket transport reads lines out
/// of a reused byte buffer). A non-UTF-8 line becomes an error reply,
/// mirroring the stdin transport's `InvalidData` handling.
pub(crate) fn lower_line_bytes(bytes: &[u8], line_no: u64, default_dc: i32) -> Lowered {
    match std::str::from_utf8(bytes) {
        Ok(text) => lower_line(text, line_no, default_dc),
        Err(e) => Lowered::Bad {
            id: None,
            error: format!("reading input line {line_no}: invalid UTF-8: {e}"),
        },
    }
}

/// The outcome of executing one [`WorkPayload`].
pub(crate) struct RunOutcome {
    /// The reply object (a `result`, `explore`, or `error` line).
    pub reply: Value,
    /// `true` when the reply is an error reply.
    pub is_err: bool,
    /// `true` when a compile job was answered from the solution cache.
    pub cache_hit: bool,
}

/// Execute one lowered payload against the shared coordinator and
/// build its reply. Failures become error replies — never panics, never
/// tears down the transport.
pub(crate) fn run_payload(
    coord: &Coordinator,
    id: &str,
    payload: WorkPayload,
    cfg: &ServeConfig,
) -> RunOutcome {
    match payload {
        WorkPayload::Job { job, emit } => match coord.compile_cached(&job) {
            Ok((sol, cached)) => match result_reply(id, &sol, cached, emit, cfg) {
                Ok(reply) => RunOutcome { reply, is_err: false, cache_hit: cached },
                Err(e) => RunOutcome {
                    reply: error_reply(Some(id), &format!("{e:#}")),
                    is_err: true,
                    cache_hit: cached,
                },
            },
            Err(e) => RunOutcome {
                reply: error_reply(Some(id), &format!("{e:#}")),
                is_err: true,
                cache_hit: false,
            },
        },
        WorkPayload::Explore { target, space, objective } => {
            match explore_reply(coord, id, &target, space, objective, cfg) {
                Ok(reply) => RunOutcome { reply, is_err: false, cache_hit: false },
                Err(e) => RunOutcome {
                    reply: error_reply(Some(id), &format!("{e:#}")),
                    is_err: true,
                    cache_hit: false,
                },
            }
        }
    }
}

/// RTL module names come from job ids, which are arbitrary strings:
/// sanitize to a legal Verilog/VHDL identifier.
pub(crate) fn module_name(id: &str) -> String {
    let mut s: String = id
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' })
        .collect();
    match s.chars().next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => s.insert_str(0, "m_"),
    }
    s
}

/// Build one `"type": "result"` reply (including the optional RTL
/// text). RTL emission failures bubble up and become an error reply.
pub(crate) fn result_reply(
    id: &str,
    sol: &CmvmSolution,
    cached: bool,
    emit: Option<EmitLang>,
    cfg: &ServeConfig,
) -> Result<Value> {
    let rep = estimate::combinational(&sol.program, &cfg.model);
    let mut o = BTreeMap::new();
    o.insert("type".into(), Value::Str("result".into()));
    o.insert("id".into(), Value::Str(id.into()));
    o.insert("adders".into(), Value::Int(sol.adders as i64));
    o.insert("depth".into(), Value::Int(sol.depth as i64));
    o.insert("lut".into(), Value::Int(rep.lut as i64));
    o.insert("ff".into(), Value::Int(rep.ff as i64));
    o.insert("latency_ns".into(), Value::Float(rep.latency_ns));
    o.insert("cached".into(), Value::Bool(cached));
    o.insert("opt_ms".into(), Value::Float(sol.opt_time.as_secs_f64() * 1e3));
    if let Some(lang) = emit {
        let module = module_name(id);
        let text = match lang {
            EmitLang::Verilog => crate::rtl::emit_verilog(&sol.program, &module, None)?,
            EmitLang::Vhdl => crate::rtl::emit_vhdl(&sol.program, &module, None)?,
        };
        o.insert("rtl".into(), Value::Str(text));
    }
    Ok(Value::Object(o))
}

/// Run one validated explore job against the shared coordinator (so
/// CMVM candidates hit the same solution cache as compile jobs) and
/// build its `"type": "explore"` reply. A compile failure bubbles up
/// into an error reply.
pub(crate) fn explore_reply(
    coord: &Coordinator,
    id: &str,
    target: &ExploreTarget,
    space: SpaceConfig,
    objective: Option<Objective>,
    cfg: &ServeConfig,
) -> Result<Value> {
    let ecfg = ExploreConfig { space, jobs: cfg.threads, model: cfg.model };
    let report = explore::explore(target, coord, &ecfg)?;
    let mut o = BTreeMap::new();
    o.insert("type".into(), Value::Str("explore".into()));
    o.insert("id".into(), Value::Str(id.into()));
    o.insert("target".into(), Value::Str(report.target.clone()));
    o.insert(
        "schema_version".into(),
        Value::Int(report.schema_version as i64),
    );
    o.insert(
        "front".into(),
        Value::Array(report.front.iter().map(explore::schema::point_value).collect()),
    );
    o.insert(
        "dominated".into(),
        Value::Array(report.dominated.iter().map(explore::schema::point_value).collect()),
    );
    o.insert(
        "skipped".into(),
        Value::Array(
            report
                .skipped
                .iter()
                .map(|s| {
                    let mut sk = BTreeMap::new();
                    sk.insert("id".into(), Value::Str(s.id.clone()));
                    sk.insert("reason".into(), Value::Str(s.reason.clone()));
                    Value::Object(sk)
                })
                .collect(),
        ),
    );
    if let Some(obj) = objective {
        if let Some(picked) = explore::pick(&report.front, obj) {
            o.insert("objective".into(), Value::Str(obj.name().into()));
            o.insert("picked".into(), explore::schema::point_value(picked));
        }
    }
    Ok(Value::Object(o))
}

/// Per-stage wall-clock microseconds for one `"timing": true` job,
/// assembled by the transport as the job crosses each stage. Becomes
/// the reply's `"timing"` object — only on jobs that opted in, so an
/// untimed reply keeps its exact historical bytes.
pub(crate) struct JobTiming {
    /// The job's trace correlation id (`client-<n>#<seq>` on the
    /// socket transport, `stdin#<line#>` on stdin).
    pub trace_id: String,
    /// Wire-decode + lowering time.
    pub decode_us: u64,
    /// Time between lowering and execution start (queue residency on
    /// the socket transport, batch residency on stdin).
    pub queue_wait_us: u64,
    /// Job execution time.
    pub exec_us: u64,
    /// Time the built reply waited for earlier replies to drain
    /// (socket write resequencing; always 0 on stdin).
    pub write_wait_us: u64,
}

impl JobTiming {
    /// The `"timing"` object (keys sorted, like every reply).
    pub(crate) fn value(&self) -> Value {
        let mut o = BTreeMap::new();
        o.insert("decode_us".into(), Value::Int(self.decode_us as i64));
        o.insert("exec_us".into(), Value::Int(self.exec_us as i64));
        o.insert("queue_wait_us".into(), Value::Int(self.queue_wait_us as i64));
        o.insert("trace_id".into(), Value::Str(self.trace_id.clone()));
        o.insert("write_wait_us".into(), Value::Int(self.write_wait_us as i64));
        Value::Object(o)
    }
}

/// Attach a timing object to a built reply (result, explore, or error
/// — a failed timed job still reports where its time went).
pub(crate) fn inject_timing(reply: &mut Value, timing: &JobTiming) {
    if let Value::Object(o) = reply {
        o.insert("timing".into(), timing.value());
    }
}

/// Build one `"type": "error"` reply (`id` is `null` when the line was
/// not correlatable).
pub(crate) fn error_reply(id: Option<&str>, error: &str) -> Value {
    let mut o = BTreeMap::new();
    o.insert("type".into(), Value::Str("error".into()));
    o.insert(
        "id".into(),
        match id {
            Some(id) => Value::Str(id.into()),
            None => Value::Null,
        },
    );
    o.insert("error".into(), Value::Str(error.into()));
    Value::Object(o)
}

/// Build a cumulative `"type": "stats"` line: the coordinator-wide base
/// fields plus transport-specific `extra` key/value pairs (the stdin
/// transport adds `batch`/`jobs`; the socket transport adds the
/// global + per-client breakdown).
pub(crate) fn stats_value(coord: &Coordinator, extra: &[(&str, Value)]) -> Value {
    let stats = coord.stats();
    let mut o = BTreeMap::new();
    o.insert("type".into(), Value::Str("stats".into()));
    o.insert("submitted".into(), Value::Int(stats.submitted as i64));
    o.insert("cache_hits".into(), Value::Int(stats.cache_hits as i64));
    o.insert("cache_size".into(), Value::Int(coord.cache_len() as i64));
    o.insert("cache_evictions".into(), Value::Int(stats.evictions as i64));
    // Deployment-shape keys: how many independently locked shards the
    // cache runs on, and how many solutions this process inherited from
    // a persisted cache file (`serve --cache-load`) rather than
    // computing or receiving over the wire.
    o.insert("cache_shards".into(), Value::Int(coord.shard_count() as i64));
    o.insert("cache_loaded".into(), Value::Int(stats.loaded as i64));
    o.insert("total_opt_ms".into(), Value::Float(stats.total_opt_time.as_secs_f64() * 1e3));
    // Optimizer work proxies (cumulative, executed jobs only — cache
    // hits add nothing): lets clients watch perf per batch the same way
    // the perf suite does per case.
    o.insert("cse_steps".into(), Value::Int(stats.total_cse_steps as i64));
    o.insert("heap_pops".into(), Value::Int(stats.total_heap_pops as i64));
    for (k, v) in extra {
        o.insert((*k).into(), v.clone());
    }
    Value::Object(o)
}

/// Build one `"type": "metrics"` reply: the schema-versioned
/// [`crate::obs::schema`] snapshot document with the wire envelope
/// (`type` + correlation `id`) layered on top. Both transports answer
/// the `{"type": "metrics"}` control line with this object.
pub(crate) fn metrics_value(id: Option<&str>) -> Value {
    let mut v = crate::obs::schema::snapshot_value();
    if let Value::Object(o) = &mut v {
        o.insert("type".into(), Value::Str("metrics".into()));
        o.insert(
            "id".into(),
            match id {
                Some(id) => Value::Str(id.into()),
                None => Value::Null,
            },
        );
    }
    v
}

/// Decode-stage measurements captured when a `"timing": true` job was
/// lowered on the stdin transport.
struct TimedDecode {
    trace_id: String,
    decode_us: u64,
    /// Clock at decode end — batch residency is measured from here.
    ready_us: u64,
}

/// One batch entry on the stdin transport: a lowered compile job, a
/// validated explore job, or an immediate error reply.
enum Pending {
    Job {
        id: String,
        job: CompileJob,
        emit: Option<EmitLang>,
        timed: Option<TimedDecode>,
    },
    Explore {
        id: String,
        target: ExploreTarget,
        space: SpaceConfig,
        objective: Option<Objective>,
        timed: Option<TimedDecode>,
    },
    Bad {
        id: Option<String>,
        error: String,
    },
}

/// Run the serve loop: read JSONL jobs from `input` until EOF, stream
/// JSONL replies to `output`. Never returns early on malformed or
/// failing jobs — only on I/O errors writing `output`.
pub fn serve<R: BufRead, W: Write>(
    input: R,
    output: &mut W,
    cfg: &ServeConfig,
) -> Result<ServeSummary> {
    let coord = Coordinator::with_shards(cfg.cache_shards);
    coord.set_cache_cap(cfg.cache_cap);
    serve_with(&coord, input, output, cfg)
}

/// [`serve`] against a caller-owned [`Coordinator`]. This is the warm
/// restart surface: the CLI loads a persisted cache into the
/// coordinator first (`serve --cache-load`), serves, then saves the
/// final cache after EOF (`--cache-save`). The coordinator's own
/// sharding/cap configuration wins — [`ServeConfig::cache_shards`] and
/// [`ServeConfig::cache_cap`] are applied only by [`serve`], which owns
/// its coordinator.
pub fn serve_with<R: BufRead, W: Write>(
    coord: &Coordinator,
    input: R,
    output: &mut W,
    cfg: &ServeConfig,
) -> Result<ServeSummary> {
    let mut summary = ServeSummary::default();
    let mut batch: Vec<Pending> = Vec::new();
    let batch_size = cfg.batch_size.max(1);
    let mut line_no = 0u64;
    for line in input.lines() {
        // Count every input line (blank ones too) so the default
        // `job-<line#>` id matches the caller's 1-based file line.
        line_no += 1;
        let entry = match line {
            Ok(line) if line.trim().is_empty() => continue,
            Ok(line) => {
                let decode_start_us = crate::obs::now_us();
                match lower_line(&line, line_no, cfg.default_dc) {
                    Lowered::Work { id, timing, payload } => {
                        let timed = timing.then(|| {
                            let ready_us = crate::obs::now_us();
                            TimedDecode {
                                trace_id: format!("stdin#{line_no}"),
                                decode_us: ready_us.saturating_sub(decode_start_us),
                                ready_us,
                            }
                        });
                        match payload {
                            WorkPayload::Job { job, emit } => {
                                Pending::Job { id, job, emit, timed }
                            }
                            WorkPayload::Explore { target, space, objective } => {
                                Pending::Explore { id, target, space, objective, timed }
                            }
                        }
                    }
                    Lowered::Bad { id, error } => Pending::Bad { id, error },
                    Lowered::Control { op: ControlOp::Stats { scope }, .. } => {
                        // On-demand stats: flush buffered jobs first
                        // (their batch emits its own stats line), then
                        // answer with a fresh cumulative stats line. On
                        // stdin the "connection" is the stream itself,
                        // so connection scope answers with the
                        // stream-local counters only.
                        flush_batch(coord, &mut batch, output, cfg, &mut summary)?;
                        match scope {
                            StatsScope::Server => emit_stats_line(coord, output, &summary)?,
                            StatsScope::Connection => {
                                let mut o = BTreeMap::new();
                                o.insert("type".into(), Value::Str("stats".into()));
                                o.insert("scope".into(), Value::Str("connection".into()));
                                o.insert("jobs".into(), Value::Int(summary.jobs as i64));
                                o.insert("replies".into(), Value::Int(summary.replies as i64));
                                o.insert("errors".into(), Value::Int(summary.errors as i64));
                                o.insert("batches".into(), Value::Int(summary.batches as i64));
                                writeln!(output, "{}", json::to_string(&Value::Object(o)))?;
                                output.flush()?;
                            }
                        }
                        continue;
                    }
                    Lowered::Control { id, op: ControlOp::Metrics } => {
                        // Observability snapshot on demand: flush
                        // buffered jobs so their counters land first,
                        // then answer with the schema-versioned metrics
                        // document.
                        flush_batch(coord, &mut batch, output, cfg, &mut summary)?;
                        writeln!(output, "{}", json::to_string(&metrics_value(id.as_deref())))?;
                        output.flush()?;
                        continue;
                    }
                    Lowered::Control { op: ControlOp::Shutdown, .. } => {
                        // Graceful drain: flush buffered jobs, emit the
                        // final stats line, stop reading (like EOF).
                        flush_batch(coord, &mut batch, output, cfg, &mut summary)?;
                        emit_stats_line(coord, output, &summary)?;
                        summary.stats = coord.stats();
                        return Ok(summary);
                    }
                }
            }
            // A non-UTF-8 line is one more malformed request, not a
            // reason to tear down the service and drop buffered jobs
            // (`lines()` has already consumed the offending bytes).
            Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                Pending::Bad { id: None, error: format!("reading input line {line_no}: {e}") }
            }
            // A genuine I/O failure: answer what we have, then stop.
            Err(e) => {
                flush_batch(coord, &mut batch, output, cfg, &mut summary)?;
                summary.stats = coord.stats();
                return Err(e.into());
            }
        };
        batch.push(entry);
        if batch.len() >= batch_size {
            flush_batch(coord, &mut batch, output, cfg, &mut summary)?;
        }
    }
    flush_batch(coord, &mut batch, output, cfg, &mut summary)?;
    summary.stats = coord.stats();
    Ok(summary)
}

/// One reply slot after the jobs have been moved out for compilation:
/// correlation metadata only (the job itself is not cloned). Explore
/// jobs (already validated) are executed at reply time against the
/// shared coordinator — and so are *timed* compile jobs, whose
/// `exec_us` is a per-job measurement the parallel batch cannot
/// provide.
enum Slot {
    Job {
        id: String,
        idx: usize,
        emit: Option<EmitLang>,
    },
    TimedJob {
        id: String,
        job: CompileJob,
        emit: Option<EmitLang>,
        timed: TimedDecode,
    },
    Explore {
        id: String,
        target: ExploreTarget,
        space: SpaceConfig,
        objective: Option<Objective>,
        timed: Option<TimedDecode>,
    },
    Bad {
        id: Option<String>,
        error: String,
    },
}

/// Write the cumulative stdin-transport stats line (`batch` counter +
/// `jobs` reply count on top of the shared base fields).
fn emit_stats_line<W: Write>(
    coord: &Coordinator,
    output: &mut W,
    summary: &ServeSummary,
) -> Result<()> {
    let v = stats_value(
        coord,
        &[
            ("batch", Value::Int(summary.batches as i64)),
            ("jobs", Value::Int(summary.replies as i64)),
        ],
    );
    writeln!(output, "{}", json::to_string(&v))?;
    output.flush()?;
    Ok(())
}

/// Assemble the stdin transport's [`JobTiming`]: queue wait is batch
/// residency (decode end → flush start) and stdin replies stream in
/// input order with no resequencing, so `write_wait_us` is always 0.
fn stdin_timing(timed: TimedDecode, flush_start_us: u64, exec_us: u64) -> JobTiming {
    JobTiming {
        trace_id: timed.trace_id,
        decode_us: timed.decode_us,
        queue_wait_us: flush_start_us.saturating_sub(timed.ready_us),
        exec_us,
        write_wait_us: 0,
    }
}

/// Compile the batched jobs through the coordinator and stream one
/// reply line per entry (input order), then the batch stats line.
/// No-op on an empty batch.
fn flush_batch<W: Write>(
    coord: &Coordinator,
    batch: &mut Vec<Pending>,
    output: &mut W,
    cfg: &ServeConfig,
    summary: &mut ServeSummary,
) -> Result<()> {
    if batch.is_empty() {
        return Ok(());
    }
    summary.batches += 1;
    let flush_start_us = crate::obs::now_us();
    // Move the jobs out for the worker pool; keep only correlation
    // metadata (id, original position) on this side.
    let mut jobs = Vec::new();
    let mut slots = Vec::with_capacity(batch.len());
    for entry in std::mem::take(batch) {
        match entry {
            Pending::Job { id, job, emit, timed: None } => {
                slots.push(Slot::Job { id, idx: jobs.len(), emit });
                jobs.push(job);
            }
            Pending::Job { id, job, emit, timed: Some(timed) } => {
                slots.push(Slot::TimedJob { id, job, emit, timed })
            }
            Pending::Explore { id, target, space, objective, timed } => {
                slots.push(Slot::Explore { id, target, space, objective, timed })
            }
            Pending::Bad { id, error } => slots.push(Slot::Bad { id, error }),
        }
    }
    let mut results: Vec<Option<Result<(Arc<CmvmSolution>, bool)>>> =
        coord.compile_batch(jobs, cfg.threads).into_iter().map(Some).collect();
    for slot in slots {
        let reply = match slot {
            Slot::Bad { id, error } => {
                summary.errors += 1;
                error_reply(id.as_deref(), &error)
            }
            Slot::Explore { id, target, space, objective, timed } => {
                summary.jobs += 1;
                let exec_start_us = crate::obs::now_us();
                let mut reply = match explore_reply(coord, &id, &target, space, objective, cfg) {
                    Ok(reply) => reply,
                    Err(e) => {
                        summary.errors += 1;
                        error_reply(Some(id.as_str()), &format!("{e:#}"))
                    }
                };
                if let Some(timed) = timed {
                    let exec_us = crate::obs::now_us().saturating_sub(exec_start_us);
                    inject_timing(&mut reply, &stdin_timing(timed, flush_start_us, exec_us));
                }
                reply
            }
            Slot::TimedJob { id, job, emit, timed } => {
                summary.jobs += 1;
                let exec_start_us = crate::obs::now_us();
                let outcome = run_payload(coord, &id, WorkPayload::Job { job, emit }, cfg);
                let exec_us = crate::obs::now_us().saturating_sub(exec_start_us);
                if outcome.is_err {
                    summary.errors += 1;
                }
                let mut reply = outcome.reply;
                inject_timing(&mut reply, &stdin_timing(timed, flush_start_us, exec_us));
                reply
            }
            Slot::Job { id, idx, emit } => {
                summary.jobs += 1;
                match results[idx].take().expect("one result per job") {
                    Ok((sol, cached)) => {
                        match result_reply(&id, &sol, cached, emit, cfg) {
                            Ok(reply) => reply,
                            Err(e) => {
                                summary.errors += 1;
                                error_reply(Some(id.as_str()), &format!("{e:#}"))
                            }
                        }
                    }
                    Err(e) => {
                        summary.errors += 1;
                        error_reply(Some(id.as_str()), &format!("{e:#}"))
                    }
                }
            }
        };
        summary.replies += 1;
        writeln!(output, "{}", json::to_string(&reply))?;
    }
    emit_stats_line(coord, output, summary)?;
    Ok(())
}
