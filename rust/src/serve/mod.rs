//! The long-lived JSONL compile service (`da4ml serve`).
//!
//! The paper's pitch is a CMVM compiler fast enough to sit inside a
//! design loop; this module is the multi-request serving surface on
//! top of it, in two transports over one engine:
//!
//! * **stdin/stdout** ([`serve`] / [`serve_with`]) — one JSONL stream,
//!   jobs batched through the [`crate::coordinator::Coordinator`]'s
//!   cache + worker pool, one reply line per job plus a stats line per
//!   batch.
//! * **socket server** ([`server`]) — a long-lived Unix-domain (plus
//!   optional TCP) listener serving many concurrent connections over a
//!   shared bounded job queue and worker pool, with per-connection
//!   backpressure, global admission control, and graceful drain.
//!
//! Both transports lower lines and build replies through the same
//! engine (the private `core` submodule), so for the same job stream
//! they produce
//! byte-identical `result`/`error` reply lines — pinned by
//! `rust/tests/serve_jsonl.rs`. Wire format documented in
//! `docs/serve.md`.
//!
//! Requests are decoded with the zero-copy pull parser
//! ([`crate::json::decode::Decoder`]), so a hot serving loop never
//! builds a [`crate::json::Value`] tree for job matrices. Malformed
//! lines and failed jobs produce `"type": "error"` replies; they never
//! tear down the service.
//!
//! Besides compile jobs, a line may post a **design-space
//! exploration** (`"type": "explore"` with a `matrix` or an inline
//! network `spec`): the [`crate::explore`] subsystem sweeps the
//! strategy × dc × pipeline space on the shared coordinator and the
//! reply carries the Pareto `front`, the `dominated` points, and —
//! when an `objective` was posted — the `picked` configuration. Three
//! **control lines** round out the wire: `{"type": "stats"}` answers
//! with an on-demand cumulative stats line (or, with `"scope":
//! "connection"`, the posting connection's own counters),
//! `{"type": "metrics"}` answers with the observability metrics
//! snapshot ([`crate::obs::schema`] v1), and `{"type": "shutdown"}`
//! drains the service gracefully (on the socket transport: stop
//! accepting, answer everything in flight, emit final stats).
//!
//! For long-lived deployments the solution cache can be bounded with
//! [`ServeConfig::cache_cap`] (`serve --cache-cap`); evictions are
//! visible on the stats line. The cache itself can be sharded across
//! independent locks ([`ServeConfig::cache_shards`], `serve
//! --cache-shards`) so concurrent batches — and the socket server's
//! concurrent workers — stop contending on one mutex, and a deployment
//! can restart warm: the CLI loads a baked cache file into the
//! coordinator before serving and saves it after EOF (`serve
//! --cache-load/--cache-save`, wired through [`serve_with`]). The
//! stats line reports both knobs (`cache_shards`, `cache_loaded`).
//!
//! ```
//! use da4ml::serve::{serve, ServeConfig};
//! use std::io::Cursor;
//!
//! // Two identical jobs: with one job per batch, the second is
//! // deterministically answered from the cache.
//! let jobs = "\
//! {\"id\": \"a\", \"matrix\": [[3, 5], [-7, 9]]}\n\
//! {\"id\": \"b\", \"matrix\": [[3, 5], [-7, 9]]}\n";
//! let cfg = ServeConfig { batch_size: 1, ..ServeConfig::default() };
//! let mut out = Vec::new();
//! let summary = serve(Cursor::new(jobs), &mut out, &cfg).unwrap();
//! assert_eq!(summary.jobs, 2);
//! assert_eq!(summary.stats.cache_hits, 1);
//! let text = String::from_utf8(out).unwrap();
//! // One result + one stats line per single-job batch.
//! assert_eq!(text.lines().count(), 4);
//! assert!(text.contains("\"cached\":true"));
//! ```

mod conn;
mod core;
pub mod server;

pub use self::core::{serve, serve_with};

use crate::cmvm::{CmvmProblem, Strategy};
use crate::coordinator::{CompileJob, CoordinatorStats};
use crate::estimate::FpgaModel;
use crate::explore::{ExploreTarget, Objective, SpaceConfig};
use crate::json::decode::Decoder;
use crate::nn::NetworkSpec;
use crate::Result;
use anyhow::{bail, ensure};

/// Serving knobs (all have CLI flags, see `da4ml serve --help` text).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Jobs per coordinator batch (replies stream after each batch).
    /// The socket transport has no batches; it streams jobs one at a
    /// time through the shared worker pool.
    pub batch_size: usize,
    /// Worker threads per batch (`0` = hardware parallelism).
    pub threads: usize,
    /// Delay constraint applied when a job omits `"dc"`.
    pub default_dc: i32,
    /// FPGA cost model used for the per-solution resource estimate.
    pub model: FpgaModel,
    /// Solution-cache entry cap (`serve --cache-cap`): past it the
    /// coordinator evicts least-recently-used solutions. `None` (the
    /// default) keeps the cache unbounded, preserving the historical
    /// behavior.
    pub cache_cap: Option<usize>,
    /// Solution-cache shard count (`serve --cache-shards`): the cache
    /// splits into this many independently locked shards keyed by
    /// job-key hash. `1` (the default) reproduces the historical
    /// single-lock cache — including its exact eviction order.
    pub cache_shards: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            batch_size: 16,
            threads: 0,
            default_dc: -1,
            model: FpgaModel::default(),
            cache_cap: None,
            cache_shards: 1,
        }
    }
}

/// End-of-stream accounting, returned by [`serve`] (the CLI prints it
/// to stderr so stdout stays pure JSONL).
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeSummary {
    /// Well-formed jobs compiled (successfully or not).
    pub jobs: u64,
    /// Error replies emitted (malformed lines + failed jobs).
    pub errors: u64,
    /// Reply lines written (every input job/line yields exactly one;
    /// control lines and stats lines are not counted).
    pub replies: u64,
    /// Batches flushed.
    pub batches: u64,
    /// Final coordinator statistics (submitted / cache hits / opt time).
    pub stats: CoordinatorStats,
}

/// One decoded compile request (see `docs/serve.md` for field
/// semantics and defaults).
#[derive(Debug, Clone)]
pub struct JobRequest {
    /// Reply correlation id; defaults to `job-<line#>` when omitted.
    pub id: Option<String>,
    /// Constant matrix as `d_in` rows of `d_out` weights.
    pub matrix: Vec<Vec<i64>>,
    /// Input bitwidth (signed), `1..=63`. Default 8.
    pub bits: i64,
    /// Strategy name (`da`, `latency`, `naive-da`, `cse-only`,
    /// `lookahead`). Default `da`.
    pub strategy: Option<String>,
    /// Delay constraint; falls back to [`ServeConfig::default_dc`].
    pub dc: Option<i64>,
    /// Optional RTL emission: `"verilog"` or `"vhdl"`. The reply then
    /// carries the combinational RTL text of the solution in an
    /// `"rtl"` field.
    pub emit: Option<String>,
    /// Per-job timing opt-in (`"timing": true`): the reply then
    /// carries a `"timing"` object (decode / queue-wait / exec /
    /// write-wait microseconds plus the job's `trace_id`). Off by
    /// default — an untimed reply is byte-identical whether or not
    /// tracing is enabled.
    pub timing: bool,
}

/// RTL language requested by a job's `"emit"` field.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EmitLang {
    /// Verilog-2001 (`rtl::emit_verilog`).
    Verilog,
    /// VHDL (`rtl::emit_vhdl`).
    Vhdl,
}

/// One decoded request line: a compile job (the default), a
/// design-space exploration (`"type": "explore"`), or a control line
/// (`"type": "shutdown"` / `"type": "stats"`) — see `docs/serve.md`.
#[derive(Debug, Clone)]
pub enum Request {
    /// A CMVM compile job.
    Compile(JobRequest),
    /// A design-space exploration job.
    Explore(ExploreRequest),
    /// A transport control line (graceful drain / on-demand stats).
    Control(ControlRequest),
}

/// One decoded control line: not a job, but an instruction to the
/// transport itself.
#[derive(Debug, Clone)]
pub struct ControlRequest {
    /// Optional correlation id (echoed nowhere — the answer is a stats
    /// line — but accepted so clients can keep uniform line shapes).
    pub id: Option<String>,
    /// Which control operation was posted.
    pub op: ControlOp,
}

/// The control operations of the wire (`"type"` values beyond the job
/// types).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ControlOp {
    /// `{"type": "shutdown"}` — drain gracefully: stop reading (stdin)
    /// or stop accepting and flush all in-flight work (socket), then
    /// emit a final stats line.
    Shutdown,
    /// `{"type": "stats"}` — answer with an on-demand stats line for
    /// the requested scope.
    Stats {
        /// Which counters to report (`"scope"` field; default server).
        scope: StatsScope,
    },
    /// `{"type": "metrics"}` — answer with the observability metrics
    /// snapshot ([`crate::obs::schema`], schema v1) as a single reply
    /// line.
    Metrics,
}

/// Scope of a `{"type": "stats"}` control line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StatsScope {
    /// Cumulative server-wide counters (the default; the historical
    /// stats line).
    Server,
    /// This connection's own counters (`"scope": "connection"`), so a
    /// client can poll its share without reading server-wide totals —
    /// previously only available as the final stats line on drain.
    Connection,
}

/// One decoded explore request (`"type": "explore"`): sweep the
/// strategy × dc × pipeline space for a posted matrix or network spec
/// and reply with the Pareto front.
#[derive(Debug, Clone)]
pub struct ExploreRequest {
    /// Reply correlation id; defaults to `job-<line#>` when omitted.
    pub id: Option<String>,
    /// CMVM target (exactly one of `matrix` / `spec` must be present).
    pub matrix: Option<Vec<Vec<i64>>>,
    /// Network target: a full inline network spec object.
    pub spec: Option<NetworkSpec>,
    /// Input bitwidth for `matrix` targets, `1..=63` (default 8). An
    /// error on `spec` targets — the spec carries its own `input_bits`,
    /// so a posted value would be silently meaningless.
    pub bits: Option<i64>,
    /// Candidate space: `"smoke"` (default) or `"full"`.
    pub space: Option<String>,
    /// Optional objective (`min-lut` | `min-latency` | `knee`); the
    /// reply then carries the `picked` front point.
    pub objective: Option<String>,
    /// Per-job timing opt-in, same semantics as
    /// [`JobRequest::timing`].
    pub timing: bool,
}

impl ExploreRequest {
    /// Validate the request into its exploration inputs. Runs at
    /// line-lowering time (like [`JobRequest::to_compile_job`]) so a
    /// malformed explore job becomes an immediate error reply with the
    /// same accounting as a malformed compile job — never a deferred
    /// failure that inflates the job count.
    pub fn validate(&self) -> Result<(ExploreTarget, SpaceConfig, Option<Objective>)> {
        let target = match (&self.matrix, &self.spec) {
            (Some(matrix), None) => {
                ExploreTarget::Cmvm(matrix_to_problem(matrix, self.bits.unwrap_or(8))?)
            }
            (None, Some(spec)) => {
                ensure!(
                    self.bits.is_none(),
                    "field 'bits' does not apply to spec targets (the spec carries its \
                     own input_bits)"
                );
                ExploreTarget::Network(spec.clone())
            }
            _ => bail!("explore job must carry exactly one of 'matrix' or 'spec'"),
        };
        let space = match self.space.as_deref() {
            None | Some("smoke") => SpaceConfig::smoke(),
            Some("full") => SpaceConfig::full(),
            Some(other) => bail!("unknown explore space '{other}' (expected smoke|full)"),
        };
        let objective = match self.objective.as_deref() {
            None => None,
            Some(name) => Some(Objective::parse(name)?),
        };
        Ok((target, space, objective))
    }
}

impl Request {
    /// Streaming-decode one request line (no `Value` tree). The
    /// `"type"` discriminator may appear anywhere on the line; fields
    /// belonging to *other* request types are rejected (strict wire:
    /// a silently ignored field would hide caller bugs).
    pub fn from_json(line: &str) -> Result<Self> {
        Self::decode_request(Decoder::new(line))
    }

    /// [`Request::from_json`] over raw bytes: the socket transport's
    /// per-connection line reader hands out `&[u8]` slices of a reused
    /// buffer, so the wire decodes without ever allocating a line
    /// `String`. Non-UTF-8 bytes are a decode error, never a panic.
    pub fn from_json_bytes(line: &[u8]) -> Result<Self> {
        Self::decode_request(Decoder::from_bytes(line)?)
    }

    fn decode_request(mut d: Decoder<'_>) -> Result<Self> {
        let mut ty: Option<String> = None;
        let mut id = None;
        let mut matrix = None;
        let mut bits: Option<i64> = None;
        let mut strategy = None;
        let mut dc = None;
        let mut emit = None;
        let mut spec: Option<NetworkSpec> = None;
        let mut space = None;
        let mut objective = None;
        let mut scope = None;
        let mut timing: Option<bool> = None;
        d.object_start()?;
        while let Some(key) = d.next_key()? {
            match key.as_ref() {
                "type" => ty = Some(d.string()?),
                "id" => id = Some(d.string()?),
                "matrix" => matrix = Some(d.i64_mat()?),
                "bits" => bits = Some(d.i64()?),
                "strategy" => strategy = Some(d.string()?),
                "dc" => dc = Some(d.i64()?),
                "emit" => emit = Some(d.string()?),
                "spec" => spec = Some(NetworkSpec::decode(&mut d)?),
                "space" => space = Some(d.string()?),
                "objective" => objective = Some(d.string()?),
                "scope" => scope = Some(d.string()?),
                "timing" => timing = Some(d.bool()?),
                _ => d.skip_value()?,
            }
        }
        d.end()?;
        match ty.as_deref() {
            None | Some("compile") => {
                for (field, present) in [
                    ("spec", spec.is_some()),
                    ("space", space.is_some()),
                    ("objective", objective.is_some()),
                ] {
                    ensure!(!present, "field '{field}' requires \"type\": \"explore\"");
                }
                ensure!(scope.is_none(), "field 'scope' requires \"type\": \"stats\"");
                let matrix = matrix.ok_or_else(|| anyhow::anyhow!("missing field 'matrix'"))?;
                let bits = bits.unwrap_or(8);
                let timing = timing.unwrap_or(false);
                Ok(Request::Compile(JobRequest { id, matrix, bits, strategy, dc, emit, timing }))
            }
            Some("explore") => {
                for (field, present) in [
                    ("strategy", strategy.is_some()),
                    ("dc", dc.is_some()),
                    ("emit", emit.is_some()),
                ] {
                    ensure!(!present, "field '{field}' does not apply to explore jobs");
                }
                ensure!(scope.is_none(), "field 'scope' requires \"type\": \"stats\"");
                let timing = timing.unwrap_or(false);
                Ok(Request::Explore(ExploreRequest {
                    id,
                    matrix,
                    spec,
                    bits,
                    space,
                    objective,
                    timing,
                }))
            }
            Some(ty @ ("shutdown" | "stats" | "metrics")) => {
                for (field, present) in [
                    ("matrix", matrix.is_some()),
                    ("bits", bits.is_some()),
                    ("strategy", strategy.is_some()),
                    ("dc", dc.is_some()),
                    ("emit", emit.is_some()),
                    ("spec", spec.is_some()),
                    ("space", space.is_some()),
                    ("objective", objective.is_some()),
                    ("timing", timing.is_some()),
                ] {
                    ensure!(!present, "field '{field}' does not apply to control lines");
                }
                let op = match ty {
                    "stats" => {
                        let scope = match scope.as_deref() {
                            None | Some("server") => StatsScope::Server,
                            Some("connection") => StatsScope::Connection,
                            Some(other) => bail!(
                                "unknown stats scope '{other}' (expected server|connection)"
                            ),
                        };
                        ControlOp::Stats { scope }
                    }
                    other => {
                        ensure!(scope.is_none(), "field 'scope' requires \"type\": \"stats\"");
                        if other == "shutdown" {
                            ControlOp::Shutdown
                        } else {
                            ControlOp::Metrics
                        }
                    }
                };
                Ok(Request::Control(ControlRequest { id, op }))
            }
            Some(other) => {
                bail!(
                    "unknown job type '{other}' \
                     (expected compile|explore|shutdown|stats|metrics)"
                )
            }
        }
    }
}

impl JobRequest {
    /// Streaming-decode one compile request line (no `Value` tree).
    /// Explore and control lines are an error here — use
    /// [`Request::from_json`] for the full wire.
    pub fn from_json(line: &str) -> Result<Self> {
        match Request::from_json(line)? {
            Request::Compile(req) => Ok(req),
            Request::Explore(_) => bail!("explore job where a compile job was expected"),
            Request::Control(_) => bail!("control line where a compile job was expected"),
        }
    }

    /// Parse the optional `"emit"` field (strict, like the strategy
    /// name: an unknown language is an error reply, never ignored).
    pub fn emit_lang(&self) -> Result<Option<EmitLang>> {
        match self.emit.as_deref() {
            None => Ok(None),
            Some("verilog") => Ok(Some(EmitLang::Verilog)),
            Some("vhdl") => Ok(Some(EmitLang::Vhdl)),
            Some(other) => bail!("unknown emit language '{other}' (expected verilog|vhdl)"),
        }
    }

    /// Validate and lower into a [`CompileJob`] (shape checked here so
    /// wire errors carry the serve-level context).
    pub fn to_compile_job(&self, name: String, default_dc: i32) -> Result<CompileJob> {
        let problem = matrix_to_problem(&self.matrix, self.bits)?;
        let dc = self.dc.unwrap_or(default_dc as i64);
        ensure!(
            i32::try_from(dc).is_ok(),
            "dc {dc} out of range (must fit a 32-bit signed integer; -1 = unconstrained)"
        );
        let dc = dc as i32;
        let strategy = parse_strategy(self.strategy.as_deref().unwrap_or("da"), dc)?;
        Ok(CompileJob { name, problem, strategy })
    }
}

/// Validate a wire matrix (shape + bits) into a [`CmvmProblem`] —
/// shared by compile and explore jobs so both wire paths accept
/// exactly the same matrices.
fn matrix_to_problem(matrix: &[Vec<i64>], bits: i64) -> Result<CmvmProblem> {
    let d_in = matrix.len();
    ensure!(d_in > 0, "matrix must have at least one row");
    let d_out = matrix[0].len();
    ensure!(d_out > 0, "matrix rows must be non-empty");
    for (j, row) in matrix.iter().enumerate() {
        ensure!(
            row.len() == d_out,
            "matrix is ragged: row {j} has {} entries, row 0 has {d_out}",
            row.len()
        );
    }
    ensure!((1..=63).contains(&bits), "bits must be in [1, 63], got {bits}");
    let flat: Vec<i64> = matrix.iter().flatten().copied().collect();
    CmvmProblem::new(d_in, d_out, flat, bits as u32)
}

/// Strict strategy-name parser (the CLI's lenient fallback is wrong for
/// a wire protocol: an unknown name must be an error reply, not
/// silently `da`).
pub fn parse_strategy(name: &str, dc: i32) -> Result<Strategy> {
    Ok(match name {
        "da" => Strategy::Da { dc },
        "latency" => Strategy::Latency,
        "naive-da" => Strategy::NaiveDa,
        "cse-only" => Strategy::CseOnly { dc },
        "lookahead" => Strategy::Lookahead { dc },
        other => bail!(
            "unknown strategy '{other}' (expected da|latency|naive-da|cse-only|lookahead)"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::core::module_name;
    use super::*;
    use crate::coordinator::Coordinator;
    use crate::json::{self, Value};
    use crate::util::Rng;
    use std::collections::BTreeMap;
    use std::io::Cursor;

    fn run(input: &str, cfg: &ServeConfig) -> (ServeSummary, Vec<Value>) {
        let mut out = Vec::new();
        let summary = serve(Cursor::new(input.to_string()), &mut out, cfg).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines = text.lines().map(|l| json::parse(l).expect("reply is JSON")).collect();
        (summary, lines)
    }

    #[test]
    fn request_decoding_defaults_and_errors() {
        let req = JobRequest::from_json(r#"{"matrix": [[1, 2], [3, 4]]}"#).unwrap();
        assert_eq!(req.bits, 8);
        assert!(req.id.is_none() && req.strategy.is_none() && req.dc.is_none());
        let job = req.to_compile_job("j".into(), 2).unwrap();
        assert_eq!(job.problem.d_in, 2);
        assert_eq!(job.strategy, Strategy::Da { dc: 2 });

        assert!(JobRequest::from_json("[1]").is_err());
        assert!(JobRequest::from_json(r#"{"matrix": 5}"#).is_err());
        assert!(JobRequest::from_json("{}").is_err());
        let ragged = JobRequest::from_json(r#"{"matrix": [[1, 2], [3]]}"#).unwrap();
        assert!(ragged.to_compile_job("j".into(), -1).is_err());
        let bad_bits = JobRequest::from_json(r#"{"matrix": [[1]], "bits": 64}"#).unwrap();
        assert!(bad_bits.to_compile_job("j".into(), -1).is_err());
        let bad_strategy =
            JobRequest::from_json(r#"{"matrix": [[1]], "strategy": "hls"}"#).unwrap();
        assert!(bad_strategy.to_compile_job("j".into(), -1).is_err());
        // dc must fit i32 — no silent wrap-around on the wire.
        let bad_dc = JobRequest::from_json(r#"{"matrix": [[1]], "dc": 4294967296}"#).unwrap();
        assert!(bad_dc.to_compile_job("j".into(), -1).is_err());
    }

    /// The `"timing"` opt-in decodes on both job types (absent and
    /// explicit `false` are the same request), is a strict boolean,
    /// and is rejected on control lines.
    #[test]
    fn timing_field_decodes_on_jobs_and_is_strict() {
        let req = JobRequest::from_json(r#"{"matrix": [[1]]}"#).unwrap();
        assert!(!req.timing);
        let req = JobRequest::from_json(r#"{"matrix": [[1]], "timing": false}"#).unwrap();
        assert!(!req.timing);
        let req = JobRequest::from_json(r#"{"matrix": [[1]], "timing": true}"#).unwrap();
        assert!(req.timing);
        match Request::from_json(r#"{"type": "explore", "matrix": [[1]], "timing": true}"#)
            .unwrap()
        {
            Request::Explore(req) => assert!(req.timing),
            other => panic!("expected explore job, got {other:?}"),
        }
        assert!(Request::from_json(r#"{"matrix": [[1]], "timing": 1}"#).is_err());
        assert!(Request::from_json(r#"{"type": "shutdown", "timing": true}"#).is_err());
        assert!(Request::from_json(r#"{"type": "stats", "timing": true}"#).is_err());
        assert!(Request::from_json(r#"{"type": "metrics", "timing": false}"#).is_err());
    }

    /// Control lines decode on the shared wire; job fields on a control
    /// line are strict errors (same policy as cross-type job fields).
    #[test]
    fn control_lines_decode_and_are_strict() {
        match Request::from_json(r#"{"type": "shutdown"}"#).unwrap() {
            Request::Control(c) => {
                assert_eq!(c.op, ControlOp::Shutdown);
                assert!(c.id.is_none());
            }
            other => panic!("expected control line, got {other:?}"),
        }
        match Request::from_json(r#"{"type": "stats", "id": "s1"}"#).unwrap() {
            Request::Control(c) => {
                assert_eq!(c.op, ControlOp::Stats { scope: StatsScope::Server });
                assert_eq!(c.id.as_deref(), Some("s1"));
            }
            other => panic!("expected control line, got {other:?}"),
        }
        // The stats scope field: explicit server, connection, unknown.
        match Request::from_json(r#"{"type": "stats", "scope": "server"}"#).unwrap() {
            Request::Control(c) => {
                assert_eq!(c.op, ControlOp::Stats { scope: StatsScope::Server })
            }
            other => panic!("expected control line, got {other:?}"),
        }
        match Request::from_json(r#"{"type": "stats", "scope": "connection"}"#).unwrap() {
            Request::Control(c) => {
                assert_eq!(c.op, ControlOp::Stats { scope: StatsScope::Connection })
            }
            other => panic!("expected control line, got {other:?}"),
        }
        assert!(Request::from_json(r#"{"type": "stats", "scope": "galaxy"}"#).is_err());
        // The metrics control line returns the obs snapshot; scope (and
        // every job field) is rejected on it.
        match Request::from_json(r#"{"type": "metrics", "id": "m1"}"#).unwrap() {
            Request::Control(c) => {
                assert_eq!(c.op, ControlOp::Metrics);
                assert_eq!(c.id.as_deref(), Some("m1"));
            }
            other => panic!("expected control line, got {other:?}"),
        }
        assert!(Request::from_json(r#"{"type": "metrics", "scope": "server"}"#).is_err());
        assert!(Request::from_json(r#"{"type": "metrics", "matrix": [[1]]}"#).is_err());
        assert!(Request::from_json(r#"{"type": "shutdown", "scope": "connection"}"#).is_err());
        // Scope is stats-only: job lines must reject it too.
        assert!(Request::from_json(r#"{"matrix": [[1]], "scope": "connection"}"#).is_err());
        assert!(Request::from_json(
            r#"{"type": "explore", "matrix": [[1]], "scope": "connection"}"#
        )
        .is_err());
        assert!(Request::from_json(r#"{"type": "shutdown", "matrix": [[1]]}"#).is_err());
        assert!(Request::from_json(r#"{"type": "stats", "objective": "knee"}"#).is_err());
        assert!(Request::from_json(r#"{"type": "restart"}"#).is_err());
        // A control line is not a compile job.
        assert!(JobRequest::from_json(r#"{"type": "shutdown"}"#).is_err());
    }

    /// The byte-slice decode path is the same wire: identical requests,
    /// identical errors.
    #[test]
    fn from_json_bytes_matches_from_json() {
        let lines = [
            r#"{"id": "a", "matrix": [[3, 5], [-7, 9]], "dc": -1}"#,
            r#"{"type": "explore", "matrix": [[1]], "objective": "knee"}"#,
            r#"{"type": "shutdown"}"#,
            r#"{"matrix": 5}"#,
        ];
        for line in lines {
            let s = Request::from_json(line).map(|r| format!("{r:?}"));
            let b = Request::from_json_bytes(line.as_bytes()).map(|r| format!("{r:?}"));
            match (s, b) {
                (Ok(s), Ok(b)) => assert_eq!(s, b),
                (Err(_), Err(_)) => {}
                (s, b) => panic!("decode paths disagree on {line}: {s:?} vs {b:?}"),
            }
        }
        assert!(Request::from_json_bytes(&[0xFF, 0xFE, b'{']).is_err());
    }

    /// Stdin-transport control lines: `stats` answers with an on-demand
    /// stats line (after flushing the pending batch), `shutdown` flushes
    /// and stops reading — lines after it are never answered.
    #[test]
    fn stdin_control_lines_stats_and_shutdown() {
        let input = "\
{\"id\": \"a\", \"matrix\": [[3, 5], [-7, 9]], \"dc\": -1}\n\
{\"type\": \"stats\"}\n\
{\"id\": \"b\", \"matrix\": [[2, 3], [5, 7]], \"dc\": -1}\n\
{\"type\": \"shutdown\"}\n\
{\"id\": \"never\", \"matrix\": [[1]], \"dc\": -1}\n";
        let (summary, lines) = run(input, &ServeConfig::default());
        assert_eq!(summary.jobs, 2);
        assert_eq!(summary.replies, 2);
        assert_eq!(summary.batches, 2);
        // result a, batch stats, on-demand stats, result b, batch
        // stats, final (shutdown) stats — and nothing for "never".
        assert_eq!(lines.len(), 6);
        assert_eq!(lines[0].get("id").unwrap().as_str().unwrap(), "a");
        assert_eq!(lines[1].get("type").unwrap().as_str().unwrap(), "stats");
        assert_eq!(lines[2].get("type").unwrap().as_str().unwrap(), "stats");
        assert_eq!(lines[3].get("id").unwrap().as_str().unwrap(), "b");
        assert_eq!(lines[4].get("type").unwrap().as_str().unwrap(), "stats");
        assert_eq!(lines[5].get("type").unwrap().as_str().unwrap(), "stats");
        assert_eq!(lines[5].get("submitted").unwrap().as_i64().unwrap(), 2);
        for line in &lines {
            if let Ok(id) = line.get("id") {
                assert_ne!(id.as_str().unwrap_or(""), "never");
            }
        }
    }

    /// The observability control lines on the stdin transport:
    /// `{"type": "metrics"}` answers with the schema-versioned
    /// snapshot, connection-scope stats with the stream's own counters.
    #[test]
    fn stdin_control_lines_metrics_and_connection_stats() {
        let input = "\
{\"id\": \"a\", \"matrix\": [[3, 5], [-7, 9]], \"dc\": -1}\n\
{\"type\": \"stats\", \"scope\": \"connection\"}\n\
{\"type\": \"metrics\", \"id\": \"snap\"}\n";
        let (summary, lines) = run(input, &ServeConfig::default());
        assert_eq!(summary.jobs, 1);
        // result a, batch stats (control lines flush first), connection
        // stats, metrics — EOF on an empty batch adds nothing.
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0].get("id").unwrap().as_str().unwrap(), "a");
        assert_eq!(lines[1].get("type").unwrap().as_str().unwrap(), "stats");
        let conn = &lines[2];
        assert_eq!(conn.get("type").unwrap().as_str().unwrap(), "stats");
        assert_eq!(conn.get("scope").unwrap().as_str().unwrap(), "connection");
        assert_eq!(conn.get("jobs").unwrap().as_i64().unwrap(), 1);
        assert_eq!(conn.get("errors").unwrap().as_i64().unwrap(), 0);
        assert!(conn.get("submitted").is_err(), "server-wide field on a connection line");
        let metrics = &lines[3];
        assert_eq!(metrics.get("type").unwrap().as_str().unwrap(), "metrics");
        assert_eq!(metrics.get("id").unwrap().as_str().unwrap(), "snap");
        assert_eq!(metrics.get("kind").unwrap().as_str().unwrap(), "obs_metrics");
        assert_eq!(
            metrics.get("schema_version").unwrap().as_i64().unwrap(),
            crate::obs::schema::SCHEMA_VERSION as i64
        );
        assert!(metrics.get("counters").unwrap().as_object().is_ok());
        assert!(metrics.get("histograms").unwrap().as_object().is_ok());
    }

    /// A non-UTF-8 input line becomes one more error reply; the jobs
    /// around it still compile and stream back (no service teardown).
    #[test]
    fn non_utf8_line_is_an_error_reply_not_a_teardown() {
        let mut input: Vec<u8> = Vec::new();
        input.extend_from_slice(b"{\"id\": \"a\", \"matrix\": [[3, 5], [-7, 9]], \"dc\": -1}\n");
        input.extend_from_slice(&[0xFF, 0xFE, b'\n']);
        input.extend_from_slice(b"{\"id\": \"b\", \"matrix\": [[2, 3], [5, 7]], \"dc\": -1}\n");
        let mut out = Vec::new();
        let summary = serve(Cursor::new(input), &mut out, &ServeConfig::default()).unwrap();
        assert_eq!(summary.jobs, 2);
        assert_eq!(summary.errors, 1);
        assert_eq!(summary.replies, 3);
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<Value> = text.lines().map(|l| json::parse(l).unwrap()).collect();
        assert_eq!(lines.len(), 4); // result, error, result, stats
        assert_eq!(lines[0].get("id").unwrap().as_str().unwrap(), "a");
        assert_eq!(lines[1].get("type").unwrap().as_str().unwrap(), "error");
        assert!(lines[1].get("error").unwrap().as_str().unwrap().contains("line 2"));
        assert_eq!(lines[2].get("id").unwrap().as_str().unwrap(), "b");
    }

    /// Default ids number *input lines* (1-based), blank lines included,
    /// so `job-<line#>` correlates with the caller's file.
    #[test]
    fn default_ids_match_input_line_numbers() {
        let input = "{\"matrix\": [[1]], \"dc\": -1}\n\n{\"matrix\": [[2]], \"dc\": -1}\n";
        let (summary, lines) = run(input, &ServeConfig::default());
        assert_eq!(summary.jobs, 2);
        assert_eq!(summary.replies, 2);
        let ids: Vec<String> = lines
            .iter()
            .filter(|l| l.get("type").unwrap().as_str().unwrap() == "result")
            .map(|l| l.get("id").unwrap().as_str().unwrap().to_string())
            .collect();
        assert_eq!(ids, vec!["job-1".to_string(), "job-3".to_string()]);
    }

    #[test]
    fn serve_streams_results_errors_and_stats() {
        // batch 1: [a, ragged]; batch 2: [not-json, a2]. Splitting the
        // identical jobs across batches makes the cache hit
        // deterministic (within one batch, duplicates may race).
        let input = r#"
{"id": "a", "matrix": [[3, 5], [-7, 9]], "dc": -1}
{"id": "bad", "matrix": [[1], [2, 3]]}
not even json
{"id": "a2", "matrix": [[3, 5], [-7, 9]], "dc": -1}
"#;
        let cfg = ServeConfig { batch_size: 2, ..ServeConfig::default() };
        let (summary, lines) = run(input, &cfg);
        assert_eq!(summary.jobs, 2);
        assert_eq!(summary.errors, 2);
        assert_eq!(summary.batches, 2);
        assert_eq!(summary.stats.cache_hits, 1);
        // (result, error, stats) then (error, result, stats), input order.
        assert_eq!(lines.len(), 6);
        assert_eq!(lines[0].get("type").unwrap().as_str().unwrap(), "result");
        assert_eq!(lines[0].get("id").unwrap().as_str().unwrap(), "a");
        assert_eq!(lines[0].get("cached").unwrap().as_bool().unwrap(), false);
        assert_eq!(lines[1].get("type").unwrap().as_str().unwrap(), "error");
        assert_eq!(lines[1].get("id").unwrap().as_str().unwrap(), "bad");
        assert!(lines[1].get("error").unwrap().as_str().unwrap().contains("ragged"));
        assert_eq!(lines[2].get("type").unwrap().as_str().unwrap(), "stats");
        assert_eq!(lines[3].get("type").unwrap().as_str().unwrap(), "error");
        assert_eq!(lines[3].get("id").unwrap(), &Value::Null);
        assert_eq!(lines[4].get("id").unwrap().as_str().unwrap(), "a2");
        assert_eq!(lines[4].get("cached").unwrap().as_bool().unwrap(), true);
        // Identical jobs report identical solutions.
        assert_eq!(
            lines[0].get("adders").unwrap().as_i64().unwrap(),
            lines[4].get("adders").unwrap().as_i64().unwrap()
        );
        let stats = &lines[5];
        assert_eq!(stats.get("type").unwrap().as_str().unwrap(), "stats");
        assert_eq!(stats.get("submitted").unwrap().as_i64().unwrap(), 2);
        assert_eq!(stats.get("cache_hits").unwrap().as_i64().unwrap(), 1);
        assert_eq!(stats.get("cache_size").unwrap().as_i64().unwrap(), 1);
    }

    #[test]
    fn batching_flushes_stats_per_batch() {
        let mut input = String::new();
        for i in 0..5 {
            input.push_str(&format!(
                "{{\"id\": \"j{i}\", \"matrix\": [[{}, 3], [5, {}]], \"dc\": -1}}\n",
                i + 1,
                i + 2
            ));
        }
        let cfg = ServeConfig { batch_size: 2, ..ServeConfig::default() };
        let (summary, lines) = run(&input, &cfg);
        assert_eq!(summary.jobs, 5);
        assert_eq!(summary.batches, 3); // 2 + 2 + 1
        let stats_lines: Vec<_> = lines
            .iter()
            .filter(|l| l.get("type").unwrap().as_str().unwrap() == "stats")
            .collect();
        assert_eq!(stats_lines.len(), 3);
        // Stats are cumulative; the last line covers all jobs.
        assert_eq!(stats_lines[2].get("submitted").unwrap().as_i64().unwrap(), 5);
    }

    /// The optional `"emit"` field returns combinational RTL text in
    /// the reply; unknown languages are error replies, and ids are
    /// sanitized into legal module names.
    #[test]
    fn emit_field_returns_rtl_text() {
        let input = r#"
{"id": "fc-1", "matrix": [[3, 5], [-7, 9]], "dc": -1, "emit": "verilog"}
{"id": "fc-1v", "matrix": [[3, 5], [-7, 9]], "dc": -1, "emit": "vhdl"}
{"id": "plain", "matrix": [[3, 5], [-7, 9]], "dc": -1}
{"id": "bad", "matrix": [[3, 5], [-7, 9]], "dc": -1, "emit": "systemverilog"}
"#;
        let cfg = ServeConfig { batch_size: 1, ..ServeConfig::default() };
        let (summary, lines) = run(input, &cfg);
        assert_eq!(summary.jobs, 3);
        assert_eq!(summary.errors, 1);
        let verilog = lines[0].get("rtl").unwrap().as_str().unwrap();
        assert!(verilog.contains("module fc_1 ("), "id sanitized into module name");
        assert!(verilog.contains("endmodule"));
        assert!(!verilog.contains("clk"), "serve emits combinational RTL");
        let vhdl = lines[2].get("rtl").unwrap().as_str().unwrap();
        assert!(vhdl.contains("entity fc_1v is"));
        assert!(vhdl.contains("end architecture;"));
        // No emit -> no rtl field.
        assert!(lines[4].get("rtl").is_err());
        assert_eq!(lines[6].get("type").unwrap().as_str().unwrap(), "error");
        assert!(lines[6]
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("unknown emit language"));
    }

    /// The explore job type: a matrix target replies with a Pareto
    /// front (plus the picked point when an objective is posted), and
    /// malformed explore jobs fail at lowering time — immediate error
    /// replies carrying the job id, never counted as jobs.
    #[test]
    fn explore_job_replies_with_front() {
        let input = r#"
{"type": "explore", "id": "x1", "matrix": [[3, 5], [-7, 9]], "objective": "min-lut"}
{"type": "explore", "id": "both"}
{"type": "explore", "id": "bad-space", "matrix": [[1]], "space": "galaxy"}
{"type": "explore", "id": "bad-obj", "matrix": [[1]], "objective": "fastest"}
"#;
        let (summary, lines) = run(input, &ServeConfig::default());
        // Validation failures never reach the explorer: same accounting
        // as malformed compile jobs (errors, not jobs).
        assert_eq!(summary.jobs, 1);
        assert_eq!(summary.errors, 3);
        assert_eq!(summary.replies, 4);
        let reply = &lines[0];
        assert_eq!(reply.get("type").unwrap().as_str().unwrap(), "explore");
        assert_eq!(reply.get("id").unwrap().as_str().unwrap(), "x1");
        assert_eq!(reply.get("target").unwrap().as_str().unwrap(), "cmvm/2x2");
        let front = reply.get("front").unwrap().as_array().unwrap();
        assert!(!front.is_empty());
        let picked = reply.get("picked").unwrap();
        let min_lut = front
            .iter()
            .map(|p| p.get("lut").unwrap().as_i64().unwrap())
            .min()
            .unwrap();
        assert_eq!(picked.get("lut").unwrap().as_i64().unwrap(), min_lut);
        assert_eq!(reply.get("objective").unwrap().as_str().unwrap(), "min-lut");
        // Lowering-time failures still correlate with the posted id.
        assert_eq!(lines[1].get("id").unwrap().as_str().unwrap(), "both");
        assert!(lines[1]
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("exactly one of 'matrix' or 'spec'"));
        assert!(lines[2].get("error").unwrap().as_str().unwrap().contains("galaxy"));
        assert!(lines[3].get("error").unwrap().as_str().unwrap().contains("fastest"));
    }

    /// An inline network spec explores through the same wire; compile
    /// fields on an explore line (and vice versa) are strict errors,
    /// as is `bits` on a spec target (the spec carries its own).
    #[test]
    fn explore_spec_target_and_field_strictness() {
        let spec = crate::bench_tables::synthetic_jet_spec_scaled(1, 8).to_json();
        let input = format!(
            "{{\"type\": \"explore\", \"id\": \"net\", \"spec\": {spec}}}\n\
             {{\"type\": \"explore\", \"id\": \"s1\", \"matrix\": [[1]], \"strategy\": \"da\"}}\n\
             {{\"id\": \"c1\", \"matrix\": [[1]], \"space\": \"smoke\"}}\n\
             {{\"type\": \"explore\", \"id\": \"sb\", \"spec\": {spec}, \"bits\": 4}}\n"
        );
        let (summary, lines) = run(&input, &ServeConfig::default());
        // The strict-field violations fail at decode/lowering time (no
        // job was formed), so only the spec exploration counts as a job.
        assert_eq!(summary.jobs, 1);
        assert_eq!(summary.errors, 3);
        assert_eq!(summary.replies, 4);
        let reply = &lines[0];
        assert_eq!(reply.get("type").unwrap().as_str().unwrap(), "explore");
        assert!(!reply.get("front").unwrap().as_array().unwrap().is_empty());
        assert!(lines[1]
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("does not apply to explore jobs"));
        assert!(lines[2]
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("requires \"type\": \"explore\""));
        assert_eq!(lines[3].get("id").unwrap().as_str().unwrap(), "sb");
        assert!(lines[3]
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("does not apply to spec targets"));
    }

    /// `--cache-cap` bounds the coordinator cache; the stats line
    /// reports evictions and the service keeps answering correctly.
    #[test]
    fn cache_cap_bounds_the_serve_cache() {
        let mut input = String::new();
        for i in 0..4 {
            input.push_str(&format!(
                "{{\"id\": \"j{i}\", \"matrix\": [[{}, 3], [5, {}]], \"dc\": -1}}\n",
                i + 1,
                i + 2
            ));
        }
        let cfg = ServeConfig {
            batch_size: 1,
            cache_cap: Some(2),
            ..ServeConfig::default()
        };
        let (summary, lines) = run(&input, &cfg);
        assert_eq!(summary.jobs, 4);
        assert_eq!(summary.errors, 0);
        assert_eq!(summary.stats.evictions, 2);
        let last_stats = lines.last().unwrap();
        assert_eq!(last_stats.get("cache_size").unwrap().as_i64().unwrap(), 2);
        assert_eq!(last_stats.get("cache_evictions").unwrap().as_i64().unwrap(), 2);
    }

    #[test]
    fn module_names_are_sanitized() {
        assert_eq!(module_name("fc-1"), "fc_1");
        assert_eq!(module_name("layer.0/dense"), "layer_0_dense");
        assert_eq!(module_name("0abc"), "m_0abc");
        assert_eq!(module_name(""), "m_");
        assert_eq!(module_name("ok_name"), "ok_name");
    }

    /// `--cache-shards` must be invisible on the wire: the same input
    /// served over 1 shard and over 4 shards yields byte-identical
    /// reply lines once the two wall-clock fields (`opt_ms`,
    /// `total_opt_ms`) are masked — and the masked fields themselves
    /// only differ because they are timings, not because the solutions
    /// or the accounting do.
    #[test]
    fn sharded_serve_replies_match_single_shard_byte_for_byte() {
        let mut input = String::new();
        for i in 0..6 {
            // Repeat every matrix once so both layouts serve a mix of
            // misses and hits. No cache cap: a cap legitimately changes
            // eviction timing across shard layouts (it splits
            // per-shard), which is exactly why the determinism claim is
            // scoped to the uncapped cache.
            let line = format!(
                "{{\"id\": \"j{i}\", \"matrix\": [[{}, 3], [5, {}]], \"dc\": -1}}\n",
                i + 1,
                i + 2
            );
            input.push_str(&line);
            input.push_str(&line);
        }
        let mask_timing = |lines: Vec<Value>| -> Vec<String> {
            lines
                .into_iter()
                .map(|mut v| {
                    if let Value::Object(o) = &mut v {
                        for key in ["opt_ms", "total_opt_ms"] {
                            if o.contains_key(key) {
                                o.insert(key.into(), Value::Int(0));
                            }
                        }
                    }
                    json::to_string(&v)
                })
                .collect()
        };
        let run_with_shards = |shards: usize| {
            let cfg = ServeConfig {
                batch_size: 1,
                cache_shards: shards,
                ..ServeConfig::default()
            };
            run(&input, &cfg)
        };
        let (sum1, lines1) = run_with_shards(1);
        let (sum4, lines4) = run_with_shards(4);
        assert_eq!(sum1.jobs, 12);
        assert_eq!(sum4.jobs, 12);
        assert_eq!(sum1.stats.submitted, sum4.stats.submitted);
        assert_eq!(sum1.stats.cache_hits, sum4.stats.cache_hits);
        let masked1 = mask_timing(lines1);
        let mut masked4 = mask_timing(lines4);
        // The only licensed difference: the stats lines advertise their
        // own shard count.
        for line in &mut masked4 {
            *line = line.replace("\"cache_shards\":4", "\"cache_shards\":1");
        }
        assert_eq!(masked1, masked4);
    }

    /// The stats line advertises the deployment shape: shard count and
    /// how many solutions arrived from a persisted cache file.
    #[test]
    fn stats_line_reports_shards_and_loaded() {
        let input = "{\"id\": \"a\", \"matrix\": [[3, 5], [-7, 9]], \"dc\": -1}\n";
        let cfg = ServeConfig { cache_shards: 3, ..ServeConfig::default() };
        let (_, lines) = run(input, &cfg);
        let stats = lines.last().unwrap();
        assert_eq!(stats.get("cache_shards").unwrap().as_i64().unwrap(), 3);
        assert_eq!(stats.get("cache_loaded").unwrap().as_i64().unwrap(), 0);
    }

    /// Warm restart through [`serve_with`]: a reply served from a
    /// loaded-from-disk cache is byte-identical to one served from the
    /// live cache that was saved — including the exact `opt_ms` (the
    /// persisted nanosecond counter round-trips).
    #[test]
    fn loaded_cache_serves_byte_identical_replies() {
        let job = crate::coordinator::CompileJob {
            name: "warm".into(),
            problem: CmvmProblem::new(2, 2, vec![3, 5, -7, 9], 8).unwrap(),
            strategy: Strategy::Da { dc: -1 },
        };
        let live = Coordinator::new();
        live.compile_cached(&job).unwrap();
        let saved = live.save_cache();

        let input = "{\"id\": \"a\", \"matrix\": [[3, 5], [-7, 9]], \"dc\": -1}\n";
        let cfg = ServeConfig::default();
        let mut out_live = Vec::new();
        let sum_live =
            serve_with(&live, Cursor::new(input), &mut out_live, &cfg).unwrap();
        assert_eq!(sum_live.stats.cache_hits, 1, "live cache answers the wire job");

        let warm = Coordinator::new();
        assert_eq!(warm.load_cache(&saved).unwrap(), 1);
        let mut out_warm = Vec::new();
        let sum_warm =
            serve_with(&warm, Cursor::new(input), &mut out_warm, &cfg).unwrap();
        assert_eq!(sum_warm.stats.cache_hits, 1, "loaded cache answers the wire job");

        let reply_live = String::from_utf8(out_live).unwrap();
        let reply_warm = String::from_utf8(out_warm).unwrap();
        // Result lines are byte-identical; only the stats lines differ
        // (the warm run reports cache_loaded=1, the live one carries
        // the pre-serve compile in submitted/total_opt_ms).
        assert_eq!(reply_live.lines().next().unwrap(), reply_warm.lines().next().unwrap());
        assert!(reply_live.lines().next().unwrap().contains("\"cached\":true"));
        let warm_stats = json::parse(reply_warm.lines().nth(1).unwrap()).unwrap();
        assert_eq!(warm_stats.get("cache_loaded").unwrap().as_i64().unwrap(), 1);
    }

    /// Within one batch, duplicate jobs may race to a miss; the
    /// cache-hit accounting must still be visible across batches.
    #[test]
    fn cross_batch_cache_hits_are_deterministic() {
        let one = "{\"id\": \"x\", \"matrix\": [[3, 5], [-7, 9]], \"dc\": -1}\n";
        let input = format!("{one}{one}{one}");
        let cfg = ServeConfig { batch_size: 1, ..ServeConfig::default() };
        let (summary, lines) = run(&input, &cfg);
        assert_eq!(summary.stats.cache_hits, 2);
        let cached: Vec<bool> = lines
            .iter()
            .filter(|l| l.get("type").unwrap().as_str().unwrap() == "result")
            .map(|l| l.get("cached").unwrap().as_bool().unwrap())
            .collect();
        assert_eq!(cached, vec![false, true, true]);
    }

    // ---- wire round-trip property: streaming decoder vs the legacy
    // DOM parser (the differential idiom from `json/legacy.rs`, lifted
    // to the serve wire). A request encoded to JSONL must decode to the
    // identical `Request` through `Request::from_json`,
    // `Request::from_json_bytes`, and a DOM-walking reference decoder
    // built on `json::legacy::parse`. ----

    fn rand_id(rng: &mut Rng) -> String {
        let stems = ["job", "fc-1", "layer.0/dense", "ünïcode ✓", "quo\"te\\slash", "nl\nnl", ""];
        let stem = stems[rng.below(stems.len())];
        format!("{stem}{}", rng.below(100))
    }

    fn rand_matrix(rng: &mut Rng) -> Vec<Vec<i64>> {
        let rows = 1 + rng.below(3);
        let cols = 1 + rng.below(3);
        (0..rows)
            .map(|_| (0..cols).map(|_| rng.range_i64(-255, 255)).collect())
            .collect()
    }

    fn mat_value(matrix: &[Vec<i64>]) -> Value {
        Value::Array(
            matrix
                .iter()
                .map(|row| Value::Array(row.iter().map(|&w| Value::Int(w)).collect()))
                .collect(),
        )
    }

    /// Generate one random request: the JSONL line and the `Request`
    /// its decode must produce. Field values are drawn beyond the
    /// *valid* sets on purpose (unknown strategies, out-of-range bits):
    /// decoding keeps them verbatim — validation is a lowering-time
    /// concern, and the round trip must not depend on it.
    fn random_request_line(rng: &mut Rng) -> (String, Request) {
        let mut o: BTreeMap<String, Value> = BTreeMap::new();
        let id = if rng.chance(0.7) { Some(rand_id(rng)) } else { None };
        if let Some(id) = &id {
            o.insert("id".into(), Value::Str(id.clone()));
        }
        if rng.chance(0.3) {
            // Unknown fields are skipped by every decoder on the wire.
            o.insert(
                "x-trace".into(),
                Value::Array(vec![Value::Int(1), Value::Null, Value::Str("t".into())]),
            );
        }
        let expected = match rng.below(4) {
            kind @ (0 | 1) => {
                if kind == 1 {
                    o.insert("type".into(), Value::Str("compile".into()));
                }
                let matrix = rand_matrix(rng);
                o.insert("matrix".into(), mat_value(&matrix));
                let bits = if rng.chance(0.5) { Some(rng.range_i64(-2, 70)) } else { None };
                if let Some(b) = bits {
                    o.insert("bits".into(), Value::Int(b));
                }
                let strategy = if rng.chance(0.4) {
                    let names = ["da", "latency", "naive-da", "cse-only", "lookahead", "hls"];
                    Some(names[rng.below(names.len())].to_string())
                } else {
                    None
                };
                if let Some(s) = &strategy {
                    o.insert("strategy".into(), Value::Str(s.clone()));
                }
                let dc = if rng.chance(0.4) { Some(rng.range_i64(-1, 8)) } else { None };
                if let Some(dc) = dc {
                    o.insert("dc".into(), Value::Int(dc));
                }
                let emit = if rng.chance(0.3) {
                    let langs = ["verilog", "vhdl", "systemverilog"];
                    Some(langs[rng.below(langs.len())].to_string())
                } else {
                    None
                };
                if let Some(e) = &emit {
                    o.insert("emit".into(), Value::Str(e.clone()));
                }
                // Explicit false must decode like an absent field.
                let timing = if rng.chance(0.3) { Some(rng.chance(0.5)) } else { None };
                if let Some(t) = timing {
                    o.insert("timing".into(), Value::Bool(t));
                }
                Request::Compile(JobRequest {
                    id,
                    matrix,
                    bits: bits.unwrap_or(8),
                    strategy,
                    dc,
                    emit,
                    timing: timing.unwrap_or(false),
                })
            }
            2 => {
                o.insert("type".into(), Value::Str("explore".into()));
                // Matrix targets only: an inline network spec has its
                // own decoder with its own differential tests, and the
                // DOM reference below deliberately stays spec-free.
                let matrix = rand_matrix(rng);
                o.insert("matrix".into(), mat_value(&matrix));
                let bits = if rng.chance(0.4) { Some(rng.range_i64(1, 63)) } else { None };
                if let Some(b) = bits {
                    o.insert("bits".into(), Value::Int(b));
                }
                let space = if rng.chance(0.5) {
                    let names = ["smoke", "full", "galaxy"];
                    Some(names[rng.below(names.len())].to_string())
                } else {
                    None
                };
                if let Some(s) = &space {
                    o.insert("space".into(), Value::Str(s.clone()));
                }
                let objective = if rng.chance(0.5) {
                    let names = ["min-lut", "min-latency", "knee", "fastest"];
                    Some(names[rng.below(names.len())].to_string())
                } else {
                    None
                };
                if let Some(obj) = &objective {
                    o.insert("objective".into(), Value::Str(obj.clone()));
                }
                let timing = if rng.chance(0.3) { Some(rng.chance(0.5)) } else { None };
                if let Some(t) = timing {
                    o.insert("timing".into(), Value::Bool(t));
                }
                Request::Explore(ExploreRequest {
                    id,
                    matrix: Some(matrix),
                    spec: None,
                    bits,
                    space,
                    objective,
                    timing: timing.unwrap_or(false),
                })
            }
            _ => {
                let (ty, op) = match rng.below(4) {
                    0 => ("shutdown", ControlOp::Shutdown),
                    1 => ("metrics", ControlOp::Metrics),
                    _ => {
                        let scope = if rng.chance(0.5) {
                            let names = ["server", "connection"];
                            Some(names[rng.below(names.len())])
                        } else {
                            None
                        };
                        if let Some(s) = scope {
                            o.insert("scope".into(), Value::Str(s.into()));
                        }
                        let scope = match scope {
                            Some("connection") => StatsScope::Connection,
                            _ => StatsScope::Server,
                        };
                        ("stats", ControlOp::Stats { scope })
                    }
                };
                o.insert("type".into(), Value::Str(ty.into()));
                Request::Control(ControlRequest { id, op })
            }
        };
        (json::to_string(&Value::Object(o)), expected)
    }

    /// Reference decoder: the same wire semantics, written against the
    /// retained recursive-descent DOM parser instead of the streaming
    /// pull decoder. Test-only, matrix targets only (no inline specs).
    fn request_from_dom(line: &str) -> Result<Request> {
        let v = crate::json::legacy::parse(line)?;
        let obj = match &v {
            Value::Object(o) => o,
            other => bail!("request line must be a JSON object, got {other:?}"),
        };
        ensure!(obj.get("spec").is_none(), "inline specs are outside the DOM reference");
        let get_str = |key: &str| -> Result<Option<String>> {
            match obj.get(key) {
                None => Ok(None),
                Some(v) => Ok(Some(v.as_str()?.to_string())),
            }
        };
        let get_i64 = |key: &str| -> Result<Option<i64>> {
            match obj.get(key) {
                None => Ok(None),
                Some(v) => Ok(Some(v.as_i64()?)),
            }
        };
        let get_bool = |key: &str| -> Result<Option<bool>> {
            match obj.get(key) {
                None => Ok(None),
                Some(v) => Ok(Some(v.as_bool()?)),
            }
        };
        let ty = get_str("type")?;
        let id = get_str("id")?;
        let matrix = match obj.get("matrix") {
            None => None,
            Some(v) => Some(v.to_i64_mat()?),
        };
        let bits = get_i64("bits")?;
        let strategy = get_str("strategy")?;
        let dc = get_i64("dc")?;
        let emit = get_str("emit")?;
        let space = get_str("space")?;
        let objective = get_str("objective")?;
        let scope = get_str("scope")?;
        let timing = get_bool("timing")?;
        match ty.as_deref() {
            None | Some("compile") => {
                ensure!(space.is_none() && objective.is_none(), "explore-only field");
                ensure!(scope.is_none(), "stats-only field");
                let matrix = matrix.ok_or_else(|| anyhow::anyhow!("missing field 'matrix'"))?;
                Ok(Request::Compile(JobRequest {
                    id,
                    matrix,
                    bits: bits.unwrap_or(8),
                    strategy,
                    dc,
                    emit,
                    timing: timing.unwrap_or(false),
                }))
            }
            Some("explore") => {
                ensure!(
                    strategy.is_none() && dc.is_none() && emit.is_none(),
                    "compile-only field"
                );
                ensure!(scope.is_none(), "stats-only field");
                Ok(Request::Explore(ExploreRequest {
                    id,
                    matrix,
                    spec: None,
                    bits,
                    space,
                    objective,
                    timing: timing.unwrap_or(false),
                }))
            }
            Some(ty @ ("shutdown" | "stats" | "metrics")) => {
                ensure!(
                    matrix.is_none()
                        && bits.is_none()
                        && strategy.is_none()
                        && dc.is_none()
                        && emit.is_none()
                        && space.is_none()
                        && objective.is_none()
                        && timing.is_none(),
                    "job field on a control line"
                );
                let op = match ty {
                    "stats" => {
                        let scope = match scope.as_deref() {
                            None | Some("server") => StatsScope::Server,
                            Some("connection") => StatsScope::Connection,
                            Some(other) => bail!("unknown stats scope '{other}'"),
                        };
                        ControlOp::Stats { scope }
                    }
                    other => {
                        ensure!(scope.is_none(), "stats-only field");
                        if other == "shutdown" { ControlOp::Shutdown } else { ControlOp::Metrics }
                    }
                };
                Ok(Request::Control(ControlRequest { id, op }))
            }
            Some(other) => bail!("unknown job type '{other}'"),
        }
    }

    #[test]
    fn wire_round_trip_matches_legacy_dom_decoder() {
        crate::util::property("serve wire round trip", 128, |rng| {
            let (line, expected) = random_request_line(rng);
            let expected = format!("{expected:?}");
            let streamed = Request::from_json(&line)
                .unwrap_or_else(|e| panic!("streaming decode failed on {line}: {e:#}"));
            assert_eq!(format!("{streamed:?}"), expected, "streaming decode of {line}");
            let bytes = Request::from_json_bytes(line.as_bytes())
                .unwrap_or_else(|e| panic!("byte decode failed on {line}: {e:#}"));
            assert_eq!(format!("{bytes:?}"), expected, "byte decode of {line}");
            let dom = request_from_dom(&line)
                .unwrap_or_else(|e| panic!("DOM decode failed on {line}: {e:#}"));
            assert_eq!(format!("{dom:?}"), expected, "DOM decode of {line}");
        });
    }

    /// The two decoders must also agree on *rejection*: a line one
    /// refuses, the other must refuse too (messages may differ — the
    /// contract is the accept set, not the prose).
    #[test]
    fn wire_rejections_match_legacy_dom_decoder() {
        let fixtures = [
            r#"{"matrix": [[1]], "space": "smoke"}"#,
            r#"{"type": "explore", "matrix": [[1]], "dc": 2}"#,
            r#"{"type": "shutdown", "matrix": [[1]]}"#,
            r#"{"type": "metrics", "matrix": [[1]]}"#,
            r#"{"type": "metrics", "scope": "server"}"#,
            r#"{"type": "shutdown", "scope": "connection"}"#,
            r#"{"type": "stats", "scope": "galaxy"}"#,
            r#"{"matrix": [[1]], "scope": "connection"}"#,
            r#"{"type": "explore", "matrix": [[1]], "scope": "server"}"#,
            r#"{"type": "warmup"}"#,
            r#"{"type": "shutdown", "timing": true}"#,
            r#"{"type": "stats", "timing": false}"#,
            r#"{"type": "metrics", "timing": true}"#,
            r#"{"matrix": [[1]], "timing": "yes"}"#,
            r#"{"matrix": [[1]], "bits": "eight"}"#,
            r#"{}"#,
            r#"[1, 2]"#,
            r#"not even json"#,
        ];
        for line in fixtures {
            assert!(Request::from_json(line).is_err(), "streaming accepted {line}");
            assert!(request_from_dom(line).is_err(), "DOM accepted {line}");
        }
    }
}
