//! The long-lived JSONL compile service (`da4ml serve`).
//!
//! The paper's pitch is a CMVM compiler fast enough to sit inside a
//! design loop; this module is the first multi-request serving surface
//! on top of it. The loop reads one compile job per input line (JSON
//! object), accumulates them into batches, drives the
//! [`Coordinator`]'s cache + worker pool, and streams one JSON reply
//! line per job (plus a stats line per batch) back out — wire format
//! documented in `docs/serve.md`.
//!
//! Requests are decoded with the zero-copy pull parser
//! ([`crate::json::decode::Decoder`]), so a hot serving loop never
//! builds a [`crate::json::Value`] tree for job matrices. Malformed
//! lines and failed jobs produce `"type": "error"` replies; they never
//! tear down the service.
//!
//! Besides compile jobs, a line may post a **design-space
//! exploration** (`"type": "explore"` with a `matrix` or an inline
//! network `spec`): the [`crate::explore`] subsystem sweeps the
//! strategy × dc × pipeline space on the shared coordinator and the
//! reply carries the Pareto `front`, the `dominated` points, and —
//! when an `objective` was posted — the `picked` configuration. For
//! long-lived deployments the solution cache can be bounded with
//! [`ServeConfig::cache_cap`] (`serve --cache-cap`); evictions are
//! visible on the stats line. The cache itself can be sharded across
//! independent locks ([`ServeConfig::cache_shards`], `serve
//! --cache-shards`) so concurrent batches stop contending on one
//! mutex, and a deployment can restart warm: the CLI loads a baked
//! cache file into the coordinator before serving and saves it after
//! EOF (`serve --cache-load/--cache-save`, wired through
//! [`serve_with`]). The stats line reports both knobs
//! (`cache_shards`, `cache_loaded`).
//!
//! ```
//! use da4ml::serve::{serve, ServeConfig};
//! use std::io::Cursor;
//!
//! // Two identical jobs: with one job per batch, the second is
//! // deterministically answered from the cache.
//! let jobs = "\
//! {\"id\": \"a\", \"matrix\": [[3, 5], [-7, 9]]}\n\
//! {\"id\": \"b\", \"matrix\": [[3, 5], [-7, 9]]}\n";
//! let cfg = ServeConfig { batch_size: 1, ..ServeConfig::default() };
//! let mut out = Vec::new();
//! let summary = serve(Cursor::new(jobs), &mut out, &cfg).unwrap();
//! assert_eq!(summary.jobs, 2);
//! assert_eq!(summary.stats.cache_hits, 1);
//! let text = String::from_utf8(out).unwrap();
//! // One result + one stats line per single-job batch.
//! assert_eq!(text.lines().count(), 4);
//! assert!(text.contains("\"cached\":true"));
//! ```

use crate::cmvm::{CmvmProblem, Strategy};
use crate::coordinator::{CompileJob, Coordinator, CoordinatorStats};
use crate::estimate::{self, FpgaModel};
use crate::explore::{self, ExploreConfig, ExploreTarget, Objective, SpaceConfig};
use crate::json::decode::Decoder;
use crate::json::{self, Value};
use crate::nn::NetworkSpec;
use crate::Result;
use anyhow::{bail, ensure};
use std::collections::BTreeMap;
use std::io::{BufRead, Write};

/// Serving knobs (all have CLI flags, see `da4ml serve --help` text).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Jobs per coordinator batch (replies stream after each batch).
    pub batch_size: usize,
    /// Worker threads per batch (`0` = hardware parallelism).
    pub threads: usize,
    /// Delay constraint applied when a job omits `"dc"`.
    pub default_dc: i32,
    /// FPGA cost model used for the per-solution resource estimate.
    pub model: FpgaModel,
    /// Solution-cache entry cap (`serve --cache-cap`): past it the
    /// coordinator evicts least-recently-used solutions. `None` (the
    /// default) keeps the cache unbounded, preserving the historical
    /// behavior.
    pub cache_cap: Option<usize>,
    /// Solution-cache shard count (`serve --cache-shards`): the cache
    /// splits into this many independently locked shards keyed by
    /// job-key hash. `1` (the default) reproduces the historical
    /// single-lock cache — including its exact eviction order.
    pub cache_shards: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            batch_size: 16,
            threads: 0,
            default_dc: -1,
            model: FpgaModel::default(),
            cache_cap: None,
            cache_shards: 1,
        }
    }
}

/// End-of-stream accounting, returned by [`serve`] (the CLI prints it
/// to stderr so stdout stays pure JSONL).
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeSummary {
    /// Well-formed jobs compiled (successfully or not).
    pub jobs: u64,
    /// Error replies emitted (malformed lines + failed jobs).
    pub errors: u64,
    /// Reply lines written (every input job/line yields exactly one).
    pub replies: u64,
    /// Batches flushed.
    pub batches: u64,
    /// Final coordinator statistics (submitted / cache hits / opt time).
    pub stats: CoordinatorStats,
}

/// One decoded compile request (see `docs/serve.md` for field
/// semantics and defaults).
#[derive(Debug, Clone)]
pub struct JobRequest {
    /// Reply correlation id; defaults to `job-<line#>` when omitted.
    pub id: Option<String>,
    /// Constant matrix as `d_in` rows of `d_out` weights.
    pub matrix: Vec<Vec<i64>>,
    /// Input bitwidth (signed), `1..=63`. Default 8.
    pub bits: i64,
    /// Strategy name (`da`, `latency`, `naive-da`, `cse-only`,
    /// `lookahead`). Default `da`.
    pub strategy: Option<String>,
    /// Delay constraint; falls back to [`ServeConfig::default_dc`].
    pub dc: Option<i64>,
    /// Optional RTL emission: `"verilog"` or `"vhdl"`. The reply then
    /// carries the combinational RTL text of the solution in an
    /// `"rtl"` field.
    pub emit: Option<String>,
}

/// RTL language requested by a job's `"emit"` field.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EmitLang {
    /// Verilog-2001 (`rtl::emit_verilog`).
    Verilog,
    /// VHDL (`rtl::emit_vhdl`).
    Vhdl,
}

/// One decoded request line: a compile job (the default) or a
/// design-space exploration (`"type": "explore"`, see `docs/serve.md`).
#[derive(Debug, Clone)]
pub enum Request {
    /// A CMVM compile job.
    Compile(JobRequest),
    /// A design-space exploration job.
    Explore(ExploreRequest),
}

/// One decoded explore request (`"type": "explore"`): sweep the
/// strategy × dc × pipeline space for a posted matrix or network spec
/// and reply with the Pareto front.
#[derive(Debug, Clone)]
pub struct ExploreRequest {
    /// Reply correlation id; defaults to `job-<line#>` when omitted.
    pub id: Option<String>,
    /// CMVM target (exactly one of `matrix` / `spec` must be present).
    pub matrix: Option<Vec<Vec<i64>>>,
    /// Network target: a full inline network spec object.
    pub spec: Option<NetworkSpec>,
    /// Input bitwidth for `matrix` targets, `1..=63` (default 8). An
    /// error on `spec` targets — the spec carries its own `input_bits`,
    /// so a posted value would be silently meaningless.
    pub bits: Option<i64>,
    /// Candidate space: `"smoke"` (default) or `"full"`.
    pub space: Option<String>,
    /// Optional objective (`min-lut` | `min-latency` | `knee`); the
    /// reply then carries the `picked` front point.
    pub objective: Option<String>,
}

impl ExploreRequest {
    /// Validate the request into its exploration inputs. Runs at
    /// line-lowering time (like [`JobRequest::to_compile_job`]) so a
    /// malformed explore job becomes an immediate error reply with the
    /// same accounting as a malformed compile job — never a deferred
    /// failure that inflates the job count.
    pub fn validate(&self) -> Result<(ExploreTarget, SpaceConfig, Option<Objective>)> {
        let target = match (&self.matrix, &self.spec) {
            (Some(matrix), None) => {
                ExploreTarget::Cmvm(matrix_to_problem(matrix, self.bits.unwrap_or(8))?)
            }
            (None, Some(spec)) => {
                ensure!(
                    self.bits.is_none(),
                    "field 'bits' does not apply to spec targets (the spec carries its \
                     own input_bits)"
                );
                ExploreTarget::Network(spec.clone())
            }
            _ => bail!("explore job must carry exactly one of 'matrix' or 'spec'"),
        };
        let space = match self.space.as_deref() {
            None | Some("smoke") => SpaceConfig::smoke(),
            Some("full") => SpaceConfig::full(),
            Some(other) => bail!("unknown explore space '{other}' (expected smoke|full)"),
        };
        let objective = match self.objective.as_deref() {
            None => None,
            Some(name) => Some(Objective::parse(name)?),
        };
        Ok((target, space, objective))
    }
}

impl Request {
    /// Streaming-decode one request line (no `Value` tree). The
    /// `"type"` discriminator may appear anywhere on the line; fields
    /// belonging to the *other* request type are rejected (strict wire:
    /// a silently ignored field would hide caller bugs).
    pub fn from_json(line: &str) -> Result<Self> {
        let mut d = Decoder::new(line);
        let mut ty: Option<String> = None;
        let mut id = None;
        let mut matrix = None;
        let mut bits: Option<i64> = None;
        let mut strategy = None;
        let mut dc = None;
        let mut emit = None;
        let mut spec: Option<NetworkSpec> = None;
        let mut space = None;
        let mut objective = None;
        d.object_start()?;
        while let Some(key) = d.next_key()? {
            match key.as_ref() {
                "type" => ty = Some(d.string()?),
                "id" => id = Some(d.string()?),
                "matrix" => matrix = Some(d.i64_mat()?),
                "bits" => bits = Some(d.i64()?),
                "strategy" => strategy = Some(d.string()?),
                "dc" => dc = Some(d.i64()?),
                "emit" => emit = Some(d.string()?),
                "spec" => spec = Some(NetworkSpec::decode(&mut d)?),
                "space" => space = Some(d.string()?),
                "objective" => objective = Some(d.string()?),
                _ => d.skip_value()?,
            }
        }
        d.end()?;
        match ty.as_deref() {
            None | Some("compile") => {
                for (field, present) in [
                    ("spec", spec.is_some()),
                    ("space", space.is_some()),
                    ("objective", objective.is_some()),
                ] {
                    ensure!(!present, "field '{field}' requires \"type\": \"explore\"");
                }
                let matrix = matrix.ok_or_else(|| anyhow::anyhow!("missing field 'matrix'"))?;
                let bits = bits.unwrap_or(8);
                Ok(Request::Compile(JobRequest { id, matrix, bits, strategy, dc, emit }))
            }
            Some("explore") => {
                for (field, present) in [
                    ("strategy", strategy.is_some()),
                    ("dc", dc.is_some()),
                    ("emit", emit.is_some()),
                ] {
                    ensure!(!present, "field '{field}' does not apply to explore jobs");
                }
                Ok(Request::Explore(ExploreRequest { id, matrix, spec, bits, space, objective }))
            }
            Some(other) => bail!("unknown job type '{other}' (expected compile|explore)"),
        }
    }
}

impl JobRequest {
    /// Streaming-decode one compile request line (no `Value` tree).
    /// Explore lines are an error here — use [`Request::from_json`] for
    /// the full wire.
    pub fn from_json(line: &str) -> Result<Self> {
        match Request::from_json(line)? {
            Request::Compile(req) => Ok(req),
            Request::Explore(_) => bail!("explore job where a compile job was expected"),
        }
    }

    /// Parse the optional `"emit"` field (strict, like the strategy
    /// name: an unknown language is an error reply, never ignored).
    pub fn emit_lang(&self) -> Result<Option<EmitLang>> {
        match self.emit.as_deref() {
            None => Ok(None),
            Some("verilog") => Ok(Some(EmitLang::Verilog)),
            Some("vhdl") => Ok(Some(EmitLang::Vhdl)),
            Some(other) => bail!("unknown emit language '{other}' (expected verilog|vhdl)"),
        }
    }

    /// Validate and lower into a [`CompileJob`] (checked here — not in
    /// `CmvmProblem::new`, whose assertions would panic the service).
    pub fn to_compile_job(&self, name: String, default_dc: i32) -> Result<CompileJob> {
        let problem = matrix_to_problem(&self.matrix, self.bits)?;
        let dc = self.dc.unwrap_or(default_dc as i64);
        ensure!(
            i32::try_from(dc).is_ok(),
            "dc {dc} out of range (must fit a 32-bit signed integer; -1 = unconstrained)"
        );
        let dc = dc as i32;
        let strategy = parse_strategy(self.strategy.as_deref().unwrap_or("da"), dc)?;
        Ok(CompileJob { name, problem, strategy })
    }
}

/// Validate a wire matrix (shape + bits) into a [`CmvmProblem`] —
/// shared by compile and explore jobs so both wire paths accept
/// exactly the same matrices.
fn matrix_to_problem(matrix: &[Vec<i64>], bits: i64) -> Result<CmvmProblem> {
    let d_in = matrix.len();
    ensure!(d_in > 0, "matrix must have at least one row");
    let d_out = matrix[0].len();
    ensure!(d_out > 0, "matrix rows must be non-empty");
    for (j, row) in matrix.iter().enumerate() {
        ensure!(
            row.len() == d_out,
            "matrix is ragged: row {j} has {} entries, row 0 has {d_out}",
            row.len()
        );
    }
    ensure!((1..=63).contains(&bits), "bits must be in [1, 63], got {bits}");
    let flat: Vec<i64> = matrix.iter().flatten().copied().collect();
    Ok(CmvmProblem::new(d_in, d_out, flat, bits as u32))
}

/// Strict strategy-name parser (the CLI's lenient fallback is wrong for
/// a wire protocol: an unknown name must be an error reply, not
/// silently `da`).
pub fn parse_strategy(name: &str, dc: i32) -> Result<Strategy> {
    Ok(match name {
        "da" => Strategy::Da { dc },
        "latency" => Strategy::Latency,
        "naive-da" => Strategy::NaiveDa,
        "cse-only" => Strategy::CseOnly { dc },
        "lookahead" => Strategy::Lookahead { dc },
        other => bail!(
            "unknown strategy '{other}' (expected da|latency|naive-da|cse-only|lookahead)"
        ),
    })
}

/// One batch entry: a lowered compile job, a validated explore job, or
/// an immediate error reply.
enum Pending {
    Job { id: String, job: CompileJob, emit: Option<EmitLang> },
    Explore { id: String, target: ExploreTarget, space: SpaceConfig, objective: Option<Objective> },
    Bad { id: Option<String>, error: String },
}

/// Run the serve loop: read JSONL jobs from `input` until EOF, stream
/// JSONL replies to `output`. Never returns early on malformed or
/// failing jobs — only on I/O errors writing `output`.
pub fn serve<R: BufRead, W: Write>(
    input: R,
    output: &mut W,
    cfg: &ServeConfig,
) -> Result<ServeSummary> {
    let coord = Coordinator::with_shards(cfg.cache_shards);
    coord.set_cache_cap(cfg.cache_cap);
    serve_with(&coord, input, output, cfg)
}

/// [`serve`] against a caller-owned [`Coordinator`]. This is the warm
/// restart surface: the CLI loads a persisted cache into the
/// coordinator first (`serve --cache-load`), serves, then saves the
/// final cache after EOF (`--cache-save`). The coordinator's own
/// sharding/cap configuration wins — [`ServeConfig::cache_shards`] and
/// [`ServeConfig::cache_cap`] are applied only by [`serve`], which owns
/// its coordinator.
pub fn serve_with<R: BufRead, W: Write>(
    coord: &Coordinator,
    input: R,
    output: &mut W,
    cfg: &ServeConfig,
) -> Result<ServeSummary> {
    let mut summary = ServeSummary::default();
    let mut batch: Vec<Pending> = Vec::new();
    let batch_size = cfg.batch_size.max(1);
    let mut line_no = 0u64;
    for line in input.lines() {
        // Count every input line (blank ones too) so the default
        // `job-<line#>` id matches the caller's 1-based file line.
        line_no += 1;
        let entry = match line {
            Ok(line) if line.trim().is_empty() => continue,
            Ok(line) => match Request::from_json(&line) {
                Ok(Request::Compile(req)) => {
                    let id = req.id.clone().unwrap_or_else(|| format!("job-{line_no}"));
                    let lowered = req
                        .to_compile_job(id.clone(), cfg.default_dc)
                        .and_then(|job| Ok((job, req.emit_lang()?)));
                    match lowered {
                        Ok((job, emit)) => Pending::Job { id, job, emit },
                        Err(e) => Pending::Bad { id: Some(id), error: format!("{e:#}") },
                    }
                }
                Ok(Request::Explore(req)) => {
                    let id = req.id.clone().unwrap_or_else(|| format!("job-{line_no}"));
                    match req.validate() {
                        Ok((target, space, objective)) => {
                            Pending::Explore { id, target, space, objective }
                        }
                        Err(e) => Pending::Bad { id: Some(id), error: format!("{e:#}") },
                    }
                }
                Err(e) => Pending::Bad { id: None, error: format!("{e:#}") },
            },
            // A non-UTF-8 line is one more malformed request, not a
            // reason to tear down the service and drop buffered jobs
            // (`lines()` has already consumed the offending bytes).
            Err(e) if e.kind() == std::io::ErrorKind::InvalidData => {
                Pending::Bad { id: None, error: format!("reading input line {line_no}: {e}") }
            }
            // A genuine I/O failure: answer what we have, then stop.
            Err(e) => {
                flush_batch(coord, &mut batch, output, cfg, &mut summary)?;
                summary.stats = coord.stats();
                return Err(e.into());
            }
        };
        batch.push(entry);
        if batch.len() >= batch_size {
            flush_batch(coord, &mut batch, output, cfg, &mut summary)?;
        }
    }
    flush_batch(coord, &mut batch, output, cfg, &mut summary)?;
    summary.stats = coord.stats();
    Ok(summary)
}

/// One reply slot after the jobs have been moved out for compilation:
/// correlation metadata only (the job itself is not cloned). Explore
/// jobs (already validated) are executed at reply time against the
/// shared coordinator.
enum Slot {
    Job { id: String, idx: usize, emit: Option<EmitLang> },
    Explore { id: String, target: ExploreTarget, space: SpaceConfig, objective: Option<Objective> },
    Bad { id: Option<String>, error: String },
}

/// RTL module names come from job ids, which are arbitrary strings:
/// sanitize to a legal Verilog/VHDL identifier.
fn module_name(id: &str) -> String {
    let mut s: String = id
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' })
        .collect();
    match s.chars().next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => s.insert_str(0, "m_"),
    }
    s
}

/// Build one `"type": "result"` reply (including the optional RTL
/// text). RTL emission failures bubble up and become an error reply.
fn result_reply(
    id: &str,
    sol: &crate::cmvm::CmvmSolution,
    cached: bool,
    emit: Option<EmitLang>,
    cfg: &ServeConfig,
) -> Result<Value> {
    let rep = estimate::combinational(&sol.program, &cfg.model);
    let mut o = BTreeMap::new();
    o.insert("type".into(), Value::Str("result".into()));
    o.insert("id".into(), Value::Str(id.into()));
    o.insert("adders".into(), Value::Int(sol.adders as i64));
    o.insert("depth".into(), Value::Int(sol.depth as i64));
    o.insert("lut".into(), Value::Int(rep.lut as i64));
    o.insert("ff".into(), Value::Int(rep.ff as i64));
    o.insert("latency_ns".into(), Value::Float(rep.latency_ns));
    o.insert("cached".into(), Value::Bool(cached));
    o.insert("opt_ms".into(), Value::Float(sol.opt_time.as_secs_f64() * 1e3));
    if let Some(lang) = emit {
        let module = module_name(id);
        let text = match lang {
            EmitLang::Verilog => crate::rtl::emit_verilog(&sol.program, &module, None)?,
            EmitLang::Vhdl => crate::rtl::emit_vhdl(&sol.program, &module, None)?,
        };
        o.insert("rtl".into(), Value::Str(text));
    }
    Ok(Value::Object(o))
}

/// Run one validated explore job against the shared coordinator (so
/// CMVM candidates hit the same solution cache as compile jobs) and
/// build its `"type": "explore"` reply. A compile failure bubbles up
/// into an error reply.
fn explore_reply(
    coord: &Coordinator,
    id: &str,
    target: &ExploreTarget,
    space: SpaceConfig,
    objective: Option<Objective>,
    cfg: &ServeConfig,
) -> Result<Value> {
    let ecfg = ExploreConfig { space, jobs: cfg.threads, model: cfg.model };
    let report = explore::explore(target, coord, &ecfg)?;
    let mut o = BTreeMap::new();
    o.insert("type".into(), Value::Str("explore".into()));
    o.insert("id".into(), Value::Str(id.into()));
    o.insert("target".into(), Value::Str(report.target.clone()));
    o.insert(
        "schema_version".into(),
        Value::Int(report.schema_version as i64),
    );
    o.insert(
        "front".into(),
        Value::Array(report.front.iter().map(explore::schema::point_value).collect()),
    );
    o.insert(
        "dominated".into(),
        Value::Array(report.dominated.iter().map(explore::schema::point_value).collect()),
    );
    o.insert(
        "skipped".into(),
        Value::Array(
            report
                .skipped
                .iter()
                .map(|s| {
                    let mut sk = BTreeMap::new();
                    sk.insert("id".into(), Value::Str(s.id.clone()));
                    sk.insert("reason".into(), Value::Str(s.reason.clone()));
                    Value::Object(sk)
                })
                .collect(),
        ),
    );
    if let Some(obj) = objective {
        if let Some(picked) = explore::pick(&report.front, obj) {
            o.insert("objective".into(), Value::Str(obj.name().into()));
            o.insert("picked".into(), explore::schema::point_value(picked));
        }
    }
    Ok(Value::Object(o))
}

/// Compile the batched jobs through the coordinator and stream one
/// reply line per entry (input order), then the batch stats line.
/// No-op on an empty batch.
fn flush_batch<W: Write>(
    coord: &Coordinator,
    batch: &mut Vec<Pending>,
    output: &mut W,
    cfg: &ServeConfig,
    summary: &mut ServeSummary,
) -> Result<()> {
    if batch.is_empty() {
        return Ok(());
    }
    summary.batches += 1;
    // Move the jobs out for the worker pool; keep only correlation
    // metadata (id, original position) on this side.
    let mut jobs = Vec::new();
    let mut slots = Vec::with_capacity(batch.len());
    for entry in std::mem::take(batch) {
        match entry {
            Pending::Job { id, job, emit } => {
                slots.push(Slot::Job { id, idx: jobs.len(), emit });
                jobs.push(job);
            }
            Pending::Explore { id, target, space, objective } => {
                slots.push(Slot::Explore { id, target, space, objective })
            }
            Pending::Bad { id, error } => slots.push(Slot::Bad { id, error }),
        }
    }
    let mut results: Vec<Option<Result<(std::sync::Arc<crate::cmvm::CmvmSolution>, bool)>>> =
        coord.compile_batch(jobs, cfg.threads).into_iter().map(Some).collect();
    for slot in slots {
        let reply = match slot {
            Slot::Bad { id, error } => {
                summary.errors += 1;
                error_reply(id.as_deref(), &error)
            }
            Slot::Explore { id, target, space, objective } => {
                summary.jobs += 1;
                match explore_reply(coord, &id, &target, space, objective, cfg) {
                    Ok(reply) => reply,
                    Err(e) => {
                        summary.errors += 1;
                        error_reply(Some(id.as_str()), &format!("{e:#}"))
                    }
                }
            }
            Slot::Job { id, idx, emit } => {
                summary.jobs += 1;
                match results[idx].take().expect("one result per job") {
                    Ok((sol, cached)) => {
                        match result_reply(&id, &sol, cached, emit, cfg) {
                            Ok(reply) => reply,
                            Err(e) => {
                                summary.errors += 1;
                                error_reply(Some(id.as_str()), &format!("{e:#}"))
                            }
                        }
                    }
                    Err(e) => {
                        summary.errors += 1;
                        error_reply(Some(id.as_str()), &format!("{e:#}"))
                    }
                }
            }
        };
        summary.replies += 1;
        writeln!(output, "{}", json::to_string(&reply))?;
    }
    let stats = coord.stats();
    let mut o = BTreeMap::new();
    o.insert("type".into(), Value::Str("stats".into()));
    o.insert("batch".into(), Value::Int(summary.batches as i64));
    o.insert("jobs".into(), Value::Int(summary.replies as i64));
    o.insert("submitted".into(), Value::Int(stats.submitted as i64));
    o.insert("cache_hits".into(), Value::Int(stats.cache_hits as i64));
    o.insert("cache_size".into(), Value::Int(coord.cache_len() as i64));
    o.insert("cache_evictions".into(), Value::Int(stats.evictions as i64));
    // Deployment-shape keys: how many independently locked shards the
    // cache runs on, and how many solutions this process inherited from
    // a persisted cache file (`serve --cache-load`) rather than
    // computing or receiving over the wire.
    o.insert("cache_shards".into(), Value::Int(coord.shard_count() as i64));
    o.insert("cache_loaded".into(), Value::Int(stats.loaded as i64));
    o.insert("total_opt_ms".into(), Value::Float(stats.total_opt_time.as_secs_f64() * 1e3));
    // Optimizer work proxies (cumulative, executed jobs only — cache
    // hits add nothing): lets clients watch perf per batch the same way
    // the perf suite does per case.
    o.insert("cse_steps".into(), Value::Int(stats.total_cse_steps as i64));
    o.insert("heap_pops".into(), Value::Int(stats.total_heap_pops as i64));
    writeln!(output, "{}", json::to_string(&Value::Object(o)))?;
    output.flush()?;
    Ok(())
}

fn error_reply(id: Option<&str>, error: &str) -> Value {
    let mut o = BTreeMap::new();
    o.insert("type".into(), Value::Str("error".into()));
    o.insert(
        "id".into(),
        match id {
            Some(id) => Value::Str(id.into()),
            None => Value::Null,
        },
    );
    o.insert("error".into(), Value::Str(error.into()));
    Value::Object(o)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn run(input: &str, cfg: &ServeConfig) -> (ServeSummary, Vec<Value>) {
        let mut out = Vec::new();
        let summary = serve(Cursor::new(input.to_string()), &mut out, cfg).unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines = text.lines().map(|l| json::parse(l).expect("reply is JSON")).collect();
        (summary, lines)
    }

    #[test]
    fn request_decoding_defaults_and_errors() {
        let req = JobRequest::from_json(r#"{"matrix": [[1, 2], [3, 4]]}"#).unwrap();
        assert_eq!(req.bits, 8);
        assert!(req.id.is_none() && req.strategy.is_none() && req.dc.is_none());
        let job = req.to_compile_job("j".into(), 2).unwrap();
        assert_eq!(job.problem.d_in, 2);
        assert_eq!(job.strategy, Strategy::Da { dc: 2 });

        assert!(JobRequest::from_json("[1]").is_err());
        assert!(JobRequest::from_json(r#"{"matrix": 5}"#).is_err());
        assert!(JobRequest::from_json("{}").is_err());
        let ragged = JobRequest::from_json(r#"{"matrix": [[1, 2], [3]]}"#).unwrap();
        assert!(ragged.to_compile_job("j".into(), -1).is_err());
        let bad_bits = JobRequest::from_json(r#"{"matrix": [[1]], "bits": 64}"#).unwrap();
        assert!(bad_bits.to_compile_job("j".into(), -1).is_err());
        let bad_strategy =
            JobRequest::from_json(r#"{"matrix": [[1]], "strategy": "hls"}"#).unwrap();
        assert!(bad_strategy.to_compile_job("j".into(), -1).is_err());
        // dc must fit i32 — no silent wrap-around on the wire.
        let bad_dc = JobRequest::from_json(r#"{"matrix": [[1]], "dc": 4294967296}"#).unwrap();
        assert!(bad_dc.to_compile_job("j".into(), -1).is_err());
    }

    /// A non-UTF-8 input line becomes one more error reply; the jobs
    /// around it still compile and stream back (no service teardown).
    #[test]
    fn non_utf8_line_is_an_error_reply_not_a_teardown() {
        let mut input: Vec<u8> = Vec::new();
        input.extend_from_slice(b"{\"id\": \"a\", \"matrix\": [[3, 5], [-7, 9]], \"dc\": -1}\n");
        input.extend_from_slice(&[0xFF, 0xFE, b'\n']);
        input.extend_from_slice(b"{\"id\": \"b\", \"matrix\": [[2, 3], [5, 7]], \"dc\": -1}\n");
        let mut out = Vec::new();
        let summary = serve(Cursor::new(input), &mut out, &ServeConfig::default()).unwrap();
        assert_eq!(summary.jobs, 2);
        assert_eq!(summary.errors, 1);
        assert_eq!(summary.replies, 3);
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<Value> = text.lines().map(|l| json::parse(l).unwrap()).collect();
        assert_eq!(lines.len(), 4); // result, error, result, stats
        assert_eq!(lines[0].get("id").unwrap().as_str().unwrap(), "a");
        assert_eq!(lines[1].get("type").unwrap().as_str().unwrap(), "error");
        assert!(lines[1].get("error").unwrap().as_str().unwrap().contains("line 2"));
        assert_eq!(lines[2].get("id").unwrap().as_str().unwrap(), "b");
    }

    /// Default ids number *input lines* (1-based), blank lines included,
    /// so `job-<line#>` correlates with the caller's file.
    #[test]
    fn default_ids_match_input_line_numbers() {
        let input = "{\"matrix\": [[1]], \"dc\": -1}\n\n{\"matrix\": [[2]], \"dc\": -1}\n";
        let (summary, lines) = run(input, &ServeConfig::default());
        assert_eq!(summary.jobs, 2);
        assert_eq!(summary.replies, 2);
        let ids: Vec<String> = lines
            .iter()
            .filter(|l| l.get("type").unwrap().as_str().unwrap() == "result")
            .map(|l| l.get("id").unwrap().as_str().unwrap().to_string())
            .collect();
        assert_eq!(ids, vec!["job-1".to_string(), "job-3".to_string()]);
    }

    #[test]
    fn serve_streams_results_errors_and_stats() {
        // batch 1: [a, ragged]; batch 2: [not-json, a2]. Splitting the
        // identical jobs across batches makes the cache hit
        // deterministic (within one batch, duplicates may race).
        let input = r#"
{"id": "a", "matrix": [[3, 5], [-7, 9]], "dc": -1}
{"id": "bad", "matrix": [[1], [2, 3]]}
not even json
{"id": "a2", "matrix": [[3, 5], [-7, 9]], "dc": -1}
"#;
        let cfg = ServeConfig { batch_size: 2, ..ServeConfig::default() };
        let (summary, lines) = run(input, &cfg);
        assert_eq!(summary.jobs, 2);
        assert_eq!(summary.errors, 2);
        assert_eq!(summary.batches, 2);
        assert_eq!(summary.stats.cache_hits, 1);
        // (result, error, stats) then (error, result, stats), input order.
        assert_eq!(lines.len(), 6);
        assert_eq!(lines[0].get("type").unwrap().as_str().unwrap(), "result");
        assert_eq!(lines[0].get("id").unwrap().as_str().unwrap(), "a");
        assert_eq!(lines[0].get("cached").unwrap().as_bool().unwrap(), false);
        assert_eq!(lines[1].get("type").unwrap().as_str().unwrap(), "error");
        assert_eq!(lines[1].get("id").unwrap().as_str().unwrap(), "bad");
        assert!(lines[1].get("error").unwrap().as_str().unwrap().contains("ragged"));
        assert_eq!(lines[2].get("type").unwrap().as_str().unwrap(), "stats");
        assert_eq!(lines[3].get("type").unwrap().as_str().unwrap(), "error");
        assert_eq!(lines[3].get("id").unwrap(), &Value::Null);
        assert_eq!(lines[4].get("id").unwrap().as_str().unwrap(), "a2");
        assert_eq!(lines[4].get("cached").unwrap().as_bool().unwrap(), true);
        // Identical jobs report identical solutions.
        assert_eq!(
            lines[0].get("adders").unwrap().as_i64().unwrap(),
            lines[4].get("adders").unwrap().as_i64().unwrap()
        );
        let stats = &lines[5];
        assert_eq!(stats.get("type").unwrap().as_str().unwrap(), "stats");
        assert_eq!(stats.get("submitted").unwrap().as_i64().unwrap(), 2);
        assert_eq!(stats.get("cache_hits").unwrap().as_i64().unwrap(), 1);
        assert_eq!(stats.get("cache_size").unwrap().as_i64().unwrap(), 1);
    }

    #[test]
    fn batching_flushes_stats_per_batch() {
        let mut input = String::new();
        for i in 0..5 {
            input.push_str(&format!(
                "{{\"id\": \"j{i}\", \"matrix\": [[{}, 3], [5, {}]], \"dc\": -1}}\n",
                i + 1,
                i + 2
            ));
        }
        let cfg = ServeConfig { batch_size: 2, ..ServeConfig::default() };
        let (summary, lines) = run(&input, &cfg);
        assert_eq!(summary.jobs, 5);
        assert_eq!(summary.batches, 3); // 2 + 2 + 1
        let stats_lines: Vec<_> = lines
            .iter()
            .filter(|l| l.get("type").unwrap().as_str().unwrap() == "stats")
            .collect();
        assert_eq!(stats_lines.len(), 3);
        // Stats are cumulative; the last line covers all jobs.
        assert_eq!(stats_lines[2].get("submitted").unwrap().as_i64().unwrap(), 5);
    }

    /// The optional `"emit"` field returns combinational RTL text in
    /// the reply; unknown languages are error replies, and ids are
    /// sanitized into legal module names.
    #[test]
    fn emit_field_returns_rtl_text() {
        let input = r#"
{"id": "fc-1", "matrix": [[3, 5], [-7, 9]], "dc": -1, "emit": "verilog"}
{"id": "fc-1v", "matrix": [[3, 5], [-7, 9]], "dc": -1, "emit": "vhdl"}
{"id": "plain", "matrix": [[3, 5], [-7, 9]], "dc": -1}
{"id": "bad", "matrix": [[3, 5], [-7, 9]], "dc": -1, "emit": "systemverilog"}
"#;
        let cfg = ServeConfig { batch_size: 1, ..ServeConfig::default() };
        let (summary, lines) = run(input, &cfg);
        assert_eq!(summary.jobs, 3);
        assert_eq!(summary.errors, 1);
        let verilog = lines[0].get("rtl").unwrap().as_str().unwrap();
        assert!(verilog.contains("module fc_1 ("), "id sanitized into module name");
        assert!(verilog.contains("endmodule"));
        assert!(!verilog.contains("clk"), "serve emits combinational RTL");
        let vhdl = lines[2].get("rtl").unwrap().as_str().unwrap();
        assert!(vhdl.contains("entity fc_1v is"));
        assert!(vhdl.contains("end architecture;"));
        // No emit -> no rtl field.
        assert!(lines[4].get("rtl").is_err());
        assert_eq!(lines[6].get("type").unwrap().as_str().unwrap(), "error");
        assert!(lines[6]
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("unknown emit language"));
    }

    /// The explore job type: a matrix target replies with a Pareto
    /// front (plus the picked point when an objective is posted), and
    /// malformed explore jobs fail at lowering time — immediate error
    /// replies carrying the job id, never counted as jobs.
    #[test]
    fn explore_job_replies_with_front() {
        let input = r#"
{"type": "explore", "id": "x1", "matrix": [[3, 5], [-7, 9]], "objective": "min-lut"}
{"type": "explore", "id": "both"}
{"type": "explore", "id": "bad-space", "matrix": [[1]], "space": "galaxy"}
{"type": "explore", "id": "bad-obj", "matrix": [[1]], "objective": "fastest"}
"#;
        let (summary, lines) = run(input, &ServeConfig::default());
        // Validation failures never reach the explorer: same accounting
        // as malformed compile jobs (errors, not jobs).
        assert_eq!(summary.jobs, 1);
        assert_eq!(summary.errors, 3);
        assert_eq!(summary.replies, 4);
        let reply = &lines[0];
        assert_eq!(reply.get("type").unwrap().as_str().unwrap(), "explore");
        assert_eq!(reply.get("id").unwrap().as_str().unwrap(), "x1");
        assert_eq!(reply.get("target").unwrap().as_str().unwrap(), "cmvm/2x2");
        let front = reply.get("front").unwrap().as_array().unwrap();
        assert!(!front.is_empty());
        let picked = reply.get("picked").unwrap();
        let min_lut = front
            .iter()
            .map(|p| p.get("lut").unwrap().as_i64().unwrap())
            .min()
            .unwrap();
        assert_eq!(picked.get("lut").unwrap().as_i64().unwrap(), min_lut);
        assert_eq!(reply.get("objective").unwrap().as_str().unwrap(), "min-lut");
        // Lowering-time failures still correlate with the posted id.
        assert_eq!(lines[1].get("id").unwrap().as_str().unwrap(), "both");
        assert!(lines[1]
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("exactly one of 'matrix' or 'spec'"));
        assert!(lines[2].get("error").unwrap().as_str().unwrap().contains("galaxy"));
        assert!(lines[3].get("error").unwrap().as_str().unwrap().contains("fastest"));
    }

    /// An inline network spec explores through the same wire; compile
    /// fields on an explore line (and vice versa) are strict errors,
    /// as is `bits` on a spec target (the spec carries its own).
    #[test]
    fn explore_spec_target_and_field_strictness() {
        let spec = crate::bench_tables::synthetic_jet_spec_scaled(1, 8).to_json();
        let input = format!(
            "{{\"type\": \"explore\", \"id\": \"net\", \"spec\": {spec}}}\n\
             {{\"type\": \"explore\", \"id\": \"s1\", \"matrix\": [[1]], \"strategy\": \"da\"}}\n\
             {{\"id\": \"c1\", \"matrix\": [[1]], \"space\": \"smoke\"}}\n\
             {{\"type\": \"explore\", \"id\": \"sb\", \"spec\": {spec}, \"bits\": 4}}\n"
        );
        let (summary, lines) = run(&input, &ServeConfig::default());
        // The strict-field violations fail at decode/lowering time (no
        // job was formed), so only the spec exploration counts as a job.
        assert_eq!(summary.jobs, 1);
        assert_eq!(summary.errors, 3);
        assert_eq!(summary.replies, 4);
        let reply = &lines[0];
        assert_eq!(reply.get("type").unwrap().as_str().unwrap(), "explore");
        assert!(!reply.get("front").unwrap().as_array().unwrap().is_empty());
        assert!(lines[1]
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("does not apply to explore jobs"));
        assert!(lines[2]
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("requires \"type\": \"explore\""));
        assert_eq!(lines[3].get("id").unwrap().as_str().unwrap(), "sb");
        assert!(lines[3]
            .get("error")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("does not apply to spec targets"));
    }

    /// `--cache-cap` bounds the coordinator cache; the stats line
    /// reports evictions and the service keeps answering correctly.
    #[test]
    fn cache_cap_bounds_the_serve_cache() {
        let mut input = String::new();
        for i in 0..4 {
            input.push_str(&format!(
                "{{\"id\": \"j{i}\", \"matrix\": [[{}, 3], [5, {}]], \"dc\": -1}}\n",
                i + 1,
                i + 2
            ));
        }
        let cfg = ServeConfig {
            batch_size: 1,
            cache_cap: Some(2),
            ..ServeConfig::default()
        };
        let (summary, lines) = run(&input, &cfg);
        assert_eq!(summary.jobs, 4);
        assert_eq!(summary.errors, 0);
        assert_eq!(summary.stats.evictions, 2);
        let last_stats = lines.last().unwrap();
        assert_eq!(last_stats.get("cache_size").unwrap().as_i64().unwrap(), 2);
        assert_eq!(last_stats.get("cache_evictions").unwrap().as_i64().unwrap(), 2);
    }

    #[test]
    fn module_names_are_sanitized() {
        assert_eq!(module_name("fc-1"), "fc_1");
        assert_eq!(module_name("layer.0/dense"), "layer_0_dense");
        assert_eq!(module_name("0abc"), "m_0abc");
        assert_eq!(module_name(""), "m_");
        assert_eq!(module_name("ok_name"), "ok_name");
    }

    /// `--cache-shards` must be invisible on the wire: the same input
    /// served over 1 shard and over 4 shards yields byte-identical
    /// reply lines once the two wall-clock fields (`opt_ms`,
    /// `total_opt_ms`) are masked — and the masked fields themselves
    /// only differ because they are timings, not because the solutions
    /// or the accounting do.
    #[test]
    fn sharded_serve_replies_match_single_shard_byte_for_byte() {
        let mut input = String::new();
        for i in 0..6 {
            // Repeat every matrix once so both layouts serve a mix of
            // misses and hits. No cache cap: a cap legitimately changes
            // eviction timing across shard layouts (it splits
            // per-shard), which is exactly why the determinism claim is
            // scoped to the uncapped cache.
            let line = format!(
                "{{\"id\": \"j{i}\", \"matrix\": [[{}, 3], [5, {}]], \"dc\": -1}}\n",
                i + 1,
                i + 2
            );
            input.push_str(&line);
            input.push_str(&line);
        }
        let mask_timing = |lines: Vec<Value>| -> Vec<String> {
            lines
                .into_iter()
                .map(|mut v| {
                    if let Value::Object(o) = &mut v {
                        for key in ["opt_ms", "total_opt_ms"] {
                            if o.contains_key(key) {
                                o.insert(key.into(), Value::Int(0));
                            }
                        }
                    }
                    json::to_string(&v)
                })
                .collect()
        };
        let run_with_shards = |shards: usize| {
            let cfg = ServeConfig {
                batch_size: 1,
                cache_shards: shards,
                ..ServeConfig::default()
            };
            run(&input, &cfg)
        };
        let (sum1, lines1) = run_with_shards(1);
        let (sum4, lines4) = run_with_shards(4);
        assert_eq!(sum1.jobs, 12);
        assert_eq!(sum4.jobs, 12);
        assert_eq!(sum1.stats.submitted, sum4.stats.submitted);
        assert_eq!(sum1.stats.cache_hits, sum4.stats.cache_hits);
        let masked1 = mask_timing(lines1);
        let mut masked4 = mask_timing(lines4);
        // The only licensed difference: the stats lines advertise their
        // own shard count.
        for line in &mut masked4 {
            *line = line.replace("\"cache_shards\":4", "\"cache_shards\":1");
        }
        assert_eq!(masked1, masked4);
    }

    /// The stats line advertises the deployment shape: shard count and
    /// how many solutions arrived from a persisted cache file.
    #[test]
    fn stats_line_reports_shards_and_loaded() {
        let input = "{\"id\": \"a\", \"matrix\": [[3, 5], [-7, 9]], \"dc\": -1}\n";
        let cfg = ServeConfig { cache_shards: 3, ..ServeConfig::default() };
        let (_, lines) = run(input, &cfg);
        let stats = lines.last().unwrap();
        assert_eq!(stats.get("cache_shards").unwrap().as_i64().unwrap(), 3);
        assert_eq!(stats.get("cache_loaded").unwrap().as_i64().unwrap(), 0);
    }

    /// Warm restart through [`serve_with`]: a reply served from a
    /// loaded-from-disk cache is byte-identical to one served from the
    /// live cache that was saved — including the exact `opt_ms` (the
    /// persisted nanosecond counter round-trips).
    #[test]
    fn loaded_cache_serves_byte_identical_replies() {
        let job = crate::coordinator::CompileJob {
            name: "warm".into(),
            problem: CmvmProblem::new(2, 2, vec![3, 5, -7, 9], 8),
            strategy: Strategy::Da { dc: -1 },
        };
        let live = Coordinator::new();
        live.compile_cached(&job).unwrap();
        let saved = live.save_cache();

        let input = "{\"id\": \"a\", \"matrix\": [[3, 5], [-7, 9]], \"dc\": -1}\n";
        let cfg = ServeConfig::default();
        let mut out_live = Vec::new();
        let sum_live =
            serve_with(&live, Cursor::new(input), &mut out_live, &cfg).unwrap();
        assert_eq!(sum_live.stats.cache_hits, 1, "live cache answers the wire job");

        let warm = Coordinator::new();
        assert_eq!(warm.load_cache(&saved).unwrap(), 1);
        let mut out_warm = Vec::new();
        let sum_warm =
            serve_with(&warm, Cursor::new(input), &mut out_warm, &cfg).unwrap();
        assert_eq!(sum_warm.stats.cache_hits, 1, "loaded cache answers the wire job");

        let reply_live = String::from_utf8(out_live).unwrap();
        let reply_warm = String::from_utf8(out_warm).unwrap();
        // Result lines are byte-identical; only the stats lines differ
        // (the warm run reports cache_loaded=1, the live one carries
        // the pre-serve compile in submitted/total_opt_ms).
        assert_eq!(reply_live.lines().next().unwrap(), reply_warm.lines().next().unwrap());
        assert!(reply_live.lines().next().unwrap().contains("\"cached\":true"));
        let warm_stats = json::parse(reply_warm.lines().nth(1).unwrap()).unwrap();
        assert_eq!(warm_stats.get("cache_loaded").unwrap().as_i64().unwrap(), 1);
    }

    /// Within one batch, duplicate jobs may race to a miss; the
    /// cache-hit accounting must still be visible across batches.
    #[test]
    fn cross_batch_cache_hits_are_deterministic() {
        let one = "{\"id\": \"x\", \"matrix\": [[3, 5], [-7, 9]], \"dc\": -1}\n";
        let input = format!("{one}{one}{one}");
        let cfg = ServeConfig { batch_size: 1, ..ServeConfig::default() };
        let (summary, lines) = run(&input, &cfg);
        assert_eq!(summary.stats.cache_hits, 2);
        let cached: Vec<bool> = lines
            .iter()
            .filter(|l| l.get("type").unwrap().as_str().unwrap() == "result")
            .map(|l| l.get("cached").unwrap().as_bool().unwrap())
            .collect();
        assert_eq!(cached, vec![false, true, true]);
    }
}
