//! Design-space exploration: deterministic parallel Pareto search over
//! strategy × pipeline × precision.
//!
//! The paper's headline claim is that DA-based CMVM optimization
//! improves area *and* latency simultaneously — which means the useful
//! answer to "how should I compile this?" is not one design point but
//! the **trade-off curve**. This module enumerates a candidate space
//! (all five [`Strategy`] variants — the `Da` variant being the
//! two-stage MST + CSE split and `CseOnly` the single-stage ablation —
//! crossed with a delay-constraint ladder and a pipeline-threshold
//! ladder derived from [`PipelineConfig::every_n_adders`]), compiles
//! each distinct strategy through the [`Coordinator`] on the
//! deterministic worker pool ([`pool`]), scores every candidate with
//! [`estimate::combinational`] / [`estimate::pipelined`] (stage
//! assignment via [`crate::pipeline::assign_stages`], depth via
//! [`crate::pipeline::latency`]), and splits the points into the
//! non-dominated (LUT, FF, latency) **Pareto front** and a retained
//! `dominated` array for audit.
//!
//! Determinism is load-bearing: the report for `--jobs N` is
//! bit-identical to `--jobs 1` (results are merged in submission
//! order; nothing machine- or schedule-dependent is recorded), so the
//! serialized JSON ([`schema`]) can be diffed, cached, and pinned by
//! tests. Candidates the explorer intentionally does not run (the
//! O(N³) lookahead comparator above its size cap, the pipeline ladder
//! under the MAC-modeled latency baseline) are listed in `skipped` —
//! no silent coverage holes, following the perf-lab convention.
//!
//! Surfaces: the `da4ml explore` CLI subcommand (JSON report + human
//! table), the `"type": "explore"` serve job ([`crate::serve`],
//! `docs/serve.md`), and the [`pick`] helper that auto-selects a front
//! point for an [`Objective`] (used by
//! [`crate::nn::compile::compile`] with an objective).
//!
//! ```
//! use da4ml::cmvm::CmvmProblem;
//! use da4ml::explore::{self, ExploreConfig, ExploreTarget, Objective};
//! use da4ml::coordinator::Coordinator;
//!
//! let problem = CmvmProblem::new(2, 2, vec![3, 5, -7, 9], 8).unwrap();
//! let cfg = ExploreConfig { jobs: 1, ..ExploreConfig::smoke() };
//! let report =
//!     explore::explore(&ExploreTarget::Cmvm(problem), &Coordinator::new(), &cfg).unwrap();
//! assert!(!report.front.is_empty());
//! let best = explore::pick(&report.front, Objective::MinLut).unwrap();
//! assert!(report.front.iter().all(|p| p.lut >= best.lut));
//! ```

pub mod pool;
pub mod schema;

use crate::baseline::mac::{mac_report, DspPolicy};
use crate::cmvm::{CmvmProblem, Strategy};
use crate::coordinator::{CompileJob, Coordinator};
use crate::estimate::{self, FpgaModel};
use crate::nn::{self, NetworkSpec};
use crate::pipeline::{self, PipelineConfig};
use crate::report::Table;
use crate::Result;

/// Version of the explore-report JSON schema ([`schema`]); bumped on
/// any incompatible change (same convention as [`crate::perf`]).
pub const SCHEMA_VERSION: u32 = 1;

/// The candidate space: which delay constraints and pipeline
/// thresholds to cross with the strategy axis.
#[derive(Debug, Clone)]
pub struct SpaceConfig {
    /// Delay-constraint ladder for the engine-driven strategies
    /// (`-1` = unconstrained).
    pub dcs: Vec<i32>,
    /// Pipeline ladder: `None` = combinational, `Some(n)` = a register
    /// every `n` adders ([`PipelineConfig::every_n_adders`]). Entries
    /// must be positive.
    pub pipes: Vec<Option<u32>>,
    /// The O(N³) lookahead comparator only runs on CMVMs whose longest
    /// edge is at most this; larger targets record a skip.
    pub lookahead_max_dim: usize,
}

impl SpaceConfig {
    /// The full ladder: `dc ∈ {-1..4}` × `{comb, pipe 1/2/3/5/8}`.
    pub fn full() -> Self {
        Self {
            dcs: vec![-1, 0, 1, 2, 3, 4],
            pipes: vec![None, Some(1), Some(2), Some(3), Some(5), Some(8)],
            lookahead_max_dim: 16,
        }
    }

    /// CI-sized subset (`da4ml explore --smoke`).
    pub fn smoke() -> Self {
        Self {
            dcs: vec![-1, 0, 2],
            pipes: vec![None, Some(1), Some(5)],
            lookahead_max_dim: 8,
        }
    }
}

/// Explorer configuration.
#[derive(Debug, Clone)]
pub struct ExploreConfig {
    /// The candidate space.
    pub space: SpaceConfig,
    /// Worker threads for the compile fan-out (`0` = hardware
    /// parallelism). The report is bit-identical for every value.
    pub jobs: usize,
    /// FPGA cost model used for scoring.
    pub model: FpgaModel,
}

impl ExploreConfig {
    /// Full space, hardware parallelism, default model.
    pub fn full() -> Self {
        Self { space: SpaceConfig::full(), jobs: 0, model: FpgaModel::default() }
    }

    /// Smoke space, hardware parallelism, default model.
    pub fn smoke() -> Self {
        Self { space: SpaceConfig::smoke(), jobs: 0, model: FpgaModel::default() }
    }
}

impl Default for ExploreConfig {
    fn default() -> Self {
        Self::full()
    }
}

/// What to explore: a single CMVM or a whole (fusible) network.
#[derive(Debug, Clone)]
pub enum ExploreTarget {
    /// One constant matrix–vector multiplication.
    Cmvm(CmvmProblem),
    /// A whole network, fused end to end per strategy
    /// ([`nn::compile::compile`]) — dense/einsum/residual
    /// layers only (conv networks use the HLS-flow path and are not
    /// fusible).
    Network(NetworkSpec),
}

impl ExploreTarget {
    /// Stable target label for reports.
    pub fn name(&self) -> String {
        match self {
            ExploreTarget::Cmvm(p) => format!("cmvm/{}x{}", p.d_in, p.d_out),
            ExploreTarget::Network(s) => s.name.clone(),
        }
    }
}

/// One scored candidate configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct DesignPoint {
    /// Stable point id, e.g. `da/dc2/pipe5`, `naive-da/comb`,
    /// `latency/mac`.
    pub id: String,
    /// The compile strategy (carries the delay constraint).
    pub strategy: Strategy,
    /// Pipeline threshold (`None` = combinational; the MAC-modeled
    /// latency baseline is also `None`).
    pub pipe: Option<u32>,
    /// Adder/subtractor count.
    pub adders: u64,
    /// Adder depth (combinational levels).
    pub depth: u32,
    /// LUT estimate — first dominance axis.
    pub lut: u64,
    /// DSP estimate (nonzero only for the MAC-modeled latency
    /// baseline; informational, not a dominance axis).
    pub dsp: u64,
    /// Flip-flop estimate — second dominance axis.
    pub ff: u64,
    /// End-to-end latency estimate in ns — third dominance axis.
    pub latency_ns: f64,
    /// Pipeline latency in cycles (1 = combinational).
    pub latency_cycles: u32,
    /// Achievable clock estimate.
    pub fmax_mhz: f64,
}

impl DesignPoint {
    /// The delay constraint of the strategy, when it has one.
    pub fn dc(&self) -> Option<i32> {
        strategy_dc(self.strategy)
    }
}

/// A candidate the explorer intentionally did not score.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SkippedCandidate {
    /// The point id(s) that would have been scored.
    pub id: String,
    /// Why they were dropped.
    pub reason: String,
}

/// The exploration result: the non-dominated front plus every
/// dominated point (retained for audit) and every skipped candidate.
#[derive(Debug, Clone)]
pub struct ExploreReport {
    /// Schema version ([`SCHEMA_VERSION`]).
    pub schema_version: u32,
    /// Target label ([`ExploreTarget::name`]).
    pub target: String,
    /// Non-dominated points, sorted by (LUT, latency, FF, id).
    pub front: Vec<DesignPoint>,
    /// Dominated points, in candidate enumeration order.
    pub dominated: Vec<DesignPoint>,
    /// Candidates not scored, with reasons.
    pub skipped: Vec<SkippedCandidate>,
}

/// Selection objective for [`pick`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Smallest LUT count (ties: latency, FF, id).
    MinLut,
    /// Smallest latency in ns (ties: LUT, FF, id).
    MinLatency,
    /// The knee of the LUT/latency curve: the front point closest (in
    /// normalized Euclidean distance) to the utopia point
    /// (min-LUT, min-latency).
    Knee,
}

impl Objective {
    /// Parse a wire/CLI objective name.
    pub fn parse(name: &str) -> Result<Self> {
        Ok(match name {
            "min-lut" => Objective::MinLut,
            "min-latency" => Objective::MinLatency,
            "knee" => Objective::Knee,
            other => anyhow::bail!(
                "unknown objective '{other}' (expected min-lut|min-latency|knee)"
            ),
        })
    }

    /// Stable objective name.
    pub fn name(&self) -> &'static str {
        match self {
            Objective::MinLut => "min-lut",
            Objective::MinLatency => "min-latency",
            Objective::Knee => "knee",
        }
    }
}

fn strategy_dc(s: Strategy) -> Option<i32> {
    match s {
        Strategy::Latency | Strategy::NaiveDa => None,
        Strategy::Da { dc } | Strategy::CseOnly { dc } | Strategy::Lookahead { dc } => Some(dc),
    }
}

/// Stable id of a (strategy, pipe) candidate.
fn point_id(strategy: Strategy, pipe: Option<u32>) -> String {
    if matches!(strategy, Strategy::Latency) {
        return "latency/mac".into();
    }
    let base = match strategy_dc(strategy) {
        Some(dc) => format!("{}/dc{}", strategy.name(), dc),
        None => strategy.name().to_string(),
    };
    match pipe {
        Some(n) => format!("{base}/pipe{n}"),
        None => format!("{base}/comb"),
    }
}

/// The compile axis of the space, in deterministic enumeration order:
/// the two dc-free baselines first, then per delay constraint the
/// single-stage CSE, the two-stage DA split, and the lookahead
/// comparator.
fn compile_axis(space: &SpaceConfig) -> Vec<Strategy> {
    let mut out = vec![Strategy::Latency, Strategy::NaiveDa];
    for &dc in &space.dcs {
        out.push(Strategy::CseOnly { dc });
        out.push(Strategy::Da { dc });
        out.push(Strategy::Lookahead { dc });
    }
    out
}

/// Build one point from a resource report.
fn point_from_report(
    strategy: Strategy,
    pipe: Option<u32>,
    rep: &estimate::ResourceReport,
) -> DesignPoint {
    DesignPoint {
        id: point_id(strategy, pipe),
        strategy,
        pipe,
        adders: rep.adders,
        depth: rep.depth,
        lut: rep.lut,
        dsp: rep.dsp,
        ff: rep.ff,
        latency_ns: rep.latency_ns,
        latency_cycles: rep.latency_cycles,
        fmax_mhz: rep.fmax_mhz,
    }
}

/// Score one compile-axis entry: produce its design points (one per
/// pipeline rung) and any skips. Pure function of the target and the
/// strategy — the determinism contract of the pool.
fn explore_one(
    target: &ExploreTarget,
    coord: &Coordinator,
    strategy: Strategy,
    space: &SpaceConfig,
    model: &FpgaModel,
) -> Result<(Vec<DesignPoint>, Vec<SkippedCandidate>)> {
    let mut points = Vec::new();
    let mut skipped = Vec::new();

    // The latency baseline is costed by the analytic MAC model
    // (baseline::mac) — one point; the pipeline ladder is an adder-graph
    // notion and does not apply to the HLS MAC schedule.
    if matches!(strategy, Strategy::Latency) {
        let rep = match target {
            ExploreTarget::Cmvm(p) => mac_report(p, model, &DspPolicy::default()),
            ExploreTarget::Network(spec) => {
                let reports = nn::compile::layer_reports(
                    spec,
                    Strategy::Latency,
                    model,
                    &PipelineConfig::default(),
                )?;
                nn::compile::aggregate(&reports)
            }
        };
        points.push(point_from_report(strategy, None, &rep));
        skipped.push(SkippedCandidate {
            id: "latency/pipe*".into(),
            reason: "the latency baseline is costed by the analytic MAC model; \
                     the adder-graph pipeline ladder does not apply"
                .into(),
        });
        return Ok((points, skipped));
    }

    // The O(N³) lookahead comparator is size-capped (CMVM) and never
    // run on whole networks, exactly like the perf suite.
    if matches!(strategy, Strategy::Lookahead { .. }) {
        let skip_reason = match target {
            ExploreTarget::Cmvm(p) if p.d_in.max(p.d_out) > space.lookahead_max_dim => {
                Some(format!(
                    "lookahead is O(N^3) in the digit count; capped at longest edge \
                     {} for this space",
                    space.lookahead_max_dim
                ))
            }
            ExploreTarget::Network(_) => {
                Some("lookahead is O(N^3) in the digit count; never run on full networks".into())
            }
            _ => None,
        };
        if let Some(reason) = skip_reason {
            skipped.push(SkippedCandidate {
                id: format!("{}/*", point_id(strategy, None).trim_end_matches("/comb")),
                reason,
            });
            return Ok((points, skipped));
        }
    }

    // Compile once per strategy; the pipeline rungs re-score the same
    // program. CMVM targets go through the coordinator so recurring
    // matrices (and repeated explorations in a serve session) hit the
    // solution cache.
    let program = match target {
        ExploreTarget::Cmvm(p) => {
            let job = CompileJob {
                name: point_id(strategy, None),
                problem: p.clone(),
                strategy,
            };
            let (sol, _cached) = coord.compile_cached(&job)?;
            sol.program.clone()
        }
        ExploreTarget::Network(spec) => {
            nn::compile::compile(spec, &nn::compile::CompileOptions::new(strategy))?.program
        }
    };

    for &pipe in &space.pipes {
        let rep = match pipe {
            None => estimate::combinational(&program, model),
            Some(n) => {
                let stages = pipeline::assign_stages(&program, &PipelineConfig::every_n_adders(n));
                debug_assert_eq!(
                    estimate::pipelined(&program, &stages, model).latency_cycles,
                    pipeline::latency(&program, &stages) + 1
                );
                estimate::pipelined(&program, &stages, model)
            }
        };
        points.push(point_from_report(strategy, pipe, &rep));
    }
    Ok((points, skipped))
}

/// `a` Pareto-dominates `b` on (LUT, FF, latency): no worse on every
/// axis and strictly better on at least one.
pub fn dominates(a: &DesignPoint, b: &DesignPoint) -> bool {
    let no_worse = a.lut <= b.lut && a.ff <= b.ff && a.latency_ns <= b.latency_ns;
    let better = a.lut < b.lut || a.ff < b.ff || a.latency_ns < b.latency_ns;
    no_worse && better
}

/// Split points into the non-dominated front and the dominated rest.
/// Ties (identical triples) are all kept on the front — they do not
/// dominate each other. The front is sorted by (LUT, latency, FF, id);
/// dominated points keep their enumeration order.
pub fn pareto_split(points: Vec<DesignPoint>) -> (Vec<DesignPoint>, Vec<DesignPoint>) {
    let mut front = Vec::new();
    let mut dominated = Vec::new();
    for i in 0..points.len() {
        let is_dominated =
            points.iter().enumerate().any(|(j, q)| j != i && dominates(q, &points[i]));
        if is_dominated {
            dominated.push(points[i].clone());
        } else {
            front.push(points[i].clone());
        }
    }
    front.sort_by(|a, b| {
        a.lut
            .cmp(&b.lut)
            .then(a.latency_ns.total_cmp(&b.latency_ns))
            .then(a.ff.cmp(&b.ff))
            .then(a.id.cmp(&b.id))
    });
    (front, dominated)
}

/// Explore a target: enumerate the space, compile each strategy on the
/// deterministic pool (shared `coord` cache), score every pipeline
/// rung, and split into front / dominated. The report is bit-identical
/// for every `cfg.jobs` value.
pub fn explore(
    target: &ExploreTarget,
    coord: &Coordinator,
    cfg: &ExploreConfig,
) -> Result<ExploreReport> {
    for pipe in &cfg.space.pipes {
        if let Some(0) = pipe {
            anyhow::bail!("explore: pipeline rung 0 is invalid (see PipelineConfig)");
        }
    }
    let strategies = compile_axis(&cfg.space);
    let results = pool::ordered_fan_out(strategies, cfg.jobs, |s| {
        let mut span = crate::obs::span("explore", "explore.candidate");
        span.arg_str("strategy", || s.name().to_string());
        if let Strategy::Da { dc } | Strategy::CseOnly { dc } | Strategy::Lookahead { dc } = s {
            span.arg("dc", dc as i64);
        }
        explore_one(target, coord, s, &cfg.space, &cfg.model)
    });
    let mut points = Vec::new();
    let mut skipped = Vec::new();
    for r in results {
        let (p, s) = r?;
        points.extend(p);
        skipped.extend(s);
    }
    let (front, dominated) = pareto_split(points);
    Ok(ExploreReport {
        schema_version: SCHEMA_VERSION,
        target: target.name(),
        front,
        dominated,
        skipped,
    })
}

/// Explore one CMVM with a fresh coordinator.
pub fn explore_cmvm(problem: &CmvmProblem, cfg: &ExploreConfig) -> Result<ExploreReport> {
    explore(&ExploreTarget::Cmvm(problem.clone()), &Coordinator::new(), cfg)
}

/// Explore one (fusible) network with a fresh coordinator.
pub fn explore_network(spec: &NetworkSpec, cfg: &ExploreConfig) -> Result<ExploreReport> {
    explore(&ExploreTarget::Network(spec.clone()), &Coordinator::new(), cfg)
}

/// Pick one front point for an objective (deterministic; ties broken
/// by id). Returns `None` only on an empty front.
pub fn pick(front: &[DesignPoint], objective: Objective) -> Option<&DesignPoint> {
    if front.is_empty() {
        return None;
    }
    match objective {
        Objective::MinLut => front.iter().min_by(|a, b| {
            a.lut
                .cmp(&b.lut)
                .then(a.latency_ns.total_cmp(&b.latency_ns))
                .then(a.ff.cmp(&b.ff))
                .then(a.id.cmp(&b.id))
        }),
        Objective::MinLatency => front.iter().min_by(|a, b| {
            a.latency_ns
                .total_cmp(&b.latency_ns)
                .then(a.lut.cmp(&b.lut))
                .then(a.ff.cmp(&b.ff))
                .then(a.id.cmp(&b.id))
        }),
        Objective::Knee => {
            let lut_min = front.iter().map(|p| p.lut).min().unwrap() as f64;
            let lut_max = front.iter().map(|p| p.lut).max().unwrap() as f64;
            let lat_min = front.iter().map(|p| p.latency_ns).fold(f64::INFINITY, f64::min);
            let lat_max = front.iter().map(|p| p.latency_ns).fold(f64::NEG_INFINITY, f64::max);
            let norm = |v: f64, lo: f64, hi: f64| if hi > lo { (v - lo) / (hi - lo) } else { 0.0 };
            let dist = |p: &DesignPoint| {
                let nl = norm(p.lut as f64, lut_min, lut_max);
                let nt = norm(p.latency_ns, lat_min, lat_max);
                nl * nl + nt * nt
            };
            front
                .iter()
                .min_by(|a, b| dist(a).total_cmp(&dist(b)).then(a.id.cmp(&b.id)))
        }
    }
}

/// Human-readable rendering of an explore report (the CLI prints
/// exactly this next to the JSON artifact).
pub fn render_table(r: &ExploreReport) -> String {
    let mut table = Table::new(
        &format!(
            "explore '{}' — Pareto front ({} points, {} dominated, schema v{})",
            r.target,
            r.front.len(),
            r.dominated.len(),
            r.schema_version
        ),
        &["point", "LUT", "DSP", "FF", "adders", "depth", "latency[ns]", "cycles", "fmax[MHz]"],
    );
    for p in &r.front {
        table.push(vec![
            p.id.clone(),
            p.lut.to_string(),
            p.dsp.to_string(),
            p.ff.to_string(),
            p.adders.to_string(),
            p.depth.to_string(),
            format!("{:.2}", p.latency_ns),
            p.latency_cycles.to_string(),
            format!("{:.0}", p.fmax_mhz),
        ]);
    }
    let mut out = table.render();
    for sk in &r.skipped {
        out.push_str(&format!("skipped: {} — {}\n", sk.id, sk.reason));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::property;

    fn tiny_point(id: &str, lut: u64, ff: u64, lat: f64) -> DesignPoint {
        DesignPoint {
            id: id.into(),
            strategy: Strategy::Da { dc: -1 },
            pipe: None,
            adders: 0,
            depth: 0,
            lut,
            dsp: 0,
            ff,
            latency_ns: lat,
            latency_cycles: 1,
            fmax_mhz: 100.0,
        }
    }

    #[test]
    fn dominance_semantics() {
        let a = tiny_point("a", 10, 10, 1.0);
        let b = tiny_point("b", 10, 10, 2.0);
        let c = tiny_point("c", 9, 11, 1.0);
        assert!(dominates(&a, &b));
        assert!(!dominates(&b, &a));
        // Incomparable: each better on one axis.
        assert!(!dominates(&a, &c) && !dominates(&c, &a));
        // Equal triples never dominate each other.
        assert!(!dominates(&a, &a.clone()));
    }

    #[test]
    fn pareto_split_keeps_ties_and_sorts_front() {
        let pts = vec![
            tiny_point("big", 20, 20, 5.0),
            tiny_point("b", 10, 10, 1.0),
            tiny_point("a", 10, 10, 1.0), // tie with b: both on the front
            tiny_point("fast", 15, 10, 0.5),
        ];
        let (front, dominated) = pareto_split(pts);
        assert_eq!(dominated.len(), 1);
        assert_eq!(dominated[0].id, "big");
        let ids: Vec<&str> = front.iter().map(|p| p.id.as_str()).collect();
        // Sorted by (lut, latency, ff, id): the tie orders a before b.
        assert_eq!(ids, vec!["a", "b", "fast"]);
    }

    #[test]
    fn pick_objectives() {
        let front = vec![
            tiny_point("lean", 10, 8, 9.0),
            tiny_point("mid", 14, 12, 5.0),
            tiny_point("fast", 30, 40, 1.0),
        ];
        assert_eq!(pick(&front, Objective::MinLut).unwrap().id, "lean");
        assert_eq!(pick(&front, Objective::MinLatency).unwrap().id, "fast");
        // The knee balances both normalized axes: "mid" (0.2, 0.5) beats
        // the corners (0, 1) and (1, 0).
        assert_eq!(pick(&front, Objective::Knee).unwrap().id, "mid");
        assert!(pick(&[], Objective::Knee).is_none());
    }

    #[test]
    fn pick_single_point_front() {
        let front = vec![tiny_point("only", 10, 8, 9.0)];
        for obj in [Objective::MinLut, Objective::MinLatency, Objective::Knee] {
            assert_eq!(pick(&front, obj).unwrap().id, "only");
        }
    }

    #[test]
    fn compile_axis_enumeration_order_is_stable() {
        let axis = compile_axis(&SpaceConfig::smoke());
        assert_eq!(axis.len(), 2 + 3 * 3);
        assert_eq!(axis[0], Strategy::Latency);
        assert_eq!(axis[1], Strategy::NaiveDa);
        assert_eq!(axis[2], Strategy::CseOnly { dc: -1 });
        assert_eq!(axis[3], Strategy::Da { dc: -1 });
        assert_eq!(axis[4], Strategy::Lookahead { dc: -1 });
    }

    #[test]
    fn point_ids_are_stable() {
        assert_eq!(point_id(Strategy::Latency, None), "latency/mac");
        assert_eq!(point_id(Strategy::NaiveDa, None), "naive-da/comb");
        assert_eq!(point_id(Strategy::Da { dc: 2 }, Some(5)), "da/dc2/pipe5");
        assert_eq!(point_id(Strategy::CseOnly { dc: -1 }, Some(1)), "cse-only/dc-1/pipe1");
        assert_eq!(point_id(Strategy::Lookahead { dc: 0 }, None), "lookahead/dc0/comb");
    }

    /// Pareto invariants on real explorations of seeded random CMVMs:
    /// no front point dominates another, and every dominated point is
    /// dominated by at least one front point.
    #[test]
    fn prop_pareto_invariants_on_random_cmvms() {
        property("explore_pareto_invariants", 4, |rng| {
            let d_in = rng.below(3) + 2;
            let d_out = rng.below(3) + 2;
            let m: Vec<i64> = (0..d_in * d_out).map(|_| rng.range_i64(-127, 127)).collect();
            let problem = CmvmProblem::new(d_in, d_out, m, 8).unwrap();
            let cfg = ExploreConfig { jobs: 2, ..ExploreConfig::smoke() };
            let report = explore_cmvm(&problem, &cfg).unwrap();
            assert!(!report.front.is_empty(), "front can never be empty");
            for (i, a) in report.front.iter().enumerate() {
                for (j, b) in report.front.iter().enumerate() {
                    if i != j {
                        assert!(!dominates(a, b), "front point {} dominates {}", a.id, b.id);
                    }
                }
            }
            for d in &report.dominated {
                assert!(
                    report.front.iter().any(|f| dominates(f, d)),
                    "dominated point {} not dominated by any front point",
                    d.id
                );
            }
        });
    }

    /// The dc ladder produces a genuine area/latency trade-off: the
    /// front of a non-trivial CMVM has at least two points.
    #[test]
    fn front_has_a_tradeoff_on_nontrivial_cmvm() {
        let problem = CmvmProblem::random(11, 8, 8, 8);
        let cfg = ExploreConfig { jobs: 1, ..ExploreConfig::smoke() };
        let report = explore_cmvm(&problem, &cfg).unwrap();
        assert!(
            report.front.len() >= 2,
            "expected a trade-off front, got {:?}",
            report.front.iter().map(|p| &p.id).collect::<Vec<_>>()
        );
        // Everything that was scored landed somewhere.
        assert!(!report.dominated.is_empty() || report.front.len() > 2);
    }
}
