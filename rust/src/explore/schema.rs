//! The explore-report JSON schema (version [`super::SCHEMA_VERSION`]),
//! following the [`crate::perf::schema`] versioning pattern: a compact
//! schema-versioned document the CLI writes (`da4ml explore --out`),
//! CI uploads as an artifact, and the serve `"explore"` reply embeds.
//!
//! The document is a pure function of the exploration result — no
//! timings, hostnames, or thread counts — so `--jobs N` output is
//! byte-identical to `--jobs 1` (pinned by `rust/tests/explore.rs`).
//! Field reference: `docs/explore.md`.

use super::{DesignPoint, ExploreReport};
use crate::json::{self, Value};
use std::collections::BTreeMap;

fn obj(entries: Vec<(&str, Value)>) -> Value {
    Value::Object(
        entries
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<_, _>>(),
    )
}

fn int(v: u64) -> Value {
    Value::Int(v as i64)
}

/// One design point as a JSON object (shared by the report document
/// and the serve `"explore"` reply).
pub fn point_value(p: &DesignPoint) -> Value {
    obj(vec![
        ("id", Value::Str(p.id.clone())),
        ("strategy", Value::Str(p.strategy.name().to_string())),
        (
            "dc",
            match p.dc() {
                Some(dc) => Value::Int(dc as i64),
                None => Value::Null,
            },
        ),
        (
            "pipe",
            match p.pipe {
                Some(n) => Value::Int(n as i64),
                None => Value::Null,
            },
        ),
        ("adders", int(p.adders)),
        ("depth", int(p.depth as u64)),
        ("lut", int(p.lut)),
        ("dsp", int(p.dsp)),
        ("ff", int(p.ff)),
        ("latency_ns", Value::Float(p.latency_ns)),
        ("latency_cycles", int(p.latency_cycles as u64)),
        ("fmax_mhz", Value::Float(p.fmax_mhz)),
    ])
}

/// The full report as a JSON value.
pub fn to_value(r: &ExploreReport) -> Value {
    obj(vec![
        ("schema_version", int(r.schema_version as u64)),
        ("target", Value::Str(r.target.clone())),
        (
            "front",
            Value::Array(r.front.iter().map(point_value).collect()),
        ),
        (
            "dominated",
            Value::Array(r.dominated.iter().map(point_value).collect()),
        ),
        (
            "skipped",
            Value::Array(
                r.skipped
                    .iter()
                    .map(|s| {
                        obj(vec![
                            ("id", Value::Str(s.id.clone())),
                            ("reason", Value::Str(s.reason.clone())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Serialize the report to its compact JSON text.
pub fn render(r: &ExploreReport) -> String {
    json::to_string(&to_value(r))
}

#[cfg(test)]
mod tests {
    use super::super::{ExploreReport, SkippedCandidate, SCHEMA_VERSION};
    use super::*;
    use crate::cmvm::Strategy;

    fn tiny_report() -> ExploreReport {
        let p = DesignPoint {
            id: "da/dc2/pipe5".into(),
            strategy: Strategy::Da { dc: 2 },
            pipe: Some(5),
            adders: 7,
            depth: 3,
            lut: 80,
            dsp: 0,
            ff: 64,
            latency_ns: 3.5,
            latency_cycles: 2,
            fmax_mhz: 400.0,
        };
        let q = DesignPoint {
            id: "latency/mac".into(),
            strategy: Strategy::Latency,
            pipe: None,
            adders: 12,
            depth: 4,
            lut: 200,
            dsp: 4,
            ff: 32,
            latency_ns: 6.0,
            latency_cycles: 1,
            fmax_mhz: 160.0,
        };
        ExploreReport {
            schema_version: SCHEMA_VERSION,
            target: "cmvm/4x4".into(),
            front: vec![p],
            dominated: vec![q],
            skipped: vec![SkippedCandidate {
                id: "lookahead/dc2/*".into(),
                reason: "O(N^3)".into(),
            }],
        }
    }

    #[test]
    fn report_roundtrips_through_json() {
        let r = tiny_report();
        let text = render(&r);
        let v = json::parse(&text).expect("report is valid JSON");
        assert_eq!(v.get("schema_version").unwrap().as_i64().unwrap(), 1);
        assert_eq!(v.get("target").unwrap().as_str().unwrap(), "cmvm/4x4");
        let front = v.get("front").unwrap().as_array().unwrap();
        assert_eq!(front.len(), 1);
        assert_eq!(front[0].get("id").unwrap().as_str().unwrap(), "da/dc2/pipe5");
        assert_eq!(front[0].get("dc").unwrap().as_i64().unwrap(), 2);
        assert_eq!(front[0].get("pipe").unwrap().as_i64().unwrap(), 5);
        assert_eq!(front[0].get("lut").unwrap().as_i64().unwrap(), 80);
        assert!((front[0].get("latency_ns").unwrap().as_f64().unwrap() - 3.5).abs() < 1e-12);
        let dom = v.get("dominated").unwrap().as_array().unwrap();
        assert_eq!(dom.len(), 1);
        assert_eq!(dom[0].get("strategy").unwrap().as_str().unwrap(), "latency");
        assert_eq!(dom[0].get("dc").unwrap(), &Value::Null);
        assert_eq!(dom[0].get("pipe").unwrap(), &Value::Null);
        assert_eq!(v.get("skipped").unwrap().as_array().unwrap().len(), 1);
    }

    /// Rendering is a pure function of the report value: two renders of
    /// the same report are byte-identical.
    #[test]
    fn render_is_deterministic() {
        let r = tiny_report();
        assert_eq!(render(&r), render(&r));
    }
}
