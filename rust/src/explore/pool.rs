//! The explorer's deterministic worker pool.
//!
//! Exploration fans candidate compiles out across threads, but the
//! report must be **bit-identical** regardless of `--jobs`: the same
//! points, in the same order, serializing to the same bytes. This
//! module owns that contract as a thin front over
//! [`crate::util::parallel_map`] (the std-only scoped pool whose
//! results always merge in submission order):
//!
//! * `jobs == 0` resolves to the available hardware parallelism;
//! * `jobs == 1` short-circuits to a plain sequential map (no threads,
//!   no locks) — the reference order the parallel path must reproduce;
//! * anything else delegates to the scoped pool, which writes each
//!   result into the slot of the item that produced it, so the merged
//!   output is the submission-order sequence no matter which thread
//!   finished when.
//!
//! The determinism tests in `rust/tests/explore.rs` pin `--jobs 4`
//! byte-identical to `--jobs 1` on the serialized report.

/// Map `f` over `items` on `jobs` scoped threads, returning results in
/// submission order. `jobs == 0` selects the available hardware
/// parallelism. Item processing must be a pure function of the item
/// (plus shared read-only state) for the determinism guarantee to mean
/// anything — the pool only guarantees *ordering*.
pub fn ordered_fan_out<T, U, F>(items: Vec<T>, jobs: usize, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(T) -> U + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let jobs = if jobs == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    } else {
        jobs
    };
    let jobs = jobs.clamp(1, n);
    if jobs == 1 {
        // The reference order: strictly sequential, no synchronization.
        return items.into_iter().map(f).collect();
    }
    crate::util::parallel_map(items, jobs, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn order_preserved_across_thread_counts() {
        let items: Vec<u64> = (0..53).collect();
        let want: Vec<u64> = items.iter().map(|x| x * 3 + 1).collect();
        for jobs in [1usize, 2, 4, 8, 0] {
            let got = ordered_fan_out(items.clone(), jobs, |x| x * 3 + 1);
            assert_eq!(got, want, "jobs={jobs}");
        }
    }

    #[test]
    fn empty_and_single_item() {
        let got: Vec<u32> = ordered_fan_out(Vec::<u32>::new(), 4, |x| x);
        assert!(got.is_empty());
        assert_eq!(ordered_fan_out(vec![7u32], 4, |x| x + 1), vec![8]);
    }

    #[test]
    fn more_jobs_than_items_is_fine() {
        let got = ordered_fan_out(vec![1u64, 2, 3], 64, |x| x * x);
        assert_eq!(got, vec![1, 4, 9]);
    }
}
