//! The pre-index CSE engine, retained verbatim as the **differential
//! reference** for the indexed hot path in `engine.rs` — the same role
//! the test-only `json::legacy` parser plays for the pull parser.
//!
//! Two consumers keep it alive:
//!
//! * the seeded differential property sweep in `cse::tests`, which
//!   proves the indexed engine emits a bit-identical
//!   [`crate::dais::DaisProgram`] on random matrices × all five
//!   [`crate::cmvm::Strategy`] variants × depth constraints;
//! * the perf suite ([`crate::perf`]), whose engine A/B case times both
//!   engines head-to-head on the jet workload and reports the measured
//!   speedup in `BENCH_cmvm.json`.
//!
//! Its occurrence matching rescans every column of the digit tensor on
//! every heap pop (`match_occurrences` below), and its a-side digit
//! collection filters a full column scan — exactly the hot-path costs
//! the indexed engine eliminates. Do not "optimize" this module: its
//! entire value is being the frozen pre-refactor behavior. Work
//! counters ([`CseStats`]) were added for the A/B report; they do not
//! influence any decision the engine makes.

use super::engine::{CseConfig, CseStats, InputTerm, OutTerm};
use super::tree;
use crate::csd::Csd;
use crate::dais::{DaisBuilder, NodeId};
use crate::fixed::QInterval;
use crate::util::fxhash::FxHashMap;
use std::collections::BinaryHeap;

/// One signed digit of the tensor, located in a column.
#[derive(Debug, Clone, Copy)]
struct ColDigit {
    row: u32,
    power: i32,
    sign: i8,
    alive: bool,
}

/// A column of `M_expr` with a (row, power) index for O(1) partner lookup
/// and the Kraft sum for the depth-feasibility check.
#[derive(Debug, Default)]
struct Column {
    digits: Vec<ColDigit>,
    index: FxHashMap<(u32, i32), u32>,
    /// Σ 2^depth(row) over alive digits (u128; depths are budget-bounded).
    kraft: u128,
    /// Dead entries in `digits` (compaction trigger).
    dead: u32,
    /// Alive digits per row, indexed by row id (lets occurrence
    /// matching skip columns that cannot contain a pattern at all).
    row_count: Vec<u32>,
}

impl Column {
    /// Drop dead digits and rebuild the index. Pattern counts are
    /// index-independent, so this is safe between update steps; it keeps
    /// the alive() scans O(live) instead of O(all-ever-created).
    fn compact(&mut self) {
        if (self.dead as usize) * 2 < self.digits.len() {
            return;
        }
        self.digits.retain(|d| d.alive);
        self.index.clear();
        for (i, d) in self.digits.iter().enumerate() {
            self.index.insert((d.row, d.power), i as u32);
        }
        self.dead = 0;
    }

    fn row_inc(&mut self, row: u32) {
        let r = row as usize;
        if r >= self.row_count.len() {
            self.row_count.resize(r + 1, 0);
        }
        self.row_count[r] += 1;
    }

    fn row_dec(&mut self, row: u32) {
        self.row_count[row as usize] -= 1;
    }

    fn has_row(&self, row: u32) -> bool {
        self.row_count.get(row as usize).copied().unwrap_or(0) > 0
    }

    fn alive(&self) -> impl Iterator<Item = (u32, &ColDigit)> {
        self.digits.iter().enumerate().filter(|(_, d)| d.alive).map(|(i, d)| (i as u32, d))
    }
}

/// Canonical two-term pattern: value `L[ra] ± (L[rb] << shift)`.
/// Orientation: the `ra` digit sits at the lower power; ties broken by
/// row order. Sign-normalized so the `ra` digit is positive.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
struct Pattern {
    ra: u32,
    rb: u32,
    shift: u32,
    sub: bool,
}

/// Canonicalize a digit pair into (pattern, a-index, b-index) — `None`
/// when the two digits are the same digit.
#[inline]
fn canon(d1: (u32, &ColDigit), d2: (u32, &ColDigit)) -> Option<(Pattern, u32, u32)> {
    let (i1, a) = d1;
    let (i2, b) = d2;
    if i1 == i2 {
        return None;
    }
    let ((ia, da), (ib, db)) = if (a.power, a.row, i1) <= (b.power, b.row, i2) {
        ((i1, a), (i2, b))
    } else {
        ((i2, b), (i1, a))
    };
    Some((
        Pattern {
            ra: da.row,
            rb: db.row,
            shift: (db.power - da.power) as u32,
            sub: da.sign != db.sign,
        },
        ia,
        ib,
    ))
}

/// Heap entry (max-heap by score, deterministic tie-break on pattern).
#[derive(PartialEq, Eq)]
struct HeapEntry {
    score: i64,
    count: u32,
    pattern: Pattern,
}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.score
            .cmp(&other.score)
            .then(self.count.cmp(&other.count))
            .then_with(|| other.pattern.cmp(&self.pattern))
    }
}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

struct Engine<'a> {
    builder: &'a mut DaisBuilder,
    d_out: usize,
    cfg: CseConfig,
    rows: Vec<RowInfo>,
    cols: Vec<Column>,
    counts: FxHashMap<Pattern, u32>,
    heap: BinaryHeap<HeapEntry>,
    parked: FxHashMap<Pattern, u32>,
    budget: Option<Vec<u32>>,
    scratch: Vec<Pattern>,
    stats: CseStats,
}

#[derive(Debug, Clone, Copy)]
struct RowInfo {
    node: NodeId,
    qint: QInterval,
    depth: u32,
}

impl<'a> Engine<'a> {
    fn weight(&self, p: &Pattern) -> i64 {
        if !self.cfg.weighted {
            return 1;
        }
        let qa = self.rows[p.ra as usize].qint;
        let qb = self.rows[p.rb as usize].qint;
        let s = p.shift as i32;
        let ov = (qa.msb().min(qb.msb() + s)) - (qa.lsb().max(qb.lsb() + s));
        ov.max(1) as i64
    }

    fn score(&self, p: &Pattern, count: u32) -> i64 {
        count as i64 * self.weight(p)
    }

    fn push_heap(&mut self, p: Pattern) {
        let count = *self.counts.get(&p).unwrap_or(&0);
        if count >= 2 {
            self.heap.push(HeapEntry { score: self.score(&p, count), count, pattern: p });
        }
    }

    /// Adjust the count of `p` by ±1 and refresh heap/parking state.
    fn bump(&mut self, p: Pattern, delta: i32) {
        let e = self.counts.entry(p).or_insert(0);
        *e = (*e as i32 + delta) as u32;
        let c = *e;
        if c == 0 {
            self.counts.remove(&p);
        }
        if let Some(&parked_at) = self.parked.get(&p) {
            if parked_at != c {
                self.parked.remove(&p);
            }
        }
        if c >= 2 && !self.parked.contains_key(&p) {
            self.heap.push(HeapEntry { score: self.score(&p, c), count: c, pattern: p });
        }
    }

    /// Kill digit `idx` in column `c`, updating counts and Kraft sum.
    fn kill(&mut self, c: usize, idx: u32) {
        let d = self.cols[c].digits[idx as usize];
        debug_assert!(d.alive);
        self.cols[c].digits[idx as usize].alive = false;
        self.cols[c].dead += 1;
        self.cols[c].row_dec(d.row);
        self.cols[c].index.remove(&(d.row, d.power));
        self.cols[c].kraft -= 1u128 << self.rows[d.row as usize].depth;
        let mut pairs = std::mem::take(&mut self.scratch);
        pairs.clear();
        pairs.extend(
            self.cols[c]
                .alive()
                .filter_map(|e| canon((idx, &d), e).map(|(p, _, _)| p)),
        );
        for p in &pairs {
            self.bump(*p, -1);
        }
        self.scratch = pairs;
    }

    /// Add a digit to column `c`, updating counts and Kraft sum.
    fn add_digit(&mut self, c: usize, row: u32, power: i32, sign: i8) {
        let digit = ColDigit { row, power, sign, alive: true };
        let mut pairs = std::mem::take(&mut self.scratch);
        pairs.clear();
        pairs.extend(
            self.cols[c]
                .alive()
                .filter_map(|e| canon((u32::MAX, &digit), e).map(|(p, _, _)| p)),
        );
        let idx = self.cols[c].digits.len() as u32;
        debug_assert!(
            !self.cols[c].index.contains_key(&(row, power)),
            "duplicate (row, power) digit in column {c}"
        );
        self.cols[c].digits.push(digit);
        self.cols[c].index.insert((row, power), idx);
        self.cols[c].row_inc(row);
        self.cols[c].kraft += 1u128 << self.rows[row as usize].depth;
        for p in &pairs {
            self.bump(*p, 1);
        }
        self.scratch = pairs;
    }

    /// Greedily match disjoint occurrences of `p` in every column —
    /// the full rescan the indexed engine replaces. Returns
    /// (column, a-digit-idx, b-digit-idx) triples.
    fn match_occurrences(&mut self, p: &Pattern) -> Vec<(usize, u32, u32)> {
        let mut occ = Vec::new();
        let mut cols_scanned = 0usize;
        let mut digits_scanned = 0usize;
        for (c, col) in self.cols.iter().enumerate() {
            if !col.has_row(p.ra) || !col.has_row(p.rb) {
                continue;
            }
            cols_scanned += 1;
            digits_scanned += col.digits.len();
            let mut used: Vec<u32> = Vec::new();
            // Iterate a-side digits in power order for maximal greedy
            // matching of chain patterns (same-row, shifted).
            let mut a_side: Vec<(u32, &ColDigit)> =
                col.alive().filter(|(_, d)| d.row == p.ra).collect();
            a_side.sort_by_key(|(_, d)| d.power);
            for (ia, da) in a_side {
                if used.contains(&ia) {
                    continue;
                }
                let pb = da.power + p.shift as i32;
                if let Some(&ib) = col.index.get(&(p.rb, pb)) {
                    if ib == ia || used.contains(&ib) {
                        continue;
                    }
                    let db = &col.digits[ib as usize];
                    debug_assert!(db.alive);
                    // Sign relation must match the canonical pattern…
                    let sub = da.sign != db.sign;
                    if sub != p.sub {
                        continue;
                    }
                    // …and the orientation must canonicalize to `p`
                    // (guards the shift==0 row-order tie and ra==rb).
                    if let Some((cp, ca, cb)) = canon((ia, da), (ib, db)) {
                        if cp == *p {
                            used.push(ca);
                            used.push(cb);
                            occ.push((c, ca, cb));
                        }
                    }
                }
            }
        }
        self.stats.occ_cols_scanned += cols_scanned;
        self.stats.occ_digits_scanned += digits_scanned;
        occ
    }

    /// Depth-feasibility filter: keep as many occurrences per column as
    /// the Kraft budget allows. Returns the admitted occurrences.
    fn filter_depth(&mut self, p: &Pattern, occ: Vec<(usize, u32, u32)>) -> Vec<(usize, u32, u32)> {
        let Some(budget) = &self.budget else { return occ };
        let da = self.rows[p.ra as usize].depth;
        let db = self.rows[p.rb as usize].depth;
        let delta: i128 =
            (1i128 << (da.max(db) + 1)) - (1i128 << da) - (1i128 << db);
        if delta == 0 {
            return occ; // equal-depth merge never hurts feasibility
        }
        let mut kept = Vec::with_capacity(occ.len());
        let mut extra: FxHashMap<usize, i128> = FxHashMap::default();
        for (c, ia, ib) in occ {
            let used = extra.entry(c).or_insert(0);
            let cap = 1i128 << budget[c];
            if self.cols[c].kraft as i128 + *used + delta <= cap {
                *used += delta;
                kept.push((c, ia, ib));
            } else {
                self.stats.depth_rejections += 1;
            }
        }
        kept
    }

    /// One update step: pick the best implementable pattern and rewrite
    /// the tensor. Returns false when exhausted.
    fn step(&mut self) -> bool {
        loop {
            let Some(top) = self.heap.pop() else { return false };
            self.stats.heap_pops += 1;
            let p = top.pattern;
            let cur = *self.counts.get(&p).unwrap_or(&0);
            if cur != top.count || cur < 2 || self.parked.contains_key(&p) {
                self.stats.stale_pops += 1;
                continue; // stale entry
            }
            let occ = self.match_occurrences(&p);
            let occ = self.filter_depth(&p, occ);
            if occ.len() < 2 {
                // Not worth an adder (or depth-blocked): park at this
                // count; any count change un-parks it.
                self.parked.insert(p, cur);
                continue;
            }
            // Implement: one new adder node, one new tensor row.
            let a = self.rows[p.ra as usize];
            let b = self.rows[p.rb as usize];
            let node = self.builder.add_shift(a.node, b.node, p.shift, p.sub);
            let row = self.rows.len() as u32;
            self.rows.push(RowInfo {
                node,
                qint: self.builder.qint(node),
                depth: self.builder.depth(node),
            });
            let mut touched: Vec<usize> = Vec::with_capacity(occ.len());
            for (c, ia, ib) in occ {
                // The occurrence's contribution is sign(a-digit) · w << p_a.
                let (pa, sa) = {
                    let d = &self.cols[c].digits[ia as usize];
                    (d.power, d.sign)
                };
                self.kill(c, ia);
                self.kill(c, ib);
                self.add_digit(c, row, pa, sa);
                touched.push(c);
            }
            for c in touched {
                self.cols[c].compact();
            }
            self.stats.steps += 1;
            return true;
        }
    }
}

/// Reference implementation of [`super::optimize_into`]: identical
/// greedy selection, pre-index occurrence matching.
pub fn optimize_into(
    builder: &mut DaisBuilder,
    inputs: &[InputTerm],
    matrix: &[i64],
    d_in: usize,
    d_out: usize,
    cfg: &CseConfig,
) -> Vec<OutTerm> {
    optimize_into_stats(builder, inputs, matrix, d_in, d_out, cfg).0
}

/// Like [`optimize_into`] but also returns engine statistics.
pub fn optimize_into_stats(
    builder: &mut DaisBuilder,
    inputs: &[InputTerm],
    matrix: &[i64],
    d_in: usize,
    d_out: usize,
    cfg: &CseConfig,
) -> (Vec<OutTerm>, CseStats) {
    assert_eq!(matrix.len(), d_in * d_out, "matrix shape mismatch");
    assert_eq!(inputs.len(), d_in, "input arity mismatch");

    let rows: Vec<RowInfo> = inputs
        .iter()
        .map(|t| RowInfo {
            node: t.node,
            qint: builder.qint(t.node),
            depth: builder.depth(t.node),
        })
        .collect();

    // Build the digit tensor column by column.
    let mut cols: Vec<Column> = (0..d_out).map(|_| Column::default()).collect();
    for (c, col) in cols.iter_mut().enumerate() {
        for j in 0..d_in {
            let w = matrix[j * d_out + c];
            for digit in Csd::encode(w).digits() {
                let idx = col.digits.len() as u32;
                col.digits.push(ColDigit {
                    row: j as u32,
                    power: digit.power,
                    sign: digit.sign,
                    alive: true,
                });
                col.index.insert((j as u32, digit.power), idx);
                col.row_inc(j as u32);
                col.kraft += 1u128 << rows[j].depth;
            }
        }
    }

    // Depth budgets, exactly as in the indexed engine (see engine.rs
    // for the Kraft-sum rationale).
    let budget = if cfg.dc >= 0 {
        let col_min: Vec<u32> = cols
            .iter()
            .map(|c| super::engine::min_feasible_depth(c.kraft))
            .collect();
        let depth_min = col_min.iter().copied().max().unwrap_or(0);
        Some(
            col_min
                .iter()
                .map(|&m| m.max(depth_min + cfg.dc as u32))
                .collect::<Vec<u32>>(),
        )
    } else {
        None
    };

    // Initial pattern counts: all digit pairs within each column.
    let mut counts: FxHashMap<Pattern, u32> = FxHashMap::default();
    for col in &cols {
        let alive: Vec<(u32, &ColDigit)> = col.alive().collect();
        for i in 0..alive.len() {
            for j in (i + 1)..alive.len() {
                if let Some((p, _, _)) = canon(alive[i], alive[j]) {
                    *counts.entry(p).or_insert(0) += 1;
                }
            }
        }
    }

    let mut engine = Engine {
        builder,
        d_out,
        cfg: *cfg,
        rows,
        cols,
        counts,
        heap: BinaryHeap::new(),
        parked: FxHashMap::default(),
        budget,
        scratch: Vec::new(),
        stats: CseStats::default(),
    };
    let patterns: Vec<Pattern> = engine.counts.keys().copied().collect();
    for p in patterns {
        engine.push_heap(p);
    }

    while engine.step() {}

    // Final summation of residual digits, column by column.
    let term_lists: Vec<Vec<tree::Term>> = (0..engine.d_out)
        .map(|c| {
            engine.cols[c]
                .alive()
                .map(|(_, d)| tree::Term {
                    node: engine.rows[d.row as usize].node,
                    shift: d.power,
                    neg: d.sign < 0,
                })
                .collect()
        })
        .collect();
    let stats = engine.stats;
    let builder = engine.builder;
    let out = term_lists.into_iter().map(|terms| tree::combine(builder, terms)).collect();
    (out, stats)
}
