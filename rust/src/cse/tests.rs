//! Integration and property tests for the CSE stage, including the
//! differential sweep proving the indexed engine bit-identical to the
//! retained pre-index reference.

use super::engine::test_hooks;
use super::*;
use crate::cmvm::{self, CmvmProblem, OptimizeOptions, Strategy};
use crate::dais::{interp, verify, DaisBuilder};
use crate::fixed::QInterval;
use crate::util::{property, Rng};

fn run_cse(matrix: &[i64], d_in: usize, d_out: usize, dc: i32) -> crate::dais::DaisProgram {
    let mut b = DaisBuilder::new();
    let q = QInterval::new(-128, 127, 0);
    let inputs: Vec<InputTerm> =
        (0..d_in).map(|j| InputTerm { node: b.input(j, q, 0) }).collect();
    let (outs, _) = compile(
        &mut b,
        &inputs,
        matrix,
        d_in,
        d_out,
        &CseConfig { dc, ..CseConfig::default() },
        None,
    );
    for o in &outs {
        match o.node {
            Some(n) => {
                let n = if o.neg { b.neg(n) } else { n };
                b.output(n, o.shift);
            }
            None => {
                let z = b.constant(0);
                b.output(z, 0);
            }
        }
    }
    b.finish()
}

/// Paper Fig. 3/4: the H.264 integer transform must optimize from 12
/// adders (naive) down to 8.
#[test]
fn h264_twelve_to_eight_adders() {
    // Paper shows y = M x with rows; our convention is y^T = x^T M, so
    // feed the transpose: column i of our matrix = row i of the paper's.
    // Paper matrix rows: [1 1 1 1; 2 1 -1 -2; 1 -1 -1 1; 1 -2 2 -1].
    let m = vec![
        1, 2, 1, 1, //
        1, 1, -1, -2, //
        1, -1, -1, 2, //
        1, -2, 1, -1, //
    ];
    let naive = {
        let mut b = DaisBuilder::new();
        let q = QInterval::new(-128, 127, 0);
        let inputs: Vec<InputTerm> =
            (0..4).map(|j| InputTerm { node: b.input(j, q, 0) }).collect();
        let outs = naive_da(&mut b, &inputs, &m, 4, 4);
        for o in &outs {
            b.output(o.node.unwrap(), o.shift);
        }
        b.finish()
    };
    assert_eq!(naive.adder_count(), 12);

    let p = run_cse(&m, 4, 4, -1);
    verify::check_cmvm_equivalence(&p, &m, 4, 4).unwrap();
    assert_eq!(p.adder_count(), 8, "paper Fig. 4: 12 -> 8 adders");
}

#[test]
fn cse_shares_scaled_subexpressions() {
    // x0 + x1 appears once plainly and once scaled by 4: the
    // shift-invariant pattern must be shared (1 shared adder + 2 column
    // adders would be 3; without scale-aware CSE it would be 4).
    let m = vec![
        1, 5, //
        1, 5, //
        1, 0, //
    ];
    // col0 = x0 + x1 + x2 ; col1 = 5(x0 + x1) = (x0+x1) + 4(x0+x1)
    let p = run_cse(&m, 3, 2, -1);
    verify::check_cmvm_equivalence(&p, &m, 3, 2).unwrap();
    assert!(p.adder_count() <= 3, "got {} adders", p.adder_count());
}

#[test]
fn cse_shares_sign_flipped_subexpressions() {
    // col0 = x0 - x1, col1 = -(x0 - x1) + x2: pattern (x0 - x1) shared
    // across opposite global signs.
    let m = vec![
        1, -1, //
        -1, 1, //
        0, 1, //
    ];
    let p = run_cse(&m, 3, 2, -1);
    verify::check_cmvm_equivalence(&p, &m, 3, 2).unwrap();
    assert!(p.adder_count() <= 2, "got {} adders", p.adder_count());
}

#[test]
fn depth_constraint_zero_gives_minimal_depth() {
    let mut rng = Rng::seed_from(42);
    for _ in 0..5 {
        let (d_in, d_out) = (8, 8);
        let m: Vec<i64> =
            (0..d_in * d_out).map(|_| rng.range_i64(129, 255)).collect();
        // Minimal depth from the densest column's digit count.
        let min_depth = (0..d_out)
            .map(|i| {
                let digits: u32 =
                    (0..d_in).map(|j| crate::csd::nnz(m[j * d_out + i])).sum();
                (digits as f64).log2().ceil() as u32
            })
            .max()
            .unwrap();
        let p = run_cse(&m, d_in, d_out, 0);
        verify::check_cmvm_equivalence(&p, &m, d_in, d_out).unwrap();
        assert!(
            p.adder_depth() <= min_depth,
            "dc=0: depth {} > minimal {min_depth}",
            p.adder_depth()
        );
    }
}

#[test]
fn depth_constraint_relaxation_reduces_adders() {
    let mut rng = Rng::seed_from(1);
    let (d_in, d_out) = (12, 12);
    let m: Vec<i64> = (0..d_in * d_out).map(|_| rng.range_i64(129, 255)).collect();
    let strict = run_cse(&m, d_in, d_out, 0);
    let relaxed = run_cse(&m, d_in, d_out, -1);
    verify::check_cmvm_equivalence(&strict, &m, d_in, d_out).unwrap();
    verify::check_cmvm_equivalence(&relaxed, &m, d_in, d_out).unwrap();
    assert!(relaxed.adder_count() <= strict.adder_count());
    assert!(relaxed.adder_depth() >= strict.adder_depth());
}

#[test]
fn single_column_mcm() {
    // MCM special case: d_out = 1.
    let m = vec![7, 11, 13, 19];
    let p = run_cse(&m, 4, 1, -1);
    verify::check_cmvm_equivalence(&p, &m, 4, 1).unwrap();
}

#[test]
fn single_input_fir_like() {
    // d_in = 1: every output is a constant multiple of x0.
    let m = vec![3, 6, 12, 96, -3];
    let p = run_cse(&m, 1, 5, -1);
    verify::check_cmvm_equivalence(&p, &m, 1, 5).unwrap();
    // 3x shared: 3 = x + 2x (1 adder); 6, 12, 96 are free shifts of 3x;
    // -3x is one negation.
    assert!(p.adder_count() <= 2, "got {}", p.adder_count());
}

#[test]
fn weighting_ablation_both_exact() {
    let mut rng = Rng::seed_from(9);
    let (d_in, d_out) = (10, 10);
    let m: Vec<i64> = (0..d_in * d_out).map(|_| rng.range_i64(-255, 255)).collect();
    for weighted in [false, true] {
        let mut b = DaisBuilder::new();
        let q = QInterval::new(-128, 127, 0);
        let inputs: Vec<InputTerm> =
            (0..d_in).map(|j| InputTerm { node: b.input(j, q, 0) }).collect();
        let (outs, _) =
            compile(&mut b, &inputs, &m, d_in, d_out, &CseConfig { dc: -1, weighted }, None);
        for o in &outs {
            match o.node {
                Some(n) => {
                    let n = if o.neg { b.neg(n) } else { n };
                    b.output(n, o.shift);
                }
                None => {
                    let z = b.constant(0);
                    b.output(z, 0);
                }
            }
        }
        let p = b.finish();
        verify::check_cmvm_equivalence(&p, &m, d_in, d_out).unwrap();
    }
}

/// The fundamental invariant: for any matrix and any delay
/// constraint, the optimized program computes x^T M exactly
/// (verified symbolically AND numerically with in-range inputs).
#[test]
fn prop_cse_preserves_cmvm_semantics() {
    property("cse_preserves_cmvm_semantics", 24, |rng| {
        let d_in = rng.below(6) + 1;
        let d_out = rng.below(6) + 1;
        let dc = rng.range_i64(-1, 2) as i32;
        let m: Vec<i64> =
            (0..d_in * d_out).map(|_| rng.range_i64(-255, 255)).collect();
        let p = run_cse(&m, d_in, d_out, dc);
        verify::check_well_formed(&p).unwrap();
        verify::check_cmvm_equivalence(&p, &m, d_in, d_out).unwrap();
        // Numeric check with interval assertion.
        for _ in 0..4 {
            let x: Vec<i64> = (0..d_in).map(|_| rng.range_i64(-128, 127)).collect();
            let got = interp::evaluate_checked(&p, &x);
            for (i, g) in got.iter().enumerate() {
                let want: i128 = (0..d_in)
                    .map(|j| x[j] as i128 * m[j * d_out + i] as i128)
                    .sum();
                assert_eq!(*g as i128, want);
            }
        }
    });
}

/// Bind CSE output terms as program outputs (shared by the differential
/// drivers below; mirrors `cmvm::bind_outputs`).
fn bind_outs(b: &mut DaisBuilder, outs: &[OutTerm]) {
    for o in outs {
        match o.node {
            Some(n) => {
                let n = if o.neg { b.neg(n) } else { n };
                b.output(n, o.shift);
            }
            None => {
                let z = b.constant(0);
                b.output(z, 0);
            }
        }
    }
}

/// The engine-overhaul acceptance sweep: on random matrices × all five
/// strategy variants × the full dc ∈ [-1, 4] ladder, the arena/bitset
/// engine must emit a **bit-identical** `DaisProgram` to the
/// pre-refactor reference (driven through the full `cmvm::compile`
/// flow — decomposition, two-stage folding and output binding included
/// — via the test-only engine switch). The indexed side runs through
/// the default thread-local arena, so warm-arena reuse is covered by
/// the same sweep.
#[test]
fn prop_strategies_bit_identical_to_reference_engine() {
    property("cse_indexed_vs_reference_strategies", 12, |rng| {
        let d_in = rng.below(6) + 1;
        let d_out = rng.below(6) + 1;
        let dc = rng.range_i64(-1, 4) as i32;
        let m: Vec<i64> =
            (0..d_in * d_out).map(|_| rng.range_i64(-255, 255)).collect();
        let p = CmvmProblem::new(d_in, d_out, m, 8).unwrap();
        for s in [
            Strategy::Latency,
            Strategy::NaiveDa,
            Strategy::CseOnly { dc },
            Strategy::Da { dc },
            Strategy::Lookahead { dc },
        ] {
            let indexed = cmvm::compile(&p, &OptimizeOptions::new(s)).unwrap();
            let reference = test_hooks::with_reference_engine(|| {
                cmvm::compile(&p, &OptimizeOptions::new(s)).unwrap()
            });
            assert_eq!(
                indexed.program, reference.program,
                "engines diverged under {s:?} (dc={dc}, {d_in}x{d_out})"
            );
            assert_eq!(indexed.adders, reference.adders);
            assert_eq!(indexed.depth, reference.depth);
        }
    });
}

/// Engine-level differential on larger tensors than the strategy sweep
/// (no decomposition in front, so the engine sees the raw matrix). The
/// indexed side reuses one arena across every property case, so the
/// sweep also proves warm storage carries nothing between problems.
#[test]
fn prop_optimize_into_bit_identical_to_reference() {
    let arena = EngineArena::new();
    property("cse_indexed_vs_reference_direct", 10, |rng| {
        let d_in = rng.below(10) + 1;
        let d_out = rng.below(10) + 1;
        let dc = rng.range_i64(-1, 4) as i32;
        let weighted = rng.chance(0.8);
        let m: Vec<i64> =
            (0..d_in * d_out).map(|_| rng.range_i64(-1023, 1023)).collect();
        let cfg = CseConfig { dc, weighted };
        let q = QInterval::new(-128, 127, 0);

        let mut bi = DaisBuilder::new();
        let inputs: Vec<InputTerm> =
            (0..d_in).map(|j| InputTerm { node: bi.input(j, q, 0) }).collect();
        let (outs, _) = compile(&mut bi, &inputs, &m, d_in, d_out, &cfg, Some(&arena));
        bind_outs(&mut bi, &outs);
        let indexed = bi.finish();

        let mut br = DaisBuilder::new();
        let inputs: Vec<InputTerm> =
            (0..d_in).map(|j| InputTerm { node: br.input(j, q, 0) }).collect();
        let (outs, _) =
            super::reference::optimize_into_stats(&mut br, &inputs, &m, d_in, d_out, &cfg);
        bind_outs(&mut br, &outs);
        let reference = br.finish();

        assert_eq!(
            indexed, reference,
            "engines diverged (dc={dc}, weighted={weighted}, {d_in}x{d_out})"
        );
    });
}

/// The heap tie-break is a documented total order, so pattern selection
/// must be bit-identical across repeated runs — on the same thread and
/// on a fresh one (pins platform/thread determinism, incl. the work
/// counters).
#[test]
fn repeated_runs_are_bit_identical() {
    let p = CmvmProblem::random(77, 12, 12, 8);
    let opts = OptimizeOptions::new(Strategy::Da { dc: 2 });
    let first = cmvm::compile(&p, &opts).unwrap();
    let again = cmvm::compile(&p, &opts).unwrap();
    assert_eq!(first.program, again.program);
    assert_eq!(first.cse, again.cse);
    let p2 = p.clone();
    let other = std::thread::spawn(move || {
        cmvm::compile(&p2, &OptimizeOptions::new(Strategy::Da { dc: 2 })).unwrap()
    })
    .join()
    .unwrap();
    assert_eq!(first.program, other.program);
    assert_eq!(first.cse, other.cse);
}

/// Depth budgets are respected: with dc >= 0 the final depth never
/// exceeds the per-column minimal feasible depth + dc (column minimum
/// floors included; +1 slack for a possible output negation).
#[test]
fn prop_cse_respects_depth_budget() {
    property("cse_respects_depth_budget", 24, |rng| {
        let d_in = rng.below(5) + 2;
        let d_out = rng.below(5) + 2;
        let dc = rng.range_i64(0, 2) as i32;
        let m: Vec<i64> =
            (0..d_in * d_out).map(|_| rng.range_i64(-255, 255)).collect();
        let p = run_cse(&m, d_in, d_out, dc);
        let col_min: Vec<u32> = (0..d_out)
            .map(|i| {
                let kraft: u128 = (0..d_in)
                    .map(|j| crate::csd::nnz(m[j * d_out + i]) as u128)
                    .sum();
                if kraft <= 1 { 0 } else { 128 - (kraft - 1).leading_zeros() }
            })
            .collect();
        let depth_min = col_min.iter().copied().max().unwrap_or(0);
        let bound = depth_min + dc as u32 + 1;
        assert!(
            p.adder_depth() <= bound,
            "depth {} > bound {bound}", p.adder_depth()
        );
    });
}
