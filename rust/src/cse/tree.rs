//! Depth-minimal balanced summation trees.
//!
//! After CSE exhausts shared subexpressions, each output column is a sum
//! of residual terms `± (node << shift)`. They are combined pairwise,
//! always merging the two shallowest terms first (Huffman on the
//! max-plus semiring), which provably achieves the minimal possible tree
//! depth for the given term depths — exactly the depth the Kraft-sum
//! feasibility check in the engine accounts for. Ties are broken towards
//! the narrower operand to keep adder widths (and LUTs) small.
//!
//! The same combiner also implements the "naive DA" reference: the plain
//! per-column CSD expansion summed without any subexpression sharing.

use super::engine::{InputTerm, OutTerm};
use crate::csd::Csd;
use crate::dais::{DaisBuilder, NodeId};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// One summand: `sign * (node << shift)`.
#[derive(Debug, Clone, Copy)]
pub struct Term {
    /// Value-carrying node.
    pub node: NodeId,
    /// Left shift (digit power), `>= 0` for integer matrices.
    pub shift: i32,
    /// Negative sign?
    pub neg: bool,
}

/// Combine terms into a single [`OutTerm`] with minimal adder depth.
pub fn combine(builder: &mut DaisBuilder, terms: Vec<Term>) -> OutTerm {
    // Min-heap keyed on (depth, width, node, shift) — deterministic.
    let mut heap: BinaryHeap<Reverse<(u32, u32, NodeId, i32, bool)>> = terms
        .into_iter()
        .map(|t| {
            let d = builder.depth(t.node);
            let w = builder.qint(t.node).width();
            Reverse((d, w, t.node, t.shift, t.neg))
        })
        .collect();

    while heap.len() >= 2 {
        let Reverse((_, _, n1, s1, g1)) = heap.pop().unwrap();
        let Reverse((_, _, n2, s2, g2)) = heap.pop().unwrap();
        // Orientation: on mixed signs put the *positive* term first so
        // the merged value stays positively signed (outputs then only
        // need a Neg when the whole column is negative); on equal signs
        // order is free. Shifts are factored down by their minimum and
        // realized with the two-sided AddShift (still one adder).
        let ((na, sa, ga), (nb, sb, gb)) = if g1 != g2 {
            if g1 { ((n2, s2, g2), (n1, s1, g1)) } else { ((n1, s1, g1), (n2, s2, g2)) }
        } else if s1 <= s2 {
            ((n1, s1, g1), (n2, s2, g2))
        } else {
            ((n2, s2, g2), (n1, s1, g1))
        };
        let g = sa.min(sb);
        // a<<(sa-g) ± b<<(sb-g); sign of result = sign of a:
        //   +a +b -> add, +   |   +a -b -> sub, +   |   -a -b -> add, -
        let node =
            builder.add_shift2(na, (sa - g) as u32, nb, (sb - g) as u32, ga != gb);
        let d = builder.depth(node);
        let w = builder.qint(node).width();
        heap.push(Reverse((d, w, node, g, ga)));
    }

    match heap.pop() {
        Some(Reverse((_, _, node, shift, neg))) => OutTerm { node: Some(node), shift, neg },
        None => OutTerm { node: None, shift: 0, neg: false },
    }
}

/// The naive distributed-arithmetic reference: expand every matrix entry
/// to CSD digits and sum each column with a balanced tree — no CSE, no
/// decomposition. This is also the *functional* model of the hls4ml
/// latency strategy (bit-exact to the MAC loop).
pub fn naive_da(
    builder: &mut DaisBuilder,
    inputs: &[InputTerm],
    matrix: &[i64],
    d_in: usize,
    d_out: usize,
) -> Vec<OutTerm> {
    assert_eq!(matrix.len(), d_in * d_out);
    assert_eq!(inputs.len(), d_in);
    (0..d_out)
        .map(|i| {
            let mut terms = Vec::new();
            for (j, input) in inputs.iter().enumerate() {
                for digit in Csd::encode(matrix[j * d_out + i]).digits() {
                    terms.push(Term {
                        node: input.node,
                        shift: digit.power,
                        neg: digit.sign < 0,
                    });
                }
            }
            combine(builder, terms)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dais::interp;
    use crate::fixed::QInterval;

    #[test]
    fn combine_is_depth_minimal() {
        let mut b = DaisBuilder::new();
        let q = QInterval::new(-8, 7, 0);
        // Seven equal-depth terms -> depth ceil(log2 7) = 3.
        let terms: Vec<Term> = (0..7)
            .map(|j| Term { node: b.input(j, q, 0), shift: 0, neg: false })
            .collect();
        let out = combine(&mut b, terms);
        let node = out.node.unwrap();
        assert_eq!(b.depth(node), 3);
    }

    #[test]
    fn combine_respects_initial_depths() {
        let mut b = DaisBuilder::new();
        let q = QInterval::new(-8, 7, 0);
        // One deep term (depth 3) and two shallow: shallow pair first,
        // final depth 4 (not 5).
        let x = b.input(0, q, 0);
        let mut deep = x;
        for _ in 0..3 {
            deep = b.add_shift(deep, x, 1, false);
        }
        let t = vec![
            Term { node: deep, shift: 0, neg: false },
            Term { node: b.input(1, q, 0), shift: 0, neg: false },
            Term { node: b.input(2, q, 0), shift: 0, neg: false },
        ];
        let out = combine(&mut b, t);
        assert_eq!(b.depth(out.node.unwrap()), 4);
    }

    #[test]
    fn combine_sign_semantics() {
        // -x0 - x1 should produce sum with neg flag, evaluating exactly.
        let mut b = DaisBuilder::new();
        let q = QInterval::new(-128, 127, 0);
        let x0 = b.input(0, q, 0);
        let x1 = b.input(1, q, 0);
        let t = vec![
            Term { node: x0, shift: 0, neg: true },
            Term { node: x1, shift: 2, neg: true },
        ];
        let out = combine(&mut b, t);
        assert!(out.neg);
        let n = out.node.unwrap();
        let m = b.neg(n);
        b.output(m, out.shift);
        let p = b.finish();
        assert_eq!(interp::evaluate(&p, &[3, 5]), vec![-3 - 20]);
    }

    #[test]
    fn naive_da_adder_count() {
        // Column digits: nnz(3)=2, nnz(5)=2 -> 4 terms -> 3 adders.
        let mut b = DaisBuilder::new();
        let q = QInterval::new(-128, 127, 0);
        let inputs: Vec<InputTerm> =
            (0..2).map(|j| InputTerm { node: b.input(j, q, 0) }).collect();
        let outs = naive_da(&mut b, &inputs, &[3, 5], 2, 1);
        let n = outs[0].node.unwrap();
        b.output(n, outs[0].shift);
        let p = b.finish();
        assert_eq!(p.adder_count(), 3);
        assert_eq!(interp::evaluate(&p, &[10, 100]), vec![30 + 500]);
    }
}
