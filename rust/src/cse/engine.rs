//! The CSE engine proper: digit tensor, pattern frequency table, greedy
//! selection loop, and delay-constraint bookkeeping.
//!
//! Hot-path layout (tracked by the `perf` suite and the
//! `optimizer_micro` bench): occurrence matching is driven by two
//! incremental indices maintained differentially in `add_digit` /
//! `kill` alongside the pattern frequency table —
//!
//! * a per-pattern **column index** (`PatEntry::cols`): the columns that
//!   currently contain at least one digit pair of the pattern, with the
//!   per-column pair count. `match_occurrences` walks exactly these
//!   columns (ascending), instead of rescanning every column of the
//!   tensor on every heap pop;
//! * a per-column **row index** (`Column::row_digits`): the alive digit
//!   indices of each row, so a pattern's a-side digits are read off
//!   directly instead of filtering a full column scan.
//!
//! Scratch buffers (`scratch`, `a_side`, `used`) are engine fields,
//! reserved once and reused across the hot loop.
//!
//! The pre-index engine is retained verbatim in `reference.rs`; the
//! seeded differential sweep in `tests.rs` proves both emit
//! bit-identical programs, and the perf suite times them head-to-head.

use super::tree;
use crate::csd::Csd;
use crate::dais::{DaisBuilder, NodeId};
use crate::fixed::QInterval;
use crate::util::fxhash::FxHashMap;
use std::collections::{BTreeMap, BinaryHeap};

/// An input to the CSE stage: a node already present in the builder.
#[derive(Debug, Clone, Copy)]
pub struct InputTerm {
    /// Node carrying the input value.
    pub node: NodeId,
}

/// An output term produced by the CSE stage: `sign * (node << shift)`,
/// or zero when `node` is `None`.
#[derive(Debug, Clone, Copy)]
pub struct OutTerm {
    /// The node holding the column's sum (None for all-zero columns).
    pub node: Option<NodeId>,
    /// Free wiring left-shift.
    pub shift: i32,
    /// Whether the value must be negated.
    pub neg: bool,
}

/// Configuration of the CSE stage.
#[derive(Debug, Clone, Copy)]
pub struct CseConfig {
    /// Delay constraint: extra adder depth allowed beyond the minimal
    /// feasible depth (`-1` = unconstrained).
    pub dc: i32,
    /// Weight pattern frequency by operand bit-overlap (paper §4.4).
    /// Disabled only by the ablation bench.
    pub weighted: bool,
}

impl Default for CseConfig {
    fn default() -> Self {
        Self { dc: -1, weighted: true }
    }
}

/// Statistics and work counters for reporting / ablations / the perf
/// suite.
///
/// The engine is fully deterministic, so every counter is an exact
/// function of the problem — the perf baseline pins them exactly, and
/// any drift is a behavior change, not noise.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CseStats {
    /// Number of CSE update steps (implemented subexpressions).
    pub steps: usize,
    /// Candidates rejected by the delay constraint.
    pub depth_rejections: usize,
    /// Heap pops in the selection loop (including stale entries).
    pub heap_pops: usize,
    /// Heap pops discarded as stale (count changed since push, below
    /// the pair threshold, or parked).
    pub stale_pops: usize,
    /// Columns visited by occurrence matching.
    pub occ_cols_scanned: usize,
    /// Digits examined by occurrence matching — the work the pattern
    /// column index and per-row digit lists bound. The reference engine
    /// counts every digit slot its full column scans walk; the indexed
    /// engine counts only the a-side digits it materializes.
    pub occ_digits_scanned: usize,
}

impl CseStats {
    /// Accumulate another run's counters (used when a strategy invokes
    /// the engine more than once, e.g. the two-stage flow, or when a
    /// report aggregates per-layer runs).
    pub fn absorb(&mut self, other: &CseStats) {
        self.steps += other.steps;
        self.depth_rejections += other.depth_rejections;
        self.heap_pops += other.heap_pops;
        self.stale_pops += other.stale_pops;
        self.occ_cols_scanned += other.occ_cols_scanned;
        self.occ_digits_scanned += other.occ_digits_scanned;
    }
}

/// One signed digit of the tensor, located in a column.
#[derive(Debug, Clone, Copy)]
struct ColDigit {
    row: u32,
    power: i32,
    sign: i8,
    alive: bool,
}

/// A column of `M_expr` with a (row, power) index for O(1) partner
/// lookup, per-row alive-digit lists for O(row) a-side collection, and
/// the Kraft sum for the depth-feasibility check.
#[derive(Debug, Default)]
struct Column {
    digits: Vec<ColDigit>,
    index: FxHashMap<(u32, i32), u32>,
    /// Σ 2^depth(row) over alive digits (u128; depths are budget-bounded).
    kraft: u128,
    /// Dead entries in `digits` (compaction trigger).
    dead: u32,
    /// Alive digit indices per row, indexed by row id. Occurrence
    /// matching reads a pattern's a-side digits straight off this list
    /// instead of filtering a full column scan.
    row_digits: Vec<Vec<u32>>,
}

impl Column {
    /// Drop dead digits and rebuild the indices. Pattern counts are
    /// index-independent, so this is safe between update steps; it keeps
    /// the alive() scans O(live) instead of O(all-ever-created).
    fn compact(&mut self) {
        if (self.dead as usize) * 2 < self.digits.len() {
            return;
        }
        self.digits.retain(|d| d.alive);
        self.index.clear();
        for list in &mut self.row_digits {
            list.clear();
        }
        for (i, d) in self.digits.iter().enumerate() {
            self.index.insert((d.row, d.power), i as u32);
            self.row_digits[d.row as usize].push(i as u32);
        }
        self.dead = 0;
    }

    fn row_add(&mut self, row: u32, idx: u32) {
        let r = row as usize;
        if r >= self.row_digits.len() {
            self.row_digits.resize_with(r + 1, Vec::new);
        }
        self.row_digits[r].push(idx);
    }

    fn row_remove(&mut self, row: u32, idx: u32) {
        let list = &mut self.row_digits[row as usize];
        let pos = list
            .iter()
            .position(|&i| i == idx)
            .expect("killed digit present in its row list");
        list.swap_remove(pos);
    }

    fn alive(&self) -> impl Iterator<Item = (u32, &ColDigit)> {
        self.digits.iter().enumerate().filter(|(_, d)| d.alive).map(|(i, d)| (i as u32, d))
    }
}

/// Canonical two-term pattern: value `L[ra] ± (L[rb] << shift)`.
/// Orientation: the `ra` digit sits at the lower power; ties broken by
/// row order. Sign-normalized so the `ra` digit is positive.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
struct Pattern {
    ra: u32,
    rb: u32,
    shift: u32,
    sub: bool,
}

/// Canonicalize a digit pair into (pattern, a-index, b-index) — `None`
/// when the two digits are the same digit.
#[inline]
fn canon(d1: (u32, &ColDigit), d2: (u32, &ColDigit)) -> Option<(Pattern, u32, u32)> {
    let (i1, a) = d1;
    let (i2, b) = d2;
    if i1 == i2 {
        return None;
    }
    // Orient: lower power first; tie -> lower row first (same row + same
    // power cannot happen: (row, power) is unique within a column).
    let ((ia, da), (ib, db)) = if (a.power, a.row, i1) <= (b.power, b.row, i2) {
        ((i1, a), (i2, b))
    } else {
        ((i2, b), (i1, a))
    };
    Some((
        Pattern {
            ra: da.row,
            rb: db.row,
            shift: (db.power - da.power) as u32,
            sub: da.sign != db.sign,
        },
        ia,
        ib,
    ))
}

/// Heap entry for the greedy selection loop.
///
/// The ordering is a **total, documented order**, so pattern selection
/// is deterministic on every platform and across repeated runs (pinned
/// by `cse::tests::repeated_runs_are_bit_identical`):
///
/// 1. higher weighted score pops first;
/// 2. then higher occurrence count (prefers the more frequent pattern
///    among equal scores);
/// 3. then the lexicographically **smallest** `(ra, rb, shift, sub)`
///    pattern — note the reversed operand order in `cmp`:
///    `BinaryHeap` is a max-heap, so inverting the pattern comparison
///    makes the smallest pattern the maximum.
///
/// Entries that compare equal are bit-identical (the pattern is part of
/// the key), so heap-internal tie handling can never influence which
/// pattern is selected.
#[derive(PartialEq, Eq)]
struct HeapEntry {
    score: i64,
    count: u32,
    pattern: Pattern,
}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.score
            .cmp(&other.score)
            .then(self.count.cmp(&other.count))
            .then_with(|| other.pattern.cmp(&self.pattern))
    }
}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Differential frequency-table entry for one pattern.
#[derive(Debug, Default)]
struct PatEntry {
    /// Total pair count across all columns — exactly the counter the
    /// pre-index reference engine maintains; it drives scoring and
    /// parking, so heap behavior is unchanged by the index.
    total: u32,
    /// Pair count per column. A `BTreeMap` so occurrence matching
    /// visits columns in ascending order — the order the reference
    /// engine's full scan visits them, which the bit-identical
    /// differential sweep relies on.
    cols: BTreeMap<u32, u32>,
}

struct Engine<'a> {
    builder: &'a mut DaisBuilder,
    d_out: usize,
    cfg: CseConfig,
    /// Implemented values; index == row id of the digit tensor.
    rows: Vec<RowInfo>,
    cols: Vec<Column>,
    counts: FxHashMap<Pattern, PatEntry>,
    heap: BinaryHeap<HeapEntry>,
    /// Patterns parked at a given count (depth-infeasible or
    /// insufficient disjoint occurrences); re-eligible when count moves.
    parked: FxHashMap<Pattern, u32>,
    /// Per-column depth budget (None = unconstrained).
    budget: Option<Vec<u32>>,
    /// Reusable pattern scratch buffer (hot path: kill/add).
    scratch: Vec<Pattern>,
    /// Reusable a-side digit buffer (hot path: match_occurrences).
    a_side: Vec<(u32, ColDigit)>,
    /// Reusable matched-digit buffer (hot path: match_occurrences).
    used: Vec<u32>,
    stats: CseStats,
}

#[derive(Debug, Clone, Copy)]
struct RowInfo {
    node: NodeId,
    qint: QInterval,
    depth: u32,
}

impl<'a> Engine<'a> {
    fn weight(&self, p: &Pattern) -> i64 {
        if !self.cfg.weighted {
            return 1;
        }
        let qa = self.rows[p.ra as usize].qint;
        let qb = self.rows[p.rb as usize].qint;
        let s = p.shift as i32;
        let ov = (qa.msb().min(qb.msb() + s)) - (qa.lsb().max(qb.lsb() + s));
        ov.max(1) as i64
    }

    fn score(&self, p: &Pattern, count: u32) -> i64 {
        count as i64 * self.weight(p)
    }

    fn push_heap(&mut self, p: Pattern) {
        let count = self.counts.get(&p).map(|e| e.total).unwrap_or(0);
        if count >= 2 {
            self.heap.push(HeapEntry { score: self.score(&p, count), count, pattern: p });
        }
    }

    /// Adjust the pair count of `p` in column `c` by ±1 and refresh
    /// heap/parking state. The heap interaction depends only on the
    /// cross-column total, matching the reference engine exactly.
    fn bump(&mut self, p: Pattern, c: usize, delta: i32) {
        let total = {
            let e = self.counts.entry(p).or_default();
            e.total = (e.total as i32 + delta) as u32;
            match e.cols.entry(c as u32) {
                std::collections::btree_map::Entry::Occupied(mut o) => {
                    let v = (*o.get() as i32 + delta) as u32;
                    if v == 0 {
                        o.remove();
                    } else {
                        *o.get_mut() = v;
                    }
                }
                std::collections::btree_map::Entry::Vacant(v) => {
                    debug_assert!(delta > 0, "negative bump on column without pairs");
                    v.insert(delta as u32);
                }
            }
            e.total
        };
        if total == 0 {
            self.counts.remove(&p);
        }
        if let Some(&parked_at) = self.parked.get(&p) {
            if parked_at != total {
                self.parked.remove(&p);
            }
        }
        if total >= 2 && !self.parked.contains_key(&p) {
            self.heap.push(HeapEntry {
                score: self.score(&p, total),
                count: total,
                pattern: p,
            });
        }
    }

    /// Kill digit `idx` in column `c`, updating counts, indices and the
    /// Kraft sum.
    fn kill(&mut self, c: usize, idx: u32) {
        let d = self.cols[c].digits[idx as usize];
        debug_assert!(d.alive);
        self.cols[c].digits[idx as usize].alive = false;
        self.cols[c].dead += 1;
        self.cols[c].row_remove(d.row, idx);
        self.cols[c].index.remove(&(d.row, d.power));
        self.cols[c].kraft -= 1u128 << self.rows[d.row as usize].depth;
        let mut pairs = std::mem::take(&mut self.scratch);
        pairs.clear();
        pairs.extend(
            self.cols[c]
                .alive()
                .filter_map(|e| canon((idx, &d), e).map(|(p, _, _)| p)),
        );
        for p in &pairs {
            self.bump(*p, c, -1);
        }
        self.scratch = pairs;
    }

    /// Add a digit to column `c`, updating counts, indices and the
    /// Kraft sum.
    fn add_digit(&mut self, c: usize, row: u32, power: i32, sign: i8) {
        let digit = ColDigit { row, power, sign, alive: true };
        let mut pairs = std::mem::take(&mut self.scratch);
        pairs.clear();
        pairs.extend(
            self.cols[c]
                .alive()
                .filter_map(|e| canon((u32::MAX, &digit), e).map(|(p, _, _)| p)),
        );
        let idx = self.cols[c].digits.len() as u32;
        debug_assert!(
            !self.cols[c].index.contains_key(&(row, power)),
            "duplicate (row, power) digit in column {c}"
        );
        self.cols[c].digits.push(digit);
        self.cols[c].index.insert((row, power), idx);
        self.cols[c].row_add(row, idx);
        self.cols[c].kraft += 1u128 << self.rows[row as usize].depth;
        for p in &pairs {
            self.bump(*p, c, 1);
        }
        self.scratch = pairs;
    }

    /// Greedily match disjoint occurrences of `p`, visiting only the
    /// columns the pattern index lists (ascending — the same order the
    /// reference engine's full scan yields them in). Returns
    /// (column, a-digit-idx, b-digit-idx) triples.
    ///
    /// A column appears in the index iff it holds at least one digit
    /// pair canonicalizing to `p`, so no occurrence can hide in a
    /// skipped column; a listed column's greedy matching depends only
    /// on the column contents, which evolve identically in both
    /// engines — hence bit-identical output.
    fn match_occurrences(&mut self, p: &Pattern) -> Vec<(usize, u32, u32)> {
        let mut occ = Vec::new();
        let Some(entry) = self.counts.get(p) else { return occ };
        let mut a_side = std::mem::take(&mut self.a_side);
        let mut used = std::mem::take(&mut self.used);
        let mut cols_scanned = 0usize;
        let mut digits_scanned = 0usize;
        for &c_id in entry.cols.keys() {
            let c = c_id as usize;
            let col = &self.cols[c];
            cols_scanned += 1;
            used.clear();
            a_side.clear();
            // Read the a-side digits straight off the per-row index, in
            // power order for maximal greedy matching of chain patterns
            // (same-row, shifted).
            if let Some(list) = col.row_digits.get(p.ra as usize) {
                a_side.extend(list.iter().map(|&i| (i, col.digits[i as usize])));
            }
            a_side.sort_by_key(|(_, d)| d.power);
            digits_scanned += a_side.len();
            for &(ia, da) in a_side.iter() {
                debug_assert!(da.alive);
                if used.contains(&ia) {
                    continue;
                }
                let pb = da.power + p.shift as i32;
                if let Some(&ib) = col.index.get(&(p.rb, pb)) {
                    if ib == ia || used.contains(&ib) {
                        continue;
                    }
                    let db = &col.digits[ib as usize];
                    debug_assert!(db.alive);
                    // Sign relation must match the canonical pattern…
                    let sub = da.sign != db.sign;
                    if sub != p.sub {
                        continue;
                    }
                    // …and the orientation must canonicalize to `p`
                    // (guards the shift==0 row-order tie and ra==rb).
                    if let Some((cp, ca, cb)) = canon((ia, &da), (ib, db)) {
                        if cp == *p {
                            used.push(ca);
                            used.push(cb);
                            occ.push((c, ca, cb));
                        }
                    }
                }
            }
        }
        self.a_side = a_side;
        self.used = used;
        self.stats.occ_cols_scanned += cols_scanned;
        self.stats.occ_digits_scanned += digits_scanned;
        occ
    }

    /// Depth-feasibility filter: keep as many occurrences per column as
    /// the Kraft budget allows. Returns the admitted occurrences.
    fn filter_depth(&mut self, p: &Pattern, occ: Vec<(usize, u32, u32)>) -> Vec<(usize, u32, u32)> {
        let Some(budget) = &self.budget else { return occ };
        let da = self.rows[p.ra as usize].depth;
        let db = self.rows[p.rb as usize].depth;
        let delta: i128 =
            (1i128 << (da.max(db) + 1)) - (1i128 << da) - (1i128 << db);
        if delta == 0 {
            return occ; // equal-depth merge never hurts feasibility
        }
        let mut kept = Vec::with_capacity(occ.len());
        let mut extra: FxHashMap<usize, i128> = FxHashMap::default();
        for (c, ia, ib) in occ {
            let used = extra.entry(c).or_insert(0);
            let cap = 1i128 << budget[c];
            if self.cols[c].kraft as i128 + *used + delta <= cap {
                *used += delta;
                kept.push((c, ia, ib));
            } else {
                self.stats.depth_rejections += 1;
            }
        }
        kept
    }

    /// One update step: pick the best implementable pattern and rewrite
    /// the tensor. Returns false when exhausted.
    fn step(&mut self) -> bool {
        loop {
            let Some(top) = self.heap.pop() else { return false };
            self.stats.heap_pops += 1;
            let p = top.pattern;
            let cur = self.counts.get(&p).map(|e| e.total).unwrap_or(0);
            if cur != top.count || cur < 2 || self.parked.contains_key(&p) {
                self.stats.stale_pops += 1;
                continue; // stale entry
            }
            let occ = self.match_occurrences(&p);
            let occ = self.filter_depth(&p, occ);
            if occ.len() < 2 {
                // Not worth an adder (or depth-blocked): park at this
                // count; any count change un-parks it.
                self.parked.insert(p, cur);
                continue;
            }
            // Implement: one new adder node, one new tensor row.
            let a = self.rows[p.ra as usize];
            let b = self.rows[p.rb as usize];
            let node = self.builder.add_shift(a.node, b.node, p.shift, p.sub);
            let row = self.rows.len() as u32;
            self.rows.push(RowInfo {
                node,
                qint: self.builder.qint(node),
                depth: self.builder.depth(node),
            });
            let mut touched: Vec<usize> = Vec::with_capacity(occ.len());
            for (c, ia, ib) in occ {
                // The occurrence's contribution is sign(a-digit) · w << p_a.
                let (pa, sa) = {
                    let d = &self.cols[c].digits[ia as usize];
                    (d.power, d.sign)
                };
                self.kill(c, ia);
                self.kill(c, ib);
                self.add_digit(c, row, pa, sa);
                touched.push(c);
            }
            for c in touched {
                self.cols[c].compact();
            }
            self.stats.steps += 1;
            return true;
        }
    }
}

/// Expand the matrix into the digit tensor, run the CSE loop, and sum the
/// residual digits of each column with depth-minimal trees. The adder
/// nodes are appended to `builder`; the returned terms describe each
/// output column.
pub fn optimize_into(
    builder: &mut DaisBuilder,
    inputs: &[InputTerm],
    matrix: &[i64],
    d_in: usize,
    d_out: usize,
    cfg: &CseConfig,
) -> Vec<OutTerm> {
    optimize_into_stats(builder, inputs, matrix, d_in, d_out, cfg).0
}

/// Like [`optimize_into`] but also returns engine statistics.
pub fn optimize_into_stats(
    builder: &mut DaisBuilder,
    inputs: &[InputTerm],
    matrix: &[i64],
    d_in: usize,
    d_out: usize,
    cfg: &CseConfig,
) -> (Vec<OutTerm>, CseStats) {
    #[cfg(test)]
    {
        if test_hooks::USE_REFERENCE.with(|c| c.get()) {
            return super::reference::optimize_into_stats(
                builder, inputs, matrix, d_in, d_out, cfg,
            );
        }
    }

    assert_eq!(matrix.len(), d_in * d_out, "matrix shape mismatch");
    assert_eq!(inputs.len(), d_in, "input arity mismatch");

    let mut span = crate::obs::span("cse", "cse.optimize");
    span.arg("d_in", d_in as i64);
    span.arg("d_out", d_out as i64);
    span.arg("dc", cfg.dc as i64);

    let rows: Vec<RowInfo> = inputs
        .iter()
        .map(|t| RowInfo {
            node: t.node,
            qint: builder.qint(t.node),
            depth: builder.depth(t.node),
        })
        .collect();

    // Build the digit tensor column by column.
    let mut cols: Vec<Column> = (0..d_out).map(|_| Column::default()).collect();
    for (c, col) in cols.iter_mut().enumerate() {
        for j in 0..d_in {
            let w = matrix[j * d_out + c];
            for digit in Csd::encode(w).digits() {
                let idx = col.digits.len() as u32;
                col.digits.push(ColDigit {
                    row: j as u32,
                    power: digit.power,
                    sign: digit.sign,
                    alive: true,
                });
                col.index.insert((j as u32, digit.power), idx);
                col.row_add(j as u32, idx);
                col.kraft += 1u128 << rows[j].depth;
            }
        }
    }

    // Depth budgets: per-column minimal feasible depth via the Kraft sum
    // (smallest D with Σ 2^{d_k} ≤ 2^D); global depth_min is the max over
    // columns (the paper's ceil(log2 d_in) generalized to digit counts
    // and non-zero input depths). Budget = depth_min + dc, floored at
    // each column's own minimum so the constraint is always satisfiable.
    let budget = if cfg.dc >= 0 {
        let col_min: Vec<u32> = cols
            .iter()
            .map(|c| min_feasible_depth(c.kraft))
            .collect();
        let depth_min = col_min.iter().copied().max().unwrap_or(0);
        Some(
            col_min
                .iter()
                .map(|&m| m.max(depth_min + cfg.dc as u32))
                .collect::<Vec<u32>>(),
        )
    } else {
        None
    };

    // Initial pattern counts: all digit pairs within each column, into
    // both the cross-column total and the per-column index.
    let mut counts: FxHashMap<Pattern, PatEntry> = FxHashMap::default();
    for (c, col) in cols.iter().enumerate() {
        let alive: Vec<(u32, &ColDigit)> = col.alive().collect();
        for i in 0..alive.len() {
            for j in (i + 1)..alive.len() {
                if let Some((p, _, _)) = canon(alive[i], alive[j]) {
                    let e = counts.entry(p).or_default();
                    e.total += 1;
                    *e.cols.entry(c as u32).or_insert(0) += 1;
                }
            }
        }
    }

    let mut engine = Engine {
        builder,
        d_out,
        cfg: *cfg,
        rows,
        cols,
        counts,
        heap: BinaryHeap::new(),
        parked: FxHashMap::default(),
        budget,
        scratch: Vec::new(),
        a_side: Vec::new(),
        used: Vec::new(),
        stats: CseStats::default(),
    };
    // Seed the heap in sorted pattern order. Pop order is a multiset
    // property of the heap's total order, so hash-map iteration order
    // can never matter — but an explicitly sorted seed keeps that
    // platform-determinism argument local and obvious.
    let mut patterns: Vec<Pattern> = engine.counts.keys().copied().collect();
    patterns.sort_unstable();
    for p in patterns {
        engine.push_heap(p);
    }

    while engine.step() {}

    // Final summation of residual digits, column by column.
    let term_lists: Vec<Vec<tree::Term>> = (0..engine.d_out)
        .map(|c| {
            engine.cols[c]
                .alive()
                .map(|(_, d)| tree::Term {
                    node: engine.rows[d.row as usize].node,
                    shift: d.power,
                    neg: d.sign < 0,
                })
                .collect()
        })
        .collect();
    let stats = engine.stats;
    let builder = engine.builder;
    let out = term_lists.into_iter().map(|terms| tree::combine(builder, terms)).collect();
    // Attach the deterministic work counters to the span (they are the
    // same counters the perf baseline pins).
    span.arg("steps", stats.steps as i64);
    span.arg("heap_pops", stats.heap_pops as i64);
    span.arg("stale_pops", stats.stale_pops as i64);
    span.arg("depth_rejections", stats.depth_rejections as i64);
    span.arg("occ_cols_scanned", stats.occ_cols_scanned as i64);
    span.arg("occ_digits_scanned", stats.occ_digits_scanned as i64);
    (out, stats)
}

/// Smallest tree depth `D` such that terms with the given Kraft sum
/// (Σ 2^{d_k}) fit: `Σ 2^{d_k} ≤ 2^D`. Shared with the reference
/// engine so both compute identical depth budgets.
pub(super) fn min_feasible_depth(kraft: u128) -> u32 {
    if kraft <= 1 {
        return 0;
    }
    128 - (kraft - 1).leading_zeros()
}

/// Test-only switch routing [`optimize_into_stats`] through the
/// pre-index reference engine on the current thread, so the
/// differential sweep can drive identical full strategy flows
/// (`crate::cmvm::optimize`) through both engines without duplicating
/// the two-stage plumbing.
#[cfg(test)]
pub(crate) mod test_hooks {
    use std::cell::Cell;

    thread_local! {
        pub static USE_REFERENCE: Cell<bool> = const { Cell::new(false) };
    }

    /// Run `f` with the reference engine substituted for the indexed
    /// one on this thread (reset on unwind).
    pub fn with_reference_engine<T>(f: impl FnOnce() -> T) -> T {
        struct Reset;
        impl Drop for Reset {
            fn drop(&mut self) {
                USE_REFERENCE.with(|c| c.set(false));
            }
        }
        USE_REFERENCE.with(|c| c.set(true));
        let _reset = Reset;
        f()
    }
}

#[cfg(test)]
mod unit {
    use super::*;

    #[test]
    fn min_feasible_depth_examples() {
        assert_eq!(min_feasible_depth(0), 0);
        assert_eq!(min_feasible_depth(1), 0);
        assert_eq!(min_feasible_depth(2), 1);
        assert_eq!(min_feasible_depth(3), 2);
        assert_eq!(min_feasible_depth(4), 2);
        assert_eq!(min_feasible_depth(5), 3);
        assert_eq!(min_feasible_depth(8), 3);
        assert_eq!(min_feasible_depth(9), 4);
        // 22 digits (8x8 8-bit column): depth 5, matching Table 2 dc=0.
        assert_eq!(min_feasible_depth(22), 5);
    }

    /// Pins the documented total heap order: score desc, then count
    /// desc, then lexicographically smallest pattern first.
    #[test]
    fn heap_order_is_total_and_documented() {
        let p_small = Pattern { ra: 0, rb: 1, shift: 0, sub: false };
        let p_big = Pattern { ra: 0, rb: 1, shift: 1, sub: false };
        assert!(p_small < p_big);
        let mut heap = BinaryHeap::new();
        heap.push(HeapEntry { score: 5, count: 2, pattern: p_big });
        heap.push(HeapEntry { score: 5, count: 2, pattern: p_small });
        heap.push(HeapEntry { score: 5, count: 3, pattern: p_big });
        heap.push(HeapEntry { score: 7, count: 2, pattern: p_big });
        let order: Vec<(i64, u32, Pattern)> =
            std::iter::from_fn(|| heap.pop().map(|e| (e.score, e.count, e.pattern))).collect();
        assert_eq!(
            order,
            vec![(7, 2, p_big), (5, 3, p_big), (5, 2, p_small), (5, 2, p_big)]
        );
    }
}
