//! The CSE engine proper: digit tensor, pattern frequency table, greedy
//! selection loop, and delay-constraint bookkeeping.
//!
//! Hot-path layout (tracked by the `perf` suite and the
//! `optimizer_micro` bench): occurrence matching is driven by two
//! word-parallel bitset indices maintained differentially in
//! `add_digit` / `kill` alongside the pattern frequency table —
//!
//! * a per-pattern **column bitset** (`PatEntry::cols`): the columns
//!   that may contain digit pairs of the pattern. Set on every `+1`
//!   bump; *lazily* cleared — a `-1` bump only decrements the totals,
//!   and `match_occurrences` clears the bit of any visited column that
//!   yields no occurrence (a column holds ≥ 1 alive pair of a pattern
//!   iff greedy matching finds ≥ 1 occurrence in it, so a cleared bit
//!   never hides work and a stale bit only costs a cheap revisit);
//! * a per-column **alive bitset** (`Column::alive`): digit slots are
//!   append-only and never compacted; liveness is one bit, so a-side
//!   collection and pair enumeration are word-parallel ascending scans
//!   instead of flag-filtered vector walks.
//!
//! All engine containers live in a recyclable [`EngineStorage`] slab:
//! hand [`compile`] an [`EngineArena`] and the digit vectors, hash-map
//! buckets, heap storage, and pattern bitset words are reset and reused
//! across compiles (the coordinator holds one per worker thread), so a
//! warm compile allocates almost nothing beyond the program it emits.
//!
//! The pre-index engine is retained verbatim in `reference.rs`; the
//! seeded differential sweep in `tests.rs` proves both emit
//! bit-identical programs, and the perf suite times them head-to-head.

use super::tree;
use crate::csd::Csd;
use crate::dais::{DaisBuilder, NodeId};
use crate::fixed::QInterval;
use crate::util::bits::BitSet;
use crate::util::fxhash::FxHashMap;
use std::collections::BinaryHeap;

/// An input to the CSE stage: a node already present in the builder.
#[derive(Debug, Clone, Copy)]
pub struct InputTerm {
    /// Node carrying the input value.
    pub node: NodeId,
}

/// An output term produced by the CSE stage: `sign * (node << shift)`,
/// or zero when `node` is `None`.
#[derive(Debug, Clone, Copy)]
pub struct OutTerm {
    /// The node holding the column's sum (None for all-zero columns).
    pub node: Option<NodeId>,
    /// Free wiring left-shift.
    pub shift: i32,
    /// Whether the value must be negated.
    pub neg: bool,
}

/// Configuration of the CSE stage.
#[derive(Debug, Clone, Copy)]
pub struct CseConfig {
    /// Delay constraint: extra adder depth allowed beyond the minimal
    /// feasible depth (`-1` = unconstrained).
    pub dc: i32,
    /// Weight pattern frequency by operand bit-overlap (paper §4.4).
    /// Disabled only by the ablation bench.
    pub weighted: bool,
}

impl Default for CseConfig {
    fn default() -> Self {
        Self { dc: -1, weighted: true }
    }
}

/// Statistics and work counters for reporting / ablations / the perf
/// suite.
///
/// The engine is fully deterministic, so every counter is an exact
/// function of the problem — the perf baseline pins them exactly, and
/// any drift is a behavior change, not noise. (One documented
/// exception: `occ_cols_scanned` includes lazily-cleared stale column
/// visits, so it is an exact function of the problem *per engine
/// layout* and is compared engine-vs-reference only as a bound.)
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CseStats {
    /// Number of CSE update steps (implemented subexpressions).
    pub steps: usize,
    /// Candidates rejected by the delay constraint.
    pub depth_rejections: usize,
    /// Heap pops in the selection loop (including stale entries).
    pub heap_pops: usize,
    /// Heap pops discarded as stale (count changed since push, below
    /// the pair threshold, or parked).
    pub stale_pops: usize,
    /// Columns visited by occurrence matching (including stale pattern
    /// bitset columns that turn out to hold no occurrence).
    pub occ_cols_scanned: usize,
    /// Digits examined by occurrence matching — the work the pattern
    /// column bitset and per-column alive bitset bound. The reference
    /// engine counts every digit slot its full column scans walk; the
    /// indexed engine counts only the a-side digits it materializes.
    pub occ_digits_scanned: usize,
}

impl CseStats {
    /// Accumulate another run's counters (used when a strategy invokes
    /// the engine more than once, e.g. the two-stage flow, or when a
    /// report aggregates per-layer runs).
    pub fn absorb(&mut self, other: &CseStats) {
        self.steps += other.steps;
        self.depth_rejections += other.depth_rejections;
        self.heap_pops += other.heap_pops;
        self.stale_pops += other.stale_pops;
        self.occ_cols_scanned += other.occ_cols_scanned;
        self.occ_digits_scanned += other.occ_digits_scanned;
    }
}

/// One signed digit of the tensor, located in a column. Liveness lives
/// in the column's `alive` bitset, not here.
#[derive(Debug, Clone, Copy)]
struct ColDigit {
    row: u32,
    power: i32,
    sign: i8,
}

/// A column of `M_expr`: an append-only digit slab with an alive
/// bitset, a (row, power) index for O(1) partner lookup, and the Kraft
/// sum for the depth-feasibility check.
///
/// Digit slots are never compacted — indices are stable for the whole
/// compile, and no engine decision reads an index *value* (the
/// `(row, power)` key is unique per column, so every canonical
/// tie-break resolves before the index component). The slab and bitset
/// are recycled across compiles via [`EngineStorage`].
#[derive(Debug, Default)]
struct Column {
    digits: Vec<ColDigit>,
    index: FxHashMap<(u32, i32), u32>,
    /// Alive digit slots, word-parallel. Ascending bit order equals
    /// ascending creation order — the same relative order the
    /// compacting reference layout preserves.
    alive: BitSet,
    /// Σ 2^depth(row) over alive digits (u128; depths are budget-bounded).
    kraft: u128,
}

impl Column {
    fn alive_digits(&self) -> impl Iterator<Item = (u32, &ColDigit)> + '_ {
        self.alive.iter().map(move |i| (i, &self.digits[i as usize]))
    }

    /// Reset for reuse, keeping every allocation.
    fn reset(&mut self) {
        self.digits.clear();
        self.index.clear();
        self.alive.clear();
        self.kraft = 0;
    }
}

/// Canonical two-term pattern: value `L[ra] ± (L[rb] << shift)`.
/// Orientation: the `ra` digit sits at the lower power; ties broken by
/// row order. Sign-normalized so the `ra` digit is positive.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
struct Pattern {
    ra: u32,
    rb: u32,
    shift: u32,
    sub: bool,
}

/// Canonicalize a digit pair into (pattern, a-index, b-index) — `None`
/// when the two digits are the same digit.
#[inline]
fn canon(d1: (u32, &ColDigit), d2: (u32, &ColDigit)) -> Option<(Pattern, u32, u32)> {
    let (i1, a) = d1;
    let (i2, b) = d2;
    if i1 == i2 {
        return None;
    }
    // Orient: lower power first; tie -> lower row first (same row + same
    // power cannot happen: (row, power) is unique within a column).
    let ((ia, da), (ib, db)) = if (a.power, a.row, i1) <= (b.power, b.row, i2) {
        ((i1, a), (i2, b))
    } else {
        ((i2, b), (i1, a))
    };
    Some((
        Pattern {
            ra: da.row,
            rb: db.row,
            shift: (db.power - da.power) as u32,
            sub: da.sign != db.sign,
        },
        ia,
        ib,
    ))
}

/// Heap entry for the greedy selection loop.
///
/// The ordering is a **total, documented order**, so pattern selection
/// is deterministic on every platform and across repeated runs (pinned
/// by `cse::tests::repeated_runs_are_bit_identical`):
///
/// 1. higher weighted score pops first;
/// 2. then higher occurrence count (prefers the more frequent pattern
///    among equal scores);
/// 3. then the lexicographically **smallest** `(ra, rb, shift, sub)`
///    pattern — note the reversed operand order in `cmp`:
///    `BinaryHeap` is a max-heap, so inverting the pattern comparison
///    makes the smallest pattern the maximum.
///
/// Entries that compare equal are bit-identical (the pattern is part of
/// the key), so heap-internal tie handling can never influence which
/// pattern is selected.
#[derive(Debug, PartialEq, Eq)]
struct HeapEntry {
    score: i64,
    count: u32,
    pattern: Pattern,
}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.score
            .cmp(&other.score)
            .then(self.count.cmp(&other.count))
            .then_with(|| other.pattern.cmp(&self.pattern))
    }
}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Differential frequency-table entry for one pattern.
#[derive(Debug, Default)]
struct PatEntry {
    /// Total pair count across all columns — exactly the counter the
    /// pre-index reference engine maintains; it drives scoring and
    /// parking, so heap behavior is unchanged by the index. Entries
    /// are kept at `total == 0` (every read site treats 0 as absent);
    /// their bitset words are recycled at end of compile.
    total: u32,
    /// Columns that may hold pairs: set on `+1` bumps, lazily cleared
    /// by `match_occurrences`. Ascending bit iteration visits columns
    /// in the order the reference engine's full scan does; stale bits
    /// are a superset that contributes zero occurrences, so matching
    /// output is unchanged.
    cols: BitSet,
}

/// Recyclable slab backing one engine run: every container the hot
/// loop touches, reset (not freed) between compiles.
#[derive(Debug, Default)]
struct EngineStorage {
    cols: Vec<Column>,
    rows: Vec<RowInfo>,
    counts: FxHashMap<Pattern, PatEntry>,
    /// Zeroed word vectors recycled from drained `PatEntry` bitsets.
    bits_pool: Vec<Vec<u64>>,
    parked: FxHashMap<Pattern, u32>,
    heap: Vec<HeapEntry>,
    budget: Vec<u32>,
    scratch: Vec<Pattern>,
    a_side: Vec<(u32, ColDigit)>,
    used: Vec<u32>,
    col_scratch: Vec<u32>,
    patterns: Vec<Pattern>,
}

/// Reusable engine storage for [`compile`]: hold one per worker thread
/// (or per compile loop) and warm compiles reuse the previous run's
/// digit slabs, hash buckets, heap and bitset words instead of
/// reallocating them.
///
/// Interior mutability keeps the handle shareable by `&`; the storage
/// is taken out for the duration of a compile, so nested/reentrant use
/// (an outer compile triggering an inner one on the same arena) safely
/// degrades to a fresh allocation for the inner run.
#[derive(Debug, Default)]
pub struct EngineArena {
    storage: std::cell::RefCell<EngineStorage>,
}

impl EngineArena {
    /// New empty arena (first compile through it allocates, later ones
    /// reuse).
    pub fn new() -> Self {
        Self::default()
    }

    fn take(&self) -> EngineStorage {
        std::mem::take(&mut *self.storage.borrow_mut())
    }

    fn put(&self, st: EngineStorage) {
        *self.storage.borrow_mut() = st;
    }
}

struct Engine<'a> {
    builder: &'a mut DaisBuilder,
    d_out: usize,
    cfg: CseConfig,
    /// Implemented values; index == row id of the digit tensor.
    rows: Vec<RowInfo>,
    cols: Vec<Column>,
    counts: FxHashMap<Pattern, PatEntry>,
    /// Zeroed word vectors for new `PatEntry` bitsets.
    bits_pool: Vec<Vec<u64>>,
    heap: BinaryHeap<HeapEntry>,
    /// Patterns parked at a given count (depth-infeasible or
    /// insufficient disjoint occurrences); re-eligible when count moves.
    parked: FxHashMap<Pattern, u32>,
    /// Per-column depth budget (None = unconstrained).
    budget: Option<Vec<u32>>,
    /// Reusable pattern scratch buffer (hot path: kill/add).
    scratch: Vec<Pattern>,
    /// Reusable a-side digit buffer (hot path: match_occurrences).
    a_side: Vec<(u32, ColDigit)>,
    /// Reusable matched-digit buffer (hot path: match_occurrences).
    used: Vec<u32>,
    /// Reusable column-id buffer (hot path: match_occurrences).
    col_scratch: Vec<u32>,
    stats: CseStats,
}

#[derive(Debug, Clone, Copy)]
struct RowInfo {
    node: NodeId,
    qint: QInterval,
    depth: u32,
}

impl<'a> Engine<'a> {
    fn weight(&self, p: &Pattern) -> i64 {
        if !self.cfg.weighted {
            return 1;
        }
        let qa = self.rows[p.ra as usize].qint;
        let qb = self.rows[p.rb as usize].qint;
        let s = p.shift as i32;
        let ov = (qa.msb().min(qb.msb() + s)) - (qa.lsb().max(qb.lsb() + s));
        ov.max(1) as i64
    }

    fn score(&self, p: &Pattern, count: u32) -> i64 {
        count as i64 * self.weight(p)
    }

    fn push_heap(&mut self, p: Pattern) {
        let count = self.counts.get(&p).map(|e| e.total).unwrap_or(0);
        if count >= 2 {
            self.heap.push(HeapEntry { score: self.score(&p, count), count, pattern: p });
        }
    }

    /// Adjust the pair count of `p` in column `c` by ±1 and refresh
    /// heap/parking state. The heap interaction depends only on the
    /// cross-column total, matching the reference engine exactly; the
    /// column bitset is only ever *set* here (lazy clearing happens in
    /// `match_occurrences`).
    fn bump(&mut self, p: Pattern, c: usize, delta: i32) {
        if !self.counts.contains_key(&p) {
            debug_assert!(delta > 0, "negative bump on untracked pattern");
            let words = self.bits_pool.pop().unwrap_or_default();
            self.counts.insert(p, PatEntry { total: 0, cols: BitSet::from_words(words) });
        }
        let e = self.counts.get_mut(&p).expect("entry ensured above");
        e.total = (e.total as i32 + delta) as u32;
        if delta > 0 {
            e.cols.set(c as u32);
        }
        let total = e.total;
        if let Some(&parked_at) = self.parked.get(&p) {
            if parked_at != total {
                self.parked.remove(&p);
            }
        }
        if total >= 2 && !self.parked.contains_key(&p) {
            self.heap.push(HeapEntry {
                score: self.score(&p, total),
                count: total,
                pattern: p,
            });
        }
    }

    /// Kill digit `idx` in column `c`, updating counts, indices and the
    /// Kraft sum.
    fn kill(&mut self, c: usize, idx: u32) {
        let d = self.cols[c].digits[idx as usize];
        debug_assert!(self.cols[c].alive.get(idx));
        self.cols[c].alive.unset(idx);
        self.cols[c].index.remove(&(d.row, d.power));
        self.cols[c].kraft -= 1u128 << self.rows[d.row as usize].depth;
        let mut pairs = std::mem::take(&mut self.scratch);
        pairs.clear();
        {
            let col = &self.cols[c];
            pairs.extend(
                col.alive_digits().filter_map(|e| canon((idx, &d), e).map(|(p, _, _)| p)),
            );
        }
        for p in &pairs {
            self.bump(*p, c, -1);
        }
        self.scratch = pairs;
    }

    /// Add a digit to column `c`, updating counts, indices and the
    /// Kraft sum.
    fn add_digit(&mut self, c: usize, row: u32, power: i32, sign: i8) {
        let digit = ColDigit { row, power, sign };
        let mut pairs = std::mem::take(&mut self.scratch);
        pairs.clear();
        {
            let col = &self.cols[c];
            pairs.extend(
                col.alive_digits()
                    .filter_map(|e| canon((u32::MAX, &digit), e).map(|(p, _, _)| p)),
            );
        }
        let idx = self.cols[c].digits.len() as u32;
        debug_assert!(
            !self.cols[c].index.contains_key(&(row, power)),
            "duplicate (row, power) digit in column {c}"
        );
        self.cols[c].digits.push(digit);
        self.cols[c].alive.set(idx);
        self.cols[c].index.insert((row, power), idx);
        self.cols[c].kraft += 1u128 << self.rows[row as usize].depth;
        for p in &pairs {
            self.bump(*p, c, 1);
        }
        self.scratch = pairs;
    }

    /// Greedily match disjoint occurrences of `p`, visiting only the
    /// columns the pattern bitset lists (ascending — the same order the
    /// reference engine's full scan yields them in). Returns
    /// (column, a-digit-idx, b-digit-idx) triples.
    ///
    /// Every column holding a pair has its bit set (bumps only add
    /// bits), so no occurrence can hide in a skipped column. The bitset
    /// may also carry *stale* bits for columns whose pairs have since
    /// died; a column holds ≥ 1 alive pair iff greedy matching (which
    /// starts from an empty used-set) finds ≥ 1 occurrence there, so a
    /// zero-occurrence visit proves the column stale and its bit is
    /// cleared here. Stale visits contribute nothing to the occurrence
    /// list, so matching output is identical to an exact column index.
    fn match_occurrences(&mut self, p: &Pattern) -> Vec<(usize, u32, u32)> {
        let mut occ = Vec::new();
        let mut cols_list = std::mem::take(&mut self.col_scratch);
        cols_list.clear();
        match self.counts.get(p) {
            Some(e) if e.total > 0 => cols_list.extend(e.cols.iter()),
            _ => {
                self.col_scratch = cols_list;
                return occ;
            }
        }
        let mut a_side = std::mem::take(&mut self.a_side);
        let mut used = std::mem::take(&mut self.used);
        let mut cols_scanned = 0usize;
        let mut digits_scanned = 0usize;
        // Stale column ids compact into the front of `cols_list` (each
        // slot is written only after it has been read).
        let mut n_stale = 0usize;
        for k in 0..cols_list.len() {
            let c_id = cols_list[k];
            let c = c_id as usize;
            let col = &self.cols[c];
            cols_scanned += 1;
            used.clear();
            a_side.clear();
            // Collect the a-side digits off the alive bitset, in power
            // order for maximal greedy matching of chain patterns
            // (same-row, shifted).
            for (i, d) in col.alive_digits() {
                if d.row == p.ra {
                    a_side.push((i, *d));
                }
            }
            a_side.sort_by_key(|(_, d)| d.power);
            digits_scanned += a_side.len();
            let occ_before = occ.len();
            for &(ia, da) in a_side.iter() {
                if used.contains(&ia) {
                    continue;
                }
                let pb = da.power + p.shift as i32;
                if let Some(&ib) = col.index.get(&(p.rb, pb)) {
                    if ib == ia || used.contains(&ib) {
                        continue;
                    }
                    debug_assert!(col.alive.get(ib), "index entry for dead digit");
                    let db = &col.digits[ib as usize];
                    // Sign relation must match the canonical pattern…
                    let sub = da.sign != db.sign;
                    if sub != p.sub {
                        continue;
                    }
                    // …and the orientation must canonicalize to `p`
                    // (guards the shift==0 row-order tie and ra==rb).
                    if let Some((cp, ca, cb)) = canon((ia, &da), (ib, db)) {
                        if cp == *p {
                            used.push(ca);
                            used.push(cb);
                            occ.push((c, ca, cb));
                        }
                    }
                }
            }
            if occ.len() == occ_before {
                cols_list[n_stale] = c_id;
                n_stale += 1;
            }
        }
        if n_stale > 0 {
            let e = self.counts.get_mut(p).expect("entry checked above");
            for &c_id in &cols_list[..n_stale] {
                e.cols.unset(c_id);
            }
        }
        self.a_side = a_side;
        self.used = used;
        self.col_scratch = cols_list;
        self.stats.occ_cols_scanned += cols_scanned;
        self.stats.occ_digits_scanned += digits_scanned;
        occ
    }

    /// Depth-feasibility filter: keep as many occurrences per column as
    /// the Kraft budget allows. Returns the admitted occurrences.
    fn filter_depth(&mut self, p: &Pattern, occ: Vec<(usize, u32, u32)>) -> Vec<(usize, u32, u32)> {
        let Some(budget) = &self.budget else { return occ };
        let da = self.rows[p.ra as usize].depth;
        let db = self.rows[p.rb as usize].depth;
        let delta: i128 =
            (1i128 << (da.max(db) + 1)) - (1i128 << da) - (1i128 << db);
        if delta == 0 {
            return occ; // equal-depth merge never hurts feasibility
        }
        let mut kept = Vec::with_capacity(occ.len());
        let mut extra: FxHashMap<usize, i128> = FxHashMap::default();
        for (c, ia, ib) in occ {
            let used = extra.entry(c).or_insert(0);
            let cap = 1i128 << budget[c];
            if self.cols[c].kraft as i128 + *used + delta <= cap {
                *used += delta;
                kept.push((c, ia, ib));
            } else {
                self.stats.depth_rejections += 1;
            }
        }
        kept
    }

    /// One update step: pick the best implementable pattern and rewrite
    /// the tensor. Returns false when exhausted.
    fn step(&mut self) -> bool {
        loop {
            let Some(top) = self.heap.pop() else { return false };
            self.stats.heap_pops += 1;
            let p = top.pattern;
            let cur = self.counts.get(&p).map(|e| e.total).unwrap_or(0);
            if cur != top.count || cur < 2 || self.parked.contains_key(&p) {
                self.stats.stale_pops += 1;
                continue; // stale entry
            }
            let occ = self.match_occurrences(&p);
            let occ = self.filter_depth(&p, occ);
            if occ.len() < 2 {
                // Not worth an adder (or depth-blocked): park at this
                // count; any count change un-parks it.
                self.parked.insert(p, cur);
                continue;
            }
            // Implement: one new adder node, one new tensor row.
            let a = self.rows[p.ra as usize];
            let b = self.rows[p.rb as usize];
            let node = self.builder.add_shift(a.node, b.node, p.shift, p.sub);
            let row = self.rows.len() as u32;
            self.rows.push(RowInfo {
                node,
                qint: self.builder.qint(node),
                depth: self.builder.depth(node),
            });
            for (c, ia, ib) in occ {
                // The occurrence's contribution is sign(a-digit) · w << p_a.
                let (pa, sa) = {
                    let d = &self.cols[c].digits[ia as usize];
                    (d.power, d.sign)
                };
                self.kill(c, ia);
                self.kill(c, ib);
                self.add_digit(c, row, pa, sa);
            }
            self.stats.steps += 1;
            return true;
        }
    }
}

/// Expand the matrix into the digit tensor, run the CSE loop, and sum
/// the residual digits of each column with depth-minimal trees. The
/// adder nodes are appended to `builder`; the returned terms describe
/// each output column.
///
/// `arena` is the allocation-reuse handle: `None` runs on fresh
/// storage (identical behavior, cold allocations); `Some` reuses the
/// arena's slabs and returns them reset afterwards. The emitted
/// program is bit-identical either way.
pub fn compile(
    builder: &mut DaisBuilder,
    inputs: &[InputTerm],
    matrix: &[i64],
    d_in: usize,
    d_out: usize,
    cfg: &CseConfig,
    arena: Option<&EngineArena>,
) -> (Vec<OutTerm>, CseStats) {
    #[cfg(test)]
    {
        if test_hooks::USE_REFERENCE.with(|c| c.get()) {
            return super::reference::optimize_into_stats(
                builder, inputs, matrix, d_in, d_out, cfg,
            );
        }
    }
    match arena {
        Some(a) => {
            let st = a.take();
            let (out, stats, st) = run(builder, inputs, matrix, d_in, d_out, cfg, st);
            a.put(st);
            (out, stats)
        }
        None => {
            let (out, stats, _) =
                run(builder, inputs, matrix, d_in, d_out, cfg, EngineStorage::default());
            (out, stats)
        }
    }
}

/// Deprecated pre-arena entry point; byte-identical to
/// [`compile`]`(…, None)`.
#[deprecated(note = "use cse::compile, which takes an optional EngineArena")]
pub fn optimize_into(
    builder: &mut DaisBuilder,
    inputs: &[InputTerm],
    matrix: &[i64],
    d_in: usize,
    d_out: usize,
    cfg: &CseConfig,
) -> Vec<OutTerm> {
    compile(builder, inputs, matrix, d_in, d_out, cfg, None).0
}

/// Deprecated pre-arena entry point; byte-identical to
/// [`compile`]`(…, None)`.
#[deprecated(note = "use cse::compile, which takes an optional EngineArena")]
pub fn optimize_into_stats(
    builder: &mut DaisBuilder,
    inputs: &[InputTerm],
    matrix: &[i64],
    d_in: usize,
    d_out: usize,
    cfg: &CseConfig,
) -> (Vec<OutTerm>, CseStats) {
    compile(builder, inputs, matrix, d_in, d_out, cfg, None)
}

/// The engine run proper, threading the storage slab through setup,
/// the greedy loop, and teardown. Returns the storage reset and ready
/// for the next compile.
fn run(
    builder: &mut DaisBuilder,
    inputs: &[InputTerm],
    matrix: &[i64],
    d_in: usize,
    d_out: usize,
    cfg: &CseConfig,
    mut st: EngineStorage,
) -> (Vec<OutTerm>, CseStats, EngineStorage) {
    assert_eq!(matrix.len(), d_in * d_out, "matrix shape mismatch");
    assert_eq!(inputs.len(), d_in, "input arity mismatch");

    let mut span = crate::obs::span("cse", "cse.optimize");
    span.arg("d_in", d_in as i64);
    span.arg("d_out", d_out as i64);
    span.arg("dc", cfg.dc as i64);

    let mut rows = std::mem::take(&mut st.rows);
    rows.clear();
    rows.extend(inputs.iter().map(|t| RowInfo {
        node: t.node,
        qint: builder.qint(t.node),
        depth: builder.depth(t.node),
    }));

    // Build the digit tensor column by column, into recycled columns
    // (put-back resets them; resize covers shape changes).
    let mut cols = std::mem::take(&mut st.cols);
    cols.resize_with(d_out, Column::default);
    for col in &mut cols {
        col.reset();
    }
    for (c, col) in cols.iter_mut().enumerate() {
        for j in 0..d_in {
            let w = matrix[j * d_out + c];
            for digit in Csd::encode(w).digits() {
                let idx = col.digits.len() as u32;
                col.digits.push(ColDigit { row: j as u32, power: digit.power, sign: digit.sign });
                col.index.insert((j as u32, digit.power), idx);
                col.alive.set(idx);
                col.kraft += 1u128 << rows[j].depth;
            }
        }
    }

    // Depth budgets: per-column minimal feasible depth via the Kraft sum
    // (smallest D with Σ 2^{d_k} ≤ 2^D); global depth_min is the max over
    // columns (the paper's ceil(log2 d_in) generalized to digit counts
    // and non-zero input depths). Budget = depth_min + dc, floored at
    // each column's own minimum so the constraint is always satisfiable.
    let mut budget_pool = std::mem::take(&mut st.budget);
    budget_pool.clear();
    let (budget, spare_budget) = if cfg.dc >= 0 {
        budget_pool.extend(cols.iter().map(|c| min_feasible_depth(c.kraft)));
        let depth_min = budget_pool.iter().copied().max().unwrap_or(0);
        for m in &mut budget_pool {
            *m = (*m).max(depth_min + cfg.dc as u32);
        }
        (Some(budget_pool), Vec::new())
    } else {
        (None, budget_pool)
    };

    // Initial pattern counts: all digit pairs within each column, into
    // both the cross-column total and the per-column bitset.
    let mut counts = std::mem::take(&mut st.counts);
    let mut bits_pool = std::mem::take(&mut st.bits_pool);
    for (c, col) in cols.iter().enumerate() {
        let n = col.digits.len();
        for i in 0..n {
            for j in (i + 1)..n {
                let pair = canon((i as u32, &col.digits[i]), (j as u32, &col.digits[j]));
                if let Some((p, _, _)) = pair {
                    if !counts.contains_key(&p) {
                        let words = bits_pool.pop().unwrap_or_default();
                        counts.insert(p, PatEntry { total: 0, cols: BitSet::from_words(words) });
                    }
                    let e = counts.get_mut(&p).expect("entry ensured above");
                    e.total += 1;
                    e.cols.set(c as u32);
                }
            }
        }
    }

    let mut engine = Engine {
        builder,
        d_out,
        cfg: *cfg,
        rows,
        cols,
        counts,
        bits_pool,
        heap: BinaryHeap::from(std::mem::take(&mut st.heap)),
        parked: std::mem::take(&mut st.parked),
        budget,
        scratch: std::mem::take(&mut st.scratch),
        a_side: std::mem::take(&mut st.a_side),
        used: std::mem::take(&mut st.used),
        col_scratch: std::mem::take(&mut st.col_scratch),
        stats: CseStats::default(),
    };
    // Seed the heap in sorted pattern order. Pop order is a multiset
    // property of the heap's total order, so hash-map iteration order
    // can never matter — but an explicitly sorted seed keeps that
    // platform-determinism argument local and obvious.
    let mut patterns = std::mem::take(&mut st.patterns);
    patterns.clear();
    patterns.extend(engine.counts.keys().copied());
    patterns.sort_unstable();
    for &p in &patterns {
        engine.push_heap(p);
    }

    while engine.step() {}

    // Final summation of residual digits, column by column.
    let term_lists: Vec<Vec<tree::Term>> = (0..engine.d_out)
        .map(|c| {
            engine.cols[c]
                .alive_digits()
                .map(|(_, d)| tree::Term {
                    node: engine.rows[d.row as usize].node,
                    shift: d.power,
                    neg: d.sign < 0,
                })
                .collect()
        })
        .collect();
    let stats = engine.stats;
    let builder = engine.builder;
    let out = term_lists.into_iter().map(|terms| tree::combine(builder, terms)).collect();
    // Attach the deterministic work counters to the span (they are the
    // same counters the perf baseline pins).
    span.arg("steps", stats.steps as i64);
    span.arg("heap_pops", stats.heap_pops as i64);
    span.arg("stale_pops", stats.stale_pops as i64);
    span.arg("depth_rejections", stats.depth_rejections as i64);
    span.arg("occ_cols_scanned", stats.occ_cols_scanned as i64);
    span.arg("occ_digits_scanned", stats.occ_digits_scanned as i64);

    // Tear down into reset storage: clear everything, keep every
    // allocation, and recycle pattern bitset words into the pool.
    let mut cols = engine.cols;
    for col in &mut cols {
        col.reset();
    }
    let mut rows = engine.rows;
    rows.clear();
    let mut counts = engine.counts;
    let mut bits_pool = engine.bits_pool;
    for (_, e) in counts.drain() {
        let mut words = e.cols.take_words();
        words.fill(0);
        bits_pool.push(words);
    }
    let mut parked = engine.parked;
    parked.clear();
    let mut heap = engine.heap.into_vec();
    heap.clear();
    let mut budget = match engine.budget {
        Some(b) => b,
        None => spare_budget,
    };
    budget.clear();
    let mut scratch = engine.scratch;
    scratch.clear();
    let mut a_side = engine.a_side;
    a_side.clear();
    let mut used = engine.used;
    used.clear();
    let mut col_scratch = engine.col_scratch;
    col_scratch.clear();
    patterns.clear();
    let st = EngineStorage {
        cols,
        rows,
        counts,
        bits_pool,
        parked,
        heap,
        budget,
        scratch,
        a_side,
        used,
        col_scratch,
        patterns,
    };
    (out, stats, st)
}

/// Smallest tree depth `D` such that terms with the given Kraft sum
/// (Σ 2^{d_k}) fit: `Σ 2^{d_k} ≤ 2^D`. Shared with the reference
/// engine so both compute identical depth budgets.
pub(super) fn min_feasible_depth(kraft: u128) -> u32 {
    if kraft <= 1 {
        return 0;
    }
    128 - (kraft - 1).leading_zeros()
}

/// Test-only switch routing [`compile`] through the pre-index
/// reference engine on the current thread, so the differential sweep
/// can drive identical full strategy flows (`crate::cmvm::compile`)
/// through both engines without duplicating the two-stage plumbing.
#[cfg(test)]
pub(crate) mod test_hooks {
    use std::cell::Cell;

    thread_local! {
        pub static USE_REFERENCE: Cell<bool> = const { Cell::new(false) };
    }

    /// Run `f` with the reference engine substituted for the indexed
    /// one on this thread (reset on unwind).
    pub fn with_reference_engine<T>(f: impl FnOnce() -> T) -> T {
        struct Reset;
        impl Drop for Reset {
            fn drop(&mut self) {
                USE_REFERENCE.with(|c| c.set(false));
            }
        }
        USE_REFERENCE.with(|c| c.set(true));
        let _reset = Reset;
        f()
    }
}

#[cfg(test)]
mod unit {
    use super::*;

    #[test]
    fn min_feasible_depth_examples() {
        assert_eq!(min_feasible_depth(0), 0);
        assert_eq!(min_feasible_depth(1), 0);
        assert_eq!(min_feasible_depth(2), 1);
        assert_eq!(min_feasible_depth(3), 2);
        assert_eq!(min_feasible_depth(4), 2);
        assert_eq!(min_feasible_depth(5), 3);
        assert_eq!(min_feasible_depth(8), 3);
        assert_eq!(min_feasible_depth(9), 4);
        // 22 digits (8x8 8-bit column): depth 5, matching Table 2 dc=0.
        assert_eq!(min_feasible_depth(22), 5);
    }

    /// Pins the documented total heap order: score desc, then count
    /// desc, then lexicographically smallest pattern first.
    #[test]
    fn heap_order_is_total_and_documented() {
        let p_small = Pattern { ra: 0, rb: 1, shift: 0, sub: false };
        let p_big = Pattern { ra: 0, rb: 1, shift: 1, sub: false };
        assert!(p_small < p_big);
        let mut heap = BinaryHeap::new();
        heap.push(HeapEntry { score: 5, count: 2, pattern: p_big });
        heap.push(HeapEntry { score: 5, count: 2, pattern: p_small });
        heap.push(HeapEntry { score: 5, count: 3, pattern: p_big });
        heap.push(HeapEntry { score: 7, count: 2, pattern: p_big });
        let order: Vec<(i64, u32, Pattern)> =
            std::iter::from_fn(|| heap.pop().map(|e| (e.score, e.count, e.pattern))).collect();
        assert_eq!(
            order,
            vec![(7, 2, p_big), (5, 3, p_big), (5, 2, p_small), (5, 2, p_big)]
        );
    }

    /// The same problem compiled cold, arena-cold, and arena-warm (the
    /// second run through the same arena reuses every slab) must emit
    /// identical terms and counters.
    #[test]
    fn arena_reuse_is_bit_identical() {
        let matrix: Vec<i64> = vec![3, 5, -7, 9, 11, 13, -3, 5, 7, 23, 0, 45];
        let (d_in, d_out) = (4, 3);
        let run_with = |arena: Option<&EngineArena>| {
            let mut b = DaisBuilder::new();
            let inputs: Vec<InputTerm> = (0..d_in)
                .map(|i| InputTerm { node: b.input(i, QInterval::new(-128, 127, 0), 0) })
                .collect();
            let (terms, stats) =
                compile(&mut b, &inputs, &matrix, d_in, d_out, &CseConfig::default(), arena);
            for t in &terms {
                b.output(t.node.expect("every column of this matrix is non-zero"), t.shift);
            }
            (b.finish(), terms.len(), stats)
        };
        let cold = run_with(None);
        let arena = EngineArena::new();
        let arena_cold = run_with(Some(&arena));
        let arena_warm = run_with(Some(&arena));
        assert_eq!(cold.0, arena_cold.0);
        assert_eq!(cold.0, arena_warm.0);
        assert_eq!(cold.2, arena_cold.2);
        assert_eq!(cold.2, arena_warm.2);
        assert_eq!(cold.1, d_out);
        assert!(cold.2.steps > 0, "matrix has shareable patterns");
    }
}
