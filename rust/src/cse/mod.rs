//! Stage 2 — cost-aware Common Subexpression Elimination (paper §4.4).
//!
//! The matrix is expanded into its CSD digit tensor
//! `M_expr ∈ {-1,0,1}^{d_in × d_out × B}`. A *two-term subexpression*
//! `a ± (b << s)` is a pair of digits in the same column; its canonical
//! pattern is shift- and sign-invariant, so reuse is captured **across
//! differently scaled terms and signed digits** (the capability SCMVM
//! lacks, §2.1). The algorithm greedily implements the pattern with the
//! highest *weighted* frequency — frequency × operand bit-overlap, the
//! full-adder-only cost proxy of §4.4 — maintaining the digit tensor and
//! a differential frequency table, until no pattern occurs twice. The
//! remaining digits of each column are summed with a depth-minimal
//! (Huffman-style) balanced tree.
//!
//! The delay constraint is enforced exactly with a Kraft-sum argument:
//! a set of terms with adder depths `d_k` can be combined into a tree of
//! depth `≤ D` iff `Σ 2^{d_k} ≤ 2^D`; every candidate implementation is
//! admitted only if each affected column stays feasible for its depth
//! budget.
//!
//! The engine's occurrence matching is bitset-driven (per-pattern
//! column bitsets + per-column alive bitsets, maintained differentially
//! — see `engine.rs`), and every engine container lives in a recyclable
//! arena ([`EngineArena`]) so warm compiles reuse the previous run's
//! allocations. The entry point is [`compile`]; the pre-index
//! implementation is retained in [`reference`] as the differential/perf
//! baseline, proven bit-identical by the seeded sweep in `tests.rs` and
//! timed head-to-head by [`crate::perf`].

mod engine;
pub mod reference;
pub mod tree;

pub use engine::{compile, CseConfig, CseStats, EngineArena, InputTerm, OutTerm};
#[allow(deprecated)]
pub use engine::{optimize_into, optimize_into_stats};
pub use tree::naive_da;

#[cfg(test)]
mod tests;
