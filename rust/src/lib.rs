//! # da4ml — Distributed Arithmetic for Real-time Neural Networks on FPGAs
//!
//! A reproduction of *da4ml: Distributed Arithmetic for Real-time Neural
//! Networks on FPGAs* (Sun, Que, Loncar, Luk, Spiropulu — ACM TRETS 2026)
//! as a three-layer rust + JAX + Pallas stack.
//!
//! The library optimizes constant matrix–vector multiplication (CMVM,
//! `y^T = x^T M`) into multiplierless shift-add adder graphs for
//! fully-unrolled, II=1 FPGA designs:
//!
//! 1. **Stage 1** ([`graph`]) — a depth-bounded Prim MST over matrix
//!    columns decomposes `M = M1 · M2`, capturing shared structure across
//!    outputs.
//! 2. **Stage 2** ([`cse`]) — cost-aware two-term common subexpression
//!    elimination over the canonical-signed-digit ([`csd`]) expansion,
//!    weighted by operand bit-overlap, under a delay constraint.
//!
//! The result is a [`dais`] program (Distributed Arithmetic Instruction
//! Set — an SSA adder-graph IR) which can be:
//!
//! * interpreted bit-accurately ([`dais::interp`], the Verilator
//!   substitute),
//! * pipelined ([`pipeline`]), lowered to the stage-aware hardware IR
//!   ([`netlist`] — explicit wires, cells and register delay lines,
//!   with a cycle-accurate simulator and a self-checking testbench
//!   generator) and emitted as Verilog/VHDL ([`rtl`]),
//! * costed by the analytic FPGA resource/timing model ([`estimate`],
//!   the Vivado substitute),
//! * or embedded in a full neural-network design through the hls4ml-like
//!   frontend ([`nn`]) driven by the [`coordinator`].
//!
//! The [`runtime`] module serves the golden model the end-to-end
//! examples cross-check bit-exactly against the DAIS simulation: by
//! default through the pure-Rust [`runtime::golden`] backend (the JSON
//! weight artifacts replayed via [`nn::sim`]), or — behind the
//! off-by-default `pjrt` feature — through the PJRT CPU client
//! executing the JAX-lowered `artifacts/*.hlo.txt`.
//!
//! Artifact ingestion is streaming end to end: the [`json`] module's
//! zero-copy pull parser feeds typed decoders so weight matrices and
//! test vectors never materialize a DOM tree, and the [`serve`] module
//! turns the [`coordinator`] into a long-lived JSONL compile service
//! (`da4ml serve`) — either over stdin, or as a concurrent socket
//! server ([`serve::server`]) with bounded in-flight work,
//! per-connection backpressure and graceful drain. `ARCHITECTURE.md`
//! at the repository root maps every module to its paper section and
//! walks both data flows.
//!
//! The [`perf`] module is the measurement subsystem: a fixed benchmark
//! suite (`da4ml perf`) that times the optimize/lower/emit phases,
//! collects the deterministic CSE work counters, writes the
//! schema-versioned `BENCH_cmvm.json`, and diffs against a committed
//! baseline so CI gates on perf regressions (`docs/perf.md`).
//!
//! The [`explore`] module is the design-space explorer (`da4ml
//! explore`, the serve `"explore"` job): it sweeps strategy ×
//! delay-constraint × pipeline candidates on a deterministic worker
//! pool and reports the non-dominated (LUT, FF, latency) Pareto front
//! — bit-identical output for any `--jobs` value — with
//! [`explore::pick`] selecting a front point per objective
//! (`docs/explore.md`).

// The optimizer kernels are deliberately index-heavy (strided matrix
// walks, triangle enumerations): sequential-index loops are clearer
// than iterator-adaptor chains there, and the serve wire layer's
// nested reply types are inherent. Everything else clippy surfaces is
// denied in CI (`cargo clippy --all-targets -- -D warnings`).
#![allow(clippy::needless_range_loop, clippy::type_complexity)]

pub mod baseline;
pub mod cmvm;
pub mod coordinator;
pub mod csd;
pub mod cse;
pub mod dais;
pub mod estimate;
pub mod explore;
pub mod fixed;
pub mod graph;
pub mod json;
pub mod netlist;
pub mod nn;
pub mod obs;
pub mod perf;
pub mod pipeline;
pub mod report;
pub mod rtl;
pub mod runtime;
pub mod serve;
pub mod util;

/// Library-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Convenience prelude re-exporting the most common public items.
///
/// ```
/// use da4ml::prelude::*;
///
/// // Compile one 2x2 CMVM into a multiplierless adder graph and cost
/// // it on the analytic FPGA model.
/// let problem = CmvmProblem::new(2, 2, vec![3, 5, -7, 9], 8).unwrap();
/// let opts = OptimizeOptions::new(Strategy::Da { dc: -1 });
/// let sol = da4ml::cmvm::compile(&problem, &opts).unwrap();
/// let report = da4ml::estimate::combinational(&sol.program, &FpgaModel::default());
/// assert!(sol.adders > 0 && report.lut > 0);
/// ```
pub mod prelude {
    pub use crate::cmvm::{
        compile, ArenaMode, CmvmProblem, CmvmSolution, CompileArena, OptimizeOptions, Strategy,
    };
    pub use crate::coordinator::{CompileJob, Coordinator};
    pub use crate::csd::Csd;
    pub use crate::cse::CseConfig;
    pub use crate::dais::{DaisOp, DaisProgram};
    pub use crate::estimate::{FpgaModel, ResourceReport};
    pub use crate::fixed::QInterval;
    pub use crate::pipeline::PipelineConfig;
}

/// Shared report generators used by the `cargo bench` table targets
/// (kept in the library so every bench prints identical conventions).
pub mod bench_tables;

/// Shared generator for the RTL-flow benches (Tables 10–12).
pub mod bench_tables_rtl;
