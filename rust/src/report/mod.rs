//! Paper-style table rendering for benches and the CLI.

/// A simple aligned text table (GitHub-flavored markdown compatible).
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create a table with the given title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header arity).
    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(row);
    }

    /// Convenience: append from displayable items.
    pub fn push_display(&mut self, row: &[&dyn std::fmt::Display]) {
        self.push(row.iter().map(|v| v.to_string()).collect());
    }

    /// Render the table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:>w$} |", c, w = widths[i]));
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("\n## {}\n\n", self.title));
        }
        out.push_str(&line(&self.headers));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }
}

/// Format a float with engineering-style precision as the paper does
/// (e.g. `1.2e4` for CPU times).
pub fn sci(v: f64) -> String {
    if v == 0.0 {
        return "0".into();
    }
    if (0.1..10_000.0).contains(&v.abs()) {
        if v.abs() >= 100.0 {
            format!("{v:.0}")
        } else {
            format!("{v:.2}")
        }
    } else {
        format!("{v:.1e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["a", "bb"]);
        t.push(vec!["1".into(), "22222".into()]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        assert!(s.contains("| 1 | 22222 |"));
    }

    #[test]
    fn sci_formats() {
        assert_eq!(sci(0.0), "0");
        assert_eq!(sci(1.5), "1.50");
        assert_eq!(sci(1234.0), "1234");
        assert_eq!(sci(1.2e6), "1.2e6");
        assert_eq!(sci(0.001), "1.0e-3");
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a"]);
        t.push(vec!["1".into(), "2".into()]);
    }
}
