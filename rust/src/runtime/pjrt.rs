//! PJRT backend (feature `pjrt`) — loads the JAX-lowered HLO artifacts
//! and executes them on the PJRT CPU client via the `xla` crate. This is
//! the *hardware* golden model the DAIS simulation is cross-checked
//! against in the end-to-end examples; Python is never on this path.
//!
//! In hermetic builds the `xla` dependency resolves to the vendored API
//! stub (`vendor/xla`), which compiles everywhere but errors at runtime;
//! point it at the real crate to execute HLO.

use super::TensorI32;
use crate::Result;
use anyhow::anyhow;
use std::path::Path;

/// A PJRT CPU runtime.
pub struct Runtime {
    client: xla::PjRtClient,
}

/// A compiled HLO module ready for execution.
pub struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    /// Human-readable provenance (artifact path).
    pub name: String,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client })
    }

    /// Platform name reported by PJRT (e.g. "cpu" / "Host").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile an HLO **text** artifact.
    pub fn load_hlo_text<P: AsRef<Path>>(&self, path: P) -> Result<LoadedModel> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parse HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
        Ok(LoadedModel { exe, name: path.display().to_string() })
    }
}

fn to_literal(t: &TensorI32) -> Result<xla::Literal> {
    xla::Literal::vec1(&t.data)
        .reshape(&t.dims)
        .map_err(|e| anyhow!("reshape literal: {e:?}"))
}

impl LoadedModel {
    /// Execute on i32 tensors; the module must return a tuple (jax
    /// lowering with `return_tuple=True`), and each element must be i32.
    pub fn run_i32(&self, inputs: &[TensorI32]) -> Result<Vec<TensorI32>> {
        let lits: Vec<xla::Literal> =
            inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let bufs = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let parts = result.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit.shape().map_err(|e| anyhow!("shape: {e:?}"))?;
                let dims = match &shape {
                    xla::Shape::Array(a) => a.dims().to_vec(),
                    _ => return Err(anyhow!("non-array output")),
                };
                let data = lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
                Ok(TensorI32::new(data, dims))
            })
            .collect()
    }

    /// Execute on f32 tensors (for float-graph artifacts).
    pub fn run_f32(&self, inputs: &[(Vec<f32>, Vec<i64>)]) -> Result<Vec<Vec<f32>>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|(d, dims)| {
                xla::Literal::vec1(d).reshape(dims).map_err(|e| anyhow!("reshape: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let bufs = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        let result = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let parts = result.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|lit| lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }
}
