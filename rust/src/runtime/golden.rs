//! Pure-Rust golden-model backend (the default, hermetic runtime).
//!
//! Serves the exported JSON weight/test-vector artifacts through the
//! bit-exact [`crate::nn::sim`] interpreter — the same integer semantics
//! the JAX export was generated with — so the end-to-end flows keep a
//! golden reference (and their skip-when-absent behavior) without
//! linking PJRT. The [`GoldenModel::run_i32`] entry point mirrors the
//! PJRT `LoadedModel::run_i32` call shape (see `runtime::pjrt`, feature
//! `pjrt`) so callers can swap backends mechanically.

use super::{artifacts_dir, load_text, TensorI32};
use crate::nn::{self, NetworkSpec, TestVectors};
use crate::Result;
use anyhow::ensure;
use std::path::Path;

/// A golden model backed by an exported network spec.
pub struct GoldenModel {
    spec: NetworkSpec,
    /// Human-readable provenance (artifact name or "inline").
    pub name: String,
}

impl GoldenModel {
    /// Wrap an already-decoded spec.
    pub fn from_spec(spec: NetworkSpec) -> Self {
        let name = spec.name.clone();
        Self { spec, name }
    }

    /// Load `<dir>/<name>.weights.json`.
    pub fn load_from<P: AsRef<Path>>(dir: P, name: &str) -> Result<Self> {
        let path = dir.as_ref().join(format!("{name}.weights.json"));
        let spec = NetworkSpec::from_json(&load_text(&path)?)?;
        Ok(Self { spec, name: path.display().to_string() })
    }

    /// Load from the default artifacts directory.
    pub fn load(name: &str) -> Result<Self> {
        Self::load_from(artifacts_dir(), name)
    }

    /// The wrapped network spec.
    pub fn spec(&self) -> &NetworkSpec {
        &self.spec
    }

    /// Run one flat input vector; returns the flat output.
    pub fn run(&self, x: &[i64]) -> Vec<i64> {
        nn::sim::forward(&self.spec, x)
    }

    /// Run a batch of input vectors.
    pub fn run_batch(&self, xs: &[Vec<i64>]) -> Vec<Vec<i64>> {
        nn::sim::forward_batch(&self.spec, xs)
    }

    /// PJRT-shaped entry point: the first tensor is the network input;
    /// any further tensors (the weight arguments of the HLO convention)
    /// are ignored because the spec already embeds the weights. Returns
    /// a single output tensor.
    pub fn run_i32(&self, inputs: &[TensorI32]) -> Result<Vec<TensorI32>> {
        ensure!(!inputs.is_empty(), "golden run_i32: no input tensor");
        let x: Vec<i64> = inputs[0].data.iter().map(|&v| v as i64).collect();
        ensure!(
            x.len() == self.spec.input_len(),
            "golden run_i32: input length {} != spec input length {}",
            x.len(),
            self.spec.input_len()
        );
        let y = self.run(&x);
        let dims = vec![y.len() as i64];
        Ok(vec![TensorI32::new(y.into_iter().map(|v| v as i32).collect(), dims)])
    }
}

/// Load `<artifacts>/<name>.testvec.json` (the exported golden vectors).
pub fn load_test_vectors(name: &str) -> Result<TestVectors> {
    let path = artifacts_dir().join(format!("{name}.testvec.json"));
    TestVectors::from_json(&load_text(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> NetworkSpec {
        NetworkSpec::from_json(
            r#"{"name":"tiny","input_bits":4,"input_signed":true,"input_shape":[2],
                "layers":[{"type":"dense","w":[[1,2],[3,4]],"b":[0,-1],"relu":false,
                           "shift":0,"clip_min":-512,"clip_max":511}]}"#,
        )
        .unwrap()
    }

    #[test]
    fn runs_spec_through_sim() {
        let g = GoldenModel::from_spec(tiny_spec());
        // y = [x0 + 3 x1, 2 x0 + 4 x1 - 1]
        assert_eq!(g.run(&[1, 2]), vec![7, 9]);
        assert_eq!(g.name, "tiny");
    }

    #[test]
    fn run_i32_matches_pjrt_call_shape() {
        let g = GoldenModel::from_spec(tiny_spec());
        let input = TensorI32::new(vec![1, 2], vec![2]);
        // Extra (weight) tensors are tolerated and ignored.
        let extra = TensorI32::new(vec![0; 4], vec![2, 2]);
        let out = g.run_i32(&[input, extra]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].data, vec![7, 9]);
        assert_eq!(out[0].dims, vec![2]);
    }

    #[test]
    fn run_i32_rejects_bad_arity() {
        let g = GoldenModel::from_spec(tiny_spec());
        assert!(g.run_i32(&[]).is_err());
        let bad = TensorI32::new(vec![1, 2, 3], vec![3]);
        assert!(g.run_i32(&[bad]).is_err());
    }

    #[test]
    fn load_missing_artifact_is_clean_error() {
        assert!(GoldenModel::load_from("/nonexistent-dir", "jet_mlp").is_err());
        assert!(load_test_vectors("definitely_missing").is_err());
    }
}
