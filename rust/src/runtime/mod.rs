//! Golden-model runtime.
//!
//! Two backends serve the build-time artifacts (`artifacts/*.json`,
//! `artifacts/*.hlo.txt`, produced once by `make artifacts` via
//! `python/compile/aot.py`):
//!
//! * [`golden`] — the **default**, pure-Rust backend: loads the exported
//!   JSON weight specs and replays them through the bit-exact
//!   [`crate::nn::sim`] interpreter. Hermetic; always available.
//! * `pjrt` (feature `pjrt`, off by default; not linkable here because
//!   the module is compiled out of default builds) — executes the
//!   JAX-lowered HLO artifacts on the PJRT CPU client via the `xla`
//!   crate. The workspace vendors an API *stub* for `xla` so the feature
//!   compiles offline; swap in the real crate to actually run HLO.
//!
//! Interchange format for PJRT is **HLO text** (not serialized protos):
//! jax ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//! rejects, while the text parser reassigns ids.

pub mod golden;
#[cfg(feature = "pjrt")]
pub mod pjrt;

#[cfg(feature = "pjrt")]
pub use pjrt::{LoadedModel, Runtime};

use crate::Result;
use anyhow::Context;
use std::path::Path;

/// A host tensor of i32 values (the integer-unit convention of the
/// quantized models).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorI32 {
    /// Row-major data.
    pub data: Vec<i32>,
    /// Dimensions.
    pub dims: Vec<i64>,
}

impl TensorI32 {
    /// Build from data and dims (checked).
    pub fn new(data: Vec<i32>, dims: Vec<i64>) -> Self {
        assert_eq!(data.len() as i64, dims.iter().product::<i64>(), "shape mismatch");
        Self { data, dims }
    }
}

/// Locate the artifacts directory (env override, then repo default).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("DA4ML_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}

/// Read a JSON artifact (weights, test vectors) into a parsed value,
/// with context on failure.
pub fn load_json_value<P: AsRef<Path>>(path: P) -> Result<crate::json::Value> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    crate::json::parse(&text).with_context(|| format!("parsing {}", path.display()))
}

/// Read a text artifact with context.
pub fn load_text<P: AsRef<Path>>(path: P) -> Result<String> {
    let path = path.as_ref();
    std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))
}
