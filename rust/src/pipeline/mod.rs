//! Greedy register insertion (paper §5.2).
//!
//! Pipelining a DAIS program assigns each node a *stage*; an edge
//! crossing `k` stages passes through `k` registers. Following the
//! paper, the insertion is greedy and local: each op accrues an
//! estimated delay (1.0 unit per adder by default, configurable), and
//! when the accumulated combinational delay since the last register
//! exceeds the threshold, a stage boundary is inserted. "Pipeline every
//! 5 adders" (the paper's 200 MHz setting) is `threshold = 5.0`;
//! "every adder" (the 1 GHz setting) is `threshold = 1.0`.

use crate::dais::{DaisOp, DaisProgram, RoundMode};

/// Pipelining configuration.
#[derive(Debug, Clone, Copy)]
pub struct PipelineConfig {
    /// Maximum accumulated delay (in adder-delay units) allowed within
    /// one pipeline stage.
    pub threshold: f64,
    /// Delay of one adder/subtractor (unit by default, per the paper).
    pub adder_delay: f64,
    /// Delay of a ReLU mux.
    pub relu_delay: f64,
}

impl PipelineConfig {
    /// The paper's 200 MHz setting: a register every 5 adders.
    ///
    /// `n` must be positive — "a register every 0 adders" is not a
    /// schedule (it used to silently behave like `every_n_adders(1)`).
    /// Panics on 0; untrusted inputs (CLI flags, wire fields) go
    /// through [`PipelineConfig::try_every_n_adders`] instead.
    pub fn every_n_adders(n: u32) -> Self {
        assert!(n > 0, "every_n_adders: n must be positive, got 0");
        Self { threshold: n as f64, adder_delay: 1.0, relu_delay: 0.5 }
    }

    /// Fallible [`PipelineConfig::every_n_adders`]: `n == 0` is a
    /// proper error instead of a panic.
    pub fn try_every_n_adders(n: u32) -> crate::Result<Self> {
        anyhow::ensure!(
            n > 0,
            "pipeline: every_n_adders(0) is invalid (the stage threshold must be positive)"
        );
        Ok(Self::every_n_adders(n))
    }
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self::every_n_adders(5)
    }
}

fn op_delay(op: &DaisOp, cfg: &PipelineConfig) -> f64 {
    match op {
        DaisOp::Input { .. } | DaisOp::Const { .. } => 0.0,
        DaisOp::AddShift { .. } | DaisOp::Neg { .. } => cfg.adder_delay,
        DaisOp::Relu { .. } => cfg.relu_delay,
        DaisOp::Quant { round, .. } => match round {
            RoundMode::Floor => 0.0,
            RoundMode::HalfUp => cfg.adder_delay,
        },
    }
}

/// Assign a pipeline stage to every node. Stage 0 holds the inputs.
///
/// Guarantees `stage[consumer] >= stage[producer]` for every edge, so
/// the assignment is directly usable by
/// [`crate::dais::interp::simulate_pipelined`] and
/// [`crate::estimate::pipelined`].
pub fn assign_stages(program: &DaisProgram, cfg: &PipelineConfig) -> Vec<u32> {
    let mut stage = vec![0u32; program.nodes.len()];
    let mut slack = vec![0f64; program.nodes.len()];
    for (i, node) in program.nodes.iter().enumerate() {
        let d = op_delay(&node.op, cfg);
        let mut s = 0u32;
        let mut acc: f64 = 0.0;
        for p in node.op.operands() {
            let (ps, pk) = (stage[p as usize], slack[p as usize]);
            if ps > s {
                s = ps;
                acc = pk;
            } else if ps == s {
                acc = acc.max(pk);
            }
        }
        // Operands on earlier stages arrive registered (slack 0).
        let total = acc + d;
        if total > cfg.threshold && acc > 0.0 {
            stage[i] = s + 1;
            slack[i] = d;
        } else {
            stage[i] = s;
            slack[i] = total;
        }
    }
    stage
}

/// Pipeline latency in cycles for a stage assignment (max output stage).
pub fn latency(program: &DaisProgram, stages: &[u32]) -> u32 {
    program
        .outputs
        .iter()
        .map(|o| stages[o.node as usize])
        .max()
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dais::{interp, DaisBuilder};
    use crate::fixed::QInterval;

    /// A chain of n adders.
    fn chain(n: usize) -> DaisProgram {
        let mut b = DaisBuilder::new();
        let q = QInterval::new(-128, 127, 0);
        let x = b.input(0, q, 0);
        let y = b.input(1, q, 0);
        let mut acc = x;
        for _ in 0..n {
            acc = b.add_shift(acc, y, 0, false);
        }
        b.output(acc, 0);
        b.finish()
    }

    #[test]
    fn every_adder_registers_each_level() {
        let p = chain(6);
        let stages = assign_stages(&p, &PipelineConfig::every_n_adders(1));
        // First adder shares stage 0 with the inputs; 5 boundaries follow.
        assert_eq!(latency(&p, &stages), 5);
    }

    #[test]
    fn every_five_adders() {
        let p = chain(10);
        let stages = assign_stages(&p, &PipelineConfig::every_n_adders(5));
        assert_eq!(latency(&p, &stages), 1);
    }

    #[test]
    fn monotone_stages() {
        let p = chain(13);
        let stages = assign_stages(&p, &PipelineConfig::default());
        for (i, node) in p.nodes.iter().enumerate() {
            for op in node.op.operands() {
                assert!(stages[op as usize] <= stages[i]);
            }
        }
    }

    #[test]
    #[should_panic(expected = "every_n_adders")]
    fn zero_threshold_rejected() {
        // Used to silently behave like every_n_adders(1); now a hard
        // error (try_every_n_adders for the fallible path).
        let _ = PipelineConfig::every_n_adders(0);
    }

    #[test]
    fn try_every_n_adders_is_the_fallible_path() {
        assert!(PipelineConfig::try_every_n_adders(0).is_err());
        let cfg = PipelineConfig::try_every_n_adders(5).unwrap();
        assert_eq!(cfg.threshold, PipelineConfig::every_n_adders(5).threshold);
    }

    /// Pinned: an empty program has an empty stage assignment and zero
    /// latency — no panics, no phantom stages.
    #[test]
    fn empty_program_assigns_no_stages() {
        let p = DaisBuilder::new().finish();
        assert!(p.nodes.is_empty() && p.outputs.is_empty());
        let stages = assign_stages(&p, &PipelineConfig::default());
        assert!(stages.is_empty());
        assert_eq!(latency(&p, &stages), 0);
    }

    /// Pinned: a program with inputs/outputs but no adders stays
    /// entirely on stage 0 for every threshold.
    #[test]
    fn adderless_program_stays_on_stage_zero() {
        let mut b = DaisBuilder::new();
        let q = QInterval::new(-128, 127, 0);
        let x = b.input(0, q, 0);
        b.output(x, 0);
        let p = b.finish();
        for n in [1, 5] {
            let stages = assign_stages(&p, &PipelineConfig::every_n_adders(n));
            assert_eq!(stages, vec![0]);
            assert_eq!(latency(&p, &stages), 0);
        }
    }

    /// Pipelined streaming simulation == combinational evaluation,
    /// for random CMVM programs and thresholds.
    #[test]
    fn prop_pipelined_equals_combinational() {
        crate::util::property("pipelined_equals_combinational", 16, |rng| {
            let n = (rng.below(5) + 1) as u32;
            let (d_in, d_out) = (rng.below(4) + 2, rng.below(4) + 2);
            let m: Vec<i64> = (0..d_in * d_out)
                .map(|_| rng.range_i64(-127, 127))
                .collect();
            let prob = crate::cmvm::CmvmProblem::new(d_in, d_out, m, 8).unwrap();
            let opts = crate::cmvm::OptimizeOptions::new(crate::cmvm::Strategy::Da { dc: -1 });
            let sol = crate::cmvm::compile(&prob, &opts).unwrap();
            let stages = assign_stages(&sol.program, &PipelineConfig::every_n_adders(n));
            let stream: Vec<Vec<i64>> = (0..12)
                .map(|_| (0..d_in).map(|_| rng.range_i64(-128, 127)).collect())
                .collect();
            let want = interp::evaluate_batch(&sol.program, &stream);
            let got = interp::simulate_pipelined(&sol.program, &stages, &stream);
            assert_eq!(got, want);
        });
    }
}
