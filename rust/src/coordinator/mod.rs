//! The L3 compile-job coordinator.
//!
//! The paper's contribution is a compiler, so the coordinator here is a
//! *compilation service*: it takes batches of CMVM jobs (one per network
//! layer / template), deduplicates them through a solution cache (the
//! same constant matrix frequently recurs — e.g. conv kernels shared
//! across positions or re-synthesized quantization sweeps), executes
//! them on a scoped worker pool, and aggregates solution statistics.
//! The CLI (`rust/src/main.rs`) and the benches drive everything through
//! this interface.

use crate::cmvm::{optimize, CmvmProblem, CmvmSolution, Strategy};
use crate::Result;
use rustc_hash::FxHashMap;
use std::hash::{Hash, Hasher};
use std::sync::{Arc, Mutex};

/// One compilation request.
#[derive(Debug, Clone)]
pub struct CompileJob {
    /// Stable name for reporting.
    pub name: String,
    /// The CMVM to optimize.
    pub problem: CmvmProblem,
    /// Strategy to apply.
    pub strategy: Strategy,
}

/// Aggregated coordinator statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct CoordinatorStats {
    /// Jobs submitted.
    pub submitted: u64,
    /// Jobs answered from cache.
    pub cache_hits: u64,
    /// Total optimizer time across executed jobs.
    pub total_opt_time: std::time::Duration,
}

/// The compile coordinator (thread-safe; cheap to clone).
#[derive(Clone, Default)]
pub struct Coordinator {
    inner: Arc<Mutex<Inner>>,
}

#[derive(Default)]
struct Inner {
    cache: FxHashMap<u64, Arc<CmvmSolution>>,
    stats: CoordinatorStats,
}

fn job_key(problem: &CmvmProblem, strategy: Strategy) -> u64 {
    let mut h = rustc_hash::FxHasher::default();
    problem.d_in.hash(&mut h);
    problem.d_out.hash(&mut h);
    problem.matrix.hash(&mut h);
    problem.input_depth.hash(&mut h);
    for q in &problem.input_qint {
        q.min.hash(&mut h);
        q.max.hash(&mut h);
        q.exp.hash(&mut h);
    }
    format!("{strategy:?}").hash(&mut h);
    h.finish()
}

impl Coordinator {
    /// Create an empty coordinator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Compile one job (synchronous; cache-aware).
    pub fn compile(&self, job: &CompileJob) -> Arc<CmvmSolution> {
        let key = job_key(&job.problem, job.strategy);
        {
            let mut inner = self.inner.lock().unwrap();
            inner.stats.submitted += 1;
            if let Some(sol) = inner.cache.get(&key).cloned() {
                inner.stats.cache_hits += 1;
                return sol;
            }
        }
        let sol = Arc::new(optimize(&job.problem, job.strategy));
        let mut inner = self.inner.lock().unwrap();
        inner.stats.total_opt_time += sol.opt_time;
        inner.cache.entry(key).or_insert_with(|| sol.clone());
        sol
    }

    /// Compile a batch concurrently on a scoped worker pool, preserving
    /// job order in the result.
    pub fn compile_many(&self, jobs: Vec<CompileJob>) -> Result<Vec<Arc<CmvmSolution>>> {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Ok(crate::util::parallel_map(jobs, threads, |job| self.compile(&job)))
    }

    /// Snapshot the statistics.
    pub fn stats(&self) -> CoordinatorStats {
        self.inner.lock().unwrap().stats
    }

    /// Number of distinct cached solutions.
    pub fn cache_len(&self) -> usize {
        self.inner.lock().unwrap().cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn job(seed: u64) -> CompileJob {
        let mut rng = Rng::seed_from(seed);
        let m: Vec<i64> = (0..16).map(|_| rng.range_i64(-127, 127)).collect();
        CompileJob {
            name: format!("job{seed}"),
            problem: CmvmProblem::new(4, 4, m, 8),
            strategy: Strategy::Da { dc: 2 },
        }
    }

    #[test]
    fn cache_dedups_identical_jobs() {
        let c = Coordinator::new();
        let j = job(1);
        let a = c.compile(&j);
        let b = c.compile(&j);
        assert!(Arc::ptr_eq(&a, &b));
        let s = c.stats();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.cache_hits, 1);
        assert_eq!(c.cache_len(), 1);
    }

    #[test]
    fn different_strategy_different_entry() {
        let c = Coordinator::new();
        let mut j = job(2);
        c.compile(&j);
        j.strategy = Strategy::Da { dc: 0 };
        c.compile(&j);
        assert_eq!(c.cache_len(), 2);
        assert_eq!(c.stats().cache_hits, 0);
    }

    #[test]
    fn batch_compile_order_preserved() {
        let c = Coordinator::new();
        let jobs: Vec<CompileJob> = (0..6).map(job).collect();
        let adders_direct: Vec<usize> =
            jobs.iter().map(|j| c.compile(j).adders).collect();
        let sols = c.compile_many(jobs).unwrap();
        let adders_batch: Vec<usize> = sols.iter().map(|s| s.adders).collect();
        assert_eq!(adders_direct, adders_batch);
        // Every batch job was a cache hit.
        assert_eq!(c.stats().cache_hits as usize, 6);
    }
}
