//! The L3 compile-job coordinator.
//!
//! The paper's contribution is a compiler, so the coordinator here is a
//! *compilation service*: it takes batches of CMVM jobs (one per network
//! layer / template), deduplicates them through a solution cache (the
//! same constant matrix frequently recurs — e.g. conv kernels shared
//! across positions or re-synthesized quantization sweeps), executes
//! them on a scoped worker pool, and aggregates solution statistics.
//! The CLI (`rust/src/main.rs`) and the benches drive everything through
//! this interface.
//!
//! The cache is keyed on the **full job identity** — matrix, dims,
//! input intervals, input depths and strategy — not on a bare 64-bit
//! hash, so hash collisions can never alias one layer's adder graph to
//! another's (cache poisoning). The hasher is pluggable (FxHash by
//! default) which lets the tests force total collisions and prove the
//! full-key equality path.
//!
//! The long-lived JSONL compile service ([`crate::serve`]) drives
//! batches through [`Coordinator::compile_batch`], which reports the
//! per-job cache-hit flag the streamed replies expose. For long-lived
//! deployments the cache can be bounded
//! ([`Coordinator::with_cache_cap`] / `serve --cache-cap`): past the
//! cap, least-recently-used solutions are evicted (counted in
//! [`CoordinatorStats::evictions`]); the default stays unbounded.
//!
//! ```
//! use da4ml::cmvm::{CmvmProblem, Strategy};
//! use da4ml::coordinator::{CompileJob, Coordinator};
//!
//! let coord = Coordinator::new();
//! let job = CompileJob {
//!     name: "layer0".into(),
//!     problem: CmvmProblem::new(2, 2, vec![3, 5, -7, 9], 8),
//!     strategy: Strategy::Da { dc: -1 },
//! };
//! let (first, hit) = coord.compile_cached(&job).unwrap();
//! assert!(!hit);
//! let (again, hit) = coord.compile_cached(&job).unwrap();
//! assert!(hit);
//! assert_eq!(first.adders, again.adders);
//! assert_eq!(coord.stats().cache_hits, 1);
//! ```

use crate::cmvm::{optimize, CmvmProblem, CmvmSolution, Strategy};
use crate::fixed::QInterval;
use crate::util::fxhash::FxBuildHasher;
use crate::Result;
use std::collections::HashMap;
use std::hash::BuildHasher;
use std::sync::{Arc, Mutex};

/// One compilation request.
#[derive(Debug, Clone)]
pub struct CompileJob {
    /// Stable name for reporting.
    pub name: String,
    /// The CMVM to optimize.
    pub problem: CmvmProblem,
    /// Strategy to apply.
    pub strategy: Strategy,
}

/// Aggregated coordinator statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct CoordinatorStats {
    /// Jobs submitted.
    pub submitted: u64,
    /// Jobs answered from cache.
    pub cache_hits: u64,
    /// Total optimizer time across executed jobs.
    pub total_opt_time: std::time::Duration,
    /// CSE update steps across executed (non-cached) jobs.
    pub total_cse_steps: u64,
    /// Optimizer heap pops across executed jobs — the work proxy the
    /// perf suite tracks; cache hits add nothing here.
    pub total_heap_pops: u64,
    /// Cached solutions evicted to honor the cache cap (always 0 for
    /// the default unbounded cache).
    pub evictions: u64,
}

/// The full identity of a compile job — everything that affects the
/// produced adder graph. Used as the cache key so equal hashes of
/// *different* jobs can never return the wrong solution.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct JobKey {
    d_in: usize,
    d_out: usize,
    matrix: Vec<i64>,
    input_qint: Vec<QInterval>,
    input_depth: Vec<u32>,
    strategy: Strategy,
}

fn job_key(problem: &CmvmProblem, strategy: Strategy) -> JobKey {
    JobKey {
        d_in: problem.d_in,
        d_out: problem.d_out,
        matrix: problem.matrix.clone(),
        input_qint: problem.input_qint.clone(),
        input_depth: problem.input_depth.clone(),
        strategy,
    }
}

/// Remove the least-recently-used cache entry. The `last_used` stamps
/// are unique (one tick per access under the lock), so the victim is
/// deterministic regardless of hash-map iteration order. Returns
/// `false` on an empty cache.
///
/// Deliberately a linear scan: it costs O(cache_len) per eviction
/// under the lock, which is fine for the modest caps serve deployments
/// use (an entry is a whole optimized adder graph — thousands, not
/// millions). A very large cap would want a secondary recency index.
fn evict_lru<S: BuildHasher>(inner: &mut Inner<S>) -> bool {
    let victim = inner
        .cache
        .iter()
        .min_by_key(|(_, e)| e.last_used)
        .map(|(k, _)| k.clone());
    match victim {
        Some(k) => {
            inner.cache.remove(&k);
            inner.stats.evictions += 1;
            true
        }
        None => false,
    }
}

/// The compile coordinator (thread-safe; cheap to clone). Generic over
/// the cache hasher — production code uses the FxHash default.
pub struct Coordinator<S = FxBuildHasher> {
    inner: Arc<Mutex<Inner<S>>>,
}

/// One cached solution plus its recency stamp (for capped caches).
struct CacheEntry {
    sol: Arc<CmvmSolution>,
    last_used: u64,
}

struct Inner<S> {
    cache: HashMap<JobKey, CacheEntry, S>,
    stats: CoordinatorStats,
    /// Maximum cached entries (`None` = unbounded, the default —
    /// preserves the pre-cap behavior exactly).
    cap: Option<usize>,
    /// Monotone access clock; every `compile_cached` call gets a fresh
    /// tick under the lock, so `last_used` stamps are unique.
    tick: u64,
}

impl<S> Clone for Coordinator<S> {
    fn clone(&self) -> Self {
        Self { inner: Arc::clone(&self.inner) }
    }
}

impl<S: BuildHasher + Default> Default for Coordinator<S> {
    fn default() -> Self {
        Self {
            inner: Arc::new(Mutex::new(Inner {
                cache: HashMap::with_hasher(S::default()),
                stats: CoordinatorStats::default(),
                cap: None,
                tick: 0,
            })),
        }
    }
}

impl Coordinator<FxBuildHasher> {
    /// Create an empty coordinator with the default (FxHash) cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Create a coordinator whose cache holds at most `cap` solutions
    /// (least-recently-used entries are evicted past the cap; `cap == 0`
    /// disables caching entirely). Long-lived `serve` deployments use
    /// this via `serve --cache-cap`.
    pub fn with_cache_cap(cap: usize) -> Self {
        let c = Self::default();
        c.set_cache_cap(Some(cap));
        c
    }
}

impl<S: BuildHasher + Default> Coordinator<S> {
    /// Compile one job (synchronous; cache-aware).
    pub fn compile(&self, job: &CompileJob) -> Result<Arc<CmvmSolution>> {
        self.compile_cached(job).map(|(sol, _)| sol)
    }

    /// Compile one job, additionally reporting whether the solution was
    /// served from the cache (`true` = no optimizer run for this call).
    ///
    /// Two identical jobs racing through a batch can both report a miss
    /// (both saw the empty slot before either inserted); the cache still
    /// ends up with a single entry.
    pub fn compile_cached(&self, job: &CompileJob) -> Result<(Arc<CmvmSolution>, bool)> {
        let key = job_key(&job.problem, job.strategy);
        {
            let mut inner = self.inner.lock().unwrap();
            inner.stats.submitted += 1;
            inner.tick += 1;
            let tick = inner.tick;
            if let Some(entry) = inner.cache.get_mut(&key) {
                entry.last_used = tick;
                let sol = entry.sol.clone();
                inner.stats.cache_hits += 1;
                return Ok((sol, true));
            }
        }
        let sol = Arc::new(optimize(&job.problem, job.strategy)?);
        let mut inner = self.inner.lock().unwrap();
        inner.stats.total_opt_time += sol.opt_time;
        inner.stats.total_cse_steps += sol.cse.steps as u64;
        inner.stats.total_heap_pops += sol.cse.heap_pops as u64;
        inner.tick += 1;
        let tick = inner.tick;
        match inner.cap {
            Some(0) => {} // caching disabled
            cap => {
                // A racing duplicate may have inserted first; then just
                // refresh its recency and keep the existing entry.
                let raced = match inner.cache.get_mut(&key) {
                    Some(entry) => {
                        entry.last_used = tick;
                        true
                    }
                    None => false,
                };
                if !raced {
                    if let Some(cap) = cap {
                        while inner.cache.len() >= cap {
                            if !evict_lru(&mut inner) {
                                break;
                            }
                        }
                    }
                    inner
                        .cache
                        .insert(key, CacheEntry { sol: sol.clone(), last_used: tick });
                }
            }
        }
        Ok((sol, false))
    }

    /// Bound (or unbound) the solution cache. `Some(cap)` evicts
    /// least-recently-used entries immediately if the cache is already
    /// over the cap; `Some(0)` disables caching; `None` (the default)
    /// is unbounded. Eviction only drops cached solutions — the
    /// hit/miss statistics are never rewritten.
    pub fn set_cache_cap(&self, cap: Option<usize>) {
        let mut inner = self.inner.lock().unwrap();
        inner.cap = cap;
        if let Some(cap) = cap {
            while inner.cache.len() > cap {
                if !evict_lru(&mut inner) {
                    break;
                }
            }
        }
    }

    /// Compile a batch concurrently on a scoped worker pool, preserving
    /// job order in the result; the first failing job aborts the batch.
    pub fn compile_many(&self, jobs: Vec<CompileJob>) -> Result<Vec<Arc<CmvmSolution>>>
    where
        S: Send,
    {
        self.compile_batch(jobs, 0).into_iter().map(|r| r.map(|(sol, _)| sol)).collect()
    }

    /// Compile a batch concurrently, returning **per-job** results with
    /// the cache-hit flag, in job order. Unlike
    /// [`Coordinator::compile_many`], one failing job does not abort the
    /// batch — the serve loop turns individual failures into JSONL error
    /// replies while the rest of the batch proceeds.
    ///
    /// `threads == 0` selects the available hardware parallelism.
    pub fn compile_batch(
        &self,
        jobs: Vec<CompileJob>,
        threads: usize,
    ) -> Vec<Result<(Arc<CmvmSolution>, bool)>>
    where
        S: Send,
    {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        crate::util::parallel_map(jobs, threads, |job| self.compile_cached(&job))
    }

    /// Snapshot the statistics.
    pub fn stats(&self) -> CoordinatorStats {
        self.inner.lock().unwrap().stats
    }

    /// Number of distinct cached solutions.
    pub fn cache_len(&self) -> usize {
        self.inner.lock().unwrap().cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dais::verify;
    use crate::util::Rng;
    use std::hash::Hasher;

    fn job(seed: u64) -> CompileJob {
        let mut rng = Rng::seed_from(seed);
        let m: Vec<i64> = (0..16).map(|_| rng.range_i64(-127, 127)).collect();
        CompileJob {
            name: format!("job{seed}"),
            problem: CmvmProblem::new(4, 4, m, 8),
            strategy: Strategy::Da { dc: 2 },
        }
    }

    #[test]
    fn cache_dedups_identical_jobs() {
        let c = Coordinator::new();
        let j = job(1);
        let a = c.compile(&j).unwrap();
        let b = c.compile(&j).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let s = c.stats();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.cache_hits, 1);
        assert_eq!(c.cache_len(), 1);
        // Optimizer work counters accumulate once per *executed* job;
        // the cached reply added nothing.
        assert_eq!(s.total_cse_steps, a.cse.steps as u64);
        assert_eq!(s.total_heap_pops, a.cse.heap_pops as u64);
    }

    #[test]
    fn different_strategy_different_entry() {
        let c = Coordinator::new();
        let mut j = job(2);
        c.compile(&j).unwrap();
        j.strategy = Strategy::Da { dc: 0 };
        c.compile(&j).unwrap();
        assert_eq!(c.cache_len(), 2);
        assert_eq!(c.stats().cache_hits, 0);
    }

    #[test]
    fn different_qint_or_depth_different_entry() {
        let c = Coordinator::new();
        let j = job(3);
        c.compile(&j).unwrap();
        let mut j2 = j.clone();
        j2.problem.input_qint = vec![QInterval::new(0, 15, 0); 4];
        c.compile(&j2).unwrap();
        let mut j3 = j.clone();
        j3.problem.input_depth = vec![1; 4];
        c.compile(&j3).unwrap();
        assert_eq!(c.cache_len(), 3);
        assert_eq!(c.stats().cache_hits, 0);
    }

    #[test]
    fn batch_compile_order_preserved() {
        let c = Coordinator::new();
        let jobs: Vec<CompileJob> = (0..6).map(job).collect();
        let adders_direct: Vec<usize> =
            jobs.iter().map(|j| c.compile(j).unwrap().adders).collect();
        let sols = c.compile_many(jobs).unwrap();
        let adders_batch: Vec<usize> = sols.iter().map(|s| s.adders).collect();
        assert_eq!(adders_direct, adders_batch);
        // Every batch job was a cache hit.
        assert_eq!(c.stats().cache_hits as usize, 6);
    }

    #[test]
    fn compile_batch_reports_per_job_cache_hits() {
        let c = Coordinator::new();
        // Jobs 0 and 2 are identical; job 1 differs.
        let jobs = vec![job(20), job(21), job(20)];
        let first = c.compile_batch(jobs.clone(), 2);
        assert_eq!(first.len(), 3);
        let flags: Vec<bool> = first.iter().map(|r| r.as_ref().unwrap().1).collect();
        // The duplicate pair may race (both miss) but never yields more
        // than one cached entry per distinct key.
        assert!(!flags[1], "distinct job can never be a hit in a cold cache");
        assert_eq!(c.cache_len(), 2);
        // A warm re-run is all hits, order preserved.
        let again = c.compile_batch(jobs, 0);
        for (a, b) in first.iter().zip(&again) {
            let (sa, _) = a.as_ref().unwrap();
            let (sb, hit) = b.as_ref().unwrap();
            assert!(*hit);
            assert!(Arc::ptr_eq(sa, sb) || sa.adders == sb.adders);
        }
    }

    /// A hasher that maps *every* key to the same bucket, simulating
    /// worst-case hash collisions.
    struct CollidingHasher;

    impl Hasher for CollidingHasher {
        fn finish(&self) -> u64 {
            0
        }
        fn write(&mut self, _bytes: &[u8]) {}
    }

    #[derive(Default)]
    struct CollidingBuildHasher;

    impl std::hash::BuildHasher for CollidingBuildHasher {
        type Hasher = CollidingHasher;
        fn build_hasher(&self) -> CollidingHasher {
            CollidingHasher
        }
    }

    /// A capped cache evicts the least-recently-used entry, and
    /// eviction only drops solutions — submitted/hit/miss accounting
    /// stays exact across evictions and re-compiles.
    #[test]
    fn cache_cap_evicts_lru_without_corrupting_stats() {
        let c = Coordinator::with_cache_cap(2);
        let (j0, j1, j2) = (job(30), job(31), job(32));
        c.compile(&j0).unwrap(); // cache: {j0}
        c.compile(&j1).unwrap(); // cache: {j0, j1}
        c.compile(&j0).unwrap(); // hit — j0 becomes most recent
        c.compile(&j2).unwrap(); // evicts j1 (the LRU entry)
        let s = c.stats();
        assert_eq!(c.cache_len(), 2);
        assert_eq!(s.evictions, 1);
        assert_eq!(s.submitted, 4);
        assert_eq!(s.cache_hits, 1);
        // j0 survived (recently used) …
        let (_, hit) = c.compile_cached(&j0).unwrap();
        assert!(hit, "recently used entry must survive eviction");
        // … while j1 was evicted: a miss that re-optimizes and in turn
        // evicts the new LRU (j2).
        let (_, hit) = c.compile_cached(&j1).unwrap();
        assert!(!hit, "evicted entry must be a miss");
        let s = c.stats();
        assert_eq!(s.submitted, 6);
        assert_eq!(s.cache_hits, 2);
        assert_eq!(s.evictions, 2);
        assert_eq!(c.cache_len(), 2);
    }

    #[test]
    fn zero_cap_disables_caching() {
        let c = Coordinator::with_cache_cap(0);
        let j = job(33);
        c.compile(&j).unwrap();
        c.compile(&j).unwrap();
        assert_eq!(c.cache_len(), 0);
        let s = c.stats();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.cache_hits, 0);
        assert_eq!(s.evictions, 0);
    }

    #[test]
    fn shrinking_the_cap_evicts_immediately() {
        let c = Coordinator::new();
        for seed in 40..44 {
            c.compile(&job(seed)).unwrap();
        }
        assert_eq!(c.cache_len(), 4);
        c.set_cache_cap(Some(2));
        assert_eq!(c.cache_len(), 2);
        assert_eq!(c.stats().evictions, 2);
        // The two most recently inserted entries survive.
        let (_, hit) = c.compile_cached(&job(43)).unwrap();
        assert!(hit);
        let (_, hit) = c.compile_cached(&job(42)).unwrap();
        assert!(hit);
    }

    /// Regression for the cache-poisoning bug: with the old bare-u64
    /// cache key, two jobs whose hashes collide returned the *first*
    /// job's adder graph for the second job. Full-key equality must
    /// disambiguate even when every hash collides.
    #[test]
    fn hash_collisions_never_alias_solutions() {
        let c: Coordinator<CollidingBuildHasher> = Coordinator::default();
        let j1 = job(10);
        let j2 = job(11);
        assert_ne!(j1.problem.matrix, j2.problem.matrix, "test needs distinct jobs");
        let s1 = c.compile(&j1).unwrap();
        let s2 = c.compile(&j2).unwrap();
        // Both cached under colliding hashes, as distinct entries.
        assert_eq!(c.cache_len(), 2);
        assert_eq!(c.stats().cache_hits, 0);
        // Each solution is exactly equivalent to its *own* matrix.
        verify::check_cmvm_equivalence(&s1.program, &j1.problem.matrix, 4, 4).unwrap();
        verify::check_cmvm_equivalence(&s2.program, &j2.problem.matrix, 4, 4).unwrap();
        // Re-compiling hits the correct entries.
        assert!(Arc::ptr_eq(&c.compile(&j1).unwrap(), &s1));
        assert!(Arc::ptr_eq(&c.compile(&j2).unwrap(), &s2));
        assert_eq!(c.stats().cache_hits, 2);
    }
}
