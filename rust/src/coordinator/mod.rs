//! The L3 compile-job coordinator.
//!
//! The paper's contribution is a compiler, so the coordinator here is a
//! *compilation service*: it takes batches of CMVM jobs (one per network
//! layer / template), deduplicates them through a solution cache (the
//! same constant matrix frequently recurs — e.g. conv kernels shared
//! across positions or re-synthesized quantization sweeps), executes
//! them on a scoped worker pool, and aggregates solution statistics.
//! The CLI (`rust/src/main.rs`) and the benches drive everything through
//! this interface.
//!
//! The cache is keyed on the **full job identity** — matrix, dims,
//! input intervals, input depths and strategy — not on a bare 64-bit
//! hash, so hash collisions can never alias one layer's adder graph to
//! another's (cache poisoning). The hasher is pluggable (FxHash by
//! default) which lets the tests force total collisions and prove the
//! full-key equality path.
//!
//! # Sharding
//!
//! The cache is split into N independent shards selected by the job
//! key's hash ([`Coordinator::with_shards`]); each shard has its own
//! lock, its own LRU recency index, and its own statistics, so
//! concurrent clients of a long-lived service do not contend on one
//! mutex. The default is a single shard, which reproduces the
//! un-sharded coordinator exactly — including its eviction order.
//! [`Coordinator::stats`] merges the shard-local counters in shard
//! order into one deterministic [`CoordinatorStats`] view; solutions
//! are identical under any shard count because the optimizer is
//! deterministic and entries never migrate between shards.
//!
//! Per-shard LRU eviction is O(log n): each shard keeps a `BTreeMap`
//! recency index from the unique `last_used` tick to the cached key, so
//! the victim is the first index entry instead of an O(cache_len) scan.
//!
//! # Persistence
//!
//! The full solution cache can be saved to, and warm-started from, a
//! schema-versioned JSON document (see [`persist`] and `docs/cache.md`):
//! [`Coordinator::save_cache`] / [`Coordinator::load_cache`], surfaced
//! as `da4ml cache bake|info|merge` and `serve --cache-load/--cache-save`.
//!
//! The long-lived JSONL compile service ([`crate::serve`]) drives
//! batches through [`Coordinator::compile_batch`], which reports the
//! per-job cache-hit flag the streamed replies expose. The concurrent
//! socket server ([`crate::serve::server`]) is the scenario sharding
//! was built for: one `Arc<Coordinator>` shared by a worker pool
//! serving many client connections at once, where one client's
//! compile warms the cache for every other client and shard-local
//! locks keep the warm path contention-free. For long-lived
//! deployments the cache can be bounded
//! ([`Coordinator::with_cache_cap`] / `serve --cache-cap`): past the
//! cap, least-recently-used solutions are evicted (counted in
//! [`CoordinatorStats::evictions`]); the default stays unbounded.
//!
//! ```
//! use da4ml::cmvm::{CmvmProblem, Strategy};
//! use da4ml::coordinator::{CompileJob, Coordinator};
//!
//! let coord = Coordinator::new();
//! let job = CompileJob {
//!     name: "layer0".into(),
//!     problem: CmvmProblem::new(2, 2, vec![3, 5, -7, 9], 8).unwrap(),
//!     strategy: Strategy::Da { dc: -1 },
//! };
//! let (first, hit) = coord.compile_cached(&job).unwrap();
//! assert!(!hit);
//! let (again, hit) = coord.compile_cached(&job).unwrap();
//! assert!(hit);
//! assert_eq!(first.adders, again.adders);
//! assert_eq!(coord.stats().cache_hits, 1);
//! ```

pub mod persist;

use crate::cmvm::{self, CmvmProblem, CmvmSolution, OptimizeOptions, Strategy};
use crate::fixed::QInterval;
use crate::util::fxhash::FxBuildHasher;
use crate::Result;
use std::collections::{BTreeMap, HashMap};
use std::hash::{BuildHasher, Hash, Hasher};
use std::sync::{Arc, Mutex};

/// One compilation request.
#[derive(Debug, Clone)]
pub struct CompileJob {
    /// Stable name for reporting.
    pub name: String,
    /// The CMVM to optimize.
    pub problem: CmvmProblem,
    /// Strategy to apply.
    pub strategy: Strategy,
}

/// Aggregated coordinator statistics.
///
/// Under sharding each shard accumulates its own copy;
/// [`Coordinator::stats`] merges them (in shard order) with
/// [`CoordinatorStats::merge`], so the global view stays exact — every
/// counter is attributed to exactly one shard.
#[derive(Debug, Clone, Copy, Default)]
pub struct CoordinatorStats {
    /// Jobs submitted.
    pub submitted: u64,
    /// Jobs answered from cache.
    pub cache_hits: u64,
    /// Total optimizer time across executed jobs.
    pub total_opt_time: std::time::Duration,
    /// CSE update steps across executed (non-cached) jobs.
    pub total_cse_steps: u64,
    /// Optimizer heap pops across executed jobs — the work proxy the
    /// perf suite tracks; cache hits add nothing here.
    pub total_heap_pops: u64,
    /// Cached solutions evicted to honor the cache cap (always 0 for
    /// the default unbounded cache).
    pub evictions: u64,
    /// Solutions warm-started from a persisted cache file
    /// ([`Coordinator::load_cache`]); 0 for caches built purely in
    /// memory. Loads are not `submitted` jobs and never count as hits.
    pub loaded: u64,
}

impl CoordinatorStats {
    /// Accumulate another stats snapshot (used to fold the shard-local
    /// counters into the global view; every field is a plain sum).
    pub fn merge(&mut self, other: &CoordinatorStats) {
        self.submitted += other.submitted;
        self.cache_hits += other.cache_hits;
        self.total_opt_time += other.total_opt_time;
        self.total_cse_steps += other.total_cse_steps;
        self.total_heap_pops += other.total_heap_pops;
        self.evictions += other.evictions;
        self.loaded += other.loaded;
    }
}

/// The full identity of a compile job — everything that affects the
/// produced adder graph. Used as the cache key so equal hashes of
/// *different* jobs can never return the wrong solution. The `Ord` is
/// the canonical entry order of persisted cache files.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
struct JobKey {
    d_in: usize,
    d_out: usize,
    matrix: Vec<i64>,
    input_qint: Vec<QInterval>,
    input_depth: Vec<u32>,
    strategy: Strategy,
}

fn job_key(problem: &CmvmProblem, strategy: Strategy) -> JobKey {
    JobKey {
        d_in: problem.d_in,
        d_out: problem.d_out,
        matrix: problem.matrix.clone(),
        input_qint: problem.input_qint.clone(),
        input_depth: problem.input_depth.clone(),
        strategy,
    }
}

/// The compile coordinator (thread-safe; cheap to clone). Generic over
/// the cache hasher — production code uses the FxHash default. The
/// hasher doubles as the shard router, so a colliding hasher degrades
/// to one active shard but can never alias solutions.
pub struct Coordinator<S = FxBuildHasher> {
    inner: Arc<Inner<S>>,
}

/// One cached solution plus its recency stamp (for capped caches).
struct CacheEntry {
    sol: Arc<CmvmSolution>,
    last_used: u64,
}

/// Per-shard observability handles in the global metrics registry
/// (`coordinator.shard.<i>.*`). These are an additive side channel for
/// the `obs` snapshot — [`CoordinatorStats`] stays the accounting
/// source of truth and is never derived from them.
struct ShardObs {
    hits: crate::obs::Counter,
    misses: crate::obs::Counter,
    evictions: crate::obs::Counter,
    /// Time spent waiting on this shard's lock (only recorded while
    /// tracing is enabled — the clock read is the cost being gated).
    lock_wait_us: crate::obs::Histogram,
}

impl ShardObs {
    fn new(index: usize) -> Self {
        let reg = crate::obs::metrics();
        ShardObs {
            hits: reg.counter(&format!("coordinator.shard.{index}.hits")),
            misses: reg.counter(&format!("coordinator.shard.{index}.misses")),
            evictions: reg.counter(&format!("coordinator.shard.{index}.evictions")),
            lock_wait_us: reg.histogram(&format!("coordinator.shard.{index}.lock_wait_us")),
        }
    }
}

/// One cache shard: entries, the recency index, and shard-local stats,
/// all behind a single shard lock. The key is `Arc`-shared between the
/// entry map and the recency index so the two stay one allocation.
struct Shard<S> {
    cache: HashMap<Arc<JobKey>, CacheEntry, S>,
    /// Recency index: `last_used` tick -> cached key. Ticks are unique
    /// within a shard (one per access under the shard lock), so this is
    /// a total order and the first entry is always the LRU victim.
    by_tick: BTreeMap<u64, Arc<JobKey>>,
    stats: CoordinatorStats,
    /// Maximum cached entries in *this shard* (`None` = unbounded, the
    /// default). A global cap is split evenly across shards.
    cap: Option<usize>,
    /// Monotone access clock; every `compile_cached` call gets a fresh
    /// tick under the lock, so `last_used` stamps are unique.
    tick: u64,
    /// Metrics-registry handles for this shard.
    obs: ShardObs,
}

impl<S: BuildHasher> Shard<S> {
    /// Remove the least-recently-used entry: the first entry of the
    /// recency index, O(log n). The `last_used` stamps are unique, so
    /// the victim is deterministic regardless of hash-map iteration
    /// order (and identical to what a linear `min_by_key` scan over
    /// `last_used` would pick). Returns `false` on an empty shard.
    fn evict_lru(&mut self) -> bool {
        let oldest = match self.by_tick.keys().next() {
            Some(&t) => t,
            None => return false,
        };
        let key = self.by_tick.remove(&oldest).expect("tick observed in index");
        self.cache.remove(key.as_ref());
        self.stats.evictions += 1;
        self.obs.evictions.inc();
        true
    }

    /// Move a key's recency-index entry from tick `prev` to `tick`
    /// (the entry map's `last_used` is updated by the caller).
    fn retick(&mut self, prev: u64, tick: u64) {
        let key = self.by_tick.remove(&prev).expect("recency index out of sync");
        self.by_tick.insert(tick, key);
    }

    /// Insert a new entry (the key must be absent and caching enabled),
    /// evicting down to the shard cap first.
    fn insert_new(&mut self, key: JobKey, sol: Arc<CmvmSolution>, tick: u64) {
        if let Some(cap) = self.cap {
            while self.cache.len() >= cap {
                if !self.evict_lru() {
                    break;
                }
            }
        }
        let key = Arc::new(key);
        self.by_tick.insert(tick, Arc::clone(&key));
        self.cache.insert(key, CacheEntry { sol, last_used: tick });
    }
}

struct Inner<S> {
    /// Shard router: hashes the full job key (same hasher family as the
    /// shard maps) to pick a shard. With one shard no hash is computed.
    router: S,
    shards: Vec<Mutex<Shard<S>>>,
}

impl<S: BuildHasher> Inner<S> {
    fn shard_index(&self, key: &JobKey) -> usize {
        if self.shards.len() == 1 {
            return 0;
        }
        let mut h = self.router.build_hasher();
        key.hash(&mut h);
        (h.finish() % self.shards.len() as u64) as usize
    }
}

impl<S> Clone for Coordinator<S> {
    fn clone(&self) -> Self {
        Self { inner: Arc::clone(&self.inner) }
    }
}

impl<S: BuildHasher + Default> Default for Coordinator<S> {
    fn default() -> Self {
        Self::sharded(1)
    }
}

impl Coordinator<FxBuildHasher> {
    /// Create an empty coordinator with the default (FxHash) cache and
    /// a single shard (the legacy-exact configuration).
    pub fn new() -> Self {
        Self::default()
    }

    /// Create a coordinator whose cache is split into `shards`
    /// independent shards (clamped to at least 1). Long-lived `serve`
    /// deployments use this via `serve --cache-shards` to take mutex
    /// contention off the compile hot path.
    pub fn with_shards(shards: usize) -> Self {
        Self::sharded(shards)
    }

    /// Create a coordinator whose cache holds at most `cap` solutions
    /// (least-recently-used entries are evicted past the cap; `cap == 0`
    /// disables caching entirely). Long-lived `serve` deployments use
    /// this via `serve --cache-cap`.
    pub fn with_cache_cap(cap: usize) -> Self {
        let c = Self::default();
        c.set_cache_cap(Some(cap));
        c
    }
}

impl<S: BuildHasher + Default> Coordinator<S> {
    /// Create an empty coordinator with `shards` cache shards (clamped
    /// to at least 1) and the hasher's default state. `sharded(1)` is
    /// exactly the historical single-lock coordinator.
    pub fn sharded(shards: usize) -> Self {
        let shards = shards.max(1);
        let shards = (0..shards)
            .map(|i| {
                Mutex::new(Shard {
                    cache: HashMap::with_hasher(S::default()),
                    by_tick: BTreeMap::new(),
                    stats: CoordinatorStats::default(),
                    cap: None,
                    tick: 0,
                    obs: ShardObs::new(i),
                })
            })
            .collect();
        Self { inner: Arc::new(Inner { router: S::default(), shards }) }
    }

    /// Compile one job (synchronous; cache-aware).
    pub fn compile(&self, job: &CompileJob) -> Result<Arc<CmvmSolution>> {
        self.compile_cached(job).map(|(sol, _)| sol)
    }

    /// Compile one job, additionally reporting whether the solution was
    /// served from the cache (`true` = no optimizer run for this call).
    ///
    /// Two identical jobs racing through a batch can both report a miss
    /// (both saw the empty slot before either inserted); the cache still
    /// ends up with a single entry.
    pub fn compile_cached(&self, job: &CompileJob) -> Result<(Arc<CmvmSolution>, bool)> {
        let key = job_key(&job.problem, job.strategy);
        let idx = self.inner.shard_index(&key);
        {
            // Clock reads are the gated cost: lock-wait is only timed
            // while tracing is on; the hit/miss counters below are plain
            // relaxed atomics and stay on unconditionally.
            let lock_t0 = crate::obs::enabled().then(std::time::Instant::now);
            let mut shard = self.inner.shards[idx].lock().unwrap();
            if let Some(t0) = lock_t0 {
                shard.obs.lock_wait_us.record(t0.elapsed().as_micros() as u64);
            }
            shard.stats.submitted += 1;
            shard.tick += 1;
            let tick = shard.tick;
            let hit = shard.cache.get_mut(&key).map(|entry| {
                let prev = entry.last_used;
                entry.last_used = tick;
                (prev, Arc::clone(&entry.sol))
            });
            if let Some((prev, sol)) = hit {
                shard.retick(prev, tick);
                shard.stats.cache_hits += 1;
                shard.obs.hits.inc();
                return Ok((sol, true));
            }
            shard.obs.misses.inc();
        }
        // Thread-local arena: each worker thread reuses its engine and
        // builder slabs across jobs instead of reallocating per compile.
        let sol = Arc::new(cmvm::compile(&job.problem, &OptimizeOptions::new(job.strategy))?);
        let lock_t0 = crate::obs::enabled().then(std::time::Instant::now);
        let mut shard = self.inner.shards[idx].lock().unwrap();
        if let Some(t0) = lock_t0 {
            shard.obs.lock_wait_us.record(t0.elapsed().as_micros() as u64);
        }
        shard.stats.total_opt_time += sol.opt_time;
        shard.stats.total_cse_steps += sol.cse.steps as u64;
        shard.stats.total_heap_pops += sol.cse.heap_pops as u64;
        shard.tick += 1;
        let tick = shard.tick;
        if shard.cap != Some(0) {
            // A racing duplicate may have inserted first; then just
            // refresh its recency and keep the existing entry.
            let raced = shard.cache.get_mut(&key).map(|entry| {
                let prev = entry.last_used;
                entry.last_used = tick;
                prev
            });
            match raced {
                Some(prev) => shard.retick(prev, tick),
                None => shard.insert_new(key, Arc::clone(&sol), tick),
            }
        }
        Ok((sol, false))
    }

    /// Bound (or unbound) the solution cache. `Some(cap)` evicts
    /// least-recently-used entries immediately if the cache is already
    /// over the cap; `Some(0)` disables caching; `None` (the default)
    /// is unbounded. Eviction only drops cached solutions — the
    /// hit/miss statistics are never rewritten.
    ///
    /// Under sharding the cap is split evenly: each of the N shards
    /// holds at most `ceil(cap / N)` entries and evicts by its own
    /// recency order, so the global entry count stays within
    /// `cap` rounded up to a multiple of N. With one shard this is the
    /// historical global LRU exactly.
    pub fn set_cache_cap(&self, cap: Option<usize>) {
        let n = self.inner.shards.len();
        let per_shard = cap.map(|c| if c == 0 { 0 } else { (c + n - 1) / n });
        for shard in &self.inner.shards {
            let mut shard = shard.lock().unwrap();
            shard.cap = per_shard;
            if let Some(cap) = per_shard {
                while shard.cache.len() > cap {
                    if !shard.evict_lru() {
                        break;
                    }
                }
            }
        }
    }

    /// Compile a batch concurrently on a scoped worker pool, preserving
    /// job order in the result; the first failing job aborts the batch.
    pub fn compile_many(&self, jobs: Vec<CompileJob>) -> Result<Vec<Arc<CmvmSolution>>>
    where
        S: Send + Sync,
    {
        self.compile_batch(jobs, 0).into_iter().map(|r| r.map(|(sol, _)| sol)).collect()
    }

    /// Compile a batch concurrently, returning **per-job** results with
    /// the cache-hit flag, in job order. Unlike
    /// [`Coordinator::compile_many`], one failing job does not abort the
    /// batch — the serve loop turns individual failures into JSONL error
    /// replies while the rest of the batch proceeds.
    ///
    /// `threads == 0` selects the available hardware parallelism.
    pub fn compile_batch(
        &self,
        jobs: Vec<CompileJob>,
        threads: usize,
    ) -> Vec<Result<(Arc<CmvmSolution>, bool)>>
    where
        S: Send + Sync,
    {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        crate::util::parallel_map(jobs, threads, |job| self.compile_cached(&job))
    }

    /// Snapshot the statistics: the shard-local counters merged in
    /// shard order (every field is a plain sum, so the result is exact
    /// and deterministic for a quiescent coordinator).
    pub fn stats(&self) -> CoordinatorStats {
        let mut total = CoordinatorStats::default();
        for shard in &self.inner.shards {
            total.merge(&shard.lock().unwrap().stats);
        }
        total
    }

    /// Number of distinct cached solutions (summed across shards).
    pub fn cache_len(&self) -> usize {
        self.inner.shards.iter().map(|s| s.lock().unwrap().cache.len()).sum()
    }

    /// Number of cache shards (≥ 1).
    pub fn shard_count(&self) -> usize {
        self.inner.shards.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dais::verify;
    use crate::util::Rng;
    use std::hash::Hasher;

    fn job(seed: u64) -> CompileJob {
        let mut rng = Rng::seed_from(seed);
        let m: Vec<i64> = (0..16).map(|_| rng.range_i64(-127, 127)).collect();
        CompileJob {
            name: format!("job{seed}"),
            problem: CmvmProblem::new(4, 4, m, 8).unwrap(),
            strategy: Strategy::Da { dc: 2 },
        }
    }

    /// Smaller job for the concurrency hammer (2x2 optimizes in
    /// microseconds, so the test stays fast on one core).
    fn small_job(seed: u64) -> CompileJob {
        let mut rng = Rng::seed_from(seed ^ 0xABCD);
        let m: Vec<i64> = (0..4).map(|_| rng.range_i64(-127, 127)).collect();
        CompileJob {
            name: format!("small{seed}"),
            problem: CmvmProblem::new(2, 2, m, 8).unwrap(),
            strategy: Strategy::Da { dc: -1 },
        }
    }

    #[test]
    fn cache_dedups_identical_jobs() {
        let c = Coordinator::new();
        let j = job(1);
        let a = c.compile(&j).unwrap();
        let b = c.compile(&j).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let s = c.stats();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.cache_hits, 1);
        assert_eq!(c.cache_len(), 1);
        // Optimizer work counters accumulate once per *executed* job;
        // the cached reply added nothing.
        assert_eq!(s.total_cse_steps, a.cse.steps as u64);
        assert_eq!(s.total_heap_pops, a.cse.heap_pops as u64);
    }

    #[test]
    fn different_strategy_different_entry() {
        let c = Coordinator::new();
        let mut j = job(2);
        c.compile(&j).unwrap();
        j.strategy = Strategy::Da { dc: 0 };
        c.compile(&j).unwrap();
        assert_eq!(c.cache_len(), 2);
        assert_eq!(c.stats().cache_hits, 0);
    }

    #[test]
    fn different_qint_or_depth_different_entry() {
        let c = Coordinator::new();
        let j = job(3);
        c.compile(&j).unwrap();
        let mut j2 = j.clone();
        j2.problem.input_qint = vec![QInterval::new(0, 15, 0); 4];
        c.compile(&j2).unwrap();
        let mut j3 = j.clone();
        j3.problem.input_depth = vec![1; 4];
        c.compile(&j3).unwrap();
        assert_eq!(c.cache_len(), 3);
        assert_eq!(c.stats().cache_hits, 0);
    }

    #[test]
    fn batch_compile_order_preserved() {
        let c = Coordinator::new();
        let jobs: Vec<CompileJob> = (0..6).map(job).collect();
        let adders_direct: Vec<usize> =
            jobs.iter().map(|j| c.compile(j).unwrap().adders).collect();
        let sols = c.compile_many(jobs).unwrap();
        let adders_batch: Vec<usize> = sols.iter().map(|s| s.adders).collect();
        assert_eq!(adders_direct, adders_batch);
        // Every batch job was a cache hit.
        assert_eq!(c.stats().cache_hits as usize, 6);
    }

    #[test]
    fn compile_batch_reports_per_job_cache_hits() {
        let c = Coordinator::new();
        // Jobs 0 and 2 are identical; job 1 differs.
        let jobs = vec![job(20), job(21), job(20)];
        let first = c.compile_batch(jobs.clone(), 2);
        assert_eq!(first.len(), 3);
        let flags: Vec<bool> = first.iter().map(|r| r.as_ref().unwrap().1).collect();
        // The duplicate pair may race (both miss) but never yields more
        // than one cached entry per distinct key.
        assert!(!flags[1], "distinct job can never be a hit in a cold cache");
        assert_eq!(c.cache_len(), 2);
        // A warm re-run is all hits, order preserved.
        let again = c.compile_batch(jobs, 0);
        for (a, b) in first.iter().zip(&again) {
            let (sa, _) = a.as_ref().unwrap();
            let (sb, hit) = b.as_ref().unwrap();
            assert!(*hit);
            assert!(Arc::ptr_eq(sa, sb) || sa.adders == sb.adders);
        }
    }

    /// A hasher that maps *every* key to the same bucket, simulating
    /// worst-case hash collisions.
    struct CollidingHasher;

    impl Hasher for CollidingHasher {
        fn finish(&self) -> u64 {
            0
        }
        fn write(&mut self, _bytes: &[u8]) {}
    }

    #[derive(Default)]
    struct CollidingBuildHasher;

    impl std::hash::BuildHasher for CollidingBuildHasher {
        type Hasher = CollidingHasher;
        fn build_hasher(&self) -> CollidingHasher {
            CollidingHasher
        }
    }

    /// A capped cache evicts the least-recently-used entry, and
    /// eviction only drops solutions — submitted/hit/miss accounting
    /// stays exact across evictions and re-compiles.
    #[test]
    fn cache_cap_evicts_lru_without_corrupting_stats() {
        let c = Coordinator::with_cache_cap(2);
        let (j0, j1, j2) = (job(30), job(31), job(32));
        c.compile(&j0).unwrap(); // cache: {j0}
        c.compile(&j1).unwrap(); // cache: {j0, j1}
        c.compile(&j0).unwrap(); // hit — j0 becomes most recent
        c.compile(&j2).unwrap(); // evicts j1 (the LRU entry)
        let s = c.stats();
        assert_eq!(c.cache_len(), 2);
        assert_eq!(s.evictions, 1);
        assert_eq!(s.submitted, 4);
        assert_eq!(s.cache_hits, 1);
        // j0 survived (recently used) …
        let (_, hit) = c.compile_cached(&j0).unwrap();
        assert!(hit, "recently used entry must survive eviction");
        // … while j1 was evicted: a miss that re-optimizes and in turn
        // evicts the new LRU (j2).
        let (_, hit) = c.compile_cached(&j1).unwrap();
        assert!(!hit, "evicted entry must be a miss");
        let s = c.stats();
        assert_eq!(s.submitted, 6);
        assert_eq!(s.cache_hits, 2);
        assert_eq!(s.evictions, 2);
        assert_eq!(c.cache_len(), 2);
    }

    #[test]
    fn zero_cap_disables_caching() {
        let c = Coordinator::with_cache_cap(0);
        let j = job(33);
        c.compile(&j).unwrap();
        c.compile(&j).unwrap();
        assert_eq!(c.cache_len(), 0);
        let s = c.stats();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.cache_hits, 0);
        assert_eq!(s.evictions, 0);
    }

    #[test]
    fn shrinking_the_cap_evicts_immediately() {
        let c = Coordinator::new();
        for seed in 40..44 {
            c.compile(&job(seed)).unwrap();
        }
        assert_eq!(c.cache_len(), 4);
        c.set_cache_cap(Some(2));
        assert_eq!(c.cache_len(), 2);
        assert_eq!(c.stats().evictions, 2);
        // The two most recently inserted entries survive.
        let (_, hit) = c.compile_cached(&job(43)).unwrap();
        assert!(hit);
        let (_, hit) = c.compile_cached(&job(42)).unwrap();
        assert!(hit);
    }

    /// Regression for the cache-poisoning bug: with the old bare-u64
    /// cache key, two jobs whose hashes collide returned the *first*
    /// job's adder graph for the second job. Full-key equality must
    /// disambiguate even when every hash collides.
    #[test]
    fn hash_collisions_never_alias_solutions() {
        let c: Coordinator<CollidingBuildHasher> = Coordinator::default();
        let j1 = job(10);
        let j2 = job(11);
        assert_ne!(j1.problem.matrix, j2.problem.matrix, "test needs distinct jobs");
        let s1 = c.compile(&j1).unwrap();
        let s2 = c.compile(&j2).unwrap();
        // Both cached under colliding hashes, as distinct entries.
        assert_eq!(c.cache_len(), 2);
        assert_eq!(c.stats().cache_hits, 0);
        // Each solution is exactly equivalent to its *own* matrix.
        verify::check_cmvm_equivalence(&s1.program, &j1.problem.matrix, 4, 4).unwrap();
        verify::check_cmvm_equivalence(&s2.program, &j2.problem.matrix, 4, 4).unwrap();
        // Re-compiling hits the correct entries.
        assert!(Arc::ptr_eq(&c.compile(&j1).unwrap(), &s1));
        assert!(Arc::ptr_eq(&c.compile(&j2).unwrap(), &s2));
        assert_eq!(c.stats().cache_hits, 2);
    }

    /// A colliding router sends everything to shard 0; sharding must
    /// still never alias solutions (correctness cannot depend on the
    /// hash spreading keys).
    #[test]
    fn colliding_router_with_many_shards_still_correct() {
        let c: Coordinator<CollidingBuildHasher> = Coordinator::sharded(4);
        assert_eq!(c.shard_count(), 4);
        let (j1, j2) = (job(10), job(11));
        let s1 = c.compile(&j1).unwrap();
        let s2 = c.compile(&j2).unwrap();
        assert_eq!(c.cache_len(), 2);
        verify::check_cmvm_equivalence(&s1.program, &j1.problem.matrix, 4, 4).unwrap();
        verify::check_cmvm_equivalence(&s2.program, &j2.problem.matrix, 4, 4).unwrap();
        assert!(c.compile_cached(&j1).unwrap().1);
        assert!(c.compile_cached(&j2).unwrap().1);
    }

    /// Determinism pin: a fixed sequential job sequence produces
    /// bit-identical programs and identical final stats (modulo
    /// wall-clock time) under shards=1 and shards=4.
    #[test]
    fn sharded_matches_single_shard_exactly() {
        // Repeats interleaved with fresh jobs: 0,1,0,2,1,3,0,4,2,5,...
        let seq: Vec<u64> = vec![0, 1, 0, 2, 1, 3, 0, 4, 2, 5, 5, 3, 1, 0, 6, 7, 6, 2];
        let run = |c: &Coordinator| -> (Vec<bool>, Vec<crate::dais::DaisProgram>) {
            let mut hits = Vec::new();
            let mut progs = Vec::new();
            for &s in &seq {
                let (sol, hit) = c.compile_cached(&job(100 + s)).unwrap();
                hits.push(hit);
                progs.push(sol.program.clone());
            }
            (hits, progs)
        };
        let c1 = Coordinator::new();
        let c4 = Coordinator::with_shards(4);
        assert_eq!(c4.shard_count(), 4);
        let (hits1, progs1) = run(&c1);
        let (hits4, progs4) = run(&c4);
        assert_eq!(hits1, hits4, "hit/miss sequence must not depend on shard count");
        assert_eq!(progs1, progs4, "programs must be bit-identical across shard counts");
        let (s1, s4) = (c1.stats(), c4.stats());
        assert_eq!(s1.submitted, s4.submitted);
        assert_eq!(s1.cache_hits, s4.cache_hits);
        assert_eq!(s1.total_cse_steps, s4.total_cse_steps);
        assert_eq!(s1.total_heap_pops, s4.total_heap_pops);
        assert_eq!(s1.evictions, s4.evictions);
        assert_eq!(c1.cache_len(), c4.cache_len());
    }

    /// Satellite pin for the O(log n) recency index: the new eviction
    /// path must pick exactly the victims the historical linear
    /// `min_by_key(last_used)` scan picked. The reference model below
    /// *is* that historical algorithm; a wrong victim flips a later
    /// hit/miss, so matching the full flag sequence pins the order.
    #[test]
    fn eviction_order_matches_linear_scan_reference() {
        crate::util::property("lru_eviction_order", 8, |rng| {
            let cap = 3usize;
            let c = Coordinator::with_cache_cap(cap);
            // Reference model: seed -> last_used, one global tick.
            let mut model: HashMap<u64, u64> = HashMap::new();
            let mut tick = 0u64;
            let mut model_evictions = 0u64;
            for _ in 0..60 {
                let seed = 200u64 + rng.below(7) as u64;
                tick += 1;
                let model_hit = if let Some(t) = model.get_mut(&seed) {
                    *t = tick;
                    true
                } else {
                    tick += 1; // miss path takes a second tick (post-optimize)
                    while model.len() >= cap {
                        let victim =
                            *model.iter().min_by_key(|(_, &t)| t).map(|(s, _)| s).unwrap();
                        model.remove(&victim);
                        model_evictions += 1;
                    }
                    model.insert(seed, tick);
                    false
                };
                let (_, hit) = c.compile_cached(&small_job(seed)).unwrap();
                assert_eq!(hit, model_hit, "divergence from linear-scan LRU at seed {seed}");
            }
            let s = c.stats();
            assert_eq!(s.evictions, model_evictions);
            assert_eq!(c.cache_len(), model.len());
        });
    }

    /// Concurrency hammer (satellite): N threads hammer overlapping
    /// keys through a small capped sharded cache. No lost updates —
    /// hit/miss/eviction accounting is exact and every reply is
    /// bit-identical to the sequential solution.
    #[test]
    fn concurrent_hammer_accounting_is_exact() {
        let threads = 4usize;
        let iters = 24usize;
        let keys = 6u64;
        // Sequential ground truth: one program per key.
        let reference: Vec<CmvmSolution> = (0..keys)
            .map(|s| {
                let job = small_job(s);
                cmvm::compile(&job.problem, &OptimizeOptions::new(job.strategy)).unwrap()
            })
            .collect();
        let per_key_steps: Vec<u64> = reference.iter().map(|r| r.cse.steps as u64).collect();
        let per_key_pops: Vec<u64> = reference.iter().map(|r| r.cse.heap_pops as u64).collect();

        let c = Coordinator::with_shards(4);
        c.set_cache_cap(Some(4));
        let results: Mutex<Vec<(u64, bool)>> = Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            for t in 0..threads {
                let c = c.clone();
                let results = &results;
                let reference = &reference;
                scope.spawn(move || {
                    for i in 0..iters {
                        let seed = ((i + t * 3) as u64) % keys;
                        let (sol, hit) = c.compile_cached(&small_job(seed)).unwrap();
                        assert_eq!(
                            sol.program, reference[seed as usize].program,
                            "thread {t} got a wrong solution for key {seed}"
                        );
                        results.lock().unwrap().push((seed, hit));
                    }
                });
            }
        });
        let results = results.lock().unwrap();
        let s = c.stats();
        assert_eq!(results.len(), threads * iters);
        assert_eq!(s.submitted, (threads * iters) as u64);
        let hits = results.iter().filter(|(_, h)| *h).count() as u64;
        assert_eq!(s.cache_hits, hits, "per-call hit flags must sum to the stats counter");
        // Every miss ran the optimizer exactly once: the deterministic
        // per-key work counters account for the totals exactly.
        let mut want_steps = 0u64;
        let mut want_pops = 0u64;
        for (seed, hit) in results.iter() {
            if !hit {
                want_steps += per_key_steps[*seed as usize];
                want_pops += per_key_pops[*seed as usize];
            }
        }
        assert_eq!(s.total_cse_steps, want_steps);
        assert_eq!(s.total_heap_pops, want_pops);
        // Caps hold per shard: global len <= ceil(4/4) * 4 = 4.
        assert!(c.cache_len() <= 4, "cache over cap: {}", c.cache_len());
        assert!(s.evictions <= s.submitted - s.cache_hits);
    }
}
