//! Schema-versioned persistence for the coordinator's solution cache.
//!
//! A cache file is a single JSON document (schema v1, following the
//! `perf::schema` / `explore::schema` discipline):
//!
//! ```json
//! {
//!   "schema_version": 1,
//!   "kind": "da4ml-solution-cache",
//!   "entries": [ { "key": { ... }, "solution": { ... } }, ... ]
//! }
//! ```
//!
//! Each entry carries the **full job identity** (dims, matrix, input
//! intervals/depths, strategy) and the complete optimized solution: the
//! DAIS program node-by-node, the adder/depth metadata, the exact
//! optimizer wall-clock in integer nanoseconds (so a warm-started
//! `serve` reply reproduces `opt_ms` byte-identically), and the CSE
//! work counters.
//!
//! Determinism: entries are written in the canonical [`Ord`] order of
//! the job key and every object is serialized with sorted keys, so
//! save → load → save is byte-identical and two caches with the same
//! entries serialize identically regardless of insertion order, shard
//! count, or recency state (recency is runtime state and is *not*
//! persisted — loaded entries start in file order).
//!
//! Loading is paranoid by design — the cache is the service's most
//! valuable state and a cache file is an integrity boundary: every
//! program is re-checked for structural well-formedness *and* exact
//! CMVM equivalence against its key's matrix, and the stored
//! adder/depth metadata is cross-checked against the program. A
//! tampered or corrupt file is rejected with an actionable error and
//! loads nothing; it can never serve a wrong solution.

use super::{Coordinator, JobKey};
use crate::cmvm::{CmvmSolution, Strategy};
use crate::cse::CseStats;
use crate::dais::{verify, DaisNode, DaisOp, DaisProgram, NodeId, OutputSpec, RoundMode};
use crate::fixed::QInterval;
use crate::json::{self, Value};
use crate::Result;
use anyhow::{anyhow, bail, ensure};
use std::collections::BTreeMap;
use std::hash::BuildHasher;
use std::sync::Arc;
use std::time::Duration;

/// Cache-file schema version this binary writes and reads.
pub const SCHEMA_VERSION: u32 = 1;

/// The `kind` discriminator of a solution-cache file.
pub const KIND: &str = "da4ml-solution-cache";

fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::Object(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn qint_value(q: QInterval) -> Value {
    Value::Array(vec![Value::Int(q.min), Value::Int(q.max), Value::Int(q.exp as i64)])
}

fn parse_qint(v: &Value) -> Result<QInterval> {
    let a = v.as_array()?;
    ensure!(a.len() == 3, "qint must be a [min, max, exp] triple, got {} elements", a.len());
    let (min, max) = (a[0].as_i64()?, a[1].as_i64()?);
    ensure!(min <= max, "qint min {min} > max {max}");
    let exp = parse_i32(&a[2], "qint exp")?;
    Ok(QInterval { min, max, exp })
}

fn parse_i32(v: &Value, what: &str) -> Result<i32> {
    let raw = v.as_i64()?;
    i32::try_from(raw).map_err(|_| anyhow!("{what} {raw} out of i32 range"))
}

fn parse_u32(v: &Value, what: &str) -> Result<u32> {
    let raw = v.as_i64()?;
    u32::try_from(raw).map_err(|_| anyhow!("{what} {raw} out of u32 range"))
}

fn parse_usize(v: &Value, what: &str) -> Result<usize> {
    let raw = v.as_i64()?;
    usize::try_from(raw).map_err(|_| anyhow!("{what} {raw} is negative"))
}

fn strategy_value(strategy: Strategy) -> Value {
    let mut fields = vec![("name", s(strategy.name()))];
    match strategy {
        Strategy::Da { dc } | Strategy::CseOnly { dc } | Strategy::Lookahead { dc } => {
            fields.push(("dc", Value::Int(dc as i64)));
        }
        Strategy::Latency | Strategy::NaiveDa => {}
    }
    obj(fields)
}

fn parse_strategy(v: &Value) -> Result<Strategy> {
    let name = v.get("name")?.as_str()?;
    let dc = |v: &Value| parse_i32(v.get("dc")?, "strategy dc");
    Ok(match name {
        "latency" => Strategy::Latency,
        "naive-da" => Strategy::NaiveDa,
        "da" => Strategy::Da { dc: dc(v)? },
        "cse-only" => Strategy::CseOnly { dc: dc(v)? },
        "lookahead" => Strategy::Lookahead { dc: dc(v)? },
        other => bail!("unknown strategy '{other}'"),
    })
}

fn op_value(op: DaisOp) -> Vec<(&'static str, Value)> {
    match op {
        DaisOp::Input { index } => {
            vec![("op", s("input")), ("index", Value::Int(index as i64))]
        }
        DaisOp::Const { value } => vec![("op", s("const")), ("value", Value::Int(value))],
        DaisOp::AddShift { a, b, shift_a, shift_b, sub } => vec![
            ("op", s("add-shift")),
            ("a", Value::Int(a as i64)),
            ("b", Value::Int(b as i64)),
            ("shift_a", Value::Int(shift_a as i64)),
            ("shift_b", Value::Int(shift_b as i64)),
            ("sub", Value::Bool(sub)),
        ],
        DaisOp::Neg { a } => vec![("op", s("neg")), ("a", Value::Int(a as i64))],
        DaisOp::Relu { a } => vec![("op", s("relu")), ("a", Value::Int(a as i64))],
        DaisOp::Quant { a, shift, round, clip_min, clip_max } => vec![
            ("op", s("quant")),
            ("a", Value::Int(a as i64)),
            ("shift", Value::Int(shift as i64)),
            (
                "round",
                s(match round {
                    RoundMode::Floor => "floor",
                    RoundMode::HalfUp => "half-up",
                }),
            ),
            ("clip_min", Value::Int(clip_min)),
            ("clip_max", Value::Int(clip_max)),
        ],
    }
}

fn parse_op(v: &Value) -> Result<DaisOp> {
    let node = |key: &str| -> Result<NodeId> { parse_u32(v.get(key)?, key) };
    Ok(match v.get("op")?.as_str()? {
        "input" => DaisOp::Input { index: parse_u32(v.get("index")?, "input index")? },
        "const" => DaisOp::Const { value: v.get("value")?.as_i64()? },
        "add-shift" => DaisOp::AddShift {
            a: node("a")?,
            b: node("b")?,
            shift_a: parse_u32(v.get("shift_a")?, "shift_a")?,
            shift_b: parse_u32(v.get("shift_b")?, "shift_b")?,
            sub: v.get("sub")?.as_bool()?,
        },
        "neg" => DaisOp::Neg { a: node("a")? },
        "relu" => DaisOp::Relu { a: node("a")? },
        "quant" => DaisOp::Quant {
            a: node("a")?,
            shift: parse_i32(v.get("shift")?, "quant shift")?,
            round: match v.get("round")?.as_str()? {
                "floor" => RoundMode::Floor,
                "half-up" => RoundMode::HalfUp,
                other => bail!("unknown round mode '{other}'"),
            },
            clip_min: v.get("clip_min")?.as_i64()?,
            clip_max: v.get("clip_max")?.as_i64()?,
        },
        other => bail!("unknown op '{other}'"),
    })
}

fn program_value(p: &DaisProgram) -> Value {
    let nodes: Vec<Value> = p
        .nodes
        .iter()
        .map(|n| {
            let mut fields = op_value(n.op);
            fields.push(("qint", qint_value(n.qint)));
            fields.push(("depth", Value::Int(n.depth as i64)));
            obj(fields)
        })
        .collect();
    let outputs: Vec<Value> = p
        .outputs
        .iter()
        .map(|o| Value::Array(vec![Value::Int(o.node as i64), Value::Int(o.shift as i64)]))
        .collect();
    obj(vec![
        ("num_inputs", Value::Int(p.num_inputs as i64)),
        ("nodes", Value::Array(nodes)),
        ("outputs", Value::Array(outputs)),
    ])
}

fn parse_node(v: &Value) -> Result<DaisNode> {
    Ok(DaisNode {
        op: parse_op(v)?,
        qint: parse_qint(v.get("qint")?)?,
        depth: parse_u32(v.get("depth")?, "node depth")?,
    })
}

fn parse_program(v: &Value) -> Result<DaisProgram> {
    let num_inputs = parse_usize(v.get("num_inputs")?, "num_inputs")?;
    let mut nodes = Vec::new();
    for (i, n) in v.get("nodes")?.as_array()?.iter().enumerate() {
        nodes.push(parse_node(n).map_err(|e| anyhow!("node {i}: {e}"))?);
    }
    let mut outputs = Vec::new();
    for (i, o) in v.get("outputs")?.as_array()?.iter().enumerate() {
        let pair = o.as_array()?;
        ensure!(pair.len() == 2, "output {i} must be a [node, shift] pair");
        outputs.push(OutputSpec {
            node: parse_u32(&pair[0], "output node")?,
            shift: parse_i32(&pair[1], "output shift")?,
        });
    }
    Ok(DaisProgram { nodes, outputs, num_inputs })
}

fn key_value(key: &JobKey) -> Value {
    obj(vec![
        ("d_in", Value::Int(key.d_in as i64)),
        ("d_out", Value::Int(key.d_out as i64)),
        ("matrix", Value::Array(key.matrix.iter().map(|&w| Value::Int(w)).collect())),
        ("input_qint", Value::Array(key.input_qint.iter().map(|&q| qint_value(q)).collect())),
        (
            "input_depth",
            Value::Array(key.input_depth.iter().map(|&d| Value::Int(d as i64)).collect()),
        ),
        ("strategy", strategy_value(key.strategy)),
    ])
}

fn parse_key(v: &Value) -> Result<JobKey> {
    let d_in = parse_usize(v.get("d_in")?, "d_in")?;
    let d_out = parse_usize(v.get("d_out")?, "d_out")?;
    ensure!(d_in >= 1 && d_out >= 1, "degenerate dims {d_in}x{d_out}");
    let matrix = v.get("matrix")?.to_i64_vec()?;
    ensure!(
        matrix.len() == d_in * d_out,
        "matrix has {} entries, dims say {d_in}x{d_out}",
        matrix.len()
    );
    let input_qint: Vec<QInterval> = v
        .get("input_qint")?
        .as_array()?
        .iter()
        .map(parse_qint)
        .collect::<Result<_>>()?;
    ensure!(input_qint.len() == d_in, "input_qint has {} entries, d_in is {d_in}", input_qint.len());
    let input_depth: Vec<u32> = v
        .get("input_depth")?
        .as_array()?
        .iter()
        .map(|d| parse_u32(d, "input depth"))
        .collect::<Result<_>>()?;
    ensure!(
        input_depth.len() == d_in,
        "input_depth has {} entries, d_in is {d_in}",
        input_depth.len()
    );
    let strategy = parse_strategy(v.get("strategy")?)?;
    Ok(JobKey { d_in, d_out, matrix, input_qint, input_depth, strategy })
}

fn cse_value(c: &CseStats) -> Value {
    obj(vec![
        ("steps", Value::Int(c.steps as i64)),
        ("depth_rejections", Value::Int(c.depth_rejections as i64)),
        ("heap_pops", Value::Int(c.heap_pops as i64)),
        ("stale_pops", Value::Int(c.stale_pops as i64)),
        ("occ_cols_scanned", Value::Int(c.occ_cols_scanned as i64)),
        ("occ_digits_scanned", Value::Int(c.occ_digits_scanned as i64)),
    ])
}

fn parse_cse(v: &Value) -> Result<CseStats> {
    Ok(CseStats {
        steps: parse_usize(v.get("steps")?, "cse steps")?,
        depth_rejections: parse_usize(v.get("depth_rejections")?, "cse depth_rejections")?,
        heap_pops: parse_usize(v.get("heap_pops")?, "cse heap_pops")?,
        stale_pops: parse_usize(v.get("stale_pops")?, "cse stale_pops")?,
        occ_cols_scanned: parse_usize(v.get("occ_cols_scanned")?, "cse occ_cols_scanned")?,
        occ_digits_scanned: parse_usize(v.get("occ_digits_scanned")?, "cse occ_digits_scanned")?,
    })
}

fn entry_value(key: &JobKey, sol: &CmvmSolution) -> Value {
    let opt_ns = i64::try_from(sol.opt_time.as_nanos()).unwrap_or(i64::MAX);
    obj(vec![
        ("key", key_value(key)),
        (
            "solution",
            obj(vec![
                ("adders", Value::Int(sol.adders as i64)),
                ("depth", Value::Int(sol.depth as i64)),
                ("opt_ns", Value::Int(opt_ns)),
                ("cse", cse_value(&sol.cse)),
                ("program", program_value(&sol.program)),
            ]),
        ),
    ])
}

/// Parse and fully validate one cache entry. The strategy is part of
/// the key, so the solution does not repeat it.
fn parse_entry(v: &Value) -> Result<(JobKey, CmvmSolution)> {
    let key = parse_key(v.get("key")?)?;
    let sv = v.get("solution")?;
    let adders = parse_usize(sv.get("adders")?, "adders")?;
    let depth = parse_u32(sv.get("depth")?, "depth")?;
    let opt_ns = sv.get("opt_ns")?.as_i64()?;
    ensure!(opt_ns >= 0, "negative opt_ns {opt_ns}");
    let cse = parse_cse(sv.get("cse")?)?;
    let program = parse_program(sv.get("program")?)?;

    // Integrity boundary: the program must be structurally sound and
    // *exactly* equivalent to the key's matrix — a tampered cache file
    // can never serve a wrong adder graph.
    verify::check_well_formed(&program).map_err(|e| anyhow!("corrupt program: {e}"))?;
    ensure!(
        program.num_inputs == key.d_in,
        "program arity {} != key d_in {}",
        program.num_inputs,
        key.d_in
    );
    ensure!(
        program.outputs.len() == key.d_out,
        "program has {} outputs, key d_out is {}",
        program.outputs.len(),
        key.d_out
    );
    verify::check_cmvm_equivalence(&program, &key.matrix, key.d_in, key.d_out)
        .map_err(|e| anyhow!("program does not compute the key's matrix: {e}"))?;
    ensure!(
        adders == program.adder_count(),
        "adders metadata {adders} != program adder count {}",
        program.adder_count()
    );
    ensure!(
        depth == program.adder_depth(),
        "depth metadata {depth} != program adder depth {}",
        program.adder_depth()
    );

    let strategy = key.strategy;
    let sol = CmvmSolution {
        program,
        adders,
        depth,
        opt_time: Duration::from_nanos(opt_ns as u64),
        strategy,
        cse,
    };
    Ok((key, sol))
}

/// Parse and validate a whole cache document into its entries.
fn parse_entries(text: &str) -> Result<Vec<(JobKey, CmvmSolution)>> {
    let v = json::parse(text).map_err(|e| anyhow!("cache load: not valid JSON: {e}"))?;
    let kind = v
        .get_opt("kind")
        .and_then(|k| k.as_str().ok())
        .unwrap_or("<missing>");
    ensure!(
        kind == KIND,
        "cache load: not a solution-cache file (kind = '{kind}', expected '{KIND}')"
    );
    let sv = v.get("schema_version")?.as_i64()?;
    ensure!(
        sv == SCHEMA_VERSION as i64,
        "cache load: file is schema v{sv}, this binary reads v{SCHEMA_VERSION} — \
         re-bake it with `da4ml cache bake`"
    );
    let mut out = Vec::new();
    for (i, e) in v.get("entries")?.as_array()?.iter().enumerate() {
        out.push(parse_entry(e).map_err(|err| anyhow!("cache load: entry {i}: {err}"))?);
    }
    Ok(out)
}

/// Summary of a cache file, as printed by `da4ml cache info`. Produced
/// by [`info`], which runs the *full* load-path validation — `cache
/// info` doubles as an integrity check.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CacheInfo {
    /// Schema version of the file.
    pub schema_version: u32,
    /// Number of cached solutions.
    pub entries: usize,
    /// Entry count per strategy name.
    pub by_strategy: BTreeMap<String, usize>,
    /// Sum of adder counts across all cached programs.
    pub total_adders: u64,
}

/// Validate a cache document and summarize it (see [`CacheInfo`]).
pub fn info(text: &str) -> Result<CacheInfo> {
    let entries = parse_entries(text)?;
    let mut by_strategy: BTreeMap<String, usize> = BTreeMap::new();
    let mut total_adders = 0u64;
    for (key, sol) in &entries {
        *by_strategy.entry(key.strategy.name().to_string()).or_insert(0) += 1;
        total_adders += sol.adders as u64;
    }
    Ok(CacheInfo {
        schema_version: SCHEMA_VERSION,
        entries: entries.len(),
        by_strategy,
        total_adders,
    })
}

impl<S: BuildHasher> Coordinator<S> {
    /// Serialize the full solution cache to the schema-v1 JSON document.
    ///
    /// Deterministic: entries are sorted by the canonical job-key order
    /// and recency state is not persisted, so the bytes depend only on
    /// the set of cached (key, solution) pairs — not on shard count,
    /// insertion order, or access history.
    pub fn save_cache(&self) -> String {
        let mut entries: Vec<(JobKey, Arc<CmvmSolution>)> = Vec::new();
        for shard in &self.inner.shards {
            let shard = shard.lock().unwrap();
            for (key, entry) in &shard.cache {
                entries.push((JobKey::clone(key), Arc::clone(&entry.sol)));
            }
        }
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        let items: Vec<Value> = entries.iter().map(|(k, sol)| entry_value(k, sol)).collect();
        let doc = obj(vec![
            ("schema_version", Value::Int(SCHEMA_VERSION as i64)),
            ("kind", s(KIND)),
            ("entries", Value::Array(items)),
        ]);
        json::to_string(&doc)
    }

    /// Warm-start the cache from a document produced by
    /// [`Coordinator::save_cache`]. Returns the number of entries
    /// inserted (counted in [`super::CoordinatorStats::loaded`]).
    ///
    /// The whole file is validated *before* anything is inserted — a
    /// corrupt, tampered, or wrong-schema file is rejected with an
    /// actionable error and leaves the cache untouched. Entries already
    /// present in the live cache win over the file's copy; a `cap == 0`
    /// (caching disabled) coordinator loads nothing; a capped cache
    /// honors its cap by evicting exactly as a computed insert would.
    pub fn load_cache(&self, text: &str) -> Result<u64> {
        let entries = parse_entries(text)?;
        let mut loaded = 0u64;
        for (key, sol) in entries {
            let idx = self.inner.shard_index(&key);
            let mut shard = self.inner.shards[idx].lock().unwrap();
            if shard.cap == Some(0) || shard.cache.contains_key(&key) {
                continue;
            }
            shard.tick += 1;
            let tick = shard.tick;
            shard.insert_new(key, Arc::new(sol), tick);
            shard.stats.loaded += 1;
            loaded += 1;
        }
        Ok(loaded)
    }
}

#[cfg(test)]
mod tests {
    use super::super::CompileJob;
    use super::*;
    use crate::cmvm::CmvmProblem;
    use crate::util::Rng;

    fn job(seed: u64, strategy: Strategy) -> CompileJob {
        let mut rng = Rng::seed_from(seed);
        let m: Vec<i64> = (0..6).map(|_| rng.range_i64(-127, 127)).collect();
        CompileJob {
            name: format!("p{seed}"),
            problem: CmvmProblem::new(2, 3, m, 8).unwrap(),
            strategy,
        }
    }

    fn warm_coordinator() -> Coordinator {
        let c = Coordinator::new();
        c.compile(&job(1, Strategy::Da { dc: 2 })).unwrap();
        c.compile(&job(2, Strategy::Da { dc: -1 })).unwrap();
        c.compile(&job(3, Strategy::NaiveDa)).unwrap();
        c.compile(&job(4, Strategy::CseOnly { dc: 0 })).unwrap();
        c.compile(&job(5, Strategy::Latency)).unwrap();
        c
    }

    #[test]
    fn save_load_save_is_byte_identical() {
        let c = warm_coordinator();
        let saved = c.save_cache();
        let fresh = Coordinator::new();
        let n = fresh.load_cache(&saved).unwrap();
        assert_eq!(n, 5);
        assert_eq!(fresh.cache_len(), 5);
        assert_eq!(fresh.stats().loaded, 5);
        assert_eq!(fresh.save_cache(), saved, "save -> load -> save must round-trip");
    }

    #[test]
    fn loaded_solutions_serve_identical_hits() {
        let c = warm_coordinator();
        let saved = c.save_cache();
        let fresh = Coordinator::new();
        fresh.load_cache(&saved).unwrap();
        for (seed, strategy) in [
            (1, Strategy::Da { dc: 2 }),
            (2, Strategy::Da { dc: -1 }),
            (3, Strategy::NaiveDa),
            (4, Strategy::CseOnly { dc: 0 }),
            (5, Strategy::Latency),
        ] {
            let j = job(seed, strategy);
            let original = c.compile(&j).unwrap();
            let (warm, hit) = fresh.compile_cached(&j).unwrap();
            assert!(hit, "loaded entry must serve a hit");
            assert_eq!(warm.program, original.program);
            assert_eq!(warm.adders, original.adders);
            assert_eq!(warm.depth, original.depth);
            assert_eq!(warm.cse, original.cse);
            // Exact nanosecond round-trip keeps serve's opt_ms
            // byte-identical between warm and in-memory replies.
            assert_eq!(warm.opt_time, original.opt_time);
        }
        // Loads are not submissions; the 5 probe compiles are.
        assert_eq!(fresh.stats().submitted, 5);
        assert_eq!(fresh.stats().cache_hits, 5);
    }

    #[test]
    fn save_is_shard_count_invariant() {
        let saved = warm_coordinator().save_cache();
        let sharded = Coordinator::with_shards(4);
        sharded.load_cache(&saved).unwrap();
        assert_eq!(sharded.save_cache(), saved);
    }

    #[test]
    fn wrong_schema_version_rejected_with_actionable_error() {
        let saved = warm_coordinator().save_cache();
        let doctored = saved.replace("\"schema_version\":1", "\"schema_version\":2");
        assert_ne!(saved, doctored, "test must actually change the version");
        let fresh = Coordinator::new();
        let err = fresh.load_cache(&doctored).unwrap_err().to_string();
        assert!(err.contains("schema v2"), "unhelpful error: {err}");
        assert!(err.contains("re-bake"), "error must say how to recover: {err}");
        assert_eq!(fresh.cache_len(), 0);
    }

    #[test]
    fn corrupt_and_foreign_files_rejected() {
        let fresh = Coordinator::new();
        let err = fresh.load_cache("{\"not\": json").unwrap_err().to_string();
        assert!(err.contains("not valid JSON"), "got: {err}");
        let err = fresh.load_cache("{\"schema_version\":1}").unwrap_err().to_string();
        assert!(err.contains("kind"), "got: {err}");
        assert_eq!(fresh.cache_len(), 0);
    }

    #[test]
    fn tampered_matrix_rejected() {
        let c = Coordinator::new();
        c.compile(&job(7, Strategy::Da { dc: -1 })).unwrap();
        let saved = c.save_cache();
        // Flip one matrix weight: the stored program no longer computes
        // the claimed matrix, so equivalence checking must reject it.
        let matrix = job(7, Strategy::Da { dc: -1 }).problem.matrix;
        let needle = format!("\"matrix\":[{}", matrix[0]);
        let swapped = format!("\"matrix\":[{}", matrix[0] + 1);
        let doctored = saved.replace(&needle, &swapped);
        assert_ne!(saved, doctored, "needle not found in the saved document");
        let fresh = Coordinator::new();
        let err = fresh.load_cache(&doctored).unwrap_err().to_string();
        assert!(err.contains("does not compute"), "got: {err}");
        assert_eq!(fresh.cache_len(), 0);
    }

    #[test]
    fn live_entries_win_over_loaded_ones() {
        let c = warm_coordinator();
        let saved = c.save_cache();
        let fresh = Coordinator::new();
        let j = job(1, Strategy::Da { dc: 2 });
        let live = fresh.compile(&j).unwrap();
        let n = fresh.load_cache(&saved).unwrap();
        assert_eq!(n, 4, "the already-live entry is skipped");
        let (again, hit) = fresh.compile_cached(&j).unwrap();
        assert!(hit);
        assert!(Arc::ptr_eq(&live, &again), "load must not replace the live entry");
    }

    #[test]
    fn zero_cap_coordinator_loads_nothing() {
        let saved = warm_coordinator().save_cache();
        let disabled = Coordinator::with_cache_cap(0);
        assert_eq!(disabled.load_cache(&saved).unwrap(), 0);
        assert_eq!(disabled.cache_len(), 0);
        assert_eq!(disabled.stats().loaded, 0);
    }

    #[test]
    fn capped_load_evicts_like_computed_inserts() {
        let saved = warm_coordinator().save_cache();
        let capped = Coordinator::with_cache_cap(2);
        let n = capped.load_cache(&saved).unwrap();
        assert_eq!(n, 5, "every entry is loaded (then LRU-bounded)");
        assert_eq!(capped.cache_len(), 2);
        assert_eq!(capped.stats().evictions, 3);
        assert_eq!(capped.stats().loaded, 5);
    }

    #[test]
    fn info_summarizes_and_validates() {
        let c = warm_coordinator();
        let i = info(&c.save_cache()).unwrap();
        assert_eq!(i.schema_version, SCHEMA_VERSION);
        assert_eq!(i.entries, 5);
        assert_eq!(i.by_strategy.get("da"), Some(&2));
        assert_eq!(i.by_strategy.get("naive-da"), Some(&1));
        assert_eq!(i.by_strategy.get("cse-only"), Some(&1));
        assert_eq!(i.by_strategy.get("latency"), Some(&1));
        assert!(i.total_adders > 0);
        assert!(info("[]").is_err());
    }
}
