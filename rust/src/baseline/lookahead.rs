//! An `H_cmvm`-like conflict-aware CSE with one-step look-ahead.
//!
//! This is the reproduction's stand-in for the closed-source `H_cmvm`
//! comparator of paper Table 2. It follows the mechanism the paper
//! credits for `H_cmvm`'s ~2 % adder advantage (and its O(N³)–O(N³·⁵)
//! runtime): at every update step it *recounts all two-term patterns
//! from scratch* and evaluates, for each maximal-frequency candidate, a
//! one-step look-ahead conflict score — how many occurrences of the
//! other frequent patterns would be destroyed by implementing it —
//! selecting the least-conflicting candidate.
//!
//! Per step: O(N²) recount + O(candidates · N) conflict evaluation, with
//! O(N) steps ⇒ O(N³) overall, matching the comparator's asymptotics.
//! The adder *quality* matches da4ml to within a few percent while the
//! runtime gap reproduces Table 2's five orders of magnitude.

use crate::cmvm::{CmvmProblem, CmvmSolution, Strategy};
use crate::csd::Csd;
use crate::cse::{naive_da, InputTerm, OutTerm};
use crate::cse::{self as cse_mod};
use crate::dais::{DaisBuilder, NodeId};
use crate::util::fxhash::FxHashMap;

#[derive(Debug, Clone, Copy)]
struct Digit {
    row: u32,
    power: i32,
    sign: i8,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
struct Pattern {
    ra: u32,
    rb: u32,
    shift: u32,
    sub: bool,
}

fn canon(a: Digit, b: Digit) -> Pattern {
    let (a, b) = if (a.power, a.row) <= (b.power, b.row) { (a, b) } else { (b, a) };
    Pattern { ra: a.row, rb: b.row, shift: (b.power - a.power) as u32, sub: a.sign != b.sign }
}

struct State {
    cols: Vec<Vec<Digit>>,
    rows: Vec<(NodeId, u32)>, // (node, depth)
}

impl State {
    /// Full recount of every pattern (the deliberately expensive part).
    fn count_all(&self) -> FxHashMap<Pattern, u32> {
        let mut counts = FxHashMap::default();
        for col in &self.cols {
            for i in 0..col.len() {
                for j in (i + 1)..col.len() {
                    *counts.entry(canon(col[i], col[j])).or_insert(0u32) += 1;
                }
            }
        }
        counts
    }

    /// Greedy disjoint occurrences of `p` (col, idx_a, idx_b).
    fn occurrences(&self, p: Pattern) -> Vec<(usize, usize, usize)> {
        let mut occ = Vec::new();
        for (c, col) in self.cols.iter().enumerate() {
            let mut used = vec![false; col.len()];
            let mut order: Vec<usize> = (0..col.len()).collect();
            order.sort_by_key(|&i| (col[i].power, col[i].row));
            for &i in &order {
                if used[i] || col[i].row != p.ra {
                    continue;
                }
                for &j in &order {
                    if j == i || used[j] || col[j].row != p.rb {
                        continue;
                    }
                    if col[j].power - col[i].power == p.shift as i32
                        && (col[i].sign != col[j].sign) == p.sub
                        && canon(col[i], col[j]) == p
                    {
                        used[i] = true;
                        used[j] = true;
                        occ.push((c, i, j));
                        break;
                    }
                }
            }
        }
        occ
    }

    /// One-step look-ahead conflict: occurrences of *other* count≥2
    /// patterns that share a digit with `occ`.
    fn conflict(&self, p: Pattern, occ: &[(usize, usize, usize)], counts: &FxHashMap<Pattern, u32>) -> u64 {
        let mut conflict = 0u64;
        for &(c, i, j) in occ {
            let col = &self.cols[c];
            for k in 0..col.len() {
                if k == i || k == j {
                    continue;
                }
                for &d in &[i, j] {
                    let q = canon(col[d], col[k]);
                    if q != p && counts.get(&q).copied().unwrap_or(0) >= 2 {
                        conflict += 1;
                    }
                }
            }
        }
        conflict
    }

    /// Kraft depth bookkeeping (same feasibility rule as the engine).
    fn col_kraft(&self, c: usize) -> u128 {
        self.cols[c].iter().map(|d| 1u128 << self.rows[d.row as usize].1).sum()
    }
}

/// Run the look-ahead CSE into `builder`. Used by
/// [`crate::cmvm::compile`] for [`Strategy::Lookahead`].
pub fn optimize_into(
    builder: &mut DaisBuilder,
    inputs: &[InputTerm],
    problem: &CmvmProblem,
    dc: i32,
) -> Vec<OutTerm> {
    let (d_in, d_out) = (problem.d_in, problem.d_out);
    let mut st = State {
        cols: (0..d_out)
            .map(|i| {
                let mut v = Vec::new();
                for j in 0..d_in {
                    for d in Csd::encode(problem.at(j, i)).digits() {
                        v.push(Digit { row: j as u32, power: d.power, sign: d.sign });
                    }
                }
                v
            })
            .collect(),
        rows: inputs.iter().map(|t| (t.node, builder.depth(t.node))).collect(),
    };

    // Depth budgets (Kraft), identical to the engine's rule.
    let budget: Option<Vec<u32>> = if dc >= 0 {
        let mins: Vec<u32> = (0..d_out)
            .map(|c| {
                let k = st.col_kraft(c);
                if k <= 1 { 0 } else { 128 - (k - 1).leading_zeros() }
            })
            .collect();
        let dmin = mins.iter().copied().max().unwrap_or(0);
        Some(mins.iter().map(|&m| m.max(dmin + dc as u32)).collect())
    } else {
        None
    };

    loop {
        let counts = st.count_all();
        let max_count = counts.values().copied().max().unwrap_or(0);
        if max_count < 2 {
            break;
        }
        // Evaluate every maximal-count candidate with look-ahead.
        let mut best: Option<(u64, Pattern, Vec<(usize, usize, usize)>)> = None;
        let mut cands: Vec<Pattern> =
            counts.iter().filter(|(_, &c)| c == max_count).map(|(p, _)| *p).collect();
        cands.sort(); // determinism
        for p in cands {
            let occ = st.occurrences(p);
            // Depth filter.
            let occ = match &budget {
                None => occ,
                Some(b) => {
                    let da = st.rows[p.ra as usize].1;
                    let db = st.rows[p.rb as usize].1;
                    let delta =
                        (1i128 << (da.max(db) + 1)) - (1i128 << da) - (1i128 << db);
                    let mut extra: FxHashMap<usize, i128> = FxHashMap::default();
                    occ.into_iter()
                        .filter(|&(c, _, _)| {
                            let used = extra.entry(c).or_insert(0);
                            if st.col_kraft(c) as i128 + *used + delta <= 1i128 << b[c] {
                                *used += delta;
                                true
                            } else {
                                false
                            }
                        })
                        .collect()
                }
            };
            if occ.len() < 2 {
                continue;
            }
            let cf = st.conflict(p, &occ, &counts);
            let better = match &best {
                None => true,
                Some((bc, bp, bo)) => {
                    (occ.len(), std::cmp::Reverse(cf), std::cmp::Reverse(p))
                        > (bo.len(), std::cmp::Reverse(*bc), std::cmp::Reverse(*bp))
                }
            };
            if better {
                best = Some((cf, p, occ));
            }
        }
        let Some((_, p, occ)) = best else { break };

        // Implement.
        let (na, _) = st.rows[p.ra as usize];
        let (nb, _) = st.rows[p.rb as usize];
        let node = builder.add_shift(na, nb, p.shift, p.sub);
        let row = st.rows.len() as u32;
        st.rows.push((node, builder.depth(node)));
        // Group removals per column: indices refer to the pre-removal
        // layout, so mark-and-compact instead of removing in place.
        let mut per_col: FxHashMap<usize, Vec<(usize, usize)>> = FxHashMap::default();
        for (c, i, j) in occ {
            per_col.entry(c).or_default().push((i, j));
        }
        for (c, pairs) in per_col {
            let mut dead = vec![false; st.cols[c].len()];
            let mut fresh = Vec::with_capacity(pairs.len());
            for (i, j) in pairs {
                let (pa, sa) = (st.cols[c][i].power, st.cols[c][i].sign);
                dead[i] = true;
                dead[j] = true;
                fresh.push(Digit { row, power: pa, sign: sa });
            }
            let mut kept: Vec<Digit> = st.cols[c]
                .iter()
                .zip(&dead)
                .filter(|(_, &d)| !d)
                .map(|(d, _)| *d)
                .collect();
            kept.extend(fresh);
            st.cols[c] = kept;
        }
    }

    // Final balanced trees.
    let term_lists: Vec<Vec<cse_mod::tree::Term>> = st
        .cols
        .iter()
        .map(|col| {
            col.iter()
                .map(|d| cse_mod::tree::Term {
                    node: st.rows[d.row as usize].0,
                    shift: d.power,
                    neg: d.sign < 0,
                })
                .collect()
        })
        .collect();
    term_lists
        .into_iter()
        .map(|terms| cse_mod::tree::combine(builder, terms))
        .collect()
}

/// Standalone entry matching [`crate::cmvm::compile`]'s output shape.
pub fn optimize_lookahead(problem: &CmvmProblem, dc: i32) -> crate::Result<CmvmSolution> {
    let opts = crate::cmvm::OptimizeOptions::new(Strategy::Lookahead { dc });
    crate::cmvm::compile(problem, &opts)
}

/// The naive-DA functional reference, re-exported for bench symmetry.
pub fn naive_reference(
    builder: &mut DaisBuilder,
    inputs: &[InputTerm],
    problem: &CmvmProblem,
) -> Vec<OutTerm> {
    naive_da(builder, inputs, &problem.matrix, problem.d_in, problem.d_out)
}

#[cfg(test)]
mod tests {
    use crate::cmvm::{compile, CmvmProblem, OptimizeOptions, Strategy};

    fn optimize(p: &CmvmProblem, s: Strategy) -> crate::Result<crate::cmvm::CmvmSolution> {
        compile(p, &OptimizeOptions::new(s))
    }
    use crate::dais::verify;
    use crate::util::Rng;

    #[test]
    fn lookahead_exact_and_competitive() {
        let mut rng = Rng::seed_from(21);
        for _ in 0..3 {
            let m: Vec<i64> = (0..36).map(|_| rng.range_i64(-255, 255)).collect();
            let p = CmvmProblem::new(6, 6, m.clone(), 8).unwrap();
            let la = optimize(&p, Strategy::Lookahead { dc: -1 }).unwrap();
            verify::check_cmvm_equivalence(&la.program, &m, 6, 6).unwrap();
            let da = optimize(&p, Strategy::Da { dc: -1 }).unwrap();
            // Comparable quality: within ±20% of each other.
            let (a, b) = (la.adders as f64, da.adders as f64);
            assert!((a - b).abs() / b.max(1.0) < 0.25, "lookahead {a} vs da {b}");
        }
    }

    #[test]
    fn lookahead_depth_constraint() {
        let mut rng = Rng::seed_from(8);
        let m: Vec<i64> = (0..36).map(|_| rng.range_i64(129, 255)).collect();
        let p = CmvmProblem::new(6, 6, m.clone(), 8).unwrap();
        let s0 = optimize(&p, Strategy::Lookahead { dc: 0 }).unwrap();
        let sf = optimize(&p, Strategy::Lookahead { dc: -1 }).unwrap();
        verify::check_cmvm_equivalence(&s0.program, &m, 6, 6).unwrap();
        assert!(s0.depth <= sf.depth.max(5));
    }

    #[test]
    fn lookahead_slower_than_da() {
        // The runtime gap (Table 2's headline): even at 10×10 the
        // look-ahead recount loop is measurably slower.
        let mut rng = Rng::seed_from(30);
        let m: Vec<i64> = (0..100).map(|_| rng.range_i64(129, 255)).collect();
        let p = CmvmProblem::new(10, 10, m, 8).unwrap();
        let la = optimize(&p, Strategy::Lookahead { dc: -1 }).unwrap();
        let da = optimize(&p, Strategy::Da { dc: -1 }).unwrap();
        assert!(la.opt_time > da.opt_time, "{:?} <= {:?}", la.opt_time, da.opt_time);
    }
}
