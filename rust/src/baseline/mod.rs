//! Baseline CMVM implementations the paper compares against.
//!
//! * [`mac`] — the hls4ml **latency strategy**: an unrolled
//!   multiply-accumulate loop, with Vivado-style DSP inference. Modeled
//!   analytically (its functional semantics are bit-exact to the naive
//!   DA program, see [`crate::cse::naive_da`]).
//! * [`lookahead`] — an `H_cmvm`-like O(N³) conflict-aware CSE with
//!   one-step look-ahead, the slow-but-slightly-better comparator of
//!   Table 2.

pub mod lookahead;
pub mod mac;

pub use lookahead::optimize_lookahead;
pub use mac::mac_report;
