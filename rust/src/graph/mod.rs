//! Stage 1 — graph-based matrix decomposition (paper §4.3).
//!
//! Every column `v_i` of the constant matrix becomes a vertex; a root
//! vertex carries the zero vector. The distance between two vertices is
//! `min(nnz_csd(v_i - v_j), nnz_csd(v_i + v_j))` — the cost, in signed
//! digits, of deriving one output from the other. A depth-bounded Prim
//! MST then rewrites `M = M1 · M2`: each tree edge becomes a column of
//! `M1` (the vector that must actually be summed from the inputs), and
//! `M2` records each original column as the ±1 combination of the edges
//! on its root path. `M2` is typically much sparser than `M`, and stage 2
//! CSE runs on both factors.
//!
//! With a delay constraint `dc ≥ 0` the tree depth is capped at `2^dc`
//! edges (paper §4.3), so `dc = 0` forces the trivial decomposition.

use crate::csd;

/// The stage-1 result: `M (d_in×d_out) = M1 (d_in×k) · M2 (k×d_out)`.
#[derive(Debug, Clone)]
pub struct Decomposition {
    /// Edge-vector matrix, row-major `d_in × k`.
    pub m1: Vec<i64>,
    /// Path-coefficient matrix, row-major `k × d_out`, entries in
    /// `{-1, 0, 1}`.
    pub m2: Vec<i64>,
    /// Number of tree edges (== `d_out`; one edge per non-root vertex).
    pub k: usize,
    /// Parent vertex per column (0 = root, `c` = column `c-1`).
    pub parent: Vec<usize>,
    /// Whether the edge to the parent used the `v_c + v_p` form (the
    /// parent path contributes negated).
    pub flip: Vec<bool>,
}

impl Decomposition {
    /// True when every vertex hangs directly off the root with positive
    /// sign — `M1` is `M` and `M2` the identity, so stage 1 found no
    /// exploitable cross-column structure.
    pub fn is_trivial(&self) -> bool {
        self.parent.iter().all(|&p| p == 0) && self.flip.iter().all(|&f| !f)
    }

    /// Verify `M1 · M2 == M` exactly (i128 accumulation).
    pub fn check(&self, matrix: &[i64], d_in: usize, d_out: usize) -> bool {
        for j in 0..d_in {
            for i in 0..d_out {
                let mut acc: i128 = 0;
                for r in 0..self.k {
                    acc += self.m1[j * self.k + r] as i128 * self.m2[r * d_out + i] as i128;
                }
                if acc != matrix[j * d_out + i] as i128 {
                    return false;
                }
            }
        }
        true
    }
}

/// Distance between two column vectors: fewest CSD digits to derive one
/// from (±) the other. Returns (distance, use_sum_form).
fn distance(a: &[i64], b: &[i64]) -> (u32, bool) {
    let mut diff = 0u32;
    let mut sum = 0u32;
    for (&x, &y) in a.iter().zip(b) {
        diff += csd::nnz(x - y);
        sum += csd::nnz(x + y);
    }
    if diff <= sum {
        (diff, false)
    } else {
        (sum, true)
    }
}

/// Run the depth-bounded Prim decomposition.
///
/// `dc < 0` leaves the tree depth unconstrained; otherwise the root path
/// of every vertex is at most `2^dc` edges.
pub fn decompose(matrix: &[i64], d_in: usize, d_out: usize, dc: i32) -> Decomposition {
    assert_eq!(matrix.len(), d_in * d_out);
    let max_depth: u64 = if dc < 0 { u64::MAX } else { 1u64 << dc.min(62) };

    // Column views (vertex v_{c+1} = column c); vertex 0 is the root.
    let col = |c: usize| -> Vec<i64> { (0..d_in).map(|j| matrix[j * d_out + c]).collect() };
    let columns: Vec<Vec<i64>> = (0..d_out).map(col).collect();
    let zero = vec![0i64; d_in];
    let vertex = |v: usize| -> &[i64] { if v == 0 { &zero } else { &columns[v - 1] } };

    let n = d_out + 1;
    let mut in_tree = vec![false; n];
    let mut depth = vec![0u64; n];
    let mut parent = vec![0usize; d_out];
    let mut flip = vec![false; d_out];
    // best[v] = (dist, parent, use_sum) among *eligible* tree vertices.
    let mut best: Vec<(u32, usize, bool)> = (0..n)
        .map(|v| {
            if v == 0 {
                (0, 0, false)
            } else {
                let (d, s) = distance(vertex(v), &zero);
                (d, 0usize, s)
            }
        })
        .collect();
    in_tree[0] = true;

    for _ in 0..d_out {
        // Pick the closest out-of-tree vertex (deterministic tie-break
        // by vertex index).
        let mut pick = usize::MAX;
        for v in 1..n {
            if !in_tree[v] && (pick == usize::MAX || best[v].0 < best[pick].0) {
                pick = v;
            }
        }
        let (_, p, s) = best[pick];
        in_tree[pick] = true;
        depth[pick] = depth[p] + 1;
        parent[pick - 1] = p;
        flip[pick - 1] = s;

        // Relax: the new vertex may be a better (and eligible) parent.
        if depth[pick] < max_depth {
            for v in 1..n {
                if !in_tree[v] {
                    let (d, s) = distance(vertex(v), vertex(pick));
                    if d < best[v].0 {
                        best[v] = (d, pick, s);
                    }
                }
            }
        }
    }

    // Edge vectors: w_c = v_c - v_p (diff form) or v_c + v_p (sum form).
    let k = d_out;
    let mut m1 = vec![0i64; d_in * k];
    for c in 0..d_out {
        let p = parent[c];
        let pv = vertex(p);
        for j in 0..d_in {
            let w = if flip[c] {
                columns[c][j] + pv[j]
            } else {
                columns[c][j] - pv[j]
            };
            m1[j * k + c] = w;
        }
    }

    // Path coefficients: v_c = w_c + (flip ? -1 : +1) * v_parent.
    let mut m2 = vec![0i64; k * d_out];
    for i in 0..d_out {
        // Walk up from v_{i+1}, accumulating the sign.
        let mut v = i + 1;
        let mut sign = 1i64;
        loop {
            let c = v - 1;
            m2[c * d_out + i] = sign;
            if flip[c] {
                sign = -sign;
            }
            v = parent[c];
            if v == 0 {
                break;
            }
        }
    }

    Decomposition { m1, m2, k, parent, flip }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Fig. 2 / Eq. (2): the MST must be the chain
    /// root -> v1 -> v2 -> v3.
    #[test]
    fn paper_fig2_chain() {
        // M columns: v1=(0,1,2), v2=(1,2,3), v3=(3,4,5); row-major d_in=3.
        let m = vec![
            0, 1, 3, //
            1, 2, 4, //
            2, 3, 5, //
        ];
        let d = decompose(&m, 3, 3, -1);
        assert_eq!(d.parent, vec![0, 1, 2]);
        assert!(!d.is_trivial());
        assert!(d.check(&m, 3, 3));
        // Edge vectors: v1, v2-v1=(1,1,1), v3-v2=(2,2,2).
        assert_eq!((0..3).map(|j| d.m1[j * 3 + 1]).collect::<Vec<_>>(), vec![1, 1, 1]);
        assert_eq!((0..3).map(|j| d.m1[j * 3 + 2]).collect::<Vec<_>>(), vec![2, 2, 2]);
        // M2 columns: v1 = e1; v2 = e1+e2; v3 = e1+e2+e3.
        assert_eq!(d.m2, vec![1, 1, 1, 0, 1, 1, 0, 0, 1]);
    }

    #[test]
    fn dc_zero_forces_trivial_star() {
        let m = vec![
            0, 1, 3, //
            1, 2, 4, //
            2, 3, 5, //
        ];
        let d = decompose(&m, 3, 3, 0);
        // Depth cap 2^0 = 1: every vertex hangs off the root.
        assert_eq!(d.parent, vec![0, 0, 0]);
        assert!(d.check(&m, 3, 3));
    }

    #[test]
    fn negated_duplicate_columns_use_sum_form() {
        // v2 = -v1: the sum form gives a zero edge vector.
        let m = vec![
            3, -3, //
            5, -5, //
        ];
        let d = decompose(&m, 2, 2, -1);
        assert!(d.check(&m, 2, 2));
        let total_digits: u32 = d.m1.iter().map(|&x| csd::nnz(x)).sum();
        // Only one copy of (3,5) should remain in M1: nnz(3)+nnz(5) = 4.
        assert_eq!(total_digits, 4);
    }

    #[test]
    fn depth_cap_respected() {
        use crate::util::Rng;
        let mut rng = Rng::seed_from(11);
        let (d_in, d_out) = (6, 12);
        let m: Vec<i64> = (0..d_in * d_out).map(|_| rng.range_i64(1, 255)).collect();
        for dc in [0, 1, 2] {
            let d = decompose(&m, d_in, d_out, dc);
            assert!(d.check(&m, d_in, d_out));
            // Re-derive depths and check the cap.
            for c in 0..d_out {
                let mut depth = 0;
                let mut v = c + 1;
                while v != 0 {
                    depth += 1;
                    v = d.parent[v - 1];
                }
                assert!(depth <= 1u64 << dc, "dc={dc}: vertex {c} depth {depth}");
            }
        }
    }

    #[test]
    fn random_decomposition_always_exact() {
        use crate::util::Rng;
        let mut rng = Rng::seed_from(5);
        for _ in 0..10 {
            let d_in = rng.below(7) + 1;
            let d_out = rng.below(7) + 1;
            let m: Vec<i64> =
                (0..d_in * d_out).map(|_| rng.range_i64(-128, 127)).collect();
            let d = decompose(&m, d_in, d_out, -1);
            assert!(d.check(&m, d_in, d_out));
        }
    }
}
