//! Shared generators for the paper-table benches.

use crate::baseline::mac::{mac_report, DspPolicy};
use crate::cmvm::{self, CmvmProblem, OptimizeOptions, Strategy};
use crate::estimate::{combinational, FpgaModel};
use crate::nn::{self, LayerSpec, NetworkSpec, TestVectors};
use crate::pipeline::PipelineConfig;
use crate::report::Table;
use crate::runtime;
use crate::util::Rng;
use crate::Result;

/// A seeded random dense layer for the synthetic benchmark specs.
fn synthetic_dense(rng: &mut Rng, d_in: usize, d_out: usize, relu: bool) -> LayerSpec {
    LayerSpec::Dense {
        w: (0..d_in)
            .map(|_| (0..d_out).map(|_| rng.range_i64(-127, 127)).collect())
            .collect(),
        b: (0..d_out).map(|_| rng.range_i64(-512, 511)).collect(),
        relu,
        shift: 6,
        clip_min: -128,
        clip_max: 127,
    }
}

/// The paper's jet-tagging MLP shape (§6.2: 16-64-32-32-5) with seeded
/// 8-bit weights — the micro-benches (`ingestion_micro`,
/// `netlist_micro`) fall back to this when the exported artifacts are
/// absent, so `cargo bench` works on a bare checkout.
pub fn synthetic_jet_spec() -> NetworkSpec {
    let mut rng = Rng::seed_from(42);
    NetworkSpec {
        name: "jet_mlp_synthetic".into(),
        input_bits: 8,
        input_signed: true,
        input_shape: vec![16],
        layers: vec![
            synthetic_dense(&mut rng, 16, 64, true),
            synthetic_dense(&mut rng, 64, 32, true),
            synthetic_dense(&mut rng, 32, 32, true),
            synthetic_dense(&mut rng, 32, 5, false),
        ],
    }
}

/// The jet-MLP shape with every hidden dimension scaled by `num/den`
/// (floored at 2, output head fixed at 5) — the size axis of the perf
/// suite's network cases. `synthetic_jet_spec_scaled(1, 1)` has the
/// dimensions of [`synthetic_jet_spec`] under a scale-tagged name.
pub fn synthetic_jet_spec_scaled(num: usize, den: usize) -> NetworkSpec {
    assert!(num > 0 && den > 0, "scale must be positive");
    let s = |d: usize| ((d * num) / den).max(2);
    let dims = [s(16), s(64), s(32), s(32)];
    let mut rng = Rng::seed_from(42);
    NetworkSpec {
        name: format!("jet_mlp_synthetic_x{num}of{den}"),
        input_bits: 8,
        input_signed: true,
        input_shape: vec![dims[0]],
        layers: vec![
            synthetic_dense(&mut rng, dims[0], dims[1], true),
            synthetic_dense(&mut rng, dims[1], dims[2], true),
            synthetic_dense(&mut rng, dims[2], dims[3], true),
            synthetic_dense(&mut rng, dims[3], 5, false),
        ],
    }
}

/// Tables 3/4: resource/latency rows for random matrices at one weight
/// bitwidth, DA(dc ∈ {0,2,-1}) vs the latency baseline.
pub fn resource_table(title: &str, bw: u32) {
    let model = FpgaModel::default();
    let mut table = Table::new(
        title,
        &["strategy", "DC", "size", "LUT", "DSP", "FF", "latency[ns]", "adders"],
    );
    for &m in &[8usize, 16, 32, 64] {
        let p = CmvmProblem::random(9000 + m as u64 + bw as u64, m, m, bw);
        let macr = mac_report(&p, &model, &DspPolicy::default());
        table.push(vec![
            "latency".into(),
            "-".into(),
            format!("{m}x{m}"),
            macr.lut.to_string(),
            macr.dsp.to_string(),
            macr.ff.to_string(),
            format!("{:.2}", macr.latency_ns),
            format!("({})", macr.adders),
        ]);
        for dc in [0i32, 2, -1] {
            let opts = OptimizeOptions::new(Strategy::Da { dc });
            let sol = cmvm::compile(&p, &opts).expect("compile");
            let rep = combinational(&sol.program, &model);
            table.push(vec![
                "DA".into(),
                dc.to_string(),
                format!("{m}x{m}"),
                rep.lut.to_string(),
                "0".into(),
                rep.ff.to_string(),
                format!("{:.2}", rep.latency_ns),
                sol.adders.to_string(),
            ]);
        }
    }
    println!("{}", table.render());
}

/// The six quantization levels exported by the Python build layer.
pub const LEVELS: &[(u32, u32)] = &[(8, 8), (7, 7), (6, 6), (5, 6), (4, 6), (4, 5)];

/// Load an artifact network spec at a quantization level.
pub fn load_level(name: &str, w: u32, a: u32) -> Result<NetworkSpec> {
    let dir = runtime::artifacts_dir();
    NetworkSpec::from_json(&runtime::load_text(
        dir.join(format!("{name}_w{w}a{a}.weights.json")),
    )?)
}

/// Load the test vectors at a quantization level.
pub fn load_vectors(name: &str, w: u32, a: u32) -> Result<TestVectors> {
    let dir = runtime::artifacts_dir();
    TestVectors::from_json(&runtime::load_text(
        dir.join(format!("{name}_w{w}a{a}.testvec.json")),
    )?)
}

/// Fetch a metric (accuracy / resolution) from metrics.json.
pub fn metric(name: &str, w: u32, a: u32, key: &str) -> Result<f64> {
    let dir = runtime::artifacts_dir();
    let m = runtime::load_json_value(dir.join("metrics.json"))?;
    m.get(name)?.get(&format!("w{w}a{a}"))?.get(key)?.as_f64()
}

/// Tables 5/6/8/9: a network sweep over quantization levels for
/// latency vs DA, with the given pipeline config and metric column.
pub fn network_table(
    title: &str,
    name: &str,
    metric_key: &str,
    metric_label: &str,
    pipe: &PipelineConfig,
) -> Result<()> {
    let model = FpgaModel::default();
    let mut table = Table::new(
        title,
        &[
            "strategy",
            metric_label,
            "latency[cycles]",
            "LUT",
            "DSP",
            "FF",
            "Fmax[MHz]",
            "adders",
        ],
    );
    for &(w, a) in LEVELS {
        let spec = load_level(name, w, a)?;
        let mv = metric(name, w, a, metric_key)?;
        for s in [Strategy::Latency, Strategy::Da { dc: 2 }] {
            let rep = nn::compile::network_report(&spec, s, &model, pipe)?;
            let adders = if matches!(s, Strategy::Latency) {
                format!("({})", rep.adders)
            } else {
                rep.adders.to_string()
            };
            table.push(vec![
                format!("{} w{w}a{a}", s.name()),
                format!("{:.3}", mv),
                rep.latency_cycles.to_string(),
                rep.lut.to_string(),
                rep.dsp.to_string(),
                rep.ff.to_string(),
                format!("{:.0}", rep.fmax_mhz),
                adders,
            ]);
        }
    }
    println!("{}", table.render());
    Ok(())
}
