//! CMVM problem formulation (paper §3) and top-level optimization entry.
//!
//! A CMVM computes `y^T = x^T M` for a constant integer matrix `M` of
//! shape `d_in × d_out` (entry `(j, i)` is the weight of input `j` on
//! output `i`). The optimizer turns it into a multiplierless DAIS adder
//! graph under a delay constraint `dc` (extra adder depth allowed beyond
//! the minimal achievable depth; `dc = -1` disables the constraint).
//!
//! The single entry point is [`compile`] (self-contained program) /
//! [`compile_terms`] (into a caller-owned builder, the NN frontend's
//! composition point), both driven by [`OptimizeOptions`]: the strategy
//! plus the [`ArenaMode`] allocation-reuse policy. The pre-redesign
//! free functions (`optimize`, `optimize_terms`, `optimize_terms_stats`)
//! remain as deprecated shims delegating to the new surface.

mod arena;
mod normalize;

pub use arena::{ArenaMode, CompileArena};
pub use normalize::{denormalize_check, normalize, Normalization};

use crate::csd;
use crate::cse::{self, CseConfig, CseStats, EngineArena, InputTerm, OutTerm};
use crate::dais::{DaisBuilder, DaisProgram};
use crate::fixed::QInterval;
use crate::graph;
use crate::Result;
use anyhow::{bail, ensure};

/// Which CMVM implementation strategy to use (mirrors the hls4ml
/// `strategy` knob: `latency` vs `distributed_arithmetic`). The derived
/// order (variant order, then `dc`) is part of the canonical cache-file
/// entry ordering ([`crate::coordinator::persist`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Strategy {
    /// hls4ml's latency-optimized MAC loop (baseline; DSP/LUT multipliers,
    /// modeled analytically by [`crate::baseline::mac`]).
    Latency,
    /// Plain distributed arithmetic: per-weight CSD shift-adds + balanced
    /// accumulation trees, no CSE (the "no optimization" DA reference).
    NaiveDa,
    /// The full da4ml algorithm: graph decomposition + cost-aware CSE.
    Da {
        /// Delay constraint (`-1` = unconstrained).
        dc: i32,
    },
    /// da4ml stage 2 only (CSE without the MST decomposition) — ablation.
    CseOnly {
        /// Delay constraint (`-1` = unconstrained).
        dc: i32,
    },
    /// The `H_cmvm`-like O(N³) conflict-aware look-ahead CSE
    /// (see [`crate::baseline::lookahead`]).
    Lookahead {
        /// Delay constraint (`-1` = unconstrained).
        dc: i32,
    },
}

impl Strategy {
    /// Short name used in reports.
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Latency => "latency",
            Strategy::NaiveDa => "naive-da",
            Strategy::Da { .. } => "da",
            Strategy::CseOnly { .. } => "cse-only",
            Strategy::Lookahead { .. } => "lookahead",
        }
    }
}

/// A CMVM optimization problem.
#[derive(Debug, Clone)]
pub struct CmvmProblem {
    /// Number of inputs (rows of `M`).
    pub d_in: usize,
    /// Number of outputs (columns of `M`).
    pub d_out: usize,
    /// Row-major constant matrix: `matrix[j * d_out + i]`.
    pub matrix: Vec<i64>,
    /// Quantized interval of each input (integer-unit convention).
    pub input_qint: Vec<QInterval>,
    /// Initial adder depth of each input (paper's `depth_int`; non-zero
    /// when the CMVM consumes values produced by earlier adder trees).
    pub input_depth: Vec<u32>,
}

impl CmvmProblem {
    /// Build a problem with uniform signed `input_bits`-bit inputs at
    /// depth 0.
    ///
    /// Errors when `input_bits` is outside `[1, 63]`: 0 would underflow
    /// the `input_bits - 1` sign-bit split below, 64+ the i64 shifts.
    /// (The shape check stays an assert — a mismatched matrix length is
    /// a caller bug, not an input-validation question.)
    pub fn new(d_in: usize, d_out: usize, matrix: Vec<i64>, input_bits: u32) -> Result<Self> {
        assert_eq!(matrix.len(), d_in * d_out, "matrix shape mismatch");
        ensure!(
            (1..=63).contains(&input_bits),
            "input_bits must be in [1, 63], got {input_bits}"
        );
        let q = QInterval::new(-(1i64 << (input_bits - 1)), (1i64 << (input_bits - 1)) - 1, 0);
        Ok(Self {
            d_in,
            d_out,
            matrix,
            input_qint: vec![q; d_in],
            input_depth: vec![0; d_in],
        })
    }

    /// Random problem in the paper's Table-2 convention: a `bw`-bit
    /// matrix samples integers uniformly from `[2^(bw-1)+1, 2^bw - 1]`
    /// (Aksoy et al.'s benchmark convention, §6.1).
    pub fn random(seed: u64, d_in: usize, d_out: usize, bw: u32) -> Self {
        let mut rng = crate::util::Rng::seed_from(seed);
        let lo = (1i64 << (bw - 1)) + 1;
        let hi = (1i64 << bw) - 1;
        let m: Vec<i64> = (0..d_in * d_out).map(|_| rng.range_i64(lo, hi)).collect();
        Self::new(d_in, d_out, m, 8).expect("random problems use valid input_bits")
    }

    /// Entry `(j, i)`.
    pub fn at(&self, j: usize, i: usize) -> i64 {
        self.matrix[j * self.d_out + i]
    }

    /// Column `i` as a vector.
    pub fn column(&self, i: usize) -> Vec<i64> {
        (0..self.d_in).map(|j| self.at(j, i)).collect()
    }

    /// Total number of non-zero CSD digits of the matrix — the paper's
    /// problem-size parameter `N`.
    pub fn csd_nnz(&self) -> u32 {
        csd::nnz_vec(&self.matrix)
    }

    /// Reference computation `x^T M` in i128 (ground truth for tests).
    pub fn reference(&self, x: &[i64]) -> Vec<i128> {
        assert_eq!(x.len(), self.d_in);
        (0..self.d_out)
            .map(|i| (0..self.d_in).map(|j| x[j] as i128 * self.at(j, i) as i128).sum())
            .collect()
    }
}

/// The result of optimizing one CMVM.
#[derive(Debug, Clone)]
pub struct CmvmSolution {
    /// The adder-graph program realizing the CMVM.
    pub program: DaisProgram,
    /// Adder/subtractor count (paper's "adders" column).
    pub adders: usize,
    /// Adder depth (paper's "depth" column).
    pub depth: u32,
    /// Optimizer wall-clock time.
    pub opt_time: std::time::Duration,
    /// Strategy that produced this solution.
    pub strategy: Strategy,
    /// CSE engine work counters, accumulated over every engine
    /// invocation the strategy made (two for the two-stage flow; zeros
    /// for strategies that bypass the engine: latency / naive-da /
    /// lookahead). Deterministic — the perf baseline pins them.
    pub cse: CseStats,
}

/// Options for [`compile`] / [`compile_terms`]: the strategy (which
/// carries its own `dc`) plus the allocation-arena policy.
#[derive(Debug, Clone, Copy)]
pub struct OptimizeOptions<'a> {
    /// The implementation strategy (carries the delay constraint).
    pub strategy: Strategy,
    /// Allocation reuse policy (default: per-thread arena).
    pub arena: ArenaMode<'a>,
}

impl OptimizeOptions<'_> {
    /// Options for `strategy` with the default thread-local arena.
    pub fn new(strategy: Strategy) -> Self {
        Self { strategy, arena: ArenaMode::ThreadLocal }
    }
}

impl<'a> OptimizeOptions<'a> {
    /// Override the arena policy.
    pub fn with_arena(self, arena: ArenaMode<'a>) -> Self {
        Self { arena, ..self }
    }
}

/// Optimize a CMVM problem into a self-contained DAIS program (inputs
/// 0..d_in, outputs 0..d_out). The single compile entry point: strategy
/// and arena policy ride in [`OptimizeOptions`], and the solution always
/// carries the engine work counters.
pub fn compile(problem: &CmvmProblem, opts: &OptimizeOptions) -> Result<CmvmSolution> {
    let mut span = crate::obs::span("cmvm", "cmvm.compile");
    span.arg_str("strategy", || opts.strategy.name().to_string());
    span.arg_str("arena", || opts.arena.name().to_string());
    span.arg("d_in", problem.d_in as i64);
    span.arg("d_out", problem.d_out as i64);
    let t0 = std::time::Instant::now();
    let strategy = opts.strategy;
    arena::with_arena(opts.arena, |arena| {
        let mut builder = match arena {
            Some(a) => DaisBuilder::with_storage(a.take_builder()),
            None => DaisBuilder::new(),
        };
        let inputs: Vec<InputTerm> = (0..problem.d_in)
            .map(|j| {
                let node = builder.input(j, problem.input_qint[j], problem.input_depth[j]);
                InputTerm { node }
            })
            .collect();

        let engine_arena = arena.map(|a| a.engine());
        let (outs, cse_stats) =
            compile_terms_inner(&mut builder, &inputs, problem, strategy, engine_arena)?;
        bind_outputs(&mut builder, &outs);
        let program = match arena {
            Some(a) => {
                let (program, storage) = builder.finish_reuse();
                a.put_builder(storage);
                program
            }
            None => builder.finish(),
        };
        // The deterministic result counters ride on the span; wall-clock
        // stays in `opt_time` only (timing never enters cached replies).
        span.arg("adders", program.adder_count() as i64);
        span.arg("depth", program.adder_depth() as i64);
        span.arg("cse_steps", cse_stats.steps as i64);
        span.arg("heap_pops", cse_stats.heap_pops as i64);
        Ok(CmvmSolution {
            adders: program.adder_count(),
            depth: program.adder_depth(),
            program,
            opt_time: t0.elapsed(),
            strategy,
            cse: cse_stats,
        })
    })
}

/// Run a strategy into an existing builder with caller-provided input
/// terms; returns the raw output terms (no output binding) plus the
/// engine work counters. This is the composition point used by the NN
/// frontend to chain CMVMs (the engine arena from `opts.arena` is used;
/// builder storage stays the caller's concern since the builder is
/// theirs).
///
/// Errors when an optimizer invariant is violated (e.g. a stage-1
/// decomposition output with a negative shift) instead of silently
/// producing a wrong graph.
pub fn compile_terms(
    builder: &mut DaisBuilder,
    inputs: &[InputTerm],
    problem: &CmvmProblem,
    opts: &OptimizeOptions,
) -> Result<(Vec<OutTerm>, CseStats)> {
    arena::with_arena(opts.arena, |arena| {
        compile_terms_inner(builder, inputs, problem, opts.strategy, arena.map(|a| a.engine()))
    })
}

/// Strategy dispatch with the engine arena resolved.
fn compile_terms_inner(
    builder: &mut DaisBuilder,
    inputs: &[InputTerm],
    problem: &CmvmProblem,
    strategy: Strategy,
    engine_arena: Option<&EngineArena>,
) -> Result<(Vec<OutTerm>, CseStats)> {
    let mut span = crate::obs::span("cmvm", "cmvm.compile_terms");
    span.arg_str("strategy", || strategy.name().to_string());
    Ok(match strategy {
        Strategy::Latency | Strategy::NaiveDa => {
            // The latency strategy's *functional* model is the naive DA
            // graph (bit-exact); its *resource* model differs (see
            // baseline::mac).
            (
                cse::naive_da(builder, inputs, &problem.matrix, problem.d_in, problem.d_out),
                CseStats::default(),
            )
        }
        Strategy::CseOnly { dc } => cse::compile(
            builder,
            inputs,
            &problem.matrix,
            problem.d_in,
            problem.d_out,
            &CseConfig { dc, ..CseConfig::default() },
            engine_arena,
        ),
        Strategy::Da { dc } => two_stage(builder, inputs, problem, dc, engine_arena)?,
        Strategy::Lookahead { dc } => (
            crate::baseline::lookahead::optimize_into(builder, inputs, problem, dc),
            CseStats::default(),
        ),
    })
}

/// Deprecated pre-redesign entry point; equivalent to
/// [`compile_terms`] with [`ArenaMode::Fresh`].
#[deprecated(note = "use cmvm::compile_terms with OptimizeOptions")]
pub fn optimize_terms(
    builder: &mut DaisBuilder,
    inputs: &[InputTerm],
    problem: &CmvmProblem,
    strategy: Strategy,
) -> Result<Vec<OutTerm>> {
    compile_terms_inner(builder, inputs, problem, strategy, None).map(|(outs, _)| outs)
}

/// Deprecated pre-redesign entry point; equivalent to
/// [`compile_terms`] with [`ArenaMode::Fresh`].
#[deprecated(note = "use cmvm::compile_terms with OptimizeOptions")]
pub fn optimize_terms_stats(
    builder: &mut DaisBuilder,
    inputs: &[InputTerm],
    problem: &CmvmProblem,
    strategy: Strategy,
) -> Result<(Vec<OutTerm>, CseStats)> {
    compile_terms_inner(builder, inputs, problem, strategy, None)
}

/// Deprecated pre-redesign entry point; equivalent to [`compile`] with
/// [`ArenaMode::Fresh`].
#[deprecated(note = "use cmvm::compile with OptimizeOptions")]
pub fn optimize(problem: &CmvmProblem, strategy: Strategy) -> Result<CmvmSolution> {
    compile(problem, &OptimizeOptions::new(strategy).with_arena(ArenaMode::Fresh))
}

/// The full two-stage da4ml flow: MST decomposition `M = M1 · M2`
/// (stage 1), then CSE on `M1` and on `M2` with the stage-1 outputs as
/// stage-2 inputs (stage 2), concatenated into one program.
fn two_stage(
    builder: &mut DaisBuilder,
    inputs: &[InputTerm],
    problem: &CmvmProblem,
    dc: i32,
    engine_arena: Option<&EngineArena>,
) -> Result<(Vec<OutTerm>, CseStats)> {
    let decomp = {
        let _span = crate::obs::span("cmvm", "cmvm.stage1.decompose");
        graph::decompose(&problem.matrix, problem.d_in, problem.d_out, dc)
    };
    let cfg = CseConfig { dc, ..CseConfig::default() };

    if decomp.is_trivial() {
        // No cross-column structure found: stage 1 degenerates to the
        // identity and we run CSE on M directly.
        return Ok(cse::compile(
            builder,
            inputs,
            &problem.matrix,
            problem.d_in,
            problem.d_out,
            &cfg,
            engine_arena,
        ));
    }

    // Stage 2a: CSE over M1 (d_in × k).
    let (mids, mut stats) = {
        let _span = crate::obs::span("cmvm", "cmvm.stage2a");
        cse::compile(builder, inputs, &decomp.m1, problem.d_in, decomp.k, &cfg, engine_arena)
    };

    // Fold each intermediate's wiring shift/sign into the M2 entries so
    // stage 2b consumes plain nodes. A negative stage-1 shift cannot be
    // folded into an integer M2 scale — previously this was silently
    // clamped (`shift.max(0)`) in release builds, folding a *wrong* M2.
    // Integer M1 columns always yield non-negative shifts, so any
    // violation is an internal invariant break: fail loudly.
    let mut m2 = vec![0i64; decomp.k * problem.d_out];
    let mut mid_inputs = Vec::with_capacity(decomp.k);
    for (r, mid) in mids.iter().enumerate() {
        match mid.node {
            Some(node) => {
                if mid.shift < 0 {
                    bail!(
                        "two_stage: stage-1 intermediate {r} carries negative shift {} \
                         (cannot fold into M2; optimizer invariant violated)",
                        mid.shift
                    );
                }
                mid_inputs.push(InputTerm { node });
                let scale = (if mid.neg { -1i64 } else { 1 }) << mid.shift;
                for i in 0..problem.d_out {
                    m2[r * problem.d_out + i] = decomp.m2[r * problem.d_out + i] * scale;
                }
            }
            None => {
                // Zero intermediate: contributes nothing. Bind a dummy
                // zero row (all-zero M2 entries already).
                let z = builder.constant(0);
                mid_inputs.push(InputTerm { node: z });
            }
        }
    }

    let (outs, stage2) = {
        let _span = crate::obs::span("cmvm", "cmvm.stage2b");
        cse::compile(builder, &mid_inputs, &m2, decomp.k, problem.d_out, &cfg, engine_arena)
    };
    stats.absorb(&stage2);
    Ok((outs, stats))
}

/// Materialize the CSE output terms as program outputs (inserting `Neg`
/// ops for negative-signed terms and constants for zero columns).
fn bind_outputs(builder: &mut DaisBuilder, outs: &[OutTerm]) {
    for out in outs {
        match out.node {
            Some(node) => {
                let n = if out.neg { builder.neg(node) } else { node };
                builder.output(n, out.shift);
            }
            None => {
                let z = builder.constant(0);
                builder.output(z, 0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dais::interp;
    use crate::dais::verify;
    use crate::util::{property, Rng};

    /// The five strategy variants under one delay constraint.
    fn all_strategies(dc: i32) -> [Strategy; 5] {
        [
            Strategy::Latency,
            Strategy::NaiveDa,
            Strategy::CseOnly { dc },
            Strategy::Da { dc },
            Strategy::Lookahead { dc },
        ]
    }

    fn check_strategy(matrix: Vec<i64>, d_in: usize, d_out: usize, s: Strategy) {
        let p = CmvmProblem::new(d_in, d_out, matrix, 8).unwrap();
        let sol = compile(&p, &OptimizeOptions::new(s)).unwrap();
        verify::check_well_formed(&sol.program).unwrap();
        verify::check_cmvm_equivalence(&sol.program, &p.matrix, d_in, d_out).unwrap();
        // Numeric spot check.
        let x: Vec<i64> = (0..d_in as i64).map(|j| (j * 37 % 255) - 128).collect();
        let want = p.reference(&x);
        let got = interp::evaluate_checked(&sol.program, &x);
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(*g as i128, *w);
        }
    }

    /// Seeded property sweep: every strategy variant must produce a
    /// well-formed, exactly equivalent adder graph on random matrices of
    /// random shapes under random delay constraints — not just the
    /// hand-picked fixtures below. (Sizes stay small because the
    /// Lookahead comparator is deliberately O(N³).)
    #[test]
    fn prop_all_strategies_exact_on_random_matrices() {
        property("cmvm_all_strategies_exact", 12, |rng: &mut Rng| {
            let d_in = rng.below(5) + 1;
            let d_out = rng.below(5) + 1;
            let dc = rng.range_i64(-1, 2) as i32;
            let m: Vec<i64> =
                (0..d_in * d_out).map(|_| rng.range_i64(-255, 255)).collect();
            for s in all_strategies(dc) {
                check_strategy(m.clone(), d_in, d_out, s);
            }
        });
    }

    #[test]
    fn paper_eq2_matrix_all_strategies() {
        let m = vec![0, 1, 3, 1, 2, 4, 2, 3, 5]; // paper Eq. (2), row-major d_in=3
        for s in [
            Strategy::NaiveDa,
            Strategy::CseOnly { dc: -1 },
            Strategy::CseOnly { dc: 0 },
            Strategy::Da { dc: -1 },
            Strategy::Da { dc: 0 },
            Strategy::Da { dc: 2 },
        ] {
            check_strategy(m.clone(), 3, 3, s);
        }
    }

    #[test]
    fn negative_and_zero_entries() {
        let m = vec![-7, 0, 5, 0, 0, -1, 3, 128, -128];
        for s in [Strategy::NaiveDa, Strategy::Da { dc: -1 }, Strategy::Da { dc: 1 }] {
            check_strategy(m.clone(), 3, 3, s);
        }
    }

    #[test]
    fn zero_column_outputs_zero() {
        let m = vec![1, 0, 2, 0]; // d_in=2, d_out=2, second column all-zero
        let p = CmvmProblem::new(2, 2, m, 8).unwrap();
        let sol = compile(&p, &OptimizeOptions::new(Strategy::Da { dc: -1 })).unwrap();
        let got = interp::evaluate(&sol.program, &[5, 9]);
        assert_eq!(got, vec![5 + 18, 0]);
    }

    #[test]
    fn da_never_worse_than_naive() {
        let mut rng = Rng::seed_from(7);
        for _ in 0..5 {
            let (d_in, d_out) = (8, 8);
            let m: Vec<i64> =
                (0..d_in * d_out).map(|_| rng.range_i64(-127, 127)).collect();
            let p = CmvmProblem::new(d_in, d_out, m, 8).unwrap();
            let naive = compile(&p, &OptimizeOptions::new(Strategy::NaiveDa)).unwrap();
            let da = compile(&p, &OptimizeOptions::new(Strategy::Da { dc: -1 })).unwrap();
            assert!(
                da.adders <= naive.adders,
                "da {} > naive {}",
                da.adders,
                naive.adders
            );
        }
    }

    /// The engine counters ride along on solutions (the perf suite and
    /// coordinator totals depend on this plumbing).
    #[test]
    fn cse_stats_flow_through_solutions() {
        let p = CmvmProblem::random(5, 8, 8, 8);
        let da = compile(&p, &OptimizeOptions::new(Strategy::Da { dc: -1 })).unwrap();
        assert!(da.cse.steps > 0, "8x8 8-bit CMVM must share something");
        assert!(da.cse.heap_pops >= da.cse.steps);
        assert!(da.cse.occ_cols_scanned > 0);
        let naive = compile(&p, &OptimizeOptions::new(Strategy::NaiveDa)).unwrap();
        assert_eq!(naive.cse, CseStats::default(), "naive-da bypasses the engine");
    }

    /// `input_bits` validation is a proper `Err` (API-consistency
    /// satellite): 0 used to underflow `input_bits - 1` and panic with a
    /// shift overflow deep inside QInterval.
    #[test]
    fn out_of_range_input_bits_rejected() {
        for bits in [0u32, 64, 65] {
            let err = CmvmProblem::new(1, 1, vec![3], bits).unwrap_err();
            assert!(err.to_string().contains("input_bits"), "unhelpful error: {err}");
        }
        for bits in [1u32, 8, 63] {
            assert!(CmvmProblem::new(1, 1, vec![3], bits).is_ok());
        }
    }

    /// All three arena modes must emit bit-identical solutions, warm or
    /// cold — the arena is an allocation policy, never a behavior knob.
    #[test]
    fn arena_modes_are_bit_identical() {
        let p = CmvmProblem::random(11, 10, 10, 8);
        let s = Strategy::Da { dc: 1 };
        let fresh = compile(&p, &OptimizeOptions::new(s).with_arena(ArenaMode::Fresh)).unwrap();
        let local_arena = CompileArena::new();
        let local_opts = OptimizeOptions::new(s).with_arena(ArenaMode::Local(&local_arena));
        let local_cold = compile(&p, &local_opts).unwrap();
        let local_warm = compile(&p, &local_opts).unwrap();
        let tls_a = compile(&p, &OptimizeOptions::new(s)).unwrap();
        let tls_b = compile(&p, &OptimizeOptions::new(s)).unwrap();
        for sol in [&local_cold, &local_warm, &tls_a, &tls_b] {
            assert_eq!(fresh.program, sol.program);
            assert_eq!(fresh.cse, sol.cse);
            assert_eq!(fresh.adders, sol.adders);
            assert_eq!(fresh.depth, sol.depth);
        }
        // A different problem through the now-warm arena carries nothing
        // over from the previous compile.
        let p2 = CmvmProblem::random(12, 6, 13, 8);
        let warm2 = compile(&p2, &local_opts).unwrap();
        let fresh2 =
            compile(&p2, &OptimizeOptions::new(s).with_arena(ArenaMode::Fresh)).unwrap();
        assert_eq!(fresh2.program, warm2.program);
        assert_eq!(fresh2.cse, warm2.cse);
    }

    /// The deprecated shims stay byte-identical to the new entry points
    /// (they delegate, so this pins the delegation, not a copy).
    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_match_new_api() {
        let p = CmvmProblem::random(21, 9, 9, 8);
        for s in all_strategies(1) {
            let old = optimize(&p, s).unwrap();
            let new =
                compile(&p, &OptimizeOptions::new(s).with_arena(ArenaMode::Fresh)).unwrap();
            assert_eq!(old.program, new.program, "shim diverged under {s:?}");
            assert_eq!(old.cse, new.cse);

            // Terms-level shims against compile_terms.
            let run_terms = |use_old: bool| {
                let mut b = DaisBuilder::new();
                let inputs: Vec<InputTerm> = (0..p.d_in)
                    .map(|j| InputTerm {
                        node: b.input(j, p.input_qint[j], p.input_depth[j]),
                    })
                    .collect();
                let (outs, stats) = if use_old {
                    optimize_terms_stats(&mut b, &inputs, &p, s).unwrap()
                } else {
                    compile_terms(
                        &mut b,
                        &inputs,
                        &p,
                        &OptimizeOptions::new(s).with_arena(ArenaMode::Fresh),
                    )
                    .unwrap()
                };
                bind_outputs(&mut b, &outs);
                (b.finish(), stats)
            };
            let (old_p, old_s) = run_terms(true);
            let (new_p, new_s) = run_terms(false);
            assert_eq!(old_p, new_p);
            assert_eq!(old_s, new_s);
        }
    }
}
