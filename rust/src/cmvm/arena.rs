//! Per-compile allocation arenas and the [`ArenaMode`] policy knob on
//! [`OptimizeOptions`](super::OptimizeOptions).
//!
//! A [`CompileArena`] bundles the two recyclable slabs a CMVM compile
//! touches: the CSE engine's container storage
//! ([`cse::EngineArena`](crate::cse::EngineArena)) and the DAIS
//! builder's consing-map/capacity storage
//! ([`dais::BuilderStorage`](crate::dais::BuilderStorage)). Reusing one
//! arena across compiles (the coordinator worker loop, the perf suite's
//! repeat loop) replaces per-compile allocation churn with
//! clear-and-reuse; the emitted programs are bit-identical either way —
//! the differential sweep in `cse::tests` proves it.

use crate::cse::EngineArena;
use crate::dais::BuilderStorage;
use std::cell::RefCell;

/// Reusable allocation slabs for one compile pipeline. Not `Sync` —
/// hold one per thread (or use [`ArenaMode::ThreadLocal`], which does
/// exactly that).
#[derive(Debug, Default)]
pub struct CompileArena {
    engine: EngineArena,
    builder: RefCell<Option<BuilderStorage>>,
}

impl CompileArena {
    /// New empty arena; the first compile through it allocates, later
    /// ones reuse.
    pub fn new() -> Self {
        Self::default()
    }

    /// The CSE engine's storage handle.
    pub fn engine(&self) -> &EngineArena {
        &self.engine
    }

    /// Take the builder storage (fresh default when absent — first use
    /// or a reentrant compile already holding it).
    pub fn take_builder(&self) -> BuilderStorage {
        self.builder.borrow_mut().take().unwrap_or_default()
    }

    /// Return builder storage after a compile.
    pub fn put_builder(&self, storage: BuilderStorage) {
        *self.builder.borrow_mut() = Some(storage);
    }
}

/// Where a compile gets its allocation arena from.
///
/// The default reuses a per-thread arena — the right call for compile
/// loops (coordinator workers, batch sweeps) with zero setup. `Fresh`
/// opts out entirely (cold allocations, e.g. for A/B measurement);
/// `Local` pins an explicit arena, for callers that manage lifetimes
/// themselves (tests, single-shot tools).
#[derive(Debug, Clone, Copy, Default)]
pub enum ArenaMode<'a> {
    /// Reuse a per-thread [`CompileArena`] (the default).
    #[default]
    ThreadLocal,
    /// Fresh allocations, no reuse.
    Fresh,
    /// Use this specific arena.
    Local(&'a CompileArena),
}

thread_local! {
    static TLS_ARENA: CompileArena = CompileArena::default();
}

/// Resolve an [`ArenaMode`] to an optional arena reference for the
/// duration of `f`.
pub(super) fn with_arena<R>(mode: ArenaMode<'_>, f: impl FnOnce(Option<&CompileArena>) -> R) -> R {
    match mode {
        ArenaMode::ThreadLocal => TLS_ARENA.with(|a| f(Some(a))),
        ArenaMode::Fresh => f(None),
        ArenaMode::Local(a) => f(Some(a)),
    }
}

impl ArenaMode<'_> {
    /// Short name for observability args.
    pub fn name(&self) -> &'static str {
        match self {
            ArenaMode::ThreadLocal => "thread-local",
            ArenaMode::Fresh => "fresh",
            ArenaMode::Local(_) => "local",
        }
    }
}
