//! Matrix normalization (paper §4.2): strip common power-of-two factors
//! from rows and columns so that no row or column is entirely even
//! (zeros excepted). The stripped shifts are recorded and re-applied to
//! the inputs (row shifts: free input wiring) and outputs (column
//! shifts: free output wiring).

/// The result of normalizing a matrix.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Normalization {
    /// The normalized matrix (same shape, row-major).
    pub matrix: Vec<i64>,
    /// Left-shift to re-apply per input row `j`.
    pub row_shift: Vec<u32>,
    /// Left-shift to re-apply per output column `i`.
    pub col_shift: Vec<u32>,
}

/// Normalize `matrix` (`d_in × d_out`, row-major).
pub fn normalize(matrix: &[i64], d_in: usize, d_out: usize) -> Normalization {
    assert_eq!(matrix.len(), d_in * d_out);
    let mut m = matrix.to_vec();
    let mut row_shift = vec![0u32; d_in];
    let mut col_shift = vec![0u32; d_out];

    let tz_slice = |vals: &mut dyn Iterator<Item = i64>| -> u32 {
        let mut min_tz = u32::MAX;
        let mut any = false;
        for v in vals {
            if v != 0 {
                any = true;
                min_tz = min_tz.min(v.trailing_zeros());
            }
        }
        if any {
            min_tz
        } else {
            0
        }
    };

    // Rows first, then columns; a single pass each suffices because
    // stripping a row factor can only *reduce* trailing zeros in columns.
    for j in 0..d_in {
        let s = tz_slice(&mut (0..d_out).map(|i| m[j * d_out + i]));
        if s > 0 {
            for i in 0..d_out {
                m[j * d_out + i] >>= s;
            }
            row_shift[j] = s;
        }
    }
    for i in 0..d_out {
        let s = tz_slice(&mut (0..d_in).map(|j| m[j * d_out + i]));
        if s > 0 {
            for j in 0..d_in {
                m[j * d_out + i] >>= s;
            }
            col_shift[i] = s;
        }
    }
    Normalization { matrix: m, row_shift, col_shift }
}

/// Verify that a [`Normalization`] reconstructs the original matrix
/// (round-trip invariant used by tests).
pub fn denormalize_check(n: &Normalization, original: &[i64], d_in: usize, d_out: usize) -> bool {
    if n.matrix.len() != original.len() {
        return false;
    }
    for j in 0..d_in {
        for i in 0..d_out {
            let v = n.matrix[j * d_out + i] << (n.row_shift[j] + n.col_shift[i]);
            if v != original[j * d_out + i] {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_row_and_column_factors() {
        // Row 0 has common factor 4; after row-stripping, column 1 has
        // common factor 2.
        let m = vec![
            4, 8, //
            1, 2, //
        ];
        let n = normalize(&m, 2, 2);
        assert_eq!(n.row_shift, vec![2, 0]);
        assert_eq!(n.col_shift, vec![0, 1]);
        assert_eq!(n.matrix, vec![1, 1, 1, 1]);
        assert!(denormalize_check(&n, &m, 2, 2));
    }

    #[test]
    fn odd_matrix_untouched() {
        let m = vec![3, 5, 7, 9];
        let n = normalize(&m, 2, 2);
        assert_eq!(n.matrix, m);
        assert_eq!(n.row_shift, vec![0, 0]);
        assert_eq!(n.col_shift, vec![0, 0]);
    }

    #[test]
    fn zero_rows_and_columns() {
        let m = vec![
            0, 6, //
            0, 2, //
        ];
        let n = normalize(&m, 2, 2);
        // Column 0 is all zero: shift 0. Column 1 factor 2.
        assert!(denormalize_check(&n, &m, 2, 2));
        assert_eq!(n.matrix[1] % 2, 1);
    }

    #[test]
    fn no_all_even_rows_or_cols_after() {
        use crate::util::Rng;
        let mut rng = Rng::seed_from(3);
        for _ in 0..20 {
            let (d_in, d_out) = ((rng.below(6 - 1) + 1), (rng.below(6 - 1) + 1));
            let m: Vec<i64> =
                (0..d_in * d_out).map(|_| rng.range_i64(-64, 64) * 2).collect();
            let n = normalize(&m, d_in, d_out);
            assert!(denormalize_check(&n, &m, d_in, d_out));
            for j in 0..d_in {
                let row: Vec<i64> =
                    (0..d_out).map(|i| n.matrix[j * d_out + i]).filter(|&v| v != 0).collect();
                if !row.is_empty() {
                    assert!(row.iter().any(|v| v % 2 != 0), "row {j} all even: {row:?}");
                }
            }
        }
    }
}
