//! The schema-versioned metrics snapshot (obs schema v1), in the
//! [`crate::perf::schema`] style: a single JSON document built from
//! sorted maps so the rendered bytes are deterministic for a given
//! registry state.
//!
//! Shape (all maps sorted by name):
//!
//! ```json
//! {
//!   "schema_version": 1,
//!   "kind": "obs_metrics",
//!   "dropped_events": 0,
//!   "counters": {"coordinator.shard.0.hits": 8},
//!   "gauges": {"serve.queue_depth": 0},
//!   "histograms": {
//!     "serve.queue_wait_us": {
//!       "count": 4, "sum": 120, "min": 12, "max": 60,
//!       "p50": 31, "p99": 60, "buckets": [[4, 1], [5, 2], [6, 1]]
//!     }
//!   }
//! }
//! ```
//!
//! `buckets` pairs are `[log2_index, count]`: bucket 0 holds exact
//! zeros, bucket `i` holds `[2^(i-1), 2^i - 1]` (see
//! [`super::metrics::HIST_BUCKETS`]).

use super::metrics::HistSnapshot;
use crate::json::Value;

/// Version of the snapshot document layout. Bump on any breaking
/// change to field names or shapes.
pub const SCHEMA_VERSION: u32 = 1;

fn obj(entries: Vec<(&str, Value)>) -> Value {
    Value::Object(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn int(v: u64) -> Value {
    Value::Int(v as i64)
}

/// One histogram as a JSON object.
pub fn hist_value(h: &HistSnapshot) -> Value {
    let buckets = h
        .buckets
        .iter()
        .map(|&(i, n)| Value::Array(vec![int(i as u64), int(n)]))
        .collect();
    obj(vec![
        ("count", int(h.count)),
        ("sum", int(h.sum)),
        ("min", int(h.min)),
        ("max", int(h.max)),
        ("p50", int(h.p50)),
        ("p99", int(h.p99)),
        ("buckets", Value::Array(buckets)),
    ])
}

/// The full snapshot document for the current registry state.
pub fn snapshot_value() -> Value {
    let snap = super::metrics().snapshot();
    let counters = Value::Object(snap.counters.into_iter().map(|(k, v)| (k, int(v))).collect());
    let gauges = Value::Object(snap.gauges.into_iter().map(|(k, v)| (k, Value::Int(v))).collect());
    let histograms =
        Value::Object(snap.histograms.into_iter().map(|(k, h)| (k, hist_value(&h))).collect());
    obj(vec![
        ("schema_version", int(SCHEMA_VERSION as u64)),
        ("kind", Value::Str("obs_metrics".into())),
        ("dropped_events", int(super::dropped_events())),
        ("counters", counters),
        ("gauges", gauges),
        ("histograms", histograms),
    ])
}

/// The snapshot rendered as compact JSON.
pub fn render() -> String {
    crate::json::to_string(&snapshot_value())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    #[test]
    fn snapshot_document_round_trips_and_is_versioned() {
        // Register through the global registry under test-unique names
        // (the registry is process-global and shared across tests).
        let c = crate::obs::metrics().counter("test.schema.counter");
        c.add(41);
        c.inc();
        crate::obs::metrics().gauge("test.schema.gauge").set(-3);
        let h = crate::obs::metrics().histogram("test.schema.hist");
        h.record(0);
        h.record(9);

        let v = json::parse(&render()).expect("snapshot is valid JSON");
        assert_eq!(v.get("schema_version").unwrap().as_i64().unwrap(), SCHEMA_VERSION as i64);
        assert_eq!(v.get("kind").unwrap().as_str().unwrap(), "obs_metrics");
        assert!(v.get("dropped_events").unwrap().as_i64().is_ok());
        assert_eq!(
            v.get("counters").unwrap().get("test.schema.counter").unwrap().as_i64().unwrap(),
            42
        );
        assert_eq!(v.get("gauges").unwrap().get("test.schema.gauge").unwrap().as_i64().unwrap(), -3);
        let hist = v.get("histograms").unwrap().get("test.schema.hist").unwrap();
        assert_eq!(hist.get("count").unwrap().as_i64().unwrap(), 2);
        assert_eq!(hist.get("min").unwrap().as_i64().unwrap(), 0);
        assert_eq!(hist.get("max").unwrap().as_i64().unwrap(), 9);
        let buckets = hist.get("buckets").unwrap().as_array().unwrap();
        assert_eq!(buckets.len(), 2, "bucket 0 (zeros) and bucket 4 ([8,15])");
        assert_eq!(buckets[0].as_array().unwrap()[0].as_i64().unwrap(), 0);
        assert_eq!(buckets[1].as_array().unwrap()[0].as_i64().unwrap(), 4);
    }
}
