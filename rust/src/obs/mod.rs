//! Structured observability: hierarchical spans + a metrics registry,
//! dependency-free and thread-safe.
//!
//! The perf lab ([`crate::perf`]) answers "how fast is the optimizer on
//! a fixed suite"; this module answers "where did *this* compile, *this*
//! cache lookup, *this* served job spend its time" in a live process.
//! Two facilities share the module:
//!
//! * **Spans** ([`span`]) — scoped RAII guards on the monotonic clock.
//!   A span records one *complete* event (begin timestamp + duration)
//!   when its guard drops, with parent/child nesting tracked per thread
//!   and deterministic counters attached as args ([`Span::arg`]).
//!   Events land in a bounded per-thread buffer; overflow is counted in
//!   [`dropped_events`], never silently discarded. Tracing is **off by
//!   default**: the disabled path is one relaxed atomic load and no
//!   allocation ([`enabled`]), so instrumentation can live on hot paths.
//! * **Metrics** ([`metrics`]) — a process-global registry of named
//!   counters, gauges, and fixed-log2-bucket histograms ([`metrics::Counter`],
//!   [`metrics::Gauge`], [`metrics::Histogram`]). Handles are plain
//!   atomics (always on — recording is an atomic add), snapshotted into
//!   the schema-versioned document of [`schema`].
//!
//! Exporters ([`export`]): Chrome trace-event JSON (loadable in
//! Perfetto / `chrome://tracing`) and a JSONL event log. The CLI wires
//! both through `--trace-out` on `perf` / `explore` / `serve`
//! ([`begin_trace`] / [`TraceSession::finish`]); `serve` with a
//! `.jsonl` path streams incrementally with size-based rotation
//! instead ([`trace`]). The serve wire exposes the metrics snapshot as
//! a `{"type": "metrics"}` control line, and its stats lines carry
//! rolling-window latency digests ([`window`]). Recorded logs are
//! analyzed offline by `da4ml obs report|critical-path|diff|check`
//! ([`analyze`]).
//!
//! **Determinism contract**: timing lives *beside* the deterministic
//! surfaces, never inside them. Enabling tracing must not change a
//! single reply byte of `da4ml serve` — pinned by
//! `rust/tests/failure_injection.rs`. Full field reference:
//! `docs/observability.md`.

pub mod analyze;
pub mod export;
pub mod metrics;
pub mod schema;
pub mod trace;
pub mod window;

pub use metrics::{metrics, Counter, Gauge, Histogram, MetricsRegistry};
pub use trace::{StreamConfig, StreamingTraceSession};
pub use window::WindowedHistogram;

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Per-thread event-buffer bound: past it new events are dropped (and
/// counted in [`dropped_events`]) instead of growing without bound.
pub const MAX_EVENTS_PER_THREAD: usize = 1 << 16;

static ENABLED: AtomicBool = AtomicBool::new(false);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

/// The trace epoch: every timestamp is microseconds since the first
/// clock access of the process (monotonic, never wall-clock).
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the trace epoch (monotonic clock).
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// Whether span tracing is enabled — the *only* cost instrumentation
/// pays when tracing is off (one relaxed atomic load).
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span recording on (idempotent). Pins the trace epoch first so
/// the first span never sees a zero-initialized clock.
pub fn enable() {
    let _ = epoch();
    ENABLED.store(true, Ordering::SeqCst);
}

/// Turn span recording off (idempotent). Spans already open finish
/// recording; new ones become no-ops.
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// One attached span argument value.
#[derive(Debug, Clone, PartialEq)]
pub enum ArgValue {
    /// A deterministic counter (the common case).
    Int(i64),
    /// A label (job id, strategy name, …).
    Str(String),
}

/// One recorded complete event: a closed span.
#[derive(Debug, Clone)]
pub struct Event {
    /// Span name (static — names are a closed vocabulary, args carry
    /// the specifics).
    pub name: &'static str,
    /// Category (subsystem: `cmvm`, `cse`, `nn`, `serve`, `explore`).
    pub cat: &'static str,
    /// Unique span id (process-global).
    pub span_id: u64,
    /// Enclosing span id on the same thread (`0` = root).
    pub parent: u64,
    /// Recording thread (small stable integer, assigned on first use).
    pub tid: u64,
    /// Begin timestamp, microseconds since the trace epoch.
    pub ts_us: u64,
    /// Duration in microseconds.
    pub dur_us: u64,
    /// Attached counters/labels, in attachment order.
    pub args: Vec<(&'static str, ArgValue)>,
}

/// One thread's bounded event buffer, registered globally so
/// [`drain_events`] can collect from every thread.
struct ThreadBuf {
    events: Mutex<Vec<Event>>,
}

fn buffers() -> &'static Mutex<Vec<Arc<ThreadBuf>>> {
    static BUFFERS: OnceLock<Mutex<Vec<Arc<ThreadBuf>>>> = OnceLock::new();
    BUFFERS.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    /// (tid, this thread's buffer) — registered on first use.
    static LOCAL: (u64, Arc<ThreadBuf>) = {
        let tid = NEXT_TID.fetch_add(1, Ordering::SeqCst);
        let buf = Arc::new(ThreadBuf { events: Mutex::new(Vec::new()) });
        buffers().lock().unwrap().push(Arc::clone(&buf));
        (tid, buf)
    };
    /// Open-span stack (ids) for parent/child nesting.
    static STACK: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
}

fn thread_id() -> u64 {
    LOCAL.with(|(tid, _)| *tid)
}

fn push_event(event: Event) {
    LOCAL.with(|(_, buf)| {
        let mut events = buf.events.lock().unwrap();
        if events.len() < MAX_EVENTS_PER_THREAD {
            events.push(event);
        } else {
            DROPPED.fetch_add(1, Ordering::SeqCst);
        }
    });
}

/// The RAII span guard: records one complete event when dropped. When
/// tracing is disabled this is an inert `None` — no id, no clock read,
/// no allocation.
#[must_use = "a span records its duration when dropped; bind it to a variable"]
pub struct Span {
    meta: Option<Box<SpanMeta>>,
}

struct SpanMeta {
    name: &'static str,
    cat: &'static str,
    id: u64,
    parent: u64,
    tid: u64,
    start_us: u64,
    args: Vec<(&'static str, ArgValue)>,
}

/// Open a span. The guard must be bound (`let _span = …` or a named
/// binding when attaching args) — its drop point is the span end.
pub fn span(cat: &'static str, name: &'static str) -> Span {
    if !enabled() {
        return Span { meta: None };
    }
    let id = NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed);
    let tid = thread_id();
    let parent = STACK.with(|stack| {
        let mut stack = stack.borrow_mut();
        let parent = stack.last().copied().unwrap_or(0);
        stack.push(id);
        parent
    });
    Span {
        meta: Some(Box::new(SpanMeta {
            name,
            cat,
            id,
            parent,
            tid,
            start_us: now_us(),
            args: Vec::new(),
        })),
    }
}

impl Span {
    /// Whether this guard is recording (tracing was enabled when it
    /// opened). Lets callers skip expensive arg computation.
    pub fn is_active(&self) -> bool {
        self.meta.is_some()
    }

    /// Attach a deterministic counter to the span.
    pub fn arg(&mut self, key: &'static str, value: i64) {
        if let Some(meta) = &mut self.meta {
            meta.args.push((key, ArgValue::Int(value)));
        }
    }

    /// Attach a label, computed lazily — the closure only runs when the
    /// span is recording, so the disabled path never allocates.
    pub fn arg_str<F: FnOnce() -> String>(&mut self, key: &'static str, value: F) {
        if let Some(meta) = &mut self.meta {
            meta.args.push((key, ArgValue::Str(value())));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(meta) = self.meta.take() else { return };
        STACK.with(|stack| {
            let mut stack = stack.borrow_mut();
            // RAII guarantees LIFO per thread; tolerate surprises
            // instead of corrupting the nesting of later spans.
            if stack.last() == Some(&meta.id) {
                stack.pop();
            } else {
                stack.retain(|&id| id != meta.id);
            }
        });
        let end = now_us();
        push_event(Event {
            name: meta.name,
            cat: meta.cat,
            span_id: meta.id,
            parent: meta.parent,
            tid: meta.tid,
            ts_us: meta.start_us,
            dur_us: end.saturating_sub(meta.start_us),
            args: meta.args,
        });
    }
}

/// Record a complete event with explicit timestamps — for intervals
/// that cross threads and cannot be an RAII guard (e.g. a job's
/// queue-wait, which begins on the reader thread and ends on a worker).
/// No-op when tracing is disabled.
pub fn complete_event(
    cat: &'static str,
    name: &'static str,
    start_us: u64,
    end_us: u64,
    args: Vec<(&'static str, ArgValue)>,
) {
    if !enabled() {
        return;
    }
    let id = NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed);
    push_event(Event {
        name,
        cat,
        span_id: id,
        parent: 0,
        tid: thread_id(),
        ts_us: start_us,
        dur_us: end_us.saturating_sub(start_us),
        args,
    });
}

/// Collect (and clear) every thread's recorded events, sorted by
/// (timestamp, span id) so the export order is deterministic for a
/// quiescent process.
pub fn drain_events() -> Vec<Event> {
    let bufs: Vec<Arc<ThreadBuf>> = buffers().lock().unwrap().clone();
    let mut out = Vec::new();
    for buf in bufs {
        out.append(&mut buf.events.lock().unwrap());
    }
    out.sort_by_key(|e| (e.ts_us, e.span_id));
    out
}

/// Events dropped by full per-thread buffers since the last
/// [`take_dropped_events`].
pub fn dropped_events() -> u64 {
    DROPPED.load(Ordering::SeqCst)
}

/// Events currently waiting in the per-thread buffers (trace-buffer
/// pressure): how close the process is to dropping. Counts events
/// recorded but not yet collected by [`drain_events`] — under the
/// streaming exporter this is at most one flush interval's worth.
pub fn buffered_events() -> u64 {
    let bufs: Vec<Arc<ThreadBuf>> = buffers().lock().unwrap().clone();
    bufs.iter().map(|b| b.events.lock().unwrap().len() as u64).sum()
}

/// Read and reset the dropped-event counter.
pub fn take_dropped_events() -> u64 {
    DROPPED.swap(0, Ordering::SeqCst)
}

/// An active `--trace-out` session: created by [`begin_trace`] (which
/// enables tracing), finished by [`TraceSession::finish`] (which
/// disables tracing, drains the buffers, and writes the artifacts).
pub struct TraceSession {
    path: String,
}

/// Enable tracing and bind the output path. A `.jsonl` path selects the
/// JSONL event-log exporter; anything else gets Chrome trace-event
/// JSON. The metrics snapshot is always written beside the trace (see
/// [`metrics_sibling`]).
pub fn begin_trace(path: &str) -> TraceSession {
    enable();
    TraceSession { path: path.to_string() }
}

/// The metrics-snapshot path derived from a trace path:
/// `trace.json` → `trace.metrics.json`, `trace.jsonl` →
/// `trace.metrics.json`, anything else gets `.metrics.json` appended.
pub fn metrics_sibling(path: &str) -> String {
    for suffix in [".jsonl", ".json"] {
        if let Some(stem) = path.strip_suffix(suffix) {
            return format!("{stem}.metrics.json");
        }
    }
    format!("{path}.metrics.json")
}

impl TraceSession {
    /// Disable tracing, drain every buffer, and write the trace plus
    /// the metrics snapshot. Returns `(trace_path, metrics_path)`.
    pub fn finish(self) -> crate::Result<(String, String)> {
        disable();
        let events = drain_events();
        let body = if self.path.ends_with(".jsonl") {
            export::jsonl(&events)
        } else {
            crate::json::to_string(&export::chrome_value(&events))
        };
        std::fs::write(&self.path, body)?;
        let metrics_path = metrics_sibling(&self.path);
        std::fs::write(&metrics_path, schema::render())?;
        Ok((self.path, metrics_path))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    /// Tests that flip the global enable flag and drain the shared
    /// buffers serialize on this lock (unit tests share one process).
    pub(crate) fn obs_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _guard = obs_lock();
        disable();
        let _ = drain_events();
        {
            let mut s = span("test", "disabled.span");
            assert!(!s.is_active());
            s.arg("n", 1);
            s.arg_str("label", || panic!("must not evaluate when disabled"));
        }
        let events = drain_events();
        assert!(
            events.iter().all(|e| e.name != "disabled.span"),
            "disabled span leaked an event"
        );
    }

    #[test]
    fn spans_nest_and_attach_args() {
        let _guard = obs_lock();
        disable();
        let _ = drain_events();
        enable();
        {
            let mut outer = span("test", "nest.outer");
            outer.arg("depth", 0);
            {
                let mut inner = span("test", "nest.inner");
                inner.arg("depth", 1);
                inner.arg_str("label", || "leaf".into());
            }
        }
        disable();
        let events = drain_events();
        let outer = events.iter().find(|e| e.name == "nest.outer").expect("outer recorded");
        let inner = events.iter().find(|e| e.name == "nest.inner").expect("inner recorded");
        assert_eq!(inner.parent, outer.span_id, "nesting tracked per thread");
        assert_eq!(outer.parent, 0, "outer span is a root");
        assert!(inner.ts_us >= outer.ts_us);
        assert_eq!(inner.args.len(), 2);
        assert_eq!(inner.args[1], ("label", ArgValue::Str("leaf".into())));
    }

    #[test]
    fn complete_events_cross_threads() {
        let _guard = obs_lock();
        disable();
        let _ = drain_events();
        enable();
        complete_event("test", "xthread.wait", 10, 35, vec![("seq", ArgValue::Int(7))]);
        disable();
        let events = drain_events();
        let e = events.iter().find(|e| e.name == "xthread.wait").expect("recorded");
        assert_eq!((e.ts_us, e.dur_us), (10, 25));
        assert_eq!(e.parent, 0);
    }

    /// The trace-validity pin: the Chrome exporter's output must parse
    /// back through the in-tree JSON layer, with the trace-event shape
    /// Perfetto expects (`ph: "X"`, numeric ts/dur, args object).
    #[test]
    fn chrome_trace_round_trips_through_json_parse() {
        let _guard = obs_lock();
        disable();
        let _ = drain_events();
        enable();
        {
            let mut s = span("test", "chrome.case");
            s.arg("steps", 42);
            s.arg_str("id", || "job \"quoted\" ✓".into());
        }
        disable();
        let events = drain_events();
        let text = json::to_string(&export::chrome_value(&events));
        let v = json::parse(&text).expect("chrome trace is valid JSON");
        assert_eq!(v.get("displayTimeUnit").unwrap().as_str().unwrap(), "ms");
        let traced = v.get("traceEvents").unwrap().as_array().unwrap();
        let e = traced
            .iter()
            .find(|e| e.get("name").unwrap().as_str().unwrap() == "chrome.case")
            .expect("span exported");
        assert_eq!(e.get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(e.get("pid").unwrap().as_i64().unwrap(), 1);
        assert!(e.get("ts").unwrap().as_i64().is_ok());
        assert!(e.get("dur").unwrap().as_i64().is_ok());
        let args = e.get("args").unwrap();
        assert_eq!(args.get("steps").unwrap().as_i64().unwrap(), 42);
        assert_eq!(args.get("id").unwrap().as_str().unwrap(), "job \"quoted\" ✓");

        // The JSONL exporter: one valid JSON object per line.
        let log = export::jsonl(&events);
        for line in log.lines() {
            let v = json::parse(line).expect("JSONL line is valid JSON");
            assert!(v.get("name").unwrap().as_str().is_ok());
        }
    }

    #[test]
    fn metrics_sibling_naming() {
        assert_eq!(metrics_sibling("trace.json"), "trace.metrics.json");
        assert_eq!(metrics_sibling("a/b/trace.jsonl"), "a/b/trace.metrics.json");
        assert_eq!(metrics_sibling("trace.out"), "trace.out.metrics.json");
    }

    #[test]
    fn dropped_events_counter_accounts_overflow() {
        let _guard = obs_lock();
        disable();
        let _ = drain_events();
        let _ = take_dropped_events();
        // Fill this thread's buffer to the cap directly, then record
        // one span over it: the span must be dropped and counted.
        LOCAL.with(|(_, buf)| {
            let mut events = buf.events.lock().unwrap();
            while events.len() < MAX_EVENTS_PER_THREAD {
                events.push(Event {
                    name: "fill",
                    cat: "test",
                    span_id: 0,
                    parent: 0,
                    tid: 0,
                    ts_us: 0,
                    dur_us: 0,
                    args: Vec::new(),
                });
            }
        });
        enable();
        drop(span("test", "over.cap"));
        disable();
        assert_eq!(take_dropped_events(), 1, "overflow must be counted, not silent");
        let events = drain_events();
        assert!(events.iter().all(|e| e.name != "over.cap"));
    }
}
