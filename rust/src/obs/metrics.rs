//! The metrics registry: named counters, gauges, and fixed-log2-bucket
//! histograms behind cheap cloneable handles.
//!
//! Handles are `Arc`-shared atomics — recording is a relaxed atomic
//! operation with no lock and no allocation, so metrics stay on
//! unconditionally (unlike spans, which gate on [`super::enabled`]).
//! The registry itself is process-global and only locked at
//! registration and snapshot time.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Histogram bucket count: bucket 0 holds exact zeros, bucket `i`
/// (1..=64) holds values in `[2^(i-1), 2^i - 1]`.
pub const HIST_BUCKETS: usize = 65;

/// A monotonically increasing counter.
#[derive(Clone)]
pub struct Counter {
    inner: Arc<AtomicU64>,
}

impl Counter {
    fn new() -> Self {
        Counter { inner: Arc::new(AtomicU64::new(0)) }
    }

    /// Add `delta` to the counter.
    pub fn add(&self, delta: u64) {
        self.inner.fetch_add(delta, Ordering::Relaxed);
    }

    /// Increment by one.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.inner.load(Ordering::Relaxed)
    }
}

/// A point-in-time signed value (queue depth, busy workers, …).
#[derive(Clone)]
pub struct Gauge {
    inner: Arc<AtomicI64>,
}

impl Gauge {
    fn new() -> Self {
        Gauge { inner: Arc::new(AtomicI64::new(0)) }
    }

    /// Set the gauge to an absolute value.
    pub fn set(&self, value: i64) {
        self.inner.store(value, Ordering::Relaxed);
    }

    /// Add a (possibly negative) delta.
    pub fn add(&self, delta: i64) {
        self.inner.fetch_add(delta, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> i64 {
        self.inner.load(Ordering::Relaxed)
    }
}

struct HistCore {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    /// `u64::MAX` until the first record (lets `fetch_min` work).
    min: AtomicU64,
    max: AtomicU64,
}

/// A fixed-log2-bucket histogram of `u64` samples (typically
/// microseconds). Bucket boundaries are powers of two, so recording is
/// a `leading_zeros` plus three atomic adds — no allocation, no lock.
#[derive(Clone)]
pub struct Histogram {
    inner: Arc<HistCore>,
}

/// A consistent-enough copy of a histogram's state (individual atomics
/// are read without a global lock; totals can lag by in-flight records).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    /// Smallest recorded value (0 when empty).
    pub min: u64,
    /// Largest recorded value (0 when empty).
    pub max: u64,
    /// Estimated 50th percentile — the upper bound of the bucket
    /// holding the `ceil(count/2)`-th sample, clamped to `[min, max]`.
    /// Exact when all samples share a bucket, otherwise within 2× of
    /// the true percentile (see [`percentile_from_buckets`] for the
    /// full clamping rules).
    pub p50: u64,
    /// Estimated 99th percentile, same convention (rank
    /// `ceil(count * 99/100)`, clamped to `[1, count]`).
    pub p99: u64,
    /// Non-empty buckets as `(log2_index, count)` pairs, ascending.
    pub buckets: Vec<(u32, u64)>,
}

/// Bucket index of a sample. The boundaries are pinned:
///
/// * `0` → bucket 0 (exact zeros only),
/// * an exact power of two `2^(i-1)` is the *lowest* value of bucket
///   `i` — so `1` → bucket 1, `2` → bucket 2, `1024` → bucket 11,
/// * `2^i - 1` is the *highest* value of bucket `i`,
/// * `u64::MAX` → bucket 64 (the only bucket whose upper bound is not
///   `2^i - 1`).
pub fn bucket_index(value: u64) -> usize {
    if value == 0 {
        0
    } else {
        (64 - value.leading_zeros()) as usize
    }
}

/// Inclusive upper bound of a bucket: the largest value it can hold
/// (`0` for bucket 0, `u64::MAX` for bucket 64, `2^i - 1` otherwise).
pub fn bucket_upper(index: usize) -> u64 {
    match index {
        0 => 0,
        64 => u64::MAX,
        i => (1u64 << i) - 1,
    }
}

/// Percentile estimate over sparse `(log2_index, count)` buckets.
///
/// The clamping rules (shared by the cumulative [`Histogram`] and the
/// rolling-window variant in [`super::window`]):
///
/// 1. The rank of the q-quantile sample is `ceil(count * q)`, 1-based,
///    clamped to `[1, count]` — so p99 of a single sample is that
///    sample's bucket, never an empty rank.
/// 2. The estimate is the *upper bound* of the bucket holding that
///    rank, clamped to `[min, max]` of the recorded samples. The
///    result is exact when all samples share one bucket (the bound
///    clamps to `max`), and otherwise within 2× of the true
///    percentile (one log2 bucket of slack).
pub fn percentile_from_buckets(
    buckets: &[(u32, u64)],
    count: u64,
    min: u64,
    max: u64,
    q_num: u64,
    q_den: u64,
) -> u64 {
    let rank = (count * q_num).div_ceil(q_den).clamp(1, count);
    let mut seen = 0u64;
    for &(i, n) in buckets {
        seen += n;
        if seen >= rank {
            return bucket_upper(i as usize).clamp(min, max);
        }
    }
    max
}

impl Histogram {
    fn new() -> Self {
        Histogram {
            inner: Arc::new(HistCore {
                buckets: std::array::from_fn(|_| AtomicU64::new(0)),
                count: AtomicU64::new(0),
                sum: AtomicU64::new(0),
                min: AtomicU64::new(u64::MAX),
                max: AtomicU64::new(0),
            }),
        }
    }

    /// Record one sample.
    pub fn record(&self, value: u64) {
        let core = &*self.inner;
        core.buckets[bucket_index(value)].fetch_add(1, Ordering::Relaxed);
        core.count.fetch_add(1, Ordering::Relaxed);
        core.sum.fetch_add(value, Ordering::Relaxed);
        core.min.fetch_min(value, Ordering::Relaxed);
        core.max.fetch_max(value, Ordering::Relaxed);
    }

    /// Snapshot counts and derive the percentile estimates.
    pub fn snapshot(&self) -> HistSnapshot {
        let core = &*self.inner;
        let mut buckets = Vec::new();
        let mut count = 0u64;
        for (i, b) in core.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n > 0 {
                buckets.push((i as u32, n));
                count += n;
            }
        }
        if count == 0 {
            return HistSnapshot::default();
        }
        let min = core.min.load(Ordering::Relaxed);
        let max = core.max.load(Ordering::Relaxed);
        HistSnapshot {
            count,
            sum: core.sum.load(Ordering::Relaxed),
            min,
            max,
            p50: percentile_from_buckets(&buckets, count, min, max, 50, 100),
            p99: percentile_from_buckets(&buckets, count, min, max, 99, 100),
            buckets,
        }
    }
}

enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

/// The process-global name → metric map. Names are sorted (BTreeMap) so
/// every snapshot is deterministically ordered.
pub struct MetricsRegistry {
    inner: Mutex<BTreeMap<String, Metric>>,
}

/// Everything the registry knows, sorted by name within each kind.
#[derive(Debug, Clone, Default)]
pub struct RegistrySnapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, i64)>,
    pub histograms: Vec<(String, HistSnapshot)>,
}

/// The process-global registry.
pub fn metrics() -> &'static MetricsRegistry {
    static REGISTRY: OnceLock<MetricsRegistry> = OnceLock::new();
    REGISTRY.get_or_init(|| MetricsRegistry { inner: Mutex::new(BTreeMap::new()) })
}

impl MetricsRegistry {
    fn entry<T, F, G>(&self, name: &str, make: F, pick: G) -> T
    where
        F: FnOnce() -> Metric,
        G: FnOnce(&Metric) -> Option<T>,
    {
        let mut map = self.inner.lock().unwrap();
        let metric = map.entry(name.to_string()).or_insert_with(make);
        pick(metric).unwrap_or_else(|| {
            panic!("metric '{name}' already registered as a {}", metric.kind())
        })
    }

    /// Get or create the counter `name`.
    ///
    /// # Panics
    /// If `name` is already registered as a different kind.
    pub fn counter(&self, name: &str) -> Counter {
        self.entry(
            name,
            || Metric::Counter(Counter::new()),
            |m| match m {
                Metric::Counter(c) => Some(c.clone()),
                _ => None,
            },
        )
    }

    /// Get or create the gauge `name`.
    ///
    /// # Panics
    /// If `name` is already registered as a different kind.
    pub fn gauge(&self, name: &str) -> Gauge {
        self.entry(
            name,
            || Metric::Gauge(Gauge::new()),
            |m| match m {
                Metric::Gauge(g) => Some(g.clone()),
                _ => None,
            },
        )
    }

    /// Get or create the histogram `name`.
    ///
    /// # Panics
    /// If `name` is already registered as a different kind.
    pub fn histogram(&self, name: &str) -> Histogram {
        self.entry(
            name,
            || Metric::Histogram(Histogram::new()),
            |m| match m {
                Metric::Histogram(h) => Some(h.clone()),
                _ => None,
            },
        )
    }

    /// Snapshot every registered metric, sorted by name.
    pub fn snapshot(&self) -> RegistrySnapshot {
        let map = self.inner.lock().unwrap();
        let mut snap = RegistrySnapshot::default();
        for (name, metric) in map.iter() {
            match metric {
                Metric::Counter(c) => snap.counters.push((name.clone(), c.get())),
                Metric::Gauge(g) => snap.gauges.push((name.clone(), g.get())),
                Metric::Histogram(h) => snap.histograms.push((name.clone(), h.snapshot())),
            }
        }
        snap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_log2() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(1023), 10);
        assert_eq!(bucket_index(1024), 11);
        assert_eq!(bucket_index(u64::MAX), 64);
        assert_eq!(bucket_upper(0), 0);
        assert_eq!(bucket_upper(2), 3);
        assert_eq!(bucket_upper(64), u64::MAX);
    }

    /// The edge pins of the bucketing scheme: every exact power of two
    /// `2^(i-1)` opens bucket `i`, every `2^i - 1` closes it, and each
    /// bucket's upper bound maps back into the same bucket — so a
    /// percentile estimate (a bucket upper bound) always lands in the
    /// bucket it summarizes.
    #[test]
    fn every_power_of_two_is_a_bucket_floor() {
        for i in 1..=63usize {
            let floor = 1u64 << (i - 1);
            assert_eq!(bucket_index(floor), i, "2^{} opens bucket {i}", i - 1);
            assert_eq!(bucket_index(floor - 1), i - 1, "2^{} - 1 closes bucket {}", i - 1, i - 1);
            assert_eq!(bucket_index(bucket_upper(i)), i, "upper bound stays in bucket {i}");
        }
        // The top bucket: 2^63 .. u64::MAX all land in bucket 64.
        assert_eq!(bucket_index(1u64 << 63), 64);
        assert_eq!(bucket_index(u64::MAX - 1), 64);
        assert_eq!(bucket_index(u64::MAX), 64);
        assert_eq!(bucket_index(bucket_upper(64)), 64);
        // The zero bucket holds zeros only.
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_upper(0), 0);
    }

    #[test]
    fn u64_max_samples_round_trip_without_overflow() {
        let h = Histogram::new();
        h.record(u64::MAX);
        let s = h.snapshot();
        assert_eq!(s.buckets, vec![(64, 1)]);
        assert_eq!((s.min, s.max), (u64::MAX, u64::MAX));
        assert_eq!((s.p50, s.p99), (u64::MAX, u64::MAX), "bucket 64's bound is u64::MAX");
    }

    /// Percentile clamping rules, pinned against hand-computed ranks:
    /// rank = ceil(count * q) clamped to [1, count]; result = bucket
    /// upper bound clamped to [min, max].
    #[test]
    fn percentile_rank_and_clamp_rules_are_exact() {
        // Two buckets: 4 samples of 10 ([8,15]) + 1 sample of 100
        // ([64,127]). p50 rank = ceil(5*0.5) = 3 → bucket 4, bound 15.
        // p99 rank = ceil(5*0.99) = 5 → bucket 7, bound 127 clamped to
        // max = 100.
        let buckets = vec![(4u32, 4u64), (7, 1)];
        assert_eq!(percentile_from_buckets(&buckets, 5, 10, 100, 50, 100), 15);
        assert_eq!(percentile_from_buckets(&buckets, 5, 10, 100, 99, 100), 100);
        // Single sample: every percentile clamps to that sample.
        let one = vec![(4u32, 1u64)];
        assert_eq!(percentile_from_buckets(&one, 1, 9, 9, 1, 100), 9);
        assert_eq!(percentile_from_buckets(&one, 1, 9, 9, 99, 100), 9);
        // min-clamp: when the rank bucket's bound undershoots min
        // (possible only via the [min, max] clamp on bucket 0).
        let zeros_then_big = vec![(0u32, 1u64), (10, 99)];
        assert_eq!(percentile_from_buckets(&zeros_then_big, 100, 0, 1000, 1, 100), 0);
    }

    #[test]
    fn empty_histogram_snapshot_is_zeroed() {
        let h = Histogram::new();
        assert_eq!(h.snapshot(), HistSnapshot::default());
    }

    #[test]
    fn histogram_percentiles_track_the_tail() {
        let h = Histogram::new();
        // 99 fast samples and one slow outlier: p50 stays in the fast
        // bucket, p99 reaches the outlier's bucket.
        for _ in 0..99 {
            h.record(10);
        }
        h.record(5_000);
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        assert_eq!(s.sum, 99 * 10 + 5_000);
        assert_eq!((s.min, s.max), (10, 5_000));
        assert_eq!(s.p50, 15, "upper bound of the [8, 15] bucket");
        assert_eq!(s.p99, 5_000, "outlier bucket bound clamped to max");
        assert_eq!(s.buckets, vec![(4, 99), (13, 1)]);
    }

    #[test]
    fn single_value_histogram_pins_both_percentiles() {
        let h = Histogram::new();
        h.record(7);
        let s = h.snapshot();
        assert_eq!((s.p50, s.p99), (7, 7), "clamped to [min, max]");
    }

    #[test]
    fn zero_samples_live_in_bucket_zero() {
        let h = Histogram::new();
        h.record(0);
        h.record(0);
        let s = h.snapshot();
        assert_eq!(s.buckets, vec![(0, 2)]);
        assert_eq!((s.p50, s.p99), (0, 0));
    }

    #[test]
    fn registry_handles_share_state_and_snapshot_sorts() {
        let reg = MetricsRegistry { inner: Mutex::new(BTreeMap::new()) };
        let c1 = reg.counter("b.count");
        let c2 = reg.counter("b.count");
        c1.add(2);
        c2.inc();
        let g = reg.gauge("a.depth");
        g.set(5);
        g.add(-2);
        reg.histogram("c.wait").record(100);
        let snap = reg.snapshot();
        assert_eq!(snap.counters, vec![("b.count".to_string(), 3)]);
        assert_eq!(snap.gauges, vec![("a.depth".to_string(), 3)]);
        assert_eq!(snap.histograms.len(), 1);
        assert_eq!(snap.histograms[0].0, "c.wait");
        assert_eq!(snap.histograms[0].1.count, 1);
    }

    #[test]
    #[should_panic(expected = "already registered as a counter")]
    fn kind_mismatch_panics() {
        let reg = MetricsRegistry { inner: Mutex::new(BTreeMap::new()) };
        let _ = reg.counter("x");
        let _ = reg.gauge("x");
    }
}
