//! Streaming trace export with size-based rotation — the long-lived
//! server's alternative to [`super::TraceSession`]'s buffer-at-exit
//! model.
//!
//! A [`StreamingTraceSession`] enables tracing and starts one flusher
//! thread that periodically drains the per-thread event buffers
//! ([`super::drain_events`]) and appends each event as a JSONL line
//! ([`super::export::jsonl_event`]) to the output file, so a crash
//! loses at most one flush interval of events instead of the whole
//! run. With a rotation cap (`--trace-rotate-mb` on `da4ml serve`) the
//! total trace footprint on disk is bounded:
//!
//! * the live file rotates to `<path>.1` when appending the next line
//!   would push it past **half** the cap,
//! * exactly one rotated generation is kept (`<path>.1` is replaced),
//!   so `size(path) + size(path.1) ≤ cap` at all times,
//! * every file (re)starts with a `trace_meta` header line carrying
//!   the cumulative `dropped_events` counter, which is process-global
//!   — rotation discards old *events*, never the drop accounting.
//!
//! Streaming is JSONL-only: a Chrome trace is a single JSON document
//! and cannot be appended to ([`super::metrics_sibling`] still gets a
//! metrics snapshot at finish). `da4ml obs check/report` consume the
//! rotated pair by concatenation; `trace_meta` lines are recognized
//! and skipped by [`super::analyze`].

use std::fs::File;
use std::io::Write;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// How often the flusher thread drains the event buffers.
const FLUSH_INTERVAL: Duration = Duration::from_millis(200);

/// Configuration for [`StreamingTraceSession::begin`].
pub struct StreamConfig {
    /// Output path (must end in `.jsonl`).
    pub path: String,
    /// Total on-disk cap in bytes across the live file and the one
    /// rotated generation; `None` = never rotate.
    pub rotate_bytes: Option<u64>,
}

struct Sink {
    path: String,
    /// Per-file rotation threshold (`rotate_bytes / 2`), `None` = no
    /// rotation.
    file_cap: Option<u64>,
    file: File,
    written: u64,
    rotations: u64,
}

impl Sink {
    fn open(path: &str, rotate_bytes: Option<u64>) -> std::io::Result<Sink> {
        let file = File::create(path)?;
        let mut sink = Sink {
            path: path.to_string(),
            // Two generations share the cap; a cap so small the header
            // alone would trip it still rotates correctly (the header
            // is written without a cap check).
            file_cap: rotate_bytes.map(|b| (b / 2).max(1)),
            file,
            written: 0,
            rotations: 0,
        };
        sink.write_meta()?;
        Ok(sink)
    }

    /// The `<path>.1` rotated-generation path.
    fn rotated_path(path: &str) -> String {
        format!("{path}.1")
    }

    fn write_meta(&mut self) -> std::io::Result<()> {
        // Keys sorted like every other artifact in the tree. The
        // dropped counter is process-global: each generation's header
        // carries the cumulative value at its creation, so the
        // accounting survives however many files rotation discards.
        let line = format!(
            "{{\"dropped_events\":{},\"kind\":\"trace_meta\",\"rotation\":{}}}\n",
            super::dropped_events(),
            self.rotations,
        );
        self.written += line.len() as u64;
        self.file.write_all(line.as_bytes())
    }

    fn rotate(&mut self) -> std::io::Result<()> {
        self.file.flush()?;
        std::fs::rename(&self.path, Self::rotated_path(&self.path))?;
        self.file = File::create(&self.path)?;
        self.written = 0;
        self.rotations += 1;
        self.write_meta()
    }

    fn append(&mut self, line: &str) -> std::io::Result<()> {
        let len = line.len() as u64 + 1;
        if let Some(cap) = self.file_cap {
            if self.written > 0 && self.written + len > cap {
                self.rotate()?;
            }
        }
        self.written += len;
        self.file.write_all(line.as_bytes())?;
        self.file.write_all(b"\n")
    }

    fn flush_events(&mut self) -> std::io::Result<()> {
        let events = super::drain_events();
        for event in &events {
            self.append(&super::export::jsonl_event(event))?;
        }
        if !events.is_empty() {
            self.file.flush()?;
        }
        Ok(())
    }
}

/// An active streaming `--trace-out` session: tracing is enabled for
/// its lifetime, a background thread incrementally flushes events, and
/// [`StreamingTraceSession::finish`] performs the final drain and
/// writes the metrics snapshot beside the trace.
pub struct StreamingTraceSession {
    path: String,
    stop: Arc<AtomicBool>,
    error: Arc<Mutex<Option<std::io::Error>>>,
    flusher: Option<std::thread::JoinHandle<()>>,
}

impl StreamingTraceSession {
    /// Enable tracing and start the flusher thread. Fails if the path
    /// does not end in `.jsonl` (streaming has no Chrome-JSON mode) or
    /// the output file cannot be created.
    pub fn begin(cfg: StreamConfig) -> crate::Result<StreamingTraceSession> {
        anyhow::ensure!(
            cfg.path.ends_with(".jsonl"),
            "streaming trace export requires a .jsonl path, got '{}' \
             (Chrome trace JSON cannot be appended to)",
            cfg.path
        );
        super::enable();
        let mut sink = Sink::open(&cfg.path, cfg.rotate_bytes)?;
        let stop = Arc::new(AtomicBool::new(false));
        let error: Arc<Mutex<Option<std::io::Error>>> = Arc::new(Mutex::new(None));
        let flusher = {
            let stop = Arc::clone(&stop);
            let error = Arc::clone(&error);
            std::thread::Builder::new()
                .name("obs-flush".into())
                .spawn(move || {
                    loop {
                        let stopping = stop.load(Ordering::SeqCst);
                        if let Err(e) = sink.flush_events() {
                            *error.lock().unwrap() = Some(e);
                            return;
                        }
                        if stopping {
                            // The final drain above ran *after* the
                            // stop flag was observed, so every event
                            // recorded before finish() is on disk.
                            return;
                        }
                        std::thread::sleep(FLUSH_INTERVAL);
                    }
                })
                .expect("spawn obs flusher thread")
        };
        Ok(StreamingTraceSession { path: cfg.path, stop, error, flusher: Some(flusher) })
    }

    /// Disable tracing, stop the flusher (which performs one final
    /// drain), and write the metrics snapshot. Returns
    /// `(trace_path, metrics_path)`.
    pub fn finish(mut self) -> crate::Result<(String, String)> {
        super::disable();
        self.stop.store(true, Ordering::SeqCst);
        if let Some(handle) = self.flusher.take() {
            let _ = handle.join();
        }
        if let Some(e) = self.error.lock().unwrap().take() {
            return Err(anyhow::anyhow!("trace flusher failed: {e}"));
        }
        let metrics_path = super::metrics_sibling(&self.path);
        std::fs::write(&metrics_path, super::schema::render())?;
        Ok((self.path, metrics_path))
    }
}

impl Drop for StreamingTraceSession {
    fn drop(&mut self) {
        // finish() already joined; this only runs on early drops
        // (error paths) — stop the thread rather than leaking it.
        self.stop.store(true, Ordering::SeqCst);
        if let Some(handle) = self.flusher.take() {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::tests::obs_lock;

    fn temp_path(tag: &str) -> String {
        let mut p = std::env::temp_dir();
        p.push(format!(
            "da4ml_trace_{tag}_{}_{:?}.jsonl",
            std::process::id(),
            std::thread::current().id()
        ));
        p.to_str().unwrap().to_string()
    }

    #[test]
    fn non_jsonl_paths_are_rejected() {
        match StreamingTraceSession::begin(StreamConfig {
            path: "trace.json".into(),
            rotate_bytes: None,
        }) {
            Ok(_) => panic!("chrome paths cannot stream"),
            Err(err) => assert!(err.to_string().contains(".jsonl"), "{err}"),
        }
    }

    #[test]
    fn streams_events_and_writes_metrics_sibling() {
        let _guard = obs_lock();
        crate::obs::disable();
        let _ = crate::obs::drain_events();
        let path = temp_path("stream");
        let session =
            StreamingTraceSession::begin(StreamConfig { path: path.clone(), rotate_bytes: None })
                .unwrap();
        {
            let mut s = crate::obs::span("test", "stream.case");
            s.arg("n", 1);
        }
        let (trace_path, metrics_path) = session.finish().unwrap();
        let body = std::fs::read_to_string(&trace_path).unwrap();
        let mut names = Vec::new();
        for line in body.lines() {
            let v = crate::json::parse(line).expect("every line is valid JSON");
            if let Ok(name) = v.get("name").map(|n| n.as_str().unwrap().to_string()) {
                names.push(name);
            } else {
                assert_eq!(v.get("kind").unwrap().as_str().unwrap(), "trace_meta");
            }
        }
        assert!(names.contains(&"stream.case".to_string()), "{names:?}");
        assert!(std::fs::metadata(&metrics_path).is_ok());
        let _ = std::fs::remove_file(&trace_path);
        let _ = std::fs::remove_file(&metrics_path);
    }

    /// The rotation bound: under a sustained hammer of events the live
    /// file plus the single rotated generation never exceed the cap.
    #[test]
    fn rotation_bounds_total_disk_under_sustained_load() {
        let _guard = obs_lock();
        crate::obs::disable();
        let _ = crate::obs::drain_events();
        let path = temp_path("rotate");
        let cap: u64 = 16 * 1024;
        let session = StreamingTraceSession::begin(StreamConfig {
            path: path.clone(),
            rotate_bytes: Some(cap),
        })
        .unwrap();
        // Hammer: far more event bytes than the cap, across several
        // flush intervals so rotation happens mid-stream.
        for round in 0..4i64 {
            for i in 0..600i64 {
                let mut s = crate::obs::span("test", "rotate.hammer");
                s.arg("round", round);
                s.arg("i", i);
            }
            std::thread::sleep(Duration::from_millis(250));
            let live = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
            let old = std::fs::metadata(Sink::rotated_path(&path)).map(|m| m.len()).unwrap_or(0);
            assert!(
                live + old <= cap,
                "trace disk {live} + {old} exceeds the {cap}-byte cap mid-run"
            );
        }
        let (trace_path, metrics_path) = session.finish().unwrap();
        let live = std::fs::metadata(&trace_path).map(|m| m.len()).unwrap_or(0);
        let rotated_path = Sink::rotated_path(&trace_path);
        let old = std::fs::metadata(&rotated_path).map(|m| m.len()).unwrap_or(0);
        assert!(live + old <= cap, "final trace disk {live} + {old} exceeds the {cap}-byte cap");
        assert!(old > 0, "the hammer must actually have rotated");
        // Rotation preserved the drop accounting: every generation
        // opens with a trace_meta header carrying the global counter.
        for p in [&trace_path, &rotated_path] {
            let body = std::fs::read_to_string(p).unwrap();
            let first = crate::json::parse(body.lines().next().unwrap()).unwrap();
            assert_eq!(first.get("kind").unwrap().as_str().unwrap(), "trace_meta");
            assert!(first.get("dropped_events").unwrap().as_i64().is_ok());
        }
        let _ = std::fs::remove_file(&trace_path);
        let _ = std::fs::remove_file(&rotated_path);
        let _ = std::fs::remove_file(&metrics_path);
    }
}
