//! Offline analysis over JSONL trace logs — the engine behind
//! `da4ml obs report|critical-path|diff|check`.
//!
//! Input is the JSONL event log written by [`super::export::jsonl`] or
//! streamed by [`super::trace::StreamingTraceSession`] (whose
//! `trace_meta` header lines are recognized and skipped, their
//! `dropped_events` counters retained). Rotated generations are
//! analyzed by passing both files — the caller concatenates
//! `<path>.1` before `<path>`.
//!
//! Four analyses:
//!
//! * [`report`] — per-span-name aggregation (count / p50 / p99 /
//!   total µs) as a [`crate::report::Table`]. Percentiles here are
//!   *exact* (offline analysis holds every duration), unlike the
//!   log2-bucket estimates of the live registry.
//! * [`critical_path`] — per-trace phase reconstruction: every event
//!   carrying a `trace_id` arg is grouped by it and ordered by begin
//!   timestamp, yielding the decode → queue_wait → exec → write story
//!   of each serve job. Jobs whose execution lacks a queue-wait
//!   interval (or vice versa) are structural problems.
//! * [`diff`] — two-log comparison with perf-lab semantics
//!   ([`crate::perf::diff::DiffOutcome`]): a span name present in the
//!   baseline but missing from the candidate is a regression; mean
//!   and p99 per span may grow by the relative tolerance with a 1 ms
//!   absolute jitter floor.
//! * [`check`] — structural validation: span ids unique (exactly-once
//!   closure), parents exist on the same thread and contain their
//!   children in time, per-trace serve phases appear at most once.
//!   Missing parents downgrade to notes when the log admits drops
//!   (`dropped_events > 0`) — rotation and buffer overflow discard
//!   events, not the invariant.

use crate::json::Value;
use crate::perf::diff::DiffOutcome;
use crate::report::Table;
use std::collections::BTreeMap;

/// One parsed trace-log event (owned mirror of [`super::Event`]).
#[derive(Debug, Clone)]
pub struct LogEvent {
    pub name: String,
    pub cat: String,
    pub tid: u64,
    pub ts_us: u64,
    pub dur_us: u64,
    pub span_id: u64,
    pub parent: u64,
    pub args: Vec<(String, Value)>,
}

impl LogEvent {
    /// String arg by key (e.g. `trace_id`, `id`).
    pub fn arg_str(&self, key: &str) -> Option<&str> {
        self.args.iter().find(|(k, _)| k == key).and_then(|(_, v)| match v {
            Value::Str(s) => Some(s.as_str()),
            _ => None,
        })
    }

    fn end_us(&self) -> u64 {
        self.ts_us.saturating_add(self.dur_us)
    }
}

/// A parsed JSONL log: the events plus what the meta lines said.
#[derive(Debug, Default)]
pub struct ParsedLog {
    pub events: Vec<LogEvent>,
    /// Largest `dropped_events` any `trace_meta` line reported (the
    /// counter is cumulative, so the max is the final value seen).
    pub dropped_events: u64,
}

fn field_u64(v: &Value, key: &str) -> crate::Result<u64> {
    let raw = v.get(key)?.as_i64()?;
    anyhow::ensure!(raw >= 0, "field '{key}' is negative: {raw}");
    Ok(raw as u64)
}

/// Parse a JSONL event log. Every non-blank line must be a JSON
/// object: either an event (has `name`) or a `trace_meta` header from
/// the streaming exporter. Anything else is a parse error carrying the
/// 1-based line number.
pub fn parse_log(text: &str) -> crate::Result<ParsedLog> {
    let mut out = ParsedLog::default();
    for (idx, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let lineno = idx + 1;
        let v = crate::json::parse(line)
            .map_err(|e| anyhow::anyhow!("line {lineno}: not valid JSON: {e}"))?;
        if let Some(kind) = v.get_opt("kind").and_then(|k| k.as_str().ok()) {
            if kind == "trace_meta" {
                let dropped = field_u64(&v, "dropped_events")
                    .map_err(|e| anyhow::anyhow!("line {lineno}: bad trace_meta: {e}"))?;
                out.dropped_events = out.dropped_events.max(dropped);
                continue;
            }
        }
        let parse_event = || -> crate::Result<LogEvent> {
            let args = match v.get_opt("args") {
                Some(Value::Object(map)) => {
                    map.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
                }
                _ => Vec::new(),
            };
            Ok(LogEvent {
                name: v.get("name")?.as_str()?.to_string(),
                cat: v.get("cat")?.as_str()?.to_string(),
                tid: field_u64(&v, "tid")?,
                ts_us: field_u64(&v, "ts_us")?,
                dur_us: field_u64(&v, "dur_us")?,
                span_id: field_u64(&v, "span_id")?,
                parent: field_u64(&v, "parent")?,
                args,
            })
        };
        let event = parse_event()
            .map_err(|e| anyhow::anyhow!("line {lineno}: not a trace event: {e}"))?;
        out.events.push(event);
    }
    Ok(out)
}

/// Exact percentile of a *sorted* duration list, using the same rank
/// convention as the live histograms (`ceil(count * q)`, 1-based,
/// clamped to `[1, count]`) so the offline and online digests agree on
/// which sample a percentile names.
fn exact_percentile(sorted: &[u64], q_num: u64, q_den: u64) -> u64 {
    let count = sorted.len() as u64;
    if count == 0 {
        return 0;
    }
    let rank = (count * q_num).div_ceil(q_den).clamp(1, count);
    sorted[(rank - 1) as usize]
}

/// Per-span-name aggregate of one log.
#[derive(Debug, Clone, Default)]
pub struct SpanAggregate {
    pub count: u64,
    pub total_us: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub mean_us: f64,
}

/// Aggregate durations per span name, sorted by name.
pub fn aggregate(events: &[LogEvent]) -> BTreeMap<String, SpanAggregate> {
    let mut durs: BTreeMap<String, Vec<u64>> = BTreeMap::new();
    for e in events {
        durs.entry(e.name.clone()).or_default().push(e.dur_us);
    }
    durs.into_iter()
        .map(|(name, mut d)| {
            d.sort_unstable();
            let count = d.len() as u64;
            let total: u64 = d.iter().sum();
            let agg = SpanAggregate {
                count,
                total_us: total,
                p50_us: exact_percentile(&d, 50, 100),
                p99_us: exact_percentile(&d, 99, 100),
                mean_us: total as f64 / count as f64,
            };
            (name, agg)
        })
        .collect()
}

/// The `obs report` table: one row per span name.
pub fn report(events: &[LogEvent]) -> Table {
    let mut table =
        Table::new("Trace span report", &["span", "count", "p50_us", "p99_us", "total_us"]);
    for (name, agg) in aggregate(events) {
        table.push(vec![
            name,
            agg.count.to_string(),
            agg.p50_us.to_string(),
            agg.p99_us.to_string(),
            agg.total_us.to_string(),
        ]);
    }
    table
}

/// `obs critical-path` output: the per-trace table plus any structural
/// problems (a problem list non-empty should exit nonzero).
#[derive(Debug)]
pub struct CriticalPaths {
    pub table: Table,
    /// Traces whose phase story is broken (execution without a
    /// queue-wait, queue-wait without execution, out-of-order phases).
    pub problems: Vec<String>,
    pub traces: usize,
}

/// Group events by their `trace_id` arg and reconstruct each trace's
/// phase sequence in begin-timestamp order. Events without a
/// `trace_id` (compile internals, accept spans) are not part of any
/// job's path and are ignored here.
pub fn critical_path(events: &[LogEvent]) -> CriticalPaths {
    let mut traces: BTreeMap<String, Vec<&LogEvent>> = BTreeMap::new();
    for e in events {
        if let Some(tid) = e.arg_str("trace_id") {
            traces.entry(tid.to_string()).or_default().push(e);
        }
    }
    let mut table =
        Table::new("Per-trace critical path", &["trace_id", "path", "busy_us", "span_us"]);
    let mut problems = Vec::new();
    let trace_count = traces.len();
    for (trace_id, mut evs) in traces {
        evs.sort_by_key(|e| (e.ts_us, e.span_id));
        let path: Vec<String> = evs
            .iter()
            .map(|e| {
                let phase = e.name.strip_prefix("serve.").unwrap_or(&e.name);
                format!("{phase}({}us)", e.dur_us)
            })
            .collect();
        let busy: u64 = evs.iter().map(|e| e.dur_us).sum();
        let first = evs.iter().map(|e| e.ts_us).min().unwrap_or(0);
        let last = evs.iter().map(|e| e.end_us()).max().unwrap_or(0);
        table.push(vec![
            trace_id.clone(),
            path.join(" -> "),
            busy.to_string(),
            last.saturating_sub(first).to_string(),
        ]);
        let wait = evs.iter().find(|e| e.name == "serve.queue_wait");
        let exec = evs.iter().find(|e| e.name == "serve.execute");
        match (wait, exec) {
            (Some(w), Some(x)) => {
                if w.ts_us > x.ts_us {
                    problems.push(format!(
                        "trace '{trace_id}': queue_wait begins at {}us, after execute at {}us",
                        w.ts_us, x.ts_us
                    ));
                }
            }
            (None, Some(_)) => {
                problems
                    .push(format!("trace '{trace_id}': executed but has no queue_wait interval"));
            }
            (Some(_), None) => {
                problems.push(format!("trace '{trace_id}': queue_wait without an execution"));
            }
            (None, None) => {}
        }
    }
    CriticalPaths { table, problems, traces: trace_count }
}

/// Relative growth tolerance `obs diff` applies to per-span times when
/// the caller does not override it (same spirit as the perf baseline's
/// default).
pub const DEFAULT_TIME_TOLERANCE: f64 = 0.5;

/// Absolute jitter floor in µs: a span whose mean/p99 grew by less
/// than this never counts as a regression, whatever the ratio —
/// microsecond spans jitter more than any tolerance can bound.
pub const JITTER_FLOOR_US: u64 = 1_000;

/// Compare a candidate log against a baseline log, span name by span
/// name. Perf-lab semantics: coverage loss (a span name disappearing)
/// is a regression, new span names are notes, and per-span mean / p99
/// may grow by `time_tolerance` (relative) above the baseline with a
/// [`JITTER_FLOOR_US`] absolute floor.
pub fn diff(baseline: &[LogEvent], candidate: &[LogEvent], time_tolerance: f64) -> DiffOutcome {
    let mut out = DiffOutcome::default();
    let base = aggregate(baseline);
    let cand = aggregate(candidate);
    for (name, b) in &base {
        out.checked += 1;
        let Some(c) = cand.get(name) else {
            out.regressions.push(format!(
                "span '{name}' ({} events in baseline) is missing from the candidate trace",
                b.count
            ));
            continue;
        };
        if b.count != c.count {
            out.notes.push(format!(
                "span '{name}': count {} -> {} (different workloads? per-event \
                 comparison still applies)",
                b.count, c.count
            ));
        }
        let mut gate = |metric: &str, want: f64, got: f64| {
            out.checked += 1;
            let limit = want * (1.0 + time_tolerance);
            if got > limit && got - want > JITTER_FLOOR_US as f64 {
                out.regressions.push(format!(
                    "span '{name}': {metric} {got:.0}us exceeds baseline {want:.0}us \
                     (+{:.0}% tolerance, {}us floor)",
                    time_tolerance * 100.0,
                    JITTER_FLOOR_US
                ));
            }
        };
        gate("mean", b.mean_us, c.mean_us);
        gate("p99", b.p99_us as f64, c.p99_us as f64);
    }
    for name in cand.keys() {
        if !base.contains_key(name) {
            out.notes.push(format!("span '{name}' is new in the candidate trace"));
        }
    }
    out
}

/// `obs check` output.
#[derive(Debug, Default)]
pub struct CheckReport {
    /// Structural violations; non-empty should exit nonzero.
    pub errors: Vec<String>,
    /// Informational findings (e.g. unresolvable parents on a log
    /// that admits drops).
    pub notes: Vec<String>,
    pub events: usize,
}

impl CheckReport {
    /// True when the log passed validation.
    pub fn passed(&self) -> bool {
        self.errors.is_empty()
    }
}

/// Structurally validate a log: every span id unique (a duplicate
/// means a span closed twice — the exactly-once invariant the serve
/// tests pin live, checked here offline), every parent reference
/// resolvable on the same thread and containing its child in time,
/// and every per-trace serve phase appearing at most once. When the
/// log admits dropped events (`dropped > 0`, from the `trace_meta`
/// headers), unresolvable parents become notes — the event may have
/// been dropped or rotated away, which is bounded-buffer behavior,
/// not corruption.
pub fn check(events: &[LogEvent], dropped: u64) -> CheckReport {
    let mut out = CheckReport { events: events.len(), ..Default::default() };
    let mut by_id: BTreeMap<u64, &LogEvent> = BTreeMap::new();
    for e in events {
        if e.span_id == 0 {
            out.errors.push(format!("event '{}' at {}us has span_id 0", e.name, e.ts_us));
            continue;
        }
        if let Some(prev) = by_id.insert(e.span_id, e) {
            out.errors.push(format!(
                "span id {} recorded twice ('{}' at {}us and '{}' at {}us) — \
                 a span closed more than once",
                e.span_id, prev.name, prev.ts_us, e.name, e.ts_us
            ));
        }
    }
    for e in events {
        if e.parent == 0 {
            continue;
        }
        let Some(p) = by_id.get(&e.parent) else {
            let msg = format!(
                "span {} ('{}') references missing parent {}",
                e.span_id, e.name, e.parent
            );
            if dropped > 0 {
                out.notes.push(format!("{msg} (log admits {dropped} dropped events)"));
            } else {
                out.errors.push(msg);
            }
            continue;
        };
        if p.tid != e.tid {
            out.errors.push(format!(
                "span {} ('{}') on tid {} has parent {} on tid {} — nesting is \
                 per-thread",
                e.span_id, e.name, e.tid, p.span_id, p.tid
            ));
        }
        if e.ts_us < p.ts_us || e.end_us() > p.end_us() {
            out.errors.push(format!(
                "span {} ('{}') [{}, {}]us escapes its parent {} [{}, {}]us",
                e.span_id,
                e.name,
                e.ts_us,
                e.end_us(),
                p.span_id,
                p.ts_us,
                p.end_us()
            ));
        }
    }
    // Per-trace exactly-once: a serve job passes each phase once.
    let mut seen: BTreeMap<(String, String), u64> = BTreeMap::new();
    for e in events {
        if let Some(tid) = e.arg_str("trace_id") {
            *seen.entry((tid.to_string(), e.name.clone())).or_default() += 1;
        }
    }
    for ((trace_id, name), n) in seen {
        if n > 1 {
            out.errors.push(format!(
                "trace '{trace_id}': phase '{name}' recorded {n} times (expected at most once)"
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(
        name: &str,
        span_id: u64,
        parent: u64,
        tid: u64,
        ts: u64,
        dur: u64,
        trace_id: Option<&str>,
    ) -> LogEvent {
        LogEvent {
            name: name.into(),
            cat: "serve".into(),
            tid,
            ts_us: ts,
            dur_us: dur,
            span_id,
            parent,
            args: trace_id
                .map(|t| vec![("trace_id".to_string(), Value::Str(t.into()))])
                .unwrap_or_default(),
        }
    }

    #[test]
    fn parse_round_trips_the_jsonl_exporter() {
        let events = vec![
            crate::obs::Event {
                name: "serve.execute",
                cat: "serve",
                span_id: 7,
                parent: 0,
                tid: 2,
                ts_us: 100,
                dur_us: 40,
                args: vec![
                    ("id", crate::obs::ArgValue::Str("a".into())),
                    ("trace_id", crate::obs::ArgValue::Str("client-0#0".into())),
                ],
            },
            crate::obs::Event {
                name: "serve.queue_wait",
                cat: "serve",
                span_id: 8,
                parent: 0,
                tid: 2,
                ts_us: 90,
                dur_us: 10,
                args: vec![("trace_id", crate::obs::ArgValue::Str("client-0#0".into()))],
            },
        ];
        let text = format!(
            "{{\"dropped_events\":3,\"kind\":\"trace_meta\",\"rotation\":0}}\n{}",
            crate::obs::export::jsonl(&events)
        );
        let log = parse_log(&text).unwrap();
        assert_eq!(log.events.len(), 2);
        assert_eq!(log.dropped_events, 3);
        assert_eq!(log.events[0].name, "serve.execute");
        assert_eq!(log.events[0].arg_str("trace_id"), Some("client-0#0"));
        assert_eq!(log.events[0].span_id, 7);
    }

    #[test]
    fn parse_rejects_garbage_with_line_numbers() {
        let err = parse_log("{\"name\": \"ok\", \"cat\": \"c\", \"tid\": 1, \"ts_us\": 0, \
                             \"dur_us\": 1, \"span_id\": 1, \"parent\": 0}\nnot json\n")
            .unwrap_err();
        assert!(err.to_string().contains("line 2"), "{err}");
        let err = parse_log("{\"cat\": \"only\"}\n").unwrap_err();
        assert!(err.to_string().contains("line 1"), "{err}");
    }

    #[test]
    fn report_aggregates_exact_percentiles_per_name() {
        let mut events = Vec::new();
        for (i, dur) in [10u64, 20, 30, 40].iter().enumerate() {
            events.push(ev("serve.execute", i as u64 + 1, 0, 1, i as u64 * 100, *dur, None));
        }
        events.push(ev("serve.decode", 9, 0, 1, 5, 7, None));
        let agg = aggregate(&events);
        let exec = &agg["serve.execute"];
        assert_eq!(exec.count, 4);
        assert_eq!(exec.total_us, 100);
        assert_eq!(exec.p50_us, 20, "rank ceil(4*0.5) = 2 -> 20");
        assert_eq!(exec.p99_us, 40, "rank ceil(4*0.99) = 4 -> 40");
        let rendered = report(&events).render();
        assert!(rendered.contains("serve.execute"), "{rendered}");
        assert!(rendered.contains("serve.decode"), "{rendered}");
    }

    #[test]
    fn critical_path_orders_phases_and_flags_missing_waits() {
        let events = vec![
            ev("serve.decode", 1, 0, 1, 0, 5, Some("c#0")),
            ev("serve.queue_wait", 2, 0, 2, 5, 10, Some("c#0")),
            ev("serve.execute", 3, 0, 2, 15, 100, Some("c#0")),
            ev("serve.write", 4, 0, 2, 115, 3, Some("c#0")),
            // A broken trace: executed with no queue_wait.
            ev("serve.execute", 5, 0, 2, 200, 50, Some("c#1")),
        ];
        let cp = critical_path(&events);
        assert_eq!(cp.traces, 2);
        let rendered = cp.table.render();
        assert!(
            rendered.contains("decode(5us) -> queue_wait(10us) -> execute(100us) -> write(3us)"),
            "{rendered}"
        );
        assert_eq!(cp.problems.len(), 1);
        assert!(cp.problems[0].contains("c#1"), "{:?}", cp.problems);
        assert!(cp.problems[0].contains("no queue_wait"), "{:?}", cp.problems);
    }

    #[test]
    fn diff_gates_on_growth_and_coverage() {
        let base = vec![
            ev("serve.execute", 1, 0, 1, 0, 10_000, None),
            ev("serve.decode", 2, 0, 1, 0, 100, None),
        ];
        // Identical candidate: passes.
        assert!(diff(&base, &base, DEFAULT_TIME_TOLERANCE).passed());
        // 3x slower execute (well past +50% and the 1ms floor).
        let slow = vec![
            ev("serve.execute", 1, 0, 1, 0, 30_000, None),
            ev("serve.decode", 2, 0, 1, 0, 100, None),
        ];
        let d = diff(&base, &slow, DEFAULT_TIME_TOLERANCE);
        assert!(!d.passed());
        assert!(d.regressions[0].contains("serve.execute"), "{:?}", d.regressions);
        // A sub-millisecond span can triple without tripping the floor.
        let jitter = vec![
            ev("serve.execute", 1, 0, 1, 0, 10_000, None),
            ev("serve.decode", 2, 0, 1, 0, 300, None),
        ];
        assert!(diff(&base, &jitter, DEFAULT_TIME_TOLERANCE).passed());
        // Coverage loss: a span name vanishing is a regression.
        let missing = vec![ev("serve.execute", 1, 0, 1, 0, 10_000, None)];
        let d = diff(&base, &missing, DEFAULT_TIME_TOLERANCE);
        assert!(!d.passed());
        assert!(d.regressions[0].contains("serve.decode"), "{:?}", d.regressions);
    }

    #[test]
    fn check_catches_structural_violations() {
        // Clean log passes.
        let ok = vec![
            ev("outer", 1, 0, 1, 0, 100, None),
            ev("inner", 2, 1, 1, 10, 20, None),
            ev("serve.execute", 3, 0, 2, 50, 10, Some("c#0")),
        ];
        let r = check(&ok, 0);
        assert!(r.passed(), "{:?}", r.errors);

        // Duplicate span id = double closure.
        let dup = vec![ev("a", 1, 0, 1, 0, 10, None), ev("a", 1, 0, 1, 20, 10, None)];
        assert!(check(&dup, 0).errors[0].contains("recorded twice"));

        // Missing parent: error on a complete log, note when drops
        // are admitted.
        let orphan = vec![ev("inner", 2, 99, 1, 10, 20, None)];
        assert!(check(&orphan, 0).errors[0].contains("missing parent"));
        let with_drops = check(&orphan, 5);
        assert!(with_drops.passed());
        assert!(with_drops.notes[0].contains("dropped"), "{:?}", with_drops.notes);

        // Child escaping its parent's interval.
        let escape = vec![ev("outer", 1, 0, 1, 0, 10, None), ev("inner", 2, 1, 1, 5, 50, None)];
        assert!(check(&escape, 0).errors[0].contains("escapes"));

        // Cross-thread parent.
        let xthread = vec![ev("outer", 1, 0, 1, 0, 100, None), ev("inner", 2, 1, 9, 5, 10, None)];
        assert!(check(&xthread, 0).errors[0].contains("per-thread"));

        // A trace phase recorded twice.
        let twice = vec![
            ev("serve.execute", 1, 0, 1, 0, 10, Some("c#0")),
            ev("serve.execute", 2, 0, 1, 50, 10, Some("c#0")),
        ];
        assert!(check(&twice, 0).errors[0].contains("recorded 2 times"));
    }
}
