//! Trace exporters: Chrome trace-event JSON (the
//! [Trace Event Format] consumed by Perfetto and `chrome://tracing`)
//! and a line-oriented JSONL event log for ad-hoc tooling (`grep`,
//! `jq`). Both are pure functions of a drained event list — see
//! [`super::drain_events`].
//!
//! [Trace Event Format]: https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU

use super::{ArgValue, Event};
use crate::json::Value;

fn obj(entries: Vec<(&str, Value)>) -> Value {
    Value::Object(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn event_args(event: &Event) -> Value {
    let mut args: Vec<(&str, Value)> = event
        .args
        .iter()
        .map(|(k, v)| {
            let v = match v {
                ArgValue::Int(i) => Value::Int(*i),
                ArgValue::Str(s) => Value::Str(s.clone()),
            };
            (*k, v)
        })
        .collect();
    // Span identity rides in args: the trace-event format has no
    // first-class span ids for complete ("X") events.
    args.push(("span_id", Value::Int(event.span_id as i64)));
    args.push(("parent", Value::Int(event.parent as i64)));
    obj(args)
}

fn chrome_event(event: &Event) -> Value {
    obj(vec![
        ("ph", Value::Str("X".into())),
        ("name", Value::Str(event.name.into())),
        ("cat", Value::Str(event.cat.into())),
        ("pid", Value::Int(1)),
        ("tid", Value::Int(event.tid as i64)),
        ("ts", Value::Int(event.ts_us as i64)),
        ("dur", Value::Int(event.dur_us as i64)),
        ("args", event_args(event)),
    ])
}

/// The Chrome trace document: every event as a complete ("X") event —
/// begin timestamp plus duration — so no begin/end pairing can ever be
/// unbalanced; nesting is implied by time containment per `tid`.
pub fn chrome_value(events: &[Event]) -> Value {
    obj(vec![
        ("displayTimeUnit", Value::Str("ms".into())),
        ("traceEvents", Value::Array(events.iter().map(chrome_event).collect())),
    ])
}

/// One event rendered as a compact JSONL log line (no trailing
/// newline) — the unit the streaming exporter ([`super::trace`])
/// appends incrementally.
pub fn jsonl_event(event: &Event) -> String {
    let line = obj(vec![
        ("name", Value::Str(event.name.into())),
        ("cat", Value::Str(event.cat.into())),
        ("tid", Value::Int(event.tid as i64)),
        ("ts_us", Value::Int(event.ts_us as i64)),
        ("dur_us", Value::Int(event.dur_us as i64)),
        ("span_id", Value::Int(event.span_id as i64)),
        ("parent", Value::Int(event.parent as i64)),
        ("args", event_args(event)),
    ]);
    crate::json::to_string(&line)
}

/// The JSONL event log: one compact JSON object per event, one per
/// line, in drain order (sorted by timestamp then span id).
pub fn jsonl(events: &[Event]) -> String {
    let mut out = String::new();
    for event in events {
        out.push_str(&jsonl_event(event));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Event> {
        vec![
            Event {
                name: "outer",
                cat: "test",
                span_id: 1,
                parent: 0,
                tid: 3,
                ts_us: 100,
                dur_us: 50,
                args: vec![("steps", ArgValue::Int(12))],
            },
            Event {
                name: "inner",
                cat: "test",
                span_id: 2,
                parent: 1,
                tid: 3,
                ts_us: 110,
                dur_us: 20,
                args: vec![("id", ArgValue::Str("j1".into()))],
            },
        ]
    }

    #[test]
    fn chrome_events_carry_identity_in_args() {
        let v = chrome_value(&sample());
        let events = v.get("traceEvents").unwrap().as_array().unwrap();
        assert_eq!(events.len(), 2);
        let inner = &events[1];
        assert_eq!(inner.get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(inner.get("tid").unwrap().as_i64().unwrap(), 3);
        let args = inner.get("args").unwrap();
        assert_eq!(args.get("parent").unwrap().as_i64().unwrap(), 1);
        assert_eq!(args.get("id").unwrap().as_str().unwrap(), "j1");
    }

    #[test]
    fn jsonl_is_one_object_per_line_in_order() {
        let log = jsonl(&sample());
        let lines: Vec<&str> = log.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = crate::json::parse(lines[0]).unwrap();
        assert_eq!(first.get("name").unwrap().as_str().unwrap(), "outer");
        assert_eq!(first.get("dur_us").unwrap().as_i64().unwrap(), 50);
        let second = crate::json::parse(lines[1]).unwrap();
        assert_eq!(second.get("parent").unwrap().as_i64().unwrap(), 1);
    }
}
