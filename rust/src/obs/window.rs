//! Rolling-window histograms: the same fixed-log2-bucket digest as
//! [`super::metrics::Histogram`], but over the *last W microseconds*
//! instead of the process lifetime — so a week-old latency spike
//! cannot pollute a live server's stats line forever.
//!
//! The window is a ring of `SLOTS` sub-histograms, each covering
//! `window / SLOTS` microseconds. Recording lands a sample in the slot
//! owning its timestamp; a slot whose epoch has lapsed is reset before
//! reuse, and snapshots merge only slots still inside the window. Both
//! operations take explicit timestamps (`record_at` / `snapshot_at`)
//! so expiry is a pure function of the arguments — the clock-reading
//! conveniences ([`WindowedHistogram::record`] /
//! [`WindowedHistogram::snapshot`]) just pass [`super::now_us`].
//!
//! Percentile derivation is shared byte-for-byte with the cumulative
//! histogram ([`super::metrics::percentile_from_buckets`]): on a single
//! window the two digests agree exactly (pinned by a unit test).
//!
//! Unlike registry histograms these are plain values guarded by one
//! mutex, owned by their call site (e.g. the socket server's stats
//! digests) — they are windows over a site, not process-global names.

use super::metrics::{percentile_from_buckets, HistSnapshot, HIST_BUCKETS};
use std::sync::Mutex;

/// Ring granularity: the window is covered by this many slots, so
/// expiry resolution is `window / SLOTS`.
pub const SLOTS: usize = 16;

/// Sentinel for "slot never written" (no valid epoch).
const EMPTY: u64 = u64::MAX;

struct Slot {
    /// Absolute slot number (`ts / slot_width`) this slot currently
    /// holds, or [`EMPTY`].
    epoch: u64,
    buckets: [u64; HIST_BUCKETS],
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Slot {
    fn new() -> Self {
        Slot { epoch: EMPTY, buckets: [0; HIST_BUCKETS], count: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    fn reset(&mut self, epoch: u64) {
        self.epoch = epoch;
        self.buckets = [0; HIST_BUCKETS];
        self.count = 0;
        self.sum = 0;
        self.min = u64::MAX;
        self.max = 0;
    }
}

/// A histogram whose snapshot covers only the last `window_us`
/// microseconds (to slot granularity).
pub struct WindowedHistogram {
    window_us: u64,
    slot_width_us: u64,
    ring: Mutex<Vec<Slot>>,
}

impl WindowedHistogram {
    /// A window of `window_us` microseconds (clamped to at least
    /// [`SLOTS`], so every slot spans ≥ 1 µs).
    pub fn new(window_us: u64) -> Self {
        let window_us = window_us.max(SLOTS as u64);
        WindowedHistogram {
            window_us,
            slot_width_us: window_us.div_ceil(SLOTS as u64),
            ring: Mutex::new((0..SLOTS).map(|_| Slot::new()).collect()),
        }
    }

    /// The configured window width in microseconds.
    pub fn window_us(&self) -> u64 {
        self.window_us
    }

    /// Record one sample stamped `now_us` (microseconds since the
    /// trace epoch). Samples are attributed to the slot owning their
    /// timestamp; a slot holding data from a lapsed epoch is reset
    /// first, so the ring never mixes generations.
    pub fn record_at(&self, now_us: u64, value: u64) {
        let epoch = now_us / self.slot_width_us;
        let mut ring = self.ring.lock().unwrap();
        let slot = &mut ring[(epoch % SLOTS as u64) as usize];
        if slot.epoch != epoch {
            slot.reset(epoch);
        }
        slot.buckets[super::metrics::bucket_index(value)] += 1;
        slot.count += 1;
        // Wrapping like the cumulative histogram's atomic sum, so the
        // two digests agree bit-for-bit even on extreme samples.
        slot.sum = slot.sum.wrapping_add(value);
        slot.min = slot.min.min(value);
        slot.max = slot.max.max(value);
    }

    /// Record one sample at the current monotonic time.
    pub fn record(&self, value: u64) {
        self.record_at(super::now_us(), value);
    }

    /// Digest of the samples whose slots are still inside the window
    /// ending at `now_us`. A slot is live when its epoch is within the
    /// last [`SLOTS`] epochs (the current one included); everything
    /// older has expired and is excluded without being touched.
    pub fn snapshot_at(&self, now_us: u64) -> HistSnapshot {
        let epoch = now_us / self.slot_width_us;
        let oldest_live = epoch.saturating_sub(SLOTS as u64 - 1);
        let ring = self.ring.lock().unwrap();
        let mut merged = [0u64; HIST_BUCKETS];
        let mut count = 0u64;
        let mut sum = 0u64;
        let mut min = u64::MAX;
        let mut max = 0u64;
        for slot in ring.iter() {
            if slot.epoch == EMPTY || slot.epoch < oldest_live || slot.epoch > epoch {
                continue;
            }
            for (m, b) in merged.iter_mut().zip(slot.buckets.iter()) {
                *m += b;
            }
            count += slot.count;
            sum = sum.wrapping_add(slot.sum);
            min = min.min(slot.min);
            max = max.max(slot.max);
        }
        if count == 0 {
            return HistSnapshot::default();
        }
        let buckets: Vec<(u32, u64)> = merged
            .iter()
            .enumerate()
            .filter(|&(_, &n)| n > 0)
            .map(|(i, &n)| (i as u32, n))
            .collect();
        HistSnapshot {
            count,
            sum,
            min,
            max,
            p50: percentile_from_buckets(&buckets, count, min, max, 50, 100),
            p99: percentile_from_buckets(&buckets, count, min, max, 99, 100),
            buckets,
        }
    }

    /// Digest of the window ending now.
    pub fn snapshot(&self) -> HistSnapshot {
        self.snapshot_at(super::now_us())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// On a single window the rolling digest must agree with the
    /// cumulative histogram exactly — same counts, same buckets, same
    /// percentile bytes.
    #[test]
    fn agrees_with_cumulative_histogram_inside_one_window() {
        let w = WindowedHistogram::new(1_000_000);
        // A registry histogram under a test-unique name: the registry
        // is process-global, so the name must not collide.
        let c = crate::obs::metrics().histogram("test.window.agreement");
        let samples = [0u64, 1, 7, 8, 15, 16, 100, 5_000, 5_000, 65_535, u64::MAX];
        for (i, &v) in samples.iter().enumerate() {
            w.record_at(10_000 * i as u64, v);
            c.record(v);
        }
        let ws = w.snapshot_at(10_000 * samples.len() as u64);
        let cs = c.snapshot();
        assert_eq!(ws, cs, "windowed and cumulative digests diverged on one window");
    }

    /// Expiry is deterministic in the explicit timestamps: advancing
    /// `now` past the window drops old samples at slot granularity,
    /// and a snapshot never mutates the ring.
    #[test]
    fn window_advance_expires_old_samples_deterministically() {
        let w = WindowedHistogram::new(SLOTS as u64 * 100); // slot = 100 µs
        w.record_at(50, 10); // slot epoch 0
        w.record_at(150, 20); // slot epoch 1
        assert_eq!(w.snapshot_at(200).count, 2, "both inside the window");
        // now = 1_550 → epoch 15, oldest live epoch = 15 - 15 = 0: the
        // epoch-0 sample is still (just) inside the window.
        assert_eq!(w.snapshot_at(1_550).count, 2, "epoch 0 is the oldest live slot");
        // now = 1_650 → epoch 16, oldest live = 1: the epoch-0 sample
        // has expired, epoch 1 survives.
        let s = w.snapshot_at(1_650);
        assert_eq!(s.count, 1);
        assert_eq!((s.min, s.max), (20, 20));
        // Snapshots are read-only: the same call repeated agrees.
        assert_eq!(w.snapshot_at(1_650), s);
        // A full window later everything is gone.
        assert_eq!(w.snapshot_at(10_000).count, 0);
    }

    /// A lapsed slot is reset on reuse, not merged: a sample landing in
    /// the same ring position one full revolution later must not see
    /// the old generation's counts.
    #[test]
    fn ring_reuse_resets_lapsed_slots() {
        let w = WindowedHistogram::new(SLOTS as u64 * 100);
        w.record_at(50, 1); // epoch 0, ring position 0
        w.record_at(50 + SLOTS as u64 * 100, 2); // epoch 16, same position
        let s = w.snapshot_at(50 + SLOTS as u64 * 100);
        assert_eq!(s.count, 1, "old generation must be reset, not merged");
        assert_eq!((s.min, s.max), (2, 2));
    }

    #[test]
    fn empty_window_snapshot_is_zeroed() {
        let w = WindowedHistogram::new(1_000);
        assert_eq!(w.snapshot_at(0), HistSnapshot::default());
        assert_eq!(w.snapshot_at(u64::MAX / 2), HistSnapshot::default());
    }

    #[test]
    fn tiny_window_is_clamped_to_slot_count() {
        let w = WindowedHistogram::new(1);
        assert_eq!(w.window_us(), SLOTS as u64);
        w.record_at(0, 5);
        assert_eq!(w.snapshot_at(0).count, 1);
    }
}
