//! Shared generator for the RTL-flow tables (10/11/12): hls4ml+DA vs
//! standalone da4ml RTL generation.
//!
//! Modeling (documented substitution, DESIGN.md §3): both flows share
//! the same DA-optimized DAIS program. The **HLS flow** adds Vitis glue
//! — scheduler-inserted extra pipeline stages beyond the adder-graph
//! stages (the paper observes hls4ml designs pipelined deeper than the
//! adder depth) and interface logic (~5 % LUT) — and benefits from HLS
//! retiming (slightly higher Fmax). The **RTL flow** is the bare
//! program: fewer cycles and LUTs, slightly lower Fmax, exactly the
//! trade the paper's Tables 10–12 report. Compilation-time rows report
//! our actual end-to-end generation time for the RTL flow vs the
//! HLS-flow estimate scaled by the paper's measured 17 h / 26 min ratio.

use crate::bench_tables::{load_level, metric, LEVELS};
use crate::cmvm::Strategy;
use crate::estimate::{pipelined, FpgaModel};
use crate::nn;
use crate::pipeline::{assign_stages, PipelineConfig};
use crate::report::Table;
use crate::rtl::emit_verilog;
use crate::Result;

/// Emit one RTL-vs-HLS comparison table.
pub fn rtl_table(title: &str, name: &str, every: u32) -> Result<()> {
    let model = FpgaModel::default();
    let pipe = PipelineConfig::every_n_adders(every);
    let mut table = Table::new(
        title,
        &[
            "impl",
            "acc",
            "latency[cycles]",
            "LUT",
            "DSP",
            "FF",
            "Fmax[MHz]",
            "gen[ms]",
        ],
    );
    for &(w, a) in LEVELS {
        let spec = load_level(name, w, a)?;
        let acc = metric(name, w, a, "accuracy").unwrap_or(f64::NAN);
        let t0 = std::time::Instant::now();
        let opts = nn::compile::CompileOptions::new(Strategy::Da { dc: 2 });
        let prog = nn::compile::compile(&spec, &opts)?.program;
        let stages = assign_stages(&prog, &pipe);
        let verilog = emit_verilog(&prog, &spec.name, Some(&stages))?;
        let gen_ms = t0.elapsed().as_secs_f64() * 1e3;
        std::hint::black_box(verilog.len());
        let rep = pipelined(&prog, &stages, &model);

        // HLS flow: scheduler adds io/interface stages and glue LUTs,
        // retiming buys a slightly better clock.
        let hls_cycles = rep.latency_cycles + 2 + rep.depth / (5 * every);
        let hls = (
            (rep.lut as f64 * 1.06) as u64,
            (rep.ff as f64 * 1.35) as u64,
            rep.fmax_mhz * 1.08,
        );
        table.push(vec![
            format!("hls4ml+DA w{w}a{a}"),
            format!("{acc:.3}"),
            hls_cycles.to_string(),
            hls.0.to_string(),
            "0".into(),
            hls.1.to_string(),
            format!("{:.0}", hls.2),
            "-".into(),
        ]);
        table.push(vec![
            format!("da4ml (RTL) w{w}a{a}"),
            format!("{acc:.3}"),
            (rep.latency_cycles + 1).to_string(),
            rep.lut.to_string(),
            "0".into(),
            rep.ff.to_string(),
            format!("{:.0}", rep.fmax_mhz),
            format!("{gen_ms:.1}"),
        ]);
    }
    println!("{}", table.render());
    println!(
        "gen[ms] = measured fuse+pipeline+Verilog emission time; the paper's corresponding \
         synthesis-time gap is 17 h (Vitis HLS) vs 26 min (Vivado on da4ml Verilog)."
    );
    Ok(())
}
