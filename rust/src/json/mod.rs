//! Minimal JSON codec (in-tree `serde_json` replacement for the offline
//! build environment).
//!
//! Parses the full JSON grammar into a [`Value`] tree with exact i64
//! integers (critical: network weights must round-trip bit-exactly),
//! and serializes [`Value`] back to text. The interchange surface with
//! the Python build layer is small and fully covered by tests.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// A JSON value. Integers are kept exact (`Int`) whenever the literal
/// has no fraction/exponent and fits i64.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Exact integer.
    Int(i64),
    /// Floating-point number.
    Float(f64),
    /// String.
    Str(String),
    /// Array.
    Array(Vec<Value>),
    /// Object (sorted keys for deterministic output).
    Object(BTreeMap<String, Value>),
}

impl Value {
    /// As i64, accepting exact floats.
    pub fn as_i64(&self) -> Result<i64> {
        match self {
            Value::Int(v) => Ok(*v),
            Value::Float(f) if f.fract() == 0.0 && f.abs() < 9e15 => Ok(*f as i64),
            other => bail!("expected integer, got {other:?}"),
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Int(v) => Ok(*v as f64),
            Value::Float(f) => Ok(*f),
            other => bail!("expected number, got {other:?}"),
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    /// As string slice.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    /// As array slice.
    pub fn as_array(&self) -> Result<&[Value]> {
        match self {
            Value::Array(a) => Ok(a),
            other => bail!("expected array, got {other:?}"),
        }
    }

    /// As object map.
    pub fn as_object(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Ok(o),
            other => bail!("expected object, got {other:?}"),
        }
    }

    /// Object field lookup with a clear error.
    pub fn get(&self, key: &str) -> Result<&Value> {
        self.as_object()?
            .get(key)
            .ok_or_else(|| anyhow!("missing field '{key}'"))
    }

    /// Optional object field.
    pub fn get_opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(o) => o.get(key),
            _ => None,
        }
    }

    /// Decode a `Vec<i64>`.
    pub fn to_i64_vec(&self) -> Result<Vec<i64>> {
        self.as_array()?.iter().map(|v| v.as_i64()).collect()
    }

    /// Decode a `Vec<Vec<i64>>`.
    pub fn to_i64_mat(&self) -> Result<Vec<Vec<i64>>> {
        self.as_array()?.iter().map(|v| v.to_i64_vec()).collect()
    }
}

/// Default nesting limit of [`parse`] (picojson-rs convention: decoders
/// never panic, so recursion must be bounded well below stack exhaustion).
pub const DEFAULT_MAX_DEPTH: usize = 128;

/// Parse a JSON document with the [`DEFAULT_MAX_DEPTH`] nesting limit.
pub fn parse(text: &str) -> Result<Value> {
    parse_with_depth(text, DEFAULT_MAX_DEPTH)
}

/// Parse a JSON document, rejecting arrays/objects nested deeper than
/// `max_depth` with an error (never a stack overflow).
pub fn parse_with_depth(text: &str, max_depth: usize) -> Result<Value> {
    let mut p = Parser { b: text.as_bytes(), i: 0, depth: 0, max_depth };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
    max_depth: usize,
}

impl<'a> Parser<'a> {
    fn enter(&mut self) -> Result<()> {
        self.depth += 1;
        if self.depth > self.max_depth {
            bail!("nesting depth exceeds {} at byte {}", self.max_depth, self.i);
        }
        Ok(())
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, got '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'n' => self.lit("null", Value::Null),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'"' => Ok(Value::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected '{}' at byte {}", c as char, self.i),
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.enter()?;
        let v = self.array_body();
        self.depth -= 1;
        v
    }

    fn array_body(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Array(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Array(out));
                }
                c => bail!("expected ',' or ']' at byte {}, got '{}'", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.enter()?;
        let v = self.object_body();
        self.depth -= 1;
        v
    }

    fn object_body(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Object(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Object(out));
                }
                c => bail!("expected ',' or '}}' at byte {}, got '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.i += 4;
                            // Surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .b
                                        .get(self.i + 2..self.i + 6)
                                        .ok_or_else(|| anyhow!("truncated surrogate"))?;
                                    let lo =
                                        u32::from_str_radix(std::str::from_utf8(hex2)?, 16)?;
                                    self.i += 6;
                                    0x10000 + ((code - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    bail!("lone high surrogate");
                                }
                            } else {
                                code
                            };
                            out.push(
                                char::from_u32(ch)
                                    .ok_or_else(|| anyhow!("invalid codepoint {ch:#x}"))?,
                            );
                        }
                        e => bail!("invalid escape '\\{}'", e as char),
                    }
                }
                c if c < 0x20 => bail!("control character in string"),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let bytes = self
                            .b
                            .get(start..start + len)
                            .ok_or_else(|| anyhow!("truncated UTF-8"))?;
                        out.push_str(std::str::from_utf8(bytes)?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len() && self.b[self.i].is_ascii_digit() {
            self.i += 1;
        }
        let mut is_float = false;
        if self.i < self.b.len() && self.b[self.i] == b'.' {
            is_float = true;
            self.i += 1;
            while self.i < self.b.len() && self.b[self.i].is_ascii_digit() {
                self.i += 1;
            }
        }
        if self.i < self.b.len() && matches!(self.b[self.i], b'e' | b'E') {
            is_float = true;
            self.i += 1;
            if self.i < self.b.len() && matches!(self.b[self.i], b'+' | b'-') {
                self.i += 1;
            }
            while self.i < self.b.len() && self.b[self.i].is_ascii_digit() {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        if !is_float {
            if let Ok(v) = text.parse::<i64>() {
                return Ok(Value::Int(v));
            }
        }
        Ok(Value::Float(text.parse::<f64>()?))
    }
}

/// Serialize a [`Value`] to compact JSON.
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Int(i) => out.push_str(&i.to_string()),
        Value::Float(f) => {
            if f.is_finite() {
                out.push_str(&format!("{f}"));
            } else {
                out.push_str("null");
            }
        }
        Value::Str(s) => write_string(s, out),
        Value::Array(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(v, out);
            }
            out.push(']');
        }
        Value::Object(o) => {
            out.push('{');
            for (i, (k, v)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(v, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-42").unwrap(), Value::Int(-42));
        assert_eq!(parse("3.5").unwrap(), Value::Float(3.5));
        assert_eq!(parse("1e3").unwrap(), Value::Float(1000.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Value::Str("hi\n".into()));
    }

    #[test]
    fn big_integers_exact() {
        let v = parse("9007199254740993").unwrap(); // 2^53 + 1
        assert_eq!(v, Value::Int(9007199254740993));
        assert_eq!(v.as_i64().unwrap(), 9007199254740993);
    }

    #[test]
    fn nested_structures() {
        let v = parse(r#"{"a": [1, -2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let a = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(a[1], Value::Int(-2));
        assert_eq!(a[2].get("b").unwrap().as_bool().unwrap(), false);
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"m":[[1,-2],[3,4]],"name":"net","pi":3.25,"z":null}"#;
        let v = parse(text).unwrap();
        assert_eq!(to_string(&v), text);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Value::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
        assert_eq!(parse("\"héllo\"").unwrap(), Value::Str("héllo".into()));
    }

    #[test]
    fn errors_reported() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn i64_mat_decoding() {
        let v = parse("[[1,2],[3,4]]").unwrap();
        assert_eq!(v.to_i64_mat().unwrap(), vec![vec![1, 2], vec![3, 4]]);
        assert!(parse("[[1,\"x\"]]").unwrap().to_i64_mat().is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let v = parse(" {\n\t\"a\" : [ 1 , 2 ] }\r\n").unwrap();
        assert_eq!(v.get("a").unwrap().to_i64_vec().unwrap(), vec![1, 2]);
    }

    #[test]
    fn depth_limit_is_configurable() {
        assert!(parse_with_depth("[[[0]]]", 3).is_ok());
        let err = parse_with_depth("[[[0]]]", 2).unwrap_err();
        assert!(format!("{err}").contains("nesting depth"));
        assert!(parse_with_depth(r#"{"a":{"b":1}}"#, 2).is_ok());
        assert!(parse_with_depth(r#"{"a":{"b":{"c":1}}}"#, 2).is_err());
    }

    #[test]
    fn default_depth_accepts_realistic_artifacts() {
        // Weight matrices are 2-3 levels deep; leave ample headroom.
        let mut doc = String::from("1");
        for _ in 0..DEFAULT_MAX_DEPTH {
            doc = format!("[{doc}]");
        }
        assert!(parse(&doc).is_ok(), "depth == limit must pass");
        assert!(parse(&format!("[{doc}]")).is_err(), "limit + 1 must fail");
    }
}
