//! Minimal JSON codec (in-tree `serde_json` replacement for the offline
//! build environment), built around a streaming core.
//!
//! Two ingestion APIs share one iterative scanner:
//!
//! * **Pull API** ([`pull`], [`decode`]) — the zero-copy event stream
//!   and the typed decoders on top of it. This is the artifact hot
//!   path: weight matrices and test vectors stream straight into their
//!   final `Vec` storage, unescaped strings are borrowed `&str` slices,
//!   and no intermediate tree is allocated.
//! * **DOM API** ([`parse`], [`Value`]) — a thin adapter that folds the
//!   event stream into a [`Value`] tree, for callers that genuinely
//!   need random access (e.g. free-form `metrics.json`).
//!
//! Both APIs parse the full JSON grammar with exact i64 integers
//! (critical: network weights must round-trip bit-exactly — integer
//! literals outside the i64 range are a parse error, never a silent
//! f64 approximation), bound nesting by a plain depth counter (no
//! recursion anywhere, so no stack overflow on hostile inputs), and
//! serialize [`Value`] back to compact text.
//!
//! ```
//! // DOM API: parse into a tree, navigate with typed accessors.
//! let v = da4ml::json::parse(r#"{"name": "net", "w": [[1, -2], [3, 4]]}"#).unwrap();
//! assert_eq!(v.get("name").unwrap().as_str().unwrap(), "net");
//! assert_eq!(v.get("w").unwrap().to_i64_mat().unwrap(), vec![vec![1, -2], vec![3, 4]]);
//!
//! // Pull API: stream events, no tree.
//! use da4ml::json::pull::{Event, PullParser};
//! let mut p = PullParser::new("[1, 2]");
//! assert_eq!(p.next().unwrap(), Event::ArrayStart);
//! assert_eq!(p.next().unwrap(), Event::Int(1));
//! ```

pub mod decode;
pub mod pull;

#[cfg(test)]
pub(crate) mod legacy;

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// A JSON value. Integers are kept exact (`Int`) whenever the literal
/// has no fraction/exponent (out-of-range integer literals are a parse
/// error).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Exact integer.
    Int(i64),
    /// Floating-point number.
    Float(f64),
    /// String.
    Str(String),
    /// Array.
    Array(Vec<Value>),
    /// Object (sorted keys for deterministic output).
    Object(BTreeMap<String, Value>),
}

impl Value {
    /// As i64, accepting exact floats.
    pub fn as_i64(&self) -> Result<i64> {
        match self {
            Value::Int(v) => Ok(*v),
            Value::Float(f) if f.fract() == 0.0 && f.abs() < 9e15 => Ok(*f as i64),
            other => bail!("expected integer, got {other:?}"),
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Int(v) => Ok(*v as f64),
            Value::Float(f) => Ok(*f),
            other => bail!("expected number, got {other:?}"),
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    /// As string slice.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    /// As array slice.
    pub fn as_array(&self) -> Result<&[Value]> {
        match self {
            Value::Array(a) => Ok(a),
            other => bail!("expected array, got {other:?}"),
        }
    }

    /// As object map.
    pub fn as_object(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Ok(o),
            other => bail!("expected object, got {other:?}"),
        }
    }

    /// Object field lookup with a clear error.
    pub fn get(&self, key: &str) -> Result<&Value> {
        self.as_object()?
            .get(key)
            .ok_or_else(|| anyhow!("missing field '{key}'"))
    }

    /// Optional object field.
    pub fn get_opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(o) => o.get(key),
            _ => None,
        }
    }

    /// Decode a `Vec<i64>`.
    pub fn to_i64_vec(&self) -> Result<Vec<i64>> {
        self.as_array()?.iter().map(|v| v.as_i64()).collect()
    }

    /// Decode a `Vec<Vec<i64>>`.
    pub fn to_i64_mat(&self) -> Result<Vec<Vec<i64>>> {
        self.as_array()?.iter().map(|v| v.to_i64_vec()).collect()
    }
}

/// Default nesting limit of [`parse`] (picojson-rs convention: decoders
/// never panic, so nesting must be bounded — here by a counter, not the
/// call stack).
pub const DEFAULT_MAX_DEPTH: usize = 128;

/// Parse a JSON document with the [`DEFAULT_MAX_DEPTH`] nesting limit.
pub fn parse(text: &str) -> Result<Value> {
    parse_with_depth(text, DEFAULT_MAX_DEPTH)
}

/// Parse a JSON document into a [`Value`] tree, rejecting
/// arrays/objects nested deeper than `max_depth`.
///
/// This is an adapter over the iterative [`pull`] event stream: the
/// tree is folded up with an explicit frame stack, so even documents at
/// the depth limit never recurse.
pub fn parse_with_depth(text: &str, max_depth: usize) -> Result<Value> {
    use pull::Event;

    enum Frame {
        Array(Vec<Value>),
        Object(BTreeMap<String, Value>, Option<String>),
    }

    let mut p = pull::PullParser::with_max_depth(text, max_depth);
    let mut stack: Vec<Frame> = Vec::new();
    loop {
        let completed: Option<Value> = match p.next()? {
            Event::ObjectStart => {
                stack.push(Frame::Object(BTreeMap::new(), None));
                None
            }
            Event::ArrayStart => {
                stack.push(Frame::Array(Vec::new()));
                None
            }
            Event::Key(k) => {
                match stack.last_mut() {
                    Some(Frame::Object(_, pending)) => *pending = Some(k.into_owned()),
                    _ => unreachable!("parser emits keys only inside objects"),
                }
                None
            }
            Event::ObjectEnd => match stack.pop() {
                Some(Frame::Object(m, _)) => Some(Value::Object(m)),
                _ => unreachable!("parser matches container ends"),
            },
            Event::ArrayEnd => match stack.pop() {
                Some(Frame::Array(a)) => Some(Value::Array(a)),
                _ => unreachable!("parser matches container ends"),
            },
            Event::Str(s) => Some(Value::Str(s.into_owned())),
            Event::Int(v) => Some(Value::Int(v)),
            Event::Float(f) => Some(Value::Float(f)),
            Event::Bool(b) => Some(Value::Bool(b)),
            Event::Null => Some(Value::Null),
            Event::Eof => bail!("unexpected end of input"),
        };
        if let Some(v) = completed {
            match stack.last_mut() {
                None => {
                    // Top-level value complete; the parser enforces the
                    // no-trailing-garbage rule on the final pull.
                    return match p.next()? {
                        Event::Eof => Ok(v),
                        _ => unreachable!("parser ends after the top-level value"),
                    };
                }
                Some(Frame::Array(a)) => a.push(v),
                Some(Frame::Object(m, pending)) => {
                    let key = pending.take().expect("parser emits a key before each value");
                    m.insert(key, v);
                }
            }
        }
    }
}

/// Serialize a [`Value`] to compact JSON.
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Int(i) => out.push_str(&i.to_string()),
        Value::Float(f) => {
            if f.is_finite() {
                out.push_str(&format!("{f}"));
            } else {
                out.push_str("null");
            }
        }
        Value::Str(s) => write_string(s, out),
        Value::Array(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(v, out);
            }
            out.push(']');
        }
        Value::Object(o) => {
            out.push('{');
            for (i, (k, v)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(v, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-42").unwrap(), Value::Int(-42));
        assert_eq!(parse("3.5").unwrap(), Value::Float(3.5));
        assert_eq!(parse("1e3").unwrap(), Value::Float(1000.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Value::Str("hi\n".into()));
    }

    #[test]
    fn big_integers_exact() {
        let v = parse("9007199254740993").unwrap(); // 2^53 + 1
        assert_eq!(v, Value::Int(9007199254740993));
        assert_eq!(v.as_i64().unwrap(), 9007199254740993);
    }

    /// Regression: integer literals beyond i64 used to silently degrade
    /// to f64 (losing low bits of would-be weights); they are now a
    /// parse error in both the pull parser and the legacy reference.
    #[test]
    fn integer_overflow_is_a_parse_error() {
        assert_eq!(parse("9223372036854775807").unwrap(), Value::Int(i64::MAX));
        assert_eq!(parse("-9223372036854775808").unwrap(), Value::Int(i64::MIN));
        for bad in ["9223372036854775808", "-9223372036854775809", "[18446744073709551615]"] {
            let err = parse(bad).unwrap_err();
            assert!(format!("{err}").contains("out of i64 range"), "got: {err}");
            assert!(legacy::parse(bad).is_err(), "legacy accepted: {bad}");
        }
        // A fraction or exponent keeps the f64 reading.
        assert_eq!(
            parse("9223372036854775808.0").unwrap(),
            Value::Float(9223372036854775808.0)
        );
    }

    #[test]
    fn nested_structures() {
        let v = parse(r#"{"a": [1, -2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let a = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(a[1], Value::Int(-2));
        assert_eq!(a[2].get("b").unwrap().as_bool().unwrap(), false);
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"m":[[1,-2],[3,4]],"name":"net","pi":3.25,"z":null}"#;
        let v = parse(text).unwrap();
        assert_eq!(to_string(&v), text);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""é""#).unwrap(), Value::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Value::Str("😀".into()));
        assert_eq!(parse("\"héllo\"").unwrap(), Value::Str("héllo".into()));
    }

    #[test]
    fn errors_reported() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn i64_mat_decoding() {
        let v = parse("[[1,2],[3,4]]").unwrap();
        assert_eq!(v.to_i64_mat().unwrap(), vec![vec![1, 2], vec![3, 4]]);
        assert!(parse("[[1,\"x\"]]").unwrap().to_i64_mat().is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let v = parse(" {\n\t\"a\" : [ 1 , 2 ] }\r\n").unwrap();
        assert_eq!(v.get("a").unwrap().to_i64_vec().unwrap(), vec![1, 2]);
    }

    #[test]
    fn depth_limit_is_configurable() {
        assert!(parse_with_depth("[[[0]]]", 3).is_ok());
        let err = parse_with_depth("[[[0]]]", 2).unwrap_err();
        assert!(format!("{err}").contains("nesting depth"));
        assert!(parse_with_depth(r#"{"a":{"b":1}}"#, 2).is_ok());
        assert!(parse_with_depth(r#"{"a":{"b":{"c":1}}}"#, 2).is_err());
    }

    #[test]
    fn default_depth_accepts_realistic_artifacts() {
        // Weight matrices are 2-3 levels deep; leave ample headroom.
        let mut doc = String::from("1");
        for _ in 0..DEFAULT_MAX_DEPTH {
            doc = format!("[{doc}]");
        }
        assert!(parse(&doc).is_ok(), "depth == limit must pass");
        assert!(parse(&format!("[{doc}]")).is_err(), "limit + 1 must fail");
    }

    // ---- differential: pull-parser adapter vs the legacy recursive DOM ----

    fn gen_ws(rng: &mut Rng, out: &mut String) {
        for _ in 0..rng.below(3) {
            out.push([' ', '\n', '\t'][rng.below(3)]);
        }
    }

    fn gen_string(rng: &mut Rng, out: &mut String) {
        out.push('"');
        for _ in 0..rng.below(8) {
            match rng.below(9) {
                0 => out.push_str("\\n"),
                1 => out.push_str("\\\""),
                2 => out.push_str("\\\\"),
                3 => out.push_str("\\u0041"),
                4 => out.push_str("\\ud83d\\ude00"), // surrogate pair
                5 => out.push('é'),
                6 => out.push('😀'),
                7 => out.push_str("\\t"),
                _ => out.push((b'a' + rng.below(26) as u8) as char),
            }
        }
        out.push('"');
    }

    fn gen_value(rng: &mut Rng, depth: usize, out: &mut String) {
        let choice = if depth == 0 { rng.below(5) } else { rng.below(7) };
        match choice {
            0 => out.push_str("null"),
            1 => out.push_str(if rng.chance(0.5) { "true" } else { "false" }),
            2 => {
                let v: i64 = match rng.below(4) {
                    0 => rng.range_i64(-10, 10),
                    1 => i64::MAX,
                    2 => i64::MIN,
                    _ => rng.next_u64() as i64,
                };
                out.push_str(&v.to_string());
            }
            3 => {
                // Float edge cases: -0, exponent overflow/underflow, exact halves.
                let s = [
                    "-0.0", "0.0", "-0e0", "3.25", "-1.5e3", "2e-3", "1e999", "-1e999",
                    "1e-999", "123456789.125",
                ][rng.below(10)];
                out.push_str(s);
            }
            4 => gen_string(rng, out),
            5 => {
                out.push('[');
                let n = rng.below(4);
                for i in 0..n {
                    if i > 0 {
                        out.push(',');
                    }
                    gen_ws(rng, out);
                    gen_value(rng, depth - 1, out);
                    gen_ws(rng, out);
                }
                out.push(']');
            }
            _ => {
                out.push('{');
                let n = rng.below(4);
                for i in 0..n {
                    if i > 0 {
                        out.push(',');
                    }
                    gen_ws(rng, out);
                    gen_string(rng, out);
                    gen_ws(rng, out);
                    out.push(':');
                    gen_ws(rng, out);
                    gen_value(rng, depth - 1, out);
                    gen_ws(rng, out);
                }
                out.push('}');
            }
        }
    }

    /// Property: on seeded random documents (escapes, unicode, integer
    /// extremes, float edge cases, random whitespace) the iterative
    /// pull-parser adapter and the legacy recursive parser produce
    /// identical `Value` trees — or both reject.
    #[test]
    fn differential_pull_vs_legacy_dom() {
        crate::util::property("json pull vs legacy DOM", 400, |rng| {
            let mut text = String::new();
            gen_ws(rng, &mut text);
            gen_value(rng, 4, &mut text);
            gen_ws(rng, &mut text);
            match (parse(&text), legacy::parse(&text)) {
                (Ok(a), Ok(b)) => assert_eq!(a, b, "tree mismatch on: {text}"),
                (Err(_), Err(_)) => {}
                (a, b) => panic!("accept/reject divergence on {text:?}: new={a:?} legacy={b:?}"),
            }
        });
    }

    /// The same differential over a fixed corpus of grammar edge cases,
    /// including documents at and beyond the depth limit.
    #[test]
    fn differential_edge_corpus() {
        let at_limit =
            format!("{}0{}", "[".repeat(DEFAULT_MAX_DEPTH), "]".repeat(DEFAULT_MAX_DEPTH));
        let over_limit = format!(
            "{}0{}",
            "[".repeat(DEFAULT_MAX_DEPTH + 1),
            "]".repeat(DEFAULT_MAX_DEPTH + 1)
        );
        let mixed_at_limit = {
            // Alternate {"k": [ ... ]} nesting down to the limit.
            let pairs = DEFAULT_MAX_DEPTH / 2;
            format!("{}0{}", "{\"k\":[".repeat(pairs), "]}".repeat(pairs))
        };
        let cases: Vec<String> = [
            "-0", "-0.0", "0e0", "0E-0", "1e999", "-1e999", "1e-999", "1.5e308",
            "9223372036854775807", "-9223372036854775808", "9223372036854775808",
            "-9223372036854775809", "0.0000000000000000000000001",
            r#""😀""#, r#""\ud83d""#, r#""\udc00""#, r#""\ud800\u0041""#,
            r#""\ud800\udbff""#, r#""\u+041""#, r#""\u004g""#, "\"\u{0}\"",
            "[]", "{}", "[[],{}]", r#"{"a":1,"a":2}"#, "[1,]", "{\"a\":}", "", "-", "1e",
            "nul", "[1 2]", "123abc", "{\"k\": \"v\",}",
        ]
        .into_iter()
        .map(String::from)
        .chain([at_limit, over_limit, mixed_at_limit])
        .collect();
        for text in &cases {
            match (parse(text), legacy::parse(text)) {
                (Ok(a), Ok(b)) => assert_eq!(a, b, "tree mismatch on: {text}"),
                (Err(_), Err(_)) => {}
                (a, b) => panic!("accept/reject divergence on {text:?}: new={a:?} legacy={b:?}"),
            }
        }
    }
}
