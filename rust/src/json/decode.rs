//! Typed streaming decoders over the [`PullParser`] event stream.
//!
//! [`Decoder`] is the ingestion surface the artifact loaders
//! ([`crate::nn::NetworkSpec`], [`crate::nn::TestVectors`], the `serve`
//! JSONL jobs) are written against: field-by-field object walking,
//! integer vectors/matrices decoded straight into their final `Vec`
//! storage, and `skip_value` for unknown fields — no intermediate
//! [`crate::json::Value`] tree is ever materialized.
//!
//! ```
//! use da4ml::json::decode::Decoder;
//!
//! let mut d = Decoder::new(r#"{"name": "net", "w": [[1, -2], [3, 4]], "extra": null}"#);
//! let mut name = String::new();
//! let mut w = Vec::new();
//! d.object_start().unwrap();
//! while let Some(key) = d.next_key().unwrap() {
//!     match key.as_ref() {
//!         "name" => name = d.string().unwrap(),
//!         "w" => w = d.i64_mat().unwrap(),
//!         _ => d.skip_value().unwrap(),
//!     }
//! }
//! d.end().unwrap();
//! assert_eq!(name, "net");
//! assert_eq!(w, vec![vec![1, -2], vec![3, 4]]);
//! ```

use super::pull::{Event, PullParser};
use anyhow::{bail, Result};
use std::borrow::Cow;

/// Exact-integer view of a numeric event, accepting integral floats
/// inside the f64-exact window (mirrors [`crate::json::Value::as_i64`]).
fn int_like(ev: &Event<'_>) -> Option<i64> {
    match ev {
        Event::Int(v) => Some(*v),
        Event::Float(f) if f.fract() == 0.0 && f.abs() < 9e15 => Some(*f as i64),
        _ => None,
    }
}

/// A typed pull decoder. Methods consume exactly the events of the
/// construct they name and error (without panicking) on anything else.
pub struct Decoder<'a> {
    p: PullParser<'a>,
}

impl<'a> Decoder<'a> {
    /// Decoder over `text` with the default depth limit.
    pub fn new(text: &'a str) -> Self {
        Self { p: PullParser::new(text) }
    }

    /// Decoder over `text` with an explicit depth limit.
    pub fn with_max_depth(text: &'a str, max_depth: usize) -> Self {
        Self { p: PullParser::with_max_depth(text, max_depth) }
    }

    /// Decoder over raw bytes (UTF-8 validated here, not copied). The
    /// socket transport reads request lines out of a reused byte
    /// buffer; this is its entry into the same zero-copy pipeline.
    pub fn from_bytes(bytes: &'a [u8]) -> Result<Self> {
        match std::str::from_utf8(bytes) {
            Ok(text) => Ok(Self::new(text)),
            Err(e) => bail!("invalid UTF-8: {e}"),
        }
    }

    /// Consume the opening `{` of an object.
    pub fn object_start(&mut self) -> Result<()> {
        match self.p.next()? {
            Event::ObjectStart => Ok(()),
            ev => bail!("expected object, got {ev:?}"),
        }
    }

    /// Consume the opening `[` of an array.
    pub fn array_start(&mut self) -> Result<()> {
        match self.p.next()? {
            Event::ArrayStart => Ok(()),
            ev => bail!("expected array, got {ev:?}"),
        }
    }

    /// Inside an object: the next key, or `None` at the closing `}`.
    pub fn next_key(&mut self) -> Result<Option<Cow<'a, str>>> {
        match self.p.next()? {
            Event::Key(k) => Ok(Some(k)),
            Event::ObjectEnd => Ok(None),
            ev => bail!("expected object key, got {ev:?}"),
        }
    }

    /// At an array-element position: consume an `{` and return `true`,
    /// or the closing `]` and return `false`.
    pub fn next_object_in_array(&mut self) -> Result<bool> {
        match self.p.next()? {
            Event::ObjectStart => Ok(true),
            Event::ArrayEnd => Ok(false),
            ev => bail!("expected object or end of array, got {ev:?}"),
        }
    }

    /// Decode an exact integer value.
    pub fn i64(&mut self) -> Result<i64> {
        let ev = self.p.next()?;
        int_like(&ev).ok_or_else(|| anyhow::anyhow!("expected integer, got {ev:?}"))
    }

    /// Decode a number as `f64`.
    pub fn f64(&mut self) -> Result<f64> {
        match self.p.next()? {
            Event::Int(v) => Ok(v as f64),
            Event::Float(f) => Ok(f),
            ev => bail!("expected number, got {ev:?}"),
        }
    }

    /// Decode a boolean value.
    pub fn bool(&mut self) -> Result<bool> {
        match self.p.next()? {
            Event::Bool(b) => Ok(b),
            ev => bail!("expected bool, got {ev:?}"),
        }
    }

    /// Decode a string value (owned).
    pub fn string(&mut self) -> Result<String> {
        match self.p.next()? {
            Event::Str(s) => Ok(s.into_owned()),
            ev => bail!("expected string, got {ev:?}"),
        }
    }

    /// Decode `[int, ...]` straight into a `Vec<i64>`.
    pub fn i64_vec(&mut self) -> Result<Vec<i64>> {
        self.array_start()?;
        let mut out = Vec::new();
        loop {
            let ev = self.p.next()?;
            if ev == Event::ArrayEnd {
                return Ok(out);
            }
            match int_like(&ev) {
                Some(v) => out.push(v),
                None => bail!("expected integer, got {ev:?}"),
            }
        }
    }

    /// Decode `[[int, ...], ...]` straight into a `Vec<Vec<i64>>` (the
    /// weight-matrix hot path — no per-element `Value` boxing).
    pub fn i64_mat(&mut self) -> Result<Vec<Vec<i64>>> {
        self.array_start()?;
        let mut out = Vec::new();
        loop {
            match self.p.next()? {
                Event::ArrayEnd => return Ok(out),
                Event::ArrayStart => {
                    let mut row = Vec::new();
                    loop {
                        let ev = self.p.next()?;
                        if ev == Event::ArrayEnd {
                            break;
                        }
                        match int_like(&ev) {
                            Some(v) => row.push(v),
                            None => bail!("expected integer, got {ev:?}"),
                        }
                    }
                    out.push(row);
                }
                ev => bail!("expected row array, got {ev:?}"),
            }
        }
    }

    /// Skip one complete value of any shape (scalar or container).
    pub fn skip_value(&mut self) -> Result<()> {
        let mut depth = 0usize;
        loop {
            match self.p.next()? {
                Event::ObjectStart | Event::ArrayStart => depth += 1,
                Event::ObjectEnd | Event::ArrayEnd => {
                    // Guard against misuse at a container-end boundary:
                    // error, don't underflow.
                    if depth == 0 {
                        bail!("expected a value to skip, got a container end");
                    }
                    depth -= 1;
                    if depth == 0 {
                        return Ok(());
                    }
                }
                Event::Eof => bail!("unexpected end of input"),
                _ => {
                    if depth == 0 {
                        return Ok(());
                    }
                }
            }
        }
    }

    /// Assert the document is complete (only whitespace remains).
    pub fn end(&mut self) -> Result<()> {
        match self.p.next()? {
            Event::Eof => Ok(()),
            ev => bail!("expected end of input, got {ev:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn object_walk_any_field_order() {
        // The exporter sorts keys, but the decoder must not rely on it.
        for text in [
            r#"{"a": 1, "b": [2, 3]}"#,
            r#"{"b": [2, 3], "a": 1}"#,
        ] {
            let mut d = Decoder::new(text);
            let (mut a, mut b) = (None, None);
            d.object_start().unwrap();
            while let Some(key) = d.next_key().unwrap() {
                match key.as_ref() {
                    "a" => a = Some(d.i64().unwrap()),
                    "b" => b = Some(d.i64_vec().unwrap()),
                    _ => d.skip_value().unwrap(),
                }
            }
            d.end().unwrap();
            assert_eq!(a, Some(1));
            assert_eq!(b, Some(vec![2, 3]));
        }
    }

    #[test]
    fn mat_decoding() {
        let mut d = Decoder::new("[[1, 2], [], [-3]]");
        assert_eq!(d.i64_mat().unwrap(), vec![vec![1, 2], vec![], vec![-3]]);
        d.end().unwrap();

        let mut d = Decoder::new(r#"[[1, "x"]]"#);
        assert!(d.i64_mat().is_err());
    }

    #[test]
    fn skip_value_consumes_whole_subtrees() {
        let mut d = Decoder::new(r#"{"skip": {"x": [1, {"y": 2}]}, "keep": 7}"#);
        d.object_start().unwrap();
        let mut keep = None;
        while let Some(key) = d.next_key().unwrap() {
            match key.as_ref() {
                "keep" => keep = Some(d.i64().unwrap()),
                _ => d.skip_value().unwrap(),
            }
        }
        d.end().unwrap();
        assert_eq!(keep, Some(7));
    }

    /// Misusing skip_value at a container-end boundary must error, not
    /// underflow the depth counter.
    #[test]
    fn skip_value_rejects_container_end_position() {
        let mut d = Decoder::new("[1]");
        d.array_start().unwrap();
        d.skip_value().unwrap(); // consumes the 1
        assert!(d.skip_value().is_err()); // positioned at the ']'
    }

    #[test]
    fn integral_floats_accepted_as_ints() {
        let mut d = Decoder::new("[1.0, 2]");
        assert_eq!(d.i64_vec().unwrap(), vec![1, 2]);
        let mut d = Decoder::new("[1.5]");
        assert!(d.i64_vec().is_err());
    }

    #[test]
    fn trailing_garbage_rejected() {
        let mut d = Decoder::new("[1] x");
        assert_eq!(d.i64_vec().unwrap(), vec![1]);
        assert!(d.end().is_err());
    }

    #[test]
    fn type_mismatches_are_errors() {
        assert!(Decoder::new("[1]").object_start().is_err());
        assert!(Decoder::new("{}").array_start().is_err());
        assert!(Decoder::new("\"s\"").i64().is_err());
        assert!(Decoder::new("1").bool().is_err());
        assert!(Decoder::new("true").string().is_err());
    }
}
