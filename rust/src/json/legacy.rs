//! The original recursive-descent DOM parser, kept **test-only** as the
//! differential-testing reference for the iterative pull parser (the
//! production [`crate::json::parse`] is now an adapter over
//! [`crate::json::pull`]). Semantics are identical by construction —
//! including the integer-overflow hard error — and the property test in
//! `crate::json::tests` holds the two implementations equal on seeded
//! random documents.

use super::Value;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Recursive-descent parse with the default depth limit.
#[allow(dead_code)]
pub fn parse(text: &str) -> Result<Value> {
    parse_with_depth(text, super::DEFAULT_MAX_DEPTH)
}

/// Recursive-descent parse with an explicit depth limit.
pub fn parse_with_depth(text: &str, max_depth: usize) -> Result<Value> {
    let mut p = Parser { b: text.as_bytes(), i: 0, depth: 0, max_depth };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
    max_depth: usize,
}

impl<'a> Parser<'a> {
    fn enter(&mut self) -> Result<()> {
        self.depth += 1;
        if self.depth > self.max_depth {
            bail!("nesting depth exceeds {} at byte {}", self.max_depth, self.i);
        }
        Ok(())
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, got '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'n' => self.lit("null", Value::Null),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'"' => Ok(Value::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected '{}' at byte {}", c as char, self.i),
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.enter()?;
        let v = self.array_body();
        self.depth -= 1;
        v
    }

    fn array_body(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Array(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Array(out));
                }
                c => bail!("expected ',' or ']' at byte {}, got '{}'", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.enter()?;
        let v = self.object_body();
        self.depth -= 1;
        v
    }

    fn object_body(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Object(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Object(out));
                }
                c => bail!("expected ',' or '}}' at byte {}, got '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                            let code = super::pull::hex4(hex)?;
                            self.i += 4;
                            // Surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .b
                                        .get(self.i + 2..self.i + 6)
                                        .ok_or_else(|| anyhow!("truncated surrogate"))?;
                                    let lo = super::pull::hex4(hex2)?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        bail!("invalid low surrogate {lo:#x}");
                                    }
                                    self.i += 6;
                                    0x10000 + ((code - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    bail!("lone high surrogate");
                                }
                            } else {
                                code
                            };
                            out.push(
                                char::from_u32(ch)
                                    .ok_or_else(|| anyhow!("invalid codepoint {ch:#x}"))?,
                            );
                        }
                        e => bail!("invalid escape '\\{}'", e as char),
                    }
                }
                c if c < 0x20 => bail!("control character in string"),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let bytes = self
                            .b
                            .get(start..start + len)
                            .ok_or_else(|| anyhow!("truncated UTF-8"))?;
                        out.push_str(std::str::from_utf8(bytes)?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len() && self.b[self.i].is_ascii_digit() {
            self.i += 1;
        }
        let mut is_float = false;
        if self.i < self.b.len() && self.b[self.i] == b'.' {
            is_float = true;
            self.i += 1;
            while self.i < self.b.len() && self.b[self.i].is_ascii_digit() {
                self.i += 1;
            }
        }
        if self.i < self.b.len() && matches!(self.b[self.i], b'e' | b'E') {
            is_float = true;
            self.i += 1;
            if self.i < self.b.len() && matches!(self.b[self.i], b'+' | b'-') {
                self.i += 1;
            }
            while self.i < self.b.len() && self.b[self.i].is_ascii_digit() {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        if !is_float {
            if text == "-" {
                bail!("invalid number at byte {start}");
            }
            return match text.parse::<i64>() {
                Ok(v) => Ok(Value::Int(v)),
                // Same overflow contract as the pull parser: exact or error.
                Err(_) => bail!("integer literal '{text}' out of i64 range at byte {start}"),
            };
        }
        Ok(Value::Float(text.parse::<f64>()?))
    }
}
