//! Iterative, zero-copy JSON **pull parser** (event stream over
//! `&[u8]`).
//!
//! The parser walks the input with an explicit container-kind bit stack
//! instead of recursion, so arbitrarily deep (malicious) documents can
//! never exhaust the call stack — the depth limit is a plain counter
//! check, the picojson-rs idiom. Strings that contain no escape
//! sequences are returned as *borrowed* `&str` slices of the input
//! ([`std::borrow::Cow::Borrowed`]); only escaped strings allocate.
//! This is the ingestion fast path the typed artifact decoders
//! ([`crate::json::decode`]) and the DOM adapter ([`crate::json::parse`])
//! are built on.
//!
//! Integer literals that do not fit `i64` are a hard parse error (the
//! artifact convention is exact `i64` weights; silently degrading to
//! `f64` would corrupt them), while literals with a fraction or exponent
//! parse as [`Event::Float`].
//!
//! ```
//! use da4ml::json::pull::{Event, PullParser};
//!
//! let mut p = PullParser::new(r#"{"w": [1, -2]}"#);
//! assert_eq!(p.next().unwrap(), Event::ObjectStart);
//! assert!(matches!(p.next().unwrap(), Event::Key(k) if k == "w"));
//! assert_eq!(p.next().unwrap(), Event::ArrayStart);
//! assert_eq!(p.next().unwrap(), Event::Int(1));
//! assert_eq!(p.next().unwrap(), Event::Int(-2));
//! assert_eq!(p.next().unwrap(), Event::ArrayEnd);
//! assert_eq!(p.next().unwrap(), Event::ObjectEnd);
//! assert_eq!(p.next().unwrap(), Event::Eof);
//! ```

use anyhow::{anyhow, bail, Result};
use std::borrow::Cow;

/// Decode exactly four ASCII hex digits (the JSON `\uXXXX` payload).
/// Stricter than `u32::from_str_radix`, which would accept a sign.
pub(crate) fn hex4(bytes: &[u8]) -> Result<u32> {
    debug_assert_eq!(bytes.len(), 4);
    let mut code = 0u32;
    for &b in bytes {
        let digit = (b as char).to_digit(16).ok_or_else(|| {
            anyhow!("invalid \\u escape digit '{}'", b as char)
        })?;
        code = code * 16 + digit;
    }
    Ok(code)
}

/// One parse event. String-carrying events borrow from the input
/// whenever the literal contains no escapes.
#[derive(Debug, Clone, PartialEq)]
pub enum Event<'a> {
    /// `{`
    ObjectStart,
    /// `}`
    ObjectEnd,
    /// `[`
    ArrayStart,
    /// `]`
    ArrayEnd,
    /// An object key (always followed by the value's event(s)).
    Key(Cow<'a, str>),
    /// A string value.
    Str(Cow<'a, str>),
    /// An exact integer value.
    Int(i64),
    /// A floating-point value (literal had a fraction or exponent).
    Float(f64),
    /// `true` / `false`
    Bool(bool),
    /// `null`
    Null,
    /// End of a complete document; repeats on further calls.
    Eof,
}

/// What the parser expects next.
#[derive(Debug, Clone, Copy, PartialEq)]
enum State {
    /// A value (document start, after `,` in an array, or after `:`).
    Value,
    /// A value or `]` (right after `[`).
    ValueOrArrayEnd,
    /// A key or `}` (right after `{`).
    KeyOrObjectEnd,
    /// A key (after `,` in an object).
    Key,
    /// `,` or the closing bracket of the enclosing container.
    PostValue,
    /// Document complete; only whitespace may remain.
    End,
}

/// The pull parser. See the [module docs](self) for the event contract.
pub struct PullParser<'a> {
    b: &'a [u8],
    i: usize,
    /// Open-container count (the depth-limit counter).
    depth: usize,
    max_depth: usize,
    /// Container kinds, bit-packed (bit set = object, clear = array).
    kinds: Vec<u64>,
    state: State,
}

impl<'a> PullParser<'a> {
    /// Parser over `text` with the default depth limit
    /// ([`crate::json::DEFAULT_MAX_DEPTH`]).
    pub fn new(text: &'a str) -> Self {
        Self::with_max_depth(text, crate::json::DEFAULT_MAX_DEPTH)
    }

    /// Parser over `text` rejecting containers nested deeper than
    /// `max_depth`.
    pub fn with_max_depth(text: &'a str, max_depth: usize) -> Self {
        Self {
            b: text.as_bytes(),
            i: 0,
            depth: 0,
            max_depth,
            kinds: Vec::new(),
            state: State::Value,
        }
    }

    /// Byte offset of the parse cursor (for error reporting by callers).
    pub fn offset(&self) -> usize {
        self.i
    }

    /// Pull the next event. After the document completes, returns
    /// [`Event::Eof`] forever (or an error if non-whitespace trails).
    pub fn next(&mut self) -> Result<Event<'a>> {
        loop {
            self.ws();
            match self.state {
                State::End => {
                    if self.i != self.b.len() {
                        bail!("trailing garbage at byte {}", self.i);
                    }
                    return Ok(Event::Eof);
                }
                State::Value => return self.value(),
                State::ValueOrArrayEnd => {
                    if self.peek()? == b']' {
                        self.i += 1;
                        return self.close();
                    }
                    return self.value();
                }
                State::KeyOrObjectEnd => {
                    if self.peek()? == b'}' {
                        self.i += 1;
                        return self.close();
                    }
                    return self.key();
                }
                State::Key => return self.key(),
                State::PostValue => {
                    let in_object = self.top_is_object();
                    match self.peek()? {
                        b',' => {
                            self.i += 1;
                            self.state = if in_object { State::Key } else { State::Value };
                            // Loop: emit the next key/value event directly.
                        }
                        b'}' if in_object => {
                            self.i += 1;
                            return self.close();
                        }
                        b']' if !in_object => {
                            self.i += 1;
                            return self.close();
                        }
                        c => bail!(
                            "expected ',' or '{}' at byte {}, got '{}'",
                            if in_object { '}' } else { ']' },
                            self.i,
                            c as char
                        ),
                    }
                }
            }
        }
    }

    /// Parse one value-start token; containers push and emit their
    /// start event, scalars emit directly.
    fn value(&mut self) -> Result<Event<'a>> {
        match self.peek()? {
            b'{' => {
                self.i += 1;
                self.push(true)?;
                self.state = State::KeyOrObjectEnd;
                Ok(Event::ObjectStart)
            }
            b'[' => {
                self.i += 1;
                self.push(false)?;
                self.state = State::ValueOrArrayEnd;
                Ok(Event::ArrayStart)
            }
            b'"' => {
                let s = self.string()?;
                self.after_value();
                Ok(Event::Str(s))
            }
            b'n' => self.lit("null", Event::Null),
            b't' => self.lit("true", Event::Bool(true)),
            b'f' => self.lit("false", Event::Bool(false)),
            b'-' | b'0'..=b'9' => {
                let ev = self.number()?;
                self.after_value();
                Ok(ev)
            }
            c => bail!("unexpected '{}' at byte {}", c as char, self.i),
        }
    }

    fn key(&mut self) -> Result<Event<'a>> {
        let k = self.string()?;
        self.ws();
        self.eat(b':')?;
        self.state = State::Value;
        Ok(Event::Key(k))
    }

    fn lit(&mut self, s: &str, ev: Event<'a>) -> Result<Event<'a>> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            self.after_value();
            Ok(ev)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    /// A scalar or container just completed: decide the next state.
    fn after_value(&mut self) {
        self.state = if self.depth == 0 { State::End } else { State::PostValue };
    }

    /// Close the innermost container, emitting its end event.
    fn close(&mut self) -> Result<Event<'a>> {
        let was_object = self.top_is_object();
        self.depth -= 1;
        self.after_value();
        Ok(if was_object { Event::ObjectEnd } else { Event::ArrayEnd })
    }

    fn push(&mut self, is_object: bool) -> Result<()> {
        if self.depth >= self.max_depth {
            bail!("nesting depth exceeds {} at byte {}", self.max_depth, self.i);
        }
        let (word, bit) = (self.depth / 64, self.depth % 64);
        if word == self.kinds.len() {
            self.kinds.push(0);
        }
        if is_object {
            self.kinds[word] |= 1 << bit;
        } else {
            self.kinds[word] &= !(1 << bit);
        }
        self.depth += 1;
        Ok(())
    }

    fn top_is_object(&self) -> bool {
        debug_assert!(self.depth > 0);
        let d = self.depth - 1;
        (self.kinds[d / 64] >> (d % 64)) & 1 == 1
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, got '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    /// Parse a string literal. Fast path: no escapes — return a borrowed
    /// slice of the input (validated UTF-8). Slow path: decode escapes
    /// into an owned buffer.
    fn string(&mut self) -> Result<Cow<'a, str>> {
        self.eat(b'"')?;
        let start = self.i;
        while self.i < self.b.len() {
            match self.b[self.i] {
                b'"' => {
                    let s = std::str::from_utf8(&self.b[start..self.i])?;
                    self.i += 1;
                    return Ok(Cow::Borrowed(s));
                }
                b'\\' => return self.string_owned(start),
                c if c < 0x20 => bail!("control character in string at byte {}", self.i),
                _ => self.i += 1,
            }
        }
        bail!("unexpected end of input in string")
    }

    /// Escape-decoding path; `start` is the first content byte and
    /// `self.i` points at the first backslash (the escape-free prefix
    /// `[start..i]` carries over verbatim).
    fn string_owned(&mut self, start: usize) -> Result<Cow<'a, str>> {
        let mut out = String::from(std::str::from_utf8(&self.b[start..self.i])?);
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(Cow::Owned(out)),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => out.push(self.unicode_escape()?),
                        e => bail!("invalid escape '\\{}'", e as char),
                    }
                }
                c if c < 0x20 => bail!("control character in string"),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let seq_start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let bytes = self
                            .b
                            .get(seq_start..seq_start + len)
                            .ok_or_else(|| anyhow!("truncated UTF-8"))?;
                        out.push_str(std::str::from_utf8(bytes)?);
                        self.i = seq_start + len;
                    }
                }
            }
        }
    }

    /// Decode `XXXX` (and a following low surrogate if needed); the
    /// cursor sits just past the `\u`.
    fn unicode_escape(&mut self) -> Result<char> {
        let hex = self.b.get(self.i..self.i + 4).ok_or_else(|| anyhow!("truncated \\u escape"))?;
        let code = hex4(hex)?;
        self.i += 4;
        let ch = if (0xD800..0xDC00).contains(&code) {
            if self.b.get(self.i) == Some(&b'\\') && self.b.get(self.i + 1) == Some(&b'u') {
                let hex2 = self
                    .b
                    .get(self.i + 2..self.i + 6)
                    .ok_or_else(|| anyhow!("truncated surrogate"))?;
                let lo = hex4(hex2)?;
                if !(0xDC00..0xE000).contains(&lo) {
                    bail!("invalid low surrogate {lo:#x}");
                }
                self.i += 6;
                0x10000 + ((code - 0xD800) << 10) + (lo - 0xDC00)
            } else {
                bail!("lone high surrogate");
            }
        } else {
            code
        };
        char::from_u32(ch).ok_or_else(|| anyhow!("invalid codepoint {ch:#x}"))
    }

    fn number(&mut self) -> Result<Event<'a>> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len() && self.b[self.i].is_ascii_digit() {
            self.i += 1;
        }
        let mut is_float = false;
        if self.i < self.b.len() && self.b[self.i] == b'.' {
            is_float = true;
            self.i += 1;
            while self.i < self.b.len() && self.b[self.i].is_ascii_digit() {
                self.i += 1;
            }
        }
        if self.i < self.b.len() && matches!(self.b[self.i], b'e' | b'E') {
            is_float = true;
            self.i += 1;
            if self.i < self.b.len() && matches!(self.b[self.i], b'+' | b'-') {
                self.i += 1;
            }
            while self.i < self.b.len() && self.b[self.i].is_ascii_digit() {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        if !is_float {
            if text == "-" {
                bail!("invalid number at byte {start}");
            }
            return match text.parse::<i64>() {
                Ok(v) => Ok(Event::Int(v)),
                // The matrices are exact i64; falling back to f64 would
                // silently round the weights.
                Err(_) => bail!("integer literal '{text}' out of i64 range at byte {start}"),
            };
        }
        Ok(Event::Float(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn events(text: &str) -> Result<Vec<Event<'_>>> {
        let mut p = PullParser::new(text);
        let mut out = Vec::new();
        loop {
            let ev = p.next()?;
            let done = ev == Event::Eof;
            out.push(ev);
            if done {
                return Ok(out);
            }
        }
    }

    #[test]
    fn scalar_documents() {
        assert_eq!(events("42").unwrap(), vec![Event::Int(42), Event::Eof]);
        assert_eq!(events("-3.5").unwrap(), vec![Event::Float(-3.5), Event::Eof]);
        assert_eq!(events("null").unwrap(), vec![Event::Null, Event::Eof]);
        assert_eq!(events("false").unwrap(), vec![Event::Bool(false), Event::Eof]);
    }

    #[test]
    fn nested_stream_order() {
        let evs = events(r#"{"a": [1, {"b": null}], "c": true}"#).unwrap();
        assert_eq!(
            evs,
            vec![
                Event::ObjectStart,
                Event::Key("a".into()),
                Event::ArrayStart,
                Event::Int(1),
                Event::ObjectStart,
                Event::Key("b".into()),
                Event::Null,
                Event::ObjectEnd,
                Event::ArrayEnd,
                Event::Key("c".into()),
                Event::Bool(true),
                Event::ObjectEnd,
                Event::Eof,
            ]
        );
    }

    #[test]
    fn unescaped_strings_borrow() {
        let text = r#"["plain", "esc\n"]"#;
        let mut p = PullParser::new(text);
        assert_eq!(p.next().unwrap(), Event::ArrayStart);
        match p.next().unwrap() {
            Event::Str(Cow::Borrowed(s)) => assert_eq!(s, "plain"),
            other => panic!("expected borrowed string, got {other:?}"),
        }
        match p.next().unwrap() {
            Event::Str(Cow::Owned(s)) => assert_eq!(s, "esc\n"),
            other => panic!("expected owned string, got {other:?}"),
        }
    }

    #[test]
    fn eof_repeats_after_completion() {
        let mut p = PullParser::new("[]");
        assert_eq!(p.next().unwrap(), Event::ArrayStart);
        assert_eq!(p.next().unwrap(), Event::ArrayEnd);
        assert_eq!(p.next().unwrap(), Event::Eof);
        assert_eq!(p.next().unwrap(), Event::Eof);
    }

    #[test]
    fn depth_limit_is_a_counter_not_a_stack() {
        // 200k unclosed arrays: a recursive parser would blow the stack
        // long before reporting the depth error.
        let bomb = "[".repeat(200_000);
        let mut p = PullParser::new(&bomb);
        let err = loop {
            match p.next() {
                Ok(_) => continue,
                Err(e) => break e,
            }
        };
        assert!(format!("{err}").contains("nesting depth"), "got: {err}");
    }

    #[test]
    fn malformed_inputs_error() {
        for bad in [
            "", "{", "[1,]", "{\"a\":}", "[1 2]", "tru", "1 2", "{\"a\" 1}", "-", "\"\\q\"",
            "{\"a\":1,}", "[,1]",
        ] {
            assert!(events(bad).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn integer_overflow_is_an_error() {
        assert_eq!(
            events("9223372036854775807").unwrap()[0],
            Event::Int(i64::MAX),
        );
        assert_eq!(
            events("-9223372036854775808").unwrap()[0],
            Event::Int(i64::MIN),
        );
        assert!(events("9223372036854775808").is_err());
        assert!(events("-9223372036854775809").is_err());
        // Fraction/exponent forms still parse as floats.
        assert_eq!(
            events("9223372036854775808.0").unwrap()[0],
            Event::Float(9223372036854775808.0),
        );
    }

    #[test]
    fn surrogate_pairs_and_unicode() {
        assert_eq!(events(r#""\ud83d\ude00""#).unwrap()[0], Event::Str("😀".into()));
        assert_eq!(events("\"héllo😀\"").unwrap()[0], Event::Str("héllo😀".into()));
        assert!(events(r#""\ud83d""#).is_err());
        assert!(events(r#""\udc00""#).is_err());
        // A high surrogate must be followed by a *low* surrogate: a
        // non-surrogate or second high surrogate is an error, never a
        // u32 underflow (debug panic) or a garbage codepoint.
        assert!(events(r#""\ud800A""#).is_err());
        assert!(events(r#""\ud800\u0041""#).is_err());
        assert!(events(r#""\ud800\udbff""#).is_err());
    }

    /// `\u` escapes are exactly four hex digits — `from_str_radix`
    /// leniency (signs, shorter payloads) must not leak in.
    #[test]
    fn unicode_escape_requires_four_hex_digits() {
        assert_eq!(events(r#""\u0041""#).unwrap()[0], Event::Str("A".into()));
        assert!(events(r#""\u+041""#).is_err());
        assert!(events(r#""\u00 1""#).is_err());
        assert!(events(r#""\u004g""#).is_err());
    }
}
