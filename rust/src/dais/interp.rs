//! Bit-accurate (and cycle-accurate) DAIS interpretation — the
//! Verilator/GHDL substitute of this reproduction.
//!
//! The combinational interpreter evaluates a program on one input vector
//! with exact integer semantics and (in debug/checked mode) asserts every
//! intermediate value stays inside its statically-tracked [`crate::fixed::QInterval`] —
//! i.e. the synthesized bitwidths are sufficient and no wrap can occur.
//!
//! The pipelined interpreter replays a *stream* of input vectors through
//! a register-staged version of the program (one vector per cycle, II=1)
//! and checks that outputs equal the combinational results delayed by the
//! pipeline latency.

use super::{DaisOp, DaisProgram, RoundMode};

/// Apply a `Quant` op to a scalar (`shift < 0` is a left shift; rounding
/// then never applies).
pub fn quant_scalar(x: i64, shift: i32, round: RoundMode, clip_min: i64, clip_max: i64) -> i64 {
    let shifted = if shift <= 0 {
        x << -shift
    } else {
        match round {
            RoundMode::Floor => x >> shift,
            RoundMode::HalfUp => (x + (1 << (shift - 1))) >> shift,
        }
    };
    shifted.clamp(clip_min, clip_max)
}

/// Evaluate one op given resolved operand values.
#[inline]
fn eval_op(op: &DaisOp, values: &[i64], inputs: &[i64]) -> i64 {
    match *op {
        DaisOp::Input { index } => inputs[index as usize],
        DaisOp::Const { value } => value,
        DaisOp::AddShift { a, b, shift_a, shift_b, sub } => {
            let av = values[a as usize] << shift_a;
            let bv = values[b as usize] << shift_b;
            if sub {
                av - bv
            } else {
                av + bv
            }
        }
        DaisOp::Neg { a } => -values[a as usize],
        DaisOp::Relu { a } => values[a as usize].max(0),
        DaisOp::Quant { a, shift, round, clip_min, clip_max } => {
            quant_scalar(values[a as usize], shift, round, clip_min, clip_max)
        }
    }
}

/// Evaluate the program combinationally on one input vector.
///
/// Returns the output values (with output wiring shifts applied).
/// Panics if `inputs.len() != program.num_inputs`.
pub fn evaluate(program: &DaisProgram, inputs: &[i64]) -> Vec<i64> {
    assert_eq!(inputs.len(), program.num_inputs, "input arity mismatch");
    let mut values = vec![0i64; program.nodes.len()];
    for (i, node) in program.nodes.iter().enumerate() {
        values[i] = eval_op(&node.op, &values, inputs);
    }
    read_outputs(program, &values)
}

/// Like [`evaluate`] but additionally asserts every node value stays
/// inside its static [`crate::fixed::QInterval`] — the "no wrap possible" soundness
/// check (used by tests and the `simulate --checked` CLI path).
pub fn evaluate_checked(program: &DaisProgram, inputs: &[i64]) -> Vec<i64> {
    assert_eq!(inputs.len(), program.num_inputs, "input arity mismatch");
    let mut values = vec![0i64; program.nodes.len()];
    for (i, node) in program.nodes.iter().enumerate() {
        let v = eval_op(&node.op, &values, inputs);
        assert!(
            node.qint.contains(v, 0),
            "node {i} ({:?}) value {v} escapes tracked interval {:?}",
            node.op,
            node.qint
        );
        values[i] = v;
    }
    read_outputs(program, &values)
}

fn read_outputs(program: &DaisProgram, values: &[i64]) -> Vec<i64> {
    program
        .outputs
        .iter()
        .map(|o| {
            let v = values[o.node as usize];
            if o.shift >= 0 {
                v << o.shift
            } else {
                debug_assert_eq!(
                    v & ((1i64 << (-o.shift).min(63)) - 1),
                    0,
                    "negative output shift would drop set bits"
                );
                v >> -o.shift
            }
        })
        .collect()
}

/// Evaluate a batch of input vectors (row-major `[n][num_inputs]`).
pub fn evaluate_batch(program: &DaisProgram, batch: &[Vec<i64>]) -> Vec<Vec<i64>> {
    batch.iter().map(|x| evaluate(program, x)).collect()
}

/// Cycle-accurate simulation of a pipelined program.
///
/// `stages[i]` is the pipeline stage assigned to node `i` (see
/// [`crate::pipeline`]); an edge from `p` to `c` crosses
/// `stages[c] - stages[p]` registers. One input vector is consumed per
/// cycle (II = 1); the stream is flushed with zero vectors so every
/// result drains. Returns one output vector per input vector, delayed by
/// `latency` cycles internally but re-aligned before returning, so the
/// result is directly comparable with [`evaluate_batch`].
pub fn simulate_pipelined(
    program: &DaisProgram,
    stages: &[u32],
    stream: &[Vec<i64>],
) -> Vec<Vec<i64>> {
    assert_eq!(stages.len(), program.nodes.len());
    let latency = program
        .outputs
        .iter()
        .map(|o| stages[o.node as usize])
        .max()
        .unwrap_or(0) as usize;

    // Register file: for each node, a delay line long enough for its
    // furthest consumer (+ output read-out at `latency`).
    let mut line_len = vec![1usize; program.nodes.len()];
    for (c, node) in program.nodes.iter().enumerate() {
        for p in node.op.operands() {
            let d = (stages[c] - stages[p as usize]) as usize;
            line_len[p as usize] = line_len[p as usize].max(d + 1);
        }
    }
    for o in &program.outputs {
        let d = latency - stages[o.node as usize] as usize;
        line_len[o.node as usize] = line_len[o.node as usize].max(d + 1);
    }

    // delay_line[i][k] = value of node i computed k cycles ago.
    let mut delay: Vec<Vec<i64>> = line_len.iter().map(|&l| vec![0; l]).collect();
    let zero = vec![0i64; program.num_inputs];
    let total_cycles = stream.len() + latency;
    let mut outputs = Vec::with_capacity(stream.len());

    for cycle in 0..total_cycles {
        let inputs = stream.get(cycle).unwrap_or(&zero);
        // Shift every delay line by one cycle (registers clock in).
        for line in delay.iter_mut() {
            for k in (1..line.len()).rev() {
                line[k] = line[k - 1];
            }
        }
        // Combinational evaluation of the new front values, reading each
        // operand through the register count its edge crosses.
        for (i, node) in program.nodes.iter().enumerate() {
            let v = match node.op {
                DaisOp::Input { index } => inputs[index as usize],
                DaisOp::Const { value } => value,
                DaisOp::AddShift { a, b, shift_a, shift_b, sub } => {
                    let da = (stages[i] - stages[a as usize]) as usize;
                    let db = (stages[i] - stages[b as usize]) as usize;
                    let av = delay[a as usize][da] << shift_a;
                    let bv = delay[b as usize][db] << shift_b;
                    if sub {
                        av - bv
                    } else {
                        av + bv
                    }
                }
                DaisOp::Neg { a } => {
                    let da = (stages[i] - stages[a as usize]) as usize;
                    -delay[a as usize][da]
                }
                DaisOp::Relu { a } => {
                    let da = (stages[i] - stages[a as usize]) as usize;
                    delay[a as usize][da].max(0)
                }
                DaisOp::Quant { a, shift, round, clip_min, clip_max } => {
                    let da = (stages[i] - stages[a as usize]) as usize;
                    quant_scalar(delay[a as usize][da], shift, round, clip_min, clip_max)
                }
            };
            delay[i][0] = v;
        }
        // Read outputs for the input injected `latency` cycles ago.
        if cycle >= latency {
            let vals: Vec<i64> = program
                .outputs
                .iter()
                .map(|o| {
                    let d = latency - stages[o.node as usize] as usize;
                    let v = delay[o.node as usize][d];
                    if o.shift >= 0 {
                        v << o.shift
                    } else {
                        v >> -o.shift
                    }
                })
                .collect();
            outputs.push(vals);
        }
    }
    outputs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dais::DaisBuilder;
    use crate::fixed::QInterval;

    fn toy_program() -> DaisProgram {
        // y0 = (x0 + 2*x1) - x2 ; y1 = 4*(x0 + 2*x1)
        let mut b = DaisBuilder::new();
        let q = QInterval::new(-128, 127, 0);
        let x0 = b.input(0, q, 0);
        let x1 = b.input(1, q, 0);
        let x2 = b.input(2, q, 0);
        let t = b.add_shift(x0, x1, 1, false);
        let y0 = b.add_shift(t, x2, 0, true);
        b.output(y0, 0);
        b.output(t, 2);
        b.finish()
    }

    #[test]
    fn evaluate_toy() {
        let p = toy_program();
        let out = evaluate(&p, &[3, 5, 7]);
        assert_eq!(out, vec![3 + 10 - 7, 4 * 13]);
    }

    #[test]
    fn checked_matches_unchecked() {
        let p = toy_program();
        for x in [-127i64, -1, 0, 1, 127] {
            let inputs = [x, -x, x / 2];
            assert_eq!(evaluate(&p, &inputs), evaluate_checked(&p, &inputs));
        }
    }

    #[test]
    #[should_panic(expected = "escapes tracked interval")]
    fn checked_catches_out_of_range_inputs() {
        let p = toy_program();
        // 1000 is outside the declared input interval [-128, 127].
        evaluate_checked(&p, &[1000, 0, 0]);
    }

    #[test]
    fn quant_scalar_floor_and_halfup() {
        assert_eq!(quant_scalar(13, 2, RoundMode::Floor, -100, 100), 3);
        assert_eq!(quant_scalar(-13, 2, RoundMode::Floor, -100, 100), -4);
        assert_eq!(quant_scalar(13, 2, RoundMode::HalfUp, -100, 100), 3); // 3.25 -> 3
        assert_eq!(quant_scalar(14, 2, RoundMode::HalfUp, -100, 100), 4);
        assert_eq!(quant_scalar(200, 0, RoundMode::Floor, -100, 100), 100);
        assert_eq!(quant_scalar(-200, 0, RoundMode::HalfUp, -100, 100), -100);
    }

    #[test]
    fn pipelined_matches_combinational() {
        let p = toy_program();
        // Stage assignment: inputs 0, t 1, y0 2 (one register per level).
        let stages: Vec<u32> =
            p.nodes.iter().map(|n| n.depth).collect();
        let stream: Vec<Vec<i64>> = (0..20)
            .map(|i| vec![(i * 7 % 255) - 128, (i * 13 % 255) - 128, (i * 29 % 255) - 128])
            .collect();
        let expect = evaluate_batch(&p, &stream);
        let got = simulate_pipelined(&p, &stages, &stream);
        assert_eq!(got, expect);
    }

    #[test]
    fn pipelined_with_coarser_stages() {
        // Register only every other level: stages = depth / 2.
        let p = toy_program();
        let stages: Vec<u32> = p.nodes.iter().map(|n| n.depth / 2).collect();
        let stream: Vec<Vec<i64>> =
            (0..8).map(|i| vec![i, -i, 2 * i]).collect();
        assert_eq!(simulate_pipelined(&p, &stages, &stream), evaluate_batch(&p, &stream));
    }
}
