//! Graphviz export of adder graphs (the paper's Fig. 4 rendering):
//! square nodes for adders/subtractors, circles for inputs, edge labels
//! carrying the power-of-two coefficients.

use super::{DaisOp, DaisProgram, RoundMode};
use std::fmt::Write;

/// Render the program as a Graphviz `digraph`.
pub fn to_dot(program: &DaisProgram, name: &str) -> String {
    let mut s = String::new();
    writeln!(s, "digraph {name} {{").unwrap();
    writeln!(s, "    rankdir=LR;").unwrap();
    for (i, node) in program.nodes.iter().enumerate() {
        match node.op {
            DaisOp::Input { index } => {
                writeln!(
                    s,
                    "    n{i} [shape=circle, label=\"x{index}\", style=filled, fillcolor=lightblue];"
                )
                .unwrap();
            }
            DaisOp::Const { value } => {
                writeln!(s, "    n{i} [shape=circle, label=\"{value}\"];").unwrap();
            }
            DaisOp::AddShift { a, b, shift_a, shift_b, sub } => {
                let op = if sub { "−" } else { "+" };
                writeln!(
                    s,
                    "    n{i} [shape=box, label=\"{op}\\nd{}\"];",
                    node.depth
                )
                .unwrap();
                let lbl = |sh: u32| if sh == 0 { String::new() } else { format!("×2^{sh}") };
                writeln!(s, "    n{a} -> n{i} [label=\"{}\"];", lbl(shift_a)).unwrap();
                writeln!(
                    s,
                    "    n{b} -> n{i} [label=\"{}{}\", color={}];",
                    if sub { "−" } else { "" },
                    lbl(shift_b),
                    if sub { "red" } else { "black" }
                )
                .unwrap();
            }
            DaisOp::Neg { a } => {
                writeln!(s, "    n{i} [shape=box, label=\"neg\"];").unwrap();
                writeln!(s, "    n{a} -> n{i} [color=red];").unwrap();
            }
            DaisOp::Relu { a } => {
                writeln!(s, "    n{i} [shape=diamond, label=\"relu\"];").unwrap();
                writeln!(s, "    n{a} -> n{i};").unwrap();
            }
            DaisOp::Quant { a, shift, round, .. } => {
                let r = match round {
                    RoundMode::Floor => "floor",
                    RoundMode::HalfUp => "round",
                };
                writeln!(s, "    n{i} [shape=diamond, label=\"{r}>>{shift}\"];").unwrap();
                writeln!(s, "    n{a} -> n{i};").unwrap();
            }
        }
    }
    for (k, o) in program.outputs.iter().enumerate() {
        writeln!(
            s,
            "    y{k} [shape=doublecircle, label=\"y{k}\", style=filled, fillcolor=lightyellow];"
        )
        .unwrap();
        let lbl = if o.shift != 0 { format!("×2^{}", o.shift) } else { String::new() };
        writeln!(s, "    n{} -> y{k} [label=\"{lbl}\"];", o.node).unwrap();
    }
    writeln!(s, "}}").unwrap();
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dais::DaisBuilder;
    use crate::fixed::QInterval;

    #[test]
    fn dot_structure() {
        let mut b = DaisBuilder::new();
        let q = QInterval::new(-8, 7, 0);
        let x = b.input(0, q, 0);
        let y = b.input(1, q, 0);
        let t = b.add_shift(x, y, 2, true);
        b.output(t, 1);
        let p = b.finish();
        let dot = to_dot(&p, "g");
        assert!(dot.starts_with("digraph g {"));
        assert!(dot.contains("shape=circle"));
        assert!(dot.contains("shape=box"));
        assert!(dot.contains("×2^2"));
        assert!(dot.contains("doublecircle"));
        assert!(dot.trim_end().ends_with('}'));
        // One edge per operand + one per output.
        assert_eq!(dot.matches("->").count(), 3);
    }
}
