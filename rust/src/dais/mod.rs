//! DAIS — the Distributed Arithmetic Instruction Set (paper §5.2).
//!
//! DAIS is a low-level, SSA-form IR in which every operation directly
//! describes a piece of combinational hardware: shift-add/subtract nodes
//! (the adders of the adder graph), negations, constants, and the few
//! auxiliary ops the NN frontend needs (ReLU, requantization). Emitting
//! RTL from DAIS is a 1:1 mapping of ops to modules; interpreting DAIS
//! bit-accurately (see [`interp`]) is the Verilator substitute used for
//! verification throughout this reproduction.
//!
//! Value convention: every node's runtime value is a plain integer in the
//! *global LSB unit* of the enclosing computation. The per-node
//! [`QInterval`] metadata records the exact reachable range and the
//! guaranteed trailing-zero count (`exp`), which feed the cost model
//! (paper Eq. 1) without affecting the integer semantics.

pub mod dot;
pub mod interp;
pub mod verify;

use crate::fixed::QInterval;
use crate::util::fxhash::FxHashMap;

/// Index of a node inside a [`DaisProgram`].
pub type NodeId = u32;

/// Rounding behaviour of a [`DaisOp::Quant`] right-shift.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RoundMode {
    /// Truncate towards negative infinity (free in hardware: wiring).
    Floor,
    /// Round half-up: `(x + (1 << (s-1))) >> s` (costs one adder).
    HalfUp,
}

/// One DAIS operation. Operands always refer to earlier nodes (SSA).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DaisOp {
    /// External input number `index`.
    Input { index: u32 },
    /// Compile-time constant.
    Const { value: i64 },
    /// `(a << shift_a) + (b << shift_b)` or `(a << shift_a) - (b << shift_b)`.
    /// This is the paper's two-term subexpression `a ± (b << s)` (shifts
    /// are free wiring) and maps to one LUT-implemented adder/subtractor
    /// on the FPGA. CSE always emits `shift_a == 0`; the generalized form
    /// lets the final summation trees keep results positively signed.
    AddShift { a: NodeId, b: NodeId, shift_a: u32, shift_b: u32, sub: bool },
    /// `-a` (a hardware subtractor from zero).
    Neg { a: NodeId },
    /// `max(a, 0)` — ReLU for the NN frontend (a mux, no carry chain).
    Relu { a: NodeId },
    /// Arithmetic right shift by `shift` (negative = left shift, pure
    /// wiring) with the given rounding, then saturation to
    /// `[clip_min, clip_max]` — the NN requantization node.
    Quant { a: NodeId, shift: i32, round: RoundMode, clip_min: i64, clip_max: i64 },
}

impl DaisOp {
    /// Operand node ids of this op (0, 1 or 2 of them).
    pub fn operands(&self) -> impl Iterator<Item = NodeId> {
        let (a, b) = match *self {
            DaisOp::Input { .. } | DaisOp::Const { .. } => (None, None),
            DaisOp::AddShift { a, b, .. } => (Some(a), Some(b)),
            DaisOp::Neg { a } | DaisOp::Relu { a } | DaisOp::Quant { a, .. } => (Some(a), None),
        };
        debug_assert!(a.is_some() || b.is_none());
        a.into_iter().chain(b)
    }

    /// Whether this op consumes a carry chain (counts as an "adder" in
    /// the paper's adder-count metric).
    pub fn is_adder(&self) -> bool {
        match self {
            DaisOp::AddShift { .. } | DaisOp::Neg { .. } => true,
            DaisOp::Quant { round: RoundMode::HalfUp, shift, .. } => *shift > 0,
            _ => false,
        }
    }
}

/// A node: the op plus its statically-tracked interval and adder depth.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DaisNode {
    /// The operation.
    pub op: DaisOp,
    /// Exact reachable value range and trailing-zero count.
    pub qint: QInterval,
    /// Adder depth: longest chain of adder ops from any input.
    pub depth: u32,
}

/// An output of the program: a node, a free left-shift (wiring), applied
/// on read-out.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OutputSpec {
    /// Node whose value is exposed.
    pub node: NodeId,
    /// Free output wiring shift (may be negative: output consumes only
    /// the upper bits; semantics are exact — callers arrange shifts so no
    /// set bit is discarded).
    pub shift: i32,
}

/// A DAIS program: a topologically ordered op list plus output bindings.
///
/// Equality is structural and exact (node-by-node, output-by-output) —
/// the differential engine sweeps and the perf suite's A/B check use it
/// to prove two optimizer paths emitted bit-identical programs.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DaisProgram {
    /// Nodes in SSA order (operands strictly before users).
    pub nodes: Vec<DaisNode>,
    /// Output bindings, in output order.
    pub outputs: Vec<OutputSpec>,
    /// Number of external inputs.
    pub num_inputs: usize,
}

impl DaisProgram {
    /// Total adder/subtractor count (the paper's "adders" column).
    pub fn adder_count(&self) -> usize {
        self.nodes.iter().filter(|n| n.op.is_adder()).count()
    }

    /// Maximum adder depth over the outputs (the paper's "depth" column).
    pub fn adder_depth(&self) -> u32 {
        self.outputs
            .iter()
            .map(|o| self.nodes[o.node as usize].depth)
            .max()
            .unwrap_or(0)
    }

    /// Node metadata accessor.
    pub fn node(&self, id: NodeId) -> &DaisNode {
        &self.nodes[id as usize]
    }

    /// Iterate over (id, node).
    pub fn iter(&self) -> impl Iterator<Item = (NodeId, &DaisNode)> {
        self.nodes.iter().enumerate().map(|(i, n)| (i as NodeId, n))
    }
}

/// Reusable builder storage: the hash-consing table plus capacity hints
/// for the node/output slabs.
///
/// The node and output vectors themselves transfer into the finished
/// [`DaisProgram`] (programs outlive the compile — the coordinator
/// caches them), so what carries across compiles is the consing map's
/// buckets and right-sized initial capacities for the slabs. Obtain one
/// from [`DaisBuilder::finish_reuse`] and hand it back to
/// [`DaisBuilder::with_storage`] for the next compile.
#[derive(Debug, Default)]
pub struct BuilderStorage {
    cache: FxHashMap<DaisOp, NodeId>,
    nodes_hint: usize,
    outputs_hint: usize,
}

/// Incremental builder for [`DaisProgram`] with structural hash-consing:
/// emitting the same op twice returns the same node.
#[derive(Debug, Default)]
pub struct DaisBuilder {
    nodes: Vec<DaisNode>,
    cache: FxHashMap<DaisOp, NodeId>,
    outputs: Vec<OutputSpec>,
    num_inputs: usize,
}

impl DaisBuilder {
    /// New empty builder.
    pub fn new() -> Self {
        Self::default()
    }

    /// New builder reusing [`BuilderStorage`] from a previous compile:
    /// the consing map keeps its buckets and the slabs start at the
    /// previous program's sizes. Behaviorally identical to [`new`].
    ///
    /// [`new`]: DaisBuilder::new
    pub fn with_storage(mut storage: BuilderStorage) -> Self {
        storage.cache.clear();
        Self {
            nodes: Vec::with_capacity(storage.nodes_hint),
            cache: storage.cache,
            outputs: Vec::with_capacity(storage.outputs_hint),
            num_inputs: 0,
        }
    }

    fn push(&mut self, op: DaisOp, qint: QInterval, depth: u32) -> NodeId {
        if let Some(&id) = self.cache.get(&op) {
            return id;
        }
        let id = self.nodes.len() as NodeId;
        self.nodes.push(DaisNode { op, qint, depth });
        self.cache.insert(op, id);
        id
    }

    /// Declare input `index` with its quantized interval and initial
    /// depth (paper's `depth_int`, default 0).
    pub fn input(&mut self, index: usize, qint: QInterval, depth: u32) -> NodeId {
        self.num_inputs = self.num_inputs.max(index + 1);
        self.push(DaisOp::Input { index: index as u32 }, qint, depth)
    }

    /// Emit a constant.
    pub fn constant(&mut self, value: i64) -> NodeId {
        let tz = if value == 0 { 0 } else { value.trailing_zeros() as i32 };
        let q = QInterval::constant(value >> tz, tz);
        self.push(DaisOp::Const { value }, q, 0)
    }

    /// Emit `a ± (b << shift)` (the canonical CSE two-term form).
    pub fn add_shift(&mut self, a: NodeId, b: NodeId, shift: u32, sub: bool) -> NodeId {
        self.add_shift2(a, 0, b, shift, sub)
    }

    /// Emit `(a << shift_a) ± (b << shift_b)`.
    pub fn add_shift2(
        &mut self,
        a: NodeId,
        shift_a: u32,
        b: NodeId,
        shift_b: u32,
        sub: bool,
    ) -> NodeId {
        let qa = self.nodes[a as usize].qint.shl(shift_a as i32);
        let qb = self.nodes[b as usize].qint.shl(shift_b as i32);
        let q = if sub { qa.sub(&qb) } else { qa.add(&qb) };
        let depth = self.nodes[a as usize].depth.max(self.nodes[b as usize].depth) + 1;
        self.push(DaisOp::AddShift { a, b, shift_a, shift_b, sub }, q, depth)
    }

    /// Emit `-a`.
    pub fn neg(&mut self, a: NodeId) -> NodeId {
        let q = self.nodes[a as usize].qint.neg();
        let depth = self.nodes[a as usize].depth + 1;
        self.push(DaisOp::Neg { a }, q, depth)
    }

    /// Emit `relu(a)`.
    pub fn relu(&mut self, a: NodeId) -> NodeId {
        let qa = self.nodes[a as usize].qint;
        let q = QInterval::new(qa.min.max(0), qa.max.max(0), qa.exp);
        let depth = self.nodes[a as usize].depth;
        self.push(DaisOp::Relu { a }, q, depth)
    }

    /// Emit a requantization (shift-right + round + clip).
    pub fn quant(
        &mut self,
        a: NodeId,
        shift: i32,
        round: RoundMode,
        clip_min: i64,
        clip_max: i64,
    ) -> NodeId {
        let qa = self.nodes[a as usize].qint;
        // quant is monotone, so mapping the interval endpoints suffices.
        // In the integer-unit convention exp >= 0 (trailing zeros).
        debug_assert!(qa.exp >= 0, "DAIS nodes carry integer-unit intervals");
        let lo = interp::quant_scalar(qa.min << qa.exp, shift, round, clip_min, clip_max);
        let hi = interp::quant_scalar(qa.max << qa.exp, shift, round, clip_min, clip_max);
        let q = QInterval::new(lo, hi, 0);
        let depth = self.nodes[a as usize].depth
            + (round == RoundMode::HalfUp && shift > 0) as u32;
        self.push(DaisOp::Quant { a, shift, round, clip_min, clip_max }, q, depth)
    }

    /// Bind an output.
    pub fn output(&mut self, node: NodeId, shift: i32) {
        self.outputs.push(OutputSpec { node, shift });
    }

    /// Interval metadata of an already-built node.
    pub fn qint(&self, id: NodeId) -> QInterval {
        self.nodes[id as usize].qint
    }

    /// Depth metadata of an already-built node.
    pub fn depth(&self, id: NodeId) -> u32 {
        self.nodes[id as usize].depth
    }

    /// Finish building.
    pub fn finish(self) -> DaisProgram {
        DaisProgram { nodes: self.nodes, outputs: self.outputs, num_inputs: self.num_inputs }
    }

    /// Finish building and return the reusable storage alongside the
    /// program (see [`BuilderStorage`]). The program is byte-identical
    /// to what [`finish`] returns.
    ///
    /// [`finish`]: DaisBuilder::finish
    pub fn finish_reuse(mut self) -> (DaisProgram, BuilderStorage) {
        let storage = BuilderStorage {
            nodes_hint: self.nodes.len(),
            outputs_hint: self.outputs.len(),
            cache: {
                self.cache.clear();
                self.cache
            },
        };
        let program =
            DaisProgram { nodes: self.nodes, outputs: self.outputs, num_inputs: self.num_inputs };
        (program, storage)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q8() -> QInterval {
        QInterval::new(-128, 127, 0)
    }

    #[test]
    fn builder_hash_consing() {
        let mut b = DaisBuilder::new();
        let x = b.input(0, q8(), 0);
        let y = b.input(1, q8(), 0);
        let s1 = b.add_shift(x, y, 0, false);
        let s2 = b.add_shift(x, y, 0, false);
        assert_eq!(s1, s2);
        let s3 = b.add_shift(x, y, 0, true);
        assert_ne!(s1, s3);
        let p = b.finish();
        assert_eq!(p.nodes.len(), 4);
        assert_eq!(p.adder_count(), 2);
    }

    #[test]
    fn depth_tracking() {
        let mut b = DaisBuilder::new();
        let x = b.input(0, q8(), 0);
        let y = b.input(1, q8(), 0);
        let s = b.add_shift(x, y, 0, false);
        let t = b.add_shift(s, y, 2, true);
        b.output(t, 0);
        let p = b.finish();
        assert_eq!(p.adder_depth(), 2);
        assert_eq!(p.node(s).depth, 1);
    }

    #[test]
    fn interval_propagation_addshift() {
        let mut b = DaisBuilder::new();
        let x = b.input(0, QInterval::new(0, 15, 0), 0);
        let y = b.input(1, QInterval::new(0, 15, 0), 0);
        let s = b.add_shift(x, y, 2, false); // x + 4y in [0, 75]
        assert_eq!(b.qint(s).min, 0);
        assert_eq!(b.qint(s).max, 75);
        let d = b.add_shift(x, y, 0, true); // x - y in [-15, 15]
        assert_eq!((b.qint(d).min, b.qint(d).max), (-15, 15));
    }

    #[test]
    fn relu_interval() {
        let mut b = DaisBuilder::new();
        let x = b.input(0, QInterval::new(-10, 5, 0), 0);
        let r = b.relu(x);
        assert_eq!((b.qint(r).min, b.qint(r).max), (0, 5));
        // ReLU adds no adder depth.
        assert_eq!(b.depth(r), 0);
    }

    #[test]
    fn storage_reuse_is_behavior_free() {
        let build = |mut b: DaisBuilder| {
            let x = b.input(0, q8(), 0);
            let y = b.input(1, q8(), 0);
            let s = b.add_shift(x, y, 1, false);
            let t = b.add_shift(s, x, 0, true);
            // consing must still hit through a reused cache map
            assert_eq!(b.add_shift(x, y, 1, false), s);
            b.output(t, 2);
            b
        };
        let (fresh, storage) = build(DaisBuilder::new()).finish_reuse();
        let reused = build(DaisBuilder::with_storage(storage)).finish();
        assert_eq!(fresh, reused);
        assert_eq!(fresh.num_inputs, 2);
    }

    #[test]
    fn input_counting() {
        let mut b = DaisBuilder::new();
        b.input(2, q8(), 0);
        b.input(0, q8(), 0);
        let p = b.finish();
        assert_eq!(p.num_inputs, 3);
    }
}
