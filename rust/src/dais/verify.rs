//! Static verification of DAIS programs.
//!
//! Three checks, used pervasively by the test suite and callable from the
//! CLI:
//!
//! 1. **Well-formedness** — SSA operand ordering, shift bounds, interval
//!    consistency (re-derive every node's interval from its operands and
//!    compare), depth consistency.
//! 2. **Linearity extraction** — for programs built from the linear op
//!    subset (input/const/add-shift/neg), compute each node's exact
//!    symbolic form `c0 + Σ_j c_j · x_j` with i128 coefficients.
//! 3. **CMVM equivalence** — the program's outputs realize `x^T M`
//!    exactly, verified symbolically via (2).

use super::{DaisOp, DaisProgram};
use anyhow::{bail, ensure, Result};

/// Check structural well-formedness; returns an error describing the
/// first violation found.
pub fn check_well_formed(program: &DaisProgram) -> Result<()> {
    for (i, node) in program.nodes.iter().enumerate() {
        for op in node.op.operands() {
            ensure!(
                (op as usize) < i,
                "node {i}: operand {op} does not precede it (SSA violation)"
            );
        }
        match node.op {
            DaisOp::AddShift { a, b, shift_a, shift_b, sub } => {
                ensure!(shift_a <= 62 && shift_b <= 62, "node {i}: shift out of range");
                let qa = program.nodes[a as usize].qint.shl(shift_a as i32);
                let qb = program.nodes[b as usize].qint.shl(shift_b as i32);
                let expect = if sub { qa.sub(&qb) } else { qa.add(&qb) };
                ensure!(
                    node.qint == expect,
                    "node {i}: interval {:?} != derived {:?}",
                    node.qint,
                    expect
                );
                let d = program.nodes[a as usize]
                    .depth
                    .max(program.nodes[b as usize].depth)
                    + 1;
                ensure!(node.depth == d, "node {i}: depth {} != derived {d}", node.depth);
            }
            DaisOp::Neg { a } => {
                let expect = program.nodes[a as usize].qint.neg();
                ensure!(node.qint == expect, "node {i}: neg interval mismatch");
            }
            DaisOp::Input { .. } | DaisOp::Const { .. } => {}
            DaisOp::Relu { a } => {
                let qa = program.nodes[a as usize].qint;
                ensure!(
                    node.qint.min >= 0 && node.qint.max >= qa.max.max(0),
                    "node {i}: relu interval unsound"
                );
            }
            DaisOp::Quant { clip_min, clip_max, .. } => {
                ensure!(clip_min <= clip_max, "node {i}: empty clip range");
            }
        }
    }
    for (k, o) in program.outputs.iter().enumerate() {
        ensure!(
            (o.node as usize) < program.nodes.len(),
            "output {k}: node {} out of range",
            o.node
        );
    }
    Ok(())
}

/// Symbolic affine form of a value: `c0 + Σ_j coeffs[j] * x_j`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Affine {
    /// Constant term.
    pub c0: i128,
    /// One coefficient per program input.
    pub coeffs: Vec<i128>,
}

impl Affine {
    fn zero(n: usize) -> Self {
        Self { c0: 0, coeffs: vec![0; n] }
    }
}

/// Extract the exact affine form of every output. Fails if the program
/// uses non-linear ops (ReLU/Quant).
pub fn output_affine_forms(program: &DaisProgram) -> Result<Vec<Affine>> {
    let n = program.num_inputs;
    let mut forms: Vec<Affine> = Vec::with_capacity(program.nodes.len());
    for (i, node) in program.nodes.iter().enumerate() {
        let f = match node.op {
            DaisOp::Input { index } => {
                let mut f = Affine::zero(n);
                f.coeffs[index as usize] = 1;
                f
            }
            DaisOp::Const { value } => {
                let mut f = Affine::zero(n);
                f.c0 = value as i128;
                f
            }
            DaisOp::AddShift { a, b, shift_a, shift_b, sub } => {
                let fa = &forms[a as usize];
                let fb = &forms[b as usize];
                let ma = 1i128 << shift_a;
                let mb = (if sub { -1i128 } else { 1 }) << shift_b;
                Affine {
                    c0: ma * fa.c0 + mb * fb.c0,
                    coeffs: fa
                        .coeffs
                        .iter()
                        .zip(&fb.coeffs)
                        .map(|(&x, &y)| ma * x + mb * y)
                        .collect(),
                }
            }
            DaisOp::Neg { a } => {
                let fa = &forms[a as usize];
                Affine { c0: -fa.c0, coeffs: fa.coeffs.iter().map(|&x| -x).collect() }
            }
            DaisOp::Relu { .. } | DaisOp::Quant { .. } => {
                bail!("node {i}: program is not linear ({:?})", node.op)
            }
        };
        forms.push(f);
    }
    Ok(program
        .outputs
        .iter()
        .map(|o| {
            let f = &forms[o.node as usize];
            let m = if o.shift >= 0 { 1i128 << o.shift } else { 0 };
            if o.shift >= 0 {
                Affine {
                    c0: f.c0 * m,
                    coeffs: f.coeffs.iter().map(|&c| c * m).collect(),
                }
            } else {
                // Negative wiring shift: exact division (checked by interp
                // in debug); symbolically divide.
                let d = 1i128 << -o.shift;
                Affine {
                    c0: f.c0 / d,
                    coeffs: f.coeffs.iter().map(|&c| c / d).collect(),
                }
            }
        })
        .collect())
}

/// Verify the program computes `y_i = Σ_j x_j * matrix[j][i]` exactly
/// (matrix is `d_in × d_out`, row-major).
pub fn check_cmvm_equivalence(
    program: &DaisProgram,
    matrix: &[i64],
    d_in: usize,
    d_out: usize,
) -> Result<()> {
    ensure!(matrix.len() == d_in * d_out, "matrix shape mismatch");
    ensure!(program.num_inputs == d_in, "program arity {} != d_in {d_in}", program.num_inputs);
    ensure!(program.outputs.len() == d_out, "program outputs != d_out");
    let forms = output_affine_forms(program)?;
    for (i, f) in forms.iter().enumerate() {
        ensure!(f.c0 == 0, "output {i}: non-zero constant term {}", f.c0);
        for j in 0..d_in {
            let want = matrix[j * d_out + i] as i128;
            ensure!(
                f.coeffs[j] == want,
                "output {i}, input {j}: coefficient {} != matrix {want}",
                f.coeffs[j]
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dais::DaisBuilder;
    use crate::fixed::QInterval;

    #[test]
    fn affine_extraction() {
        let mut b = DaisBuilder::new();
        let q = QInterval::new(-8, 7, 0);
        let x0 = b.input(0, q, 0);
        let x1 = b.input(1, q, 0);
        let t = b.add_shift(x0, x1, 2, true); // x0 - 4 x1
        let u = b.neg(t); // -x0 + 4 x1
        b.output(u, 1); // -2 x0 + 8 x1
        let p = b.finish();
        check_well_formed(&p).unwrap();
        let forms = output_affine_forms(&p).unwrap();
        assert_eq!(forms[0].coeffs, vec![-2, 8]);
        assert_eq!(forms[0].c0, 0);
    }

    #[test]
    fn cmvm_equivalence_detects_mismatch() {
        let mut b = DaisBuilder::new();
        let q = QInterval::new(-8, 7, 0);
        let x0 = b.input(0, q, 0);
        let x1 = b.input(1, q, 0);
        let t = b.add_shift(x0, x1, 0, false); // x0 + x1
        b.output(t, 0);
        let p = b.finish();
        // matrix column (1, 1): ok.
        check_cmvm_equivalence(&p, &[1, 1], 2, 1).unwrap();
        // matrix column (1, 2): mismatch.
        assert!(check_cmvm_equivalence(&p, &[1, 2], 2, 1).is_err());
    }

    #[test]
    fn nonlinear_rejected() {
        let mut b = DaisBuilder::new();
        let x = b.input(0, QInterval::new(-8, 7, 0), 0);
        let r = b.relu(x);
        b.output(r, 0);
        let p = b.finish();
        assert!(output_affine_forms(&p).is_err());
    }
}
