//! RTL emission (paper §5.2): both emitters are thin structural walks
//! over the shared [`crate::netlist`] IR. Lowering — wire widths,
//! register delay lines, stage validation — happens once in
//! [`crate::netlist::Netlist::lower`]; Verilog and VHDL then print the
//! same netlist, so the two backends are pipelined-feature-identical by
//! construction (same registers, same widths, same latency).
//!
//! Generated designs are fully combinational or fully pipelined with
//! II = 1, exactly as the paper's standalone flow. Bit-and-cycle
//! accurate verification is performed by the netlist simulator
//! ([`crate::netlist::sim`], which also models wire-width truncation)
//! and the DAIS interpreter ([`crate::dais::interp`]); the emitted text
//! itself is pinned by golden-file snapshot tests
//! (`rust/tests/rtl_golden.rs`).

mod verilog;
mod vhdl;

pub use verilog::{emit_verilog, verilog_from_netlist};
pub use vhdl::{emit_vhdl, vhdl_from_netlist};
