//! RTL emission (paper §5.2): each DAIS op maps 1:1 to a Verilog/VHDL
//! statement; pipelining becomes register delay lines derived from a
//! stage assignment. Generated designs are fully combinational or fully
//! pipelined with II = 1, exactly as the paper's standalone flow.
//!
//! Bit-and-cycle-accurate verification is performed by the DAIS
//! interpreter ([`crate::dais::interp`], the Verilator substitute); the
//! emitters here are golden-tested for structure.

mod verilog;
mod vhdl;

pub use verilog::emit_verilog;
pub use vhdl::emit_vhdl;

use crate::dais::DaisProgram;

/// Bitwidth used for a node's wire (at least 1 bit).
pub(crate) fn wire_width(program: &DaisProgram, id: u32) -> u32 {
    program.nodes[id as usize].qint.width().max(1)
}

/// Width of an output port including its wiring shift.
pub(crate) fn output_width(program: &DaisProgram, k: usize) -> u32 {
    let o = &program.outputs[k];
    let q = program.nodes[o.node as usize].qint.shl(o.shift);
    q.width().max(1)
}
